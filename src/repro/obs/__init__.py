"""PPAC flight recorder: instruction ledger, serving metrics, trace export.

Always available, off by default: opening a :class:`Ledger` turns on
per-launch recording at the kernel dispatch chokepoint; a
:class:`MetricsRegistry` rides inside every server; a
:class:`TraceBuilder` serializes both into one Perfetto-loadable trace.
"""
from .ledger import LaunchRecord, Ledger, launch_cost, record_for
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .trace import TraceBuilder, annotate

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "LaunchRecord",
    "Ledger",
    "MetricsRegistry",
    "TraceBuilder",
    "annotate",
    "launch_cost",
    "record_for",
]
