"""Per-launch PPAC instruction ledger — the flight recorder's data plane.

Every call through the unified dispatch surface (``kernels.engine.
ppac_matmul``) can emit one :class:`LaunchRecord`: mode, backend, operand
shapes, K/L bit widths, the resolved tile plan/grid (noted by
``kernels.tiling`` during the launch), modeled PPAC cycles (the paper's
§III/IV accounting via ``core.cost_model``) and modeled energy calibrated
to the paper's 28nm Tables II–IV. Records accumulate into whatever
:class:`Ledger` context managers are open on the *current thread* — any
caller (the serving engine, ``CAMIndex``, the gf2 stack, ``LMServer``)
can open one around its work:

    with Ledger() as led:
        y = serve_dense(x, container, act_bits=8)
    led.total_cycles, led.total_energy_nj, led.by_mode()

Overhead-when-disabled guarantee: when no ledger is open,
``ppac_matmul`` performs exactly one ``active()`` check and *zero* other
per-launch Python work — no record construction, no timing calls, no
plan capture (asserted by tests/test_obs.py).

Launches recorded while a function is being traced under ``jax.jit``
happen once per *compile*, not once per execution; such records carry
``traced=True``. Eager launches (e.g. under ``jax.disable_jit()``)
record once per execution with real wall timestamps — that is the
configuration the CI golden gate replays a decode step in.

The costing helpers (:func:`launch_cost`, :func:`record_for`) are shared
bit-for-bit with the *static* accounting: ``serve.step.
serving_cycle_report`` is a replay over these same constructors, so the
recorded and estimated cycle totals can never diverge.
"""
from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from ..core import cost_model
from ..core.ppac import PPACConfig

_TLS = threading.local()


def _ledgers() -> List["Ledger"]:
    st = getattr(_TLS, "ledgers", None)
    if st is None:
        st = _TLS.ledgers = []
    return st


def active() -> bool:
    """True when at least one Ledger is open on this thread. This is the
    ONLY call the dispatch chokepoint makes when recording is off."""
    return bool(getattr(_TLS, "ledgers", None))


@dataclasses.dataclass
class LaunchRecord:
    """One PPAC launch as seen at the dispatch chokepoint."""

    mode: str                   # engine mode (or 'mvp_int8_mxu' fallback)
    backend: str                # resolved lowering: pallas | ref | mxu
    batch: int                  # streamed vectors in this launch
    m_rows: int                 # resident matrix rows
    n_bits: int                 # logical bit width of one row
    k_bits: int                 # matrix bits (1 for the 1-bit modes)
    l_bits: int                 # vector bits
    cycles: int                 # modeled PPAC cycles (§III/IV accounting)
    tile_ops: int               # array-cycles of work (energy accounting)
    energy_nj: float            # modeled energy, Tables II–IV calibration
    x_shape: Tuple[int, ...] = ()
    a_shape: Tuple[int, ...] = ()
    t_start: float = 0.0        # perf_counter at dispatch
    dur_s: float = 0.0          # host-side dispatch duration
    plan: Optional[Dict[str, Any]] = None   # resolved tile blocks + grid
    traced: bool = False        # recorded during jit tracing (per compile)
    phase: str = ""             # speculative phase tag: 'draft' | 'verify'
    window: int = 0             # tokens covered by the launch's batch dim
    worker: str = ""            # serving worker attribution: 'p0' | 'd0' | ''
    retry: bool = False         # launch belongs to a re-prefill cycle
    #   (a batched verify over k+1 drafted positions is otherwise
    #   indistinguishable from a decode step of the same shape; the
    #   window lets ledger replays split draft from verify cycles
    #   *per token*: cycles / (batch / window) / window)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["energy_nj"] = round(float(d["energy_nj"]), 6)
        return d


# Modes whose §III-C schedule runs K·L bit-plane-pair passes; everything
# else is a single 1-bit pass per streamed vector.
_MULTIBIT_PREFIX = "mvp_multibit"
_INT8_FALLBACK = "mvp_int8_mxu"


def launch_cost(mode: str, batch: int, m_rows: int, n_bits: int, *,
                k_bits: int = 1, l_bits: int = 1, k: int = 0,
                config: Optional[PPACConfig] = None,
                parallel_arrays: Optional[int] = None) -> Tuple[int, int]:
    """(cycles, tile_ops) for one launch.

    ``cycles`` is latency in the §III/IV accounting: each of the
    ``passes`` bit-plane-pair passes scans the virtualized tile grid and
    merges col-split partials (``cost_model.tiled_scan_merge_cycles``);
    top-k adds the bit-serial max-search drain per winner. ``tile_ops``
    is *work* — array-cycles summed over every tile regardless of how
    many physical arrays run them in parallel — and is what the energy
    model integrates.
    """
    base = cost_model.tiled_scan_merge_cycles(m_rows, n_bits, config,
                                              parallel_arrays)
    passes = 1
    if mode.startswith(_MULTIBIT_PREFIX) or mode == _INT8_FALLBACK:
        passes = max(1, k_bits) * max(1, l_bits)
    per = passes * base
    if mode == "topk" and k > 0:
        per += k * int(math.ceil(math.log2(n_bits + 1)))
    ops = passes * cost_model.tile_grid_ops(m_rows, n_bits, config)
    return batch * per, batch * ops


def record_for(mode: str, backend: str, *, batch: int, m_rows: int,
               n_bits: int, k_bits: int = 1, l_bits: int = 1, k: int = 0,
               x_shape: Tuple[int, ...] = (), a_shape: Tuple[int, ...] = (),
               config: Optional[PPACConfig] = None,
               parallel_arrays: Optional[int] = None,
               t_start: float = 0.0, dur_s: float = 0.0,
               plan: Optional[Dict[str, Any]] = None,
               traced: bool = False) -> LaunchRecord:
    """Build one costed LaunchRecord — THE shared constructor: the live
    ledger path and the static ``serving_cycle_report`` replay both come
    through here, which is what keeps them bit-exact with each other."""
    cycles, ops = launch_cost(mode, batch, m_rows, n_bits, k_bits=k_bits,
                              l_bits=l_bits, k=k, config=config,
                              parallel_arrays=parallel_arrays)
    energy = ops * cost_model.energy_per_cycle_pj(mode, config) * 1e-3
    return LaunchRecord(mode=mode, backend=backend, batch=batch,
                        m_rows=m_rows, n_bits=n_bits, k_bits=k_bits,
                        l_bits=l_bits, cycles=cycles, tile_ops=ops,
                        energy_nj=energy, x_shape=tuple(x_shape),
                        a_shape=tuple(a_shape), t_start=t_start,
                        dur_s=dur_s, plan=plan, traced=traced)


def record_launch(mode: str, backend: str, *, batch: int, m_rows: int,
                  n_bits: int, k_bits: int = 1, l_bits: int = 1, k: int = 0,
                  x_shape: Tuple[int, ...] = (),
                  a_shape: Tuple[int, ...] = (),
                  t_start: Optional[float] = None, dur_s: float = 0.0,
                  plan: Optional[Dict[str, Any]] = None,
                  traced: bool = False) -> None:
    """Append one launch to every open ledger (each costed under that
    ledger's own array config)."""
    t0 = time.perf_counter() if t_start is None else t_start
    ph, win = current_phase()
    wk = current_worker()
    rt = current_retry()
    for led in _ledgers():
        rec = record_for(
            mode, backend, batch=batch, m_rows=m_rows, n_bits=n_bits,
            k_bits=k_bits, l_bits=l_bits, k=k, x_shape=x_shape,
            a_shape=a_shape, config=led.config,
            parallel_arrays=led.parallel_arrays, t_start=t0, dur_s=dur_s,
            plan=plan, traced=traced)
        rec.phase, rec.window = ph, win
        rec.worker = wk
        rec.retry = rt
        led.records.append(rec)


class phase:
    """Tag launches with a speculative phase while the context is open.

    Works both eagerly and at jit-trace time (the tag is ambient Python
    state, read when the record is constructed — i.e. when the traced
    computation is *staged*, which is exactly when traced records are
    emitted):

        with ledger.phase("verify", window=k + 1):
            logits, cache = lm.verify(...)
    """

    def __init__(self, tag: str, *, window: int = 1, worker: str = "",
                 retry: bool = False):
        self.tag = tag
        self.window = int(window)
        self.worker = worker
        self.retry = bool(retry)

    def __enter__(self):
        st = getattr(_TLS, "phases", None)
        if st is None:
            st = _TLS.phases = []
        if st:
            if not self.worker:
                self.worker = st[-1][2]  # nested phases inherit the worker
            # retry propagates down: the scheduler opens the retry phase,
            # the executor nests its worker phase inside it
            self.retry = self.retry or st[-1][3]
        st.append((self.tag, self.window, self.worker, self.retry))
        return self

    def __exit__(self, *exc) -> bool:
        _TLS.phases.pop()
        return False


def current_phase() -> Tuple[str, int]:
    """(tag, window) of the innermost open phase ('', 0 outside any)."""
    st = getattr(_TLS, "phases", None)
    return st[-1][:2] if st else ("", 0)


def current_worker() -> str:
    """Worker tag of the innermost open phase ('' outside any — the
    single-device server never tags workers)."""
    st = getattr(_TLS, "phases", None)
    return st[-1][2] if st else ""


def current_retry() -> bool:
    """True while a retry-tagged phase is open (re-prefill cycles)."""
    st = getattr(_TLS, "phases", None)
    return st[-1][3] if st else False


def note_plan(plan) -> None:
    """Called by ``kernels.tiling`` when a tile plan resolves while a
    launch is being recorded; no-op (and never called — the chokepoint
    guards on :func:`active`) otherwise."""
    notes = getattr(_TLS, "plan_notes", None)
    if notes is not None:
        notes.append(dict(blocks=plan.blocks, grid=tuple(plan.grid)))


def _is_tracer(x) -> bool:
    try:
        import jax
        return isinstance(x, jax.core.Tracer)
    except Exception:  # pragma: no cover - jax.core moved/absent
        return False


def _geometry(mode: str, x, a, kwargs: Dict[str, Any]):
    """(batch, m_rows, n_bits, k_bits, l_bits, k) from the dispatch args."""
    xs = tuple(int(d) for d in getattr(x, "shape", ()))
    ash = tuple(int(d) for d in getattr(a, "shape", ()))
    batch = 1
    for d in xs[:-1]:
        batch *= d
    if mode == "mvp_multibit" and len(ash) >= 2:
        m_rows, n_bits = ash[-2], ash[-1]
    else:
        m_rows = ash[-2] if len(ash) >= 2 else 1
        n_bits = int(kwargs.get("n", (ash[-1] if ash else 1) * 32))
    k_bits = int(kwargs.get("k_bits", 1))
    l_bits = int(kwargs.get("l_bits", 1))
    if not mode.startswith(_MULTIBIT_PREFIX):
        k_bits = l_bits = 1
    return xs, ash, batch, m_rows, n_bits, k_bits, l_bits, \
        int(kwargs.get("k", 0) or 0)


def recorded_launch(fn, mode: str, backend: str, x, a,
                    kwargs: Dict[str, Any]):
    """Run one engine dispatch with recording: time it, collect the tile
    plan(s) resolved during the call, then append a costed record to every
    open ledger. Only reached when :func:`active` — the disabled path
    never enters this function."""
    prev = getattr(_TLS, "plan_notes", None)
    _TLS.plan_notes = []
    t0 = time.perf_counter()
    try:
        out = fn(x, a, backend=backend, **kwargs)
    finally:
        notes, _TLS.plan_notes = _TLS.plan_notes, prev
    dur = time.perf_counter() - t0
    xs, ash, batch, m_rows, n_bits, k_bits, l_bits, k = \
        _geometry(mode, x, a, kwargs)
    record_launch(mode, backend, batch=batch, m_rows=m_rows, n_bits=n_bits,
                  k_bits=k_bits, l_bits=l_bits, k=k, x_shape=xs,
                  a_shape=ash, t_start=t0, dur_s=dur,
                  plan=notes[-1] if notes else None,
                  traced=_is_tracer(x) or _is_tracer(a))
    return out


class Ledger:
    """Per-thread launch accumulator (context manager; nestable — every
    open ledger sees every launch, each costed at its own geometry)."""

    def __init__(self, config: Optional[PPACConfig] = None,
                 parallel_arrays: Optional[int] = None):
        self.config = config or PPACConfig()
        self.parallel_arrays = parallel_arrays
        self.records: List[LaunchRecord] = []

    def __enter__(self) -> "Ledger":
        _ledgers().append(self)
        return self

    def __exit__(self, *exc) -> bool:
        _ledgers().remove(self)
        return False

    # -- aggregation ---------------------------------------------------------

    @property
    def num_launches(self) -> int:
        return len(self.records)

    @property
    def total_cycles(self) -> int:
        return sum(r.cycles for r in self.records)

    @property
    def total_tile_ops(self) -> int:
        return sum(r.tile_ops for r in self.records)

    @property
    def total_energy_nj(self) -> float:
        return sum(r.energy_nj for r in self.records)

    def by_mode(self) -> Dict[str, dict]:
        out: Dict[str, dict] = {}
        for r in self.records:
            agg = out.setdefault(r.mode, dict(launches=0, cycles=0,
                                              tile_ops=0, energy_nj=0.0))
            agg["launches"] += 1
            agg["cycles"] += r.cycles
            agg["tile_ops"] += r.tile_ops
            agg["energy_nj"] += r.energy_nj
        return out

    def by_worker(self) -> Dict[str, dict]:
        """Aggregate by serving-worker tag ('' for untagged launches) —
        the disaggregated server's per-pool cycle/energy attribution
        (prefill workers vs the resident decoder)."""
        out: Dict[str, dict] = {}
        for r in self.records:
            agg = out.setdefault(r.worker, dict(launches=0, cycles=0,
                                                energy_nj=0.0, tokens=0))
            agg["launches"] += 1
            agg["cycles"] += r.cycles
            agg["energy_nj"] += r.energy_nj
            agg["tokens"] += r.window
        return out

    def by_phase(self) -> Dict[str, dict]:
        """Aggregate by speculative phase tag ('' for untagged launches).
        ``tokens`` sums each launch's window (the decoded positions the
        launch covers), so draft and verify cycles divide out per token."""
        out: Dict[str, dict] = {}
        for r in self.records:
            agg = out.setdefault(r.phase, dict(launches=0, cycles=0,
                                               energy_nj=0.0, tokens=0))
            agg["launches"] += 1
            agg["cycles"] += r.cycles
            agg["energy_nj"] += r.energy_nj
            agg["tokens"] += r.window
        return out

    def by_retry(self) -> Dict[bool, dict]:
        """Aggregate by retry flag — splits first-attempt prefill/decode
        cycles from re-prefill cycles after a worker crash, so the cost
        of recovery is separable in recorded traces."""
        out: Dict[bool, dict] = {}
        for r in self.records:
            agg = out.setdefault(r.retry, dict(launches=0, cycles=0,
                                               energy_nj=0.0))
            agg["launches"] += 1
            agg["cycles"] += r.cycles
            agg["energy_nj"] += r.energy_nj
        return out

    def summary(self) -> dict:
        return dict(launches=self.num_launches, cycles=self.total_cycles,
                    tile_ops=self.total_tile_ops,
                    energy_nj=self.total_energy_nj,
                    array=f"{self.config.m}x{self.config.n}",
                    by_mode=self.by_mode())
