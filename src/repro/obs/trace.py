"""Chrome-trace (``chrome://tracing`` / Perfetto) export for the flight
recorder, plus optional ``jax.profiler`` trace-annotation hooks.

A :class:`TraceBuilder` collects complete ("ph": "X") events on named
tracks and serializes the standard Trace Event JSON format: server step
spans (prefill batches, decode steps) land on one track, instruction-
ledger launch events (with cycles / energy / tile-plan args) interleave
on another — all on the same ``perf_counter`` clock, shifted so the
earliest event sits at ts=0. Load the written file directly in Perfetto
(https://ui.perfetto.dev) or chrome://tracing.

``annotate(name)`` additionally brackets a region as a
``jax.profiler.TraceAnnotation`` when the profiler is available, so the
same spans show up inside an XLA profiler capture; it degrades to a
no-op silently.
"""
from __future__ import annotations

import contextlib
import json
import time
from typing import Any, Dict, List, Optional

_PID = 1


def annotate(name: str):
    """jax.profiler.TraceAnnotation(name) when available, else a no-op
    context manager — safe to use unconditionally on hot paths."""
    try:
        import jax.profiler
        return jax.profiler.TraceAnnotation(name)
    except Exception:  # pragma: no cover - profiler unavailable
        return contextlib.nullcontext()


class TraceBuilder:
    """Accumulates trace events; serializes Trace Event Format JSON."""

    def __init__(self):
        self._events: List[dict] = []   # with absolute t_start seconds
        self._tids: Dict[str, int] = {}

    def _tid(self, track: str) -> int:
        tid = self._tids.get(track)
        if tid is None:
            tid = self._tids[track] = len(self._tids) + 1
        return tid

    def event(self, name: str, *, track: str, t_start: float, dur_s: float,
              args: Optional[Dict[str, Any]] = None) -> None:
        """One complete event; ``t_start`` is a ``perf_counter`` reading."""
        self._events.append(dict(name=name, track=track, t=t_start,
                                 dur=max(dur_s, 1e-7), args=args or {}))

    @contextlib.contextmanager
    def span(self, name: str, *, track: str = "server",
             args: Optional[Dict[str, Any]] = None):
        """Context manager timing one span onto ``track``."""
        t0 = time.perf_counter()
        try:
            yield
        finally:
            self.event(name, track=track, t_start=t0,
                       dur_s=time.perf_counter() - t0, args=args)

    def add_ledger(self, ledger, *, track: str = "ppac") -> None:
        """Interleave every ledger launch record as one event on ``track``
        (cycles / energy / plan ride in the event args)."""
        for rec in ledger.records:
            name = f"{rec.mode}[{rec.backend}]"
            if rec.traced:
                name += " (traced)"
            self.event(name, track=track, t_start=rec.t_start,
                       dur_s=rec.dur_s, args=rec.as_dict())

    @property
    def num_events(self) -> int:
        return len(self._events)

    def to_dict(self) -> dict:
        """Trace Event Format: metadata naming each track, then the events
        sorted by timestamp (ts in microseconds, earliest event at 0)."""
        base = min((e["t"] for e in self._events), default=0.0)
        out: List[dict] = []
        for e in self._events:  # assign tids in first-seen track order
            self._tid(e["track"])
        for track in self._tids:
            out.append(dict(name="thread_name", ph="M", pid=_PID,
                            tid=self._tid(track),
                            args=dict(name=track)))
        for e in sorted(self._events, key=lambda e: e["t"]):
            out.append(dict(name=e["name"], ph="X", pid=_PID,
                            tid=self._tid(e["track"]),
                            ts=(e["t"] - base) * 1e6,
                            dur=e["dur"] * 1e6, args=e["args"]))
        return {"traceEvents": out, "displayTimeUnit": "ms"}

    def write(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=1)
