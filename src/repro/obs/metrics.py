"""Lightweight serving-metrics registry: counters, gauges, streaming
histograms — no third-party dependencies.

One :class:`MetricsRegistry` per server records request-level telemetry
(TTFT, time-per-output-token, queue wait, admission/eviction/retirement
counts, bucket fill ratios, slot occupancy). Histograms are *streaming*:
a fixed geometric bucket grid (quarter-decade resolution over 1e-7..1e5,
unit-agnostic — seconds, ratios and counts all fit) plus exact count /
sum / min / max, so memory is O(buckets) regardless of traffic and
percentiles are bucket-interpolated estimates.

``snapshot()`` returns one plain nested dict (JSON-serializable — the CI
artifact format); ``prometheus_text()`` renders the registry in the
Prometheus exposition format (counters, gauges, and summary-style
quantiles for histograms).

Metrics optionally carry *labels* (``registry.histogram("lm_prefill_s",
worker="p0", role="prefill")``): each distinct label set is its own
metric instance, keyed — and snapshotted — under the canonical
``name{k="v",...}`` rendering, so the multi-device server attributes
per-worker latency without disturbing the unlabeled aggregate series
(and their snapshot keys) that single-device consumers read.
``prometheus_text()`` escapes label values per the exposition format
(backslash, double quote, newline).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Sequence, Union

# quarter-decade geometric grid: 1e-7 .. 1e5
_DEFAULT_BOUNDS = tuple(10.0 ** (e / 4.0) for e in range(-28, 21))


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def snapshot(self) -> int:
        return self.value


class Gauge:
    """Last-set value (plus the running max, for capacity headroom)."""

    __slots__ = ("value", "max")

    def __init__(self):
        self.value = 0.0
        self.max = 0.0

    def set(self, v: float) -> None:
        self.value = v
        self.max = max(self.max, v)

    def snapshot(self) -> dict:
        return dict(value=self.value, max=self.max)


class Histogram:
    """Streaming histogram over a fixed geometric bucket grid."""

    __slots__ = ("bounds", "counts", "count", "total", "min", "max")

    def __init__(self, bounds: Optional[Sequence[float]] = None):
        self.bounds = tuple(bounds) if bounds else _DEFAULT_BOUNDS
        assert self.bounds == tuple(sorted(self.bounds))
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def record(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        lo, hi = 0, len(self.bounds)
        while lo < hi:  # first bucket with bound >= v
            mid = (lo + hi) // 2
            if v <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1

    def percentile(self, p: float) -> Optional[float]:
        """Bucket-interpolated p-th percentile (p in [0, 100])."""
        if self.count == 0:
            return None
        target = max(1e-12, p / 100.0) * self.count
        cum = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.bounds[i - 1] if i > 0 else self.min
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if hi <= lo:
                    return lo
                return lo + (hi - lo) * (target - cum) / c
            cum += c
        return self.max

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None

    def snapshot(self) -> dict:
        if self.count == 0:
            return dict(count=0)
        return dict(count=self.count, sum=self.total, mean=self.mean,
                    min=self.min, max=self.max, p50=self.percentile(50),
                    p90=self.percentile(90), p99=self.percentile(99))


Metric = Union[Counter, Gauge, Histogram]


def escape_label_value(v) -> str:
    """Prometheus exposition-format label-value escaping: backslash,
    double quote, and newline must be escaped or a hostile/odd value
    (a worker id with a quote, a path) corrupts the whole scrape."""
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _render_labels(labels: Dict[str, str]) -> str:
    """Canonical ``{k="v",...}`` rendering (sorted, escaped); '' if none."""
    if not labels:
        return ""
    inner = ",".join(f'{k}="{escape_label_value(v)}"'
                     for k, v in sorted(labels.items()))
    return "{" + inner + "}"


class MetricsRegistry:
    """Name (+ label set) -> metric map with get-or-create accessors."""

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}
        # key -> (bare name, labels dict) for exposition rendering
        self._meta: Dict[str, tuple] = {}

    def _get(self, name: str, cls, labels: Dict[str, str], *args) -> Metric:
        key = name + _render_labels(labels)
        m = self._metrics.get(key)
        if m is None:
            m = self._metrics[key] = cls(*args)
            self._meta[key] = (name, dict(labels))
        assert isinstance(m, cls), \
            f"metric {key!r} already registered as {type(m).__name__}"
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(name, Counter, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(name, Gauge, labels)

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None,
                  **labels) -> Histogram:
        return self._get(name, Histogram, labels, bounds)

    def snapshot(self) -> dict:
        """Plain nested dict of every metric (JSON-serializable).
        Unlabeled metrics keep their bare-name keys; labeled instances
        appear under the canonical ``name{k="v"}`` key."""
        return {name: m.snapshot() for name, m in sorted(self._metrics.items())}

    def total(self, name: str) -> int:
        """Sum a counter family across every label set (bare + labeled) —
        e.g. ``total("lm_requests_failed")`` over all ``reason=`` labels,
        the conservation-law side the chaos gate checks. Zero if the
        family was never touched."""
        return sum(m.value for key, m in self._metrics.items()
                   if isinstance(m, Counter)
                   and self._meta.get(key, (key, {}))[0] == name)

    def prometheus_text(self) -> str:
        """Prometheus exposition-format dump (histograms as summaries)."""
        lines: List[str] = []
        typed = set()
        for key, m in sorted(self._metrics.items()):
            name, labels = self._meta.get(key, (key, {}))
            pname = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
            lab = _render_labels(labels)
            if isinstance(m, Counter):
                if pname not in typed:
                    lines.append(f"# TYPE {pname} counter")
                    typed.add(pname)
                lines.append(f"{pname}{lab} {m.value}")
            elif isinstance(m, Gauge):
                if pname not in typed:
                    lines.append(f"# TYPE {pname} gauge")
                    typed.add(pname)
                lines.append(f"{pname}{lab} {m.value:g}")
                lines.append(f"{pname}_max{lab} {m.max:g}")
            else:
                if pname not in typed:
                    lines.append(f"# TYPE {pname} summary")
                    typed.add(pname)
                for q in (0.5, 0.9, 0.99):
                    v = m.percentile(q * 100)
                    if v is not None:
                        qlab = _render_labels(
                            dict(labels, quantile=f"{q:g}"))
                        lines.append(f"{pname}{qlab} {v:g}")
                lines.append(f"{pname}_sum{lab} {m.total:g}")
                lines.append(f"{pname}_count{lab} {m.count}")
        return "\n".join(lines) + "\n"
