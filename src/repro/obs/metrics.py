"""Lightweight serving-metrics registry: counters, gauges, streaming
histograms — no third-party dependencies.

One :class:`MetricsRegistry` per server records request-level telemetry
(TTFT, time-per-output-token, queue wait, admission/eviction/retirement
counts, bucket fill ratios, slot occupancy). Histograms are *streaming*:
a fixed geometric bucket grid (quarter-decade resolution over 1e-7..1e5,
unit-agnostic — seconds, ratios and counts all fit) plus exact count /
sum / min / max, so memory is O(buckets) regardless of traffic and
percentiles are bucket-interpolated estimates.

``snapshot()`` returns one plain nested dict (JSON-serializable — the CI
artifact format); ``prometheus_text()`` renders the registry in the
Prometheus exposition format (counters, gauges, and summary-style
quantiles for histograms).
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional, Sequence, Union

# quarter-decade geometric grid: 1e-7 .. 1e5
_DEFAULT_BOUNDS = tuple(10.0 ** (e / 4.0) for e in range(-28, 21))


class Counter:
    """Monotonically increasing count."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def snapshot(self) -> int:
        return self.value


class Gauge:
    """Last-set value (plus the running max, for capacity headroom)."""

    __slots__ = ("value", "max")

    def __init__(self):
        self.value = 0.0
        self.max = 0.0

    def set(self, v: float) -> None:
        self.value = v
        self.max = max(self.max, v)

    def snapshot(self) -> dict:
        return dict(value=self.value, max=self.max)


class Histogram:
    """Streaming histogram over a fixed geometric bucket grid."""

    __slots__ = ("bounds", "counts", "count", "total", "min", "max")

    def __init__(self, bounds: Optional[Sequence[float]] = None):
        self.bounds = tuple(bounds) if bounds else _DEFAULT_BOUNDS
        assert self.bounds == tuple(sorted(self.bounds))
        self.counts = [0] * (len(self.bounds) + 1)
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def record(self, v: float) -> None:
        v = float(v)
        self.count += 1
        self.total += v
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        lo, hi = 0, len(self.bounds)
        while lo < hi:  # first bucket with bound >= v
            mid = (lo + hi) // 2
            if v <= self.bounds[mid]:
                hi = mid
            else:
                lo = mid + 1
        self.counts[lo] += 1

    def percentile(self, p: float) -> Optional[float]:
        """Bucket-interpolated p-th percentile (p in [0, 100])."""
        if self.count == 0:
            return None
        target = max(1e-12, p / 100.0) * self.count
        cum = 0.0
        for i, c in enumerate(self.counts):
            if c == 0:
                continue
            if cum + c >= target:
                lo = self.bounds[i - 1] if i > 0 else self.min
                hi = self.bounds[i] if i < len(self.bounds) else self.max
                lo = max(lo, self.min)
                hi = min(hi, self.max)
                if hi <= lo:
                    return lo
                return lo + (hi - lo) * (target - cum) / c
            cum += c
        return self.max

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None

    def snapshot(self) -> dict:
        if self.count == 0:
            return dict(count=0)
        return dict(count=self.count, sum=self.total, mean=self.mean,
                    min=self.min, max=self.max, p50=self.percentile(50),
                    p90=self.percentile(90), p99=self.percentile(99))


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Name -> metric map with get-or-create accessors."""

    def __init__(self):
        self._metrics: Dict[str, Metric] = {}

    def _get(self, name: str, cls, *args) -> Metric:
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = cls(*args)
        assert isinstance(m, cls), \
            f"metric {name!r} already registered as {type(m).__name__}"
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str,
                  bounds: Optional[Sequence[float]] = None) -> Histogram:
        return self._get(name, Histogram, bounds)

    def snapshot(self) -> dict:
        """Plain nested dict of every metric (JSON-serializable)."""
        return {name: m.snapshot() for name, m in sorted(self._metrics.items())}

    def prometheus_text(self) -> str:
        """Prometheus exposition-format dump (histograms as summaries)."""
        lines: List[str] = []
        for name, m in sorted(self._metrics.items()):
            pname = re.sub(r"[^a-zA-Z0-9_:]", "_", name)
            if isinstance(m, Counter):
                lines.append(f"# TYPE {pname} counter")
                lines.append(f"{pname} {m.value}")
            elif isinstance(m, Gauge):
                lines.append(f"# TYPE {pname} gauge")
                lines.append(f"{pname} {m.value:g}")
                lines.append(f"{pname}_max {m.max:g}")
            else:
                lines.append(f"# TYPE {pname} summary")
                for q in (0.5, 0.9, 0.99):
                    v = m.percentile(q * 100)
                    if v is not None:
                        lines.append(f'{pname}{{quantile="{q:g}"}} {v:g}')
                lines.append(f"{pname}_sum {m.total:g}")
                lines.append(f"{pname}_count {m.count}")
        return "\n".join(lines) + "\n"
