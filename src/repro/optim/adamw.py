"""AdamW with optional quantized moments (low-memory optimizer state).

No optax dependency — the optimizer is part of the substrate. With
``quantized_state``: the first moment (mu) is stored int8 with per-block
fp32 max-scales; the second moment (nu) is stored bf16. Why not int8 for
nu: block max-scaling underflows small v elements to exactly 0, and
mh/(sqrt(0)+eps) explodes — observed as immediate divergence in tests.
bf16 keeps nu's full dynamic range at 0.4% relative error. Net state is
~3.1 bytes/param vs 8 (2.6x), which is what lets the 1T-param MoE fit the
per-chip HBM budget at 512 chips (EXPERIMENTS.md §Dry-run). Tests check a
quantized-state run stays within tolerance of fp32 and converges.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantized_state: bool = False   # int8 moments
    block: int = 256                # quantization block size


# -- int8 block quantization ---------------------------------------------------

def _q8(x, block: int, block_align: int = 512):
    flat = x.reshape(-1)
    pad = (-flat.size) % (block * block_align)  # align block count for ZeRO
    fp = jnp.pad(flat, (0, pad)).reshape(-1, block)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(fp / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    return flat[:_size(shape)].reshape(shape)


def _size(shape) -> int:
    n = 1
    for s in shape:
        n *= int(s)
    return n


def _moment_init(p, cfg: AdamWConfig, kind: str = "mu"):
    if cfg.quantized_state:
        if kind == "mu":
            q, s = _q8(jnp.zeros_like(p, jnp.float32), cfg.block)
            return {"q": q, "s": s}
        return jnp.zeros(p.shape, jnp.bfloat16)
    return jnp.zeros_like(p, jnp.float32)


def _moment_get(m, shape, cfg: AdamWConfig, kind: str = "mu"):
    if cfg.quantized_state:
        if kind == "mu":
            return _dq8(m["q"], m["s"], shape)
        return m.astype(jnp.float32)
    return m


def _moment_set(x, cfg: AdamWConfig, kind: str = "mu"):
    if cfg.quantized_state:
        if kind == "mu":
            q, s = _q8(x, cfg.block)
            return {"q": q, "s": s}
        return x.astype(jnp.bfloat16)
    return x


# -- optimizer ----------------------------------------------------------------

def opt_init(params, cfg: AdamWConfig):
    return {
        "step": jnp.zeros((), jnp.int32),
        "mu": jax.tree.map(lambda p: _moment_init(p, cfg, "mu"), params),
        "nu": jax.tree.map(lambda p: _moment_init(p, cfg, "nu"), params),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def opt_update(params, grads, state, cfg: AdamWConfig,
               lr_scale: jnp.ndarray = 1.0):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9)) \
        if cfg.grad_clip > 0 else 1.0

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        m = _moment_get(mu, p.shape, cfg, "mu")
        v = _moment_get(nu, p.shape, cfg, "nu")
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh, vh = m / b1c, v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, _moment_set(m, cfg, "mu"), _moment_set(v, cfg, "nu")

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state["mu"])
    flat_nu = treedef.flatten_up_to(state["nu"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_mu = treedef.unflatten([o[1] for o in out])
    new_nu = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"step": step, "mu": new_mu, "nu": new_nu}, metrics


def opt_state_axes(param_axes, cfg: AdamWConfig, zero1_axis: Optional[str] = "data"):
    """Sharding axes for optimizer state.

    ZeRO-1: moments inherit the param spec *plus* the zero1 axis on the
    first dimension not already sharded (applied via rule remap in the
    launcher — here we just replicate param axes; the launcher's rules
    table decides the extra sharding).
    """
    def mu_axes(ax):
        if cfg.quantized_state:
            # flattened block store: shard the block dim over every mesh
            # axis (ZeRO for moments); block count is padded to 512-multiples
            return {"q": ("qblocks", None), "s": ("qblocks", None)}
        return ax

    is_leaf = lambda x: x is None or (isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x))
    return {
        "step": None,
        "mu": jax.tree.map(mu_axes, param_axes, is_leaf=is_leaf),
        "nu": jax.tree.map(lambda ax: ax, param_axes, is_leaf=is_leaf),
    }


def cosine_schedule(step, *, warmup: int, total: int, floor: float = 0.1):
    s = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, s / jnp.maximum(1, warmup))
    prog = jnp.clip((s - warmup) / jnp.maximum(1, total - warmup), 0.0, 1.0)
    cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return warm * cos
