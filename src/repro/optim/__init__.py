from .adamw import (  # noqa: F401
    AdamWConfig,
    cosine_schedule,
    global_norm,
    opt_init,
    opt_state_axes,
    opt_update,
)
