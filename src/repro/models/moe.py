"""GShard-style Mixture-of-Experts with expert parallelism.

Dispatch is capacity-based over small token groups (group_size tokens):
with E experts and top-k routing, per-group capacity C = ceil(k*Sg*cf/E),
so the dispatch one-hot is [G, Sg, E, C] with E*C ≈ k*Sg*cf independent of
E — the standard trick that keeps dispatch ~O(k·cf) per token. The expert
dimension is sharded on the 'model' mesh axis (EP); GSPMD materializes the
all-to-alls from the dispatch/combine einsums. Shared experts (DeepSeek/
Kimi style) run as a plain dense FFN on every token.

Aux outputs: load-balance loss (Switch-style) and router z-loss.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import _normal, dense_apply


def moe_init(key, cfg: ModelConfig):
    mo = cfg.moe
    d, f, e = cfg.d_model, mo.d_ff_expert, mo.num_experts
    ks = jax.random.split(key, 5)
    p = {
        "router": {"w": _normal(ks[0], (d, e), stddev=0.02)},
        "wi": _normal(ks[1], (e, d, f)),
        "wg": _normal(ks[2], (e, d, f)),
        "wo": _normal(ks[3], (e, f, d)),
    }
    a = {
        "router": {"w": ("embed", None)},
        "wi": ("expert", "embed", "expert_mlp"),
        "wg": ("expert", "embed", "expert_mlp"),
        "wo": ("expert", "expert_mlp", "embed"),
    }
    if mo.num_shared:
        from .layers import mlp_init
        p["shared"], a["shared"] = mlp_init(ks[4], d, f * mo.num_shared)
    return p, a


def _capacity(group: int, top_k: int, e: int, cf: float) -> int:
    return max(1, int(math.ceil(group * top_k * cf / e)))


def moe_apply(p, x, cfg: ModelConfig, *, mode: str = "float"):
    """x: [B,S,d] -> (y, aux) with aux = {'lb_loss', 'z_loss'}."""
    mo = cfg.moe
    dtype = jnp.dtype(cfg.dtype)
    b, s, d = x.shape
    e, k = mo.num_experts, mo.top_k
    tokens = b * s
    sg = min(mo.group_size, tokens)
    while tokens % sg:
        sg //= 2
    g = tokens // sg
    cap = _capacity(sg, k, e, mo.capacity_factor)

    xg = x.reshape(g, sg, d)
    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        p["router"]["w"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)

    gate_vals, gate_idx = jax.lax.top_k(probs, k)          # [G,Sg,k]
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # position of each (token, choice) within its expert queue
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.int32)  # [G,Sg,k,E]
    flat = onehot.reshape(g, sg * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                  # [G,Sg*k,E]
    pos = jnp.sum(pos.reshape(g, sg, k, e) * onehot, -1)   # [G,Sg,k]
    keep = pos < cap

    # dispatch/combine tensors: [G,Sg,E,C]
    pos_oh = jax.nn.one_hot(pos, cap, dtype=dtype) * keep[..., None]
    disp = jnp.einsum("gske,gskc->gsec", onehot.astype(dtype), pos_oh)
    comb = jnp.einsum("gsk,gske,gskc->gsec", gate_vals.astype(dtype),
                      onehot.astype(dtype), pos_oh)

    exp_in = jnp.einsum("gsec,gsd->gecd", disp, xg.astype(dtype))
    h = jnp.einsum("gecd,edf->gecf", exp_in, p["wi"].astype(dtype))
    gate = jnp.einsum("gecd,edf->gecf", exp_in, p["wg"].astype(dtype))
    h = jax.nn.silu(gate.astype(jnp.float32)).astype(dtype) * h
    exp_out = jnp.einsum("gecf,efd->gecd", h, p["wo"].astype(dtype))
    y = jnp.einsum("gsec,gecd->gsd", comb, exp_out)

    if mo.num_shared:
        from .layers import mlp_apply
        y = y + mlp_apply(p["shared"], xg, cfg, mode=mode)

    # Switch-style load-balance loss + router z-loss
    frac_tokens = jnp.mean(onehot[:, :, 0, :].astype(jnp.float32), axis=1)
    frac_probs = jnp.mean(probs, axis=1)
    lb = e * jnp.mean(jnp.sum(frac_tokens * frac_probs, -1))
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {"lb_loss": lb, "z_loss": z}
    return y.reshape(b, s, d), aux
