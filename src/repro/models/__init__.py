from . import attention, lm, mamba2, moe  # noqa: F401
from .lm import (  # noqa: F401
    abstract_init,
    decode_step,
    forward,
    init,
    init_cache,
    prefill,
)
