"""Shared layers: dense (with PPAC modes), norm, embeddings, RoPE, MLP.

Conventions:
  * Every ``*_init`` returns ``(params, axes)`` — parallel pytrees where
    ``axes`` holds logical-axis tuples consumed by sharding.rules.
  * Every ``*_apply`` is a pure function of (params, inputs, config).
  * Compute dtype is cfg.dtype (bf16 by default); params are fp32 masters
    unless converted for serving.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.engine import (
    QuantContainer,
    qat_dense,
    serve_dense,
    serve_dense_grouped,
)
from ..configs.base import ModelConfig, PPACModeConfig


def _normal(key, shape, dtype=jnp.float32, stddev=0.02):
    return jax.random.normal(key, shape, dtype) * stddev


# -- dense -------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, axes: Tuple, *, bias: bool = False,
               stddev: float = 0.02):
    p = {"w": _normal(key, (d_in, d_out), stddev=stddev)}
    a = {"w": axes}
    if bias:
        p["b"] = jnp.zeros((d_out,), jnp.float32)
        a["b"] = (axes[-1],)
    return p, a


def dense_apply(p, x, *, ppac: Optional[PPACModeConfig] = None,
                mode: str = "float", dtype=jnp.bfloat16):
    """Projection with optional PPAC execution.

    mode: 'float' | 'qat' | 'serve' | 'draft'. In 'serve' mode ``p['w']``
    may be a quantized container produced by pack_weight_for_serving;
    'draft' serves the container's resident packed1 rung (speculative
    drafting) and degrades to the target rung / plain matmul when no
    draft rung or no container exists.
    """
    w = p["w"]
    use_ppac = (ppac is not None and ppac.enabled and mode != "float"
                and not isinstance(w, QuantContainer)
                and min(w.shape) >= ppac.min_features)
    if isinstance(w, QuantContainer):  # resident quantized weight
        y = serve_dense(x, w, act_bits=ppac.act_bits if ppac else 8,
                        act_format=ppac.act_format if ppac else "int",
                        backend=ppac.backend if ppac else "mxu",
                        rung="draft" if mode == "draft" else "target")
    elif use_ppac and mode == "qat":
        y = qat_dense(x, w, weight_bits=ppac.weight_bits,
                      act_bits=ppac.act_bits,
                      weight_format=ppac.weight_format,
                      act_format=ppac.act_format)
    else:
        y = jnp.einsum("...i,io->...o", x, w.astype(dtype))
    if "b" in p:
        y = y + p["b"].astype(dtype)
    return y


def grouped_dense_apply(p, x, *, ppac: Optional[PPACModeConfig] = None,
                        mode: str = "serve"):
    """Serving fast path for a fused projection group: one resident
    container covers several same-input projections (wq/wk/wv, wi/wg);
    returns the tuple of member outputs. Only exists post-conversion —
    ``convert_params_for_serving`` creates these nodes."""
    w = p["w"]
    assert isinstance(w, QuantContainer) and w.splits, w
    return serve_dense_grouped(x, w,
                               act_bits=ppac.act_bits if ppac else 8,
                               act_format=ppac.act_format if ppac else "int",
                               backend=ppac.backend if ppac else "mxu",
                               rung="draft" if mode == "draft" else "target")


# -- norm --------------------------------------------------------------------

def rmsnorm_init(d: int, axes=("embed",)):
    return {"scale": jnp.ones((d,), jnp.float32)}, {"scale": axes}


def rmsnorm_apply(p, x, *, eps: float = 1e-5, dtype=jnp.bfloat16):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps) * p["scale"]
    return y.astype(dtype)


def gated_rmsnorm_apply(p, x, z, *, eps: float = 1e-5, dtype=jnp.bfloat16):
    """Mamba2's gated RMSNorm: norm(x * silu(z))."""
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"]).astype(dtype)


# -- embedding ---------------------------------------------------------------

def embed_init(key, vocab: int, d: int):
    p = {"table": _normal(key, (vocab, d))}
    a = {"table": ("vocab", "embed")}
    return p, a


def embed_apply(p, tokens, *, dtype=jnp.bfloat16):
    return jnp.take(p["table"].astype(dtype), tokens, axis=0)


def unembed_apply(p, x, *, dtype=jnp.bfloat16):
    """Logits projection (optionally tied). Returns fp32 logits."""
    return jnp.einsum("...d,vd->...v", x.astype(dtype),
                      p["table"].astype(dtype)).astype(jnp.float32)


# -- RoPE --------------------------------------------------------------------

def rope(x, positions, *, theta: float = 1e4):
    """x: [..., S, H, D] (D even); positions: [..., S] int32."""
    d = x.shape[-1]
    freqs = 1.0 / (theta ** (jnp.arange(0, d, 2, dtype=jnp.float32) / d))
    ang = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :]  # broadcast over heads
    sin = sin[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- MLP (SwiGLU) -------------------------------------------------------------

def mlp_init(key, d: int, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    wi, ai = dense_init(k1, d, d_ff, ("embed", "mlp"))
    wg, ag = dense_init(k2, d, d_ff, ("embed", "mlp"))
    wo, ao = dense_init(k3, d_ff, d, ("mlp", "embed"))
    return ({"wi": wi, "wg": wg, "wo": wo}, {"wi": ai, "wg": ag, "wo": ao})


def mlp_apply(p, x, cfg: ModelConfig, *, mode: str = "float"):
    dtype = jnp.dtype(cfg.dtype)
    if "wig" in p:  # fused up+gate group (serving fast path)
        h, g = grouped_dense_apply(p["wig"], x, ppac=cfg.ppac, mode=mode)
    else:
        h = dense_apply(p["wi"], x, ppac=cfg.ppac, mode=mode, dtype=dtype)
        g = dense_apply(p["wg"], x, ppac=cfg.ppac, mode=mode, dtype=dtype)
    h = jax.nn.silu(g.astype(jnp.float32)).astype(dtype) * h
    return dense_apply(p["wo"], h, ppac=cfg.ppac, mode=mode, dtype=dtype)
