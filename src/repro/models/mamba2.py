"""Mamba2 (SSD — state-space duality) block: chunked train scan + O(1) decode.

Implements the SSD algorithm of arXiv:2405.21060: within-chunk quadratic
attention-like form + inter-chunk linear recurrence, in fp32 for the decay
algebra. Decode carries (ssm_state [B,H,N,P], conv_state [B,dc-1,conv_dim])
— constant memory per step, which is what qualifies SSM archs for the
long_500k cell.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from .layers import _normal, dense_apply, dense_init


def mamba2_dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    nh = d_in // s.head_dim
    conv_dim = d_in + 2 * s.n_groups * s.d_state
    return d_in, nh, conv_dim


def mamba2_init(key, cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    d_in, nh, conv_dim = mamba2_dims(cfg)
    ks = jax.random.split(key, 4)
    proj_out = 2 * d_in + 2 * s.n_groups * s.d_state + nh
    p, a = {}, {}
    p["in_proj"], a["in_proj"] = dense_init(ks[0], d, proj_out,
                                            ("embed", "ssm_inner"))
    p["conv_w"] = _normal(ks[1], (conv_dim, s.d_conv), stddev=0.1)
    a["conv_w"] = ("ssm_inner", "conv")
    p["conv_b"] = jnp.zeros((conv_dim,), jnp.float32)
    a["conv_b"] = ("ssm_inner",)
    p["A_log"] = jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32))
    a["A_log"] = ("ssm_inner",)
    p["dt_bias"] = jnp.zeros((nh,), jnp.float32)
    a["dt_bias"] = ("ssm_inner",)
    p["D"] = jnp.ones((nh,), jnp.float32)
    a["D"] = ("ssm_inner",)
    p["norm_scale"] = jnp.ones((d_in,), jnp.float32)
    a["norm_scale"] = ("ssm_inner",)
    p["out_proj"], a["out_proj"] = dense_init(ks[2], d_in, d,
                                              ("ssm_inner", "embed"))
    return p, a


def mamba2_cache_init(cfg: ModelConfig, batch: int, dtype=jnp.float32):
    s = cfg.ssm
    d_in, nh, conv_dim = mamba2_dims(cfg)
    return {
        "ssm": jnp.zeros((batch, nh, s.d_state, s.head_dim), jnp.float32),
        "conv": jnp.zeros((batch, s.d_conv - 1, conv_dim), dtype),
    }


MAMBA2_CACHE_AXES = {"ssm": ("batch", "ssm_inner", None, None),
                     "conv": ("batch", None, "ssm_inner")}


def _causal_conv(xbc, w, b):
    """Depthwise causal conv: xbc [B,S,C], w [C,dc], b [C]."""
    dc = w.shape[-1]
    x = jnp.pad(xbc, ((0, 0), (dc - 1, 0), (0, 0)))
    out = lax.conv_general_dilated(
        x.astype(jnp.float32), w.T[:, None, :].astype(jnp.float32),
        window_strides=(1,), padding="VALID",
        dimension_numbers=("NWC", "WIO", "NWC"),
        feature_group_count=w.shape[0])
    return out + b


def _split_proj(cfg: ModelConfig, zxbcdt):
    s = cfg.ssm
    d_in, nh, conv_dim = mamba2_dims(cfg)
    z = zxbcdt[..., :d_in]
    xbc = zxbcdt[..., d_in:d_in + conv_dim]
    dt = zxbcdt[..., d_in + conv_dim:]
    return z, xbc, dt


def _split_xbc(cfg: ModelConfig, xbc):
    s = cfg.ssm
    d_in, nh, _ = mamba2_dims(cfg)
    g, n = s.n_groups, s.d_state
    x = xbc[..., :d_in]
    bc = xbc[..., d_in:]
    b_ssm = bc[..., :g * n].reshape(bc.shape[:-1] + (g, n))
    c_ssm = bc[..., g * n:].reshape(bc.shape[:-1] + (g, n))
    return x, b_ssm, c_ssm


def ssd_chunked(x, dt, a_log, b_ssm, c_ssm, *, chunk: int):
    """SSD scan. x [B,S,H,P], dt [B,S,H], b/c [B,S,G,N] -> y [B,S,H,P] fp32."""
    bsz, s, h, p = x.shape
    g, n = b_ssm.shape[2], b_ssm.shape[3]
    rep = h // g
    q = min(chunk, s)
    while s % q:
        q //= 2
    nc = s // q

    a = -jnp.exp(a_log.astype(jnp.float32))            # [H], negative
    da = dt * a                                        # [B,S,H]
    xr = x * dt[..., None]                             # dt-scaled input
    bh = jnp.repeat(b_ssm, rep, axis=2)                # [B,S,H,N]
    ch = jnp.repeat(c_ssm, rep, axis=2)

    def c_(t, extra=()):  # chunkify
        return t.reshape((bsz, nc, q) + t.shape[2:])

    da_c, xr_c, bh_c, ch_c = c_(da), c_(xr), c_(bh), c_(ch)
    cs = jnp.cumsum(da_c, axis=2)                      # [B,nc,Q,H] inclusive

    # within-chunk (diagonal blocks)
    li = cs[:, :, :, None, :] - cs[:, :, None, :, :]   # [B,nc,Qi,Qj,H]
    mask = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
    cb = jnp.einsum("bcihn,bcjhn->bcijh", ch_c, bh_c)
    y_diag = jnp.einsum("bcijh,bcijh,bcjhp->bcihp", cb, decay, xr_c)

    # chunk states and inter-chunk recurrence
    decay_end = jnp.exp(cs[:, :, -1:, :] - cs)         # [B,nc,Q,H]
    states = jnp.einsum("bcqhn,bcqh,bcqhp->bchnp", bh_c, decay_end, xr_c)
    chunk_decay = jnp.exp(cs[:, :, -1, :])             # [B,nc,H]

    def scan_body(carry, xs):
        st, dec = xs                                   # [B,H,N,P], [B,H]
        prev = carry
        new = prev * dec[..., None, None] + st
        return new, prev

    init = jnp.zeros((bsz, h, n, p), jnp.float32)
    _, prev_states = lax.scan(
        scan_body, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # [B,nc,H,N,P]

    y_off = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp", ch_c, prev_states,
                       jnp.exp(cs))
    y = (y_diag + y_off).reshape(bsz, s, h, p)
    return y


def mamba2_apply(p, x, cfg: ModelConfig, *, cache=None, mode: str = "float"):
    """x: [B,S,d]. Returns (y, new_cache). cache=None -> train (no state);
    S==1 with cache -> decode step; S>1 with cache -> prefill (state at end)."""
    s_cfg = cfg.ssm
    dtype = jnp.dtype(cfg.dtype)
    bsz, s, d = x.shape
    d_in, nh, conv_dim = mamba2_dims(cfg)

    zxbcdt = dense_apply(p["in_proj"], x, ppac=cfg.ppac, mode=mode, dtype=dtype)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])

    if cache is not None and s == 1:
        # ---- decode: O(1) state update ----
        window = jnp.concatenate(
            [cache["conv"].astype(jnp.float32), xbc.astype(jnp.float32)], 1)
        conv_out = jnp.einsum("bwc,cw->bc", window,
                              p["conv_w"].astype(jnp.float32)) + p["conv_b"]
        xbc_t = jax.nn.silu(conv_out)[:, None, :]
        new_conv = window[:, 1:].astype(cache["conv"].dtype)
        xi, b_ssm, c_ssm = _split_xbc(cfg, xbc_t)
        xi = xi.reshape(bsz, 1, nh, s_cfg.head_dim).astype(jnp.float32)
        rep = nh // s_cfg.n_groups
        bh = jnp.repeat(b_ssm, rep, axis=2)[:, 0]      # [B,H,N]
        ch = jnp.repeat(c_ssm, rep, axis=2)[:, 0]
        a = -jnp.exp(p["A_log"].astype(jnp.float32))
        dec = jnp.exp(dt[:, 0] * a)                    # [B,H]
        upd = jnp.einsum("bhn,bhp->bhnp", bh, xi[:, 0] * dt[:, 0][..., None])
        st = cache["ssm"] * dec[..., None, None] + upd
        y = jnp.einsum("bhn,bhnp->bhp", ch, st) + p["D"][None, :, None] * xi[:, 0]
        y = y.reshape(bsz, 1, d_in)
        new_cache = {"ssm": st, "conv": new_conv}
    else:
        xbc_conv = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
        xi, b_ssm, c_ssm = _split_xbc(cfg, xbc_conv)
        xi = xi.reshape(bsz, s, nh, s_cfg.head_dim).astype(jnp.float32)
        y = ssd_chunked(xi, dt, p["A_log"], b_ssm.astype(jnp.float32),
                        c_ssm.astype(jnp.float32), chunk=s_cfg.chunk_size)
        y = y + p["D"][None, None, :, None] * xi
        y = y.reshape(bsz, s, d_in)
        new_cache = cache
        if cache is not None:
            # prefill: leave final state in cache (recompute last-step state)
            # cheap approximation: rerun decode-style update is avoided; we
            # recompute the full state via one extra chunk reduction.
            a = -jnp.exp(p["A_log"].astype(jnp.float32))
            da = dt * a
            cs_total = jnp.cumsum(da, axis=1)
            decay_end = jnp.exp(cs_total[:, -1:, :] - cs_total)
            rep = nh // s_cfg.n_groups
            bh = jnp.repeat(b_ssm, rep, axis=2).astype(jnp.float32)
            st = jnp.einsum("bqhn,bqh,bqhp->bhnp", bh, decay_end,
                            xi * dt[..., None])
            new_conv = xbc[:, -(s_cfg.d_conv - 1):, :].astype(cache["conv"].dtype)
            new_cache = {"ssm": st, "conv": new_conv}

    # gated RMSNorm + out projection
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    yn = (yf * lax.rsqrt(var + cfg.norm_eps) * p["norm_scale"]).astype(dtype)
    out = dense_apply(p["out_proj"], yn, ppac=cfg.ppac, mode=mode, dtype=dtype)
    return out, new_cache
