"""Unified causal LM covering all ten assigned architectures.

One parameterized model: token/frontend embeddings -> N blocks -> norm ->
logits. Block flavor is dispatched on cfg.family:

  dense/audio/vlm : [GQA|MLA] attention + SwiGLU MLP (pre-norm)
  moe             : attention + (dense MLP for leading layers | MoE)
  ssm             : Mamba2 (SSD) block
  hybrid          : Mamba2 backbone + one *shared* attention+MLP block
                    applied every cfg.hybrid.shared_every layers (Zamba2)

Identical layers are stacked and executed with ``lax.scan`` (small HLO —
essential for the 80-layer dry-runs); heterogeneous prefixes (MoE leading
dense layers) and the hybrid's shared block are handled outside/inside the
scan respectively. Remat policy per cfg.remat.

Entry points:
  init(cfg, key)                      -> (params, axes)
  forward(params, cfg, batch)         -> (logits, aux)        train/eval
  init_cache(cfg, batch, max_seq)     -> (cache, cache_axes)
  prefill(params, cfg, batch, cache)  -> (logits, cache)
  decode_step(params, cfg, tokens, pos, cache) -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from ..sharding.rules import ShardingRules, constrain
from . import attention as attn_mod
from . import mamba2 as ssm_mod
from . import moe as moe_mod
from .layers import (
    dense_apply,
    dense_init,
    embed_apply,
    embed_init,
    mlp_apply,
    mlp_init,
    rmsnorm_apply,
    rmsnorm_init,
    unembed_apply,
)

def AUX0():
    return {"lb_loss": jnp.zeros((), jnp.float32),
            "z_loss": jnp.zeros((), jnp.float32)}


def _is_axes(x):
    return x is None or (isinstance(x, tuple)
                         and all(a is None or isinstance(a, str) for a in x))


def _stack_init(init_fn, key, n: int):
    """Stack n i.i.d. block inits along a leading 'layers' axis.

    Axes (static strings) are captured through a side channel so this
    remains traceable under jax.eval_shape (abstract init for the dry-run).
    """
    keys = jax.random.split(key, n)
    box = {}

    def one(k):
        p, a = init_fn(k)
        box["a"] = a
        return p

    p = jax.vmap(one)(keys)
    a = jax.tree.map(lambda ax: ("layers",) + tuple(ax) if ax else ("layers",),
                     box["a"], is_leaf=_is_axes)
    return p, a


def abstract_init(cfg: ModelConfig, key=None):
    """(param ShapeDtypeStructs, axes) without allocating anything."""
    key = key if key is not None else jax.random.PRNGKey(0)
    box = {}

    def f(k):
        p, a = init(cfg, k)
        box["a"] = a
        return p

    shapes = jax.eval_shape(f, key)
    return shapes, box["a"]


# -- block definitions --------------------------------------------------------

def _attn_init(key, cfg: ModelConfig):
    return (attn_mod.mla_init(key, cfg) if cfg.mla
            else attn_mod.gqa_init(key, cfg))


def _attn_apply(p, x, cfg, **kw):
    return (attn_mod.mla_apply(p, x, cfg, **kw) if cfg.mla
            else attn_mod.gqa_apply(p, x, cfg, **kw))


def _dense_block_init(key, cfg: ModelConfig, d_ff: Optional[int] = None):
    k1, k2 = jax.random.split(key)
    p, a = {}, {}
    p["ln1"], a["ln1"] = rmsnorm_init(cfg.d_model)
    p["attn"], a["attn"] = _attn_init(k1, cfg)
    p["ln2"], a["ln2"] = rmsnorm_init(cfg.d_model)
    p["mlp"], a["mlp"] = mlp_init(k2, cfg.d_model, d_ff or cfg.d_ff)
    return p, a


def _dense_block_apply(p, x, cfg, *, positions, cache=None, pos=None,
                       lengths=None, mode="float", rules=None, table=None,
                       history=False, verify=False):
    h = rmsnorm_apply(p["ln1"], x, eps=cfg.norm_eps, dtype=jnp.dtype(cfg.dtype))
    att, new_cache = _attn_apply(p["attn"], h, cfg, positions=positions,
                                 cache=cache, pos=pos, lengths=lengths,
                                 mode=mode, rules=rules, table=table,
                                 history=history, verify=verify)
    x = x + att
    x = constrain(x, rules, "batch", "seq", None) if rules else x
    h = rmsnorm_apply(p["ln2"], x, eps=cfg.norm_eps, dtype=jnp.dtype(cfg.dtype))
    x = x + mlp_apply(p["mlp"], h, cfg, mode=mode)
    return x, new_cache, AUX0()


def _moe_block_init(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    p, a = {}, {}
    p["ln1"], a["ln1"] = rmsnorm_init(cfg.d_model)
    p["attn"], a["attn"] = _attn_init(k1, cfg)
    p["ln2"], a["ln2"] = rmsnorm_init(cfg.d_model)
    p["moe"], a["moe"] = moe_mod.moe_init(k2, cfg)
    return p, a


def _moe_block_apply(p, x, cfg, *, positions, cache=None, pos=None,
                     lengths=None, mode="float", rules=None, table=None,
                     history=False, verify=False):
    h = rmsnorm_apply(p["ln1"], x, eps=cfg.norm_eps, dtype=jnp.dtype(cfg.dtype))
    att, new_cache = _attn_apply(p["attn"], h, cfg, positions=positions,
                                 cache=cache, pos=pos, lengths=lengths,
                                 mode=mode, rules=rules, table=table,
                                 history=history, verify=verify)
    x = x + att
    x = constrain(x, rules, "batch", "seq", None) if rules else x
    h = rmsnorm_apply(p["ln2"], x, eps=cfg.norm_eps, dtype=jnp.dtype(cfg.dtype))
    y, aux = moe_mod.moe_apply(p["moe"], h, cfg, mode=mode)
    return x + y, new_cache, aux


def _ssm_block_init(key, cfg: ModelConfig):
    p, a = {}, {}
    p["ln"], a["ln"] = rmsnorm_init(cfg.d_model)
    p["mamba"], a["mamba"] = ssm_mod.mamba2_init(key, cfg)
    return p, a


def _ssm_block_apply(p, x, cfg, *, positions=None, cache=None, pos=None,
                     lengths=None, mode="float", rules=None, table=None,
                     history=False, verify=False):
    assert not verify, "SSM blocks have no token-indexed cache to verify into"
    h = rmsnorm_apply(p["ln"], x, eps=cfg.norm_eps, dtype=jnp.dtype(cfg.dtype))
    y, new_cache = ssm_mod.mamba2_apply(p["mamba"], h, cfg, cache=cache,
                                        mode=mode)
    return x + y, new_cache, AUX0()


# -- model --------------------------------------------------------------------

def _block_fns(cfg: ModelConfig):
    if cfg.family == "ssm" or cfg.family == "hybrid":
        return _ssm_block_init, _ssm_block_apply
    if cfg.family == "moe":
        return _moe_block_init, _moe_block_apply
    return _dense_block_init, _dense_block_apply


def init(cfg: ModelConfig, key) -> Tuple[Dict, Dict]:
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {}
    a: Dict[str, Any] = {}
    p["embed"], a["embed"] = embed_init(ks[0], cfg.vocab, cfg.d_model)
    binit, _ = _block_fns(cfg)

    n_scan = cfg.n_layers
    if cfg.moe and cfg.moe.first_dense_layers:
        nd = cfg.moe.first_dense_layers
        p["dense_layers"], a["dense_layers"] = _stack_init(
            lambda k: _dense_block_init(k, cfg, d_ff=cfg.moe.d_ff_dense
                                        or cfg.d_ff), ks[1], nd)
        n_scan = cfg.n_layers - nd
    p["layers"], a["layers"] = _stack_init(
        lambda k: binit(k, cfg), ks[2], n_scan)

    if cfg.family == "hybrid":
        hp, ha = _dense_block_init(ks[3], cfg, d_ff=cfg.hybrid.shared_d_ff)
        p["shared"], a["shared"] = hp, ha

    if cfg.frontend == "vision":
        p["patch_proj"], a["patch_proj"] = dense_init(
            ks[4], cfg.d_model, cfg.d_model, ("embed", None))

    p["final_norm"], a["final_norm"] = rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        p["lm_head"], a["lm_head"] = dense_init(ks[5], cfg.d_model, cfg.vocab,
                                                ("embed", "vocab"))
    return p, a


def _embed_inputs(params, cfg: ModelConfig, batch, rules=None):
    """Merge token/frontend inputs into [B,S,d] activations."""
    dtype = jnp.dtype(cfg.dtype)
    parts = []
    if "embeds" in batch:  # audio frontend stub: precomputed frame embeddings
        parts.append(batch["embeds"].astype(dtype))
    if "patches" in batch:  # vision frontend stub: precomputed patch embeds
        pe = batch["patches"].astype(dtype)
        if "patch_proj" in params:
            pe = dense_apply(params["patch_proj"], pe, dtype=dtype)
        parts.append(pe)
    if "tokens" in batch:
        parts.append(embed_apply(params["embed"], batch["tokens"], dtype=dtype))
    h = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
    if rules:
        h = constrain(h, rules, "batch", "seq", None)
    return h


def _run_layers(params, cfg: ModelConfig, h, *, positions, caches=None,
                pos=None, lengths=None, mode="float", rules=None,
                layer_offset=0, table=None, history=False, verify=False):
    """Scan (or unroll, for hybrid) the stacked blocks; returns
    (h, new_caches, aux). ``table`` (paged caches) is shared by every
    layer, so it rides as a closure capture, not a scan input."""
    _, bapply = _block_fns(cfg)
    aux = AUX0()

    def body(carry, xs):
        hh, ax = carry
        if caches is None:
            lp = xs
            lc = None
        else:
            lp, lc = xs
        hh, nc, a2 = bapply(lp, hh, cfg, positions=positions, cache=lc,
                            pos=pos, lengths=lengths, mode=mode, rules=rules,
                            table=table, history=history, verify=verify)
        ax = {k: ax[k] + a2[k] for k in ax}
        return (hh, ax), (nc if caches is not None else 0)

    if cfg.remat == "dots":
        body_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.checkpoint_dots)
    elif cfg.remat != "none":
        body_fn = jax.checkpoint(body)
    else:
        body_fn = body

    if cfg.family == "hybrid":
        # unrolled: interleave the shared attention block. Each block is
        # individually rematerialized (the unrolled path bypasses the scan
        # body checkpoint).
        def shared_fn(sp, hh, sc):
            return _dense_block_apply(sp, hh, cfg, positions=positions,
                                      cache=sc, pos=pos, lengths=lengths,
                                      mode=mode, rules=rules, table=table,
                                      history=history)

        def block_fn(lp, hh, lc):
            return bapply(lp, hh, cfg, positions=positions, cache=lc,
                          pos=pos, lengths=lengths, mode=mode, rules=rules,
                          table=table, history=history)

        if cfg.remat != "none":
            shared_fn = jax.checkpoint(shared_fn)
            block_fn = jax.checkpoint(block_fn)

        n = jax.tree.leaves(params["layers"])[0].shape[0]
        new_caches = {"layers": [], "shared": []}
        sh_i = 0
        for i in range(n):
            lp = jax.tree.map(lambda t: t[i], params["layers"])
            lc = (jax.tree.map(lambda t: t[i], caches["layers"])
                  if caches is not None else None)
            if i % cfg.hybrid.shared_every == 0:
                sc = (jax.tree.map(lambda t: t[sh_i], caches["shared"])
                      if caches is not None else None)
                h, nsc, a2 = shared_fn(params["shared"], h, sc)
                aux = {k: aux[k] + a2[k] for k in aux}
                if caches is not None:
                    new_caches["shared"].append(nsc)
                sh_i += 1
            h, nc, a2 = block_fn(lp, h, lc)
            aux = {k: aux[k] + a2[k] for k in aux}
            if caches is not None:
                new_caches["layers"].append(nc)
        if caches is not None:
            stack = lambda lst: jax.tree.map(lambda *t: jnp.stack(t), *lst)
            return h, {"layers": stack(new_caches["layers"]),
                       "shared": stack(new_caches["shared"])}, aux
        return h, None, aux

    xs = params["layers"] if caches is None else (params["layers"],
                                                  caches["layers"])
    (h, aux), ncs = lax.scan(body_fn, (h, aux), xs)
    new_caches = None if caches is None else {"layers": ncs}
    return h, new_caches, aux


def forward(params, cfg: ModelConfig, batch, *, mode: str = "float",
            rules: Optional[ShardingRules] = None):
    """Training/eval forward: batch -> (logits [B,S,V] fp32, aux)."""
    h = _embed_inputs(params, cfg, batch, rules)
    b, s, _ = h.shape
    positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    aux = AUX0()

    if "dense_layers" in params:
        for i in range(jax.tree.leaves(params["dense_layers"])[0].shape[0]):
            lp = jax.tree.map(lambda t: t[i], params["dense_layers"])
            h, _, a2 = _dense_block_apply(lp, h, cfg, positions=positions,
                                          mode=mode, rules=rules)
            aux = {k: aux[k] + a2[k] for k in aux}

    h, _, a2 = _run_layers(params, cfg, h, positions=positions, mode=mode,
                           rules=rules)
    aux = {k: aux[k] + a2[k] for k in aux}
    h = rmsnorm_apply(params["final_norm"], h, eps=cfg.norm_eps,
                      dtype=jnp.dtype(cfg.dtype))
    if cfg.tie_embeddings:
        logits = unembed_apply(params["embed"], h, dtype=jnp.dtype(cfg.dtype))
    else:
        logits = dense_apply(params["lm_head"], h,
                             dtype=jnp.dtype(cfg.dtype)).astype(jnp.float32)
    if rules:
        logits = constrain(logits, rules, "batch", None, "vocab")
    return logits, aux


# -- caches -------------------------------------------------------------------

def _layer_cache_init(cfg: ModelConfig, batch: int, max_seq: int, dtype):
    if cfg.family in ("ssm", "hybrid"):
        return (ssm_mod.mamba2_cache_init(cfg, batch, dtype),
                ssm_mod.MAMBA2_CACHE_AXES)
    if cfg.mla:
        return (attn_mod.mla_cache_init(cfg, batch, max_seq, dtype),
                attn_mod.MLA_CACHE_AXES)
    return (attn_mod.gqa_cache_init(cfg, batch, max_seq, dtype),
            attn_mod.gqa_cache_axes(cfg))


def paged_extent(cfg: ModelConfig, max_seq: int) -> int:
    """Logical per-slot token extent a paged table must cover (the
    sliding window bounds it for ring caches)."""
    if cfg.sliding_window:
        return min(max_seq, cfg.sliding_window)
    return max_seq


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               dtype=jnp.bfloat16, *, page_size: Optional[int] = None,
               pool_pages: Optional[int] = None):
    """Returns (cache, cache_axes). Layer-stacked; hybrid adds shared-attn
    caches (one per shared-block application).

    With ``page_size`` the KV leaves become paged pools: every layer
    stack holds ``[n_layers, pool_pages, page_size, ...]`` and one shared
    ``table: [batch, extent/page_size]`` int32 maps each slot's logical
    pages to physical ones (a single page id addresses the same page in
    every stack). Fresh tables are filled with the out-of-range sentinel
    ``pool_pages`` — unmapped reads clip (and sit beyond every attention
    mask), unmapped writes drop. ``pool_pages`` defaults to full backing
    (batch * pages_per_slot); smaller pools oversubscribe the slots and
    rely on the server's page allocator."""
    first_dense = cfg.moe.first_dense_layers if cfg.moe else 0
    n_scan = cfg.n_layers - first_dense
    paged = page_size is not None
    if paged:
        if cfg.family in ("ssm", "hybrid"):
            raise ValueError("paged caches are token-indexed; SSM/hybrid "
                             "state caches have no token axis to page")
        extent = paged_extent(cfg, max_seq)
        if extent % page_size:
            raise ValueError(f"page_size={page_size} must divide the "
                             f"logical cache extent {extent}")
        n_pages = extent // page_size
        if pool_pages is None:
            pool_pages = batch * n_pages
        if cfg.mla:
            single = attn_mod.mla_paged_cache_init(cfg, pool_pages,
                                                   page_size, dtype)
            axes1 = attn_mod.MLA_PAGED_CACHE_AXES
        else:
            single = attn_mod.gqa_paged_cache_init(cfg, pool_pages,
                                                   page_size, dtype)
            axes1 = attn_mod.gqa_paged_cache_axes(cfg)
    else:
        single, axes1 = _layer_cache_init(cfg, batch, max_seq, dtype)

    def stack(t, n):
        return jax.tree.map(lambda x: jnp.zeros((n,) + x.shape, x.dtype), t)

    cache = {"layers": stack(single, n_scan)}
    axes = {"layers": jax.tree.map(
        lambda ax: ("layers",) + tuple(ax), axes1, is_leaf=_is_axes)}
    if first_dense:
        if paged:
            dsingle, daxes = single, axes1
        else:
            dsingle = attn_mod.mla_cache_init(cfg, batch, max_seq, dtype) \
                if cfg.mla else attn_mod.gqa_cache_init(cfg, batch,
                                                        max_seq, dtype)
            daxes = attn_mod.MLA_CACHE_AXES if cfg.mla \
                else attn_mod.gqa_cache_axes(cfg)
        cache["dense_layers"] = stack(dsingle, first_dense)
        axes["dense_layers"] = jax.tree.map(
            lambda ax: ("layers",) + tuple(ax), daxes, is_leaf=_is_axes)
    if cfg.family == "hybrid":
        n_shared = (cfg.n_layers + cfg.hybrid.shared_every - 1) \
            // cfg.hybrid.shared_every
        sh = attn_mod.gqa_cache_init(cfg, batch, max_seq, dtype)
        cache["shared"] = stack(sh, n_shared)
        axes["shared"] = jax.tree.map(
            lambda ax: ("layers",) + tuple(ax), attn_mod.GQA_CACHE_AXES,
            is_leaf=_is_axes)
    if paged:
        cache["table"] = jnp.full((batch, n_pages), pool_pages, jnp.int32)
        axes["table"] = ("batch", None)
    # per-sequence decode positions: mixed-progress batches (continuous
    # batching) decode with one fused step
    cache["pos"] = jnp.zeros((batch,), jnp.int32)
    axes["pos"] = ("batch",)
    return cache, axes


def _split_pos(cache):
    c = {k: v for k, v in cache.items() if k not in ("pos", "table")}
    return c, cache["pos"], cache.get("table")


def prefill(params, cfg: ModelConfig, batch, cache, *, lengths=None,
            mode: str = "float", rules: Optional[ShardingRules] = None,
            start=None, history: bool = False, table=None, slot_ids=None):
    """Run the full prompt, filling caches. Returns (logits, cache).

    ``lengths: [B]`` (optional) — per-sequence prompt lengths for
    *right-padded* ragged batches: the returned logits are taken at each
    sequence's last real token, ``cache['pos']`` starts each sequence at
    its own length, and attention-family caches mask the padded tail
    (causal attention makes right-pad bit-exact; SSM state accumulation
    has no position mask, so ragged prefill is attention-only — SSM
    prompts must arrive unpadded).

    Paged caches (a ``table`` leaf) route all KV writes through the page
    table. ``history=True`` is suffix prefill after a prefix-cache hit:
    ``batch['tokens']`` holds only the un-cached suffix, ``start: [B]``
    its absolute offset (shared pages already populate rows [0, start)),
    and attention runs over the full gathered history.

    ``table: [B, n_pages]`` (optional) overrides the cache's own table —
    the group-prefill path prefills B admitted sequences straight into
    the shared pools through their slots' table rows while the resident
    cache keeps all slots' rows; ``slot_ids: [B]`` then scatters the
    end positions into the resident ``pos`` vector."""
    caches, pos0, tbl = _split_pos(cache)
    if table is None:
        table = tbl
    h = _embed_inputs(params, cfg, batch, rules)
    b, s, _ = h.shape
    if start is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    else:
        start = jnp.asarray(start, jnp.int32)
        positions = start[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    ln = (jnp.full((b,), s, jnp.int32) if lengths is None
          else jnp.asarray(lengths, jnp.int32))
    aux = AUX0()
    new = dict(cache)
    if "dense_layers" in params:
        ncs = []
        for i in range(jax.tree.leaves(params["dense_layers"])[0].shape[0]):
            lp = jax.tree.map(lambda t: t[i], params["dense_layers"])
            lc = jax.tree.map(lambda t: t[i], caches["dense_layers"])
            h, nc, _ = _dense_block_apply(lp, h, cfg, positions=positions,
                                          cache=lc, lengths=ln, mode=mode,
                                          rules=rules, table=table,
                                          history=history)
            ncs.append(nc)
        new["dense_layers"] = jax.tree.map(lambda *t: jnp.stack(t), *ncs)
    h, ncaches, _ = _run_layers(params, cfg, h, positions=positions,
                                caches={k: caches[k] for k in ("layers", "shared")
                                        if k in caches},
                                lengths=ln, mode=mode, rules=rules,
                                table=table, history=history)
    new.update(ncaches)
    h = rmsnorm_apply(params["final_norm"], h, eps=cfg.norm_eps,
                      dtype=jnp.dtype(cfg.dtype))
    h_last = jnp.take_along_axis(h, (ln - 1)[:, None, None], axis=1)
    if cfg.tie_embeddings:
        logits = unembed_apply(params["embed"], h_last,
                               dtype=jnp.dtype(cfg.dtype))
    else:
        logits = dense_apply(params["lm_head"], h_last,
                             dtype=jnp.dtype(cfg.dtype)).astype(jnp.float32)
    end = ln if start is None else start + ln
    if slot_ids is None:
        new["pos"] = end
    else:
        new["pos"] = pos0.at[jnp.asarray(slot_ids, jnp.int32)].set(
            end, mode="drop")
    return logits, new


def decode_step(params, cfg: ModelConfig, tokens, cache, *,
                mode: str = "float", rules: Optional[ShardingRules] = None):
    """One decode step: tokens [B,1] -> (logits [B,1,V], cache).
    ``cache['pos']`` is a per-sequence [B] vector (mixed-progress batches
    from the continuous-batching server decode in one fused step)."""
    caches, pos, table = _split_pos(cache)
    h = embed_apply(params["embed"], tokens, dtype=jnp.dtype(cfg.dtype))
    b = h.shape[0]
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos[:, None]
    new = dict(cache)
    if "dense_layers" in params:
        ncs = []
        for i in range(jax.tree.leaves(params["dense_layers"])[0].shape[0]):
            lp = jax.tree.map(lambda t: t[i], params["dense_layers"])
            lc = jax.tree.map(lambda t: t[i], caches["dense_layers"])
            h, nc, _ = _moe_or_dense_decode(lp, h, cfg, positions, lc, pos,
                                            mode, rules, dense=True,
                                            table=table)
            ncs.append(nc)
        new["dense_layers"] = jax.tree.map(lambda *t: jnp.stack(t), *ncs)
    h, ncaches, _ = _run_layers(params, cfg, h, positions=positions,
                                caches={k: caches[k] for k in ("layers", "shared")
                                        if k in caches},
                                pos=pos, mode=mode, rules=rules, table=table)
    new.update(ncaches)
    h = rmsnorm_apply(params["final_norm"], h, eps=cfg.norm_eps,
                      dtype=jnp.dtype(cfg.dtype))
    if cfg.tie_embeddings:
        logits = unembed_apply(params["embed"], h, dtype=jnp.dtype(cfg.dtype))
    else:
        logits = dense_apply(params["lm_head"], h,
                             dtype=jnp.dtype(cfg.dtype)).astype(jnp.float32)
    new["pos"] = pos + 1
    return logits, new


def _moe_or_dense_decode(lp, h, cfg, positions, lc, pos, mode, rules, *,
                         dense: bool, table=None, verify=False):
    if dense:
        return _dense_block_apply(lp, h, cfg, positions=positions, cache=lc,
                                  pos=pos, mode=mode, rules=rules,
                                  table=table, verify=verify)
    return _moe_block_apply(lp, h, cfg, positions=positions, cache=lc,
                            pos=pos, mode=mode, rules=rules, table=table,
                            verify=verify)


def verify(params, cfg: ModelConfig, tokens, cache, *, mode: str = "float",
           rules: Optional[ShardingRules] = None):
    """Speculative-verify forward: tokens [B,S] at per-sequence positions
    ``pos + i`` -> (logits [B,S,V] fp32 at EVERY row, cache).

    Row ``i`` runs the exact decode-step compute at position ``pos + i``
    (decode's einsums, masks and KV quantization — see the ``verify``
    branches in models.attention), so its logits bit-match the decode
    step that would consume ``tokens[:, i]`` there. All S rows' target-
    rung K/V are written to the cache and ``pos`` advances by S; the
    caller rewinds ``pos`` to the accepted prefix (linear/paged caches
    need nothing else — rows past ``pos`` sit beyond every mask and are
    overwritten later; ring caches additionally need
    :func:`rollback_ring_cache`). Writes past a paged slot's allocation
    hit the table's out-of-range sentinel and drop."""
    if cfg.family in ("ssm", "hybrid"):
        raise ValueError("speculative verify needs a token-indexed cache; "
                         "SSM/hybrid recurrent state cannot rewind")
    caches, pos, table = _split_pos(cache)
    h = embed_apply(params["embed"], tokens, dtype=jnp.dtype(cfg.dtype))
    b, s = tokens.shape
    pos = jnp.broadcast_to(jnp.asarray(pos, jnp.int32), (b,))
    positions = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    new = dict(cache)
    if "dense_layers" in params:
        ncs = []
        for i in range(jax.tree.leaves(params["dense_layers"])[0].shape[0]):
            lp = jax.tree.map(lambda t: t[i], params["dense_layers"])
            lc = jax.tree.map(lambda t: t[i], caches["dense_layers"])
            h, nc, _ = _moe_or_dense_decode(lp, h, cfg, positions, lc, pos,
                                            mode, rules, dense=True,
                                            table=table, verify=True)
            ncs.append(nc)
        new["dense_layers"] = jax.tree.map(lambda *t: jnp.stack(t), *ncs)
    h, ncaches, _ = _run_layers(params, cfg, h, positions=positions,
                                caches={k: caches[k] for k in ("layers", "shared")
                                        if k in caches},
                                pos=pos, mode=mode, rules=rules, table=table,
                                verify=True)
    new.update(ncaches)
    h = rmsnorm_apply(params["final_norm"], h, eps=cfg.norm_eps,
                      dtype=jnp.dtype(cfg.dtype))
    if cfg.tie_embeddings:
        logits = unembed_apply(params["embed"], h, dtype=jnp.dtype(cfg.dtype))
    else:
        logits = dense_apply(params["lm_head"], h,
                             dtype=jnp.dtype(cfg.dtype)).astype(jnp.float32)
    new["pos"] = pos + s
    return logits, new


def rollback_ring_cache(cfg: ModelConfig, prev, cache, start, new_pos,
                        window: int):
    """Undo a ring cache's rejected verify rows.

    A verify over rows ``start + i`` scattered ALL its window's rows into
    the ring (slot ``(start+i) % t``); rows at positions >= ``new_pos``
    (the accepted end) were rejected, and — unlike linear/paged caches,
    where stale rows sit beyond every mask — their slots must get their
    pre-round content back (``prev``, the cache snapshot from before
    drafting: draft-rung KV writes polluted the same slots). Restores
    every KV leaf's rejected slots and sets ``pos = new_pos``.
    """
    start = jnp.asarray(start, jnp.int32)
    new_pos = jnp.asarray(new_pos, jnp.int32)
    b = start.shape[0]
    s = window
    row = start[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]  # [B,S]
    bi = jnp.arange(b)[:, None]

    def one(pv, nv):
        if pv.ndim < 4:          # pos [B] / table [B,n] leaves
            return nv
        t = pv.shape[2]
        slot = row % t
        # restore-only-rejected: kept rows route to the OOB slot and drop
        idx = jnp.where(row < new_pos[:, None], t, slot)
        rows = pv[:, bi, slot]                       # [L,B,S,...] pre-round
        return nv.at[:, bi, idx].set(rows, mode="drop")

    out = jax.tree.map(one, prev, dict(cache))
    out["pos"] = new_pos
    return out
