"""Attention variants: GQA (+QKV bias, sliding window), MLA (DeepSeek-style).

Memory-efficient chunked attention: queries are processed in chunks via
``lax.scan`` (peak activation = one [chunk × kv] score tile) with optional
remat of the chunk body — required for the 32k prefill shapes on a real
chip and for bounded compile-time memory on the dry-run.

KV caches are plain pytrees: {"k": [B,T,Hkv,D], "v": [B,T,Hkv,Dv]} with a
*per-sequence* write position ``pos: [B]`` — mixed-progress batches (the
continuous-batching server admits new prompts mid-flight) decode with one
fused step. Sliding-window attention uses a rolling (ring) cache of size
``window`` for decode: position ``p`` always lives at slot ``p % window``,
in prefill and decode alike, so decode can roll straight out of any
prefill length (bounds long-context memory). MLA caches the compressed
(kv_lora + rope) stream and decodes via the absorbed-projection trick —
the KV-memory win that makes it the natural PPAC companion for decode
shapes. Decode writes are batched scatters (per-sequence slots), which
lower in place when the cache pytree is donated (serve/step.py jits every
decode entry point with ``donate_argnums`` on the cache).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from ..sharding.rules import constrain, constrain_fitted
from .layers import (
    dense_apply,
    dense_init,
    grouped_dense_apply,
    rmsnorm_apply,
    rmsnorm_init,
    rope,
)

NEG_INF = -1e9


def _attend_chunk(qc, k, v, q_pos, k_valid, *, window: int, scale: float,
                  causal: bool, rules=None, scores_dtype=None):
    """qc: [B,C,H,D]; k: [B,T,Hkv,D]; v: [B,T,Hkv,Dv]; q_pos: [C] int32.

    Returns [B,C,H,Dv]. GQA keys/values are repeated to the full head
    count and every head-indexed tensor is explicitly constrained to the
    'model' axis: without the constraints GSPMD replicates the quadratic
    score einsums whenever heads don't divide the axis (observed 16x
    redundant compute on smollm — EXPERIMENTS.md §Perf iteration 1).
    """
    b, c, h, d = qc.shape
    t, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)   # [B,T,H,D]
        v = jnp.repeat(v, rep, axis=2)
    if rules is not None:
        qc = constrain(qc, rules, "batch", None, "act_heads", None)
        k = constrain(k, rules, "batch", None, "act_heads", None)
        v = constrain(v, rules, "batch", None, "act_heads", None)
    return _attend_prepped(qc, k, v, q_pos, k_valid, window=window,
                           scale=scale, causal=causal, rules=rules,
                           scores_dtype=scores_dtype)


def _attend_prepped(qc, k, v, q_pos, k_valid, *, window, scale, causal,
                    rules=None, scores_dtype=None):
    """Like _attend_chunk but assumes k/v are already head-expanded and
    constrained (hoisted out of chunk loops so GSPMD gathers once, not
    once per chunk — §Perf llava iteration 3b)."""
    b, c, h, d = qc.shape
    t = k.shape[1]
    # fp32 ACCUMULATION without materializing fp32 copies of q/k/v
    # (input .astype(f32) casts were ~half the HBM traffic — §Perf it.2)
    scores = jnp.einsum("bchd,bthd->bhct", qc, k,
                        preferred_element_type=jnp.float32) * scale
    if rules is not None:
        scores = constrain(scores, rules, "batch", "act_heads", None, None)
    k_pos = jnp.arange(t)
    mask = k_pos[None, :] < k_valid  # valid cache entries
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    scores = jnp.where(mask[None, None, :, :], scores, NEG_INF)
    if scores_dtype is not None:
        # bf16 probability boundary (softmax max-subtracts internally;
        # bf16 keeps f32's exponent range) — halves the [C,T] HBM tensors
        scores = scores.astype(scores_dtype)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhct,bthv->bchv", w.astype(qc.dtype), v,
                     preferred_element_type=jnp.float32)
    if rules is not None:
        out = constrain(out, rules, "batch", None, "act_heads", None)
    return out


def chunked_attention(q, k, v, *, q_offset=0, k_valid=None, causal=True,
                      window: int = 0, q_chunk: int = 512,
                      scale: Optional[float] = None, remat: bool = True,
                      rules=None, blocking: str = "scan",
                      scores_dtype=None):
    """q: [B,S,H,D] against k/v: [B,T,Hkv,D*] -> [B,S,H,Dv]."""
    b, s, h, d = q.shape
    t = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    k_valid = t if k_valid is None else k_valid
    k_valid = jnp.asarray(k_valid, jnp.int32)

    if s <= q_chunk:
        q_pos = q_offset + jnp.arange(s)
        return _attend_chunk(q, k, v, q_pos, k_valid, window=window,
                             scale=scale, causal=causal, rules=rules,
                             scores_dtype=scores_dtype)

    c = q_chunk
    pad = (-s) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // c

    if blocking == "triangle" and causal and t == s and not window:
        # Unrolled triangular blocking: chunk i only attends to keys
        # [0, (i+1)*c) — statically sliced, so the fully-masked half of
        # the [C, T] score work (and its HBM traffic) never exists.
        # K/V head expansion + sharding constraints are hoisted OUT of
        # the loop (inside it, GSPMD re-gathers per chunk).
        h_full = q.shape[2]
        rep = h_full // k.shape[2]
        if rep > 1:
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
        if rules is not None:
            k = constrain(k, rules, "batch", None, "act_heads", None)
            v = constrain(v, rules, "batch", None, "act_heads", None)
        outs = []

        def chunk_fn(qc, ki, vi, q_pos):
            if rules is not None:
                qc = constrain(qc, rules, "batch", None, "act_heads", None)
            return _attend_prepped(qc, ki, vi, q_pos, ki.shape[1],
                                   window=0, scale=scale, causal=True,
                                   rules=rules, scores_dtype=scores_dtype)

        fn = jax.checkpoint(chunk_fn) if remat else chunk_fn
        for i in range(nq):
            hi = min((i + 1) * c, t)
            qc = q[:, i * c:(i + 1) * c]
            q_pos = q_offset + i * c + jnp.arange(c)
            outs.append(fn(qc, k[:, :hi], v[:, :hi], q_pos))
        out = jnp.concatenate(outs, axis=1)
        return out[:, :s]

    qs = q.reshape(b, nq, c, h, d).transpose(1, 0, 2, 3, 4)  # [nq,B,C,H,D]

    def body(_, xs):
        qc, idx = xs
        q_pos = q_offset + idx * c + jnp.arange(c)
        out = _attend_chunk(qc, k, v, q_pos, k_valid, window=window,
                            scale=scale, causal=causal, rules=rules,
                            scores_dtype=scores_dtype)
        return None, out

    fn = jax.checkpoint(body) if remat else body
    _, ys = lax.scan(fn, None, (qs, jnp.arange(nq)))
    out = ys.transpose(1, 0, 2, 3, 4).reshape(b, nq * c, h, v.shape[-1])
    return out[:, :s]


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["wq"], a["wq"] = dense_init(ks[0], d, h * hd, ("embed", "heads"),
                                  bias=cfg.qkv_bias)
    p["wk"], a["wk"] = dense_init(ks[1], d, hkv * hd, ("embed", "kv_heads"),
                                  bias=cfg.qkv_bias)
    p["wv"], a["wv"] = dense_init(ks[2], d, hkv * hd, ("embed", "kv_heads"),
                                  bias=cfg.qkv_bias)
    p["wo"], a["wo"] = dense_init(ks[3], h * hd, d, ("heads", "embed"))
    return p, a


def gqa_cache_init(cfg: ModelConfig, batch: int, max_seq: int,
                   dtype=jnp.bfloat16):
    t = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    shape = (batch, t, cfg.n_kv_heads, cfg.hd)
    if cfg.kv_dtype == "int8":
        # per-(token, head) max-scaled int8 store — 2x smaller cache, the
        # decode memory-roofline lever paired with PPAC resident weights
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "ks": jnp.zeros(shape[:3] + (1,), jnp.bfloat16),
                "vs": jnp.zeros(shape[:3] + (1,), jnp.bfloat16)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_cache_axes(cfg: ModelConfig):
    ax = ("batch", "kv_seq", "kv_heads", None)
    if cfg.kv_dtype == "int8":
        return {"k": ax, "v": ax, "ks": ax, "vs": ax}
    return {"k": ax, "v": ax}


# -- paged KV pools -----------------------------------------------------------
#
# A paged cache virtualizes the per-slot [T, ...] token axis onto a bounded
# physical pool of fixed-size pages: leaves are [pool_pages, page_size, ...]
# and an int32 page table [slots, T / page_size] maps each slot's logical
# page to a physical one. Reads gather rows through the table (the same
# take-based trick as ``_ring_rows``), writes scatter through it — both
# lower in place under donation, so thousands of logical slots can share a
# pool sized by *live tokens*. Which physical pages back which slot (free
# list, refcounts, copy-on-write, prefix sharing) is host-side policy in
# ``launch.paging`` / ``launch.serve_lm``; the model layer only follows the
# table it is handed.


def gqa_paged_cache_init(cfg: ModelConfig, pool_pages: int, page_size: int,
                         dtype=jnp.bfloat16):
    shape = (pool_pages, page_size, cfg.n_kv_heads, cfg.hd)
    if cfg.kv_dtype == "int8":
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "ks": jnp.zeros(shape[:3] + (1,), jnp.bfloat16),
                "vs": jnp.zeros(shape[:3] + (1,), jnp.bfloat16)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_paged_cache_axes(cfg: ModelConfig):
    ax = (None, None, "kv_heads", None)
    if cfg.kv_dtype == "int8":
        return {"k": ax, "v": ax, "ks": ax, "vs": ax}
    return {"k": ax, "v": ax}


def paged_view(pool, table):
    """Gather a pool [P, psz, ...] through table [B, n] -> [B, n*psz, ...].

    The per-slot logical view decode/suffix attention runs against —
    identical, row for row, to what a contiguous [B, T, ...] cache would
    hold (unallocated table entries read page 0; those rows sit beyond
    every validity/causality mask, so their values never contribute)."""
    b, n = table.shape
    rows = jnp.take(pool, table, axis=0, mode="clip")   # [B, n, psz, ...]
    return rows.reshape((b, n * pool.shape[1]) + pool.shape[2:])


def paged_scatter(pool, table, rows, row_idx, valid=None):
    """Write rows [B, S, ...] at logical rows ``row_idx`` [B, S] through
    the table. Invalid (right-pad) rows are routed to an out-of-range page
    and dropped — pads must never reach a page another slot may own."""
    p, psz = pool.shape[0], pool.shape[1]
    b, s = row_idx.shape
    page = jnp.take_along_axis(
        table, jnp.clip(row_idx // psz, 0, table.shape[1] - 1), axis=1)
    off = row_idx % psz
    if valid is not None:
        page = jnp.where(valid, page, p)                # OOB -> mode="drop"
    flat = rows.reshape((b * s,) + rows.shape[2:]).astype(pool.dtype)
    return pool.at[page.reshape(-1), off.reshape(-1)].set(flat, mode="drop")


def _attend_causal_rows(q, k, v, q_pos, *, scale, rules=None,
                        scores_dtype=None):
    """Per-sequence causal attention for suffix prefill: q [B,S,H,D] rows
    at absolute positions ``q_pos`` [B,S] against an assembled history
    view k/v [B,T,H,*]. Mirrors ``_attend_prepped`` (same einsums, same
    NEG_INF masking, same probability-boundary cast) so a 1-token suffix
    reproduces cold prefill's last-row attention bit for bit when the
    cached rows store exact values; the only change is the [B,S,T] mask
    (per-sequence positions instead of one shared chunk offset)."""
    b, s, h, d = q.shape
    t, hk = k.shape[1], k.shape[2]
    rep = h // hk
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    if rules is not None:
        q = constrain(q, rules, "batch", None, "act_heads", None)
        k = constrain(k, rules, "batch", None, "act_heads", None)
        v = constrain(v, rules, "batch", None, "act_heads", None)
    scores = jnp.einsum("bchd,bthd->bhct", q, k,
                        preferred_element_type=jnp.float32) * scale
    if rules is not None:
        scores = constrain(scores, rules, "batch", "act_heads", None, None)
    mask = jnp.arange(t)[None, None, :] <= q_pos[:, :, None]   # [B,S,T]
    scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
    if scores_dtype is not None:
        scores = scores.astype(scores_dtype)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhct,bthv->bchv", w.astype(q.dtype), v,
                     preferred_element_type=jnp.float32)
    if rules is not None:
        out = constrain(out, rules, "batch", None, "act_heads", None)
    return out


GQA_CACHE_AXES = {"k": ("batch", "kv_seq", "kv_heads", None),
                  "v": ("batch", "kv_seq", "kv_heads", None)}


def _q8_kv(x):
    """x [B,S,Hkv,D] -> (int8 values, bf16 scales [B,S,Hkv,1])."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def as_pos_vector(pos, batch: int):
    """Normalize a write position (python int / scalar / [B]) to [B] int32."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (batch,))
    return pos


def _scatter_rows(cache_leaf, rows, slot):
    """Write rows [B,1,...] at per-sequence slots [B] of cache [B,T,...]."""
    b = cache_leaf.shape[0]
    return cache_leaf.at[jnp.arange(b), slot].set(
        rows[:, 0].astype(cache_leaf.dtype), mode="drop")


def _scatter_rows_multi(cache_leaf, rows, row_idx):
    """Write rows [B,S,...] at per-sequence rows [B,S] of cache [B,T,...].
    Out-of-range rows (a verify window running past the cache) drop."""
    b = cache_leaf.shape[0]
    return cache_leaf.at[jnp.arange(b)[:, None], row_idx].set(
        rows.astype(cache_leaf.dtype), mode="drop")


def _ring_rows(stream, lengths, t: int):
    """Ring-layout a per-position stream into rolling-cache rows.

    stream: [B,S,...] (positions 0..S-1, right-padded past ``lengths``);
    returns [B,t,...] where slot ``s`` holds the *latest* valid position
    ``p < lengths`` with ``p % t == s`` (zeros for never-written slots).
    This is exactly the layout decode's ``slot = pos % t`` writes produce,
    so decode rolls seamlessly out of any prefill length — including
    lengths that are neither multiples of nor smaller than the window.
    """
    b = stream.shape[0]
    ln = lengths[:, None]                              # [B,1]
    s_idx = jnp.arange(t)[None, :]                     # [1,t]
    p = ln - 1 - jnp.mod(ln - 1 - s_idx, t)            # [B,t]
    valid = (p >= 0) & (ln > 0)
    idx = jnp.clip(p, 0, stream.shape[1] - 1)
    rows = jnp.take_along_axis(
        stream, idx.reshape((b, t) + (1,) * (stream.ndim - 2)), axis=1)
    return jnp.where(valid.reshape((b, t) + (1,) * (stream.ndim - 2)),
                     rows, jnp.zeros((), stream.dtype))


def _decode_attend_q8(q, cache, k_valid, *, scale, rules=None):
    """(Optionally quantized) cache decode attention, GQA-grouped (NO
    key/value repeat: repeating a seq-sharded cache forces GSPMD into
    involuntary full rematerialization — replicate + repartition of the
    whole cache per layer; XLA emits a warning and ~800 GiB of phantom
    copies).

    ``k_valid: [B]`` — per-sequence count of valid cache slots (mixed-
    progress batches decode at different positions in one fused step).
    The per-(t,g) scales factor out of both einsums, so no dequantized
    [B,T,G,D] tensor is materialized:
        scores = (q · ki) * ks ;  out = ((w*vs) · vi)
    Like ``_attend_prepped``, every head-indexed einsum is constrained to
    the 'model' axis (the grouped dim g carries the kv-head sharding).
    """
    b, s, h, d = q.shape          # s == 1 decode; s > 1 verifies a window
    ki, vi = cache["k"], cache["v"]
    ks, vs = cache.get("ks"), cache.get("vs")
    t, g = ki.shape[1], ki.shape[2]
    rep = h // g
    qg = q.reshape(b, s, g, rep, d)
    if rules is not None:
        qg = constrain(qg, rules, "batch", None, "act_heads", None, None)
    scores = jnp.einsum("bsgrd,btgd->bgrst", qg, ki.astype(q.dtype),
                        preferred_element_type=jnp.float32) * scale
    if rules is not None:
        scores = constrain(scores, rules, "batch", "act_heads", None, None,
                           None)
    if ks is not None:
        scores = scores * ks[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
    kv = jnp.asarray(k_valid, jnp.int32)
    kv = kv[:, None] if kv.ndim == 1 else kv           # [B,S] counts
    mask = jnp.arange(t)[None, None, :] < kv[:, :, None]   # [B,S,T]
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    wv = w.astype(q.dtype)
    if vs is not None:
        wv = wv * vs[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
    out = jnp.einsum("bgrst,btgv->bsgrv", wv, vi.astype(q.dtype),
                     preferred_element_type=jnp.float32)
    if rules is not None:
        out = constrain(out, rules, "batch", None, "act_heads", None, None)
    return out.reshape(b, s, h, -1).astype(q.dtype)


def _verify_attend_views(q, views, k_valid, *, scale, rules=None):
    """``_decode_attend_q8`` against per-query cache views: leaves are
    [B,S,T,g,*] — query i sees its OWN snapshot of the ring (slots a later
    window row will overwrite still hold their pre-window content). Same
    einsum contractions, scale ordering and count masking as decode, with
    one extra query-indexed key axis, so each row of the window reproduces
    the decode step it replaces bit for bit up to key order (which the
    view construction preserves: slot order)."""
    b, s, h, d = q.shape
    ki, vi = views["k"], views["v"]
    ks, vs = views.get("ks"), views.get("vs")
    t, g = ki.shape[2], ki.shape[3]
    rep = h // g
    qg = q.reshape(b, s, g, rep, d)
    if rules is not None:
        qg = constrain(qg, rules, "batch", None, "act_heads", None, None)
    scores = jnp.einsum("bsgrd,bstgd->bgrst", qg, ki.astype(q.dtype),
                        preferred_element_type=jnp.float32) * scale
    if rules is not None:
        scores = constrain(scores, rules, "batch", "act_heads", None, None,
                           None)
    if ks is not None:
        scores = scores * ks[..., 0].transpose(0, 3, 1, 2)[:, :, None, :, :]
    mask = jnp.arange(t)[None, None, :] < k_valid[:, :, None]   # [B,S,T]
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    wv = w.astype(q.dtype)
    if vs is not None:
        wv = wv * vs[..., 0].transpose(0, 3, 1, 2)[:, :, None, :, :]
    out = jnp.einsum("bgrst,bstgv->bsgrv", wv, vi.astype(q.dtype),
                     preferred_element_type=jnp.float32)
    if rules is not None:
        out = constrain(out, rules, "batch", None, "act_heads", None, None)
    return out.reshape(b, s, h, -1).astype(q.dtype)


def _ring_query_views(ext, j0, n_q: int, t: int):
    """Per-query ring views from extended leaves [B, t+S, ...]: query i's
    slot s reads window row ``j0[b,s]`` (appended at t+j0) once that row
    exists for i (``j0 <= i`` — covering both in-window replacement and
    window expiry of the slot's old content), else the untouched ring row.
    Returns leaves [B, n_q, t, ...]."""
    b = j0.shape[0]
    qi = jnp.arange(n_q, dtype=jnp.int32)[None, :, None]         # [1,S,1]
    idx = jnp.where(j0[:, None, :] <= qi, t + j0[:, None, :],
                    jnp.arange(t, dtype=jnp.int32)[None, None, :])  # [B,S,t]
    flat = idx.reshape(b, n_q * t)
    out = {}
    for kk, leaf in ext.items():
        rows = jnp.take_along_axis(
            leaf, flat.reshape((b, n_q * t) + (1,) * (leaf.ndim - 2)),
            axis=1)
        out[kk] = rows.reshape((b, n_q, t) + leaf.shape[2:])
    return out


def gqa_apply(p, x, cfg: ModelConfig, *, positions, cache=None, pos=None,
              lengths=None, mode: str = "float", rules=None, table=None,
              history=False, verify=False):
    """x: [B,S,d]. Train/prefill when cache is None or S>1 (writes cache
    at positions [0, lengths) — right-padded ragged prompts supported);
    decode (S==1) updates the rolling/linear cache at per-sequence
    ``pos: [B]`` (scalars are broadcast).

    With ``table`` [B, n_pages] the cache leaves are paged pools
    ([P, psz, ...]) and all reads/writes route through the table.
    ``history=True`` is the suffix-prefill path for prefix-reuse hits:
    ``positions`` [B,S] are absolute rows past an already-populated
    history (shared pages), written through the table and attended via
    the gathered per-slot view under a per-sequence causal mask.

    ``verify=True`` is the speculative-verify path: the S tokens sit at
    per-sequence positions ``pos + i`` PAST the populated cache, and
    every row runs the exact decode-step compute (same einsums, same
    count masking) so row i's logits bit-match the decode step it
    replaces. All S rows' target-rung K/V are written; rejected rows are
    masked by ``pos`` afterwards (linear/paged) or rolled back by the
    caller (ring — see ``models.lm.rollback_ring_cache``)."""
    dtype = jnp.dtype(cfg.dtype)
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if "wqkv" in p:  # fused q/k/v group (serving fast path)
        q, k, v = grouped_dense_apply(p["wqkv"], x, ppac=cfg.ppac, mode=mode)
    else:
        q = dense_apply(p["wq"], x, ppac=cfg.ppac, mode=mode, dtype=dtype)
        k = dense_apply(p["wk"], x, ppac=cfg.ppac, mode=mode, dtype=dtype)
        v = dense_apply(p["wv"], x, ppac=cfg.ppac, mode=mode, dtype=dtype)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    q = rope(q, positions, theta=cfg.rope_theta)
    k = rope(k, positions, theta=cfg.rope_theta)

    sdt = (jnp.bfloat16 if cfg.scores_dtype == "bfloat16" else None)
    paged = table is not None and cache is not None
    new_cache = cache
    if cache is None:
        attn = chunked_attention(q, k, v, causal=True,
                                 window=cfg.sliding_window,
                                 q_chunk=cfg.q_chunk,
                                 remat=cfg.remat != "none", rules=rules,
                                 blocking=cfg.attn_blocking,
                                 scores_dtype=sdt)
    elif history:  # paged suffix prefill after a prefix-cache hit
        assert paged and not cfg.sliding_window
        t = table.shape[1] * cache["k"].shape[1]
        ln = (jnp.full((b,), s, jnp.int32) if lengths is None
              else as_pos_vector(lengths, b))
        row_idx = positions.astype(jnp.int32)               # [B,S] absolute
        valid = (jnp.arange(s)[None, :] < ln[:, None]) & (row_idx < t)
        if "ks" in cache:
            kq, ksc = _q8_kv(k)
            vq, vsc = _q8_kv(v)
            new_cache = {
                "k": paged_scatter(cache["k"], table, kq, row_idx, valid),
                "v": paged_scatter(cache["v"], table, vq, row_idx, valid),
                "ks": paged_scatter(cache["ks"], table, ksc, row_idx, valid),
                "vs": paged_scatter(cache["vs"], table, vsc, row_idx, valid),
            }
        else:
            new_cache = {
                "k": paged_scatter(cache["k"], table, k, row_idx, valid),
                "v": paged_scatter(cache["v"], table, v, row_idx, valid),
            }
        view = {kk: paged_view(vv, table) for kk, vv in new_cache.items()}
        kf = view["k"].astype(q.dtype)
        vf = view["v"].astype(q.dtype)
        if "ks" in view:
            kf = kf * view["ks"].astype(q.dtype)
            vf = vf * view["vs"].astype(q.dtype)
        attn = _attend_causal_rows(q, kf, vf, row_idx, scale=hd ** -0.5,
                                   rules=rules, scores_dtype=sdt)
    elif verify:  # speculative verify: S decode-equivalent rows at pos+i
        pos = as_pos_vector(pos, b)
        row_idx = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
        if "ks" in cache:            # quantized store (same as decode)
            kq, ksc = _q8_kv(k)
            vq, vsc = _q8_kv(v)
            leaves = {"k": kq, "v": vq, "ks": ksc, "vs": vsc}
        else:
            leaves = {"k": k, "v": v}
        if cfg.sliding_window:
            # ring: window rows ride as S appended keys; each query reads
            # a per-query slot-ordered view, so softmax sums in the same
            # key order as the decode steps being replaced.
            assert not paged, "spec verify: paged ring caches unsupported"
            t = cache["k"].shape[1]
            assert s <= t, "verify window must fit the sliding window"
            slot = row_idx % t
            j0 = jnp.mod(jnp.arange(t, dtype=jnp.int32)[None, :]
                         - pos[:, None], t)             # [B,t]
            ext = {kk: jnp.concatenate(
                [cache[kk], vv.astype(cache[kk].dtype)], axis=1)
                for kk, vv in leaves.items()}
            views = _ring_query_views(ext, j0, s, t)
            k_valid = jnp.minimum(row_idx + 1, t)
            attn = _verify_attend_views(q, views, k_valid, scale=hd ** -0.5,
                                        rules=rules)
            new_cache = {kk: _scatter_rows_multi(cache[kk], vv, slot)
                         for kk, vv in leaves.items()}
        elif paged:
            t = table.shape[1] * cache["k"].shape[1]
            # rows past the slot's allocation hit sentinel table entries
            # and drop; rows past the logical extent drop explicitly
            valid = row_idx < t
            new_cache = {kk: paged_scatter(cache[kk], table, vv, row_idx,
                                           valid)
                         for kk, vv in leaves.items()}
            attend = {kk: paged_view(vv, table)
                      for kk, vv in new_cache.items()}
            attn = _decode_attend_q8(q, attend, row_idx + 1,
                                     scale=hd ** -0.5, rules=rules)
        else:
            new_cache = {kk: _scatter_rows_multi(cache[kk], vv, row_idx)
                         for kk, vv in leaves.items()}
            attn = _decode_attend_q8(q, new_cache, row_idx + 1,
                                     scale=hd ** -0.5, rules=rules)
    elif s > 1:  # prefill into cache (cold: no history in the cache yet)
        psz = cache["k"].shape[1]
        t = table.shape[1] * psz if paged else cache["k"].shape[1]
        ln = (jnp.full((b,), s, jnp.int32) if lengths is None
              else as_pos_vector(lengths, b))
        if cfg.sliding_window:
            # ring layout: position p at slot p % t, per-sequence lengths
            kw, vw = _ring_rows(k, ln, t), _ring_rows(v, ln, t)
        else:
            kw, vw = k, v
        if "ks" in cache:
            kq, ksc = _q8_kv(kw)
            vq, vsc = _q8_kv(vw)
            leaves = {"k": kq, "v": vq, "ks": ksc, "vs": vsc}
        else:
            leaves = {"k": kw, "v": vw}
        if paged:
            sw = kw.shape[1]       # t for ring layout, s for linear
            row_idx = jnp.broadcast_to(
                jnp.arange(sw, dtype=jnp.int32)[None, :], (b, sw))
            # ring writes all t ring rows (never-written slots hold zeros,
            # and every ring page is privately allocated); linear drops
            # right-pad rows so they cannot land in shareable pages.
            valid = (None if cfg.sliding_window
                     else (row_idx < ln[:, None]) & (row_idx < t))
            new_cache = {kk: paged_scatter(cache[kk], table, vv, row_idx,
                                           valid)
                         for kk, vv in leaves.items()}
        else:
            new_cache = {kk: lax.dynamic_update_slice(
                cache[kk], vv.astype(cache[kk].dtype),
                (0,) * cache[kk].ndim) for kk, vv in leaves.items()}
        attn = chunked_attention(q, k, v, causal=True,
                                 window=cfg.sliding_window,
                                 q_chunk=cfg.q_chunk,
                                 remat=cfg.remat != "none", rules=rules,
                                 blocking=cfg.attn_blocking,
                                 scores_dtype=sdt)
    else:  # decode, S == 1, per-sequence positions
        t = (table.shape[1] * cache["k"].shape[1] if paged
             else cache["k"].shape[1])
        pos = as_pos_vector(pos, b)
        if cfg.sliding_window:
            slot = pos % t           # rolling (ring) cache
            k_valid = jnp.minimum(pos + 1, t)
        else:
            slot = pos               # linear cache
            k_valid = pos + 1
        if "ks" in cache:            # quantized store
            kq, ksc = _q8_kv(k)
            vq, vsc = _q8_kv(v)
            leaves = {"k": kq, "v": vq, "ks": ksc, "vs": vsc}
        else:
            leaves = {"k": k, "v": v}
        if paged:
            # vacant slots carry all-pad table rows, so their writes drop
            # instead of landing in pages another sequence owns.
            new_cache = {kk: paged_scatter(cache[kk], table, vv,
                                           slot[:, None])
                         for kk, vv in leaves.items()}
            attend = {kk: paged_view(vv, table)
                      for kk, vv in new_cache.items()}
        else:
            new_cache = {kk: _scatter_rows(cache[kk], vv, slot)
                         for kk, vv in leaves.items()}
            attend = new_cache
        # rolling-cache entries are unordered but all within the window,
        # so the validity mask alone is the correct attention mask.
        attn = _decode_attend_q8(q, attend, k_valid, scale=hd ** -0.5,
                                 rules=rules)
    attn = attn.reshape(b, s, h * hd).astype(dtype)
    y = dense_apply(p["wo"], attn, ppac=cfg.ppac, mode=mode, dtype=dtype)
    if cache is not None and rules is not None:
        # Pin the updated leaves to the fitted resident-cache placement:
        # left to propagation, GSPMD pushes the projection shardings onto
        # the outputs, the output sharding diverges from the donated
        # input's, and strict aliasing degrades to a buffer donation
        # (a device-local cache-sized copy every step).
        cax = ((None, None, "kv_heads", None) if paged
               else GQA_CACHE_AXES["k"])
        new_cache = {kk: constrain_fitted(vv, rules, *cax)
                     for kk, vv in new_cache.items()}
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V2-style multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["w_dkv"], a["w_dkv"] = dense_init(ks[0], d, m.kv_lora_rank,
                                        ("embed", "kv_lora"))
    p["norm_kv"], a["norm_kv"] = rmsnorm_init(m.kv_lora_rank, ("kv_lora",))
    p["w_kr"], a["w_kr"] = dense_init(ks[1], d, m.qk_rope_head_dim,
                                      ("embed", None))
    p["w_q"], a["w_q"] = dense_init(
        ks[2], d, h * (m.qk_nope_head_dim + m.qk_rope_head_dim),
        ("embed", "heads"))
    p["w_uk"], a["w_uk"] = dense_init(ks[3], m.kv_lora_rank,
                                      h * m.qk_nope_head_dim,
                                      ("kv_lora", "heads"))
    p["w_uv"], a["w_uv"] = dense_init(ks[4], m.kv_lora_rank,
                                      h * m.v_head_dim, ("kv_lora", "heads"))
    p["wo"], a["wo"] = dense_init(ks[5], h * m.v_head_dim, d,
                                  ("heads", "embed"))
    return p, a


def mla_cache_init(cfg: ModelConfig, batch: int, max_seq: int,
                   dtype=jnp.bfloat16):
    m = cfg.mla
    return {"kv_c": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_seq, m.qk_rope_head_dim), dtype)}


MLA_CACHE_AXES = {"kv_c": ("batch", "kv_seq", None),
                  "k_rope": ("batch", "kv_seq", None)}


def mla_paged_cache_init(cfg: ModelConfig, pool_pages: int, page_size: int,
                         dtype=jnp.bfloat16):
    m = cfg.mla
    return {"kv_c": jnp.zeros((pool_pages, page_size, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((pool_pages, page_size, m.qk_rope_head_dim),
                                dtype)}


MLA_PAGED_CACHE_AXES = {"kv_c": (None, None, None),
                        "k_rope": (None, None, None)}


def mla_apply(p, x, cfg: ModelConfig, *, positions, cache=None, pos=None,
              lengths=None, mode: str = "float", rules=None, table=None,
              history=False, verify=False):
    m = cfg.mla
    dtype = jnp.dtype(cfg.dtype)
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    scale = (dn + dr) ** -0.5

    kv_c = dense_apply(p["w_dkv"], x, dtype=dtype)
    kv_c = rmsnorm_apply(p["norm_kv"], kv_c, eps=cfg.norm_eps, dtype=dtype)
    k_r = dense_apply(p["w_kr"], x, dtype=dtype).reshape(b, s, 1, dr)
    k_r = rope(k_r, positions, theta=cfg.rope_theta).reshape(b, s, dr)

    q = dense_apply(p["w_q"], x, ppac=cfg.ppac, mode=mode, dtype=dtype)
    q = q.reshape(b, s, h, dn + dr)
    q_n, q_r = q[..., :dn], q[..., dn:]
    q_r = rope(q_r, positions, theta=cfg.rope_theta)

    paged = table is not None and cache is not None
    sdt = (jnp.bfloat16 if cfg.scores_dtype == "bfloat16" else None)
    if history:
        # Paged suffix prefill after a prefix-cache hit: scatter the
        # compressed suffix through the table, then regenerate K/V over
        # the gathered per-slot view (history pages included).
        assert paged
        t = table.shape[1] * cache["kv_c"].shape[1]
        ln = (jnp.full((b,), s, jnp.int32) if lengths is None
              else as_pos_vector(lengths, b))
        row_idx = positions.astype(jnp.int32)               # [B,S] absolute
        valid = (jnp.arange(s)[None, :] < ln[:, None]) & (row_idx < t)
        ckp = paged_scatter(cache["kv_c"], table, kv_c, row_idx, valid)
        crp = paged_scatter(cache["k_rope"], table, k_r, row_idx, valid)
        new_cache = {"kv_c": ckp, "k_rope": crp}
        ckv = paged_view(ckp, table).astype(dtype)          # [B,T,lora]
        crv = paged_view(crp, table).astype(dtype)          # [B,T,dr]
        k_n = dense_apply(p["w_uk"], ckv, dtype=dtype).reshape(b, t, h, dn)
        vv = dense_apply(p["w_uv"], ckv, dtype=dtype).reshape(b, t, h, dv)
        k_full = jnp.concatenate(
            [k_n, jnp.broadcast_to(crv[:, :, None, :], (b, t, h, dr))], -1)
        q_full = jnp.concatenate([q_n, q_r], -1)
        attn = _attend_causal_rows(q_full, k_full, vv, row_idx, scale=scale,
                                   rules=rules, scores_dtype=sdt)
    elif cache is None or (s > 1 and not verify):
        # Non-absorbed (train/prefill) path: materialize K/V.
        k_n = dense_apply(p["w_uk"], kv_c, dtype=dtype).reshape(b, s, h, dn)
        v = dense_apply(p["w_uv"], kv_c, dtype=dtype).reshape(b, s, h, dv)
        k_full = jnp.concatenate(
            [k_n, jnp.broadcast_to(k_r[:, :, None, :], (b, s, h, dr))], -1)
        q_full = jnp.concatenate([q_n, q_r], -1)
        attn = chunked_attention(q_full, k_full, v, causal=True,
                                 q_chunk=cfg.q_chunk, scale=scale,
                                 remat=cfg.remat != "none", rules=rules,
                                 blocking=cfg.attn_blocking,
                                 scores_dtype=sdt)
        new_cache = cache
        if paged:
            t = table.shape[1] * cache["kv_c"].shape[1]
            ln = (jnp.full((b,), s, jnp.int32) if lengths is None
                  else as_pos_vector(lengths, b))
            row_idx = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None, :], (b, s))
            valid = (row_idx < ln[:, None]) & (row_idx < t)
            new_cache = {
                "kv_c": paged_scatter(cache["kv_c"], table, kv_c, row_idx,
                                      valid),
                "k_rope": paged_scatter(cache["k_rope"], table, k_r,
                                        row_idx, valid),
            }
        elif cache is not None:
            new_cache = {
                "kv_c": lax.dynamic_update_slice(
                    cache["kv_c"], kv_c.astype(cache["kv_c"].dtype), (0, 0, 0)),
                "k_rope": lax.dynamic_update_slice(
                    cache["k_rope"], k_r.astype(cache["k_rope"].dtype), (0, 0, 0)),
            }
    else:
        # Absorbed decode: score against the compressed cache directly,
        # at per-sequence write positions. The same path serves the
        # S-token speculative verify window (rows at pos+i, per-row
        # causal masks) — the absorbed einsums are S-generic, so every
        # verify row reproduces its decode step's float op order.
        pos = as_pos_vector(pos, b)
        row_idx = pos[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
        if paged:
            t = table.shape[1] * cache["kv_c"].shape[1]
            valid = row_idx < t    # unallocated pages drop via sentinel
            ckp = paged_scatter(cache["kv_c"], table, kv_c, row_idx, valid)
            crp = paged_scatter(cache["k_rope"], table, k_r, row_idx, valid)
            new_cache = {"kv_c": ckp, "k_rope": crp}
            ck = paged_view(ckp, table)
            cr = paged_view(crp, table)
        else:
            ck = _scatter_rows_multi(cache["kv_c"], kv_c, row_idx)
            cr = _scatter_rows_multi(cache["k_rope"], k_r, row_idx)
            new_cache = {"kv_c": ck, "k_rope": cr}
        t = ck.shape[1]
        w_uk = p["w_uk"]["w"].astype(dtype).reshape(m.kv_lora_rank, h, dn)
        # absorb: q' = q_n @ w_uk^T  -> [B,S,H,lora]
        q_abs = jnp.einsum("bshd,lhd->bshl", q_n, w_uk)
        scores = (jnp.einsum("bshl,btl->bhst", q_abs, ck,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshd,btd->bhst", q_r, cr,
                               preferred_element_type=jnp.float32)) * scale
        k_pos = jnp.arange(t)
        mask = k_pos[None, None, :] <= row_idx[:, :, None]     # [B,S,T]
        scores = jnp.where(mask[:, None, :, :], scores, NEG_INF)
        wts = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhst,btl->bshl", wts.astype(ck.dtype), ck,
                         preferred_element_type=jnp.float32)
        w_uv = p["w_uv"]["w"].astype(jnp.float32).reshape(m.kv_lora_rank, h, dv)
        attn = jnp.einsum("bshl,lhv->bshv", ctx, w_uv)

    attn = attn.reshape(b, s, h * dv).astype(dtype)
    y = dense_apply(p["wo"], attn, ppac=cfg.ppac, mode=mode, dtype=dtype)
    if cache is not None and rules is not None:
        # Same strict-aliasing contract as the GQA path (see gqa_apply).
        cax = ((None, None, None) if paged else MLA_CACHE_AXES["kv_c"])
        new_cache = {kk: constrain_fitted(vv, rules, *cax)
                     for kk, vv in new_cache.items()}
    return y, new_cache
