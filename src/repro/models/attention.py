"""Attention variants: GQA (+QKV bias, sliding window), MLA (DeepSeek-style).

Memory-efficient chunked attention: queries are processed in chunks via
``lax.scan`` (peak activation = one [chunk × kv] score tile) with optional
remat of the chunk body — required for the 32k prefill shapes on a real
chip and for bounded compile-time memory on the dry-run.

KV caches are plain pytrees: {"k": [B,T,Hkv,D], "v": [B,T,Hkv,Dv]} with a
*per-sequence* write position ``pos: [B]`` — mixed-progress batches (the
continuous-batching server admits new prompts mid-flight) decode with one
fused step. Sliding-window attention uses a rolling (ring) cache of size
``window`` for decode: position ``p`` always lives at slot ``p % window``,
in prefill and decode alike, so decode can roll straight out of any
prefill length (bounds long-context memory). MLA caches the compressed
(kv_lora + rope) stream and decodes via the absorbed-projection trick —
the KV-memory win that makes it the natural PPAC companion for decode
shapes. Decode writes are batched scatters (per-sequence slots), which
lower in place when the cache pytree is donated (serve/step.py jits every
decode entry point with ``donate_argnums`` on the cache).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs.base import ModelConfig
from ..sharding.rules import constrain
from .layers import (
    dense_apply,
    dense_init,
    grouped_dense_apply,
    rmsnorm_apply,
    rmsnorm_init,
    rope,
)

NEG_INF = -1e9


def _attend_chunk(qc, k, v, q_pos, k_valid, *, window: int, scale: float,
                  causal: bool, rules=None, scores_dtype=None):
    """qc: [B,C,H,D]; k: [B,T,Hkv,D]; v: [B,T,Hkv,Dv]; q_pos: [C] int32.

    Returns [B,C,H,Dv]. GQA keys/values are repeated to the full head
    count and every head-indexed tensor is explicitly constrained to the
    'model' axis: without the constraints GSPMD replicates the quadratic
    score einsums whenever heads don't divide the axis (observed 16x
    redundant compute on smollm — EXPERIMENTS.md §Perf iteration 1).
    """
    b, c, h, d = qc.shape
    t, hkv = k.shape[1], k.shape[2]
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)   # [B,T,H,D]
        v = jnp.repeat(v, rep, axis=2)
    if rules is not None:
        qc = constrain(qc, rules, "batch", None, "act_heads", None)
        k = constrain(k, rules, "batch", None, "act_heads", None)
        v = constrain(v, rules, "batch", None, "act_heads", None)
    return _attend_prepped(qc, k, v, q_pos, k_valid, window=window,
                           scale=scale, causal=causal, rules=rules,
                           scores_dtype=scores_dtype)


def _attend_prepped(qc, k, v, q_pos, k_valid, *, window, scale, causal,
                    rules=None, scores_dtype=None):
    """Like _attend_chunk but assumes k/v are already head-expanded and
    constrained (hoisted out of chunk loops so GSPMD gathers once, not
    once per chunk — §Perf llava iteration 3b)."""
    b, c, h, d = qc.shape
    t = k.shape[1]
    # fp32 ACCUMULATION without materializing fp32 copies of q/k/v
    # (input .astype(f32) casts were ~half the HBM traffic — §Perf it.2)
    scores = jnp.einsum("bchd,bthd->bhct", qc, k,
                        preferred_element_type=jnp.float32) * scale
    if rules is not None:
        scores = constrain(scores, rules, "batch", "act_heads", None, None)
    k_pos = jnp.arange(t)
    mask = k_pos[None, :] < k_valid  # valid cache entries
    if causal:
        mask = mask & (k_pos[None, :] <= q_pos[:, None])
    if window:
        mask = mask & (k_pos[None, :] > q_pos[:, None] - window)
    scores = jnp.where(mask[None, None, :, :], scores, NEG_INF)
    if scores_dtype is not None:
        # bf16 probability boundary (softmax max-subtracts internally;
        # bf16 keeps f32's exponent range) — halves the [C,T] HBM tensors
        scores = scores.astype(scores_dtype)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhct,bthv->bchv", w.astype(qc.dtype), v,
                     preferred_element_type=jnp.float32)
    if rules is not None:
        out = constrain(out, rules, "batch", None, "act_heads", None)
    return out


def chunked_attention(q, k, v, *, q_offset=0, k_valid=None, causal=True,
                      window: int = 0, q_chunk: int = 512,
                      scale: Optional[float] = None, remat: bool = True,
                      rules=None, blocking: str = "scan",
                      scores_dtype=None):
    """q: [B,S,H,D] against k/v: [B,T,Hkv,D*] -> [B,S,H,Dv]."""
    b, s, h, d = q.shape
    t = k.shape[1]
    scale = scale if scale is not None else d ** -0.5
    k_valid = t if k_valid is None else k_valid
    k_valid = jnp.asarray(k_valid, jnp.int32)

    if s <= q_chunk:
        q_pos = q_offset + jnp.arange(s)
        return _attend_chunk(q, k, v, q_pos, k_valid, window=window,
                             scale=scale, causal=causal, rules=rules,
                             scores_dtype=scores_dtype)

    c = q_chunk
    pad = (-s) % c
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nq = q.shape[1] // c

    if blocking == "triangle" and causal and t == s and not window:
        # Unrolled triangular blocking: chunk i only attends to keys
        # [0, (i+1)*c) — statically sliced, so the fully-masked half of
        # the [C, T] score work (and its HBM traffic) never exists.
        # K/V head expansion + sharding constraints are hoisted OUT of
        # the loop (inside it, GSPMD re-gathers per chunk).
        h_full = q.shape[2]
        rep = h_full // k.shape[2]
        if rep > 1:
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
        if rules is not None:
            k = constrain(k, rules, "batch", None, "act_heads", None)
            v = constrain(v, rules, "batch", None, "act_heads", None)
        outs = []

        def chunk_fn(qc, ki, vi, q_pos):
            if rules is not None:
                qc = constrain(qc, rules, "batch", None, "act_heads", None)
            return _attend_prepped(qc, ki, vi, q_pos, ki.shape[1],
                                   window=0, scale=scale, causal=True,
                                   rules=rules, scores_dtype=scores_dtype)

        fn = jax.checkpoint(chunk_fn) if remat else chunk_fn
        for i in range(nq):
            hi = min((i + 1) * c, t)
            qc = q[:, i * c:(i + 1) * c]
            q_pos = q_offset + i * c + jnp.arange(c)
            outs.append(fn(qc, k[:, :hi], v[:, :hi], q_pos))
        out = jnp.concatenate(outs, axis=1)
        return out[:, :s]

    qs = q.reshape(b, nq, c, h, d).transpose(1, 0, 2, 3, 4)  # [nq,B,C,H,D]

    def body(_, xs):
        qc, idx = xs
        q_pos = q_offset + idx * c + jnp.arange(c)
        out = _attend_chunk(qc, k, v, q_pos, k_valid, window=window,
                            scale=scale, causal=causal, rules=rules,
                            scores_dtype=scores_dtype)
        return None, out

    fn = jax.checkpoint(body) if remat else body
    _, ys = lax.scan(fn, None, (qs, jnp.arange(nq)))
    out = ys.transpose(1, 0, 2, 3, 4).reshape(b, nq * c, h, v.shape[-1])
    return out[:, :s]


# ---------------------------------------------------------------------------
# GQA block
# ---------------------------------------------------------------------------

def gqa_init(key, cfg: ModelConfig):
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    p["wq"], a["wq"] = dense_init(ks[0], d, h * hd, ("embed", "heads"),
                                  bias=cfg.qkv_bias)
    p["wk"], a["wk"] = dense_init(ks[1], d, hkv * hd, ("embed", "kv_heads"),
                                  bias=cfg.qkv_bias)
    p["wv"], a["wv"] = dense_init(ks[2], d, hkv * hd, ("embed", "kv_heads"),
                                  bias=cfg.qkv_bias)
    p["wo"], a["wo"] = dense_init(ks[3], h * hd, d, ("heads", "embed"))
    return p, a


def gqa_cache_init(cfg: ModelConfig, batch: int, max_seq: int,
                   dtype=jnp.bfloat16):
    t = min(max_seq, cfg.sliding_window) if cfg.sliding_window else max_seq
    shape = (batch, t, cfg.n_kv_heads, cfg.hd)
    if cfg.kv_dtype == "int8":
        # per-(token, head) max-scaled int8 store — 2x smaller cache, the
        # decode memory-roofline lever paired with PPAC resident weights
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "ks": jnp.zeros(shape[:3] + (1,), jnp.bfloat16),
                "vs": jnp.zeros(shape[:3] + (1,), jnp.bfloat16)}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def gqa_cache_axes(cfg: ModelConfig):
    ax = ("batch", "kv_seq", "kv_heads", None)
    if cfg.kv_dtype == "int8":
        return {"k": ax, "v": ax, "ks": ax, "vs": ax}
    return {"k": ax, "v": ax}


GQA_CACHE_AXES = {"k": ("batch", "kv_seq", "kv_heads", None),
                  "v": ("batch", "kv_seq", "kv_heads", None)}


def _q8_kv(x):
    """x [B,S,Hkv,D] -> (int8 values, bf16 scales [B,S,Hkv,1])."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1,
                    keepdims=True) / 127.0 + 1e-8
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def as_pos_vector(pos, batch: int):
    """Normalize a write position (python int / scalar / [B]) to [B] int32."""
    pos = jnp.asarray(pos, jnp.int32)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (batch,))
    return pos


def _scatter_rows(cache_leaf, rows, slot):
    """Write rows [B,1,...] at per-sequence slots [B] of cache [B,T,...]."""
    b = cache_leaf.shape[0]
    return cache_leaf.at[jnp.arange(b), slot].set(
        rows[:, 0].astype(cache_leaf.dtype), mode="drop")


def _ring_rows(stream, lengths, t: int):
    """Ring-layout a per-position stream into rolling-cache rows.

    stream: [B,S,...] (positions 0..S-1, right-padded past ``lengths``);
    returns [B,t,...] where slot ``s`` holds the *latest* valid position
    ``p < lengths`` with ``p % t == s`` (zeros for never-written slots).
    This is exactly the layout decode's ``slot = pos % t`` writes produce,
    so decode rolls seamlessly out of any prefill length — including
    lengths that are neither multiples of nor smaller than the window.
    """
    b = stream.shape[0]
    ln = lengths[:, None]                              # [B,1]
    s_idx = jnp.arange(t)[None, :]                     # [1,t]
    p = ln - 1 - jnp.mod(ln - 1 - s_idx, t)            # [B,t]
    valid = (p >= 0) & (ln > 0)
    idx = jnp.clip(p, 0, stream.shape[1] - 1)
    rows = jnp.take_along_axis(
        stream, idx.reshape((b, t) + (1,) * (stream.ndim - 2)), axis=1)
    return jnp.where(valid.reshape((b, t) + (1,) * (stream.ndim - 2)),
                     rows, jnp.zeros((), stream.dtype))


def _decode_attend_q8(q, cache, k_valid, *, scale, rules=None):
    """(Optionally quantized) cache decode attention, GQA-grouped (NO
    key/value repeat: repeating a seq-sharded cache forces GSPMD into
    involuntary full rematerialization — replicate + repartition of the
    whole cache per layer; XLA emits a warning and ~800 GiB of phantom
    copies).

    ``k_valid: [B]`` — per-sequence count of valid cache slots (mixed-
    progress batches decode at different positions in one fused step).
    The per-(t,g) scales factor out of both einsums, so no dequantized
    [B,T,G,D] tensor is materialized:
        scores = (q · ki) * ks ;  out = ((w*vs) · vi)
    Like ``_attend_prepped``, every head-indexed einsum is constrained to
    the 'model' axis (the grouped dim g carries the kv-head sharding).
    """
    b, s, h, d = q.shape          # s == 1
    ki, vi = cache["k"], cache["v"]
    ks, vs = cache.get("ks"), cache.get("vs")
    t, g = ki.shape[1], ki.shape[2]
    rep = h // g
    qg = q.reshape(b, s, g, rep, d)
    if rules is not None:
        qg = constrain(qg, rules, "batch", None, "act_heads", None, None)
    scores = jnp.einsum("bsgrd,btgd->bgrst", qg, ki.astype(q.dtype),
                        preferred_element_type=jnp.float32) * scale
    if rules is not None:
        scores = constrain(scores, rules, "batch", "act_heads", None, None,
                           None)
    if ks is not None:
        scores = scores * ks[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
    mask = jnp.arange(t)[None, :] < k_valid[:, None]   # [B,T]
    scores = jnp.where(mask[:, None, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    wv = w.astype(q.dtype)
    if vs is not None:
        wv = wv * vs[..., 0].transpose(0, 2, 1)[:, :, None, None, :]
    out = jnp.einsum("bgrst,btgv->bsgrv", wv, vi.astype(q.dtype),
                     preferred_element_type=jnp.float32)
    if rules is not None:
        out = constrain(out, rules, "batch", None, "act_heads", None, None)
    return out.reshape(b, s, h, -1).astype(q.dtype)


def gqa_apply(p, x, cfg: ModelConfig, *, positions, cache=None, pos=None,
              lengths=None, mode: str = "float", rules=None):
    """x: [B,S,d]. Train/prefill when cache is None or S>1 (writes cache
    at positions [0, lengths) — right-padded ragged prompts supported);
    decode (S==1) updates the rolling/linear cache at per-sequence
    ``pos: [B]`` (scalars are broadcast)."""
    dtype = jnp.dtype(cfg.dtype)
    b, s, d = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    if "wqkv" in p:  # fused q/k/v group (serving fast path)
        q, k, v = grouped_dense_apply(p["wqkv"], x, ppac=cfg.ppac)
    else:
        q = dense_apply(p["wq"], x, ppac=cfg.ppac, mode=mode, dtype=dtype)
        k = dense_apply(p["wk"], x, ppac=cfg.ppac, mode=mode, dtype=dtype)
        v = dense_apply(p["wv"], x, ppac=cfg.ppac, mode=mode, dtype=dtype)
    q = q.reshape(b, s, h, hd)
    k = k.reshape(b, s, hkv, hd)
    v = v.reshape(b, s, hkv, hd)
    q = rope(q, positions, theta=cfg.rope_theta)
    k = rope(k, positions, theta=cfg.rope_theta)

    sdt = (jnp.bfloat16 if cfg.scores_dtype == "bfloat16" else None)
    new_cache = cache
    if cache is None:
        attn = chunked_attention(q, k, v, causal=True,
                                 window=cfg.sliding_window,
                                 q_chunk=cfg.q_chunk,
                                 remat=cfg.remat != "none", rules=rules,
                                 blocking=cfg.attn_blocking,
                                 scores_dtype=sdt)
    elif s > 1:  # prefill into cache
        t = cache["k"].shape[1]
        if cfg.sliding_window:
            # ring layout: position p at slot p % t, per-sequence lengths
            ln = (jnp.full((b,), s, jnp.int32) if lengths is None
                  else as_pos_vector(lengths, b))
            kw, vw = _ring_rows(k, ln, t), _ring_rows(v, ln, t)
        else:
            kw, vw = k, v
        if "ks" in cache:
            kq, ksc = _q8_kv(kw)
            vq, vsc = _q8_kv(vw)
            new_cache = {
                "k": lax.dynamic_update_slice(cache["k"], kq, (0, 0, 0, 0)),
                "v": lax.dynamic_update_slice(cache["v"], vq, (0, 0, 0, 0)),
                "ks": lax.dynamic_update_slice(cache["ks"], ksc, (0, 0, 0, 0)),
                "vs": lax.dynamic_update_slice(cache["vs"], vsc, (0, 0, 0, 0)),
            }
        else:
            new_cache = {
                "k": lax.dynamic_update_slice(cache["k"], kw.astype(cache["k"].dtype),
                                              (0, 0, 0, 0)),
                "v": lax.dynamic_update_slice(cache["v"], vw.astype(cache["v"].dtype),
                                              (0, 0, 0, 0)),
            }
        attn = chunked_attention(q, k, v, causal=True,
                                 window=cfg.sliding_window,
                                 q_chunk=cfg.q_chunk,
                                 remat=cfg.remat != "none", rules=rules,
                                 blocking=cfg.attn_blocking,
                                 scores_dtype=sdt)
    else:  # decode, S == 1, per-sequence positions
        t = cache["k"].shape[1]
        pos = as_pos_vector(pos, b)
        if cfg.sliding_window:
            slot = pos % t           # rolling (ring) cache
            k_valid = jnp.minimum(pos + 1, t)
        else:
            slot = pos               # linear cache
            k_valid = pos + 1
        if "ks" in cache:            # quantized store
            kq, ksc = _q8_kv(k)
            vq, vsc = _q8_kv(v)
            new_cache = {
                "k": _scatter_rows(cache["k"], kq, slot),
                "v": _scatter_rows(cache["v"], vq, slot),
                "ks": _scatter_rows(cache["ks"], ksc, slot),
                "vs": _scatter_rows(cache["vs"], vsc, slot),
            }
        else:
            new_cache = {"k": _scatter_rows(cache["k"], k, slot),
                         "v": _scatter_rows(cache["v"], v, slot)}
        # rolling-cache entries are unordered but all within the window,
        # so the validity mask alone is the correct attention mask.
        attn = _decode_attend_q8(q, new_cache, k_valid, scale=hd ** -0.5,
                                 rules=rules)
    attn = attn.reshape(b, s, h * hd).astype(dtype)
    y = dense_apply(p["wo"], attn, ppac=cfg.ppac, mode=mode, dtype=dtype)
    return y, new_cache


# ---------------------------------------------------------------------------
# MLA block (DeepSeek-V2-style multi-head latent attention)
# ---------------------------------------------------------------------------

def mla_init(key, cfg: ModelConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    p, a = {}, {}
    p["w_dkv"], a["w_dkv"] = dense_init(ks[0], d, m.kv_lora_rank,
                                        ("embed", "kv_lora"))
    p["norm_kv"], a["norm_kv"] = rmsnorm_init(m.kv_lora_rank, ("kv_lora",))
    p["w_kr"], a["w_kr"] = dense_init(ks[1], d, m.qk_rope_head_dim,
                                      ("embed", None))
    p["w_q"], a["w_q"] = dense_init(
        ks[2], d, h * (m.qk_nope_head_dim + m.qk_rope_head_dim),
        ("embed", "heads"))
    p["w_uk"], a["w_uk"] = dense_init(ks[3], m.kv_lora_rank,
                                      h * m.qk_nope_head_dim,
                                      ("kv_lora", "heads"))
    p["w_uv"], a["w_uv"] = dense_init(ks[4], m.kv_lora_rank,
                                      h * m.v_head_dim, ("kv_lora", "heads"))
    p["wo"], a["wo"] = dense_init(ks[5], h * m.v_head_dim, d,
                                  ("heads", "embed"))
    return p, a


def mla_cache_init(cfg: ModelConfig, batch: int, max_seq: int,
                   dtype=jnp.bfloat16):
    m = cfg.mla
    return {"kv_c": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, max_seq, m.qk_rope_head_dim), dtype)}


MLA_CACHE_AXES = {"kv_c": ("batch", "kv_seq", None),
                  "k_rope": ("batch", "kv_seq", None)}


def mla_apply(p, x, cfg: ModelConfig, *, positions, cache=None, pos=None,
              lengths=None, mode: str = "float", rules=None):
    m = cfg.mla
    dtype = jnp.dtype(cfg.dtype)
    b, s, d = x.shape
    h = cfg.n_heads
    dn, dr, dv = m.qk_nope_head_dim, m.qk_rope_head_dim, m.v_head_dim
    scale = (dn + dr) ** -0.5

    kv_c = dense_apply(p["w_dkv"], x, dtype=dtype)
    kv_c = rmsnorm_apply(p["norm_kv"], kv_c, eps=cfg.norm_eps, dtype=dtype)
    k_r = dense_apply(p["w_kr"], x, dtype=dtype).reshape(b, s, 1, dr)
    k_r = rope(k_r, positions, theta=cfg.rope_theta).reshape(b, s, dr)

    q = dense_apply(p["w_q"], x, ppac=cfg.ppac, mode=mode, dtype=dtype)
    q = q.reshape(b, s, h, dn + dr)
    q_n, q_r = q[..., :dn], q[..., dn:]
    q_r = rope(q_r, positions, theta=cfg.rope_theta)

    if cache is None or s > 1:
        # Non-absorbed (train/prefill) path: materialize K/V.
        k_n = dense_apply(p["w_uk"], kv_c, dtype=dtype).reshape(b, s, h, dn)
        v = dense_apply(p["w_uv"], kv_c, dtype=dtype).reshape(b, s, h, dv)
        k_full = jnp.concatenate(
            [k_n, jnp.broadcast_to(k_r[:, :, None, :], (b, s, h, dr))], -1)
        q_full = jnp.concatenate([q_n, q_r], -1)
        attn = chunked_attention(q_full, k_full, v, causal=True,
                                 q_chunk=cfg.q_chunk, scale=scale,
                                 remat=cfg.remat != "none", rules=rules,
                                 blocking=cfg.attn_blocking,
                                 scores_dtype=(jnp.bfloat16
                                               if cfg.scores_dtype == "bfloat16"
                                               else None))
        new_cache = cache
        if cache is not None:
            new_cache = {
                "kv_c": lax.dynamic_update_slice(
                    cache["kv_c"], kv_c.astype(cache["kv_c"].dtype), (0, 0, 0)),
                "k_rope": lax.dynamic_update_slice(
                    cache["k_rope"], k_r.astype(cache["k_rope"].dtype), (0, 0, 0)),
            }
    else:
        # Absorbed decode: score against the compressed cache directly,
        # at per-sequence write positions.
        pos = as_pos_vector(pos, b)
        ck = _scatter_rows(cache["kv_c"], kv_c, pos)
        cr = _scatter_rows(cache["k_rope"], k_r, pos)
        new_cache = {"kv_c": ck, "k_rope": cr}
        t = ck.shape[1]
        w_uk = p["w_uk"]["w"].astype(dtype).reshape(m.kv_lora_rank, h, dn)
        # absorb: q' = q_n @ w_uk^T  -> [B,1,H,lora]
        q_abs = jnp.einsum("bshd,lhd->bshl", q_n, w_uk)
        scores = (jnp.einsum("bshl,btl->bhst", q_abs, ck,
                             preferred_element_type=jnp.float32)
                  + jnp.einsum("bshd,btd->bhst", q_r, cr,
                               preferred_element_type=jnp.float32)) * scale
        k_pos = jnp.arange(t)
        mask = k_pos[None, :] <= pos[:, None]          # [B,T]
        scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
        wts = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhst,btl->bshl", wts.astype(ck.dtype), ck,
                         preferred_element_type=jnp.float32)
        w_uv = p["w_uv"]["w"].astype(jnp.float32).reshape(m.kv_lora_rank, h, dv)
        attn = jnp.einsum("bshl,lhv->bshv", ctx, w_uv)

    attn = attn.reshape(b, s, h * dv).astype(dtype)
    y = dense_apply(p["wo"], attn, ppac=cfg.ppac, mode=mode, dtype=dtype)
    return y, new_cache
