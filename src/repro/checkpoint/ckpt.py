"""Fault-tolerant, mesh-elastic checkpointing.

Design (production constraints, scaled down to one host):
  * atomic: write to ``step_XXXX.tmp/`` then rename — a crash mid-write
    never corrupts the latest checkpoint;
  * self-describing: a JSON manifest stores the tree structure, shapes,
    dtypes, step and data-iterator state;
  * mesh-elastic: arrays are saved unsharded-logical (gathered); restore
    accepts any target mesh/sharding — ``restore(..., shardings=...)``
    device_puts each leaf with the *new* mesh's NamedSharding, so a job
    can restart on a different pod count (elastic scaling);
  * bounded retention: ``keep`` most recent checkpoints are retained.

On a real multi-host pod this writes per-host shard files; the single-host
container writes one file per leaf group (npz).
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = leaf
    return out


def save(ckpt_dir: str, step: int, state, *, extra: Optional[Dict] = None,
         keep: int = 3) -> str:
    """Atomically save `state` (pytree) at `step`."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    leaves = _flatten_with_paths(state)
    arrays = {k: np.asarray(v) for k, v in leaves.items()}
    np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
    manifest = {
        "step": step,
        "keys": {k: {"shape": list(a.shape), "dtype": str(a.dtype)}
                 for k, a in arrays.items()},
        "extra": extra or {},
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic publish

    # retention
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
    return final


def all_steps(ckpt_dir: str):
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for d in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", d)
        if m and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
            out.append(int(m.group(1)))
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, template, *, shardings=None):
    """Restore into the structure of `template`. If `shardings` (matching
    pytree of jax.sharding.Sharding) is given, leaves are placed with the
    *target* sharding — this is the elastic-re-mesh path."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    leaves_t, treedef = jax.tree_util.tree_flatten(template)
    keys = list(_flatten_with_paths(template).keys())
    assert len(keys) == len(leaves_t)
    new_leaves = []
    flat_sh = (jax.tree_util.tree_flatten(shardings)[0]
               if shardings is not None else [None] * len(keys))
    for key, tleaf, sh in zip(keys, leaves_t, flat_sh):
        arr = data[key]
        want = tuple(getattr(tleaf, "shape", arr.shape))
        assert tuple(arr.shape) == want, (key, arr.shape, want)
        if sh is not None:
            new_leaves.append(jax.device_put(arr, sh))
        else:
            new_leaves.append(jax.numpy.asarray(arr))
    return treedef.unflatten(new_leaves), manifest["extra"]
