from .ckpt import all_steps, latest_step, restore, save  # noqa: F401
