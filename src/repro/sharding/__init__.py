from .rules import (  # noqa: F401
    DEFAULT_RULES,
    ShardingRules,
    constrain,
    default_rules,
    tree_shardings,
    tree_specs,
)
