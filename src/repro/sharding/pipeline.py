"""GPipe-style pipeline parallelism over a 'pipe' mesh axis.

Stages hold disjoint layer slices; microbatches flow through a rotating
``lax.ppermute`` ring inside a fully-manual ``shard_map`` (fully manual —
the partial-manual form crashes the CPU XLA backend, see EXPERIMENTS.md).
The schedule is the classic M+S-1-tick GPipe pipeline:

    tick t: stage s computes microbatch (t - s) if 0 <= t-s < M,
            then passes its activation to stage s+1.

Differentiable end-to-end (ppermute has a transpose rule), so the same
function serves training; bubble fraction = (S-1)/(M+S-1).

This maps pods to stages on the production mesh (pod axis = pipe) as the
alternative to pure cross-pod DP; the dry-run default keeps DP because
the assigned shapes are batch-rich, but the feature is here and tested.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from .compat import shard_map


def pipeline_apply(stage_fn: Callable, stage_params, x, *, mesh: Mesh,
                   axis: str = "pipe", microbatches: int):
    """Run ``y = stage_{S-1}(...stage_0(x))`` pipelined over `axis`.

    stage_params: pytree stacked on a leading stage dim (sharded over
    `axis`). x: [B, ...] global batch (replicated); B % microbatches == 0.
    Returns y with x's shape. stage_fn(params_slice, h) -> h.
    """
    s_count = mesh.shape[axis]
    m = microbatches
    b = x.shape[0]
    assert b % m == 0, (b, m)
    mb = b // m
    xs = x.reshape((m, mb) + x.shape[1:])

    def body(params_local, xs_rep):
        # params_local: stage slice [1, ...]; xs_rep: full [M, mb, ...]
        sid = lax.axis_index(axis)
        p_slice = jax.tree.map(lambda t: t[0], params_local)
        perm = [(i, (i + 1) % s_count) for i in range(s_count)]

        state = jnp.zeros_like(xs_rep[0])
        outs = jnp.zeros_like(xs_rep)
        for t in range(m + s_count - 1):
            # stage 0 ingests microbatch t (while it exists)
            inject = xs_rep[min(t, m - 1)]
            h_in = jnp.where(sid == 0, inject, state)
            h_out = stage_fn(p_slice, h_in)
            # last stage emits microbatch t - (S-1)
            emit_idx = t - (s_count - 1)
            if 0 <= emit_idx < m:
                outs = outs.at[emit_idx].set(
                    jnp.where(sid == s_count - 1, h_out, outs[emit_idx]))
            state = lax.ppermute(h_out, axis, perm)
        # non-last stages contributed exact zeros, so a psum replicates
        # the last stage's result everywhere
        outs = lax.psum(outs, axis)
        return outs

    fn = shard_map(body, mesh=mesh, in_specs=(P(axis), P()), out_specs=P())
    y = fn(stage_params, xs)
    return y.reshape((b,) + x.shape[1:])
