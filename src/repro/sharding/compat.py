"""shard_map across jax versions.

jax >= 0.5 exposes ``jax.shard_map`` (with ``check_vma`` and, for
partial-manual mode, ``axis_names``); 0.4.x only has
``jax.experimental.shard_map.shard_map`` (``check_rep`` and the
complementary ``auto=`` axis set). One entry point hides the difference;
replication/VMA checking is always off (the repo uses fully-manual or
pod-manual bodies that those checkers reject).
"""
from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs, axis_names=None):
    """axis_names: iterable of *manual* mesh axes; None -> fully manual."""
    if hasattr(jax, "shard_map"):
        kw = {} if axis_names is None else {"axis_names": set(axis_names)}
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False, **kw)
    from jax.experimental.shard_map import shard_map as _shard_map

    kw = {}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=False, **kw)
