"""Logical-axis sharding rules (MaxText-style) for the whole model zoo.

Every parameter is annotated at init time with a tuple of *logical* axis
names; a rules table maps logical axes to mesh axes. One table drives TP,
EP, SP and DP for all ten architectures, and the perf hillclimb mutates the
table instead of the model code.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

MeshAxes = Union[None, str, Tuple[str, ...]]

# Default rules: Megatron-style TP on 'model', DP over ('pod','data').
DEFAULT_RULES: Dict[str, MeshAxes] = {
    # weights
    "embed": None,               # d_model dim of weights: replicated
    "mlp": "model",              # FFN hidden
    "heads": "model",            # attention heads (fused q dim)
    "kv_heads": "model",         # KV heads (GQA; uneven sizes padded by GSPMD)
    "head_dim": None,
    "vocab": "model",            # embedding/output vocab dim
    "expert": "model",           # MoE expert dim (EP)
    "expert_mlp": None,
    "kv_lora": None,             # MLA compression dim
    "ssm_inner": "model",        # Mamba d_inner / heads
    "ssm_state": None,
    "conv": None,
    "layers": None,              # stacked scan dim: always replicated
    "qblocks": ("data", "model"),  # int8 optimizer moment blocks (ZeRO)
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "kv_seq": None,              # KV-cache seq dim (SP shards this for 500k)
    "act_embed": None,
    "act_heads": "model",
    "groups": ("pod", "data"),   # MoE dispatch groups
    "expert_cap": None,
}


@dataclasses.dataclass
class ShardingRules:
    rules: Dict[str, MeshAxes]

    def __hash__(self):
        # treated as immutable everywhere (with_overrides/for_mesh build
        # new instances); hashable so jitted-entry-point factories can
        # lru-cache on (cfg, rules, ...) instead of retracing per call
        return hash(tuple(sorted(self.rules.items())))

    def spec(self, logical_axes: Optional[Tuple[Optional[str], ...]]) -> PartitionSpec:
        if logical_axes is None:
            return PartitionSpec()
        out = []
        for ax in logical_axes:
            r = self.rules.get(ax) if ax is not None else None
            out.append(r)
        return PartitionSpec(*out)

    def with_overrides(self, **kv) -> "ShardingRules":
        d = dict(self.rules)
        d.update(kv)
        return ShardingRules(d)

    def for_mesh(self, mesh: Mesh) -> "ShardingRules":
        """Drop mesh axes that don't exist in `mesh` (e.g. 'pod' on the
        single-pod mesh) from every rule."""
        names = set(mesh.axis_names)

        def fit(v: MeshAxes) -> MeshAxes:
            if v is None:
                return None
            if isinstance(v, str):
                return v if v in names else None
            kept = tuple(a for a in v if a in names)
            if not kept:
                return None
            return kept[0] if len(kept) == 1 else kept

        return ShardingRules({k: fit(v) for k, v in self.rules.items()})


def default_rules(**overrides) -> ShardingRules:
    return ShardingRules(dict(DEFAULT_RULES)).with_overrides(**overrides)


def tree_specs(rules: ShardingRules, axes_tree):
    """Map a tree of logical-axis tuples to PartitionSpecs."""
    return jax.tree.map(
        lambda axes: rules.spec(axes),
        axes_tree,
        is_leaf=lambda x: x is None or (isinstance(x, tuple)
                                        and all(a is None or isinstance(a, str)
                                                for a in x)),
    )


def tree_shardings(mesh: Mesh, rules: ShardingRules, axes_tree):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        tree_specs(rules, axes_tree),
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


def fit_spec(mesh: Mesh, spec: PartitionSpec, shape) -> PartitionSpec:
    """Drop sharded axes that do not divide the dimension evenly (explicit
    pjit argument shardings require exact divisibility; GSPMD pads only
    internal constraints). Also truncates specs longer than the rank."""
    out = []
    seen = set()
    entries = tuple(spec)[: len(shape)]
    for d, ax in enumerate(entries):
        if ax is None:
            out.append(None)
            continue
        axes = tuple(a for a in ((ax,) if isinstance(ax, str) else tuple(ax))
                     if a not in seen)  # a mesh axis may appear only once
        if not axes:
            out.append(None)
            continue
        prod = 1
        for a in axes:
            prod *= mesh.shape[a]
        if shape[d] % prod == 0:
            seen.update(axes)
            out.append(axes if len(axes) > 1 else axes[0])
        else:
            out.append(None)
    return PartitionSpec(*out)


def fitted_shardings(mesh: Mesh, rules: ShardingRules, axes_tree, shapes_tree):
    """NamedShardings with non-divisible axes dropped per-leaf."""
    is_ax = lambda x: x is None or (isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x))

    def one(ax, leaf):
        spec = rules.spec(ax)
        return NamedSharding(mesh, fit_spec(mesh, spec, tuple(leaf.shape)))

    return jax.tree.map(one, axes_tree, shapes_tree, is_leaf=is_ax)


def constrain(x, rules: ShardingRules, *logical_axes):
    """with_sharding_constraint by logical axes (no-op outside mesh ctx)."""
    try:
        return jax.lax.with_sharding_constraint(x, rules.spec(logical_axes))
    except Exception:
        return x


def active_mesh():
    """The physical mesh of the enclosing ``with mesh:`` block, or None."""
    try:
        m = jax.interpreters.pxla.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def constrain_fitted(x, rules: ShardingRules, *logical_axes):
    """Like :func:`constrain`, but drops mesh axes that do not divide the
    dimension (mirrors :func:`fit_spec`). Donated buffers only alias
    strictly when the traced output sharding matches the fitted input
    placement, so in-place cache updates must constrain with the same
    divisibility rule the placement used. No-op outside a mesh context."""
    mesh = active_mesh()
    if mesh is None or rules is None:
        return x
    try:
        spec = fit_spec(mesh, rules.spec(logical_axes), tuple(x.shape))
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x
