"""Batched GF(2) linear algebra on PPAC: affine maps, keystreams, CRC.

Everything here reduces to the paper's §III-D GF(2) MVP mode — the one
workload where PPAC's fully-digital design is qualitatively ahead of
mixed-signal PIM (bit-true LSB arithmetic cannot tolerate analog error):

* ``affine_map``       — y = A·x ⊕ c (e.g. the AES S-box finishing step),
  batched over inputs.
* ``lfsr_keystream``   — T bits of a Fibonacci LFSR produced in one MVP:
  the t-th output bit is e_outᵀ Cᵗ s₀ for the companion matrix C, so a
  whole keystream block is the GF(2) product of the precomputed
  observation matrix [e_outᵀ Cᵗ]ₜ with the seed state.
* ``scramble``         — additive scrambler: data ⊕ keystream (its own
  inverse, as ``descramble`` aliases).
* ``crc``              — for a fixed message length, CRC is a linear map
  over GF(2); the [deg, msg_len] CRC matrix is precomputed column-wise
  and applied as one batched MVP.

Matrix *construction* (companion powers, CRC columns) is host-side numpy
— it is configuration, like loading the latch array, which the paper
excludes from its measurements (§IV-A).  The *application* is always a
PPAC GF(2) MVP through the unified kernel engine
(:func:`repro.kernels.engine.ppac_matmul`, mode ``"gf2"``).

``gf2_cycles`` prices one batched MVP in emulated PPAC cycles using the
same tile-virtualization rules as ``retrieval.index.CAMIndex``: every
(row, col) tile of the configured array geometry runs one GF(2) cycle;
col-split partial parities merge through an XOR tree (ceil(log2 ct)
cycles) — an XOR tree, not the adder tree of the integer modes, which is
exactly why the merge depth is the same but the peripheral is cheaper
(Table III: GF(2) burns 353 mW vs 498 mW for ±1 MVPs).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from ..core.backend import resolve_backend  # noqa: F401  (re-exported)
from ..core.cost_model import tiled_scan_merge_cycles
from ..core.formats import pack_bits
from ..core.ppac import CycleCounter, PPACConfig
from ..kernels.engine import ppac_matmul


def gf2_cycles(nq: int, m_rows: int, n_bits: int,
               config: Optional[PPACConfig] = None,
               parallel_arrays: Optional[int] = None) -> int:
    """Emulated cycles for ``nq`` GF(2) MVPs against an [m_rows, n_bits]
    matrix virtualized onto the configured array geometry."""
    return nq * tiled_scan_merge_cycles(m_rows, n_bits, config,
                                        parallel_arrays)


def gf2_matvec(x_bits, a_bits, *, backend: str = "auto",
               counter: Optional[CycleCounter] = None,
               config: Optional[PPACConfig] = None) -> jnp.ndarray:
    """y = x Aᵀ over GF(2) on unpacked {0,1} arrays: [B, n] × [m, n] -> [B, m]."""
    x = np.asarray(x_bits, np.uint8)
    a = np.asarray(a_bits, np.uint8)
    assert x.ndim == 2 and a.ndim == 2 and x.shape[1] == a.shape[1], \
        (x.shape, a.shape)
    out = ppac_matmul(pack_bits(x), pack_bits(a), mode="gf2", n=x.shape[1],
                      backend=backend)
    if counter is not None:
        counter.tick(gf2_cycles(x.shape[0], a.shape[0], x.shape[1], config)
                     + counter.pipeline_latency)
    return out


def affine_map(x_bits, a_bits, c_bits=None, *, backend: str = "auto",
               counter: Optional[CycleCounter] = None,
               config: Optional[PPACConfig] = None) -> jnp.ndarray:
    """Batched GF(2) affine map y = A·x ⊕ c: [B, n] -> [B, m].

    The xor constant rides on the row ALU's offset path (cEn/c in Fig. 2c)
    and costs no extra cycles.
    """
    y = gf2_matvec(x_bits, a_bits, backend=backend, counter=counter,
                   config=config)
    if c_bits is not None:
        y = y ^ jnp.asarray(c_bits, jnp.uint8)[None, :]
    return y


# ---------------------------------------------------------------------------
# LFSR keystreams / scramblers
# ---------------------------------------------------------------------------

def lfsr_companion(taps: Sequence[int], deg: int) -> np.ndarray:
    """Companion matrix C of a Fibonacci LFSR over GF(2): s' = C s.

    ``taps`` are the Fibonacci feedback tap positions in [1, deg]: the new
    bit is ⊕_{t∈taps} s[t-1].  The maximal-length x⁷+x⁶+1 register is
    taps=(7, 6), deg=7.  State bit 0 is the newest; the output bit is
    state bit deg-1 (the oldest).
    """
    c = np.zeros((deg, deg), np.uint8)
    for t in taps:
        assert 1 <= t <= deg, t
        c[0, t - 1] = 1
    for i in range(1, deg):
        c[i, i - 1] = 1
    return c


@functools.lru_cache(maxsize=64)
def _lfsr_observation_matrix(taps: tuple, deg: int, length: int) -> np.ndarray:
    c = lfsr_companion(taps, deg)
    row = np.zeros((deg,), np.uint8)
    row[deg - 1] = 1
    rows = np.empty((length, deg), np.uint8)
    for t in range(length):
        rows[t] = row
        row = (row @ c) % 2  # e C^{t+1} = (e C^t) C
    return rows


def lfsr_observation_matrix(taps: Sequence[int], deg: int,
                            length: int) -> np.ndarray:
    """[length, deg] matrix M with M[t] = e_{deg-1}ᵀ Cᵗ, so that the first
    ``length`` output bits of the LFSR seeded with s₀ are M · s₀.
    Cached per (taps, deg, length) — serving loops reuse it every call."""
    return _lfsr_observation_matrix(tuple(taps), deg, length).copy()


def lfsr_keystream(states, taps: Sequence[int], length: int, *,
                   backend: str = "auto",
                   counter: Optional[CycleCounter] = None,
                   config: Optional[PPACConfig] = None) -> jnp.ndarray:
    """Keystream blocks [B, length] from seed states [B, deg] — one MVP."""
    states = np.atleast_2d(np.asarray(states, np.uint8))
    obs = lfsr_observation_matrix(taps, states.shape[1], length)
    return gf2_matvec(states, obs, backend=backend, counter=counter,
                      config=config)


def scramble(data_bits, states, taps: Sequence[int], *,
             backend: str = "auto",
             counter: Optional[CycleCounter] = None,
             config: Optional[PPACConfig] = None) -> jnp.ndarray:
    """Additive scrambler: data ⊕ keystream(state). Involutive."""
    data = np.atleast_2d(np.asarray(data_bits, np.uint8))
    ks = lfsr_keystream(states, taps, data.shape[1], backend=backend,
                        counter=counter, config=config)
    return jnp.asarray(data) ^ ks


descramble = scramble  # x ⊕ ks ⊕ ks = x


# ---------------------------------------------------------------------------
# CRC as a batched MVP
# ---------------------------------------------------------------------------

def crc_reference(msg_bits, poly: int, deg: int) -> int:
    """Bit-serial CRC (init=0, no reflection/xorout): remainder of
    m(x)·x^deg mod g(x).  ``poly`` holds g's low ``deg`` coefficient bits
    (bit i = coefficient of xⁱ); msg_bits are MSB (highest power) first."""
    reg = 0
    mask = (1 << deg) - 1
    for b in msg_bits:
        top = (reg >> (deg - 1)) & 1
        reg = ((reg << 1) & mask) | 0
        if top ^ int(b):
            reg ^= poly
    return reg


@functools.lru_cache(maxsize=64)
def _crc_matrix(poly: int, deg: int, msg_len: int) -> np.ndarray:
    r = np.zeros((deg, msg_len), np.uint8)
    for j in range(msg_len):
        e = np.zeros(msg_len, np.uint8)
        e[j] = 1
        val = crc_reference(e, poly, deg)
        r[:, j] = [(val >> i) & 1 for i in range(deg)]
    return r


def crc_matrix(poly: int, deg: int, msg_len: int) -> np.ndarray:
    """[deg, msg_len] GF(2) matrix R with crc(m) = R·m (column j = CRC of
    the unit message e_j); CRC bit i of the output is coefficient xⁱ.
    Cached per (poly, deg, msg_len) — the O(msg_len²) bit-serial setup
    runs once, not per batch."""
    return _crc_matrix(poly, deg, msg_len).copy()


def crc(msgs, poly: int, deg: int, *, backend: str = "auto",
        counter: Optional[CycleCounter] = None,
        config: Optional[PPACConfig] = None) -> jnp.ndarray:
    """Batched CRC [B, deg] of fixed-length messages [B, msg_len]."""
    msgs = np.atleast_2d(np.asarray(msgs, np.uint8))
    r = crc_matrix(poly, deg, msgs.shape[1])
    return gf2_matvec(msgs, r, backend=backend, counter=counter,
                      config=config)


# ---------------------------------------------------------------------------
# Integrity tags over byte buffers (KV pages, resident weight planes)
# ---------------------------------------------------------------------------

CRC32_POLY = 0x04C11DB7  # IEEE 802.3 generator, low-32 coefficient bits


def crc_tags(bufs, *, poly: int = CRC32_POLY, deg: int = 32,
             chunk_bits: int = 256, backend: str = "auto",
             counter: Optional[CycleCounter] = None,
             config: Optional[PPACConfig] = None) -> np.ndarray:
    """Integrity tags of ``B`` equal-length byte buffers as ONE batched
    CRC-as-MVP: [B, nbytes] uint8 -> [B] uint64.

    A whole KV page (kilobytes) as one CRC message would need an
    O(msg_len^2) bit-serial matrix build; instead each buffer is split
    into ``chunk_bits``-bit chunks (zero-padded tail), all chunks of all
    buffers stream through one cached [deg, chunk_bits] CRC matrix in a
    single GF(2) MVP launch, and the per-chunk remainders XOR-fold into
    one tag per buffer. CRC is linear over GF(2), so any single flipped
    bit perturbs exactly one chunk's remainder by a nonzero column
    syndrome and survives the fold — single-bit (and odd-weight)
    corruption is always detected, which is the scrub's contract.
    """
    bufs = np.atleast_2d(np.asarray(bufs, np.uint8))
    b = bufs.shape[0]
    bits = np.unpackbits(bufs, axis=1)
    pad = (-bits.shape[1]) % chunk_bits
    if pad:
        bits = np.pad(bits, ((0, 0), (0, pad)))
    chunks = bits.shape[1] // chunk_bits
    syn = np.asarray(crc(bits.reshape(b * chunks, chunk_bits), poly, deg,
                         backend=backend, counter=counter, config=config),
                     np.uint8)
    folded = np.bitwise_xor.reduce(syn.reshape(b, chunks, deg), axis=1)
    weights = np.left_shift(np.uint64(1), np.arange(deg, dtype=np.uint64))
    return (folded.astype(np.uint64) * weights).sum(axis=1, dtype=np.uint64)


def crc_tag(buf, **kw) -> int:
    """Scalar convenience: one buffer (any array/bytes) -> one int tag."""
    arr = np.frombuffer(bytes(buf), np.uint8) if isinstance(
        buf, (bytes, bytearray)) else np.ascontiguousarray(buf)
    flat = np.frombuffer(arr.tobytes(), np.uint8)
    return int(crc_tags(flat[None, :], **kw)[0])
