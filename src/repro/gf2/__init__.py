"""GF(2) linear-algebra + coding subsystem on PPAC (paper §III-D at scale).

ops     — batched affine maps, LFSR keystreams/scramblers, CRC-as-MVP,
          and the tile-virtualized GF(2) cycle model
ldpc    — systematic LDPC codes (random [P|L] + array codes), encode via
          back-substitution, iterative bit-flipping decoder with
          per-iteration PPAC cycle accounting
sharded — codeword blocks row-sharded over a mesh via shard_map
"""
from .ldpc import (  # noqa: F401
    BitFlipDecoder,
    DecodeResult,
    LDPCCode,
    bsc_flip,
    make_array_ldpc,
    make_random_ldpc,
    solve_unit_lower,
)
from .ops import (  # noqa: F401
    affine_map,
    crc,
    crc_matrix,
    crc_reference,
    descramble,
    gf2_cycles,
    gf2_matvec,
    lfsr_companion,
    lfsr_keystream,
    lfsr_observation_matrix,
    scramble,
)
