"""Row-sharded LDPC decode: codeword blocks split over a mesh axis.

The decode twin of ``retrieval.sharded``: the batch (codeword-block) row
dimension is split contiguously across a mesh axis via shard_map; the
parity-check matrices and column weights are replicated, and each device
runs the identical fixed-trip-count bit-flip loop on its rows.  Decoding
is per-word independent, so no collective is needed and the result is
bit-identical to the single-device path by construction — asserted in
tests rather than assumed.

Fully-manual shard_map (like sharding/pipeline.py — the partial-manual
form crashes the CPU XLA backend).
"""
from __future__ import annotations

import functools

from jax.sharding import Mesh, PartitionSpec as P

from ..sharding.compat import shard_map
from .ldpc import bitflip_decode_packed


def sharded_bitflip_decode(y_packed, h_packed, ht_packed, gamma, *, n: int,
                           n_chk: int, max_iters: int, backend: str,
                           mesh: Mesh, axis: str = "data"):
    """(c_packed [B, W], ok [B], iters [B]) — identical to the
    single-device ``bitflip_decode_packed`` on the full block.

    y_packed [B, W] is sharded over ``axis`` (B must divide by the axis
    size); h_packed/ht_packed/gamma are replicated.
    """
    d = mesh.shape[axis]
    b = y_packed.shape[0]
    assert b % d == 0, (b, d)

    local = functools.partial(bitflip_decode_packed, n=n, n_chk=n_chk,
                              max_iters=max_iters, backend=backend)
    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(axis), P(), P(), P()),
                   out_specs=(P(axis), P(axis), P(axis)))
    return fn(y_packed, h_packed, ht_packed, gamma)
