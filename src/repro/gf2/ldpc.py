"""LDPC encode + iterative bit-flipping decode on PPAC GF(2)/and-dot ops.

Forward error correction is the paper's second §III-D workload: syndrome
computation s = H·c is a GF(2) MVP, and the inner step of a Gallager
bit-flipping decoder — counting, per code bit, how many unsatisfied checks
it participates in — is an integer and-dot (mode III-B2) of the syndrome
against Hᵀ.  Both run as PPAC array operations here, with per-iteration
emulated-cycle accounting priced by the geometry rules of
``core.cost_model`` / ``gf2.ops.gf2_cycles``, plus the §IV-B
compute-cache baseline (``cycles_compute_cache_inner_product``) for the
same work.

Codes
-----
* :func:`make_random_ldpc` — random sparse H = [P | L] with L
  unit-lower-triangular (always invertible over GF(2)); systematic.
* :func:`make_array_ldpc` — the r×c array (product) code: one parity
  check per grid row and per grid column.  Every bit lies in exactly 2
  checks (γ=2) and any two bits share at most one check (λ=1), so
  bit-flipping provably corrects t = ⌊γ/2λ⌋ = 1 error per word in one
  iteration; the decode matrix keeps the one redundant check on purpose
  (majority-logic decoding wants the full orthogonal check set), while
  encoding uses the full-rank triangular subset.

Encoding is systematic: c = [m, p] with L·p = P·m, solved once at code
construction by forward substitution on the unit-lower-triangular L
(host-side setup, like loading the latch array), after which every encode
is a single PPAC GF(2) MVP p = (L⁻¹P)·m.

Decoding flips every bit whose unsatisfied-check count passes a strict
per-bit majority, 2·votes > γ_j, and stops early (per word) as soon as
the syndrome clears: a cleared word has zero votes everywhere, so extra
iterations are natural no-ops and the fixed-trip-count jax loop stays
bit-identical to an early-exit host loop — and to the row-sharded
``shard_map`` path in ``gf2.sharded``.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..core.cost_model import est_latency_us
from ..core.formats import pack_bits, unpack_bits
from ..core.ppac import CycleCounter, PPACConfig, cycles_compute_cache_inner_product
from ..kernels.binary_mvp.ops import and_dot
from ..kernels.gf2_tiled.ops import gf2_matmul_tiled
from .ops import gf2_cycles, resolve_backend


def solve_unit_lower(l_mat, rhs) -> np.ndarray:
    """Solve L·X = B over GF(2) for unit-lower-triangular L by forward
    substitution.  l_mat [p, p], rhs [p, q] -> X [p, q]."""
    l_mat = np.asarray(l_mat, np.uint8)
    x = np.array(np.atleast_2d(np.asarray(rhs, np.uint8)) % 2)
    p = l_mat.shape[0]
    assert l_mat.shape == (p, p) and np.all(np.diag(l_mat) == 1)
    assert not np.any(np.triu(l_mat, 1)), "L must be lower-triangular"
    for i in range(p):
        # x[i] -= L[i, :i] @ x[:i]  (over GF(2))
        if i:
            x[i] ^= (l_mat[i, :i] @ x[:i]) % 2
    return x


@dataclasses.dataclass(frozen=True)
class LDPCCode:
    """A binary linear code with a systematic encoder and a decode matrix.

    ``h`` is the parity-check matrix used for decoding (it may carry
    redundant rows — majority-logic decoding wants every orthogonal
    check).  ``h_enc`` = [P | L] is a full-rank subset with L
    unit-lower-triangular over the last n-k columns, used for encoding.
    """

    h: np.ndarray        # [n_chk, n] uint8
    h_enc: np.ndarray    # [n - k, n] uint8
    k: int
    gen_parity: np.ndarray = dataclasses.field(init=False)  # [n-k, k]

    def __post_init__(self):
        n = self.h.shape[1]
        r = n - self.k
        assert self.h_enc.shape == (r, n), (self.h_enc.shape, r, n)
        p_part = self.h_enc[:, : self.k]
        l_part = self.h_enc[:, self.k:]
        gen = solve_unit_lower(l_part, p_part)     # L⁻¹ P, [r, k]
        object.__setattr__(self, "gen_parity", gen.astype(np.uint8))
        # every h_enc row must be in h's row space for decode to accept
        # encoded words; we require the stronger (and simpler) subset check
        hs = {r_.tobytes() for r_ in np.asarray(self.h, np.uint8)}
        assert all(r_.tobytes() in hs for r_ in self.h_enc), \
            "h_enc rows must appear among the decode checks h"

    @property
    def n(self) -> int:
        return self.h.shape[1]

    @property
    def n_chk(self) -> int:
        return self.h.shape[0]

    @property
    def rate(self) -> float:
        return self.k / self.n

    @property
    def col_weight(self) -> np.ndarray:
        """γ_j: number of decode checks each bit participates in."""
        return np.asarray(self.h, np.int64).sum(axis=0)

    @property
    def max_overlap(self) -> int:
        """λ: max number of checks shared by any two distinct bits."""
        ov = np.asarray(self.h, np.int64).T @ np.asarray(self.h, np.int64)
        np.fill_diagonal(ov, 0)
        return int(ov.max())

    @property
    def guaranteed_t(self) -> int:
        """Errors per word the majority bit-flip rule provably corrects
        (in one iteration): ⌊γ_min / 2λ⌋ — see the decode analysis in the
        module docstring."""
        lam = max(1, self.max_overlap)
        return int(self.col_weight.min()) // (2 * lam)

    def encode(self, msgs, *, backend: str = "auto",
               counter: Optional[CycleCounter] = None,
               config: Optional[PPACConfig] = None) -> np.ndarray:
        """Systematic encode [B, k] -> [B, n]: c = [m, (L⁻¹P)·m]."""
        msgs = np.atleast_2d(np.asarray(msgs, np.uint8))
        assert msgs.shape[1] == self.k, (msgs.shape, self.k)
        parity = gf2_matmul_tiled(pack_bits(msgs), pack_bits(self.gen_parity),
                                  n=self.k, backend=resolve_backend(backend))
        if counter is not None:
            counter.tick(gf2_cycles(msgs.shape[0], self.n - self.k, self.k,
                                    config) + counter.pipeline_latency)
        return np.concatenate([msgs, np.asarray(parity, np.uint8)], axis=1)

    def syndrome(self, words, *, backend: str = "auto") -> np.ndarray:
        """s = H·c over GF(2): [B, n] -> [B, n_chk]."""
        words = np.atleast_2d(np.asarray(words, np.uint8))
        return np.asarray(gf2_matmul_tiled(
            pack_bits(words), pack_bits(self.h), n=self.n,
            backend=resolve_backend(backend)))


def make_random_ldpc(n: int, k: int, *, rng, col_weight: int = 3,
                     lower_density: float = 0.1) -> LDPCCode:
    """Random sparse systematic code: H = [P | L], P with fixed column
    weight, L = I ⊕ sparse strict-lower.  Decode matrix = encode matrix."""
    r = n - k
    assert 0 < k < n and col_weight <= r
    p = np.zeros((r, k), np.uint8)
    for j in range(k):
        p[rng.choice(r, size=col_weight, replace=False), j] = 1
    l_mat = (np.tril((rng.random((r, r)) < lower_density), -1)
             | np.eye(r, dtype=bool)).astype(np.uint8)
    h = np.concatenate([p, l_mat], axis=1)
    return LDPCCode(h=h, h_enc=h, k=k)


def make_array_ldpc(r: int, c: int) -> LDPCCode:
    """The r×c array code: bits on a grid, checks = row + column parities.

    Bit order: interior (message, row-major, (r-1)(c-1) bits), then the
    last-column parities (r-1), last-row parities (c-1), and the corner.
    Decode matrix: all r+c grid checks (γ=2, λ=1 ⇒ guaranteed_t = 1);
    encode matrix: the r+c-1 independent checks, which in this bit order
    are exactly [P | L] with L unit-lower-triangular.
    """
    assert r >= 2 and c >= 2
    n = r * c
    k = (r - 1) * (c - 1)

    def bit(i: int, j: int) -> int:
        """Grid position -> systematic bit index."""
        if i < r - 1 and j < c - 1:
            return i * (c - 1) + j                       # interior
        if j == c - 1 and i < r - 1:
            return k + i                                 # last col
        if i == r - 1 and j < c - 1:
            return k + (r - 1) + j                       # last row
        return n - 1                                     # corner

    h = np.zeros((r + c, n), np.uint8)
    for i in range(r):
        for j in range(c):
            h[i, bit(i, j)] = 1          # row checks
            h[r + j, bit(i, j)] = 1      # column checks
    # independent subset in triangular order: rows 0..r-2, cols 0..c-2,
    # then the last row check (corner on the diagonal)
    h_enc = np.concatenate(
        [h[: r - 1], h[r: r + c - 1], h[r - 1: r]], axis=0)
    return LDPCCode(h=h, h_enc=h_enc, k=k)


# ---------------------------------------------------------------------------
# Bit-flipping decoder
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DecodeResult:
    """Decoded words plus the emulated hardware cost of producing them."""

    codewords: np.ndarray   # [B, n] uint8 (best-effort when not ok)
    ok: np.ndarray          # [B] bool: syndrome cleared
    iters: np.ndarray       # [B] int32: flip iterations until clean
    k: int
    stats: Dict[str, float]

    @property
    def msgs(self) -> np.ndarray:
        """Systematic message bits of the decoded words."""
        return self.codewords[:, : self.k]


@functools.partial(jax.jit,
                   static_argnames=("n", "n_chk", "max_iters", "backend"))
def bitflip_decode_packed(y_packed, h_packed, ht_packed, gamma, *, n: int,
                          n_chk: int, max_iters: int, backend: str):
    """Fixed-trip-count bit-flip decode on packed words [B, W].

    Per iteration: syndrome s = H·c (GF(2) MVP), votes v = Hᵀ·s (integer
    and-dot), flip all bits with 2·v_j > γ_j.  Words whose syndrome is
    already clear have zero votes and never flip — iterating past
    convergence is the identity, which is what makes this loop
    shard-order- and batch-composition-invariant.
    Returns (c_packed, ok [B] bool, iters [B] int32).
    """
    b = y_packed.shape[0]
    gamma = jnp.asarray(gamma, jnp.int32)

    def syndrome(c):
        return gf2_matmul_tiled(c, h_packed, n=n, backend=backend)

    def step(t, carry):
        c, iters = carry
        syn = syndrome(c)                                        # [B, n_chk]
        clean = jnp.sum(syn.astype(jnp.int32), axis=1) == 0
        iters = jnp.where(clean, jnp.minimum(iters, t), iters)
        votes = and_dot(pack_bits(syn), ht_packed, n=n_chk,
                        backend=backend)                         # [B, n]
        flip = (2 * votes > gamma[None, :]).astype(jnp.uint8)
        return c ^ pack_bits(flip), iters

    init = (jnp.asarray(y_packed, jnp.uint32),
            jnp.full((b,), max_iters, jnp.int32))
    c, iters = lax.fori_loop(0, max_iters, step, init)
    ok = jnp.sum(syndrome(c).astype(jnp.int32), axis=1) == 0
    iters = jnp.where(ok, jnp.minimum(iters, max_iters), max_iters)
    return c, ok, iters


class BitFlipDecoder:
    """Batched LDPC bit-flip decoder with emulated PPAC cycle accounting."""

    def __init__(self, code: LDPCCode, *,
                 config: Optional[PPACConfig] = None,
                 backend: str = "auto", max_iters: int = 20,
                 parallel_arrays: Optional[int] = None):
        self.code = code
        self.config = config or PPACConfig()
        self.backend = resolve_backend(backend)
        self.max_iters = max_iters
        self.parallel_arrays = parallel_arrays
        self.counter = CycleCounter()
        self._h_packed = jnp.asarray(pack_bits(code.h))
        self._ht_packed = jnp.asarray(pack_bits(code.h.T))
        self._gamma = jnp.asarray(code.col_weight, jnp.int32)

    # -- cycle model ---------------------------------------------------------

    def cycles_per_word_iteration(self) -> int:
        """One decode iteration of one word: syndrome MVP (H, XOR-tree
        merge) + vote and-dot (Hᵀ, adder-tree merge).  The flip decision is
        the row ALU's threshold comparison and is free, like the CAM sign
        bit."""
        code, cfg, pa = self.code, self.config, self.parallel_arrays
        syn = gf2_cycles(1, code.n_chk, code.n, cfg, pa)
        votes = gf2_cycles(1, code.n, code.n_chk, cfg, pa)
        return syn + votes

    def compute_cache_cycles_per_word_iteration(self) -> int:
        """The same iteration under the §IV-B compute-cache model [3,4]:
        one N-dim 1-bit inner product per matrix, rows in parallel."""
        code = self.code
        return (cycles_compute_cache_inner_product(1, code.n)
                + cycles_compute_cache_inner_product(1, code.n_chk))

    def _stats(self, b: int, iters_exec: int, shards: int) -> Dict[str, float]:
        cpwi = self.cycles_per_word_iteration()
        total = b * iters_exec * cpwi + self.counter.pipeline_latency
        self.counter.tick(total)
        cc = b * iters_exec * self.compute_cache_cycles_per_word_iteration()
        stats = dict(words=b, iterations=iters_exec,
                     cycles_per_word_iteration=cpwi, total_cycles=total,
                     compute_cache_cycles=cc,
                     speedup_vs_compute_cache=cc / total if total else 0.0,
                     shards=shards, backend=self.backend)
        lat = est_latency_us(total, self.config, shards)
        if lat is not None:
            stats["est_latency_us"] = lat
        return stats

    # -- decode --------------------------------------------------------------

    def decode(self, words=None, *, words_packed=None, mesh=None,
               shard_axis: str = "data") -> DecodeResult:
        """Decode noisy words [B, n] {0,1} (or packed [B, W] uint32).

        With a ``mesh``, the block of codewords row-shards over
        ``shard_axis`` (each device decodes its rows; H replicated) —
        bit-identical to the single-device path.
        """
        code = self.code
        if words_packed is not None:
            y = jnp.asarray(words_packed, jnp.uint32)
        else:
            wb = np.atleast_2d(np.asarray(words, np.uint8))
            assert wb.shape[1] == code.n, (wb.shape, code.n)
            y = jnp.asarray(pack_bits(wb))
        b = y.shape[0]

        if mesh is None:
            shards = 1
            c, ok, iters = bitflip_decode_packed(
                y, self._h_packed, self._ht_packed, self._gamma,
                n=code.n, n_chk=code.n_chk, max_iters=self.max_iters,
                backend=self.backend)
        else:
            from .sharded import sharded_bitflip_decode

            shards = int(mesh.shape[shard_axis])
            pad = (-b) % shards
            if pad:  # repeat the tail word to a shardable multiple
                y = jnp.concatenate([y, jnp.repeat(y[-1:], pad, axis=0)])
            c, ok, iters = sharded_bitflip_decode(
                y, self._h_packed, self._ht_packed, self._gamma,
                n=code.n, n_chk=code.n_chk, max_iters=self.max_iters,
                backend=self.backend, mesh=mesh, axis=shard_axis)
            c, ok, iters = c[:b], ok[:b], iters[:b]

        ok = np.asarray(ok)
        iters = np.asarray(iters, np.int32)
        iters_exec = int(iters.max()) if b else 0
        stats = self._stats(b, iters_exec, shards)
        return DecodeResult(
            codewords=np.asarray(unpack_bits(c, code.n), np.uint8),
            ok=ok, iters=iters, k=code.k, stats=stats)


def bsc_flip(codewords, n_errors: int, rng) -> np.ndarray:
    """Flip exactly ``n_errors`` distinct random bits per word (a worst-case
    binary symmetric channel draw)."""
    out = np.array(np.atleast_2d(np.asarray(codewords, np.uint8)))
    for row in out:
        if n_errors:
            row[rng.choice(out.shape[1], size=n_errors, replace=False)] ^= 1
    return out
