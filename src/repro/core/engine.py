"""PPAC engine: the paper's technique as a first-class projection substrate.

A ``PPACLinear`` projection can run in three regimes:

  * ``float``  — plain bf16 matmul (baseline path).
  * ``qat``    — training-time fake quantization into the PPAC number
                 formats (Table I) with straight-through gradients; the
                 network learns weights executable on the PPAC engine.
  * ``serve``  — weights are *stored* quantized (the PPAC premise: the
                 matrix A is resident in low precision while vectors
                 stream, §IV-A) and the matmul is exact integer arithmetic.

Serving weight containers (memory-roofline lever, see EXPERIMENTS.md §Perf):

  bf16     : [in, out] bf16                       (baseline)
  int8     : [in, out] int8 + scale               (K<=8; MXU dot)
  packed4  : [K, out, in/32] uint32 bitplanes     (K<=4; fused bit-serial
             kernel — the resident layout IS the kernel operand)
  packed1  : [out, in/32] uint32 bitplanes        (K=1; XNOR-popcount kernel)

The packed kinds execute through the unified kernel engine
(``repro.kernels.engine.ppac_matmul``): packed1 via the 1-bit ±1 MVP mode,
packed4 via the fused multi-bit plane-pair kernel against the pre-packed
resident planes — no unpack-to-int8 ``dot_general`` fallback. All integer
paths are bit-true (int32 accumulation) — the property the paper holds
over mixed-signal PIM (§III-D) — and bit-identical across the
'pallas'/'ref'/'mxu' backends.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels.engine import ppac_matmul
from .formats import pack_bits, to_bitplanes
from .quant import binarize_pm1, fake_quant, quantize


@jax.tree_util.register_pytree_node_class
class QuantContainer:
    """Resident quantized weight: arrays are pytree children; ``kind`` plus
    the quantization metadata (``bits``, ``fmt``, logical ``n_in``) are
    static aux data, so jit specializes on the container format."""

    def __init__(self, kind: str, wq, scale, *, bits: Optional[int] = None,
                 fmt: Optional[str] = None, n_in: Optional[int] = None):
        self.kind = kind
        self.wq = wq
        self.scale = scale
        self.bits = bits
        self.fmt = fmt
        self.n_in = n_in

    def tree_flatten(self):
        return (self.wq, self.scale), (self.kind, self.bits, self.fmt,
                                       self.n_in)

    @classmethod
    def tree_unflatten(cls, aux, children):
        kind, bits, fmt, n_in = aux
        return cls(kind, *children, bits=bits, fmt=fmt, n_in=n_in)

    def with_children(self, wq, scale) -> "QuantContainer":
        """Same kind/metadata, different payloads (sharding specs etc.)."""
        return QuantContainer(self.kind, wq, scale, bits=self.bits,
                              fmt=self.fmt, n_in=self.n_in)

    def __repr__(self):
        return (f"QuantContainer({self.kind}, bits={self.bits}, "
                f"wq={getattr(self.wq, 'shape', None)})")


def qat_dense(x, w, *, weight_bits: int, act_bits: int,
              weight_format: str = "int", act_format: str = "int"):
    """Fake-quantized matmul with STE gradients (training path)."""
    if weight_bits == 1:
        wq, ws = binarize_pm1(w.astype(jnp.float32), axis=0)
        wq = wq * ws
    else:
        wq = fake_quant(w.astype(jnp.float32), weight_bits, weight_format, axis=0)
    xq = fake_quant(x.astype(jnp.float32), act_bits, act_format, axis=-1)
    return jnp.einsum("...i,io->...o", xq, wq).astype(x.dtype)


def pack_weight_for_serving(w, *, weight_bits: int,
                            weight_format: str = "int") -> QuantContainer:
    """Offline conversion of a float [in, out] weight to a resident
    quantized container (run once at model load, like writing the PPAC
    latch array).

    1-bit weights become one packed XNOR plane; 2..4-bit weights become K
    packed logical bitplanes [K, out, in/32] — the exact operand layout of
    the fused bit-serial kernel, so serving streams activations against
    the resident planes with no per-call weight reshaping. 5..8 bits fall
    back to int8 rows (MXU dot); wider requests keep bf16.
    """
    n_in = w.shape[0]
    w = w.astype(jnp.float32)
    if weight_bits == 1:
        q, s = binarize_pm1(w, axis=0)              # q in {±1}, s [1, out]
        bits = ((q + 1) / 2).astype(jnp.uint8)      # logical levels
        packed = pack_bits(bits.T)                  # [out, in/32] u32
        return QuantContainer("packed1", packed, s[0], bits=1, fmt="pm1",
                              n_in=n_in)
    if weight_bits > 8:
        return QuantContainer("bf16", w.astype(jnp.bfloat16),
                              jnp.ones((w.shape[1],), jnp.float32),
                              bits=16, fmt="float", n_in=n_in)
    q, s = quantize(w, weight_bits, weight_format, axis=0)  # s [1, out]
    if weight_bits <= 4:
        a_int = q.T.astype(jnp.int32)               # [out, in] exact ints
        planes = to_bitplanes(a_int, weight_bits, weight_format)
        packed = pack_bits(planes)                  # [K, out, in/32] u32
        return QuantContainer("packed4", packed, s[0], bits=weight_bits,
                              fmt=weight_format, n_in=n_in)
    return QuantContainer("int8", q.astype(jnp.int8), s[0], bits=weight_bits,
                          fmt=weight_format, n_in=n_in)


def serve_dense_acc(xf, container: QuantContainer, *, act_bits: int,
                    act_format: str = "int", backend: str = "mxu"):
    """Exact integer accumulations for a packed/int container.

    xf: [B, in] float32 activations. Returns (acc [B, out] int32,
    act_scale [B, 1] float32) — the raw PPAC row-ALU results before
    dequantization, bit-identical across backends for the packed kinds.
    """
    kind = container.kind
    if kind == "packed1":
        xq, xs = binarize_pm1(xf, axis=-1)          # {±1} activations
        xbits = ((xq + 1) / 2).astype(jnp.uint8)
        xp = pack_bits(xbits)
        acc = ppac_matmul(xp, container.wq, mode="mvp_1bit",
                          n=xf.shape[-1], backend=backend)  # [B, out] int32
        return acc, xs
    xq, xs = quantize(xf, act_bits, act_format, axis=-1)
    if kind == "packed4":
        acc = ppac_matmul(xq.astype(jnp.int32), container.wq,
                          mode="mvp_multibit_planes", n=xf.shape[-1],
                          k_bits=container.bits, l_bits=act_bits,
                          fmt_a=container.fmt, fmt_x=act_format,
                          backend=backend)
        return acc, xs
    if kind == "int8":
        acc = jax.lax.dot_general(
            xq.astype(jnp.int8), container.wq, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        return acc, xs
    raise ValueError(f"no integer path for container kind {kind!r}")


def serve_dense(x, container: QuantContainer, *, act_bits: int,
                act_format: str = "int", backend: str = "mxu"):
    """Exact-integer projection against a resident quantized weight."""
    scale = container.scale
    lead = x.shape[:-1]
    xf = x.reshape((-1, x.shape[-1])).astype(jnp.float32)

    if container.kind == "bf16":
        y = (xf.astype(jnp.bfloat16) @ container.wq).astype(jnp.float32)
        y = y * scale[None, :]
    else:
        acc, xs = serve_dense_acc(xf, container, act_bits=act_bits,
                                  act_format=act_format, backend=backend)
        y = acc.astype(jnp.float32) * xs * scale[None, :]
    return y.reshape(lead + (y.shape[-1],)).astype(x.dtype)
