"""PPAC engine: the paper's technique as a first-class projection substrate.

A ``PPACLinear`` projection can run in three regimes:

  * ``float``  — plain bf16 matmul (baseline path).
  * ``qat``    — training-time fake quantization into the PPAC number
                 formats (Table I) with straight-through gradients; the
                 network learns weights executable on the PPAC engine.
  * ``serve``  — weights are *stored* quantized (the PPAC premise: the
                 matrix A is resident in low precision while vectors
                 stream, §IV-A) and the matmul is exact integer arithmetic.

Serving weight containers (memory-roofline lever, see EXPERIMENTS.md §Perf):

  bf16     : [in, out] bf16                       (baseline)
  int8     : [in, out] int8 + scale               (K<=8; MXU dot)
  packed4  : [K1, out, in/32] uint32 bitplanes    (K<=4; fused bit-serial
             kernel — the resident layout IS the kernel operand; offset
             formats store their all-ones mask plane as the K+1-th plane)
  packed1  : [out, in/32] uint32 bitplanes        (K=1; ±1 plane)

The zero-repack invariant: everything a lowering consumes is materialized
ONCE at load time ("writing the latch array") and a serving call only
streams activations. The packed kinds execute through the unified kernel
engine's ``mvp_multibit_resident`` mode — activations are bit-sliced
*inside* the Pallas body; nothing is ever concatenated onto or broadcast
over the resident planes at call time. Off-TPU, the MXU lowering consumes
an int8 *shadow* of the same integers, also built at load time (the
per-lowering analogue of loading the array), so no backend unpacks the
resident weight per call. All integer paths are bit-true (int32
accumulation) — the property the paper holds over mixed-signal PIM
(§III-D) — and bit-identical across the 'pallas'/'ref'/'mxu' backends.

Grouped containers (``splits``) stack several projections that share an
input (wq/wk/wv, wi/wg) column-wise into ONE resident container; per-
output-channel quantization makes the stacked container bit-identical to
the per-projection ones, while a decode step launches one fat kernel per
group instead of one per projection (``serve_dense_grouped``).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels.engine import ppac_matmul
from ..obs import ledger as _flight
from .formats import fmt as _fmt
from .formats import pack_bits, to_bitplanes
from .quant import binarize_levels, binarize_pm1, fake_quant, quantize


@jax.tree_util.register_pytree_node_class
class QuantContainer:
    """Resident quantized weight: arrays are pytree children; ``kind`` plus
    the quantization metadata (``bits``, ``fmt``, logical ``n_in``, the
    grouped-projection ``splits``) are static aux data, so jit specializes
    on the container format. ``shadow`` is the optional load-time int8
    resident for the MXU lowering (None on TPU, where the packed planes
    are the native operand).

    A container may additionally carry a resident *draft rung*: a packed1
    view of the same logical weight (``dwq``/``dscale``/``dshadow``),
    built once at load time alongside the target rung. The draft rung is
    what self-speculative decoding drafts with — same weights, 1-bit
    bit-serial cost — and :meth:`draft_view` exposes it as an ordinary
    packed1 container so every serving path prices and executes it
    exactly like a standalone 1-bit conversion."""

    def __init__(self, kind: str, wq, scale, *, bits: Optional[int] = None,
                 fmt: Optional[str] = None, n_in: Optional[int] = None,
                 shadow=None, splits: Optional[Tuple[int, ...]] = None,
                 dwq=None, dscale=None, dshadow=None):
        self.kind = kind
        self.wq = wq
        self.scale = scale
        self.bits = bits
        self.fmt = fmt
        self.n_in = n_in
        self.shadow = shadow
        self.splits = tuple(splits) if splits else None
        self.dwq = dwq
        self.dscale = dscale
        self.dshadow = dshadow

    def tree_flatten(self):
        return ((self.wq, self.scale, self.shadow, self.dwq, self.dscale,
                 self.dshadow),
                (self.kind, self.bits, self.fmt, self.n_in, self.splits))

    @classmethod
    def tree_unflatten(cls, aux, children):
        kind, bits, fmt, n_in, splits = aux
        wq, scale, shadow, dwq, dscale, dshadow = children
        return cls(kind, wq, scale, bits=bits, fmt=fmt, n_in=n_in,
                   shadow=shadow, splits=splits, dwq=dwq, dscale=dscale,
                   dshadow=dshadow)

    def with_children(self, wq, scale, shadow=None, dwq=None, dscale=None,
                      dshadow=None) -> "QuantContainer":
        """Same kind/metadata, different payloads (sharding specs etc.)."""
        return QuantContainer(self.kind, wq, scale, bits=self.bits,
                              fmt=self.fmt, n_in=self.n_in, shadow=shadow,
                              splits=self.splits, dwq=dwq, dscale=dscale,
                              dshadow=dshadow)

    @property
    def has_draft(self) -> bool:
        return self.dwq is not None

    def draft_view(self) -> "QuantContainer":
        """The resident packed1 rung as a standalone container.

        Falls back to the container itself when no draft rung was packed
        (packed1 already IS the cheapest rung; a draft-less container
        drafts with the target, making the drafter exact).
        """
        if self.dwq is None:
            return self
        return QuantContainer("packed1", self.dwq, self.dscale, bits=1,
                              fmt="pm1", n_in=self.n_in, shadow=self.dshadow,
                              splits=self.splits)

    def __repr__(self):
        return (f"QuantContainer({self.kind}, bits={self.bits}, "
                f"wq={getattr(self.wq, 'shape', None)}"
                + (f", splits={self.splits}" if self.splits else "")
                + (", shadow" if self.shadow is not None else "")
                + (", draft" if self.dwq is not None else "") + ")")


def qat_dense(x, w, *, weight_bits: int, act_bits: int,
              weight_format: str = "int", act_format: str = "int"):
    """Fake-quantized matmul with STE gradients (training path)."""
    if weight_bits == 1:
        wq, ws = binarize_pm1(w.astype(jnp.float32), axis=0)
        wq = wq * ws
    else:
        wq = fake_quant(w.astype(jnp.float32), weight_bits, weight_format, axis=0)
    xq = fake_quant(x.astype(jnp.float32), act_bits, act_format, axis=-1)
    return jnp.einsum("...i,io->...o", xq, wq).astype(x.dtype)


def _want_shadow(store_shadow: Optional[bool]) -> bool:
    """Shadow policy: explicit wins; default stores the int8 resident only
    off-TPU (on TPU the packed planes are what the kernels eat)."""
    if store_shadow is not None:
        return store_shadow
    return jax.default_backend() != "tpu"


def _format_has_offset(weight_format: str) -> bool:
    from ..kernels.bitserial_mvp.ops import format_needs_mask
    return format_needs_mask(_fmt(weight_format))


def _pack_pm1(w, store_shadow: Optional[bool]):
    """One ±1 bitplane of a float [in, out] weight: (packed [out, in/32]
    u32, scale [out], optional int8 shadow [in, out])."""
    levels, q, s = binarize_levels(w, axis=0)
    packed = pack_bits(levels.T)
    shadow = q.astype(jnp.int8) if _want_shadow(store_shadow) else None
    return packed, s[0], shadow


def pack_weight_for_serving(w, *, weight_bits: int,
                            weight_format: str = "int",
                            splits: Optional[Sequence[int]] = None,
                            store_shadow: Optional[bool] = None,
                            draft: bool = False) -> QuantContainer:
    """Offline conversion of a float [in, out] weight to a resident
    quantized container (run once at model load, like writing the PPAC
    latch array).

    1-bit weights become one packed ±1 plane; 2..4-bit weights become K
    packed logical bitplanes [K, out, in/32] — the exact operand layout of
    the fused bit-serial kernel — plus a constant all-ones mask plane when
    the format carries an affine offset (oddint), so the serving kernels
    never synthesize one at call time. Off-TPU an int8 shadow of the same
    integers is stored for the MXU lowering (zero per-call unpacking on
    every backend). 5..8 bits fall back to int8 rows (MXU dot); wider
    requests keep bf16. ``splits`` records grouped-projection output
    widths (see ``serve_dense_grouped``).

    ``draft=True`` additionally packs the 1-bit rung of the SAME weight
    into the container's draft slots (``dwq``/``dscale``/``dshadow``) —
    bit-identical to a standalone ``weight_bits=1`` conversion — so
    self-speculative decoding drafts from the resident container with no
    re-conversion and no second model.
    """
    n_in = w.shape[0]
    splits = tuple(splits) if splits else None
    w = w.astype(jnp.float32)
    draft_kw = {}
    if draft and weight_bits > 1:
        dwq, dscale, dshadow = _pack_pm1(w, store_shadow)
        draft_kw = dict(dwq=dwq, dscale=dscale, dshadow=dshadow)
    if weight_bits == 1:
        packed, s0, shadow = _pack_pm1(w, store_shadow)  # [out, in/32] u32
        return QuantContainer("packed1", packed, s0, bits=1, fmt="pm1",
                              n_in=n_in, shadow=shadow, splits=splits)
    if weight_bits > 8:
        return QuantContainer("bf16", w.astype(jnp.bfloat16),
                              jnp.ones((w.shape[1],), jnp.float32),
                              bits=16, fmt="float", n_in=n_in, splits=splits,
                              **draft_kw)
    q, s = quantize(w, weight_bits, weight_format, axis=0)  # s [1, out]
    if weight_bits <= 4:
        a_int = q.T.astype(jnp.int32)               # [out, in] exact ints
        planes = to_bitplanes(a_int, weight_bits, weight_format)
        if _format_has_offset(weight_format):
            # resident all-ones mask plane: the affine-offset cross terms
            # (eqs. (2)/(3) generalized) ride an ordinary K+1-th plane
            # instead of a per-call concatenation
            mask = jnp.ones((1,) + a_int.shape, jnp.uint8)
            planes = jnp.concatenate([planes, mask], axis=0)
        packed = pack_bits(planes)                  # [K1, out, in/32] u32
        shadow = q.astype(jnp.int8) if _want_shadow(store_shadow) else None
        return QuantContainer("packed4", packed, s[0], bits=weight_bits,
                              fmt=weight_format, n_in=n_in, shadow=shadow,
                              splits=splits, **draft_kw)
    return QuantContainer("int8", q.astype(jnp.int8), s[0], bits=weight_bits,
                          fmt=weight_format, n_in=n_in, splits=splits,
                          **draft_kw)


def serve_dense_acc(xf, container: QuantContainer, *, act_bits: int,
                    act_format: str = "int", backend: str = "mxu"):
    """Exact integer accumulations for a packed/int container.

    xf: [B, in] float32 activations. Returns (acc [B, out] int32,
    act_scale [B, 1] float32) — the raw PPAC row-ALU results before
    dequantization, bit-identical across backends for the packed kinds.
    Packed kinds run the zero-repack resident mode: in-kernel activation
    bit-slicing on 'pallas', the load-time int8 shadow on 'mxu'.
    """
    kind = container.kind
    n = xf.shape[-1]
    if kind == "packed1":
        xq, xs = binarize_pm1(xf, axis=-1)          # {±1} activations
        # ±1 ≡ oddint(1): the packed1 plane serves through the same fused
        # resident kernel as packed4, with a 1x1 plane-pair schedule
        acc = ppac_matmul(xq.astype(jnp.int32), container.wq[None],
                          mode="mvp_multibit_resident", n=n, k_bits=1,
                          l_bits=1, fmt_a="oddint", fmt_x="oddint",
                          a_int8=container.shadow, backend=backend)
        return acc, xs
    xq, xs = quantize(xf, act_bits, act_format, axis=-1)
    if kind == "packed4":
        a_has_mask = container.wq.shape[-3] == (container.bits or 0) + 1
        acc = ppac_matmul(xq.astype(jnp.int32), container.wq,
                          mode="mvp_multibit_resident", n=n,
                          k_bits=container.bits, l_bits=act_bits,
                          fmt_a=container.fmt, fmt_x=act_format,
                          a_has_mask=a_has_mask, a_int8=container.shadow,
                          backend=backend)
        return acc, xs
    if kind == "int8":
        if _flight.active():
            # the int8 MXU fallback bypasses ppac_matmul; record it at its
            # would-be K-bit-serial PPAC cost so ledger totals stay in
            # lockstep with serving_cycle_report across every kind
            _flight.record_launch(
                "mvp_int8_mxu", backend, batch=int(xq.shape[0]),
                m_rows=int(container.wq.shape[-1]), n_bits=n,
                k_bits=container.bits or 8, l_bits=act_bits,
                x_shape=tuple(xq.shape), a_shape=tuple(container.wq.shape),
                traced=isinstance(xq, jax.core.Tracer))
        acc = jax.lax.dot_general(
            xq.astype(jnp.int8), container.wq, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        return acc, xs
    raise ValueError(f"no integer path for container kind {kind!r}")


def serve_dense(x, container: QuantContainer, *, act_bits: int,
                act_format: str = "int", backend: str = "mxu",
                rung: str = "target"):
    """Exact-integer projection against a resident quantized weight.

    ``rung="draft"`` serves the container's resident packed1 rung (the
    1-bit bit-serial cost class) instead of the target rung; containers
    without a packed draft rung fall back to the target rung, so a
    draft-routed forward is always well-defined.
    """
    if rung == "draft":
        container = container.draft_view()
    elif rung != "target":
        raise ValueError(f"unknown serving rung {rung!r}")
    scale = container.scale
    lead = x.shape[:-1]
    xf = x.reshape((-1, x.shape[-1])).astype(jnp.float32)

    if container.kind == "bf16":
        y = (xf.astype(jnp.bfloat16) @ container.wq).astype(jnp.float32)
        y = y * scale[None, :]
    else:
        acc, xs = serve_dense_acc(xf, container, act_bits=act_bits,
                                  act_format=act_format, backend=backend)
        y = acc.astype(jnp.float32) * xs * scale[None, :]
    return y.reshape(lead + (y.shape[-1],)).astype(x.dtype)


def serve_dense_grouped(x, container: QuantContainer, *, act_bits: int,
                        act_format: str = "int", backend: str = "mxu",
                        rung: str = "target"):
    """One fused projection for a grouped container, split back into the
    member projections' outputs.

    The container stacks several same-input projections column-wise
    (``splits`` records the member output widths): activations quantize
    ONCE and one fat kernel launch covers the whole group — halving decode
    launches for wq/wk/wv (+ wi/wg) — while per-output-channel scales keep
    each slice bit-identical to its standalone projection.
    """
    if not container.splits:
        raise ValueError("serve_dense_grouped needs a container with splits")
    y = serve_dense(x, container, act_bits=act_bits, act_format=act_format,
                    backend=backend, rung=rung)
    outs, off = [], 0
    for width in container.splits:
        outs.append(jax.lax.slice_in_dim(y, off, off + width, axis=-1))
        off += width
    return tuple(outs)


# ---------------------------------------------------------------------------
# Resident-container integrity: CRC tags, scrub, shadow repair
# ---------------------------------------------------------------------------
#
# The PPAC premise stores the matrix *in memory* — so the serving stack
# treats resident bitplane corruption as a first-class failure mode. Each
# container's target planes (``wq``) get a GF(2) CRC tag at load time
# (computed through the repo's own CRC-as-MVP ops — detection lives on
# the memory path, per the near-memory-crypto direction in PAPERS.md);
# a scrub pass recomputes and compares. Packed kinds with a load-time
# int8 ``shadow`` repair in place by re-packing the planes from the
# shadow (the same deterministic pipeline as ``pack_weight_for_serving``,
# so the repaired container is bit-identical to the original). The draft
# rung is deliberately untagged: a corrupted drafter only lowers the
# speculative accept rate — the target-rung verify keeps outputs exact.

def _is_container(x) -> bool:
    return isinstance(x, QuantContainer)


def _container_items(params):
    """[(path_str, container)] over every QuantContainer leaf, in the
    stable flatten order (the tag-dict key space)."""
    leaves = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=_is_container)[0]
    return [(jax.tree_util.keystr(kp), x) for kp, x in leaves
            if _is_container(x)]


def container_tag(c: QuantContainer) -> int:
    """GF(2) CRC tag over the container's resident target planes."""
    from ..gf2.ops import crc_tag as _crc_tag
    return _crc_tag(np.asarray(c.wq))


def container_tags(params) -> Dict[str, int]:
    """path -> CRC tag for every resident container (run once at load)."""
    return {path: container_tag(c) for path, c in _container_items(params)}


def repack_from_shadow(c: QuantContainer) -> QuantContainer:
    """Rebuild a packed container's target planes from its load-time int8
    shadow — the corruption-repair path. Returns a container bit-identical
    to the original packing; raises for kinds with no redundant resident
    (int8/bf16 store exactly one copy)."""
    if c.shadow is None or c.kind not in ("packed1", "packed4"):
        raise ValueError(f"container kind {c.kind!r} "
                         f"{'without a shadow ' if c.shadow is None else ''}"
                         f"has no redundant resident to repair from")
    shadow = jnp.asarray(c.shadow)

    def repack2d(sh):  # one layer: shadow [in, out] -> resident planes
        if c.kind == "packed1":
            return pack_bits(((sh + 1) // 2).astype(jnp.uint8).T)
        a_int = sh.T.astype(jnp.int32)
        planes = to_bitplanes(a_int, c.bits, c.fmt)
        if c.wq.shape[-3] == (c.bits or 0) + 1:  # resident mask plane
            mask = jnp.ones((1,) + a_int.shape, jnp.uint8)
            planes = jnp.concatenate([planes, mask], axis=0)
        return pack_bits(planes)

    # stacked (scan) containers carry a leading layer axis: repack each
    # layer exactly as the vmapped load-time packer did
    wq = (repack2d(shadow) if shadow.ndim == 2
          else jax.vmap(repack2d)(shadow))
    assert wq.shape == c.wq.shape and wq.dtype == c.wq.dtype, \
        (wq.shape, c.wq.shape)
    return c.with_children(wq, c.scale, shadow=c.shadow, dwq=c.dwq,
                           dscale=c.dscale, dshadow=c.dshadow)


def scrub_params(params, tags: Dict[str, int]):
    """One integrity pass over the resident containers.

    Recomputes every container's CRC tag against ``tags`` (from
    :func:`container_tags` at load). Mismatching containers with a shadow
    are repaired via :func:`repack_from_shadow`; shadow-less mismatches
    are reported irreparable (the caller fails loudly rather than serving
    wrong weights). Returns ``(params', report)`` where report maps path
    -> 'clean' | 'repaired' | 'corrupt'.
    """
    leaves, treedef = jax.tree_util.tree_flatten(params,
                                                 is_leaf=_is_container)
    paths = iter([p for p, _ in _container_items(params)])
    report: Dict[str, str] = {}
    out = []
    for leaf in leaves:
        if not _is_container(leaf):
            out.append(leaf)
            continue
        path = next(paths)
        if container_tag(leaf) == tags.get(path):
            report[path] = "clean"
            out.append(leaf)
        elif leaf.shadow is not None and leaf.kind in ("packed1", "packed4"):
            fixed = repack_from_shadow(leaf)
            assert container_tag(fixed) == tags.get(path), \
                f"shadow repair of {path} did not restore the tagged planes"
            report[path] = "repaired"
            out.append(fixed)
        else:
            report[path] = "corrupt"
            out.append(leaf)
    return jax.tree_util.tree_unflatten(treedef, out), report


def flip_container_bit(params, *, index: int = 0, bit: int = 0):
    """Fault injection: XOR one bit of the ``index``-th container's
    resident planes (host round-trip — chaos-test path only)."""
    leaves, treedef = jax.tree_util.tree_flatten(params,
                                                 is_leaf=_is_container)
    ks = [i for i, x in enumerate(leaves) if _is_container(x)]
    if not ks:
        raise ValueError("no QuantContainer leaves to corrupt")
    i = ks[index % len(ks)]
    c = leaves[i]
    wq = np.array(np.asarray(c.wq))
    flat = np.frombuffer(wq.tobytes(), np.uint8).copy()
    j = (bit // 8) % flat.size
    flat[j] ^= np.uint8(1 << (bit % 8))
    wq = np.frombuffer(flat.tobytes(), wq.dtype).reshape(wq.shape)
    leaves[i] = c.with_children(jnp.asarray(wq), c.scale, shadow=c.shadow,
                                dwq=c.dwq, dscale=c.dscale,
                                dshadow=c.dshadow)
    return jax.tree_util.tree_unflatten(treedef, leaves)
