"""PPAC engine: the paper's technique as a first-class projection substrate.

A ``PPACLinear`` projection can run in three regimes:

  * ``float``  — plain bf16 matmul (baseline path).
  * ``qat``    — training-time fake quantization into the PPAC number
                 formats (Table I) with straight-through gradients; the
                 network learns weights executable on the PPAC engine.
  * ``serve``  — weights are *stored* quantized (the PPAC premise: the
                 matrix A is resident in low precision while vectors
                 stream, §IV-A) and the matmul is exact integer arithmetic.

Serving weight containers (memory-roofline lever, see EXPERIMENTS.md §Perf):

  bf16     : [in, out] bf16                       (baseline)
  int8     : [in, out] int8 + scale               (K<=8)
  packed4  : [in, out/2] uint8, two nibbles       (K<=4; unpacked via shifts)
  packed1  : [out, in/32] uint32 bitplanes        (K=1; XNOR-popcount kernel)

All integer paths are bit-true (int32 accumulation) — the property the paper
holds over mixed-signal PIM (§III-D).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from ..kernels.binary_mvp.ops import inner_product_pm1
from .formats import pack_bits
from .quant import binarize_pm1, fake_quant, quantize


@jax.tree_util.register_pytree_node_class
class QuantContainer:
    """Resident quantized weight: arrays are pytree children, ``kind`` is
    static aux data (so jit specializes on the container format)."""

    def __init__(self, kind: str, wq, scale):
        self.kind = kind
        self.wq = wq
        self.scale = scale

    def tree_flatten(self):
        return (self.wq, self.scale), self.kind

    @classmethod
    def tree_unflatten(cls, kind, children):
        return cls(kind, *children)

    def __repr__(self):
        return f"QuantContainer({self.kind}, wq={getattr(self.wq, 'shape', None)})"


def qat_dense(x, w, *, weight_bits: int, act_bits: int,
              weight_format: str = "int", act_format: str = "int"):
    """Fake-quantized matmul with STE gradients (training path)."""
    if weight_bits == 1:
        wq, ws = binarize_pm1(w.astype(jnp.float32), axis=0)
        wq = wq * ws
    else:
        wq = fake_quant(w.astype(jnp.float32), weight_bits, weight_format, axis=0)
    xq = fake_quant(x.astype(jnp.float32), act_bits, act_format, axis=-1)
    return jnp.einsum("...i,io->...o", xq, wq).astype(x.dtype)


def pack_weight_for_serving(w, *, weight_bits: int,
                            weight_format: str = "int") -> QuantContainer:
    """Offline conversion of a float [in, out] weight to a resident
    quantized container (run once at model load, like writing the PPAC
    latch array)."""
    w = w.astype(jnp.float32)
    if weight_bits == 1:
        q, s = binarize_pm1(w, axis=0)              # q in {±1}, s [1, out]
        bits = ((q + 1) / 2).astype(jnp.uint8)      # logical levels
        packed = pack_bits(bits.T)                  # [out, in/32] u32
        return QuantContainer("packed1", packed, s[0])
    q, s = quantize(w, weight_bits, weight_format, axis=0)  # s [1, out]
    if weight_bits <= 4:
        qu = (q + 8).astype(jnp.uint8)              # int4 biased to [0,15]
        lo, hi = qu[0::2, :], qu[1::2, :]           # pack along `in` dim
        packed = (lo | (hi << 4)).astype(jnp.uint8)  # [in/2, out]
        return QuantContainer("packed4", packed, s[0])
    return QuantContainer("int8", q.astype(jnp.int8), s[0])


def serve_dense(x, container: QuantContainer, *, act_bits: int,
                act_format: str = "int", backend: str = "mxu"):
    """Exact-integer projection against a resident quantized weight."""
    kind = container.kind
    scale = container.scale
    lead = x.shape[:-1]
    xf = x.reshape((-1, x.shape[-1])).astype(jnp.float32)

    if kind == "packed1":
        xq, xs = binarize_pm1(xf, axis=-1)          # {±1} activations
        xbits = ((xq + 1) / 2).astype(jnp.uint8)
        xp = pack_bits(xbits)
        ip = inner_product_pm1(xp, container.wq, n=xf.shape[-1],
                               backend=backend)      # [B, out] int32
        y = ip.astype(jnp.float32) * xs * scale[None, :]
        return y.reshape(lead + (y.shape[-1],)).astype(x.dtype)

    xq, xs = quantize(xf, act_bits, act_format, axis=-1)
    xi = xq.astype(jnp.int8)
    if kind == "packed4":
        packed = container.wq
        lo = (packed & 0xF).astype(jnp.int8) - 8     # [in/2, out]
        hi = (packed >> 4).astype(jnp.int8) - 8
        wq = jnp.stack([lo, hi], axis=1).reshape(-1, packed.shape[-1])
    else:
        wq = container.wq
    acc = jax.lax.dot_general(xi, wq, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.int32)
    y = acc.astype(jnp.float32) * xs * scale[None, :]
    return y.reshape(lead + (y.shape[-1],)).astype(x.dtype)
