"""Analytical cost model reproducing the paper's implementation results.

The paper reports post-layout 28nm numbers (Tables II–IV). Silicon cannot be
measured here, so the *model* is: throughput derives exactly from geometry ×
clock (analytical, bit-identical to the paper's accounting), while power is
taken from the paper's measured table entries (with interpolation helpers for
other geometries). Benchmarks assert the derived numbers match the paper.

Accounting rules (paper §IV-A):
  * an M×N array performs M inner products of two N-dim 1-bit vectors/cycle;
  * 1-bit products and 1-bit additions each count as one OP
    -> M * (2N - 1) OP per clock cycle (N multiplies + N-1 adds per row);
  * the comparison Table IV counts 2N OP per row inner-product (external
    designs' convention) — both helpers are provided.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

from .ppac import PPACConfig, cycles_compute_cache_inner_product, cycles_multibit_mvp

# ---- Table II: post-layout results for the four implemented arrays --------
# keyed by (M, N): clock [GHz], power [mW], area [um^2], cell area [kGE]
TABLE_II: Dict[tuple, dict] = {
    (16, 16): dict(banks=1, subrows=1, area_um2=14161, density=75.77,
                   kge=17, f_ghz=1.116, power_mw=6.64,
                   peak_tops=0.55, fj_per_op=12.00),
    (16, 256): dict(banks=1, subrows=16, area_um2=72590, density=70.45,
                    kge=81, f_ghz=0.979, power_mw=45.60,
                    peak_tops=8.01, fj_per_op=5.69),
    (256, 16): dict(banks=16, subrows=1, area_um2=185283, density=72.52,
                    kge=213, f_ghz=0.824, power_mw=78.65,
                    peak_tops=6.54, fj_per_op=12.03),
    (256, 256): dict(banks=16, subrows=16, area_um2=783240, density=72.13,
                     kge=897, f_ghz=0.703, power_mw=381.43,
                     peak_tops=91.99, fj_per_op=4.15),
}

# ---- Table III: per-mode results on the 256x256 array ----------------------
# throughput [GMVP/s], power [mW], energy [pJ/MVP]
TABLE_III: Dict[str, dict] = {
    "hamming": dict(gmvps=0.703, power_mw=478, pj_per_mvp=680),
    "mvp_1bit_pm1": dict(gmvps=0.703, power_mw=498, pj_per_mvp=709),
    "mvp_4bit_01": dict(gmvps=0.044, power_mw=226, pj_per_mvp=5137),
    "gf2": dict(gmvps=0.703, power_mw=353, pj_per_mvp=502),
    "pla": dict(gmvps=0.703, power_mw=352, pj_per_mvp=501),
}

# ---- TPU v5e-class target constants (roofline, §Roofline) ------------------
TPU_PEAK_BF16_FLOPS = 197e12       # per chip
TPU_HBM_BW = 819e9                 # bytes/s per chip
TPU_ICI_BW = 50e9                  # bytes/s per link (one direction)


def tiled_scan_merge_cycles(m_rows: int, n_bits: int,
                            config: Optional[PPACConfig] = None,
                            parallel_arrays: Optional[int] = None) -> int:
    """Cycles for one MVP-like op against an [m_rows, n_bits] operand
    virtualized onto tiles of the configured array geometry.

    Every (row, col) tile runs one array cycle; with ``parallel_arrays``
    physical arrays the tiles time-multiplex (ceil(tiles / arrays)); the
    col-split partials then merge through a tree — an adder tree for the
    integer modes, an XOR tree for GF(2) — of depth ceil(log2(col_tiles)).
    Shared by CAMIndex scans and the gf2 subsystem.
    """
    cfg = config or PPACConfig()
    rt = max(1, -(-m_rows // cfg.m))
    ct = max(1, -(-n_bits // cfg.n))
    arrays = parallel_arrays or (rt * ct)
    scan = -(-(rt * ct) // arrays)
    merge = int(math.ceil(math.log2(ct))) if ct > 1 else 0
    return scan + merge


def tile_grid_ops(m_rows: int, n_bits: int,
                  config: Optional[PPACConfig] = None) -> int:
    """Array-cycles of *work* for one 1-bit pass over an [m_rows, n_bits]
    operand virtualized onto the configured geometry: one cycle per
    (row, col) tile, independent of how many physical arrays run them in
    parallel. Latency (`tiled_scan_merge_cycles`) divides by parallelism;
    energy integrates work, so it uses this count."""
    cfg = config or PPACConfig()
    return max(1, -(-m_rows // cfg.m)) * max(1, -(-n_bits // cfg.n))


# Engine mode -> Table III measurement row (mode-resolved power exists only
# at the paper's 256x256 implementation point).
_MODE_POWER_KEY: Dict[str, str] = {
    "hamming": "hamming",
    "cam": "hamming",
    "topk": "hamming",
    "mvp_1bit": "mvp_1bit_pm1",
    "mvp_multibit": "mvp_4bit_01",
    "mvp_multibit_planes": "mvp_4bit_01",
    "mvp_multibit_resident": "mvp_4bit_01",
    "mvp_int8_mxu": "mvp_4bit_01",
    "gf2": "gf2",
    "pla": "pla",
}


def energy_per_cycle_pj(mode: str, config: Optional[PPACConfig] = None
                        ) -> float:
    """Modeled pJ per array cycle, calibrated to the paper's 28nm tables.

    power / clock is exactly pJ/cycle: at the 256x256 measurement point
    the per-mode Table III powers reproduce the published pJ/MVP numbers
    (hamming: 478 mW / 0.703 GHz = 680 pJ/MVP; 4-bit MVP: 226 / 0.703 =
    321 pJ/cycle x 16 cycles = 5137 pJ/MVP). Other implemented
    geometries (Table II) use their mode-agnostic measured power; for
    unmeasured geometries the nearest implemented array's fJ/OP scales
    by the paper's OP/cycle accounting.
    """
    cfg = config or PPACConfig()
    impl = TABLE_II.get((cfg.m, cfg.n))
    key = _MODE_POWER_KEY.get(mode)
    if impl is not None:
        if (cfg.m, cfg.n) == (256, 256) and key in TABLE_III:
            return TABLE_III[key]["power_mw"] / impl["f_ghz"]
        return impl["power_mw"] / impl["f_ghz"]
    cells = cfg.m * cfg.n
    near = min(TABLE_II, key=lambda g: abs(math.log(g[0] * g[1] / cells)))
    return TABLE_II[near]["fj_per_op"] * 1e-3 * ops_per_cycle(cfg.m, cfg.n)


def projection_mvp_cycles(d_out: int, d_in: int, k_bits: int = 1,
                          l_bits: int = 1,
                          config: Optional[PPACConfig] = None,
                          parallel_arrays: Optional[int] = None) -> int:
    """Emulated cycles for one K-bit-matrix × L-bit-vector projection MVP
    against a [d_out, d_in] weight virtualized onto the configured array
    geometry.

    Each of the K·L bit-plane-pair passes of the §III-C schedule is one
    1-bit MVP over the [d_out, d_in]-bit tile grid (scan + adder-tree
    merge, per :func:`tiled_scan_merge_cycles`); a single-array fit
    reduces to the paper's K·L cycles exactly.
    """
    return k_bits * l_bits * tiled_scan_merge_cycles(
        d_out, d_in, config, parallel_arrays)


@dataclasses.dataclass(frozen=True)
class ProjectionCost:
    """PPAC cycle cost of one quantized projection inside a model step."""

    name: str
    kind: str
    d_in: int
    d_out: int
    k_bits: int
    l_bits: int
    count: int          # projections of this shape (e.g. stacked layers)
    cycles: int         # total for `count` projections, one token each
    fused: bool         # True when served by the fused PPAC kernels
    energy_nj: float = 0.0  # modeled energy (Tables II–IV calibration)


@dataclasses.dataclass(frozen=True)
class ServingCycleReport:
    """Per-token PPAC cycle accounting aggregated over a model step —
    the Table II NN-inference story (§III-C) at model scale."""

    projections: tuple          # tuple[ProjectionCost, ...]
    config: PPACConfig

    @property
    def cycles_per_token(self) -> int:
        return sum(p.cycles for p in self.projections)

    @property
    def fused_cycles_per_token(self) -> int:
        return sum(p.cycles for p in self.projections if p.fused)

    @property
    def num_projections(self) -> int:
        return sum(p.count for p in self.projections)

    @property
    def energy_nj_per_token(self) -> float:
        return sum(p.energy_nj for p in self.projections)

    def est_us_per_token(self) -> Optional[float]:
        return est_latency_us(self.cycles_per_token, self.config)

    def as_dict(self) -> dict:
        return dict(
            cycles_per_token=self.cycles_per_token,
            fused_cycles_per_token=self.fused_cycles_per_token,
            num_projections=self.num_projections,
            energy_nj_per_token=self.energy_nj_per_token,
            est_us_per_token=self.est_us_per_token(),
            projections=[dataclasses.asdict(p) for p in self.projections],
        )


def est_latency_us(total_cycles: int, config: PPACConfig,
                   shards: int = 1) -> Optional[float]:
    """Wall-clock estimate at the paper's post-layout clock for the
    configured geometry, when Table II measured it; None otherwise."""
    impl = TABLE_II.get((config.m, config.n))
    if not impl:
        return None
    return total_cycles / shards / (impl["f_ghz"] * 1e9) * 1e6


def ops_per_cycle(m: int, n: int, convention: str = "paper") -> int:
    """OP/cycle of an M×N PPAC (1-bit modes).

    convention='paper'  -> M(2N-1)  (Table II accounting)
    convention='extern' -> M(2N)    (Table IV cross-design accounting)
    """
    if convention == "paper":
        return m * (2 * n - 1)
    return m * 2 * n


def peak_throughput_tops(m: int, n: int, f_ghz: float,
                         convention: str = "paper") -> float:
    return ops_per_cycle(m, n, convention) * f_ghz * 1e9 / 1e12


def energy_per_op_fj(m: int, n: int, f_ghz: float, power_mw: float) -> float:
    tops = peak_throughput_tops(m, n, f_ghz)
    return power_mw * 1e-3 / (tops * 1e12) * 1e15


def mode_throughput_gmvps(cfg: PPACConfig, mode: str, f_ghz: float,
                          k_bits: int = 4, l_bits: int = 4) -> float:
    """GMVP/s for an operation mode: 1-bit modes emit one MVP/cycle; multi-bit
    needs K*L cycles (§III-C)."""
    cycles = 1
    if mode.startswith("mvp_multibit") or mode == "mvp_4bit_01":
        cycles = cycles_multibit_mvp(k_bits, l_bits)
    return f_ghz / cycles


def compare_vs_compute_cache(l_bits: int = 4, n_dim: int = 256) -> dict:
    """§IV-B cycle-count comparison: PPAC vs compute-cache [3,4]."""
    ppac = cycles_multibit_mvp(l_bits, l_bits)
    cc = cycles_compute_cache_inner_product(l_bits, n_dim)
    return dict(ppac_cycles=ppac, compute_cache_cycles=cc,
                speedup=cc / ppac)


@dataclasses.dataclass(frozen=True)
class TPURoofline:
    """Three-term roofline for a compiled step on the target pod."""

    chips: int
    flops: float
    hbm_bytes: float
    collective_bytes: float
    ici_links_per_chip: int = 4  # 2D torus: 4 links

    @property
    def compute_s(self) -> float:
        return self.flops / (self.chips * TPU_PEAK_BF16_FLOPS)

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / (self.chips * TPU_HBM_BW)

    @property
    def collective_s(self) -> float:
        return self.collective_bytes / (self.chips * self.ici_links_per_chip * TPU_ICI_BW)

    @property
    def dominant(self) -> str:
        terms = dict(compute=self.compute_s, memory=self.memory_s,
                     collective=self.collective_s)
        return max(terms, key=terms.get)

    def as_dict(self) -> dict:
        return dict(chips=self.chips, flops=self.flops, hbm_bytes=self.hbm_bytes,
                    collective_bytes=self.collective_bytes,
                    compute_s=self.compute_s, memory_s=self.memory_s,
                    collective_s=self.collective_s, dominant=self.dominant)
