"""Kernel-backend selection, shared by every subsystem.

Three interchangeable, bit-identical lowerings exist for the PPAC ops:
'pallas' (the real TPU kernels; interpret mode off-TPU), 'ref' (jnp
oracles) and 'mxu' (int8 dot-product lowering — the fast path on CPU).
"""
from __future__ import annotations

import jax


def auto_backend() -> str:
    """Native Pallas on TPU, the MXU lowering everywhere else."""
    return "pallas" if jax.default_backend() == "tpu" else "mxu"


def resolve_backend(backend: str) -> str:
    return auto_backend() if backend == "auto" else backend


def auto_interpret() -> bool:
    """Pallas kernels run in interpret mode off-TPU."""
    return jax.default_backend() != "tpu"
