"""PPAC core: number formats, cycle-exact array emulator, quantizers, cost model."""
from .cost_model import (  # noqa: F401
    TABLE_II,
    TABLE_III,
    TPURoofline,
    compare_vs_compute_cache,
    energy_per_op_fj,
    mode_throughput_gmvps,
    ops_per_cycle,
    peak_throughput_tops,
)
from .formats import (  # noqa: F401
    NumberFormat,
    fmt,
    from_bitplanes,
    pack_bits,
    pack_planes,
    packed_width,
    plane_weights,
    popcount,
    to_bitplanes,
    unpack_bits,
    value_range,
)
from .ppac import (  # noqa: F401
    PPACArray,
    PPACConfig,
    cycles_compute_cache_inner_product,
    cycles_multibit_mvp,
    hamming_similarity_ref,
    multibit_mvp_ref,
)
from .quant import binarize_pm1, dequantize, fake_quant, quantize  # noqa: F401
