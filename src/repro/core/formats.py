"""Number formats and bitplane codecs for PPAC-style bit-serial arithmetic.

The paper (Table I) defines three L-bit number formats, all built from a
logical LO/HI level per bit-plane:

  uint   : LO=0,  HI=1, unsigned          value = sum_l 2^(l-1) b_l
  int    : LO=0,  HI=1, signed (2's-comp) value = -2^(L-1) b_L + sum_{l<L} 2^(l-1) b_l
  oddint : LO=-1, HI=1, signed odd        value = sum_l 2^(l-1) (2 b_l - 1)

where b_l in {0,1} is the logical level of plane l (l=1 is the LSB).

This module provides exact encode/decode between integer arrays and
bitplane stacks, plus uint32 lane packing used by the Pallas kernels.
Everything is pure jnp and shape-polymorphic.
"""
from __future__ import annotations

import enum
from typing import Tuple

import jax.numpy as jnp
import numpy as np


class NumberFormat(enum.Enum):
    UINT = "uint"
    INT = "int"
    ODDINT = "oddint"

    @property
    def signed(self) -> bool:
        return self is not NumberFormat.UINT


def fmt(name) -> NumberFormat:
    """Coerce a string or NumberFormat to NumberFormat."""
    if isinstance(name, NumberFormat):
        return name
    return NumberFormat(str(name).lower())


def value_range(f: NumberFormat, bits: int) -> Tuple[int, int]:
    """(min, max) representable value — Table I of the paper."""
    f = fmt(f)
    if f is NumberFormat.UINT:
        return 0, 2**bits - 1
    if f is NumberFormat.INT:
        return -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
    # oddint: sum_l 2^(l-1) * (+-1) -> odd values in [-(2^L-1), 2^L-1]
    return -(2**bits) + 1, 2**bits - 1


def representable(f: NumberFormat, bits: int, x) -> jnp.ndarray:
    """Boolean mask of representable values (oddint only holds odd numbers)."""
    f = fmt(f)
    lo, hi = value_range(f, bits)
    ok = (x >= lo) & (x <= hi)
    if f is NumberFormat.ODDINT:
        ok = ok & (jnp.abs(x) % 2 == 1)
    return ok


def to_levels(x, bits: int, f: NumberFormat = NumberFormat.INT) -> jnp.ndarray:
    """Integer values -> L-bit logical level codes u (plane l = (u >> l) & 1).

    The level code is the nonnegative integer whose binary digits are the
    logical plane levels of Table I; it is what the in-kernel bit-slicing
    path streams (uint32, one shift/AND per plane inside the kernel).
    Note a *value* of 0 does not map to a zero level code for oddint —
    zero-padding must happen in the level-code domain.
    """
    f = fmt(f)
    x = jnp.asarray(x, jnp.int32)
    if f is NumberFormat.ODDINT:
        # x = sum_l 2^(l-1)(2 b_l - 1) = 2*uintval(b) - (2^L - 1)
        # => uintval(b) = (x + 2^L - 1) / 2
        u = (x + (2**bits - 1)) // 2
    elif f is NumberFormat.INT:
        u = jnp.where(x < 0, x + 2**bits, x)  # 2's complement bits
    else:
        u = x
    return u.astype(jnp.uint32)


def to_bitplanes(x, bits: int, f: NumberFormat = NumberFormat.INT) -> jnp.ndarray:
    """Decompose integer array ``x`` into logical bitplanes.

    Returns uint8 array of shape ``(bits,) + x.shape`` with plane 0 = LSB.
    Planes hold the *logical levels* (0/1), which for oddint means
    level 1 encodes +1 and level 0 encodes -1 in that plane.
    """
    u = to_levels(x, bits, f)
    planes = [(u >> l) & 1 for l in range(bits)]
    return jnp.stack(planes).astype(jnp.uint8)


def from_bitplanes(planes, f: NumberFormat = NumberFormat.INT) -> jnp.ndarray:
    """Inverse of :func:`to_bitplanes`. planes: (bits, ...) logical levels."""
    f = fmt(f)
    planes = jnp.asarray(planes, jnp.int32)
    bits = planes.shape[0]
    weights = np.asarray([2**l for l in range(bits)], np.int64)
    if f is NumberFormat.INT:
        weights = weights.copy()
        weights[-1] = -weights[-1]  # MSB plane is negated (2's complement)
    weights = jnp.asarray(weights, jnp.int32)
    if f is NumberFormat.ODDINT:
        vals = 2 * planes - 1  # level -> {-1,+1}
    else:
        vals = planes
    return jnp.tensordot(weights, vals, axes=([0], [0])).astype(jnp.int32)


def plane_weights(f: NumberFormat, bits: int) -> np.ndarray:
    """Signed contribution weight of each logical plane (LSB first).

    For uint/oddint: +2^l. For int: MSB plane weight is -2^(L-1).
    (The oddint level->value affine shift is handled separately via the
    constant offset ``sum_l 2^l`` — see ppac.py.)
    """
    f = fmt(f)
    w = np.asarray([2**l for l in range(bits)], np.int64)
    if f is NumberFormat.INT:
        w = w.copy()
        w[-1] = -w[-1]
    return w


# ---------------------------------------------------------------------------
# uint32 lane packing (TPU adaptation of the bit-cell array: N bit-cells per
# row become ceil(N/32) uint32 lanes).
# ---------------------------------------------------------------------------

LANE_BITS = 32


def packed_width(n: int) -> int:
    return (n + LANE_BITS - 1) // LANE_BITS


def pack_bits(bits_arr) -> jnp.ndarray:
    """Pack a (..., N) array of {0,1} into (..., ceil(N/32)) uint32.

    Bit n of the word goes to lane n//32, position n%32 (little-endian),
    so lane ``w`` holds bits [32w, 32w+32). Zero-padded at the tail; callers
    must make padding contribute 0 (AND) or use popcount offsets (XNOR) —
    the kernels handle this via the ``valid_bits`` argument.
    """
    bits_arr = jnp.asarray(bits_arr, jnp.uint32)
    n = bits_arr.shape[-1]
    w = packed_width(n)
    pad = w * LANE_BITS - n
    if pad:
        bits_arr = jnp.pad(bits_arr, [(0, 0)] * (bits_arr.ndim - 1) + [(0, pad)])
    shaped = bits_arr.reshape(bits_arr.shape[:-1] + (w, LANE_BITS))
    shifts = jnp.arange(LANE_BITS, dtype=jnp.uint32)
    return jnp.sum(shaped << shifts, axis=-1, dtype=jnp.uint32)


def unpack_bits(packed, n: int) -> jnp.ndarray:
    """Inverse of :func:`pack_bits` — returns (..., n) uint8 in {0,1}."""
    packed = jnp.asarray(packed, jnp.uint32)
    shifts = jnp.arange(LANE_BITS, dtype=jnp.uint32)
    bits_arr = (packed[..., None] >> shifts) & jnp.uint32(1)
    flat = bits_arr.reshape(packed.shape[:-1] + (packed.shape[-1] * LANE_BITS,))
    return flat[..., :n].astype(jnp.uint8)


def pack_planes(x, bits: int, f: NumberFormat) -> jnp.ndarray:
    """Encode integers -> logical bitplanes -> packed lanes.

    x: (..., N) integers. Returns (bits, ..., ceil(N/32)) uint32.
    """
    planes = to_bitplanes(x, bits, f)  # (bits, ..., N)
    return pack_bits(planes)


def popcount(x) -> jnp.ndarray:
    """Population count of uint32 lanes (vectorized)."""
    import jax.lax as lax

    return lax.population_count(jnp.asarray(x, jnp.uint32)).astype(jnp.int32)
