"""Quantizers + straight-through estimators for PPAC-mode layers.

PPAC consumes integer operands in uint/int/oddint formats (Table I). Training
networks that *execute* on such an engine is the BNN/QAT use case the paper
cites (§III-B, [17]). These quantizers produce (q, scale) pairs where q is an
exact integer in the target format and scale is the per-channel dequant
factor; gradients flow via straight-through estimators (STE).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .formats import NumberFormat, fmt, value_range


@jax.custom_vjp
def _ste_round(x):
    return jnp.round(x)


def _ste_round_fwd(x):
    return jnp.round(x), None


def _ste_round_bwd(_, g):
    return (g,)


_ste_round.defvjp(_ste_round_fwd, _ste_round_bwd)


@jax.custom_vjp
def _ste_sign(x):
    return jnp.where(x >= 0, 1.0, -1.0)


def _ste_sign_fwd(x):
    return _ste_sign(x), x


def _ste_sign_bwd(x, g):
    # clipped STE (Hubara et al.): pass gradient where |x| <= 1
    return (g * (jnp.abs(x) <= 1.0).astype(g.dtype),)


_ste_sign.defvjp(_ste_sign_fwd, _ste_sign_bwd)


def binarize_pm1(x, axis: int = -1):
    """Binarize to {±1} with per-channel scale = mean|x| (XNOR-Net style).

    Returns (q, scale): q float in {±1} (STE-differentiable), scale along
    ``axis``-complement so that q*scale ≈ x.
    """
    scale = jnp.mean(jnp.abs(x), axis=axis, keepdims=True)
    q = _ste_sign(x)
    return q, scale


def binarize_levels(x, axis: int = -1):
    """Binarize to logical bit levels for packing: (levels uint8 in {0,1},
    q float in {±1}, scale). ``levels = (q+1)/2`` is the single bitplane a
    packed1 resident stores; q/scale match :func:`binarize_pm1`.
    """
    q, s = binarize_pm1(x, axis=axis)
    levels = ((q + 1.0) / 2.0).astype(jnp.uint8)
    return levels, q, s


def quantize(x, bits: int, f: NumberFormat = NumberFormat.INT, axis=-1):
    """Symmetric/affine quantization into the exact PPAC format range.

    uint  : affine  q = round(x/s),           s = max(x)/ (2^L - 1), x>=0 assumed via relu
    int   : symmetric q = clip(round(x/s)),   s = max|x| / (2^(L-1) - 1)
    oddint: q = 2*round((x/s - 1)/2) + 1 clipped to odd range (s = max|x|/(2^L-1))

    Returns (q_float, scale) with q holding exact integers castable to int32.
    """
    f = fmt(f)
    lo, hi = value_range(f, bits)
    eps = 1e-8
    if f is NumberFormat.UINT:
        xp = jax.nn.relu(x)
        s = jnp.max(xp, axis=axis, keepdims=True) / hi + eps
        q = jnp.clip(_ste_round(xp / s), lo, hi)
    elif f is NumberFormat.INT:
        s = jnp.max(jnp.abs(x), axis=axis, keepdims=True) / hi + eps
        q = jnp.clip(_ste_round(x / s), lo, hi)
    else:  # oddint: nearest odd integer
        s = jnp.max(jnp.abs(x), axis=axis, keepdims=True) / hi + eps
        q = 2.0 * _ste_round((x / s - 1.0) / 2.0) + 1.0
        q = jnp.clip(q, lo, hi)
    return q, s


def dequantize(q, scale):
    return q * scale


def fake_quant(x, bits: int, f: NumberFormat = NumberFormat.INT, axis=-1):
    """QAT fake-quant: dequantize(quantize(x)) with STE gradients."""
    q, s = quantize(x, bits, f, axis)
    return q * s
