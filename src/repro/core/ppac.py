"""Cycle-exact functional emulator of the PPAC array (paper §II–III).

This is the *paper-faithful baseline*: a functional model of an M×N PPAC
array with B banks and B_s subrows per row, supporting all five operation
modes with exact cycle accounting. It is the oracle against which the
Pallas kernels and the MXU lowering are validated, and the engine behind
the Table II/III/IV benchmark reproductions.

Conventions
-----------
* The stored matrix ``A`` is kept as logical levels (uint8 {0,1}) of shape
  (M, N) — one bit per bit-cell, exactly like the latch array.
* ``s`` selects the bit-cell operator per column: 0 = XNOR, 1 = AND.
* The row ALU implements (Fig. 2c):

      r_m   = popcount over the row's bit-cell outputs        (pipelined)
      t_m   = (popX2 ? 2 r_m : r_m) + (nOZ ? acc1_m : 0) - (cEn ? c : 0)
      acc1' = weV ? (vAcc ? 2*acc1 + sgn_v * t : sgn_v * t) : acc1
      acc2' = weM ? (mAcc ? 2*acc2 + sgn_m * u : sgn_m * u) : acc2
      y_m   = u_m - delta_m      with u = acc2 path output

  We model the mode-level semantics of §III exactly (eqs. (1)–(5), Table I)
  rather than gate-level signal timing; cycle counts follow §III and §IV
  (one MVP per cycle for 1-bit ops with a 2-cycle pipeline latency; K*L
  cycles for K-bit-matrix × L-bit-vector MVPs).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from .formats import (
    NumberFormat,
    fmt,
    from_bitplanes,
    to_bitplanes,
)

XNOR = 0
AND = 1


@dataclasses.dataclass(frozen=True)
class PPACConfig:
    """Array geometry — mirrors the paper's parametrization (§IV-A)."""

    m: int = 256          # words (rows)
    n: int = 256          # bits per word (columns)
    rows_per_bank: int = 16
    subrow_bits: int = 16  # V bit-cells per subrow
    max_k: int = 4         # max matrix bits (row ALU datapath, §IV-A)
    max_l: int = 4         # max vector bits

    @property
    def banks(self) -> int:
        return max(1, -(-self.m // self.rows_per_bank))

    @property
    def subrows(self) -> int:
        return max(1, -(-self.n // self.subrow_bits))

    def validate(self):
        assert self.m > 0 and self.n > 0
        assert self.rows_per_bank > 0 and self.subrow_bits > 0


@dataclasses.dataclass
class CycleCounter:
    """Tracks emulated PPAC clock cycles (pipeline latency 2, throughput 1)."""

    cycles: int = 0
    pipeline_latency: int = 2

    def tick(self, n: int = 1):
        self.cycles += n


class PPACArray:
    """Functional PPAC array. All mode methods return bit-true results and
    advance the cycle counter by the paper's cycle cost."""

    def __init__(self, config: PPACConfig = PPACConfig()):
        config.validate()
        self.config = config
        self.a = jnp.zeros((config.m, config.n), jnp.uint8)   # latch array
        self.s = jnp.zeros((config.n,), jnp.uint8)            # XNOR/AND per col
        self.acc1 = jnp.zeros((config.m,), jnp.int32)         # vector accumulator
        self.acc2 = jnp.zeros((config.m,), jnp.int32)         # matrix accumulator
        self.delta = jnp.zeros((config.m,), jnp.int32)        # per-row threshold
        self.c = 0                                            # shared offset
        self.counter = CycleCounter()

    # -- configuration-time writes (not counted as compute cycles; the paper
    #    excludes matrix-load power/time from its measurements, §IV-A) -------
    def write(self, a_bits, row0: int = 0):
        a_bits = jnp.asarray(a_bits, jnp.uint8)
        m, n = a_bits.shape
        assert row0 + m <= self.config.m and n <= self.config.n
        self.a = self.a.at[row0 : row0 + m, :n].set(a_bits)

    def set_column_ops(self, s):
        self.s = jnp.asarray(s, jnp.uint8)

    def set_thresholds(self, delta):
        self.delta = jnp.broadcast_to(jnp.asarray(delta, jnp.int32), (self.config.m,))

    # -- the bit-cell array + subrow/row popcount ---------------------------
    def _row_popcount(self, x_bits) -> jnp.ndarray:
        """r_m = popcount of per-column XNOR/AND against broadcast x."""
        x = jnp.asarray(x_bits, jnp.uint8)[None, :]  # broadcast over rows
        xnor_out = 1 - (self.a ^ x)   # XNOR: 1 where equal
        and_out = self.a & x
        cell = jnp.where(self.s[None, :] == AND, and_out, xnor_out)
        # subrow partition: local popcounts then row ALU sums them. Integer
        # addition is associative so we sum directly; the partition only
        # affects wiring, not values (§II-B).
        return jnp.sum(cell.astype(jnp.int32), axis=1)

    # -- operation modes -----------------------------------------------------
    def hamming_similarity(self, x_bits) -> jnp.ndarray:
        """Mode III-A: y_m = h̄(a_m, x). One cycle (pipelined)."""
        self.set_column_ops(jnp.zeros((self.config.n,), jnp.uint8))
        self.counter.tick(1)
        return self._row_popcount(x_bits)

    def cam_match(self, x_bits, delta: Optional[int] = None) -> jnp.ndarray:
        """CAM: match iff h̄ >= delta (delta=N -> complete match).

        Returns boolean matches; implemented as MSB of y_m = r_m - delta,
        exactly as §III-A (match iff y_m >= 0).
        """
        n = self.config.n
        d = n if delta is None else delta
        self.set_thresholds(d)
        r = self.hamming_similarity(x_bits)
        y = r - self.delta
        return y >= 0

    def mvp_1bit(self, x_bits, fmt_a="pm1", fmt_x="pm1") -> jnp.ndarray:
        """Mode III-B: 1-bit MVP with {±1} ('pm1') / {0,1} ('01') formats.

        One cycle per MVP; the mixed formats need a one-time extra cycle
        when A changes (h̄(a,1) or h̄(a,0) precompute) — modeled in
        ``setup_cycles``.
        """
        n = self.config.n
        x = jnp.asarray(x_bits, jnp.uint8)
        if fmt_a == "pm1" and fmt_x == "pm1":
            # eq (1): <a,x> = 2 h̄ - N   (XNOR, popX2, cEn, c=N)
            r = self.hamming_similarity(x)
            return 2 * r - n
        if fmt_a == "01" and fmt_x == "01":
            # AND: r_m directly
            self.set_column_ops(jnp.ones((self.config.n,), jnp.uint8))
            self.counter.tick(1)
            return self._row_popcount(x)
        if fmt_a == "pm1" and fmt_x == "01":
            # eq (2): <a,x> = h̄(a, x̂) + h̄(a, 1) - N
            h1 = self.hamming_similarity(jnp.ones((n,), jnp.uint8))  # setup
            hx = self.hamming_similarity(x)
            return hx + h1 - n
        if fmt_a == "01" and fmt_x == "pm1":
            # eq (3): <a,x> = 2<a, x~> + h̄(a, 0) - N
            h0 = self.hamming_similarity(jnp.zeros((n,), jnp.uint8))  # setup
            self.set_column_ops(jnp.ones((self.config.n,), jnp.uint8))
            self.counter.tick(1)
            r = self._row_popcount(x)
            return 2 * r + h0 - n
        raise ValueError(f"unsupported format pair {fmt_a},{fmt_x}")

    def mvp_multibit_vector(self, x, l_bits: int, fmt_x: NumberFormat,
                            fmt_a: str = "pm1") -> jnp.ndarray:
        """Mode III-C1: 1-bit matrix × L-bit vector, bit-serially, L cycles.

        MSB-first accumulation: acc = 2*acc + A x_l  (vAcc), with the MSB
        partial product negated for signed (int) vectors (vAccX-1).
        """
        fmt_x = fmt(fmt_x)
        planes = to_bitplanes(x, l_bits, fmt_x)  # (L, N) logical levels
        acc = jnp.zeros((self.config.m,), jnp.int32)
        for step, l in enumerate(reversed(range(l_bits))):  # MSB first
            if fmt_x is NumberFormat.ODDINT:
                # levels already encode ±1 directly through the pm1 path
                partial = self.mvp_1bit(planes[l], fmt_a=fmt_a, fmt_x="pm1")
            else:
                partial = self.mvp_1bit(planes[l], fmt_a=fmt_a, fmt_x="01")
            sgn = -1 if (fmt_x is NumberFormat.INT and step == 0) else 1
            acc = 2 * acc + sgn * partial
        self.acc1 = acc
        return acc

    def mvp_multibit(self, a_int, x_int, k_bits: int, l_bits: int,
                     fmt_a: NumberFormat = NumberFormat.INT,
                     fmt_x: NumberFormat = NumberFormat.INT) -> jnp.ndarray:
        """Mode III-C2: K-bit matrix × L-bit vector over K*L cycles.

        The K bitplanes of A live in different column groups (N/K entries
        per row); we emulate by loading plane A_k and running the L-cycle
        vector loop, accumulating acc2 = 2*acc2 + A_k x (mAcc), with the
        matrix-MSB partial negated for int (mAccX-1).
        """
        fmt_a, fmt_x = fmt(fmt_a), fmt(fmt_x)
        a_planes = to_bitplanes(a_int, k_bits, fmt_a)  # (K, M, N/K entries)
        acc2 = jnp.zeros((self.config.m,), jnp.int32)
        oddint_a = fmt_a is NumberFormat.ODDINT
        for step, k in enumerate(reversed(range(k_bits))):  # MSB-plane first
            self.write(a_planes[k])
            fmt_a_1bit = "pm1" if oddint_a else "01"
            partial = self.mvp_multibit_vector(x_int, l_bits, fmt_x, fmt_a=fmt_a_1bit)
            sgn = -1 if (fmt_a is NumberFormat.INT and step == 0) else 1
            acc2 = 2 * acc2 + sgn * partial
        self.acc2 = acc2
        return acc2

    def gf2_mvp(self, x_bits) -> jnp.ndarray:
        """Mode III-D: GF(2) MVP — AND products, LSB of the integer sum."""
        self.set_column_ops(jnp.ones((self.config.n,), jnp.uint8))
        self.counter.tick(1)
        r = self._row_popcount(x_bits)
        return (r & 1).astype(jnp.uint8)

    def pla(self, x_bits, num_vars_per_row) -> jnp.ndarray:
        """Mode III-E: each row a min-term; per-bank OR of min-terms.

        num_vars_per_row: δ_m = number of variables in row m's min-term.
        Returns (banks,) uint8 Boolean outputs p_b > 0.
        """
        self.set_column_ops(jnp.ones((self.config.n,), jnp.uint8))
        self.set_thresholds(jnp.asarray(num_vars_per_row, jnp.int32))
        self.counter.tick(1)
        r = self._row_popcount(x_bits)
        y = r - self.delta  # 0 iff all vars present
        minterm = (y >= 0).astype(jnp.int32)  # complement of MSB
        banks = minterm.reshape(self.config.banks, self.config.rows_per_bank)
        p = jnp.sum(banks, axis=1)
        return (p > 0).astype(jnp.uint8)

    def pla_max_terms(self, x_bits, programmed_rows_per_bank) -> jnp.ndarray:
        """§III-E variant: δ_m=1 makes each row a max-term (OR); the bank
        output is 1 iff p_b equals the number of programmed max-terms
        (product of max-terms / CNF)."""
        self.set_column_ops(jnp.ones((self.config.n,), jnp.uint8))
        self.set_thresholds(1)
        self.counter.tick(1)
        r = self._row_popcount(x_bits)
        maxterm = (r - self.delta >= 0).astype(jnp.int32)
        banks = maxterm.reshape(self.config.banks, self.config.rows_per_bank)
        p = jnp.sum(banks, axis=1)
        want = jnp.asarray(programmed_rows_per_bank, jnp.int32)
        return (p == want).astype(jnp.uint8)


# ---------------------------------------------------------------------------
# Pure-functional conveniences (no array state) used across the framework.
# ---------------------------------------------------------------------------

def hamming_similarity_ref(a_bits, x_bits) -> jnp.ndarray:
    """h̄ for a (M,N) bit matrix against (..., N) inputs -> (..., M)."""
    a = jnp.asarray(a_bits, jnp.int32)
    x = jnp.asarray(x_bits, jnp.int32)
    # h̄ = number of equal bits = sum over n of XNOR(a, x)
    match = 1 - jnp.bitwise_xor(x[..., None, :], a)  # (..., M, N)
    return jnp.sum(match, axis=-1)


def multibit_mvp_ref(a_int, x_int,
                     fmt_a: NumberFormat = NumberFormat.INT,
                     fmt_x: NumberFormat = NumberFormat.INT) -> jnp.ndarray:
    """Ground-truth integer MVP y = A x (independent of PPAC), int32."""
    a = jnp.asarray(a_int, jnp.int32)
    x = jnp.asarray(x_int, jnp.int32)
    return a @ x


def cycles_multibit_mvp(k_bits: int, l_bits: int) -> int:
    """Paper cycle count for a K-bit-matrix × L-bit-vector MVP (§III-C)."""
    return k_bits * l_bits


def cycles_compute_cache_inner_product(l_bits: int, n_dim: int) -> int:
    """Cycle count of the compute-cache/Neural-cache approach [3,4] quoted in
    §IV-B: elementwise L-bit multiply costs L^2 + 5L - 2; the reduction of an
    N-vector with 2L-bit entries costs >= 2L * log2(N) cycles."""
    mult = l_bits * l_bits + 5 * l_bits - 2
    red = 2 * l_bits * int(np.ceil(np.log2(n_dim)))
    return mult + red
