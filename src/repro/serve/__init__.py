from .step import (  # noqa: F401
    convert_params_for_serving,
    generate_scan,
    greedy_generate,
    make_decode_select_step,
    make_decode_step,
    make_generate_scan,
    make_prefill_step,
    sample_tokens,
)
