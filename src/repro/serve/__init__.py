from .step import (  # noqa: F401
    convert_params_for_serving,
    greedy_generate,
    make_decode_step,
    make_prefill_step,
)
