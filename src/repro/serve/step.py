"""Serving steps: prefill / decode wrappers + PPAC weight conversion.

``convert_params_for_serving`` is the PPAC load path: projection weights
become resident quantized containers (int8 / packed4 / packed1), exactly
the paper's weight-stationary premise — the decode memory-roofline lever
measured in EXPERIMENTS.md §Perf. ``serving_cycle_report`` prices the
converted model in emulated PPAC cycles per decoded token (the §III-C
K·L accounting aggregated over every projection of a step).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig
from ..core.cost_model import (
    ProjectionCost,
    ServingCycleReport,
    projection_mvp_cycles,
)
from ..core.engine import QuantContainer, pack_weight_for_serving
from ..core.ppac import PPACConfig
from ..models import lm
from ..sharding.rules import ShardingRules


def make_prefill_step(cfg: ModelConfig, rules: Optional[ShardingRules] = None,
                      mode: str = "float"):
    def prefill_step(params, batch, cache):
        return lm.prefill(params, cfg, batch, cache, mode=mode, rules=rules)
    return prefill_step


def make_decode_step(cfg: ModelConfig, rules: Optional[ShardingRules] = None,
                     mode: str = "float"):
    def decode_step(params, tokens, cache):
        return lm.decode_step(params, cfg, tokens, cache, mode=mode,
                              rules=rules)
    return decode_step


def greedy_generate(params, cfg: ModelConfig, batch, *, steps: int,
                    max_seq: int, mode: str = "float"):
    """Reference generation loop (prefill + greedy decode), jit per step."""
    b = jax.tree.leaves(batch)[0].shape[0]
    cache, _ = lm.init_cache(cfg, b, max_seq)
    prefill = jax.jit(make_prefill_step(cfg, mode=mode))
    decode = jax.jit(make_decode_step(cfg, mode=mode))
    logits, cache = prefill(params, batch, cache)
    out = []
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(steps):
        out.append(tok)
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


# -- PPAC serving conversion ---------------------------------------------------

_PPAC_ELIGIBLE = ("wq", "wk", "wv", "wo", "wi", "wg", "w_q", "w_uk", "w_uv",
                  "in_proj", "out_proj")


def convert_params_for_serving(params, cfg: ModelConfig):
    """Replace large projection weights with resident PPAC containers.

    Only 2-D weight leaves under eligible projection names are converted
    (embeddings, norms, SSD internals stay float). Works on stacked
    (scan) params by vmapping the packer over the layer axis.
    """
    ppac = cfg.ppac
    if not ppac.enabled:
        return params

    pack = functools.partial(pack_weight_for_serving,
                             weight_bits=ppac.weight_bits,
                             weight_format=ppac.weight_format)

    def convert(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if "w" not in names[-1:]:
            return leaf
        parent = names[-2] if len(names) > 1 else ""
        if parent not in _PPAC_ELIGIBLE:
            return leaf
        if leaf.ndim == 2:
            if min(leaf.shape) < ppac.min_features:
                return leaf
            return pack(leaf)
        if leaf.ndim == 3:  # stacked over layers
            if min(leaf.shape[1:]) < ppac.min_features:
                return leaf
            return jax.vmap(pack)(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(convert, params)


# -- PPAC cycle accounting -----------------------------------------------------

def _container_geometry(c: QuantContainer):
    """(base_ndim, d_out, d_in) of one (possibly layer-stacked) container."""
    wq = c.wq
    if c.kind == "packed1":
        base, d_out = 2, wq.shape[-2]
        d_in = c.n_in or wq.shape[-1] * 32
    elif c.kind == "packed4":
        base, d_out = 3, wq.shape[-2]
        d_in = c.n_in or wq.shape[-1] * 32
    else:  # int8 / bf16: [in, out] rows
        base, d_out = 2, wq.shape[-1]
        d_in = c.n_in or wq.shape[-2]
    return base, d_out, d_in


def serving_cycle_report(params, cfg: ModelConfig, *,
                         config: Optional[PPACConfig] = None,
                         parallel_arrays: Optional[int] = None
                         ) -> ServingCycleReport:
    """Per-token PPAC cycle accounting over every quantized projection.

    Each K-bit container costs K·L tile-grid cycles per streamed token
    (packed1: K=L=1, one XNOR pass), aggregated across (possibly
    layer-stacked) projections — a full LM decode step priced in the
    paper's §III-C accounting. int8 containers run on the MXU fallback,
    not the fused kernels; they are reported with ``fused=False`` at their
    would-be K=8 bit-serial cost. bf16 containers are not PPAC-executable
    and are skipped.
    """
    hw = config or PPACConfig()
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, QuantContainer))
    entries = []
    for path, leaf in flat:
        if not isinstance(leaf, QuantContainer) or leaf.kind == "bf16":
            continue
        name = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                        for p in path)
        base, d_out, d_in = _container_geometry(leaf)
        if leaf.kind == "packed1":
            k_bits, l_bits = 1, 1
        else:
            k_bits = leaf.bits or 8
            l_bits = cfg.ppac.act_bits
        count = (int(np.prod(leaf.wq.shape[: leaf.wq.ndim - base]))
                 if leaf.wq.ndim > base else 1)
        cycles = count * projection_mvp_cycles(
            d_out, d_in, k_bits, l_bits, hw, parallel_arrays)
        entries.append(ProjectionCost(
            name=name, kind=leaf.kind, d_in=d_in, d_out=d_out,
            k_bits=k_bits, l_bits=l_bits, count=count, cycles=cycles,
            fused=leaf.kind in ("packed1", "packed4")))
    return ServingCycleReport(projections=tuple(entries), config=hw)
