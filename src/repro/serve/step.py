"""Serving steps: donated prefill/decode/generation + PPAC weight conversion.

Generation is *device-resident*: every jitted entry point donates the KV
cache pytree (``donate_argnums``), so per-step cache writes lower to
in-place ``dynamic_update_slice``/scatter instead of whole-cache copies —
the data-movement tax the paper's weight-stationary premise (§III) exists
to avoid, and exactly the invariant tests/test_generate.py asserts on the
lowered HLO (every cache leaf carries an aliasing attribute). On top of
the per-step path, :func:`generate_scan` fuses N decode steps *and* the
sampling (greedy / temperature / top-k) into one ``lax.scan`` program —
one dispatch for the whole generation instead of one per token.

``convert_params_for_serving`` is the PPAC load path: projection weights
become resident quantized containers (int8 / packed4 / packed1), exactly
the paper's weight-stationary premise — the decode memory-roofline lever
measured in EXPERIMENTS.md §Perf. ``serving_cycle_report`` prices the
converted model in emulated PPAC cycles per decoded token (the §III-C
K·L accounting aggregated over every projection of a step).
"""
from __future__ import annotations

import functools
import warnings
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..configs.base import ModelConfig
from ..core.cost_model import ProjectionCost, ServingCycleReport
from ..core.engine import QuantContainer, pack_weight_for_serving
from ..core.ppac import PPACConfig
from ..obs import ledger as _flight
from ..models import lm
from ..sharding.rules import ShardingRules


def _maybe_cached(factory):
    """lru-cache a jitted-entry-point factory on its hashable args.

    jax.jit caches traces by function identity: a fresh wrapper per call
    would retrace (and recompile) every generation. ModelConfig is a
    frozen dataclass, so (cfg, mode, ...) keys are hashable; unhashable
    ``rules`` objects fall through to an uncached build (sharded callers
    hold on to the returned function themselves)."""
    cached = functools.lru_cache(maxsize=128)(factory)

    @functools.wraps(factory)
    def build(*args):
        try:
            return cached(*args)
        except TypeError:  # unhashable arg (e.g. ShardingRules)
            return factory(*args)
    return build


@_maybe_cached
def _prefill_step_cached(cfg, rules, mode, donate):
    def prefill_step(params, batch, cache, lengths=None):
        return lm.prefill(params, cfg, batch, cache, lengths=lengths,
                          mode=mode, rules=rules)
    return jax.jit(prefill_step, donate_argnums=(2,) if donate else ())


def make_prefill_step(cfg: ModelConfig, rules: Optional[ShardingRules] = None,
                      mode: str = "float", *, jit: bool = True,
                      donate: bool = True):
    """(params, batch, cache, lengths=None) -> (logits, cache).

    Jitted with the cache donated by default: prefill writes the whole
    prompt into a zero cache, so the input buffers are dead on return.
    ``jit=False`` returns the raw function (the dry-run wraps it in its
    own sharded jit)."""
    if not jit:
        def prefill_step(params, batch, cache, lengths=None):
            return lm.prefill(params, cfg, batch, cache, lengths=lengths,
                              mode=mode, rules=rules)
        return prefill_step
    return _prefill_step_cached(cfg, rules, mode, donate)


@_maybe_cached
def _decode_step_cached(cfg, rules, mode, donate):
    def decode_step(params, tokens, cache):
        return lm.decode_step(params, cfg, tokens, cache, mode=mode,
                              rules=rules)
    return jax.jit(decode_step, donate_argnums=(2,) if donate else ())


def make_decode_step(cfg: ModelConfig, rules: Optional[ShardingRules] = None,
                     mode: str = "float", *, jit: bool = True,
                     donate: bool = True):
    """(params, tokens, cache) -> (logits, cache), cache donated.

    Donation is what makes the per-layer cache update an in-place
    scatter: without it XLA must copy every [B,T,H,D] cache leaf per
    layer per token to preserve the (dead) input buffers."""
    if not jit:
        def decode_step(params, tokens, cache):
            return lm.decode_step(params, cfg, tokens, cache, mode=mode,
                                  rules=rules)
        return decode_step
    return _decode_step_cached(cfg, rules, mode, donate)


# -- fused sampling ------------------------------------------------------------

def _scale_logits(logits, *, temperature: float, top_k: int):
    """Sampling pre-scale: temperature division + optional top-k mask.
    Shared by the fused sampler and speculative accept/reject, which must
    see the *same* distributions the sampler draws from."""
    scaled = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = lax.top_k(scaled, top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    return scaled


def sample_tokens(logits, key, *, temperature: float = 0.0, top_k: int = 0):
    """logits [B,V] -> tokens [B] int32, on device.

    temperature == 0 -> greedy argmax (key unused); otherwise softmax
    sampling at ``temperature``, optionally restricted to the ``top_k``
    highest-scoring tokens. Static python knobs: each setting is its own
    compiled program, fused into the decode step / scan body."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = _scale_logits(logits, temperature=temperature, top_k=top_k)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


@_maybe_cached
def _decode_select_cached(cfg, rules, mode, temperature, top_k, donate):
    def step(params, tokens, cache, key):
        logits, cache = lm.decode_step(params, cfg, tokens, cache,
                                       mode=mode, rules=rules)
        nxt = sample_tokens(logits[:, -1], key, temperature=temperature,
                            top_k=top_k)
        return nxt, cache
    return jax.jit(step, donate_argnums=(2,) if donate else ())


def make_decode_select_step(cfg: ModelConfig,
                            rules: Optional[ShardingRules] = None,
                            mode: str = "float", *,
                            temperature: float = 0.0, top_k: int = 0,
                            donate: bool = True):
    """(params, tokens [B,1], cache, key) -> (next [B] int32, cache).

    One fused, cache-donating dispatch per token: decode + token
    selection stay on device — the host never sees logits, only the [B]
    token ids it actually needs (EOS/retirement decisions)."""
    return _decode_select_cached(cfg, rules, mode, temperature, top_k,
                                 donate)


@_maybe_cached
def _prefill_select_cached(cfg, rules, mode, temperature, top_k, paged,
                           history, donate):
    if not paged:
        def step(params, tokens, lengths, cache, key):
            logits, cache = lm.prefill(params, cfg, {"tokens": tokens},
                                       cache, lengths=lengths, mode=mode,
                                       rules=rules)
            tok = sample_tokens(logits[:, -1], key, temperature=temperature,
                                top_k=top_k)
            return tok, cache
        return jax.jit(step, donate_argnums=(3,) if donate else ())

    def step(params, tokens, lengths, starts, slot_ids, table_rows, cache,
             key):
        logits, cache = lm.prefill(
            params, cfg, {"tokens": tokens}, cache, lengths=lengths,
            mode=mode, rules=rules, start=starts if history else None,
            history=history, table=table_rows, slot_ids=slot_ids)
        tok = sample_tokens(logits[:, -1], key, temperature=temperature,
                            top_k=top_k)
        return tok, cache
    return jax.jit(step, donate_argnums=(6,) if donate else ())


def make_prefill_select_step(cfg: ModelConfig,
                             rules: Optional[ShardingRules] = None,
                             mode: str = "float", *,
                             temperature: float = 0.0, top_k: int = 0,
                             paged: bool = False, history: bool = False,
                             donate: bool = True):
    """Fused prefill + first-token selection, cache donated.

    Contiguous (``paged=False``):
        (params, tokens, lengths, cache, key) -> (tok0 [B], cache)
    prefills a scratch cache whose rows the server copies into resident
    slots.

    Paged (``paged=True``): the cache IS the resident pool pytree —
        (params, tokens, lengths, starts, slot_ids, table_rows, cache,
         key) -> (tok0 [B], cache)
    writes the admitted group's KV straight through ``table_rows``
    [B, n_pages] into the shared pools (no scratch cache, no copy) and
    scatters end positions at ``slot_ids``. ``history=True`` compiles
    the suffix variant for prefix-cache hits: ``tokens`` hold only the
    un-cached suffix and ``starts`` its absolute offsets."""
    return _prefill_select_cached(cfg, rules, mode, temperature, top_k,
                                  paged, history, donate)


def greedy_generate(params, cfg: ModelConfig, batch, *, steps: int,
                    max_seq: int, mode: str = "float"):
    """Reference per-step generation loop (prefill + greedy decode).

    Legacy path kept as the scan baseline: still one jitted dispatch per
    token, but token selection is fused into the decode step and the
    cache is donated — nothing round-trips to the host between steps
    (the [B, steps] token matrix transfers once, at the end)."""
    b = jax.tree.leaves(batch)[0].shape[0]
    cache, _ = lm.init_cache(cfg, b, max_seq)
    prefill = make_prefill_step(cfg, mode=mode)
    decode = make_decode_select_step(cfg, mode=mode)
    key = jax.random.PRNGKey(0)  # greedy: unused, fixed shape
    logits, cache = prefill(params, batch, cache)
    tok = sample_tokens(logits[:, -1], key)
    out = []
    for _ in range(steps):
        out.append(tok)
        tok, cache = decode(params, tok[:, None], cache, key)
    return jnp.stack(out, axis=1)


@_maybe_cached
def _generate_scan_cached(cfg, steps, rules, mode, temperature, top_k,
                          donate):

    def gen(params, logits, cache, key):
        key, k0 = jax.random.split(key)
        tok0 = sample_tokens(logits[:, -1], k0, temperature=temperature,
                             top_k=top_k)

        def body(carry, _):
            tok, cache, key = carry
            logits, cache = lm.decode_step(params, cfg, tok[:, None], cache,
                                           mode=mode, rules=rules)
            key, ks = jax.random.split(key)
            nxt = sample_tokens(logits[:, -1], ks, temperature=temperature,
                                top_k=top_k)
            return (nxt, cache, key), tok

        (last, cache, _), toks = lax.scan(body, (tok0, cache, key), None,
                                          length=steps)
        return jnp.moveaxis(toks, 0, 1), cache
    return jax.jit(gen, donate_argnums=(2,) if donate else ())


def make_generate_scan(cfg: ModelConfig, *, steps: int,
                       rules: Optional[ShardingRules] = None,
                       mode: str = "float", temperature: float = 0.0,
                       top_k: int = 0, donate: bool = True):
    """One on-device program for the whole generation tail.

    (params, logits [B,1,V], cache, key) -> (tokens [B, steps], cache):
    samples the first token from the prefill logits, then runs ``steps``
    decode steps inside a single ``lax.scan`` with sampling fused in.
    The cache is donated and scan-carried, so every per-layer cache
    update is an in-place write — no cache-sized copy anywhere in the
    program — and the host pays one dispatch for N tokens."""
    return _generate_scan_cached(cfg, steps, rules, mode, temperature,
                                 top_k, donate)


def generate_scan(params, cfg: ModelConfig, batch, *, steps: int,
                  max_seq: int, mode: str = "float",
                  temperature: float = 0.0, top_k: int = 0, key=None,
                  rules: Optional[ShardingRules] = None,
                  return_cache: bool = False):
    """Device-resident generation: prefill + one fused N-step scan.

    Semantics match :func:`greedy_generate` at temperature 0 (token i is
    sampled from the logits *before* decode step i), with temperature /
    top-k sampling available via the fused sampler. Returns [B, steps]
    int32 tokens (and the final cache with ``return_cache``)."""
    b = jax.tree.leaves(batch)[0].shape[0]
    cache, _ = lm.init_cache(cfg, b, max_seq)
    prefill = make_prefill_step(cfg, rules, mode)
    gen = make_generate_scan(cfg, steps=steps, rules=rules, mode=mode,
                             temperature=temperature, top_k=top_k)
    logits, cache = prefill(params, batch, cache)
    key = _default_key(key, temperature, "generate_scan")
    toks, cache = gen(params, logits, cache, key)
    return (toks, cache) if return_cache else toks


def _default_key(key, temperature: float, where: str):
    """PRNG-key hygiene for the generation entry points: greedy decoding
    never consumes the key, but at ``temperature > 0`` a silently shared
    default key makes every call return identical samples — warn loudly
    instead of handing back deterministic 'randomness'."""
    if key is not None:
        return key
    if temperature > 0.0:
        warnings.warn(
            f"{where}: temperature={temperature} > 0 with no PRNG key — "
            "falling back to jax.random.PRNGKey(0), so every call returns "
            "IDENTICAL samples. Pass an explicit key= to sample.",
            stacklevel=3)
    return jax.random.PRNGKey(0)


# -- self-speculative decoding on the precision ladder -------------------------
#
# The same resident QuantContainer serves two rungs of the paper's
# precision ladder: the packed1 rung (one 8-cycle XNOR pass, §III-C) and
# the multi-bit target rung (K·L bit-plane-pair passes, e.g. 8x that for
# packed4/int8 inputs). A speculative round drafts k tokens with the cheap
# rung via
# the existing fused decode scan, then verifies all k+1 positions in ONE
# batched target-rung launch — the fused kernels are batch-oblivious, so
# verification prices like a single wide MVP, not k+1 decode steps — and
# accepts the longest matching prefix on device. Greedy outputs are
# bit-identical to target-rung-only decoding; at temperature > 0 the
# standard speculative rejection-sampling rule keeps the output
# distribution exactly the target rung's.


def _spec_round(params, cfg, tok, cache, key, *, draft_k: int, mode: str,
                rules, temperature: float, top_k: int):
    """One fused draft -> verify -> accept round.

    tok: [B] pending tokens (already emitted; logits not yet computed) at
    positions ``cache['pos']``. Returns ``(emitted [B, draft_k+1],
    n_emit [B] in [1, draft_k+1], cache)``: ``emitted[:, :n_emit]`` are
    this round's new tokens and ``emitted[b, n_emit[b]-1]`` the next
    pending token. The draft phase runs on a functional branch of the
    cache (its packed1-rung KV writes are discarded); verify writes all
    k+1 positions' target-rung KV and the accept step rewinds ``pos`` to
    the accepted prefix (ring caches also restore rejected slots).
    """
    b = tok.shape[0]
    k = draft_k
    start = jnp.broadcast_to(jnp.asarray(cache["pos"], jnp.int32), (b,))
    kd, ka, kc = jax.random.split(key, 3)

    draft_toks = draft_scaled = None
    if k:
        def dbody(carry, ks):
            t, c = carry
            with _flight.phase("draft", window=1):
                logits, c = lm.decode_step(params, cfg, t[:, None], c,
                                           mode="draft", rules=rules)
            lg = logits[:, -1]
            if temperature <= 0.0:
                nxt = jnp.argmax(lg, axis=-1).astype(jnp.int32)
                return (nxt, c), (nxt, lg)
            sc = _scale_logits(lg, temperature=temperature, top_k=top_k)
            nxt = jax.random.categorical(ks, sc, axis=-1).astype(jnp.int32)
            return (nxt, c), (nxt, sc)

        _, (dt, dsc) = lax.scan(dbody, (tok, cache),
                                jax.random.split(kd, k))
        draft_toks = jnp.moveaxis(dt, 0, 1)          # [B, k]
        draft_scaled = jnp.moveaxis(dsc, 0, 1)       # [B, k, V]
        window = jnp.concatenate([tok[:, None], draft_toks], axis=1)
    else:
        window = tok[:, None]

    with _flight.phase("verify", window=k + 1):
        vlogits, vcache = lm.verify(params, cfg, window, cache, mode=mode,
                                    rules=rules)

    if temperature <= 0.0:
        # exact greedy match: accept drafts while d_j == argmax(p_{j-1})
        g = jnp.argmax(vlogits, axis=-1).astype(jnp.int32)   # [B, k+1]
        if k:
            match = (draft_toks == g[:, :k]).astype(jnp.int32)
            a = jnp.sum(jnp.cumprod(match, axis=1), axis=1)  # [B] in [0,k]
        else:
            a = jnp.zeros((b,), jnp.int32)
        correction = jnp.take_along_axis(g, a[:, None], axis=1)
    else:
        vsc = _scale_logits(vlogits, temperature=temperature, top_k=top_k)
        p = jax.nn.softmax(vsc, axis=-1)                     # [B, k+1, V]
        if k:
            q = jax.nn.softmax(draft_scaled, axis=-1)        # [B, k, V]
            pd = jnp.take_along_axis(p[:, :k], draft_toks[..., None],
                                     axis=-1)[..., 0]        # p_{j-1}(d_j)
            qd = jnp.take_along_axis(q, draft_toks[..., None],
                                     axis=-1)[..., 0]        # q_{j-1}(d_j)
            u = jax.random.uniform(ka, (b, k))
            acc = (u * qd < pd).astype(jnp.int32)            # u < p/q
            a = jnp.sum(jnp.cumprod(acc, axis=1), axis=1)
            q_ext = jnp.concatenate(
                [q, jnp.zeros_like(p[:, :1])], axis=1)       # bonus: q = 0
        else:
            a = jnp.zeros((b,), jnp.int32)
            q_ext = jnp.zeros_like(p)
        # first rejected (or bonus) slot: sample the residual max(p-q, 0)
        p_row = jnp.take_along_axis(p, a[:, None, None], axis=1)[:, 0]
        q_row = jnp.take_along_axis(q_ext, a[:, None, None], axis=1)[:, 0]
        r = jnp.maximum(p_row - q_row, 0.0)
        tot = jnp.sum(r, axis=-1, keepdims=True)
        r = jnp.where(tot > 0.0, r, p_row)    # p <= q pointwise: fall back
        correction = jax.random.categorical(
            kc, jnp.log(r), axis=-1).astype(jnp.int32)[:, None]

    n_emit = a + 1
    if k:
        ext_d = jnp.concatenate(
            [draft_toks, jnp.zeros((b, 1), jnp.int32)], axis=1)
        emitted = jnp.where(
            jnp.arange(k + 1, dtype=jnp.int32)[None, :] == a[:, None],
            correction, ext_d)
    else:
        emitted = correction

    new_pos = start + n_emit
    if cfg.sliding_window and "table" not in cache:
        # ring caches: rejected verify rows landed in slots whose old
        # content is still in-window for later steps — restore them from
        # the pre-round snapshot (the functional `cache` value)
        vcache = lm.rollback_ring_cache(cfg, cache, vcache, start, new_pos,
                                        k + 1)
    else:
        vcache = dict(vcache)
        vcache["pos"] = new_pos
    return emitted, n_emit, vcache


@_maybe_cached
def _speculative_decode_step_cached(cfg, rules, mode, draft_k, temperature,
                                    top_k, donate):
    def step(params, tok, cache, key):
        return _spec_round(params, cfg, tok, cache, key, draft_k=draft_k,
                           mode=mode, rules=rules, temperature=temperature,
                           top_k=top_k)
    return jax.jit(step, donate_argnums=(2,) if donate else ())


def make_speculative_decode_step(cfg: ModelConfig,
                                 rules: Optional[ShardingRules] = None,
                                 mode: str = "float", *, draft_k: int = 4,
                                 temperature: float = 0.0, top_k: int = 0,
                                 donate: bool = True):
    """(params, tok [B], cache, key) -> (emitted [B, k+1], n_emit [B],
    cache) — one speculative round as a single fused, cache-donating
    dispatch, the continuous-batching server's unit of work under
    ``--spec-decode``: the host pays one dispatch and retires up to
    ``draft_k + 1`` tokens per slot (variable per round, ``n_emit``)."""
    return _speculative_decode_step_cached(cfg, rules, mode, draft_k,
                                           temperature, top_k, donate)


@_maybe_cached
def _speculative_scan_cached(cfg, steps, draft_k, rules, mode, temperature,
                             top_k, donate):
    width = steps + draft_k + 1

    def gen(params, logits, cache, key):
        key, k0 = jax.random.split(key)
        tok0 = sample_tokens(logits[:, -1], k0, temperature=temperature,
                             top_k=top_k)
        b = tok0.shape[0]
        out = jnp.zeros((b, width), jnp.int32).at[:, 0].set(tok0)
        off = jnp.ones((b,), jnp.int32)

        def cond(carry):
            return jnp.min(carry[4]) < steps

        def body(carry):
            tok, cache, key, out, off = carry
            key, kr = jax.random.split(key)
            emitted, n_emit, cache = _spec_round(
                params, cfg, tok, cache, kr, draft_k=draft_k, mode=mode,
                rules=rules, temperature=temperature, top_k=top_k)
            idx = jnp.arange(draft_k + 1, dtype=jnp.int32)[None, :]
            col = jnp.where(idx < n_emit[:, None], off[:, None] + idx,
                            width)                   # rejected/past: drop
            out = out.at[jnp.arange(b)[:, None], col].set(emitted,
                                                          mode="drop")
            tok = jnp.take_along_axis(emitted, (n_emit - 1)[:, None],
                                      axis=1)[:, 0]
            return (tok, cache, key, out, off + n_emit)

        _, cache, _, out, _ = lax.while_loop(cond, body,
                                             (tok0, cache, key, out, off))
        return out[:, :steps], cache
    return jax.jit(gen, donate_argnums=(2,) if donate else ())


def make_speculative_scan(cfg: ModelConfig, *, steps: int, draft_k: int = 4,
                          rules: Optional[ShardingRules] = None,
                          mode: str = "float", temperature: float = 0.0,
                          top_k: int = 0, donate: bool = True):
    """One on-device program for a speculative generation tail.

    (params, logits [B,1,V], cache, key) -> (tokens [B, steps], cache):
    samples the first token from the prefill logits, then loops
    draft(k, packed1 rung) -> verify(k+1, one batched target launch) ->
    accept rounds in a ``lax.while_loop`` until every sequence holds
    ``steps`` tokens. Fixed shapes throughout: each round scatters its
    variable-length accepted prefix into the [B, steps + k + 1] output
    buffer (rejected slots route out of range and drop). The cache is
    donated and loop-carried; outputs match :func:`make_generate_scan`
    on the target rung exactly (bit-identical at temperature 0,
    distribution-identical above)."""
    return _speculative_scan_cached(cfg, steps, draft_k, rules, mode,
                                    temperature, top_k, donate)


def speculative_generate(params, cfg: ModelConfig, batch, *, steps: int,
                         max_seq: int, draft_k: int = 4,
                         mode: str = "float", temperature: float = 0.0,
                         top_k: int = 0, key=None,
                         rules: Optional[ShardingRules] = None,
                         return_cache: bool = False):
    """Device-resident speculative generation: prefill + one fused
    draft/verify/accept loop. Drop-in for :func:`generate_scan` — same
    [B, steps] output (bit-identical at temperature 0), fewer target-rung
    sequential steps when the packed1 drafts keep being accepted."""
    if cfg.family in ("ssm", "hybrid"):
        raise ValueError("speculative decoding needs a token-indexed KV "
                         "cache; SSM/hybrid state cannot rewind")
    b = jax.tree.leaves(batch)[0].shape[0]
    cache, _ = lm.init_cache(cfg, b, max_seq)
    prefill = make_prefill_step(cfg, rules, mode)
    gen = make_speculative_scan(cfg, steps=steps, draft_k=draft_k,
                                rules=rules, mode=mode,
                                temperature=temperature, top_k=top_k)
    logits, cache = prefill(params, batch, cache)
    key = _default_key(key, temperature, "speculative_generate")
    toks, cache = gen(params, logits, cache, key)
    return (toks, cache) if return_cache else toks


# -- PPAC serving conversion ---------------------------------------------------

_PPAC_ELIGIBLE = ("wq", "wk", "wv", "wo", "wi", "wg", "w_q", "w_uk", "w_uv",
                  "in_proj", "out_proj")

# Same-input projections fused into ONE resident container per layer (the
# grouped serving fast path): attention's q/k/v and the SwiGLU up/gate pair.
_PPAC_GROUPS = (("wqkv", ("wq", "wk", "wv")), ("wig", ("wi", "wg")))


def convert_params_for_serving(params, cfg: ModelConfig, *,
                               group: bool = True,
                               store_shadow: Optional[bool] = None,
                               draft: bool = False):
    """Replace large projection weights with resident PPAC containers.

    Only 2-D weight leaves under eligible projection names are converted
    (embeddings, norms, SSD internals stay float). Works on stacked
    (scan) params by vmapping the packer over the layer axis.

    With ``group`` (the default), same-input projection trios/pairs
    (wq/wk/wv -> ``wqkv``, wi/wg -> ``wig``) whose members are ALL
    individually eligible and bias-free are column-concatenated and packed
    as one grouped container (``splits`` records the member widths) —
    halving decode-step kernel launches while staying bit-identical to the
    per-projection containers (quantization scales are per output
    channel). ``group=False`` keeps the per-projection layout, e.g. for
    sharding-spec trees that must mirror the init-time param structure.
    ``store_shadow`` forwards to :func:`pack_weight_for_serving`.

    With ``draft`` each multi-bit container also carries a resident
    packed1 (binarized) rung of the SAME weight — the cheap end of the
    precision ladder — enabling self-speculative decoding
    (:func:`make_speculative_scan`) with zero extra conversions at serve
    time.
    """
    ppac = cfg.ppac
    if not ppac.enabled:
        return params

    pack = functools.partial(pack_weight_for_serving,
                             weight_bits=ppac.weight_bits,
                             weight_format=ppac.weight_format,
                             store_shadow=store_shadow, draft=draft)

    def eligible(leaf):
        ndim = getattr(leaf, "ndim", 0)
        if ndim == 2:
            return min(leaf.shape) >= ppac.min_features
        if ndim == 3:  # stacked over layers
            return min(leaf.shape[1:]) >= ppac.min_features
        return False

    def pack_leaf(leaf, splits=None):
        p = functools.partial(pack, splits=splits)
        return p(leaf) if leaf.ndim == 2 else jax.vmap(p)(leaf)

    def groupable(sub):
        """A bias-free {'w': float leaf} projection dict."""
        return (isinstance(sub, dict) and set(sub) == {"w"}
                and not isinstance(sub["w"], QuantContainer)
                and eligible(sub["w"]))

    def walk(node):
        if not isinstance(node, dict):
            return node
        out = {k: walk(v) for k, v in node.items()}
        if group:
            for gname, members in _PPAC_GROUPS:
                subs = [out.get(m) for m in members]
                if not all(groupable(s) for s in subs):
                    continue
                ws = [s["w"] for s in subs]
                if (len({w.ndim for w in ws}) != 1
                        or len({w.shape[:-1] for w in ws}) != 1):
                    continue  # mismatched in-dims / stacking: keep separate
                splits = tuple(int(w.shape[-1]) for w in ws)
                wcat = jnp.concatenate(ws, axis=-1)
                out[gname] = {"w": pack_leaf(wcat, splits=splits)}
                for m in members:
                    del out[m]
        for k, v in out.items():
            if (k in _PPAC_ELIGIBLE and isinstance(v, dict)
                    and not isinstance(v.get("w"), QuantContainer)
                    and eligible(v.get("w"))):
                out[k] = {**v, "w": pack_leaf(v["w"])}
        return out

    return walk(params)


# -- tile-plan autotuning ------------------------------------------------------

def autotune_serving_plans(params, cfg: ModelConfig, *, batch: int,
                           verbose: bool = False):
    """Measure-and-persist tile plans for every distinct packed projection
    shape of a converted model (refresh with a different decode batch by
    re-running; keyed on shape × platform in the plan cache).

    Only the 'pallas' lowering consults tile plans, so this is meaningful
    on TPU (off-TPU it still runs — interpret-mode timings — and exercises
    the cache plumbing). Returns {(mode, b, m, w): blocks}.
    """
    from ..core.formats import packed_width
    from ..kernels import tiling
    from ..kernels.bitserial_mvp.ops import ppac_matmul_resident

    flat, _ = jax.tree_util.tree_flatten(
        params, is_leaf=lambda x: isinstance(x, QuantContainer))
    shapes = {}
    for leaf in flat:
        if not isinstance(leaf, QuantContainer) \
                or leaf.kind not in ("packed1", "packed4"):
            continue
        base, d_out, d_in = _container_geometry(leaf)
        if leaf.kind == "packed1":
            k_bits, l_bits, fa, fx = 1, 1, "oddint", "oddint"
        else:
            k_bits, l_bits = leaf.bits, cfg.ppac.act_bits
            fa, fx = leaf.fmt, cfg.ppac.act_format
        has_mask = leaf.kind == "packed4" and \
            leaf.wq.shape[-3] == (leaf.bits or 0) + 1
        shapes[(d_out, d_in, k_bits, l_bits, fa, fx, has_mask)] = None

    tuned = {}
    for (d_out, d_in, k_bits, l_bits, fa, fx, has_mask) in shapes:
        w = packed_width(d_in)
        key = ("bitserial_sliced", batch, d_out, w)
        if key in tuned:
            continue
        x = jnp.zeros((batch, d_in), jnp.int32)
        planes = jnp.zeros((k_bits + has_mask, d_out, w), jnp.uint32)

        def run(plan, x=x, planes=planes, n=d_in, k=k_bits, l=l_bits,
                fa=fa, fx=fx, hm=has_mask):
            return ppac_matmul_resident(
                x, planes, n=n, k_bits=k, l_bits=l, fmt_a=fa, fmt_x=fx,
                a_has_mask=hm, backend="pallas", **plan.blocks)

        plan = tiling.autotune_plan(
            "bitserial_sliced", batch, d_out, w, run,
            candidates=tiling.quick_candidates(batch, d_out, w), reps=2)
        tuned[key] = plan.blocks
        if verbose:
            print(f"autotuned bitserial_sliced b={batch} m={d_out} w={w} "
                  f"-> {plan.blocks}")
    return tuned


# -- PPAC cycle accounting -----------------------------------------------------

def _container_geometry(c: QuantContainer):
    """(base_ndim, d_out, d_in) of one (possibly layer-stacked) container."""
    wq = c.wq
    if c.kind == "packed1":
        base, d_out = 2, wq.shape[-2]
        d_in = c.n_in or wq.shape[-1] * 32
    elif c.kind == "packed4":
        base, d_out = 3, wq.shape[-2]
        d_in = c.n_in or wq.shape[-1] * 32
    else:  # int8 / bf16: [in, out] rows
        base, d_out = 2, wq.shape[-1]
        d_in = c.n_in or wq.shape[-2]
    return base, d_out, d_in


def serving_cycle_report(params, cfg: ModelConfig, *,
                         config: Optional[PPACConfig] = None,
                         parallel_arrays: Optional[int] = None
                         ) -> ServingCycleReport:
    """Per-token PPAC cycle accounting over every quantized projection.

    Each K-bit container costs K·L tile-grid cycles per streamed token
    (packed1: K=L=1, one XNOR pass), aggregated across (possibly
    layer-stacked) projections — a full LM decode step priced in the
    paper's §III-C accounting. Grouped containers (wqkv/wig) are priced
    at their *fused* [sum(out), in] shape — one virtualized tile-grid
    scan for the whole group, which is exactly what the fast path
    launches (and ≤ the per-projection sum, since row tiles amortize
    across members). int8 containers run on the MXU fallback, not the
    fused kernels; they are reported with ``fused=False`` at their
    would-be K=8 bit-serial cost. bf16 containers are not PPAC-executable
    and are skipped.

    The accounting is a *ledger replay*: each projection synthesizes the
    exact LaunchRecord (``obs.ledger.record_for``, batch=1) that one
    streamed token emits through the instrumented dispatch chokepoint, so
    this static estimate and a recorded flight ledger share one costing
    function and cannot diverge (tests/test_obs.py asserts bit-exact
    agreement across every container kind).
    """
    hw = config or PPACConfig()
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, QuantContainer))
    entries = []
    for path, leaf in flat:
        if not isinstance(leaf, QuantContainer) or leaf.kind == "bf16":
            continue
        name = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                        for p in path)
        base, d_out, d_in = _container_geometry(leaf)
        if leaf.kind == "packed1":
            k_bits, l_bits = 1, 1
        else:
            k_bits = leaf.bits or 8
            l_bits = cfg.ppac.act_bits
        count = (int(np.prod(leaf.wq.shape[: leaf.wq.ndim - base]))
                 if leaf.wq.ndim > base else 1)
        mode = ("mvp_int8_mxu" if leaf.kind == "int8"
                else "mvp_multibit_resident")
        rec = _flight.record_for(
            mode, "replay", batch=1, m_rows=d_out, n_bits=d_in,
            k_bits=k_bits, l_bits=l_bits, config=hw,
            parallel_arrays=parallel_arrays)
        entries.append(ProjectionCost(
            name=name, kind=leaf.kind, d_in=d_in, d_out=d_out,
            k_bits=k_bits, l_bits=l_bits, count=count,
            cycles=count * rec.cycles,
            fused=leaf.kind in ("packed1", "packed4"),
            energy_nj=count * rec.energy_nj))
    return ServingCycleReport(projections=tuple(entries), config=hw)
