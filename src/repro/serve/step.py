"""Serving steps: donated prefill/decode/generation + PPAC weight conversion.

Generation is *device-resident*: every jitted entry point donates the KV
cache pytree (``donate_argnums``), so per-step cache writes lower to
in-place ``dynamic_update_slice``/scatter instead of whole-cache copies —
the data-movement tax the paper's weight-stationary premise (§III) exists
to avoid, and exactly the invariant tests/test_generate.py asserts on the
lowered HLO (every cache leaf carries an aliasing attribute). On top of
the per-step path, :func:`generate_scan` fuses N decode steps *and* the
sampling (greedy / temperature / top-k) into one ``lax.scan`` program —
one dispatch for the whole generation instead of one per token.

``convert_params_for_serving`` is the PPAC load path: projection weights
become resident quantized containers (int8 / packed4 / packed1), exactly
the paper's weight-stationary premise — the decode memory-roofline lever
measured in EXPERIMENTS.md §Perf. ``serving_cycle_report`` prices the
converted model in emulated PPAC cycles per decoded token (the §III-C
K·L accounting aggregated over every projection of a step).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..configs.base import ModelConfig
from ..core.cost_model import ProjectionCost, ServingCycleReport
from ..core.engine import QuantContainer, pack_weight_for_serving
from ..core.ppac import PPACConfig
from ..obs import ledger as _flight
from ..models import lm
from ..sharding.rules import ShardingRules


def _maybe_cached(factory):
    """lru-cache a jitted-entry-point factory on its hashable args.

    jax.jit caches traces by function identity: a fresh wrapper per call
    would retrace (and recompile) every generation. ModelConfig is a
    frozen dataclass, so (cfg, mode, ...) keys are hashable; unhashable
    ``rules`` objects fall through to an uncached build (sharded callers
    hold on to the returned function themselves)."""
    cached = functools.lru_cache(maxsize=128)(factory)

    @functools.wraps(factory)
    def build(*args):
        try:
            return cached(*args)
        except TypeError:  # unhashable arg (e.g. ShardingRules)
            return factory(*args)
    return build


@_maybe_cached
def _prefill_step_cached(cfg, rules, mode, donate):
    def prefill_step(params, batch, cache, lengths=None):
        return lm.prefill(params, cfg, batch, cache, lengths=lengths,
                          mode=mode, rules=rules)
    return jax.jit(prefill_step, donate_argnums=(2,) if donate else ())


def make_prefill_step(cfg: ModelConfig, rules: Optional[ShardingRules] = None,
                      mode: str = "float", *, jit: bool = True,
                      donate: bool = True):
    """(params, batch, cache, lengths=None) -> (logits, cache).

    Jitted with the cache donated by default: prefill writes the whole
    prompt into a zero cache, so the input buffers are dead on return.
    ``jit=False`` returns the raw function (the dry-run wraps it in its
    own sharded jit)."""
    if not jit:
        def prefill_step(params, batch, cache, lengths=None):
            return lm.prefill(params, cfg, batch, cache, lengths=lengths,
                              mode=mode, rules=rules)
        return prefill_step
    return _prefill_step_cached(cfg, rules, mode, donate)


@_maybe_cached
def _decode_step_cached(cfg, rules, mode, donate):
    def decode_step(params, tokens, cache):
        return lm.decode_step(params, cfg, tokens, cache, mode=mode,
                              rules=rules)
    return jax.jit(decode_step, donate_argnums=(2,) if donate else ())


def make_decode_step(cfg: ModelConfig, rules: Optional[ShardingRules] = None,
                     mode: str = "float", *, jit: bool = True,
                     donate: bool = True):
    """(params, tokens, cache) -> (logits, cache), cache donated.

    Donation is what makes the per-layer cache update an in-place
    scatter: without it XLA must copy every [B,T,H,D] cache leaf per
    layer per token to preserve the (dead) input buffers."""
    if not jit:
        def decode_step(params, tokens, cache):
            return lm.decode_step(params, cfg, tokens, cache, mode=mode,
                                  rules=rules)
        return decode_step
    return _decode_step_cached(cfg, rules, mode, donate)


# -- fused sampling ------------------------------------------------------------

def sample_tokens(logits, key, *, temperature: float = 0.0, top_k: int = 0):
    """logits [B,V] -> tokens [B] int32, on device.

    temperature == 0 -> greedy argmax (key unused); otherwise softmax
    sampling at ``temperature``, optionally restricted to the ``top_k``
    highest-scoring tokens. Static python knobs: each setting is its own
    compiled program, fused into the decode step / scan body."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    if top_k:
        kth = lax.top_k(scaled, top_k)[0][..., -1:]
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


@_maybe_cached
def _decode_select_cached(cfg, rules, mode, temperature, top_k, donate):
    def step(params, tokens, cache, key):
        logits, cache = lm.decode_step(params, cfg, tokens, cache,
                                       mode=mode, rules=rules)
        nxt = sample_tokens(logits[:, -1], key, temperature=temperature,
                            top_k=top_k)
        return nxt, cache
    return jax.jit(step, donate_argnums=(2,) if donate else ())


def make_decode_select_step(cfg: ModelConfig,
                            rules: Optional[ShardingRules] = None,
                            mode: str = "float", *,
                            temperature: float = 0.0, top_k: int = 0,
                            donate: bool = True):
    """(params, tokens [B,1], cache, key) -> (next [B] int32, cache).

    One fused, cache-donating dispatch per token: decode + token
    selection stay on device — the host never sees logits, only the [B]
    token ids it actually needs (EOS/retirement decisions)."""
    return _decode_select_cached(cfg, rules, mode, temperature, top_k,
                                 donate)


@_maybe_cached
def _prefill_select_cached(cfg, rules, mode, temperature, top_k, paged,
                           history, donate):
    if not paged:
        def step(params, tokens, lengths, cache, key):
            logits, cache = lm.prefill(params, cfg, {"tokens": tokens},
                                       cache, lengths=lengths, mode=mode,
                                       rules=rules)
            tok = sample_tokens(logits[:, -1], key, temperature=temperature,
                                top_k=top_k)
            return tok, cache
        return jax.jit(step, donate_argnums=(3,) if donate else ())

    def step(params, tokens, lengths, starts, slot_ids, table_rows, cache,
             key):
        logits, cache = lm.prefill(
            params, cfg, {"tokens": tokens}, cache, lengths=lengths,
            mode=mode, rules=rules, start=starts if history else None,
            history=history, table=table_rows, slot_ids=slot_ids)
        tok = sample_tokens(logits[:, -1], key, temperature=temperature,
                            top_k=top_k)
        return tok, cache
    return jax.jit(step, donate_argnums=(6,) if donate else ())


def make_prefill_select_step(cfg: ModelConfig,
                             rules: Optional[ShardingRules] = None,
                             mode: str = "float", *,
                             temperature: float = 0.0, top_k: int = 0,
                             paged: bool = False, history: bool = False,
                             donate: bool = True):
    """Fused prefill + first-token selection, cache donated.

    Contiguous (``paged=False``):
        (params, tokens, lengths, cache, key) -> (tok0 [B], cache)
    prefills a scratch cache whose rows the server copies into resident
    slots.

    Paged (``paged=True``): the cache IS the resident pool pytree —
        (params, tokens, lengths, starts, slot_ids, table_rows, cache,
         key) -> (tok0 [B], cache)
    writes the admitted group's KV straight through ``table_rows``
    [B, n_pages] into the shared pools (no scratch cache, no copy) and
    scatters end positions at ``slot_ids``. ``history=True`` compiles
    the suffix variant for prefix-cache hits: ``tokens`` hold only the
    un-cached suffix and ``starts`` its absolute offsets."""
    return _prefill_select_cached(cfg, rules, mode, temperature, top_k,
                                  paged, history, donate)


def greedy_generate(params, cfg: ModelConfig, batch, *, steps: int,
                    max_seq: int, mode: str = "float"):
    """Reference per-step generation loop (prefill + greedy decode).

    Legacy path kept as the scan baseline: still one jitted dispatch per
    token, but token selection is fused into the decode step and the
    cache is donated — nothing round-trips to the host between steps
    (the [B, steps] token matrix transfers once, at the end)."""
    b = jax.tree.leaves(batch)[0].shape[0]
    cache, _ = lm.init_cache(cfg, b, max_seq)
    prefill = make_prefill_step(cfg, mode=mode)
    decode = make_decode_select_step(cfg, mode=mode)
    key = jax.random.PRNGKey(0)  # greedy: unused, fixed shape
    logits, cache = prefill(params, batch, cache)
    tok = sample_tokens(logits[:, -1], key)
    out = []
    for _ in range(steps):
        out.append(tok)
        tok, cache = decode(params, tok[:, None], cache, key)
    return jnp.stack(out, axis=1)


@_maybe_cached
def _generate_scan_cached(cfg, steps, rules, mode, temperature, top_k,
                          donate):

    def gen(params, logits, cache, key):
        key, k0 = jax.random.split(key)
        tok0 = sample_tokens(logits[:, -1], k0, temperature=temperature,
                             top_k=top_k)

        def body(carry, _):
            tok, cache, key = carry
            logits, cache = lm.decode_step(params, cfg, tok[:, None], cache,
                                           mode=mode, rules=rules)
            key, ks = jax.random.split(key)
            nxt = sample_tokens(logits[:, -1], ks, temperature=temperature,
                                top_k=top_k)
            return (nxt, cache, key), tok

        (last, cache, _), toks = lax.scan(body, (tok0, cache, key), None,
                                          length=steps)
        return jnp.moveaxis(toks, 0, 1), cache
    return jax.jit(gen, donate_argnums=(2,) if donate else ())


def make_generate_scan(cfg: ModelConfig, *, steps: int,
                       rules: Optional[ShardingRules] = None,
                       mode: str = "float", temperature: float = 0.0,
                       top_k: int = 0, donate: bool = True):
    """One on-device program for the whole generation tail.

    (params, logits [B,1,V], cache, key) -> (tokens [B, steps], cache):
    samples the first token from the prefill logits, then runs ``steps``
    decode steps inside a single ``lax.scan`` with sampling fused in.
    The cache is donated and scan-carried, so every per-layer cache
    update is an in-place write — no cache-sized copy anywhere in the
    program — and the host pays one dispatch for N tokens."""
    return _generate_scan_cached(cfg, steps, rules, mode, temperature,
                                 top_k, donate)


def generate_scan(params, cfg: ModelConfig, batch, *, steps: int,
                  max_seq: int, mode: str = "float",
                  temperature: float = 0.0, top_k: int = 0, key=None,
                  rules: Optional[ShardingRules] = None,
                  return_cache: bool = False):
    """Device-resident generation: prefill + one fused N-step scan.

    Semantics match :func:`greedy_generate` at temperature 0 (token i is
    sampled from the logits *before* decode step i), with temperature /
    top-k sampling available via the fused sampler. Returns [B, steps]
    int32 tokens (and the final cache with ``return_cache``)."""
    b = jax.tree.leaves(batch)[0].shape[0]
    cache, _ = lm.init_cache(cfg, b, max_seq)
    prefill = make_prefill_step(cfg, rules, mode)
    gen = make_generate_scan(cfg, steps=steps, rules=rules, mode=mode,
                             temperature=temperature, top_k=top_k)
    logits, cache = prefill(params, batch, cache)
    key = jax.random.PRNGKey(0) if key is None else key
    toks, cache = gen(params, logits, cache, key)
    return (toks, cache) if return_cache else toks


# -- PPAC serving conversion ---------------------------------------------------

_PPAC_ELIGIBLE = ("wq", "wk", "wv", "wo", "wi", "wg", "w_q", "w_uk", "w_uv",
                  "in_proj", "out_proj")

# Same-input projections fused into ONE resident container per layer (the
# grouped serving fast path): attention's q/k/v and the SwiGLU up/gate pair.
_PPAC_GROUPS = (("wqkv", ("wq", "wk", "wv")), ("wig", ("wi", "wg")))


def convert_params_for_serving(params, cfg: ModelConfig, *,
                               group: bool = True,
                               store_shadow: Optional[bool] = None):
    """Replace large projection weights with resident PPAC containers.

    Only 2-D weight leaves under eligible projection names are converted
    (embeddings, norms, SSD internals stay float). Works on stacked
    (scan) params by vmapping the packer over the layer axis.

    With ``group`` (the default), same-input projection trios/pairs
    (wq/wk/wv -> ``wqkv``, wi/wg -> ``wig``) whose members are ALL
    individually eligible and bias-free are column-concatenated and packed
    as one grouped container (``splits`` records the member widths) —
    halving decode-step kernel launches while staying bit-identical to the
    per-projection containers (quantization scales are per output
    channel). ``group=False`` keeps the per-projection layout, e.g. for
    sharding-spec trees that must mirror the init-time param structure.
    ``store_shadow`` forwards to :func:`pack_weight_for_serving`.
    """
    ppac = cfg.ppac
    if not ppac.enabled:
        return params

    pack = functools.partial(pack_weight_for_serving,
                             weight_bits=ppac.weight_bits,
                             weight_format=ppac.weight_format,
                             store_shadow=store_shadow)

    def eligible(leaf):
        ndim = getattr(leaf, "ndim", 0)
        if ndim == 2:
            return min(leaf.shape) >= ppac.min_features
        if ndim == 3:  # stacked over layers
            return min(leaf.shape[1:]) >= ppac.min_features
        return False

    def pack_leaf(leaf, splits=None):
        p = functools.partial(pack, splits=splits)
        return p(leaf) if leaf.ndim == 2 else jax.vmap(p)(leaf)

    def groupable(sub):
        """A bias-free {'w': float leaf} projection dict."""
        return (isinstance(sub, dict) and set(sub) == {"w"}
                and not isinstance(sub["w"], QuantContainer)
                and eligible(sub["w"]))

    def walk(node):
        if not isinstance(node, dict):
            return node
        out = {k: walk(v) for k, v in node.items()}
        if group:
            for gname, members in _PPAC_GROUPS:
                subs = [out.get(m) for m in members]
                if not all(groupable(s) for s in subs):
                    continue
                ws = [s["w"] for s in subs]
                if (len({w.ndim for w in ws}) != 1
                        or len({w.shape[:-1] for w in ws}) != 1):
                    continue  # mismatched in-dims / stacking: keep separate
                splits = tuple(int(w.shape[-1]) for w in ws)
                wcat = jnp.concatenate(ws, axis=-1)
                out[gname] = {"w": pack_leaf(wcat, splits=splits)}
                for m in members:
                    del out[m]
        for k, v in out.items():
            if (k in _PPAC_ELIGIBLE and isinstance(v, dict)
                    and not isinstance(v.get("w"), QuantContainer)
                    and eligible(v.get("w"))):
                out[k] = {**v, "w": pack_leaf(v["w"])}
        return out

    return walk(params)


# -- tile-plan autotuning ------------------------------------------------------

def autotune_serving_plans(params, cfg: ModelConfig, *, batch: int,
                           verbose: bool = False):
    """Measure-and-persist tile plans for every distinct packed projection
    shape of a converted model (refresh with a different decode batch by
    re-running; keyed on shape × platform in the plan cache).

    Only the 'pallas' lowering consults tile plans, so this is meaningful
    on TPU (off-TPU it still runs — interpret-mode timings — and exercises
    the cache plumbing). Returns {(mode, b, m, w): blocks}.
    """
    from ..core.formats import packed_width
    from ..kernels import tiling
    from ..kernels.bitserial_mvp.ops import ppac_matmul_resident

    flat, _ = jax.tree_util.tree_flatten(
        params, is_leaf=lambda x: isinstance(x, QuantContainer))
    shapes = {}
    for leaf in flat:
        if not isinstance(leaf, QuantContainer) \
                or leaf.kind not in ("packed1", "packed4"):
            continue
        base, d_out, d_in = _container_geometry(leaf)
        if leaf.kind == "packed1":
            k_bits, l_bits, fa, fx = 1, 1, "oddint", "oddint"
        else:
            k_bits, l_bits = leaf.bits, cfg.ppac.act_bits
            fa, fx = leaf.fmt, cfg.ppac.act_format
        has_mask = leaf.kind == "packed4" and \
            leaf.wq.shape[-3] == (leaf.bits or 0) + 1
        shapes[(d_out, d_in, k_bits, l_bits, fa, fx, has_mask)] = None

    tuned = {}
    for (d_out, d_in, k_bits, l_bits, fa, fx, has_mask) in shapes:
        w = packed_width(d_in)
        key = ("bitserial_sliced", batch, d_out, w)
        if key in tuned:
            continue
        x = jnp.zeros((batch, d_in), jnp.int32)
        planes = jnp.zeros((k_bits + has_mask, d_out, w), jnp.uint32)

        def run(plan, x=x, planes=planes, n=d_in, k=k_bits, l=l_bits,
                fa=fa, fx=fx, hm=has_mask):
            return ppac_matmul_resident(
                x, planes, n=n, k_bits=k, l_bits=l, fmt_a=fa, fmt_x=fx,
                a_has_mask=hm, backend="pallas", **plan.blocks)

        plan = tiling.autotune_plan(
            "bitserial_sliced", batch, d_out, w, run,
            candidates=tiling.quick_candidates(batch, d_out, w), reps=2)
        tuned[key] = plan.blocks
        if verbose:
            print(f"autotuned bitserial_sliced b={batch} m={d_out} w={w} "
                  f"-> {plan.blocks}")
    return tuned


# -- PPAC cycle accounting -----------------------------------------------------

def _container_geometry(c: QuantContainer):
    """(base_ndim, d_out, d_in) of one (possibly layer-stacked) container."""
    wq = c.wq
    if c.kind == "packed1":
        base, d_out = 2, wq.shape[-2]
        d_in = c.n_in or wq.shape[-1] * 32
    elif c.kind == "packed4":
        base, d_out = 3, wq.shape[-2]
        d_in = c.n_in or wq.shape[-1] * 32
    else:  # int8 / bf16: [in, out] rows
        base, d_out = 2, wq.shape[-1]
        d_in = c.n_in or wq.shape[-2]
    return base, d_out, d_in


def serving_cycle_report(params, cfg: ModelConfig, *,
                         config: Optional[PPACConfig] = None,
                         parallel_arrays: Optional[int] = None
                         ) -> ServingCycleReport:
    """Per-token PPAC cycle accounting over every quantized projection.

    Each K-bit container costs K·L tile-grid cycles per streamed token
    (packed1: K=L=1, one XNOR pass), aggregated across (possibly
    layer-stacked) projections — a full LM decode step priced in the
    paper's §III-C accounting. Grouped containers (wqkv/wig) are priced
    at their *fused* [sum(out), in] shape — one virtualized tile-grid
    scan for the whole group, which is exactly what the fast path
    launches (and ≤ the per-projection sum, since row tiles amortize
    across members). int8 containers run on the MXU fallback, not the
    fused kernels; they are reported with ``fused=False`` at their
    would-be K=8 bit-serial cost. bf16 containers are not PPAC-executable
    and are skipped.

    The accounting is a *ledger replay*: each projection synthesizes the
    exact LaunchRecord (``obs.ledger.record_for``, batch=1) that one
    streamed token emits through the instrumented dispatch chokepoint, so
    this static estimate and a recorded flight ledger share one costing
    function and cannot diverge (tests/test_obs.py asserts bit-exact
    agreement across every container kind).
    """
    hw = config or PPACConfig()
    flat, _ = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda x: isinstance(x, QuantContainer))
    entries = []
    for path, leaf in flat:
        if not isinstance(leaf, QuantContainer) or leaf.kind == "bf16":
            continue
        name = "/".join(str(getattr(p, "key", getattr(p, "name", p)))
                        for p in path)
        base, d_out, d_in = _container_geometry(leaf)
        if leaf.kind == "packed1":
            k_bits, l_bits = 1, 1
        else:
            k_bits = leaf.bits or 8
            l_bits = cfg.ppac.act_bits
        count = (int(np.prod(leaf.wq.shape[: leaf.wq.ndim - base]))
                 if leaf.wq.ndim > base else 1)
        mode = ("mvp_int8_mxu" if leaf.kind == "int8"
                else "mvp_multibit_resident")
        rec = _flight.record_for(
            mode, "replay", batch=1, m_rows=d_out, n_bits=d_in,
            k_bits=k_bits, l_bits=l_bits, config=hw,
            parallel_arrays=parallel_arrays)
        entries.append(ProjectionCost(
            name=name, kind=leaf.kind, d_in=d_in, d_out=d_out,
            k_bits=k_bits, l_bits=l_bits, count=count,
            cycles=count * rec.cycles,
            fused=leaf.kind in ("packed1", "packed4"),
            energy_nj=count * rec.energy_nj))
    return ServingCycleReport(projections=tuple(entries), config=hw)
