"""Serving steps: prefill / decode wrappers + PPAC weight conversion.

``convert_params_for_serving`` is the PPAC load path: projection weights
become resident quantized containers (int8 / packed4 / packed1), exactly
the paper's weight-stationary premise — the decode memory-roofline lever
measured in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core.engine import pack_weight_for_serving
from ..models import lm
from ..sharding.rules import ShardingRules


def make_prefill_step(cfg: ModelConfig, rules: Optional[ShardingRules] = None,
                      mode: str = "float"):
    def prefill_step(params, batch, cache):
        return lm.prefill(params, cfg, batch, cache, mode=mode, rules=rules)
    return prefill_step


def make_decode_step(cfg: ModelConfig, rules: Optional[ShardingRules] = None,
                     mode: str = "float"):
    def decode_step(params, tokens, cache):
        return lm.decode_step(params, cfg, tokens, cache, mode=mode,
                              rules=rules)
    return decode_step


def greedy_generate(params, cfg: ModelConfig, batch, *, steps: int,
                    max_seq: int, mode: str = "float"):
    """Reference generation loop (prefill + greedy decode), jit per step."""
    b = jax.tree.leaves(batch)[0].shape[0]
    cache, _ = lm.init_cache(cfg, b, max_seq)
    prefill = jax.jit(make_prefill_step(cfg, mode=mode))
    decode = jax.jit(make_decode_step(cfg, mode=mode))
    logits, cache = prefill(params, batch, cache)
    out = []
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    for _ in range(steps):
        out.append(tok)
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    return jnp.concatenate(out, axis=1)


# -- PPAC serving conversion ---------------------------------------------------

_PPAC_ELIGIBLE = ("wq", "wk", "wv", "wo", "wi", "wg", "w_q", "w_uk", "w_uv",
                  "in_proj", "out_proj")


def convert_params_for_serving(params, cfg: ModelConfig):
    """Replace large projection weights with resident PPAC containers.

    Only 2-D weight leaves under eligible projection names are converted
    (embeddings, norms, SSD internals stay float). Works on stacked
    (scan) params by vmapping the packer over the layer axis.
    """
    ppac = cfg.ppac
    if not ppac.enabled:
        return params

    pack = functools.partial(pack_weight_for_serving,
                             weight_bits=ppac.weight_bits,
                             weight_format=ppac.weight_format)

    def convert(path, leaf):
        names = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        if "w" not in names[-1:]:
            return leaf
        parent = names[-2] if len(names) > 1 else ""
        if parent not in _PPAC_ELIGIBLE:
            return leaf
        if leaf.ndim == 2:
            if min(leaf.shape) < ppac.min_features:
                return leaf
            return pack(leaf)
        if leaf.ndim == 3:  # stacked over layers
            if min(leaf.shape[1:]) < ppac.min_features:
                return leaf
            return jax.vmap(pack)(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(convert, params)
