"""Pallas TPU kernel: fused Hamming top-k over a streamed packed-bit matrix.

The associative-retrieval primitive of the paper's §III-A CAM mode at
scale: for packed uint32 queries x [B, W] against a resident database
a [M, W] (W lanes of 32 bit-cells each), return the k most similar rows
per query

    h[b, m] = n - popcount(x[b] ^ a[m])        (Hamming similarity)

*without ever materializing the [B, M] score matrix*. The grid streams the
database in [tm] row tiles (grid dim 1, innermost); the running per-query
top-k (scores + global row indices) lives in the revisited output block in
VMEM and is merged with each tile's scores as they are produced — the TPU
analogue of the PPAC array computing M similarities per cycle while a
peripheral priority encoder drains the k winners.

Tie handling is bit-exact against ``lax.top_k`` on the full score matrix:
selection order is (score descending, global index ascending). The merge
extracts the k best of [running ∪ tile] by k rounds of (max score, then
min index among the argmaxes) — exactly that ordering.

Row validity (deletes / padding) comes in as a [1, M] int32 mask; invalid
rows score ``MASKED_SCORE`` (-1), below any real similarity, and keep
index-ascending order among themselves, matching ref.py.

A second kernel fuses the threshold (CAM δ) match: it emits the per-tile
match lines y[b, m] = (h >= δ) directly — the match matrix *is* the CAM
output (one match wire per row in hardware), so it is written tile-by-tile
with no score matrix either.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from ..tiling import round_up as _round_up
from ..tiling import subrow_popcount_sum
from .ref import MASKED_SCORE

_NEG_INIT = -(2**30)       # running-slot init: below every candidate score
_NEG_TAKEN = jnp.iinfo(jnp.int32).min  # extracted candidates never re-win
_IDX_SENTINEL = 2**30      # index init / argmin mask: above every row index


def _tile_scores(x, a, valid, *, n: int, row_chunk: int):
    """Masked similarity scores [tb, tm] of one database tile.

    Chunks the tile's row dimension via the shared
    :func:`repro.kernels.tiling.subrow_popcount_sum` (the subrow
    partitioning of Fig. 2, as in binary_mvp).
    """
    s = subrow_popcount_sum(x, a, bit_op=jnp.bitwise_xor,
                            row_chunk=row_chunk)
    h = n - s
    return jnp.where(valid > 0, h, MASKED_SCORE)


def _merge_topk(run_s, run_i, tile_s, tile_i, *, k: int):
    """k best of [running ∪ tile] by (score desc, index asc) — exact."""
    tb = run_s.shape[0]
    cand_s = jnp.concatenate([run_s, tile_s], axis=1)
    cand_i = jnp.concatenate([run_i, tile_i], axis=1)

    def select(i, carry):
        cs, ci, outs, outi = carry
        best = jnp.max(cs, axis=1, keepdims=True)                   # [tb, 1]
        at_best = cs == best
        bidx = jnp.min(jnp.where(at_best, ci, _IDX_SENTINEL),
                       axis=1, keepdims=True)                       # [tb, 1]
        outs = lax.dynamic_update_slice_in_dim(outs, best, i, axis=1)
        outi = lax.dynamic_update_slice_in_dim(outi, bidx, i, axis=1)
        taken = at_best & (ci == bidx)
        return jnp.where(taken, _NEG_TAKEN, cs), ci, outs, outi

    _, _, outs, outi = lax.fori_loop(
        0, k, select,
        (cand_s, cand_i,
         jnp.zeros((tb, k), jnp.int32), jnp.zeros((tb, k), jnp.int32)))
    return outs, outi


def _hamming_topk_kernel(x_ref, a_ref, valid_ref, os_ref, oi_ref, *,
                         n: int, k: int, row_chunk: int):
    """x_ref [tb, tw] u32; a_ref [tm, tw] u32; valid_ref [1, tm] i32;
    os_ref/oi_ref [tb, k] i32 — the running top-k, revisited over grid dim 1.
    """
    tb = x_ref.shape[0]
    tm = a_ref.shape[0]
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        os_ref[...] = jnp.full_like(os_ref, _NEG_INIT)
        oi_ref[...] = jnp.full_like(oi_ref, _IDX_SENTINEL)

    tile_s = _tile_scores(x_ref[...], a_ref[...], valid_ref[...],
                          n=n, row_chunk=row_chunk)
    tile_i = j * tm + lax.broadcasted_iota(jnp.int32, (tb, tm), 1)
    outs, outi = _merge_topk(os_ref[...], oi_ref[...], tile_s, tile_i, k=k)
    os_ref[...] = outs
    oi_ref[...] = outi


def _hamming_threshold_kernel(x_ref, a_ref, valid_ref, o_ref, *,
                              n: int, delta: int, row_chunk: int):
    """o_ref [tb, tm] i32: CAM match lines (h >= δ) for live rows."""
    tile_s = _tile_scores(x_ref[...], a_ref[...], valid_ref[...],
                          n=n, row_chunk=row_chunk)
    o_ref[...] = (tile_s >= delta).astype(jnp.int32)


def _pad_operands(x_packed, a_packed, valid, bb, bm):
    b, w = x_packed.shape
    m, w2 = a_packed.shape
    assert w == w2, (w, w2)
    bp, mp = _round_up(b, bb), _round_up(m, bm)
    wp = _round_up(max(w, 1), 128)
    x_p = jnp.pad(x_packed.astype(jnp.uint32), ((0, bp - b), (0, wp - w)))
    a_p = jnp.pad(a_packed.astype(jnp.uint32), ((0, mp - m), (0, wp - w)))
    if valid is None:
        valid = jnp.ones((m,), jnp.int32)
    v_p = jnp.pad(jnp.asarray(valid, jnp.int32)[None, :], ((0, 0), (0, mp - m)))
    return x_p, a_p, v_p, bp, mp, wp


@functools.partial(
    jax.jit,
    static_argnames=("n", "k", "block_q", "block_m", "row_chunk", "interpret"),
)
def hamming_topk_packed(
    x_packed,
    a_packed,
    valid=None,
    *,
    n: int,
    k: int,
    block_q: int = 8,
    block_m: int = 256,
    row_chunk: int = 8,
    interpret: bool = False,
):
    """Fused top-k: (scores [B, k], indices [B, k]) int32.

    x_packed [B, W] uint32, a_packed [M, W] uint32, valid [M] (int/bool,
    optional). Requires k <= M. Padding lanes must be zero (xor of equal
    zeros adds 0 to the popcount, so they never change h).
    """
    b, _ = x_packed.shape
    m = a_packed.shape[0]
    assert 1 <= k <= m, (k, m)

    bb = min(block_q, _round_up(b, 8))
    bm = min(block_m, _round_up(m, 8))
    bm = max(bm, _round_up(k, 8))  # a single tile must hold k candidates
    rc = min(row_chunk, bm)
    while bm % rc:
        rc -= 1

    x_p, a_p, v_p, bp, mp, _ = _pad_operands(x_packed, a_packed, valid, bb, bm)
    grid = (bp // bb, mp // bm)
    scores, idx = pl.pallas_call(
        functools.partial(_hamming_topk_kernel, n=n, k=k, row_chunk=rc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, x_p.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, a_p.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),
        ],
        out_specs=(
            pl.BlockSpec((bb, k), lambda i, j: (i, 0)),
            pl.BlockSpec((bb, k), lambda i, j: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bp, k), jnp.int32),
            jax.ShapeDtypeStruct((bp, k), jnp.int32),
        ),
        interpret=interpret,
    )(x_p, a_p, v_p)
    return scores[:b], idx[:b]


@functools.partial(
    jax.jit,
    static_argnames=("n", "delta", "block_q", "block_m", "row_chunk",
                     "interpret"),
)
def hamming_threshold_packed(
    x_packed,
    a_packed,
    valid=None,
    *,
    n: int,
    delta: int,
    block_q: int = 8,
    block_m: int = 256,
    row_chunk: int = 8,
    interpret: bool = False,
):
    """Fused CAM δ-match: match lines [B, M] int32 (1 iff h >= δ, row live)."""
    b, _ = x_packed.shape
    m = a_packed.shape[0]

    bb = min(block_q, _round_up(b, 8))
    bm = min(block_m, _round_up(m, 8))
    rc = min(row_chunk, bm)
    while bm % rc:
        rc -= 1

    x_p, a_p, v_p, bp, mp, _ = _pad_operands(x_packed, a_packed, valid, bb, bm)
    grid = (bp // bb, mp // bm)
    out = pl.pallas_call(
        functools.partial(_hamming_threshold_kernel, n=n, delta=delta,
                          row_chunk=rc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, x_p.shape[1]), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, a_p.shape[1]), lambda i, j: (j, 0)),
            pl.BlockSpec((1, bm), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bb, bm), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, mp), jnp.int32),
        interpret=interpret,
    )(x_p, a_p, v_p)
    return out[:b, :m]
