"""Brute-force oracles for the fused Hamming top-k / threshold-match kernels.

These materialize the full [B, M] score matrix (exactly what the fused
kernel avoids) and are the bit-exact ground truth, including tie handling:
``lax.top_k`` orders by (score descending, index ascending), and the fused
kernels reproduce that ordering exactly.

Masked (invalid) rows score ``MASKED_SCORE`` (= -1), strictly below every
real Hamming similarity (which is >= 0), so they can only surface when
``k`` exceeds the number of live rows — and then in index-ascending order,
same as the fused paths.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax

from ..binary_mvp.ref import binary_matmul_packed_ref

MASKED_SCORE = -1


def masked_scores_ref(x_packed, a_packed, *, n: int, valid=None):
    """Hamming similarity [B, M] with invalid rows forced to MASKED_SCORE."""
    s = binary_matmul_packed_ref(x_packed, a_packed, op="xor")
    h = n - s
    if valid is None:
        return h
    v = jnp.asarray(valid)
    return jnp.where(v[None, :] > 0, h, MASKED_SCORE)


def hamming_topk_ref(x_packed, a_packed, *, n: int, k: int, valid=None):
    """(scores [B,k], indices [B,k]) of the k most similar rows per query."""
    scores = masked_scores_ref(x_packed, a_packed, n=n, valid=valid)
    return lax.top_k(scores, k)


def hamming_threshold_match_ref(x_packed, a_packed, *, n: int, delta: int,
                                valid=None):
    """CAM match lines [B, M] uint8: 1 iff live row m has h̄(a_m, x_b) >= δ."""
    scores = masked_scores_ref(x_packed, a_packed, n=n, valid=valid)
    return (scores >= delta).astype(jnp.uint8)
