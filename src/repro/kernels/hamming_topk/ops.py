"""Public wrappers for fused Hamming top-k / CAM δ-match — backend dispatch.

Mirrors ``binary_mvp.ops``: packed uint32 operands, the true bit width
``n``, and a ``backend`` in

  'pallas' — the fused streaming kernel (kernel.py); interpret mode off-TPU
  'ref'    — brute-force [B, M] score matrix + lax.top_k (oracle)
  'mxu'    — streaming MXU lowering: scans the database in row chunks,
             computes each chunk's scores as an int8 dot product and merges
             into a running top-k — like the Pallas kernel, it never
             materializes the [B, M] score matrix.

All three produce bit-identical results, including (score desc, index asc)
tie ordering and the validity-mask semantics of ref.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from ...core.backend import auto_interpret as _auto_interpret
from ...core.formats import unpack_bits
from ..tiling import round_up as _round_up
from .kernel import (
    hamming_threshold_packed,
    hamming_topk_packed,
)
from .ref import (
    MASKED_SCORE,
    hamming_threshold_match_ref,
    hamming_topk_ref,
)

_INIT_SCORE = -(2**30)
_INIT_IDX = 2**30


@functools.partial(jax.jit, static_argnames=("n", "k", "chunk_m"))
def _hamming_topk_mxu(x_packed, a_packed, valid, *, n: int, k: int,
                      chunk_m: int = 2048):
    """Streaming MXU top-k: scan over [chunk_m]-row database chunks."""
    b = x_packed.shape[0]
    m = a_packed.shape[0]
    chunk = min(chunk_m, _round_up(m, 8))
    mp = _round_up(m, chunk)

    a_p = jnp.pad(a_packed.astype(jnp.uint32), ((0, mp - m), (0, 0)))
    if valid is None:
        valid = jnp.ones((m,), jnp.int32)
    v_p = jnp.pad(jnp.asarray(valid, jnp.int32), (0, mp - m))
    a_chunks = a_p.reshape(mp // chunk, chunk, a_p.shape[1])
    v_chunks = v_p.reshape(mp // chunk, chunk)
    bases = jnp.arange(mp // chunk, dtype=jnp.int32) * chunk

    xb = unpack_bits(x_packed, n).astype(jnp.int8)       # [B, n]
    rx = jnp.sum(xb.astype(jnp.int32), axis=1)[:, None]  # [B, 1]

    def step(carry, inp):
        run_s, run_i = carry
        a_c, v_c, base = inp
        ab = unpack_bits(a_c, n).astype(jnp.int8)        # [chunk, n]
        dot = lax.dot_general(xb, ab, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.int32)
        ra = jnp.sum(ab.astype(jnp.int32), axis=1)[None, :]
        h = n - (rx + ra - 2 * dot)                      # [B, chunk]
        tile_s = jnp.where(v_c[None, :] > 0, h, MASKED_SCORE)
        tile_i = base + lax.broadcasted_iota(jnp.int32, (b, chunk), 1)
        cand_s = jnp.concatenate([run_s, tile_s], axis=1)
        cand_i = jnp.concatenate([run_i, tile_i], axis=1)
        # positions respect global-index order among equal scores (running
        # entries come from earlier chunks), so value-only top_k reproduces
        # the exact global tie ordering.
        vals, pos = lax.top_k(cand_s, k)
        idx = jnp.take_along_axis(cand_i, pos, axis=1)
        return (vals, idx), None

    init = (jnp.full((b, k), _INIT_SCORE, jnp.int32),
            jnp.full((b, k), _INIT_IDX, jnp.int32))
    (scores, idx), _ = lax.scan(step, init, (a_chunks, v_chunks, bases))
    return scores, idx


def hamming_topk(x_packed, a_packed, *, n: int, k: int, valid=None,
                 backend: str = "pallas", block_m: int = 256,
                 chunk_m: int = 2048):
    """(scores [B, k], indices [B, k]) of the k most similar database rows.

    x_packed [B, W] uint32 queries, a_packed [M, W] uint32 database,
    valid [M] optional row liveness. Requires k <= M.
    """
    assert 1 <= k <= a_packed.shape[0], (k, a_packed.shape[0])
    if backend == "pallas":
        return hamming_topk_packed(x_packed, a_packed, valid, n=n, k=k,
                                   block_m=block_m,
                                   interpret=_auto_interpret())
    if backend == "ref":
        return hamming_topk_ref(x_packed, a_packed, n=n, k=k, valid=valid)
    if backend == "mxu":
        return _hamming_topk_mxu(x_packed, a_packed, valid, n=n, k=k,
                                 chunk_m=chunk_m)
    raise ValueError(f"unknown backend {backend}")


def hamming_threshold_match(x_packed, a_packed, *, n: int, delta: int,
                            valid=None, backend: str = "pallas"):
    """CAM match lines [B, M] uint8: 1 iff live row m has h̄ >= δ."""
    if backend == "pallas":
        out = hamming_threshold_packed(x_packed, a_packed, valid, n=n,
                                       delta=delta,
                                       interpret=_auto_interpret())
        return out.astype(jnp.uint8)
    if backend == "ref":
        return hamming_threshold_match_ref(x_packed, a_packed, n=n,
                                           delta=delta, valid=valid)
    if backend == "mxu":
        xb = unpack_bits(x_packed, n).astype(jnp.int8)
        ab = unpack_bits(a_packed, n).astype(jnp.int8)
        dot = lax.dot_general(xb, ab, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.int32)
        rx = jnp.sum(xb.astype(jnp.int32), axis=1)[:, None]
        ra = jnp.sum(ab.astype(jnp.int32), axis=1)[None, :]
        h = n - (rx + ra - 2 * dot)
        if valid is not None:
            h = jnp.where(jnp.asarray(valid)[None, :] > 0, h, MASKED_SCORE)
        return (h >= delta).astype(jnp.uint8)
    raise ValueError(f"unknown backend {backend}")
