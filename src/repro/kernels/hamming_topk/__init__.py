"""Fused Hamming top-k / CAM δ-match kernels (associative retrieval)."""
from .ops import hamming_threshold_match, hamming_topk  # noqa: F401
from .ref import hamming_threshold_match_ref, hamming_topk_ref  # noqa: F401
