"""Public jit'd wrappers for the PPAC 1-bit operation modes on TPU.

All functions accept *packed* uint32 operands ([B, W] inputs against the
resident [M, W] matrix) plus the true bit width ``n`` and derive the paper's
mode semantics from the raw popcount sum S (see kernel.py). ``backend``
selects the Pallas kernel ('pallas'), the jnp oracle ('ref'), or an MXU
lowering on unpacked int8 bits ('mxu' — beyond-paper path, see DESIGN.md §2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.backend import auto_interpret as _auto_interpret
from ...core.formats import unpack_bits
from .kernel import binary_matmul_packed
from .ref import binary_matmul_packed_ref


def _raw_sum(x_packed, a_packed, op: str, backend: str, n: int):
    if backend == "pallas":
        return binary_matmul_packed(x_packed, a_packed, op=op,
                                    interpret=_auto_interpret())
    if backend == "ref":
        return binary_matmul_packed_ref(x_packed, a_packed, op=op)
    if backend == "mxu":
        # Unpack to int8 and use the MXU: and-dot = x·a ; xor-sum =
        # rowsum(x) + rowsum(a) - 2 x·a. Bit-true (int32 accumulate).
        xb = unpack_bits(x_packed, n).astype(jnp.int8)
        ab = unpack_bits(a_packed, n).astype(jnp.int8)
        dot = jax.lax.dot_general(
            xb, ab, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32)
        if op == "and":
            return dot
        rx = jnp.sum(xb.astype(jnp.int32), axis=1)[:, None]
        ra = jnp.sum(ab.astype(jnp.int32), axis=1)[None, :]
        return rx + ra - 2 * dot
    raise ValueError(f"unknown backend {backend}")


@functools.partial(jax.jit, static_argnames=("n", "backend"))
def hamming_similarity(x_packed, a_packed, *, n: int, backend: str = "pallas"):
    """h̄[b,m] = n - popcount(x^a) — paper mode III-A."""
    s = _raw_sum(x_packed, a_packed, "xor", backend, n)
    return n - s


@functools.partial(jax.jit, static_argnames=("n", "delta", "backend"))
def cam_match(x_packed, a_packed, *, n: int, delta=None, backend: str = "pallas"):
    """Boolean (dis)similarity match: h̄ >= delta; delta=None -> complete match."""
    d = n if delta is None else delta
    return hamming_similarity(x_packed, a_packed, n=n, backend=backend) >= d


@functools.partial(jax.jit, static_argnames=("n", "backend"))
def inner_product_pm1(x_packed, a_packed, *, n: int, backend: str = "pallas"):
    """<a,x> with {±1} entries: 2 h̄ - N (eq. 1) — mode III-B1."""
    return 2 * hamming_similarity(x_packed, a_packed, n=n, backend=backend) - n


@functools.partial(jax.jit, static_argnames=("n", "backend"))
def and_dot(x_packed, a_packed, *, n: int, backend: str = "pallas"):
    """<a,x> with {0,1} entries — mode III-B2."""
    return _raw_sum(x_packed, a_packed, "and", backend, n)


@functools.partial(jax.jit, static_argnames=("n", "backend"))
def gf2_matmul(x_packed, a_packed, *, n: int, backend: str = "pallas"):
    """GF(2) MVP: LSB of the and-dot integer sum — mode III-D."""
    return (and_dot(x_packed, a_packed, n=n, backend=backend) & 1).astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("n", "backend", "rows_per_bank"))
def pla_eval(x_packed, a_packed, num_vars_per_row, *, n: int,
             rows_per_bank: int = 16, backend: str = "pallas"):
    """PLA mode III-E: rows are min-terms, banks OR them.

    x_packed [B, W], a_packed [M, W], num_vars_per_row [M] -> [B, M/rpb] uint8.
    """
    r = and_dot(x_packed, a_packed, n=n, backend=backend)  # [B, M]
    minterm = (r - num_vars_per_row[None, :]) >= 0
    b, m = r.shape
    banks = minterm.reshape(b, m // rows_per_bank, rows_per_bank)
    return (jnp.sum(banks, axis=-1) > 0).astype(jnp.uint8)
