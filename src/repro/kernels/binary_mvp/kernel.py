"""Pallas TPU kernel: packed binary matmul (the PPAC bit-cell array + row popcount).

Computes, for packed uint32 operands,

    S[b, m] = sum_w popcount( op( x[b, w], a[m, w] ) )        op in {xor, and}

which is the TPU-native form of the PPAC array: each uint32 lane holds 32
bit-cells; ``a`` is the resident latch matrix (weight-stationary, like the
paper's envisioned use case of a static A with streaming x, §IV-A); the
popcount + lane reduction is the subrow/row population count of Fig. 2.

From S the wrapper derives all 1-bit modes:
    xnor (h̄)      : h̄ = N_valid - S_xor
    and-dot        : S_and
    GF(2)          : S_and & 1
    inner product  : 2*h̄ - N  (eq. 1)

Tiling, padding, lane streaming and the ``row_chunk`` subrow chunking all
come from :mod:`repro.kernels.tiling` — the kernel body here is just the
per-tile accumulation of the chunked popcount sum, so arbitrarily large
B/M/W stream through fixed VMEM tiles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..tiling import lane_stream_call, plan_tiles, subrow_popcount_sum


def _binary_matmul_kernel(x_ref, a_ref, o_ref, *, op: str, row_chunk: int):
    """x_ref: [tb, tw] uint32; a_ref: [tm, tw] uint32; o_ref: [tb, tm] int32."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    bit_op = jnp.bitwise_xor if op == "xor" else jnp.bitwise_and
    o_ref[...] += subrow_popcount_sum(x_ref[...], a_ref[...], bit_op=bit_op,
                                      row_chunk=row_chunk)


@functools.partial(
    jax.jit,
    static_argnames=("op", "block_b", "block_m", "block_w", "row_chunk", "interpret"),
)
def binary_matmul_packed(
    x_packed,
    a_packed,
    *,
    op: str = "xor",
    block_b: int = 64,
    block_m: int = 128,
    block_w: int = 64,
    row_chunk: int = 8,
    interpret: bool = False,
):
    """S[b,m] = sum_w popcount(op(x[b,w], a[m,w])).

    x_packed: [B, W] uint32, a_packed: [M, W] uint32 -> [B, M] int32.
    Shapes are padded up to tile multiples internally (padding lanes are
    zero: xor-popcount of equal zeros adds 0; and of zeros adds 0 — so
    padding never changes S).
    """
    assert op in ("xor", "and")
    b, w = x_packed.shape
    m, w2 = a_packed.shape
    assert w == w2, (w, w2)

    plan = plan_tiles(b, m, w, block_b=block_b, block_m=block_m,
                      block_w=block_w, row_chunk=row_chunk)
    return lane_stream_call(
        functools.partial(_binary_matmul_kernel, op=op, row_chunk=plan.rc),
        x_packed, a_packed, plan, interpret=interpret)
