"""Pallas TPU kernel: packed binary matmul (the PPAC bit-cell array + row popcount).

Computes, for packed uint32 operands,

    S[b, m] = sum_w popcount( op( x[b, w], a[m, w] ) )        op in {xor, and}

which is the TPU-native form of the PPAC array: each uint32 lane holds 32
bit-cells; ``a`` is the resident latch matrix (weight-stationary, like the
paper's envisioned use case of a static A with streaming x, §IV-A); the
popcount + lane reduction is the subrow/row population count of Fig. 2.

From S the wrapper derives all 1-bit modes:
    xnor (h̄)      : h̄ = N_valid - S_xor
    and-dot        : S_and
    GF(2)          : S_and & 1
    inner product  : 2*h̄ - N  (eq. 1)

Tiling: grid (B/tb, M/tm, W/tw). Per step the kernel holds an x tile
[tb, tw], an a tile [tm, tw] and the int32 accumulator [tb, tm] in VMEM.
The inner broadcast is chunked over rows of the a tile (``row_chunk``) to
bound the [tb, chunk, tw] popcount intermediate — this plays the role of
the paper's subrow partitioning (bounding adder fan-in / VMEM footprint).
Lane dims are multiples of 128 and sublane dims multiples of 8 for TPU
layout friendliness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _binary_matmul_kernel(x_ref, a_ref, o_ref, *, op: str, row_chunk: int):
    """x_ref: [tb, tw] uint32; a_ref: [tm, tw] uint32; o_ref: [tb, tm] int32."""
    tb, tw = x_ref.shape
    tm = a_ref.shape[0]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [tb, tw]
    a = a_ref[...]  # [tm, tw]

    # Chunk the row dimension to bound the [tb, chunk, tw] intermediate.
    n_chunks = tm // row_chunk

    def body(i, acc):
        a_c = lax.dynamic_slice_in_dim(a, i * row_chunk, row_chunk, axis=0)
        if op == "xor":
            bits = jnp.bitwise_xor(x[:, None, :], a_c[None, :, :])
        else:  # and
            bits = jnp.bitwise_and(x[:, None, :], a_c[None, :, :])
        pc = lax.population_count(bits).astype(jnp.int32)  # [tb, chunk, tw]
        part = jnp.sum(pc, axis=-1)  # [tb, chunk]
        return lax.dynamic_update_slice_in_dim(acc, part, i * row_chunk, axis=1)

    partial_s = lax.fori_loop(
        0, n_chunks, body, jnp.zeros((tb, tm), jnp.int32), unroll=False
    )
    o_ref[...] += partial_s


@functools.partial(
    jax.jit,
    static_argnames=("op", "block_b", "block_m", "block_w", "row_chunk", "interpret"),
)
def binary_matmul_packed(
    x_packed,
    a_packed,
    *,
    op: str = "xor",
    block_b: int = 64,
    block_m: int = 128,
    block_w: int = 64,
    row_chunk: int = 8,
    interpret: bool = False,
):
    """S[b,m] = sum_w popcount(op(x[b,w], a[m,w])).

    x_packed: [B, W] uint32, a_packed: [M, W] uint32 -> [B, M] int32.
    Shapes are padded up to tile multiples internally (padding lanes are
    zero: xor-popcount of equal zeros adds 0; and of zeros adds 0 — so
    padding never changes S).
    """
    assert op in ("xor", "and")
    b, w = x_packed.shape
    m, w2 = a_packed.shape
    assert w == w2, (w, w2)

    bb = min(block_b, _round_up(b, 8))
    bm = min(block_m, _round_up(m, 8))
    bw = min(block_w, _round_up(w, 128))
    rc = min(row_chunk, bm)
    while bm % rc:
        rc -= 1

    bp, mp, wp = _round_up(b, bb), _round_up(m, bm), _round_up(w, bw)
    x_p = jnp.pad(x_packed.astype(jnp.uint32), ((0, bp - b), (0, wp - w)))
    a_p = jnp.pad(a_packed.astype(jnp.uint32), ((0, mp - m), (0, wp - w)))

    grid = (bp // bb, mp // bm, wp // bw)
    out = pl.pallas_call(
        functools.partial(_binary_matmul_kernel, op=op, row_chunk=rc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bw), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bw), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bb, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, mp), jnp.int32),
        interpret=interpret,
    )(x_p, a_p)
    return out[:b, :m]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
