from .kernel import binary_matmul_packed  # noqa: F401
from .ops import (  # noqa: F401
    and_dot,
    cam_match,
    gf2_matmul,
    hamming_similarity,
    inner_product_pm1,
    pla_eval,
)
from .ref import binary_matmul_bits_ref, binary_matmul_packed_ref  # noqa: F401
