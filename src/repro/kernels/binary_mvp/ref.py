"""Pure-jnp oracle for the packed binary matmul kernel."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def binary_matmul_packed_ref(x_packed, a_packed, *, op: str = "xor"):
    """S[b,m] = sum_w popcount(op(x[b,w], a[m,w])) — reference, O(B*M*W)."""
    x = jnp.asarray(x_packed, jnp.uint32)[:, None, :]   # [B,1,W]
    a = jnp.asarray(a_packed, jnp.uint32)[None, :, :]   # [1,M,W]
    bits = jnp.bitwise_xor(x, a) if op == "xor" else jnp.bitwise_and(x, a)
    return jnp.sum(lax.population_count(bits).astype(jnp.int32), axis=-1)


def binary_matmul_bits_ref(x_bits, a_bits, *, op: str = "xor"):
    """Same, on unpacked {0,1} arrays: x [B,N], a [M,N] -> [B,M] int32."""
    x = jnp.asarray(x_bits, jnp.int32)[:, None, :]
    a = jnp.asarray(a_bits, jnp.int32)[None, :, :]
    bits = (x ^ a) if op == "xor" else (x & a)
    return jnp.sum(bits, axis=-1)
