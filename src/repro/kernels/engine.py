"""Unified PPAC kernel engine: one dispatch surface over every operation mode.

The paper presents PPAC as a *versatile* accelerator — one bit-cell array
whose peripherals reconfigure between Hamming similarity, CAM matching,
1-bit and multi-bit MVPs, GF(2) products and PLA evaluation (Table I,
§III). This module is the software analogue: a single entry point

    ppac_matmul(x, a, mode=..., backend=..., **mode_kwargs)

over a mode registry, so every subsystem (`core.engine` model serving,
`retrieval.CAMIndex`, the `gf2` coding stack) calls PPAC compute through
the same surface instead of importing per-mode kernels. Each mode has
three bit-identical lowerings ('pallas' | 'ref' | 'mxu', 'auto' resolves
per platform) and is validated against the cycle-exact ``PPACArray``
oracle in tests.

Modes (operands are packed uint32 lanes unless noted):

  hamming              h̄[b,m] = n - popcount(x ^ a)              (§III-A)
  cam                  match lines (h̄ >= δ), honors a validity mask
  topk                 fused streaming top-k of h̄ -> (scores, indices)
  mvp_1bit             1-bit MVP, all four Table-I format pairs
                       (fmt_a/fmt_x in {'pm1','01'}; eqs. (1)–(3))
  mvp_multibit         K-bit matrix × L-bit vector ints (§III-C)
  mvp_multibit_planes  same, against a pre-packed K-plane resident matrix
                       (the serving weight layout)
  gf2                  GF(2) MVP with XOR-parity lane accumulation (§III-D)
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict

import jax.numpy as jnp
from jax import lax

from ..core.backend import resolve_backend
from ..obs import ledger as _flight
from .binary_mvp.ops import and_dot, hamming_similarity, inner_product_pm1
from .bitserial_mvp.ops import ppac_matmul as _multibit_matmul
from .bitserial_mvp.ops import ppac_matmul_planes as _multibit_matmul_planes
from .bitserial_mvp.ops import ppac_matmul_resident as _multibit_matmul_resident
from .gf2_tiled.ops import gf2_matmul_tiled
from .hamming_topk.ops import hamming_threshold_match, hamming_topk


def _lane_popcount(packed) -> jnp.ndarray:
    """Total set bits per packed row (padding lanes are zero by contract)."""
    pc = lax.population_count(jnp.asarray(packed, jnp.uint32))
    return jnp.sum(pc.astype(jnp.int32), axis=-1)


def _mode_hamming(x, a, *, backend, n: int):
    return hamming_similarity(x, a, n=n, backend=backend)


def _mode_cam(x, a, *, backend, n: int, delta=None, valid=None):
    d = n if delta is None else delta
    return hamming_threshold_match(x, a, n=n, delta=d, valid=valid,
                                   backend=backend)


def _mode_topk(x, a, *, backend, n: int, k: int, valid=None):
    return hamming_topk(x, a, n=n, k=k, valid=valid, backend=backend)


def _mode_mvp_1bit(x, a, *, backend, n: int, fmt_a: str = "pm1",
                   fmt_x: str = "pm1"):
    """All four Table-I 1-bit format pairs over packed logical bits.

    'pm1' operands store level 1 for +1 and level 0 for -1; '01' operands
    store the value directly. The mixed pairs fold the h̄(a,1)/h̄(a,0)
    precompute of eqs. (2)/(3) into lane popcounts of the resident packed
    operand, so they stay bit-identical across backends for free.
    """
    pair = (fmt_a, fmt_x)
    if pair == ("pm1", "pm1"):
        return inner_product_pm1(x, a, n=n, backend=backend)
    if pair == ("01", "01"):
        return and_dot(x, a, n=n, backend=backend)
    s_and = and_dot(x, a, n=n, backend=backend)
    if pair == ("pm1", "01"):
        # eq. (2): <a,x> = 2*S_and - sum(x)  (a in ±1, x in {0,1})
        return 2 * s_and - _lane_popcount(x)[:, None]
    if pair == ("01", "pm1"):
        # eq. (3): <a,x> = 2*S_and - sum(a)  (a in {0,1}, x in ±1)
        return 2 * s_and - _lane_popcount(a)[None, :]
    raise ValueError(f"unsupported 1-bit format pair {pair}")


def _mode_mvp_multibit(x, a, *, backend, k_bits: int, l_bits: int,
                       fmt_a="int", fmt_x="int"):
    return _multibit_matmul(x, a, k_bits=k_bits, l_bits=l_bits,
                            fmt_a=fmt_a, fmt_x=fmt_x, backend=backend)


def _mode_mvp_multibit_planes(x, a, *, backend, n: int, k_bits: int,
                              l_bits: int, fmt_a="int", fmt_x="int",
                              a_has_mask: bool = False):
    return _multibit_matmul_planes(x, a, n=n, k_bits=k_bits, l_bits=l_bits,
                                   fmt_a=fmt_a, fmt_x=fmt_x,
                                   a_has_mask=a_has_mask, backend=backend)


def _mode_mvp_multibit_resident(x, a, *, backend, n: int, k_bits: int,
                                l_bits: int, fmt_a="int", fmt_x="int",
                                a_has_mask: bool = False, a_int8=None):
    return _multibit_matmul_resident(x, a, n=n, k_bits=k_bits, l_bits=l_bits,
                                     fmt_a=fmt_a, fmt_x=fmt_x,
                                     a_has_mask=a_has_mask, a_int8=a_int8,
                                     backend=backend)


def _mode_gf2(x, a, *, backend, n: int):
    return gf2_matmul_tiled(x, a, n=n, backend=backend)


@dataclasses.dataclass(frozen=True)
class ModeSpec:
    """One entry of the PPAC mode registry."""

    fn: Callable
    summary: str
    paper_section: str


MODES: Dict[str, ModeSpec] = {
    "hamming": ModeSpec(_mode_hamming,
                        "Hamming similarity h̄ = n - popcount(x^a)", "III-A"),
    "cam": ModeSpec(_mode_cam,
                    "CAM δ-match lines (h̄ >= δ), validity-masked", "III-A"),
    "topk": ModeSpec(_mode_topk,
                     "fused streaming top-k of h̄ -> (scores, ids)", "III-A"),
    "mvp_1bit": ModeSpec(_mode_mvp_1bit,
                         "1-bit MVP, format pairs pm1/01 (eqs. 1-3)", "III-B"),
    "mvp_multibit": ModeSpec(_mode_mvp_multibit,
                             "K-bit matrix × L-bit vector integer MVP",
                             "III-C"),
    "mvp_multibit_planes": ModeSpec(
        _mode_mvp_multibit_planes,
        "multi-bit MVP against a pre-packed K-plane resident matrix",
        "III-C"),
    "mvp_multibit_resident": ModeSpec(
        _mode_mvp_multibit_resident,
        "decode fast path: resident planes, in-kernel activation "
        "bit-slicing, zero per-call repack",
        "III-C/IV-A"),
    "gf2": ModeSpec(_mode_gf2, "GF(2) MVP (XOR-parity accumulation)", "III-D"),
}


def modes() -> Dict[str, str]:
    """Mode name -> one-line summary (for docs/CLIs)."""
    return {name: spec.summary for name, spec in MODES.items()}


def ppac_matmul(x, a, *, mode: str, backend: str = "auto", **kwargs):
    """Run one PPAC operation mode on (x, a) via the mode registry.

    x is the streaming operand ([B, W] packed lanes, or [B, n] integers
    for the multi-bit modes); a is the resident matrix ([M, W] lanes,
    [M, n] integers, or [K, M, W] packed planes for
    'mvp_multibit_planes'). ``backend`` is 'pallas' | 'ref' | 'mxu' |
    'auto' (native Pallas on TPU, the MXU lowering elsewhere); all three
    are bit-identical. Mode-specific arguments (``n``, ``k``, ``delta``,
    ``valid``, ``k_bits``/``l_bits``, ``fmt_a``/``fmt_x``) pass through
    as keywords.
    """
    spec = MODES.get(mode)
    if spec is None:
        raise ValueError(
            f"unknown PPAC mode {mode!r}; available: {sorted(MODES)}")
    be = resolve_backend(backend)
    # Flight recorder: this is THE dispatch chokepoint. When a ledger is
    # open on this thread, every launch emits one costed LaunchRecord;
    # otherwise the single active() check is the entire overhead.
    if _flight.active():
        return _flight.recorded_launch(spec.fn, mode, be, x, a, kwargs)
    return spec.fn(x, a, backend=be, **kwargs)
