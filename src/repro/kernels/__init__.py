"""Pallas TPU kernels for the PPAC operation modes.

engine        — the unified dispatch surface: ``ppac_matmul(x, a, mode=...)``
                over a registry of every Table-I operation mode, with
                bit-identical 'pallas' / 'ref' / 'mxu' backends
tiling        — shared machinery: pad-to-tile planning, lane-tile
                streaming, ``row_chunk`` subrow chunking
binary_mvp    — packed 1-bit XNOR/AND popcount matmul (modes III-A/B/D/E)
bitserial_mvp — fused multi-bitplane MVP (mode III-C, all Table-I formats;
                ``ppac_matmul_planes`` serves pre-packed resident weights,
                ``ppac_matmul_resident`` is the zero-repack decode fast
                path with in-kernel activation bit-slicing)
hamming_topk  — fused streaming Hamming top-k / CAM δ-match (mode III-A
                associative retrieval at scale; never materializes [B, M])
gf2_tiled     — tiled GF(2) matmul with XOR-parity accumulation across
                lane tiles (mode III-D at n ≫ 256; operands stay packed)
"""
from .binary_mvp.ops import (  # noqa: F401
    and_dot,
    cam_match,
    gf2_matmul,
    hamming_similarity,
    inner_product_pm1,
    pla_eval,
)
from .bitserial_mvp.ops import (  # noqa: F401
    ppac_cycles,
    ppac_matmul_planes,
    ppac_matmul_resident,
)
from .bitserial_mvp.ops import ppac_matmul as multibit_matmul  # noqa: F401
from .engine import MODES, modes, ppac_matmul  # noqa: F401
from .gf2_tiled.ops import gf2_matmul_tiled  # noqa: F401
from .hamming_topk.ops import (  # noqa: F401
    hamming_threshold_match,
    hamming_topk,
)
