from .kernel import bitserial_matmul_packed  # noqa: F401
from .ops import build_planes_and_weights, ppac_cycles, ppac_matmul  # noqa: F401
from .ref import bitserial_matmul_packed_ref, integer_matmul_ref  # noqa: F401
