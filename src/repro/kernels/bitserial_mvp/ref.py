"""Pure-jnp oracles for the fused bit-serial MVP kernels."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def _popcount_rows(planes):
    """[..., rows, W] uint32 -> [..., rows] int32 set bits per row."""
    pc = lax.population_count(jnp.asarray(planes, jnp.uint32))
    return jnp.sum(pc.astype(jnp.int32), axis=-1)


def bitserial_matmul_packed_ref(x_planes, a_planes, weights):
    """Same contract as bitserial_matmul_packed, O(K1*L1*B*M*W) jnp.

    ``weights`` may be the plain [K1, L1] plane-pair matrix or the
    extended [K1+1, L1+1] one (mask popcount row/col + constant corner —
    see kernel.py); the extended terms reproduce the kernels' in-body
    popcount accumulation exactly.
    """
    x = jnp.asarray(x_planes, jnp.uint32)  # [L1,B,W]
    a = jnp.asarray(a_planes, jnp.uint32)  # [K1,M,W]
    w = jnp.asarray(weights, jnp.int32)
    l1, k1 = x.shape[0], a.shape[0]
    bits = jnp.bitwise_and(x[None, :, :, None, :], a[:, None, None, :, :])
    pc = lax.population_count(bits).astype(jnp.int32)  # [K1,L1,B,M,W]
    s = jnp.sum(pc, axis=-1)                           # [K1,L1,B,M]
    y = jnp.einsum("kl,klbm->bm", w[:k1, :l1], s).astype(jnp.int32)
    if w.shape == (k1 + 1, l1 + 1):
        pop_a = _popcount_rows(a)                      # [K1, M]
        pop_x = _popcount_rows(x)                      # [L1, B]
        y = y + jnp.einsum("k,km->m", w[:k1, l1], pop_a)[None, :]
        y = y + jnp.einsum("l,lb->b", w[k1, :l1], pop_x)[:, None]
        y = y + w[k1, l1]
    return y.astype(jnp.int32)


def integer_matmul_ref(x_int, a_int):
    """Ground-truth y[b,m] = <a_m, x_b> on integer operands."""
    return jnp.asarray(x_int, jnp.int32) @ jnp.asarray(a_int, jnp.int32).T
