"""Pure-jnp oracles for the fused bit-serial MVP kernel."""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def bitserial_matmul_packed_ref(x_planes, a_planes, weights):
    """Same contract as bitserial_matmul_packed, O(K1*L1*B*M*W) jnp."""
    x = jnp.asarray(x_planes, jnp.uint32)  # [L1,B,W]
    a = jnp.asarray(a_planes, jnp.uint32)  # [K1,M,W]
    w = jnp.asarray(weights, jnp.int32)    # [K1,L1]
    bits = jnp.bitwise_and(x[None, :, :, None, :], a[:, None, None, :, :])
    pc = lax.population_count(bits).astype(jnp.int32)  # [K1,L1,B,M,W]
    s = jnp.sum(pc, axis=-1)                           # [K1,L1,B,M]
    return jnp.einsum("kl,klbm->bm", w, s).astype(jnp.int32)


def integer_matmul_ref(x_int, a_int):
    """Ground-truth y[b,m] = <a_m, x_b> on integer operands."""
    return jnp.asarray(x_int, jnp.int32) @ jnp.asarray(a_int, jnp.int32).T
