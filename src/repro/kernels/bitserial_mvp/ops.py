"""Public API for multi-bit PPAC MVPs (paper §III-C) on TPU.

``ppac_matmul`` takes integer operands + number formats (Table I), builds the
logical bitplanes and the plane-pair weight matrix, and dispatches to the
fused Pallas kernel ('pallas'), the jnp oracle ('ref'), or an int8 MXU
lowering ('mxu').

``ppac_matmul_planes`` is the serving variant: the K-bit matrix arrives
already decomposed into packed bitplane lanes (the resident weight layout
of ``core.engine.pack_weight_for_serving``) and only the L-bit vector batch
is decomposed on the fly — the matrix is weight-stationary, exactly the
paper's premise of a static A with streaming x (§IV-A).

Weight-matrix construction. For an operand with format f and L bits, the
value decomposes over logical planes b_l in {0,1} as

    value = sum_l w_l * b_l + c
      uint   : w_l = 2^l,                      c = 0
      int    : w_l = 2^l, w_{L-1} = -2^{L-1},  c = 0          (2's complement)
      oddint : w_l = 2^{l+1},                  c = -(2^L - 1)

Nonzero offsets c are folded in by appending a constant all-ones "mask"
plane with weight c — the TPU generalization of the paper's h̄(a,1)/h̄(a,0)
precompute in eqs. (2)/(3). The bilinear form then becomes a single
plane-pair-weighted sum of AND-popcounts, evaluated in one fused kernel.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...core.backend import auto_interpret as _auto_interpret
from ...core.formats import (
    NumberFormat,
    fmt,
    from_bitplanes,
    pack_bits,
    plane_weights,
    to_bitplanes,
    unpack_bits,
)
from .kernel import bitserial_matmul_packed
from .ref import bitserial_matmul_packed_ref


def _operand_decomposition(f: NumberFormat, bits: int) -> Tuple[np.ndarray, int]:
    """(per-plane weights w_l, constant offset c) for a Table-I format."""
    f = fmt(f)
    if f is NumberFormat.ODDINT:
        w = np.asarray([2 ** (l + 1) for l in range(bits)], np.int64)
        c = -(2**bits - 1)
    else:
        w = plane_weights(f, bits)
        c = 0
    return w, int(c)


def _pair_weights(wa, ca, wx, cx):
    """Plane-pair weight matrix [K1, L1] with mask-plane rows/cols appended
    when either side carries a constant offset (cross terms w*c and c*c)."""
    if cx != 0 or ca != 0:
        wa = np.concatenate([wa, [ca]])
        wx = np.concatenate([wx, [cx]])
    weights = np.outer(wa, wx).astype(np.int64)
    assert np.abs(weights).max() < 2**31, "plane weights overflow int32"
    return jnp.asarray(weights, jnp.int32), (cx != 0 or ca != 0)


def build_planes_and_weights(x_int, a_int, k_bits: int, l_bits: int,
                             fmt_a, fmt_x):
    """Returns (x_planes [L1,B,W], a_planes [K1,M,W], weights [K1,L1])."""
    fmt_a, fmt_x = fmt(fmt_a), fmt(fmt_x)
    b, n = x_int.shape
    m, n2 = a_int.shape
    assert n == n2

    wx, cx = _operand_decomposition(fmt_x, l_bits)
    wa, ca = _operand_decomposition(fmt_a, k_bits)
    weights, need_mask = _pair_weights(wa, ca, wx, cx)

    x_planes = to_bitplanes(x_int, l_bits, fmt_x)  # (L,B,N)
    a_planes = to_bitplanes(a_int, k_bits, fmt_a)  # (K,M,N)

    if need_mask:
        # Append mask planes so cross terms (w*c and c*c) are representable.
        mask = jnp.ones((1, n), jnp.uint8)
        x_planes = jnp.concatenate(
            [x_planes, jnp.broadcast_to(mask, (1, b, n))], axis=0)
        a_planes = jnp.concatenate(
            [a_planes, jnp.broadcast_to(mask, (1, m, n))], axis=0)

    xp = pack_bits(x_planes)  # (L1,B,W)
    ap = pack_bits(a_planes)  # (K1,M,W)
    return xp, ap, weights


@functools.partial(jax.jit,
                   static_argnames=("k_bits", "l_bits", "fmt_a", "fmt_x",
                                    "backend"))
def ppac_matmul(x_int, a_int, *, k_bits: int, l_bits: int,
                fmt_a="int", fmt_x="int", backend: str = "pallas"):
    """y[b,m] = <a_m, x_b> for K-bit A (resident matrix) and L-bit x.

    Bit-true int32 result; equivalent PPAC cost is K*L cycles per MVP.
    """
    fa, fx = fmt(fmt_a), fmt(fmt_x)
    if backend == "mxu":
        # Beyond-paper: fold planes back to integers and use the MXU
        # (int8 operands when ranges fit — bit-true int32 accumulation).
        xi = jnp.asarray(x_int, jnp.int32)
        ai = jnp.asarray(a_int, jnp.int32)
        small = max(2**k_bits, 2**l_bits) <= 128
        dt = jnp.int8 if small else jnp.int32
        return jax.lax.dot_general(
            xi.astype(dt), ai.astype(dt), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32)
    xp, ap, w = build_planes_and_weights(x_int, a_int, k_bits, l_bits, fa, fx)
    if backend == "pallas":
        return bitserial_matmul_packed(xp, ap, w, interpret=_auto_interpret())
    if backend == "ref":
        return bitserial_matmul_packed_ref(xp, ap, w)
    raise ValueError(f"unknown backend {backend}")


@functools.partial(jax.jit,
                   static_argnames=("n", "k_bits", "l_bits", "fmt_a", "fmt_x",
                                    "backend"))
def ppac_matmul_planes(x_int, a_planes, *, n: int, k_bits: int, l_bits: int,
                       fmt_a="int", fmt_x="int", backend: str = "pallas"):
    """y[b,m] = <a_m, x_b> against a *pre-packed* K-plane resident matrix.

    a_planes: [K, M, ceil(n/32)] uint32 — the K logical bitplanes of the
    K-bit matrix in packed lane form (lanes beyond ``n`` zero, as
    ``core.formats.pack_bits`` guarantees); x_int: [B, n] integers in the
    ``fmt_x`` L-bit range, decomposed on the fly. Bit-true int32 result,
    identical across backends and to ``ppac_matmul`` on the unpacked ints.
    """
    fa, fx = fmt(fmt_a), fmt(fmt_x)
    b = x_int.shape[0]
    k, m, _ = a_planes.shape
    assert k == k_bits, (k, k_bits)

    if backend == "mxu":
        # Fold the resident planes back to integers and use the MXU.
        a_bits = unpack_bits(a_planes, n)              # [K, M, n]
        ai = from_bitplanes(a_bits, fa)                # [M, n] int32
        xi = jnp.asarray(x_int, jnp.int32)
        small = max(2**k_bits, 2**l_bits) <= 128
        dt = jnp.int8 if small else jnp.int32
        return jax.lax.dot_general(
            xi.astype(dt), ai.astype(dt), (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.int32)

    wx, cx = _operand_decomposition(fx, l_bits)
    wa, ca = _operand_decomposition(fa, k_bits)
    weights, need_mask = _pair_weights(wa, ca, wx, cx)

    xp = pack_bits(to_bitplanes(x_int, l_bits, fx))    # [L, B, W]
    ap = jnp.asarray(a_planes, jnp.uint32)
    if need_mask:
        # The constant all-ones plane (valid bits only) is shape-derived —
        # it never needs to be stored with the weights.
        mask_row = pack_bits(jnp.ones((n,), jnp.uint8))  # [W]
        xp = jnp.concatenate(
            [xp, jnp.broadcast_to(mask_row, (1, b) + mask_row.shape)], axis=0)
        ap = jnp.concatenate(
            [ap, jnp.broadcast_to(mask_row, (1, m) + mask_row.shape)], axis=0)

    if backend == "pallas":
        return bitserial_matmul_packed(xp, ap, weights,
                                       interpret=_auto_interpret())
    if backend == "ref":
        return bitserial_matmul_packed_ref(xp, ap, weights)
    raise ValueError(f"unknown backend {backend}")


def ppac_cycles(k_bits: int, l_bits: int) -> int:
    """Emulated-PPAC cycle cost of one multi-bit MVP (§III-C)."""
    return k_bits * l_bits
