"""Public API for multi-bit PPAC MVPs (paper §III-C) on TPU.

``ppac_matmul`` takes integer operands + number formats (Table I), builds the
logical bitplanes and the plane-pair weight matrix, and dispatches to the
fused Pallas kernel ('pallas'), the jnp oracle ('ref'), or an int8 MXU
lowering ('mxu').

``ppac_matmul_planes`` is the serving variant: the K-bit matrix arrives
already decomposed into packed bitplane lanes (the resident weight layout
of ``core.engine.pack_weight_for_serving``) and only the L-bit vector batch
is decomposed on the fly — the matrix is weight-stationary, exactly the
paper's premise of a static A with streaming x (§IV-A).
``ppac_matmul_resident`` is its decode fast path: the streaming operand is
the quantized integer activation batch itself, bit-sliced *inside* the
Pallas body (no ``to_bitplanes``/``pack_bits`` XLA round trip), and the
optional ``a_int8`` shadow gives the MXU lowering a load-time resident
operand too.

Weight-matrix construction. For an operand with format f and L bits, the
value decomposes over logical planes b_l in {0,1} as

    value = sum_l w_l * b_l + c
      uint   : w_l = 2^l,                      c = 0
      int    : w_l = 2^l, w_{L-1} = -2^{L-1},  c = 0          (2's complement)
      oddint : w_l = 2^{l+1},                  c = -(2^L - 1)

Nonzero offsets c are the TPU generalization of the paper's h̄(a,1)/h̄(a,0)
precompute in eqs. (2)/(3). They are folded into the *extended* weight
matrix consumed by the kernels — coefficients on in-kernel plane popcounts
plus a constant — so the zero-repack invariant holds: nothing is ever
concatenated or broadcast onto an operand at call time. A resident weight
packed by ``pack_weight_for_serving`` may carry its offset as a stored
all-ones mask plane instead (``a_has_mask=True``), in which case the
offset column rides the ordinary plane-pair weights of that plane.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ...core.backend import auto_interpret as _auto_interpret
from ...core.formats import (
    NumberFormat,
    fmt,
    from_bitplanes,
    pack_bits,
    plane_weights,
    to_bitplanes,
    to_levels,
    unpack_bits,
    value_range,
)
from .kernel import bitserial_matmul_packed, bitserial_matmul_sliced
from .ref import bitserial_matmul_packed_ref


def _operand_decomposition(f: NumberFormat, bits: int) -> Tuple[np.ndarray, int]:
    """(per-plane weights w_l, constant offset c) for a Table-I format."""
    f = fmt(f)
    if f is NumberFormat.ODDINT:
        w = np.asarray([2 ** (l + 1) for l in range(bits)], np.int64)
        c = -(2**bits - 1)
    else:
        w = plane_weights(f, bits)
        c = 0
    return w, int(c)


def format_needs_mask(f) -> bool:
    """True when the Table-I format carries an affine offset (oddint) —
    the case where ``pack_weight_for_serving`` stores a resident all-ones
    mask plane alongside the value planes."""
    return _operand_decomposition(f, 1)[1] != 0


def extended_weights(fmt_a, k_bits: int, fmt_x, l_bits: int, *, n: int,
                     a_has_mask: bool = False):
    """Build the extended [K1+1, L+1] weight matrix + static term flags.

    Returns (w_ext int32 numpy, k1, pop_a, pop_x, const):
      w_ext[:K1, :L]  plane-pair AND-popcount weights
      w_ext[:K1, L]   coefficients on in-kernel popcount(a_plane_k)[m]
      w_ext[K1, :L]   coefficients on in-kernel popcount(x_plane_l)[b]
      w_ext[K1, L]    constant ca*cx*n, added once per output block

    ``a_has_mask`` means the resident matrix already stores its offset as
    a (K+1)-th all-ones plane: the a-side offset then rides that plane's
    ordinary pair weights and its pop_a column carries the corner term
    (popcount of the mask plane is n, yielding ca*cx*n exactly).
    """
    wa, ca = _operand_decomposition(fmt_a, k_bits)
    wx, cx = _operand_decomposition(fmt_x, l_bits)
    if a_has_mask:
        if ca == 0:
            raise ValueError(f"format {fmt(fmt_a)} carries no offset; "
                             "no resident mask plane expected")
        wa = np.concatenate([wa, [ca]])
        ca = 0
    k1, l1 = len(wa), len(wx)
    w = np.zeros((k1 + 1, l1 + 1), np.int64)
    w[:k1, :l1] = np.outer(wa, wx)
    w[:k1, l1] = wa * cx
    w[k1, :l1] = ca * np.asarray(wx)
    w[k1, l1] = ca * cx * n
    assert np.abs(w).max() < 2**31, "plane weights overflow int32"
    pop_a = bool(np.any(w[:k1, l1]))
    pop_x = bool(np.any(w[k1, :l1]))
    const = bool(w[k1, l1])
    return np.asarray(w, np.int32), k1, pop_a, pop_x, const


def build_planes_and_weights(x_int, a_int, k_bits: int, l_bits: int,
                             fmt_a, fmt_x):
    """Returns (x_planes [L,B,W], a_planes [K,M,W], w_ext [K+1,L+1], flags).

    Offsets live entirely in the extended weight matrix — neither operand
    grows a mask plane."""
    fmt_a, fmt_x = fmt(fmt_a), fmt(fmt_x)
    n = x_int.shape[1]
    assert a_int.shape[1] == n
    w_ext, _, pop_a, pop_x, const = extended_weights(
        fmt_a, k_bits, fmt_x, l_bits, n=n)
    xp = pack_bits(to_bitplanes(x_int, l_bits, fmt_x))  # (L,B,W)
    ap = pack_bits(to_bitplanes(a_int, k_bits, fmt_a))  # (K,M,W)
    return xp, ap, jnp.asarray(w_ext), (pop_a, pop_x, const)


def _int8_operands(fmt_a, k_bits: int, fmt_x, l_bits: int) -> bool:
    """True when both Table-I value ranges fit int8 (the accumulation is
    int32 either way, so the narrow input dtype is purely a speed lever)."""
    ranges = (value_range(fmt_a, k_bits), value_range(fmt_x, l_bits))
    return all(lo >= -128 and hi <= 127 for lo, hi in ranges)


def _mxu_dot(x_int, a_int, k_bits: int, l_bits: int, fmt_a="int",
             fmt_x="int"):
    """Beyond-paper MXU lowering on integer operands (bit-true int32
    accumulation; int8 inputs when the format ranges fit)."""
    xi = jnp.asarray(x_int, jnp.int32)
    ai = jnp.asarray(a_int, jnp.int32)
    dt = jnp.int8 if _int8_operands(fmt_a, k_bits, fmt_x, l_bits) \
        else jnp.int32
    return jax.lax.dot_general(
        xi.astype(dt), ai.astype(dt), (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("k_bits", "l_bits", "fmt_a", "fmt_x",
                                    "backend"))
def ppac_matmul(x_int, a_int, *, k_bits: int, l_bits: int,
                fmt_a="int", fmt_x="int", backend: str = "pallas"):
    """y[b,m] = <a_m, x_b> for K-bit A (resident matrix) and L-bit x.

    Bit-true int32 result; equivalent PPAC cost is K*L cycles per MVP.
    """
    fa, fx = fmt(fmt_a), fmt(fmt_x)
    if backend == "mxu":
        return _mxu_dot(x_int, a_int, k_bits, l_bits, fa, fx)
    xp, ap, w, (pop_a, pop_x, const) = build_planes_and_weights(
        x_int, a_int, k_bits, l_bits, fa, fx)
    if backend == "pallas":
        return bitserial_matmul_packed(xp, ap, w, pop_a=pop_a, pop_x=pop_x,
                                       const=const,
                                       interpret=_auto_interpret())
    if backend == "ref":
        return bitserial_matmul_packed_ref(xp, ap, w)
    raise ValueError(f"unknown backend {backend}")


def _planes_to_int(a_planes, n: int, k_bits: int, fa) -> jnp.ndarray:
    """Fold resident value planes (mask plane excluded) back to integers —
    the legacy MXU fallback when no load-time int8 shadow exists."""
    a_bits = unpack_bits(jnp.asarray(a_planes[:k_bits], jnp.uint32), n)
    return from_bitplanes(a_bits, fa)


@functools.partial(jax.jit,
                   static_argnames=("n", "k_bits", "l_bits", "fmt_a", "fmt_x",
                                    "a_has_mask", "backend"))
def ppac_matmul_planes(x_int, a_planes, *, n: int, k_bits: int, l_bits: int,
                       fmt_a="int", fmt_x="int", a_has_mask: bool = False,
                       backend: str = "pallas"):
    """y[b,m] = <a_m, x_b> against a *pre-packed* K-plane resident matrix.

    a_planes: [K1, M, ceil(n/32)] uint32 — the K logical bitplanes of the
    K-bit matrix in packed lane form (lanes beyond ``n`` zero, as
    ``core.formats.pack_bits`` guarantees), plus a stored all-ones mask
    plane when ``a_has_mask`` (offset formats packed at load time);
    x_int: [B, n] integers in the ``fmt_x`` L-bit range, decomposed on the
    fly. Bit-true int32 result, identical across backends and to
    ``ppac_matmul`` on the unpacked ints. Never concatenates onto or
    broadcasts over the resident planes.
    """
    fa, fx = fmt(fmt_a), fmt(fmt_x)
    assert a_planes.shape[0] == k_bits + bool(a_has_mask), \
        (a_planes.shape, k_bits, a_has_mask)

    if backend == "mxu":
        return _mxu_dot(x_int, _planes_to_int(a_planes, n, k_bits, fa),
                        k_bits, l_bits, fa, fx)

    w_ext, _, pop_a, pop_x, const = extended_weights(
        fa, k_bits, fx, l_bits, n=n, a_has_mask=a_has_mask)
    xp = pack_bits(to_bitplanes(x_int, l_bits, fx))    # [L, B, W]
    ap = jnp.asarray(a_planes, jnp.uint32)
    w = jnp.asarray(w_ext)
    if backend == "pallas":
        return bitserial_matmul_packed(xp, ap, w, pop_a=pop_a, pop_x=pop_x,
                                       const=const,
                                       interpret=_auto_interpret())
    if backend == "ref":
        return bitserial_matmul_packed_ref(xp, ap, w)
    raise ValueError(f"unknown backend {backend}")


def levels_to_stack(u, w: int) -> jnp.ndarray:
    """[B, n] level codes -> the bit-transposed [32, B, w] uint32 stack the
    sliced kernel streams (u_stack[t, b, j] codes logical bit 32j+t).
    Zero-padded in the level-code domain, so padding contributes no bits."""
    b, n = u.shape
    u = jnp.asarray(u, jnp.uint32)
    u = jnp.pad(u, ((0, 0), (0, w * 32 - n)))
    return u.reshape(b, w, 32).transpose(2, 0, 1)


@functools.partial(jax.jit,
                   static_argnames=("n", "k_bits", "l_bits", "fmt_a", "fmt_x",
                                    "a_has_mask", "backend", "block_b",
                                    "block_m", "block_w", "row_chunk"))
def ppac_matmul_resident(x_int, a_planes, *, n: int, k_bits: int,
                         l_bits: int, fmt_a="int", fmt_x="int",
                         a_has_mask: bool = False, backend: str = "pallas",
                         a_int8=None, block_b=None, block_m=None,
                         block_w=None, row_chunk=None):
    """The decode fast path: quantized [B, n] activations against resident
    packed planes, activation bit-slicing *inside* the kernel.

    Bit-identical to :func:`ppac_matmul_planes` (tested); differences are
    purely in data movement:
      * 'pallas' streams L-bit level codes and builds the packed planes
        per tile in the kernel body — no to_bitplanes/pack_bits round trip;
      * 'mxu' consumes ``a_int8`` — the int8 shadow materialized at load
        time by ``pack_weight_for_serving`` — instead of unpacking the
        planes per call (falls back to the legacy unpack when absent);
      * 'ref' is the jnp oracle on XLA-built planes.
    Tile blocks default to the autotune cache / decode-aware heuristics.
    """
    fa, fx = fmt(fmt_a), fmt(fmt_x)
    assert a_planes.shape[0] == k_bits + bool(a_has_mask), \
        (a_planes.shape, k_bits, a_has_mask)

    if backend == "mxu":
        if a_int8 is not None:
            # load-time shadow [n, M]: contract directly against its
            # leading dim — no per-call transpose of the resident operand
            dt = (jnp.int8 if _int8_operands(fa, k_bits, fx, l_bits)
                  else jnp.int32)
            return jax.lax.dot_general(
                jnp.asarray(x_int, jnp.int32).astype(dt), a_int8.astype(dt),
                (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32)
        return _mxu_dot(x_int, _planes_to_int(a_planes, n, k_bits, fa),
                        k_bits, l_bits, fa, fx)

    w_ext, _, pop_a, pop_x, const = extended_weights(
        fa, k_bits, fx, l_bits, n=n, a_has_mask=a_has_mask)
    ap = jnp.asarray(a_planes, jnp.uint32)
    w = jnp.asarray(w_ext)
    if backend == "ref":
        xp = pack_bits(to_bitplanes(x_int, l_bits, fx))
        return bitserial_matmul_packed_ref(xp, ap, w)
    if backend == "pallas":
        u = levels_to_stack(to_levels(x_int, l_bits, fx), ap.shape[-1])
        return bitserial_matmul_sliced(u, ap, w, l_bits=l_bits, pop_a=pop_a,
                                       pop_x=pop_x, const=const,
                                       block_b=block_b, block_m=block_m,
                                       block_w=block_w, row_chunk=row_chunk,
                                       interpret=_auto_interpret())
    raise ValueError(f"unknown backend {backend}")


def ppac_cycles(k_bits: int, l_bits: int) -> int:
    """Emulated-PPAC cycle cost of one multi-bit MVP (§III-C)."""
    return k_bits * l_bits
