"""Pallas TPU kernel: fused multi-bitplane (bit-serial) MVP — paper §III-C.

PPAC computes a K-bit-matrix × L-bit-vector MVP over K*L clock cycles of
1-bit AND/XNOR popcounts with shift-add accumulation in the two row-ALU
accumulators. On TPU we fuse the whole K×L schedule into one kernel: the
accumulator lives in VMEM across the lane-tile grid dimension, and each
"cycle" processes a [tb × tm × tw] tile instead of one word:

    y[b, m] = sum_{k<K1} sum_{l<L1} W[k, l] * sum_w popcount(a[k,m,w] & x[l,b,w])

The plane-pair weight matrix W encodes the entire number-format algebra
(Table I + eqs. (2)/(3) offsets): signed (int) MSB planes get negative
weights, and oddint's affine offset is folded in by appending a constant
"mask" plane (the all-valid-bits vector) — the exact generalization of the
paper's h̄(a, 1)/h̄(a, 0) offset trick. See ops.py for the construction.

Tiling, padding, lane streaming and the ``row_chunk`` subrow chunking all
come from :mod:`repro.kernels.tiling`: the plane stacks ride along as
leading block dims (whole stack resident per tile), so arbitrarily large
B/M/W stream through fixed VMEM tiles exactly like the 1-bit kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..tiling import lane_stream_call, plan_tiles, subrow_popcount_sum


def _bitserial_kernel(x_ref, a_ref, w_ref, o_ref, *, k1: int, l1: int,
                      row_chunk: int):
    """x_ref [l1, tb, tw] u32; a_ref [k1, tm, tw] u32; w_ref [k1, l1] i32;
    o_ref [tb, tm] i32 (accumulated over the lane grid dim)."""
    _, tb, _ = x_ref.shape
    tm = a_ref.shape[1]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    acc = jnp.zeros((tb, tm), jnp.int32)
    for k in range(k1):          # static unroll: K1*L1 <= ~36 "cycles"
        a_k = a_ref[k]           # [tm, tw]
        for l in range(l1):
            s_kl = subrow_popcount_sum(x_ref[l], a_k,
                                       bit_op=jnp.bitwise_and,
                                       row_chunk=row_chunk)
            acc = acc + w_ref[k, l] * s_kl
    o_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_m", "block_w", "row_chunk", "interpret"),
)
def bitserial_matmul_packed(
    x_planes,
    a_planes,
    weights,
    *,
    block_b: int = 64,
    block_m: int = 128,
    block_w: int = 32,
    row_chunk: int = 8,
    interpret: bool = False,
):
    """y[b,m] = sum_{k,l} W[k,l] * sum_w popcount(a[k,m,w] & x[l,b,w]).

    x_planes: [L1, B, W] uint32; a_planes: [K1, M, W] uint32;
    weights: [K1, L1] int32. Returns [B, M] int32. Padding lanes must be 0
    in every plane (AND with 0 contributes nothing).
    """
    l1, b, w = x_planes.shape
    k1, m, w2 = a_planes.shape
    assert w == w2 and weights.shape == (k1, l1)

    plan = plan_tiles(b, m, w, block_b=block_b, block_m=block_m,
                      block_w=block_w, row_chunk=row_chunk)
    return lane_stream_call(
        functools.partial(_bitserial_kernel, k1=k1, l1=l1, row_chunk=plan.rc),
        x_planes, a_planes, plan,
        x_leading=l1, a_leading=k1,
        extra_inputs=(jnp.asarray(weights, jnp.int32),),
        extra_specs=(pl.BlockSpec((k1, l1), lambda i, j, k: (0, 0)),),
        interpret=interpret)
