"""Pallas TPU kernels: fused multi-bitplane (bit-serial) MVP — paper §III-C.

PPAC computes a K-bit-matrix × L-bit-vector MVP over K*L clock cycles of
1-bit AND/XNOR popcounts with shift-add accumulation in the two row-ALU
accumulators. On TPU we fuse the whole K×L schedule into one kernel: the
accumulator lives in VMEM across the lane-tile grid dimension, and each
"cycle" processes a [tb × tm × tw] tile instead of one word:

    y[b, m] = sum_{k<K1} sum_{l<L1} W[k, l] * sum_w popcount(a[k,m,w] & x[l,b,w])

The plane-pair weight matrix W encodes the entire number-format algebra
(Table I + eqs. (2)/(3) offsets). Affine offsets (oddint's -(2^L - 1), the
eq. (2)/(3) precompute) ride in an *extended* [K1+1, L1+1] weight matrix
instead of concatenated mask planes:

    W_ext[k, L1]   weights popcount(a_k)[m]   (x-side all-ones mask folded
                                               into the resident planes —
                                               padding lanes are zero, so
                                               popcount(a & 1...1) == popcount(a))
    W_ext[K1, l]   weights popcount(x_l)[b]   (a-side mask, same argument)
    W_ext[K1, L1]  a constant added once per output block

so no kernel launch ever concatenates or broadcasts onto the resident
[K, M, W] weight — the zero-repack invariant of the serving fast path.
A resident weight *may* carry a stored mask plane (packed at load time by
``core.engine.pack_weight_for_serving`` for offset formats); it is just an
ordinary K1-th plane here.

``bitserial_matmul_sliced`` is the decode fast path: the streaming operand
arrives as L-bit *level codes* (uint32, bit-transposed to [32, B, W]) and
the per-plane packed words are built inside the kernel body with one
shift/AND per plane — no ``to_bitplanes``/``pack_bits`` XLA round trip
around the launch.

Tiling, padding, lane streaming and the ``row_chunk`` subrow chunking all
come from :mod:`repro.kernels.tiling`: the plane stacks ride along as
leading block dims (whole stack resident per tile), so arbitrarily large
B/M/W stream through fixed VMEM tiles exactly like the 1-bit kernels.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from jax import lax
from jax.experimental import pallas as pl

from ..tiling import lane_stream_call, plan_for, subrow_popcount_sum


def _lane_popcount_rows(tile):
    """[rows, tw] uint32 -> [rows] int32 total set bits of this lane tile."""
    return jnp.sum(lax.population_count(tile).astype(jnp.int32), axis=-1)


def _accumulate_bitserial(x_of, a_ref, w_ref, o_ref, *, k1: int, l1: int,
                          row_chunk: int, pop_a: bool, pop_x: bool,
                          const: bool):
    """Shared body: x plane ``l`` is ``x_of(l)`` [tb, tw]; a_ref holds the
    resident [k1, tm, tw] plane stack; w_ref is the extended [k1+1, l1+1]
    weight matrix (see module docstring)."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        if const:
            # the offset·offset constant lands once per output block
            o_ref[...] = jnp.full(o_ref.shape, w_ref[k1, l1], jnp.int32)
        else:
            o_ref[...] = jnp.zeros_like(o_ref)

    tb = x_of(0).shape[0]
    tm = a_ref.shape[1]
    acc = jnp.zeros((tb, tm), jnp.int32)
    for k in range(k1):          # static unroll: K1*L1 <= ~36 "cycles"
        a_k = a_ref[k]           # [tm, tw]
        if pop_a:
            acc = acc + w_ref[k, l1] * _lane_popcount_rows(a_k)[None, :]
        for l in range(l1):
            s_kl = subrow_popcount_sum(x_of(l), a_k,
                                       bit_op=jnp.bitwise_and,
                                       row_chunk=row_chunk)
            acc = acc + w_ref[k, l] * s_kl
    if pop_x:
        for l in range(l1):
            acc = acc + w_ref[k1, l] * _lane_popcount_rows(x_of(l))[:, None]
    o_ref[...] += acc


def _bitserial_kernel(x_ref, a_ref, w_ref, o_ref, *, k1: int, l1: int,
                      row_chunk: int, pop_a: bool, pop_x: bool, const: bool):
    """x_ref [l1, tb, tw] u32 packed planes; a_ref [k1, tm, tw] u32;
    w_ref [k1+1, l1+1] i32; o_ref [tb, tm] i32 (lane-grid accumulated)."""
    _accumulate_bitserial(lambda l: x_ref[l], a_ref, w_ref, o_ref,
                          k1=k1, l1=l1, row_chunk=row_chunk,
                          pop_a=pop_a, pop_x=pop_x, const=const)


def _bitserial_sliced_kernel(u_ref, a_ref, w_ref, o_ref, *, k1: int, l1: int,
                             row_chunk: int, pop_a: bool, pop_x: bool,
                             const: bool):
    """In-kernel bit-slicing body. u_ref [32, tb, tw] u32 holds level codes
    bit-transposed (u_ref[t, b, w] = level code of logical bit 32w+t); each
    of the l1 packed activation planes is built with one shift/AND and a
    shift-weighted reduce over the 32 bit positions — the streaming operand
    never round-trips through XLA bitplanes."""
    shifts = (jnp.uint32(1) << lax.broadcasted_iota(jnp.uint32, (32, 1, 1), 0))
    u = u_ref[...]
    x_planes = [
        jnp.sum(((u >> jnp.uint32(l)) & jnp.uint32(1)) * shifts,
                axis=0, dtype=jnp.uint32)
        for l in range(l1)
    ]
    _accumulate_bitserial(lambda l: x_planes[l], a_ref, w_ref, o_ref,
                          k1=k1, l1=l1, row_chunk=row_chunk,
                          pop_a=pop_a, pop_x=pop_x, const=const)


def _normalize_weights(weights, k1: int, l1: int, pop_a, pop_x, const):
    """Accept a plain [k1, l1] plane-pair matrix (pad a zero mask row/col)
    or an extended [k1+1, l1+1] one. Returns (w_ext, pop_a, pop_x, const)
    with unspecified flags resolved conservatively."""
    weights = jnp.asarray(weights, jnp.int32)
    if weights.shape == (k1, l1):
        weights = jnp.pad(weights, ((0, 1), (0, 1)))
        flags = (False, False, False)
    elif weights.shape == (k1 + 1, l1 + 1):
        flags = (True, True, True)  # unknown contents: keep every term
    else:
        raise ValueError(f"weights shape {weights.shape} matches neither "
                         f"[{k1},{l1}] nor [{k1 + 1},{l1 + 1}]")
    pop_a = flags[0] if pop_a is None else pop_a
    pop_x = flags[1] if pop_x is None else pop_x
    const = flags[2] if const is None else const
    return weights, pop_a, pop_x, const


@functools.partial(
    jax.jit,
    static_argnames=("pop_a", "pop_x", "const", "block_b", "block_m",
                     "block_w", "row_chunk", "interpret"),
)
def bitserial_matmul_packed(
    x_planes,
    a_planes,
    weights,
    *,
    pop_a=None,
    pop_x=None,
    const=None,
    block_b=None,
    block_m=None,
    block_w=None,
    row_chunk=None,
    interpret: bool = False,
):
    """y[b,m] = sum_{k,l} W[k,l] * sum_w popcount(a[k,m,w] & x[l,b,w])
    (+ the extended popcount/constant terms when W is [K1+1, L1+1]).

    x_planes: [L1, B, W] uint32; a_planes: [K1, M, W] uint32; weights:
    [K1, L1] int32 (plain) or [K1+1, L1+1] (extended; ``pop_a``/``pop_x``/
    ``const`` switch the mask-row/col/corner terms on). Returns [B, M]
    int32. Padding lanes must be 0 in every plane. Blocks default to the
    plan cache / decode-aware heuristics (:func:`repro.kernels.tiling.plan_for`).
    """
    l1, b, w = x_planes.shape
    k1, m, w2 = a_planes.shape
    assert w == w2
    weights, pop_a, pop_x, const = _normalize_weights(
        weights, k1, l1, pop_a, pop_x, const)

    plan = plan_for("bitserial", b, m, w, block_b=block_b, block_m=block_m,
                    block_w=block_w, row_chunk=row_chunk)
    return lane_stream_call(
        functools.partial(_bitserial_kernel, k1=k1, l1=l1, row_chunk=plan.rc,
                          pop_a=pop_a, pop_x=pop_x, const=const),
        x_planes, a_planes, plan,
        x_leading=l1, a_leading=k1,
        extra_inputs=(weights,),
        extra_specs=(pl.BlockSpec((k1 + 1, l1 + 1), lambda i, j, k: (0, 0)),),
        interpret=interpret)


@functools.partial(
    jax.jit,
    static_argnames=("l_bits", "pop_a", "pop_x", "const", "block_b",
                     "block_m", "block_w", "row_chunk", "interpret"),
)
def bitserial_matmul_sliced(
    u_stack,
    a_planes,
    weights,
    *,
    l_bits: int,
    pop_a=None,
    pop_x=None,
    const=None,
    block_b=None,
    block_m=None,
    block_w=None,
    row_chunk=None,
    interpret: bool = False,
):
    """Decode fast path: same contract as :func:`bitserial_matmul_packed`
    but the streaming operand is ``u_stack`` [32, B, W] uint32 — L-bit
    level codes bit-transposed so u_stack[t, b, w] codes logical bit
    32w+t — and the per-plane packed words are built inside the kernel.
    Zero-padded entries (level code 0) contribute no set bits.
    """
    _, b, w = u_stack.shape
    k1, m, w2 = a_planes.shape
    assert w == w2
    weights, pop_a, pop_x, const = _normalize_weights(
        weights, k1, l_bits, pop_a, pop_x, const)

    plan = plan_for("bitserial_sliced", b, m, w, block_b=block_b,
                    block_m=block_m, block_w=block_w, row_chunk=row_chunk)
    return lane_stream_call(
        functools.partial(_bitserial_sliced_kernel, k1=k1, l1=l_bits,
                          row_chunk=plan.rc, pop_a=pop_a, pop_x=pop_x,
                          const=const),
        u_stack, a_planes, plan,
        x_leading=32, a_leading=k1,
        extra_inputs=(weights,),
        extra_specs=(pl.BlockSpec((k1 + 1, l_bits + 1),
                                  lambda i, j, k: (0, 0)),),
        interpret=interpret)
