"""Pallas TPU kernel: fused multi-bitplane (bit-serial) MVP — paper §III-C.

PPAC computes a K-bit-matrix × L-bit-vector MVP over K*L clock cycles of
1-bit AND/XNOR popcounts with shift-add accumulation in the two row-ALU
accumulators. On TPU we fuse the whole K×L schedule into one kernel: the
accumulator lives in VMEM across the lane-tile grid dimension, and each
"cycle" processes a [tb × tm × tw] tile instead of one word:

    y[b, m] = sum_{k<K1} sum_{l<L1} W[k, l] * sum_w popcount(a[k,m,w] & x[l,b,w])

The plane-pair weight matrix W encodes the entire number-format algebra
(Table I + eqs. (2)/(3) offsets): signed (int) MSB planes get negative
weights, and oddint's affine offset is folded in by appending a constant
"mask" plane (the all-valid-bits vector) — the exact generalization of the
paper's h̄(a, 1)/h̄(a, 0) offset trick. See ops.py for the construction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _bitserial_kernel(x_ref, a_ref, w_ref, o_ref, *, k1: int, l1: int,
                      row_chunk: int):
    """x_ref [l1, tb, tw] u32; a_ref [k1, tm, tw] u32; w_ref [k1, l1] i32;
    o_ref [tb, tm] i32 (accumulated over the lane grid dim)."""
    _, tb, tw = x_ref.shape
    tm = a_ref.shape[1]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    n_chunks = tm // row_chunk
    acc = jnp.zeros((tb, tm), jnp.int32)
    for k in range(k1):          # static unroll: K1*L1 <= ~36 "cycles"
        a_k = a_ref[k]           # [tm, tw]
        for l in range(l1):
            x_l = x_ref[l]       # [tb, tw]
            w_kl = w_ref[k, l]

            def body(i, s):
                a_c = lax.dynamic_slice_in_dim(a_k, i * row_chunk, row_chunk, 0)
                bits = jnp.bitwise_and(x_l[:, None, :], a_c[None, :, :])
                pc = lax.population_count(bits).astype(jnp.int32)
                part = jnp.sum(pc, axis=-1)  # [tb, chunk]
                return lax.dynamic_update_slice_in_dim(s, part, i * row_chunk, 1)

            s_kl = lax.fori_loop(0, n_chunks, body,
                                 jnp.zeros((tb, tm), jnp.int32))
            acc = acc + w_kl * s_kl
    o_ref[...] += acc


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_m", "block_w", "row_chunk", "interpret"),
)
def bitserial_matmul_packed(
    x_planes,
    a_planes,
    weights,
    *,
    block_b: int = 64,
    block_m: int = 128,
    block_w: int = 32,
    row_chunk: int = 8,
    interpret: bool = False,
):
    """y[b,m] = sum_{k,l} W[k,l] * sum_w popcount(a[k,m,w] & x[l,b,w]).

    x_planes: [L1, B, W] uint32; a_planes: [K1, M, W] uint32;
    weights: [K1, L1] int32. Returns [B, M] int32. Padding lanes must be 0
    in every plane (AND with 0 contributes nothing).
    """
    l1, b, w = x_planes.shape
    k1, m, w2 = a_planes.shape
    assert w == w2 and weights.shape == (k1, l1)

    bb = min(block_b, _round_up(b, 8))
    bm = min(block_m, _round_up(m, 8))
    bw = min(block_w, _round_up(w, 128))
    rc = min(row_chunk, bm)
    while bm % rc:
        rc -= 1

    bp, mp, wp = _round_up(b, bb), _round_up(m, bm), _round_up(w, bw)
    x_p = jnp.pad(x_planes.astype(jnp.uint32),
                  ((0, 0), (0, bp - b), (0, wp - w)))
    a_p = jnp.pad(a_planes.astype(jnp.uint32),
                  ((0, 0), (0, mp - m), (0, wp - w)))

    grid = (bp // bb, mp // bm, wp // bw)
    out = pl.pallas_call(
        functools.partial(_bitserial_kernel, k1=k1, l1=l1, row_chunk=rc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((l1, bb, bw), lambda i, j, k: (0, i, k)),
            pl.BlockSpec((k1, bm, bw), lambda i, j, k: (0, j, k)),
            pl.BlockSpec((k1, l1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bb, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, mp), jnp.int32),
        interpret=interpret,
    )(x_p, a_p, weights.astype(jnp.int32))
    return out[:b, :m]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
