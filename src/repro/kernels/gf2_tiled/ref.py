"""Pure-jnp oracle for the tiled GF(2) matmul kernel.

Like the kernel, it works on packed uint32 lanes directly (no unpacking to
uint8 bit planes): the GF(2) inner product is the parity of the AND
popcount, and parity distributes over the lane sum.
"""
from __future__ import annotations

import jax.numpy as jnp
from jax import lax


def gf2_matmul_packed_ref(x_packed, a_packed):
    """y[b,m] = parity(sum_w popcount(x[b,w] & a[m,w])) — reference, O(B*M*W)."""
    x = jnp.asarray(x_packed, jnp.uint32)[:, None, :]   # [B,1,W]
    a = jnp.asarray(a_packed, jnp.uint32)[None, :, :]   # [1,M,W]
    pc = lax.population_count(jnp.bitwise_and(x, a)).astype(jnp.int32)
    return jnp.sum(pc, axis=-1) & 1
