"""Pallas TPU kernel: tiled GF(2) matmul over streamed packed-bit operands.

The crypto/FEC primitive of the paper's §III-D at scale: for packed uint32
inputs x [B, W] against a resident matrix a [M, W] (W lanes of 32 bit-cells
each), compute

    y[b, m] = ⊕_j  x[b, j] & a[m, j]          (GF(2) inner product)
            = parity( sum_w popcount(x[b, w] & a[m, w]) )

for arbitrarily large n = 32·W (n ≫ 256, i.e. many PPAC arrays side by
side).  The grid streams the lane dimension in [tw] tiles (grid dim 2,
innermost); each tile contributes the parity of its local AND-popcount and
the revisited output block *XOR-accumulates* the per-tile parities — the
TPU analogue of chaining the single-bit GF(2) outputs of adjacent PPAC
arrays through an XOR tree instead of an adder tree.  Operands stay in
packed uint32 form throughout; bits are never unpacked to uint8 planes.

The inner broadcast is chunked over rows of the a tile (``row_chunk``) to
bound the [tb, chunk, tw] popcount intermediate, exactly like binary_mvp
(the subrow partitioning of Fig. 2).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _gf2_matmul_kernel(x_ref, a_ref, o_ref, *, row_chunk: int):
    """x_ref: [tb, tw] uint32; a_ref: [tm, tw] uint32; o_ref: [tb, tm] int32
    holding the running parity (0/1), XOR-accumulated over grid dim 2."""
    tb, tw = x_ref.shape
    tm = a_ref.shape[0]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [tb, tw]
    a = a_ref[...]  # [tm, tw]
    n_chunks = tm // row_chunk

    def body(i, acc):
        a_c = lax.dynamic_slice_in_dim(a, i * row_chunk, row_chunk, axis=0)
        bits = jnp.bitwise_and(x[:, None, :], a_c[None, :, :])
        pc = lax.population_count(bits).astype(jnp.int32)  # [tb, chunk, tw]
        par = jnp.sum(pc, axis=-1) & 1                     # [tb, chunk]
        return lax.dynamic_update_slice_in_dim(acc, par, i * row_chunk, axis=1)

    tile_par = lax.fori_loop(
        0, n_chunks, body, jnp.zeros((tb, tm), jnp.int32), unroll=False
    )
    o_ref[...] ^= tile_par


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_m", "block_w", "row_chunk", "interpret"),
)
def gf2_matmul_packed(
    x_packed,
    a_packed,
    *,
    block_b: int = 64,
    block_m: int = 128,
    block_w: int = 128,  # lane tiles stay 128-multiples for native lowering
    row_chunk: int = 8,
    interpret: bool = False,
):
    """y[b,m] = parity(sum_w popcount(x[b,w] & a[m,w])) — int32 in {0,1}.

    x_packed: [B, W] uint32, a_packed: [M, W] uint32 -> [B, M] int32.
    Shapes are padded up to tile multiples internally (padding lanes are
    zero: AND against zero contributes 0 to every popcount, so padding
    never flips a parity).
    """
    b, w = x_packed.shape
    m, w2 = a_packed.shape
    assert w == w2, (w, w2)

    bb = min(block_b, _round_up(b, 8))
    bm = min(block_m, _round_up(m, 8))
    bw = min(block_w, _round_up(w, 128))
    rc = min(row_chunk, bm)
    while bm % rc:
        rc -= 1

    bp, mp, wp = _round_up(b, bb), _round_up(m, bm), _round_up(w, bw)
    x_p = jnp.pad(x_packed.astype(jnp.uint32), ((0, bp - b), (0, wp - w)))
    a_p = jnp.pad(a_packed.astype(jnp.uint32), ((0, mp - m), (0, wp - w)))

    grid = (bp // bb, mp // bm, wp // bw)
    out = pl.pallas_call(
        functools.partial(_gf2_matmul_kernel, row_chunk=rc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, bw), lambda i, j, k: (i, k)),
            pl.BlockSpec((bm, bw), lambda i, j, k: (j, k)),
        ],
        out_specs=pl.BlockSpec((bb, bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bp, mp), jnp.int32),
        interpret=interpret,
    )(x_p, a_p)
    return out[:b, :m]


def _round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult
