"""Pallas TPU kernel: tiled GF(2) matmul over streamed packed-bit operands.

The crypto/FEC primitive of the paper's §III-D at scale: for packed uint32
inputs x [B, W] against a resident matrix a [M, W] (W lanes of 32 bit-cells
each), compute

    y[b, m] = ⊕_j  x[b, j] & a[m, j]          (GF(2) inner product)
            = parity( sum_w popcount(x[b, w] & a[m, w]) )

for arbitrarily large n = 32·W (n ≫ 256, i.e. many PPAC arrays side by
side).  The lane-streamed grid comes from :mod:`repro.kernels.tiling`;
each lane tile contributes the parity of its local AND-popcount and the
revisited output block *XOR-accumulates* the per-tile parities — the TPU
analogue of chaining the single-bit GF(2) outputs of adjacent PPAC arrays
through an XOR tree instead of an adder tree.  Operands stay in packed
uint32 form throughout; bits are never unpacked to uint8 planes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..tiling import lane_stream_call, plan_tiles, subrow_popcount_sum


def _gf2_matmul_kernel(x_ref, a_ref, o_ref, *, row_chunk: int):
    """x_ref: [tb, tw] uint32; a_ref: [tm, tw] uint32; o_ref: [tb, tm] int32
    holding the running parity (0/1), XOR-accumulated over grid dim 2."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    tile_par = subrow_popcount_sum(x_ref[...], a_ref[...],
                                   bit_op=jnp.bitwise_and,
                                   row_chunk=row_chunk,
                                   postprocess=lambda p: p & 1)
    o_ref[...] ^= tile_par


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_m", "block_w", "row_chunk", "interpret"),
)
def gf2_matmul_packed(
    x_packed,
    a_packed,
    *,
    block_b: int = 64,
    block_m: int = 128,
    block_w: int = 128,  # lane tiles stay 128-multiples for native lowering
    row_chunk: int = 8,
    interpret: bool = False,
):
    """y[b,m] = parity(sum_w popcount(x[b,w] & a[m,w])) — int32 in {0,1}.

    x_packed: [B, W] uint32, a_packed: [M, W] uint32 -> [B, M] int32.
    Shapes are padded up to tile multiples internally (padding lanes are
    zero: AND against zero contributes 0 to every popcount, so padding
    never flips a parity).
    """
    b, w = x_packed.shape
    m, w2 = a_packed.shape
    assert w == w2, (w, w2)

    plan = plan_tiles(b, m, w, block_b=block_b, block_m=block_m,
                      block_w=block_w, row_chunk=row_chunk)
    return lane_stream_call(
        functools.partial(_gf2_matmul_kernel, row_chunk=plan.rc),
        x_packed, a_packed, plan, interpret=interpret)
