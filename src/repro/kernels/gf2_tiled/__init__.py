from .ops import gf2_matmul_tiled  # noqa: F401
