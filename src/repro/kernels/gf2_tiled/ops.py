"""Public jit'd wrapper for the tiled GF(2) matmul — backend dispatch.

Mirrors ``binary_mvp.ops``: packed uint32 operands, the true bit width
``n``, and a ``backend`` in

  'pallas' — the tiled XOR-parity-accumulating kernel (kernel.py);
             interpret mode off-TPU
  'ref'    — packed-lane jnp oracle (ref.py)
  'mxu'    — the LSB of binary_mvp's MXU and-dot (one shared lowering;
             it unpacks to int8 bits — the beyond-paper path)

'pallas' and 'ref' never unpack the operands to uint8 bit planes; all
three produce bit-identical results.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from ...core.backend import auto_interpret as _auto_interpret
from ..binary_mvp.ops import gf2_matmul as _gf2_matmul_mvp
from .kernel import gf2_matmul_packed
from .ref import gf2_matmul_packed_ref


@functools.partial(jax.jit, static_argnames=("n", "backend"))
def gf2_matmul_tiled(x_packed, a_packed, *, n: int, backend: str = "pallas"):
    """GF(2) MVP y = x Aᵀ over packed operands: [B, W] × [M, W] -> [B, M] uint8.

    ``n`` is the true bit width (lanes beyond it must be zero-padded, as
    :func:`repro.core.formats.pack_bits` guarantees).
    """
    if backend == "pallas":
        out = gf2_matmul_packed(x_packed, a_packed,
                                interpret=_auto_interpret())
    elif backend == "ref":
        out = gf2_matmul_packed_ref(x_packed, a_packed)
    elif backend == "mxu":
        # one shared MXU lowering: LSB of binary_mvp's and-dot
        out = _gf2_matmul_mvp(x_packed, a_packed, n=n, backend="mxu")
    else:
        raise ValueError(f"unknown backend {backend}")
    return out.astype(jnp.uint8)
