"""Shared tiling machinery for the PPAC Pallas kernels.

Every PPAC matmul-like kernel in this package streams the same way: packed
uint32 operands x [.., B, W] and a [.., M, W] are padded up to tile
multiples, a (B/bb, M/bm, W/bw) grid walks batch × row × lane tiles with
the lane dimension innermost, and the revisited [bb, bm] int32 output
block accumulates one contribution per lane tile (integer add for the
popcount modes, XOR for GF(2) parity). Inside a tile, the row dimension is
chunked (``row_chunk``) to bound the [bb, chunk, bw] popcount intermediate
— the TPU analogue of the paper's subrow partitioning (Fig. 2), which
bounds adder fan-in in hardware and VMEM footprint here.

This module owns that machinery once: tile planning (:func:`plan_tiles`),
zero-padding (:func:`pad_lanes`), the chunked popcount inner loop
(:func:`subrow_popcount_sum`) and the canonical lane-streamed
``pallas_call`` (:func:`lane_stream_call`). The per-mode kernels
(``binary_mvp``, ``bitserial_mvp``, ``gf2_tiled``) are thin bodies on top;
``hamming_topk`` reuses the planning + inner loop with its own 2-D grid
(its output is a running top-k, not a revisited matmul block).

Padding is always with zero lanes, which every mode tolerates by
construction: XOR of equal zeros and AND against zero both popcount to 0,
so padded bit-cells never change a sum or flip a parity.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

# TPU layout friendliness: lane (last) dims in multiples of 128, sublane
# (second-to-last) dims in multiples of 8.
LANE_MULTIPLE = 128
SUBLANE_MULTIPLE = 8


def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Resolved tile geometry for one lane-streamed kernel launch."""

    b: int          # logical batch rows
    m: int          # logical matrix rows
    w: int          # logical packed lanes
    bb: int         # batch tile
    bm: int         # row tile
    bw: int         # lane tile
    rc: int         # subrow chunk (divides bm)
    bp: int         # padded batch
    mp: int         # padded rows
    wp: int         # padded lanes

    @property
    def grid(self):
        """(batch tiles, row tiles, lane tiles) — lane dim innermost."""
        return (self.bp // self.bb, self.mp // self.bm, self.wp // self.bw)


def plan_tiles(b: int, m: int, w: int, *, block_b: int = 64,
               block_m: int = 128, block_w: int = 64,
               row_chunk: int = 8) -> TilePlan:
    """Clamp requested block sizes to the (rounded-up) operand shape and
    derive the padded geometry. ``row_chunk`` is shrunk until it divides
    the row tile."""
    bb = min(block_b, round_up(b, SUBLANE_MULTIPLE))
    bm = min(block_m, round_up(m, SUBLANE_MULTIPLE))
    bw = min(block_w, round_up(w, LANE_MULTIPLE))
    rc = min(row_chunk, bm)
    while bm % rc:
        rc -= 1
    return TilePlan(b, m, w, bb, bm, bw, rc,
                    round_up(b, bb), round_up(m, bm), round_up(w, bw))


def pad_lanes(arr, rows_to: int, lanes_to: int) -> jnp.ndarray:
    """Zero-pad the trailing [rows, lanes] dims of a packed uint32 operand;
    leading (bit-plane) dims pass through untouched."""
    arr = jnp.asarray(arr, jnp.uint32)
    pads = ([(0, 0)] * (arr.ndim - 2)
            + [(0, rows_to - arr.shape[-2]), (0, lanes_to - arr.shape[-1])])
    return jnp.pad(arr, pads)


def subrow_popcount_sum(x, a, *, bit_op, row_chunk: int, postprocess=None):
    """S[b, r] = sum_w popcount(bit_op(x[b, w], a[r, w])) over one tile.

    x: [tb, tw] uint32, a: [tm, tw] uint32 -> [tb, tm] int32. The row dim
    is chunked (``row_chunk`` rows at a time) to bound the [tb, chunk, tw]
    popcount intermediate — the subrow partitioning of Fig. 2.
    ``postprocess`` maps each [tb, chunk] int32 partial (e.g. ``& 1`` for
    GF(2) parity) before it lands in the result.
    """
    tb = x.shape[0]
    tm = a.shape[0]
    n_chunks = tm // row_chunk

    def body(i, acc):
        a_c = lax.dynamic_slice_in_dim(a, i * row_chunk, row_chunk, axis=0)
        bits = bit_op(x[:, None, :], a_c[None, :, :])
        pc = lax.population_count(bits).astype(jnp.int32)  # [tb, chunk, tw]
        part = jnp.sum(pc, axis=-1)                        # [tb, chunk]
        if postprocess is not None:
            part = postprocess(part)
        return lax.dynamic_update_slice_in_dim(acc, part, i * row_chunk, axis=1)

    return lax.fori_loop(0, n_chunks, body, jnp.zeros((tb, tm), jnp.int32),
                         unroll=False)


def _x_spec(plan: TilePlan, leading: int):
    if leading:
        return pl.BlockSpec((leading, plan.bb, plan.bw),
                            lambda i, j, k: (0, i, k))
    return pl.BlockSpec((plan.bb, plan.bw), lambda i, j, k: (i, k))


def _a_spec(plan: TilePlan, leading: int):
    if leading:
        return pl.BlockSpec((leading, plan.bm, plan.bw),
                            lambda i, j, k: (0, j, k))
    return pl.BlockSpec((plan.bm, plan.bw), lambda i, j, k: (j, k))


def lane_stream_call(kernel_body, x_packed, a_packed, plan: TilePlan, *,
                     x_leading: int = 0, a_leading: int = 0,
                     extra_inputs=(), extra_specs=(),
                     interpret: bool = False):
    """Run ``kernel_body`` on the canonical lane-streamed grid.

    Pads the operands per ``plan``, streams x tiles along grid dims (0, 2)
    and a tiles along (1, 2), hands any ``extra_inputs`` through with their
    ``extra_specs``, and revisits the [bb, bm] int32 output block across
    grid dim 2 (the lane stream) — the body must init it at
    ``pl.program_id(2) == 0`` and accumulate into it. Returns the result
    cropped back to the logical [b, m].

    ``x_leading``/``a_leading`` carry a bit-plane stack (bitserial MVP):
    nonzero values make the operand [leading, rows, lanes] with the whole
    plane stack resident per tile.
    """
    x_p = pad_lanes(x_packed, plan.bp, plan.wp)
    a_p = pad_lanes(a_packed, plan.mp, plan.wp)
    out = pl.pallas_call(
        kernel_body,
        grid=plan.grid,
        in_specs=[_x_spec(plan, x_leading), _a_spec(plan, a_leading),
                  *extra_specs],
        out_specs=pl.BlockSpec((plan.bb, plan.bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((plan.bp, plan.mp), jnp.int32),
        interpret=interpret,
    )(x_p, a_p, *extra_inputs)
    return out[:plan.b, :plan.m]
