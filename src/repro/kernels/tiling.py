"""Shared tiling machinery for the PPAC Pallas kernels.

Every PPAC matmul-like kernel in this package streams the same way: packed
uint32 operands x [.., B, W] and a [.., M, W] are padded up to tile
multiples, a (B/bb, M/bm, W/bw) grid walks batch × row × lane tiles with
the lane dimension innermost, and the revisited [bb, bm] int32 output
block accumulates one contribution per lane tile (integer add for the
popcount modes, XOR for GF(2) parity). Inside a tile, the row dimension is
chunked (``row_chunk``) to bound the [bb, chunk, bw] popcount intermediate
— the TPU analogue of the paper's subrow partitioning (Fig. 2), which
bounds adder fan-in in hardware and VMEM footprint here.

This module owns that machinery once: tile planning (:func:`plan_tiles`),
zero-padding (:func:`pad_lanes`), the chunked popcount inner loop
(:func:`subrow_popcount_sum`) and the canonical lane-streamed
``pallas_call`` (:func:`lane_stream_call`). The per-mode kernels
(``binary_mvp``, ``bitserial_mvp``, ``gf2_tiled``) are thin bodies on top;
``hamming_topk`` reuses the planning + inner loop with its own 2-D grid
(its output is a running top-k, not a revisited matmul block).

Tile-plan selection (:func:`plan_for`) is three-tiered: an explicit block
override always wins; otherwise a measured autotune result from the
persisted JSON cache (keyed on mode × logical shape × platform, refreshed
via :func:`autotune_plan`); otherwise shape-aware defaults — decode steps
have tiny batches, so small-B launches get a thin batch tile and a fatter
row/lane tile instead of the generic 64-row batch block.

Padding is always with zero lanes, which every mode tolerates by
construction: XOR of equal zeros and AND against zero both popcount to 0,
so padded bit-cells never change a sum or flip a parity.
"""
from __future__ import annotations

import dataclasses
import json
import math
import os
import time
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

try:  # TPU compiler hints (grid dimension semantics); absent on old jax
    from jax.experimental.pallas import tpu as pltpu
except ImportError:  # pragma: no cover
    pltpu = None

from ..obs import ledger as _flight

# TPU layout friendliness: lane (last) dims in multiples of 128, sublane
# (second-to-last) dims in multiples of 8.
LANE_MULTIPLE = 128
SUBLANE_MULTIPLE = 8

# Batch/row/lane tiles stream independently; only the lane (accumulation)
# dimension carries a loop dependence through the revisited output block.
GRID_SEMANTICS = ("parallel", "parallel", "arbitrary")


def round_up(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class TilePlan:
    """Resolved tile geometry for one lane-streamed kernel launch."""

    b: int          # logical batch rows
    m: int          # logical matrix rows
    w: int          # logical packed lanes
    bb: int         # batch tile
    bm: int         # row tile
    bw: int         # lane tile
    rc: int         # subrow chunk (divides bm)
    bp: int         # padded batch
    mp: int         # padded rows
    wp: int         # padded lanes

    @property
    def grid(self):
        """(batch tiles, row tiles, lane tiles) — lane dim innermost."""
        return (self.bp // self.bb, self.mp // self.bm, self.wp // self.bw)

    @property
    def blocks(self) -> Dict[str, int]:
        """The four tunable knobs, as kwargs for the kernel wrappers."""
        return dict(block_b=self.bb, block_m=self.bm, block_w=self.bw,
                    row_chunk=self.rc)


def plan_tiles(b: int, m: int, w: int, *, block_b: int = 64,
               block_m: int = 128, block_w: int = 64,
               row_chunk: int = 8) -> TilePlan:
    """Clamp requested block sizes to the (rounded-up) operand shape and
    derive the padded geometry. The row tile is rounded *up* to a multiple
    of ``row_chunk`` so the requested chunk is honored verbatim (shrinking
    the chunk instead used to silently degrade prime row tiles to
    ``row_chunk=1`` — a 8x fatter popcount loop)."""
    bb = min(block_b, round_up(b, SUBLANE_MULTIPLE))
    bm = min(block_m, round_up(m, SUBLANE_MULTIPLE))
    bw = min(block_w, round_up(w, LANE_MULTIPLE))
    rc = max(1, min(row_chunk, bm))
    # honor both the chunk and the sublane layout rule at once
    bm = round_up(bm, math.lcm(rc, SUBLANE_MULTIPLE))
    plan = TilePlan(b, m, w, bb, bm, bw, rc,
                    round_up(b, bb), round_up(m, bm), round_up(w, bw))
    # flight recorder: attach the resolved plan to the launch currently
    # being recorded (no-op unless a ledger is open AND a launch is live)
    _flight.note_plan(plan)
    return plan


# ---------------------------------------------------------------------------
# Decode-aware defaults + persisted autotune cache
# ---------------------------------------------------------------------------

CACHE_ENV = "PPAC_TILE_CACHE"
_DEFAULT_CACHE = "~/.cache/ppac/tile_plans.json"


def default_blocks(b: int, m: int, w: int) -> Dict[str, int]:
    """Shape-aware default blocks. Decode steps stream a tiny batch (a few
    tokens) against a large resident matrix: an 8-row batch tile frees
    VMEM for a fatter row tile, so the K·L popcount schedule amortizes
    over more resident rows per grid step."""
    if b <= 8:
        return dict(block_b=SUBLANE_MULTIPLE, block_m=256, block_w=64,
                    row_chunk=8)
    if b <= 32:
        return dict(block_b=32, block_m=192, block_w=64, row_chunk=8)
    return dict(block_b=64, block_m=128, block_w=64, row_chunk=8)


class PlanCache:
    """Persisted (mode, shape, platform) -> block-dict autotune cache.

    One tiny JSON file (``PPAC_TILE_CACHE`` env var, default
    ``~/.cache/ppac/tile_plans.json``); loaded lazily once per process,
    rewritten atomically on every :meth:`put`.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = os.path.expanduser(
            path or os.environ.get(CACHE_ENV, _DEFAULT_CACHE))
        self._data: Optional[Dict[str, Dict[str, int]]] = None

    @staticmethod
    def key(mode: str, b: int, m: int, w: int,
            platform: Optional[str] = None) -> str:
        platform = platform or jax.default_backend()
        return f"{mode}|b{b}|m{m}|w{w}|{platform}"

    def _load(self) -> Dict[str, Dict[str, int]]:
        if self._data is None:
            try:
                with open(self.path) as f:
                    self._data = json.load(f)
            except (OSError, ValueError):
                self._data = {}
        return self._data

    def get(self, mode: str, b: int, m: int, w: int) -> Optional[Dict[str, int]]:
        hit = self._load().get(self.key(mode, b, m, w))
        if hit is None:
            return None
        return {k: int(hit[k])
                for k in ("block_b", "block_m", "block_w", "row_chunk")
                if k in hit}

    def put(self, mode: str, b: int, m: int, w: int,
            blocks: Dict[str, int], *, us: Optional[float] = None) -> None:
        data = self._load()
        entry = dict(blocks)
        if us is not None:
            entry["us"] = round(float(us), 2)
        data[self.key(mode, b, m, w)] = entry
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(data, f, indent=1, sort_keys=True)
        os.replace(tmp, self.path)


_CACHES: Dict[str, PlanCache] = {}


def plan_cache() -> PlanCache:
    """Process-wide cache for the path currently selected by the env."""
    path = os.path.expanduser(os.environ.get(CACHE_ENV, _DEFAULT_CACHE))
    if path not in _CACHES:
        _CACHES[path] = PlanCache(path)
    return _CACHES[path]


def plan_for(mode: str, b: int, m: int, w: int, *,
             block_b: Optional[int] = None, block_m: Optional[int] = None,
             block_w: Optional[int] = None, row_chunk: Optional[int] = None,
             use_cache: bool = True) -> TilePlan:
    """Resolve the tile plan for one launch: explicit overrides win, then
    the autotune cache, then the decode-aware defaults."""
    blocks = default_blocks(b, m, w)
    if use_cache:
        cached = plan_cache().get(mode, b, m, w)
        if cached:
            blocks.update(cached)
    for name, val in (("block_b", block_b), ("block_m", block_m),
                      ("block_w", block_w), ("row_chunk", row_chunk)):
        if val is not None:
            blocks[name] = val
    return plan_tiles(b, m, w, **blocks)


def candidate_blocks(b: int, m: int, w: int):
    """Small measured-search space around the defaults, deduplicated by
    resolved geometry (clamping makes many candidates collapse on small
    shapes)."""
    seen, out = set(), []
    for bb in (SUBLANE_MULTIPLE, 32, 64):
        for bm in (64, 128, 256, 512):
            for bw in (32, 64, 128):
                for rc in (4, 8, 16):
                    plan = plan_tiles(b, m, w, block_b=bb, block_m=bm,
                                      block_w=bw, row_chunk=rc)
                    sig = (plan.bb, plan.bm, plan.bw, plan.rc)
                    if sig in seen:
                        continue
                    seen.add(sig)
                    out.append(dict(block_b=bb, block_m=bm, block_w=bw,
                                    row_chunk=rc))
    return out


def quick_candidates(b: int, m: int, w: int):
    """A handful of variations around the shape defaults — the compile
    cost per candidate dominates off-TPU, so the serving autotune sweeps
    this trimmed set by default (full sweep: :func:`candidate_blocks`)."""
    base = default_blocks(b, m, w)
    trial = [base,
             {**base, "block_m": 128}, {**base, "block_m": 512},
             {**base, "block_w": 32}, {**base, "row_chunk": 16}]
    seen, out = set(), []
    for blocks in trial:
        plan = plan_tiles(b, m, w, **blocks)
        sig = (plan.bb, plan.bm, plan.bw, plan.rc)
        if sig not in seen:
            seen.add(sig)
            out.append(blocks)
    return out


def autotune_plan(mode: str, b: int, m: int, w: int,
                  run: Callable[[TilePlan], object], *,
                  candidates=None, reps: int = 3,
                  cache: Optional[PlanCache] = None) -> TilePlan:
    """Measure ``run(plan)`` over candidate block geometries, persist the
    winner in the plan cache, and return its plan.

    ``run`` must execute the kernel under test with the plan's blocks and
    return the jax result (blocked on for timing). The first call per
    candidate compiles and is discarded; the best median-of-``reps`` wins.
    """
    cache = cache or plan_cache()
    best_blocks, best_us, last_err = None, None, None
    for blocks in (candidates or candidate_blocks(b, m, w)):
        plan = plan_tiles(b, m, w, **blocks)
        try:
            jax.block_until_ready(run(plan))  # compile + warm
        except Exception as e:  # geometry rejected by the backend: skip
            last_err = e
            continue
        samples = []
        for _ in range(reps):
            t0 = time.perf_counter()
            jax.block_until_ready(run(plan))
            samples.append((time.perf_counter() - t0) * 1e6)
        us = sorted(samples)[len(samples) // 2]
        if best_us is None or us < best_us:
            best_blocks, best_us = blocks, us
    if best_blocks is None:
        # every candidate failed -> the problem is the run callable, not
        # the geometry; surface the real error
        raise RuntimeError(f"no viable tile candidate for {mode} "
                           f"b={b} m={m} w={w}") from last_err
    cache.put(mode, b, m, w, best_blocks, us=best_us)
    return plan_tiles(b, m, w, **best_blocks)


# ---------------------------------------------------------------------------
# Kernel plumbing
# ---------------------------------------------------------------------------

def pad_lanes(arr, rows_to: int, lanes_to: int) -> jnp.ndarray:
    """Zero-pad the trailing [rows, lanes] dims of a packed uint32 operand;
    leading (bit-plane) dims pass through untouched."""
    arr = jnp.asarray(arr, jnp.uint32)
    pads = ([(0, 0)] * (arr.ndim - 2)
            + [(0, rows_to - arr.shape[-2]), (0, lanes_to - arr.shape[-1])])
    return jnp.pad(arr, pads)


def subrow_popcount_sum(x, a, *, bit_op, row_chunk: int, postprocess=None):
    """S[b, r] = sum_w popcount(bit_op(x[b, w], a[r, w])) over one tile.

    x: [tb, tw] uint32, a: [tm, tw] uint32 -> [tb, tm] int32. The row dim
    is chunked (``row_chunk`` rows at a time) to bound the [tb, chunk, tw]
    popcount intermediate — the subrow partitioning of Fig. 2.
    ``postprocess`` maps each [tb, chunk] int32 partial (e.g. ``& 1`` for
    GF(2) parity) before it lands in the result.
    """
    tb = x.shape[0]
    tm = a.shape[0]
    n_chunks = tm // row_chunk

    def body(i, acc):
        a_c = lax.dynamic_slice_in_dim(a, i * row_chunk, row_chunk, axis=0)
        bits = bit_op(x[:, None, :], a_c[None, :, :])
        pc = lax.population_count(bits).astype(jnp.int32)  # [tb, chunk, tw]
        part = jnp.sum(pc, axis=-1)                        # [tb, chunk]
        if postprocess is not None:
            part = postprocess(part)
        return lax.dynamic_update_slice_in_dim(acc, part, i * row_chunk, axis=1)

    return lax.fori_loop(0, n_chunks, body, jnp.zeros((tb, tm), jnp.int32),
                         unroll=False)


def _x_spec(plan: TilePlan, leading: int):
    if leading:
        return pl.BlockSpec((leading, plan.bb, plan.bw),
                            lambda i, j, k: (0, i, k))
    return pl.BlockSpec((plan.bb, plan.bw), lambda i, j, k: (i, k))


def _a_spec(plan: TilePlan, leading: int):
    if leading:
        return pl.BlockSpec((leading, plan.bm, plan.bw),
                            lambda i, j, k: (0, j, k))
    return pl.BlockSpec((plan.bm, plan.bw), lambda i, j, k: (j, k))


def lane_stream_call(kernel_body, x_packed, a_packed, plan: TilePlan, *,
                     x_leading: int = 0, a_leading: int = 0,
                     extra_inputs=(), extra_specs=(),
                     interpret: bool = False):
    """Run ``kernel_body`` on the canonical lane-streamed grid.

    Pads the operands per ``plan``, streams x tiles along grid dims (0, 2)
    and a tiles along (1, 2), hands any ``extra_inputs`` through with their
    ``extra_specs``, and revisits the [bb, bm] int32 output block across
    grid dim 2 (the lane stream) — the body must init it at
    ``pl.program_id(2) == 0`` and accumulate into it. Returns the result
    cropped back to the logical [b, m].

    ``x_leading``/``a_leading`` carry a bit-plane stack (bitserial MVP):
    nonzero values make the operand [leading, rows, lanes] with the whole
    plane stack resident per tile.

    On the native TPU lowering, the grid is annotated with
    ``GRID_SEMANTICS``: batch/row tiles are parallel, only the lane
    (accumulation) dim is order-dependent — letting Mosaic reorder and
    pipeline the independent output tiles.
    """
    x_p = pad_lanes(x_packed, plan.bp, plan.wp)
    a_p = pad_lanes(a_packed, plan.mp, plan.wp)
    extra = {}
    if pltpu is not None and not interpret:
        extra["compiler_params"] = pltpu.TPUCompilerParams(
            dimension_semantics=GRID_SEMANTICS)
    out = pl.pallas_call(
        kernel_body,
        grid=plan.grid,
        in_specs=[_x_spec(plan, x_leading), _a_spec(plan, a_leading),
                  *extra_specs],
        out_specs=pl.BlockSpec((plan.bb, plan.bm), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((plan.bp, plan.mp), jnp.int32),
        interpret=interpret,
        **extra,
    )(x_p, a_p, *extra_inputs)
    return out[:plan.b, :plan.m]
