"""Associative retrieval subsystem: PPAC as a scalable CAM/ANN index.

CAMIndex             — tile-virtualized packed-bit index with add/delete,
                       fused top-k search, CAM δ-match, cycle accounting
sharded_hamming_topk — row-sharded search with all-gather top-k merge
"""
from .index import CAMIndex, SearchResult  # noqa: F401
from .sharded import sharded_hamming_topk  # noqa: F401
