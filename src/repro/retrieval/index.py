"""CAMIndex: a scalable associative (content-addressable) memory on PPAC.

Virtualizes an arbitrarily large packed-bit database onto *tiles* of the
fixed ``PPACConfig`` array geometry (paper §IV-A builds one M×N array; a
deployment banks many of them). A database of ``size`` codes of
``n_bits`` bits occupies

    col_tiles = ceil(n_bits / config.n)   arrays side by side (bit split)
    row_tiles = ceil(high_water / config.m)  arrays stacked   (row split)

Write path is incremental: ``add`` fills tombstoned slots first and grows
capacity by doubling in whole-tile units (so device buffers take few
distinct shapes and jit recompiles stay bounded); ``delete`` tombstones
rows via the validity mask that the fused kernels honor natively — no
compaction, ids are stable row numbers.

Cycle accounting (per query, through ``core.cost_model`` geometry rules):
  * scan: every (row, col) tile runs one Hamming cycle (mode III-A);
    with ``parallel_arrays`` physical arrays the tiles time-multiplex:
    ceil(row_tiles * col_tiles / parallel_arrays) cycles;
  * merge: col-split partial similarities reduce over an adder tree,
    ceil(log2(col_tiles)) cycles;
  * select: draining k winners through a bit-serial max-search priority
    encoder costs ceil(log2(n_bits + 1)) cycles per winner (the classic
    associative-processor max-search; threshold match instead reads the
    row ALU's sign bit for free);
  * plus the 2-cycle pipeline latency once per batch.

Wall-clock estimates use the paper's post-layout clock for the configured
geometry when it appears in cost_model.TABLE_II.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import jax.numpy as jnp
import numpy as np

from ..core.backend import auto_backend
from ..core.cost_model import est_latency_us, tiled_scan_merge_cycles
from ..core.formats import pack_bits, packed_width
from ..core.ppac import CycleCounter, PPACConfig
from ..kernels.engine import ppac_matmul
from .sharded import sharded_hamming_topk


@dataclasses.dataclass
class SearchResult:
    """Top-k result plus the emulated hardware cost of producing it."""

    scores: np.ndarray   # [Q, k] int32 Hamming similarities (-1 = no row)
    ids: np.ndarray      # [Q, k] int32 stable row ids
    stats: Dict[str, float]


class CAMIndex:
    """Associative index over ``n_bits``-wide binary codes (mode III-A)."""

    def __init__(self, n_bits: int, *, config: Optional[PPACConfig] = None,
                 backend: str = "auto", parallel_arrays: Optional[int] = None,
                 min_capacity: int = 1024):
        assert n_bits > 0
        self.n_bits = n_bits
        self.config = config or PPACConfig()
        self.backend = auto_backend() if backend == "auto" else backend
        self.parallel_arrays = parallel_arrays  # None -> fully parallel
        self.w = packed_width(n_bits)
        cap = self._tile_round(max(min_capacity, self.config.m))
        self._codes = np.zeros((cap, self.w), np.uint32)   # host mirror
        self._valid = np.zeros((cap,), np.int32)
        self._high = 0          # high-water row (exclusive)
        self._live = 0
        self._free: list = []   # tombstoned rows available for reuse
        self._dev = None        # (codes, valid) device cache
        self.counter = CycleCounter()

    # -- geometry ------------------------------------------------------------

    def _tile_round(self, rows: int) -> int:
        m = self.config.m
        return max(m, ((rows + m - 1) // m) * m)

    @property
    def capacity(self) -> int:
        return self._codes.shape[0]

    @property
    def size(self) -> int:
        """Live (non-deleted) codes."""
        return self._live

    @property
    def high_water(self) -> int:
        return self._high

    @property
    def col_tiles(self) -> int:
        return max(1, -(-self.n_bits // self.config.n))

    @property
    def row_tiles(self) -> int:
        return max(1, -(-max(self._high, 1) // self.config.m))

    # -- write path ----------------------------------------------------------

    def _ensure_capacity(self, extra: int):
        need = self._high + max(0, extra - len(self._free))
        cap = self.capacity
        if need <= cap:
            return
        while cap < need:
            cap *= 2
        cap = self._tile_round(cap)
        codes = np.zeros((cap, self.w), np.uint32)
        codes[: self._high] = self._codes[: self._high]
        valid = np.zeros((cap,), np.int32)
        valid[: self._high] = self._valid[: self._high]
        self._codes, self._valid = codes, valid

    def add(self, codes_bits) -> np.ndarray:
        """Insert unpacked {0,1} codes [num, n_bits]; returns stable ids."""
        codes_bits = np.asarray(codes_bits, np.uint8)
        assert codes_bits.ndim == 2 and codes_bits.shape[1] == self.n_bits, \
            codes_bits.shape
        return self.add_packed(np.asarray(pack_bits(codes_bits), np.uint32))

    def add_packed(self, packed) -> np.ndarray:
        """Insert pre-packed codes [num, ceil(n_bits/32)] uint32."""
        packed = np.asarray(packed, np.uint32)
        num = packed.shape[0]
        assert packed.shape == (num, self.w), (packed.shape, self.w)
        self._ensure_capacity(num)
        reuse = min(num, len(self._free))
        rows = [self._free.pop() for _ in range(reuse)]
        fresh = num - reuse
        if fresh:
            rows.extend(range(self._high, self._high + fresh))
            self._high += fresh
        ids = np.asarray(rows, np.int32)
        self._codes[ids] = packed
        self._valid[ids] = 1
        self._live += num
        self._dev = None
        return ids

    def delete(self, ids) -> int:
        """Tombstone rows by id; returns the number actually deleted."""
        ids = np.unique(np.atleast_1d(np.asarray(ids, np.int64)))
        hit = ids[(ids >= 0) & (ids < self._high)]
        hit = hit[self._valid[hit] > 0]
        self._valid[hit] = 0
        self._codes[hit] = 0
        self._free.extend(int(r) for r in hit)
        self._live -= len(hit)
        self._dev = None
        return len(hit)

    # -- device state --------------------------------------------------------

    def _device_arrays(self):
        if self._dev is None:
            self._dev = (jnp.asarray(self._codes), jnp.asarray(self._valid))
        return self._dev

    def _pack_queries(self, queries, queries_packed):
        if queries_packed is not None:
            q = jnp.asarray(queries_packed, jnp.uint32)
            assert q.ndim == 2 and q.shape[1] == self.w, q.shape
            return q
        qb = np.asarray(queries, np.uint8)
        assert qb.ndim == 2 and qb.shape[1] == self.n_bits, qb.shape
        return jnp.asarray(pack_bits(qb))

    # -- cycle model ---------------------------------------------------------

    def cycles_per_query(self, k: int = 0, *, threshold_only: bool = False) -> int:
        scan_merge = tiled_scan_merge_cycles(
            max(self._high, 1), self.n_bits, self.config,
            self.parallel_arrays)
        select = 0 if threshold_only else k * int(math.ceil(math.log2(self.n_bits + 1)))
        return scan_merge + select

    def _stats(self, nq: int, k: int, *, threshold_only: bool = False,
               shards: int = 1) -> Dict[str, float]:
        cpq = self.cycles_per_query(k, threshold_only=threshold_only)
        total = nq * cpq + self.counter.pipeline_latency
        self.counter.tick(total)
        stats = dict(queries=nq, cycles_per_query=cpq, total_cycles=total,
                     row_tiles=self.row_tiles, col_tiles=self.col_tiles,
                     shards=shards, backend=self.backend)
        lat = est_latency_us(total, self.config, shards)
        if lat is not None:
            stats["est_latency_us"] = lat
        return stats

    # -- queries -------------------------------------------------------------

    def search(self, queries=None, k: int = 1, *, queries_packed=None,
               mesh=None, shard_axis: str = "data",
               backend: Optional[str] = None) -> SearchResult:
        """Top-k most similar codes per query.

        queries: [Q, n_bits] {0,1} (or pass queries_packed [Q, W] uint32).
        With a ``mesh``, database rows shard over ``shard_axis`` and the
        per-device top-k lists merge through an all-gather — bit-identical
        to the single-device path. Entries beyond the live count come back
        with score -1.
        """
        q = self._pack_queries(queries, queries_packed)
        codes, valid = self._device_arrays()
        be = backend or self.backend
        assert 1 <= k <= self.capacity, (k, self.capacity)
        if mesh is None:
            scores, idx = ppac_matmul(q, codes, mode="topk", n=self.n_bits,
                                      k=k, valid=valid, backend=be)
            shards = 1
        else:
            scores, idx = sharded_hamming_topk(
                q, codes, valid, n=self.n_bits, k=k, mesh=mesh,
                axis=shard_axis, backend=be)
            shards = int(mesh.shape[shard_axis])
        stats = self._stats(q.shape[0], k, shards=shards)
        return SearchResult(np.asarray(scores), np.asarray(idx), stats)

    def match(self, queries=None, delta: Optional[int] = None, *,
              queries_packed=None, backend: Optional[str] = None):
        """CAM δ-match lines [Q, high_water] uint8 (δ=None -> exact match).

        Agrees with ``PPACArray.cam_match`` row-for-row on live rows and
        returns 0 for tombstoned rows.
        """
        q = self._pack_queries(queries, queries_packed)
        codes, valid = self._device_arrays()
        d = self.n_bits if delta is None else delta
        out = ppac_matmul(q, codes, mode="cam", n=self.n_bits, delta=d,
                          valid=valid, backend=backend or self.backend)
        self._stats(q.shape[0], 0, threshold_only=True)
        return np.asarray(out[:, : self._high])

    def match_ids(self, queries=None, delta: Optional[int] = None, *,
                  queries_packed=None):
        """Per-query arrays of matching row ids (candidate sets)."""
        lines = self.match(queries, delta, queries_packed=queries_packed)
        return [np.flatnonzero(row) for row in lines]
