"""CAM-matched prefix reuse: page-span keys looked up associatively.

The paper's §I pitch is one fabric serving NN inference *and* hash
lookups; this module is that composition inside the LM server. Every
full page of an admitted prompt hashes its token span into a 128-bit
*chained* key (the hash folds in the previous page's key, so a key
matches only when the entire prefix up to and including that page is
identical — matching page i alone is impossible without matching pages
0..i-1). Admission packs the prompt's page keys into uint32 codes and
issues ONE batched exact CAM match (`CAMIndex.match`, the mode-III-A
kernel, recorded in the obs ledger like every other launch); the longest
matched run maps the new slot's table entries straight onto resident
physical pages and their prefill is skipped.

The index holds one pool reference per registered page, so hot prefixes
survive the retirement of the request that created them; when the pool
runs dry the server evicts *idle* registrations (refcount == 1, LRU) to
recycle their pages.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from ..core.ppac import PPACConfig
from .index import CAMIndex

KEY_BITS = 128  # blake2b digest_size=16 -> 4 packed uint32 words


def page_keys(tokens: np.ndarray, page_size: int) -> List[bytes]:
    """Chained 128-bit keys, one per FULL page of the token span."""
    tokens = np.asarray(tokens, np.int32)
    keys, prev = [], b""
    for i in range(len(tokens) // page_size):
        span = tokens[i * page_size:(i + 1) * page_size]
        keys.append(hashlib.blake2b(prev + span.tobytes(),
                                    digest_size=KEY_BITS // 8).digest())
        prev = keys[-1]
    return keys


def _packed(key: bytes) -> np.ndarray:
    return np.frombuffer(key, dtype="<u4")


class PagePrefixIndex:
    """key <-> physical page maps over an exact-match CAMIndex."""

    def __init__(self, page_size: int, *, backend: str = "auto",
                 config: Optional[PPACConfig] = None,
                 min_capacity: int = 64):
        self.page_size = page_size
        self.index = CAMIndex(KEY_BITS, backend=backend, config=config,
                              min_capacity=min_capacity)
        self._row_to_page: Dict[int, int] = {}
        self._page_meta: Dict[int, Tuple[int, bytes]] = {}  # page -> (row, key)
        self._row_of_key: Dict[bytes, int] = {}
        self._lru: "OrderedDict[int, bool]" = OrderedDict()
        self.lookups = 0
        self.pages_hit = 0
        self.pages_probed = 0

    @property
    def registered_pages(self) -> int:
        return len(self._page_meta)

    def keys_for(self, tokens: np.ndarray) -> List[bytes]:
        return page_keys(tokens, self.page_size)

    def lookup(self, keys: List[bytes]) -> List[int]:
        """Longest resident run matching the chained keys -> page ids.

        One batched CAM launch for all of a prompt's page keys; the
        chain construction means a miss at page i ends the usable run
        regardless of later matches."""
        self.lookups += 1
        self.pages_probed += len(keys)
        if not keys or self.index.size == 0:
            return []
        q = np.stack([_packed(k) for k in keys])
        rows = self.index.match_ids(queries_packed=q)
        pages: List[int] = []
        for row_ids in rows:
            page = None
            for rid in row_ids:  # exact 128-bit match: ≥1 live row is a hit
                page = self._row_to_page.get(int(rid))
                if page is not None:
                    break
            if page is None:
                break
            pages.append(page)
        for p in pages:
            self._lru.move_to_end(p)
        self.pages_hit += len(pages)
        return pages

    def register(self, key: bytes, page: int) -> bool:
        """Map ``key`` -> ``page``. Refuses duplicates (key already
        resident under another page, or page already registered) so the
        caller never holds a second reference for the same content."""
        if key in self._row_of_key or page in self._page_meta:
            return False
        row = int(self.index.add_packed(_packed(key)[None, :])[0])
        self._row_to_page[row] = page
        self._page_meta[page] = (row, key)
        self._row_of_key[key] = row
        self._lru[page] = True
        return True

    def evict_page(self, page: int) -> bool:
        """Drop a page's registration (CAM row tombstoned, maps cleared)."""
        meta = self._page_meta.pop(page, None)
        if meta is None:
            return False
        row, key = meta
        self.index.delete([row])
        self._row_to_page.pop(row, None)
        self._row_of_key.pop(key, None)
        self._lru.pop(page, None)
        return True

    def idle_pages(self, refcount: np.ndarray) -> List[int]:
        """Registered pages held ONLY by this index (refcount == 1),
        least-recently-matched first — the eviction candidates."""
        return [p for p in self._lru if refcount[p] == 1]
