"""Row-sharded associative search: shard_map + all-gather top-k merge.

The database row dimension is split contiguously across a mesh axis; each
device runs the fused local top-k on its shard, rebases local row indices
to global ones, and an all-gather + merge reproduces the single-device
result bit-exactly (replicated on every device).

Tie correctness: each shard's k-list is ordered (score desc, index asc);
shards are concatenated in axis-index order, so among equal scores the
concatenation position order *is* the global-index order, and a value-only
``lax.top_k`` over the [D*k] candidates yields exactly the single-device
(score desc, global index asc) ordering. The global top-k is always a
subset of the union of per-shard top-k lists, so nothing is lost.

Fully-manual shard_map (like sharding/pipeline.py — the partial-manual
form crashes the CPU XLA backend).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from ..kernels.hamming_topk.ops import hamming_topk
from ..sharding.compat import shard_map


def sharded_hamming_topk(x_packed, a_packed, valid, *, n: int, k: int,
                         mesh: Mesh, axis: str = "data",
                         backend: str = "mxu"):
    """(scores [B, k], global indices [B, k]) — identical to the
    single-device ``hamming_topk`` on the full database.

    a_packed [M, W] and valid [M] are sharded over ``axis`` (M must divide
    by the axis size, and k must fit in one shard); queries are replicated.
    """
    d = mesh.shape[axis]
    m = a_packed.shape[0]
    assert m % d == 0, (m, d)
    rows = m // d
    assert 1 <= k <= rows, (k, rows)

    if valid is None:
        valid = jnp.ones((m,), jnp.int32)
    valid = jnp.asarray(valid, jnp.int32)

    def local(xq, a_s, v_s):
        s, i = hamming_topk(xq, a_s, n=n, k=k, valid=v_s, backend=backend)
        i = i + lax.axis_index(axis) * rows
        s_all = lax.all_gather(s, axis)                    # [D, B, k]
        i_all = lax.all_gather(i, axis)
        b = s.shape[0]
        s_cat = jnp.moveaxis(s_all, 0, 1).reshape(b, d * k)
        i_cat = jnp.moveaxis(i_all, 0, 1).reshape(b, d * k)
        vals, pos = lax.top_k(s_cat, k)
        return vals, jnp.take_along_axis(i_cat, pos, axis=1)

    fn = shard_map(local, mesh=mesh,
                   in_specs=(P(), P(axis), P(axis)), out_specs=(P(), P()))
    return fn(x_packed, a_packed, valid)
