"""Losses: masked next-token cross-entropy + router aux losses."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def next_token_xent(logits, labels, *, z_loss_coef: float = 0.0):
    """logits [B,S,V] fp32, labels [B,S] int32 (-1 = ignore).

    Standard causal LM loss: logits at position i predict labels[i]
    (callers pre-shift). Returns (loss, metrics).
    """
    vocab = logits.shape[-1]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = (lse - ll) * mask
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    loss = jnp.sum(nll) / denom
    metrics = {"xent": loss, "tokens": jnp.sum(mask)}
    if z_loss_coef:
        z = jnp.sum(jnp.square(lse) * mask) / denom
        loss = loss + z_loss_coef * z
        metrics["z_loss"] = z
    return loss, metrics


def total_loss(logits, labels, aux, *, lb_coef: float = 0.01,
               z_router_coef: float = 1e-3, z_loss_coef: float = 1e-4):
    loss, metrics = next_token_xent(logits, labels, z_loss_coef=z_loss_coef)
    if aux is not None:
        loss = loss + lb_coef * aux["lb_loss"] + z_router_coef * aux["z_loss"]
        metrics["lb_loss"] = aux["lb_loss"]
        metrics["router_z"] = aux["z_loss"]
    metrics["loss"] = loss
    return loss, metrics
