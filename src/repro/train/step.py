"""train_step builder: autodiff + optimizer + distributed-optimization tricks.

Options (all exercised by tests and the dry-run variants):
  * microbatching / gradient accumulation (lax.scan over microbatches)
  * cross-pod gradient compression: per-pod gradients are psum'd across the
    'pod' mesh axis in bf16 (half the inter-pod ICI bytes) via a
    partial-manual shard_map — the in-graph form of compressed DP sync
  * QAT mode: forward in PPAC fake-quant mode (paper technique in training)
  * remat policy comes from the model config

The returned function is pure: (state, batch) -> (state, metrics); the
launcher jits it with in/out shardings from the logical-axis rules.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..models import lm
from ..optim.adamw import AdamWConfig, cosine_schedule, opt_init, opt_update
from ..sharding.compat import shard_map
from ..sharding.rules import ShardingRules
from .loss import total_loss


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    qat: bool = False                     # PPAC fake-quant forward
    cross_pod_grad_dtype: str = "float32"  # 'bfloat16' = compressed DP sync
    warmup_steps: int = 100
    total_steps: int = 10000
    lb_coef: float = 0.01
    z_router_coef: float = 1e-3
    z_loss_coef: float = 1e-4


def init_state(cfg: ModelConfig, tcfg: TrainConfig, key):
    params, axes = lm.init(cfg, key)
    return {"params": params, "opt": opt_init(params, tcfg.opt)}, axes


def abstract_state(cfg: ModelConfig, tcfg: TrainConfig):
    """ShapeDtypeStructs + logical axes for the dry-run (no allocation)."""
    from ..optim.adamw import opt_state_axes
    pshapes, axes = lm.abstract_init(cfg)
    state_shapes = jax.eval_shape(
        lambda p: {"params": p, "opt": opt_init(p, tcfg.opt)}, pshapes)
    state_axes = {"params": axes, "opt": opt_state_axes(axes, tcfg.opt)}
    return state_shapes, state_axes


def _loss_fn(params, batch, cfg: ModelConfig, tcfg: TrainConfig, rules):
    mode = "qat" if tcfg.qat else "float"
    fwd_batch = {k: v for k, v in batch.items() if k != "labels"}
    logits, aux = lm.forward(params, cfg, fwd_batch, mode=mode, rules=rules)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:  # vlm: loss only on text positions
        logits = logits[:, -labels.shape[1]:]
    return total_loss(logits, labels, aux if cfg.moe else None,
                      lb_coef=tcfg.lb_coef, z_router_coef=tcfg.z_router_coef,
                      z_loss_coef=tcfg.z_loss_coef)


def _grads(params, batch, cfg, tcfg, rules):
    if tcfg.microbatches <= 1:
        (loss, metrics), grads = jax.value_and_grad(
            _loss_fn, has_aux=True)(params, batch, cfg, tcfg, rules)
        return loss, metrics, grads

    n = tcfg.microbatches
    mb = jax.tree.map(lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]),
                      batch)

    def body(acc, mbatch):
        (loss, metrics), g = jax.value_and_grad(
            _loss_fn, has_aux=True)(params, mbatch, cfg, tcfg, rules)
        acc = jax.tree.map(lambda a, b: a + b.astype(jnp.float32), acc, g)
        return acc, (loss, metrics)

    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    gsum, (losses, metrics_all) = jax.lax.scan(body, zeros, mb)
    grads = jax.tree.map(lambda g: g / n, gsum)
    metrics = jax.tree.map(lambda x: jnp.mean(x, axis=0), metrics_all)
    return jnp.mean(losses), metrics, grads


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig,
                    rules: Optional[ShardingRules] = None,
                    mesh=None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def train_step(state, batch):
        params = state["params"]
        if tcfg.cross_pod_grad_dtype == "bfloat16" and mesh is not None \
                and "pod" in mesh.axis_names:
            loss, metrics, grads = _sharded_pod_grads(
                params, batch, cfg, tcfg, rules, mesh)
        else:
            loss, metrics, grads = _grads(params, batch, cfg, tcfg, rules)
        lr_scale = cosine_schedule(state["opt"]["step"],
                                   warmup=tcfg.warmup_steps,
                                   total=tcfg.total_steps)
        new_params, new_opt, m2 = opt_update(params, grads, state["opt"],
                                             tcfg.opt, lr_scale)
        metrics = dict(metrics)
        metrics.update(m2)
        metrics["loss"] = loss
        return {"params": new_params, "opt": new_opt}, metrics

    return train_step


def _sharded_pod_grads(params, batch, cfg, tcfg, rules, mesh):
    """Per-pod grads + compressed (bf16) cross-pod all-reduce.

    shard_map is manual over 'pod' only; 'data'/'model' stay auto so the
    in-pod parallelism is still GSPMD-driven.
    """
    npods = mesh.shape["pod"]

    def per_pod(params, batch):
        loss, metrics, grads = _grads(params, batch, cfg, tcfg, rules)
        grads = jax.tree.map(
            lambda g: jax.lax.psum(g.astype(jnp.bfloat16), "pod")
            .astype(jnp.float32) / npods, grads)
        loss = jax.lax.psum(loss, "pod") / npods
        metrics = jax.tree.map(lambda m: jax.lax.psum(m, "pod") / npods,
                               metrics)
        return loss, metrics, grads

    pspecs_in = (
        jax.tree.map(lambda _: P(), params),
        jax.tree.map(lambda _: P("pod"), batch),
    )
    pspecs_out = (P(), jax.tree.map(lambda _: P(), {"xent": 0, "tokens": 0}),
                  jax.tree.map(lambda _: P(), params))
    # out metric tree structure depends on cfg; build it generically:
    shaped = jax.eval_shape(lambda p, b: _grads(p, b, cfg, tcfg, rules)[1],
                            params, batch)
    pspecs_out = (P(), jax.tree.map(lambda _: P(), shaped),
                  jax.tree.map(lambda _: P(), params))
    fn = shard_map(per_pod, mesh=mesh, in_specs=pspecs_in,
                   out_specs=pspecs_out, axis_names={"pod"})
    return fn(params, batch)
