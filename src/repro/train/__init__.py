from .loss import next_token_xent, total_loss  # noqa: F401
from .step import TrainConfig, abstract_state, init_state, make_train_step  # noqa: F401
