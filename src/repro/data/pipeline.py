"""Deterministic, resumable, shardable synthetic data pipeline.

Every batch is a pure function of (seed, step), so:
  * restart-after-failure resumes exactly (checkpoint stores only `step`),
  * each data-parallel host can materialize just its shard (host_id/hosts),
  * elastic re-sharding is trivial — a new mesh re-slices the same stream.

The synthetic stream is a Zipf-ish token distribution with injected n-gram
structure so cross-entropy has signal (loss decreases during the example
training runs rather than sitting at log(V))."""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import numpy as np

from ..configs.base import InputShape, ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    structure: float = 0.7   # probability a token repeats a recent one


def _rng_for(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(
        np.random.SeedSequence([cfg.seed, step, cfg.host_id]))


def batch_for_step(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Materialize this host's shard of the global batch at `step`."""
    assert cfg.global_batch % cfg.num_hosts == 0
    local = cfg.global_batch // cfg.num_hosts
    rng = _rng_for(cfg, step)
    # Zipf-ish marginal + copy structure (predictable => learnable)
    base = rng.zipf(1.3, size=(local, cfg.seq_len + 1)) % cfg.vocab
    for t in range(2, cfg.seq_len + 1):
        copy = rng.random(local) < cfg.structure
        lag = rng.integers(1, 3, size=local)
        base[np.arange(local)[copy], t] = base[np.arange(local)[copy],
                                               t - lag[copy]]
    tokens = base[:, :-1].astype(np.int32)
    labels = base[:, 1:].astype(np.int32)
    return {"tokens": tokens, "labels": labels}


def make_model_batch(model_cfg: ModelConfig, shape: InputShape,
                     cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Batch in the model's input format (handles frontend stubs)."""
    b = batch_for_step(cfg, step)
    if model_cfg.frontend == "audio":
        rng = _rng_for(cfg, step + 10**6)
        local = b["tokens"].shape[0]
        emb = rng.standard_normal(
            (local, cfg.seq_len, model_cfg.d_model)).astype(np.float32) * 0.02
        return {"embeds": emb, "labels": b["labels"]}
    if model_cfg.frontend == "vision":
        rng = _rng_for(cfg, step + 10**6)
        local = b["tokens"].shape[0]
        p = model_cfg.frontend_tokens
        patches = rng.standard_normal(
            (local, p, model_cfg.d_model)).astype(np.float32) * 0.02
        text = b["tokens"][:, : cfg.seq_len - p]
        labels = b["labels"][:, : cfg.seq_len - p]
        return {"patches": patches, "tokens": text, "labels": labels}
    return b


@dataclasses.dataclass
class DataIterator:
    """Stateful wrapper; state == `step`, checkpointable as one int."""

    cfg: DataConfig
    model_cfg: ModelConfig
    shape: InputShape
    step: int = 0

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = make_model_batch(self.model_cfg, self.shape, self.cfg, self.step)
        self.step += 1
        return b

    def state(self) -> int:
        return self.step

    def restore(self, step: int):
        self.step = step
