from .pipeline import DataConfig, DataIterator, batch_for_step, make_model_batch  # noqa: F401
