"""h2o-danube-3-4b — dense llama/mistral mix with sliding-window attention.

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000
[arXiv:2401.16818; unverified]. SWA (window 4096) -> sub-quadratic ->
runs long_500k with a rolling KV cache.
"""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-3-4b", family="dense",
        n_layers=24, d_model=3840, n_heads=32, n_kv_heads=8,
        d_ff=10240, vocab=32000, sliding_window=4096,
        sub_quadratic=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="danube-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=128, sliding_window=32,
        q_chunk=16, sub_quadratic=True,
    )
