"""llava-next-34b — VLM backbone (anyres tiling) over a dense decoder.

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
[hf:llava-hf/llava-v1.6; unverified]. Vision tower is a stub per the
assignment: input_specs provides precomputed patch embeddings for
5 anyres tiles x 576 patches = 2880 patch positions, prepended to the
text tokens. Full attention -> long_500k skipped.
"""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="llava-next-34b", family="vlm",
        n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
        d_ff=20480, vocab=64000, head_dim=128,
        frontend="vision", frontend_tokens=2880,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="llava-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=128, frontend="vision", frontend_tokens=8,
        q_chunk=16,
    )
