"""zamba2-1.2b — hybrid Mamba2 backbone + shared attention blocks.

38L d_model=2048 32H (MHA kv=32) d_ff=8192 vocab=32000 ssm_state=64
[arXiv:2411.15242; hf]. Sub-quadratic (SSM backbone) -> runs long_500k.
"""
from .base import HybridConfig, ModelConfig, SSMConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="zamba2-1.2b", family="hybrid",
        n_layers=38, d_model=2048, n_heads=32, n_kv_heads=32,
        d_ff=8192, vocab=32000,
        ssm=SSMConfig(d_state=64, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk_size=256),
        hybrid=HybridConfig(shared_every=6, shared_d_ff=8192),
        sub_quadratic=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=128,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk_size=8),
        hybrid=HybridConfig(shared_every=2, shared_d_ff=128),
        q_chunk=16, sub_quadratic=True,
    )
