"""kimi-k2-1t-a32b — trillion-parameter MoE (paper-table config).

61L d_model=7168 64H (GQA kv=8, per the assignment line — the production
K2 uses MLA; we follow the assignment) d_ff_expert=2048 vocab=163840,
MoE 384 routed top-8 + 1 shared, first layer dense
[arXiv:2501.kimi2; unverified]. Full attention -> long_500k skipped.
"""
from .base import MoEConfig, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8,
        d_ff=18432, vocab=163840, head_dim=112,
        moe=MoEConfig(num_experts=384, top_k=8, d_ff_expert=2048,
                      num_shared=1, first_dense_layers=1, d_ff_dense=18432,
                      capacity_factor=1.25, group_size=512),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="kimi-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=192, vocab=256, head_dim=16,
        moe=MoEConfig(num_experts=8, top_k=2, d_ff_expert=32,
                      num_shared=1, first_dense_layers=1, d_ff_dense=192,
                      capacity_factor=2.0, group_size=64),
        q_chunk=16,
    )
