"""musicgen-medium — decoder-only transformer over EnCodec tokens.

48L d_model=1536 24H (kv=24) d_ff=6144 vocab=2048 [arXiv:2306.05284; hf].
Audio frontend is a stub: input_specs provides precomputed frame embeddings.
Pure full attention -> long_500k skipped per assignment.
"""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="audio",
        n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24,
        d_ff=6144, vocab=2048, frontend="audio",
        sub_quadratic=False,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=64, frontend="audio",
        q_chunk=16,
    )
