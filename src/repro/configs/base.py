"""Config system: model/arch configs, input shapes, and run options.

Every assigned architecture provides a ``ModelConfig`` (full size, used only
by the AOT dry-run) plus a ``smoke()`` reduction of the same family for CPU
tests. Shapes are the assignment's four (seq_len, global_batch) cells.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    first_dense_layers: int = 0
    d_ff_dense: int = 0            # FFN width of the leading dense layers
    capacity_factor: float = 1.25
    group_size: int = 512          # GShard dispatch group size (tokens)


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 512
    q_lora_rank: int = 0           # 0 = no q compression
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 128
    d_conv: int = 4
    expand: int = 2
    head_dim: int = 64
    n_groups: int = 1
    chunk_size: int = 256


@dataclasses.dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: SSM backbone + a single shared attention+MLP block
    applied every ``shared_every`` layers (weights shared across uses)."""
    shared_every: int = 6
    shared_d_ff: int = 8192


@dataclasses.dataclass(frozen=True)
class PPACModeConfig:
    """Paper-technique integration: run projections through the PPAC engine."""
    enabled: bool = False
    weight_bits: int = 4           # K (paper row-ALU supports up to 4)
    act_bits: int = 4              # L
    weight_format: str = "int"
    act_format: str = "int"
    backend: str = "mxu"           # 'pallas' | 'mxu' | 'ref'
    min_features: int = 512        # only quantize projections at least this big


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads
    rope_theta: float = 1e4
    qkv_bias: bool = False
    sliding_window: int = 0        # 0 = full attention
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    frontend: str = "none"         # none | audio | vision
    frontend_tokens: int = 0       # patch/frame positions taken out of seq
    ppac: PPACModeConfig = PPACModeConfig()
    dtype: str = "bfloat16"
    # attention chunking (memory-efficient scan attention)
    q_chunk: int = 512
    kv_dtype: str = "bfloat16"     # KV-cache store: bfloat16 | int8
    attn_blocking: str = "scan"    # scan | triangle (skip masked-out KV)
    scores_dtype: str = "float32"  # attention probability boundary dtype
    remat: str = "full"            # full | dots | none
    sub_quadratic: bool = False    # eligible for long_500k

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, L = self.d_model, self.n_layers
        n = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            s = self.ssm
            d_in = s.expand * d
            conv_dim = d_in + 2 * s.n_groups * s.d_state
            nheads = d_in // s.head_dim
            per = (d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)
                   + conv_dim * s.d_conv + d_in * d + 2 * nheads + d_in)
            return n + L * per
        att = d * (self.n_heads * self.hd) + 2 * d * (self.n_kv_heads * self.hd) \
            + (self.n_heads * self.hd) * d
        if self.mla:
            m = self.mla
            att = (d * m.kv_lora_rank
                   + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                   + d * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                   + d * m.qk_rope_head_dim
                   + self.n_heads * m.v_head_dim * d)
        if self.moe:
            mo = self.moe
            ffn_moe = 3 * d * mo.d_ff_expert * (mo.num_experts + mo.num_shared) \
                + d * mo.num_experts
            ffn_dense = 3 * d * (mo.d_ff_dense or self.d_ff)
            nl_moe = L - mo.first_dense_layers
            return n + nl_moe * (att + ffn_moe) + mo.first_dense_layers * (att + ffn_dense)
        ffn = 3 * d * self.d_ff
        per = att + ffn
        if self.family == "hybrid":
            s = self.ssm
            d_in = s.expand * d
            conv_dim = d_in + 2 * s.n_groups * s.d_state
            nheads = d_in // s.head_dim
            ssm_per = (d * (2 * d_in + 2 * s.n_groups * s.d_state + nheads)
                       + conv_dim * s.d_conv + d_in * d + 2 * nheads + d_in)
            shared = att + 3 * d * self.hybrid.shared_d_ff
            return n + L * ssm_per + shared
        return n + L * per

    def active_param_count(self) -> int:
        if not self.moe:
            return self.param_count()
        d, L, mo = self.d_model, self.n_layers, self.moe
        att = d * (self.n_heads * self.hd) + 2 * d * (self.n_kv_heads * self.hd) \
            + (self.n_heads * self.hd) * d
        if self.mla:
            m = self.mla
            att = (d * m.kv_lora_rank
                   + m.kv_lora_rank * self.n_heads * (m.qk_nope_head_dim + m.v_head_dim)
                   + d * self.n_heads * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                   + d * m.qk_rope_head_dim
                   + self.n_heads * m.v_head_dim * d)
        ffn_act = 3 * d * mo.d_ff_expert * (mo.top_k + mo.num_shared)
        n = self.vocab * d * (1 if self.tie_embeddings else 2)
        return n + L * (att + ffn_act)


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "zamba2_1p2b",
    "musicgen_medium",
    "h2o_danube3_4b",
    "stablelm_12b",
    "qwen2_72b",
    "smollm_360m",
    "deepseek_v2_lite_16b",
    "kimi_k2_1t_a32b",
    "llava_next_34b",
    "mamba2_370m",
]


def load_arch(arch_id: str):
    """Returns the config module for an arch id (full() and smoke())."""
    arch_id = arch_id.replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells, minus assignment-mandated skips."""
    out = []
    for a in ARCH_IDS:
        cfg = load_arch(a).full()
        for s in SHAPES.values():
            skip = s.name == "long_500k" and not cfg.sub_quadratic
            if include_skipped or not skip:
                out.append((a, s.name, skip))
    return out
