"""mamba2-370m — attention-free SSM (state-space duality).

48L d_model=1024 (attn-free) vocab=50280 ssm_state=128
[arXiv:2405.21060; unverified]. O(1)-state decode -> runs long_500k.
"""
from .base import ModelConfig, SSMConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-370m", family="ssm",
        n_layers=48, d_model=1024, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab=50280, head_dim=64,
        ssm=SSMConfig(d_state=128, d_conv=4, expand=2, head_dim=64,
                      n_groups=1, chunk_size=256),
        sub_quadratic=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        n_layers=3, d_model=64, n_heads=1, n_kv_heads=1,
        d_ff=0, vocab=128, head_dim=16,
        ssm=SSMConfig(d_state=16, d_conv=4, expand=2, head_dim=16,
                      n_groups=1, chunk_size=8),
        sub_quadratic=True,
    )
