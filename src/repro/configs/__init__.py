"""Arch configs: the ten assigned architectures + the paper's PPAC arrays."""
from .base import (  # noqa: F401
    ARCH_IDS,
    SHAPES,
    HybridConfig,
    InputShape,
    MLAConfig,
    MoEConfig,
    ModelConfig,
    PPACModeConfig,
    SSMConfig,
    cells,
    load_arch,
)
