"""stablelm-12b — dense transformer.

40L d_model=5120 32H (GQA kv=8) d_ff=13824 vocab=100352
[hf:stabilityai/stablelm-2-12b family; hf]. Full attention -> long_500k skipped.
"""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="stablelm-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
        d_ff=13824, vocab=100352,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="stablelm-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=160, vocab=256, q_chunk=16,
    )
