"""smollm-360m — small llama-arch dense transformer.

32L d_model=960 15H (GQA kv=5) d_ff=2560 vocab=49152
[hf:HuggingFaceTB/SmolLM-360M; hf]. 15 heads is not divisible by the
16-way model axis — GSPMD pads the sharded head dim (noted in DESIGN.md).
Full attention -> long_500k skipped.
"""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="smollm-360m", family="dense",
        n_layers=32, d_model=960, n_heads=15, n_kv_heads=5,
        d_ff=2560, vocab=49152, head_dim=64, tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="smollm-smoke", family="dense",
        n_layers=2, d_model=60, n_heads=3, n_kv_heads=1,
        d_ff=128, vocab=128, head_dim=20, tie_embeddings=True,
        q_chunk=16,
    )
