"""deepseek-v2-lite-16b — MoE with multi-head latent attention (MLA).

27L d_model=2048 16H d_ff_expert=1408 vocab=102400, MLA kv_lora=512,
MoE 64 routed top-6 + 2 shared, first layer dense (d_ff 10944)
[arXiv:2405.04434; hf]. Full attention -> long_500k skipped.
"""
from .base import MLAConfig, MoEConfig, ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v2-lite-16b", family="moe",
        n_layers=27, d_model=2048, n_heads=16, n_kv_heads=16,
        d_ff=10944, vocab=102400,
        mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                      qk_nope_head_dim=128, qk_rope_head_dim=64,
                      v_head_dim=128),
        moe=MoEConfig(num_experts=64, top_k=6, d_ff_expert=1408,
                      num_shared=2, first_dense_layers=1, d_ff_dense=10944,
                      capacity_factor=1.25, group_size=512),
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="dsv2lite-smoke", family="moe",
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=160, vocab=256,
        mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=64,
                      num_shared=1, first_dense_layers=1, d_ff_dense=160,
                      capacity_factor=2.0, group_size=64),
        q_chunk=16,
    )
