"""The paper's own PPAC array configurations (Table II) as named configs.

These drive the emulator/kernels in benchmarks and examples — the PPAC
analogue of an "architecture config" for the accelerator itself.
"""
from ..core.ppac import PPACConfig

# Table II: four implemented arrays (M x N, banks of 16 rows, V=16 subrows)
PPAC_16x16 = PPACConfig(m=16, n=16, rows_per_bank=16, subrow_bits=16)
PPAC_16x256 = PPACConfig(m=16, n=256, rows_per_bank=16, subrow_bits=16)
PPAC_256x16 = PPACConfig(m=256, n=16, rows_per_bank=16, subrow_bits=16)
PPAC_256x256 = PPACConfig(m=256, n=256, rows_per_bank=16, subrow_bits=16)

ARRAYS = {
    "16x16": PPAC_16x16,
    "16x256": PPAC_16x256,
    "256x16": PPAC_256x16,
    "256x256": PPAC_256x256,
}

# paper clock frequencies (GHz) per array — Table II
CLOCKS_GHZ = {"16x16": 1.116, "16x256": 0.979, "256x16": 0.824,
              "256x256": 0.703}
