"""qwen2-72b — dense transformer with GQA and QKV bias.

80L d_model=8192 64H (GQA kv=8) d_ff=29568 vocab=152064
[arXiv:2407.10671; hf]. Full attention -> long_500k skipped.
"""
from .base import ModelConfig


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen2-72b", family="dense",
        n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab=152064, qkv_bias=True, rope_theta=1e6,
    )


def smoke() -> ModelConfig:
    return ModelConfig(
        name="qwen2-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=192, vocab=256, qkv_bias=True, q_chunk=16,
    )
