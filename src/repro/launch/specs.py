"""Input/arg specs for the dry-run: ShapeDtypeStruct stand-ins + shardings.

Every (arch × shape) cell resolves to (step_fn, args, in_shardings) with
no device allocation anywhere. Shape kinds:

  train   -> train_step(state, batch)
  prefill -> prefill_step(params, batch, cache)
  decode  -> decode_step(params, tokens, cache)   (one new token, full cache)

Batch sharding: batch dim over ('pod','data') when divisible; the
long_500k cell (batch=1) instead shards the KV/SSM cache sequence dim
over 'data' (sequence parallelism for the cache)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..configs.base import InputShape, ModelConfig
from ..models import lm
from ..serve.step import make_decode_step, make_prefill_step
from ..sharding.rules import (ShardingRules, default_rules, fit_spec,
                              fitted_shardings, tree_shardings)
from ..train.step import TrainConfig, abstract_state, make_train_step


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict[str, Any]:
    """ShapeDtypeStructs for a training/prefill batch (with labels for train)."""
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.frontend == "audio":
        out["embeds"] = sds((b, s, cfg.d_model), jnp.bfloat16)
        out["labels"] = sds((b, s), jnp.int32)
    elif cfg.frontend == "vision":
        p = cfg.frontend_tokens
        out["patches"] = sds((b, p, cfg.d_model), jnp.bfloat16)
        out["tokens"] = sds((b, s - p), jnp.int32)
        out["labels"] = sds((b, s - p), jnp.int32)
    else:
        out["tokens"] = sds((b, s), jnp.int32)
        out["labels"] = sds((b, s), jnp.int32)
    if shape.kind != "train":
        out.pop("labels")
    return out


def data_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_sharding(mesh: Mesh, batch, *, shard_batch: bool):
    axes = data_axes(mesh)
    spec_fn = (lambda x: P(axes, *([None] * (len(x.shape) - 1)))) \
        if shard_batch else (lambda x: P())
    return jax.tree.map(lambda x: NamedSharding(mesh, spec_fn(x)), batch)


@dataclasses.dataclass
class Cell:
    """One dry-run cell, fully resolved."""
    fn: Any
    args: Tuple
    in_shardings: Tuple
    cfg: ModelConfig
    shape: InputShape
    rules: ShardingRules
    # argnums to donate when jitting fn (prefill/decode donate the cache:
    # production decode must alias the in-place cache update, and the
    # dry-run HLO should measure what production runs)
    donate_argnums: Tuple[int, ...] = ()


def build_cell(cfg: ModelConfig, shape: InputShape, mesh: Mesh,
               tcfg: Optional[TrainConfig] = None,
               rules: Optional[ShardingRules] = None,
               serve_quant: bool = False) -> Cell:
    tcfg = tcfg or TrainConfig()
    b = shape.global_batch
    dp = 1
    for a in data_axes(mesh):
        dp *= mesh.shape[a]
    shard_batch = b % dp == 0 and b >= dp

    if rules is None:
        overrides = {}
        if not shard_batch:
            overrides["batch"] = None
            overrides["kv_seq"] = "data"   # SP over the cache for batch=1
        rules = default_rules(**overrides)
    rules = rules.for_mesh(mesh)

    if shape.kind == "train":
        state_shapes, state_axes = abstract_state(cfg, tcfg)
        batch = batch_specs(cfg, shape)
        fn = make_train_step(cfg, tcfg, rules=rules, mesh=mesh)
        in_sh = (fitted_shardings(mesh, rules, state_axes, state_shapes),
                 batch_sharding(mesh, batch, shard_batch=shard_batch))
        return Cell(fn, (state_shapes, batch), in_sh, cfg, shape, rules)

    # serving cells: weights serve in bf16 (fp32 masters are a training
    # artifact); serve_quant packs them further via the PPAC engine.
    pshapes, paxes = lm.abstract_init(cfg)
    pshapes = jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, jnp.bfloat16)
        if l.dtype == jnp.float32 else l, pshapes)
    if serve_quant:
        from ..serve.step import convert_params_for_serving
        if not cfg.ppac.enabled:  # serve_quant implies the PPAC engine
            cfg = dataclasses.replace(
                cfg, ppac=dataclasses.replace(cfg.ppac, enabled=True))
        # group=False: the dry-run cells mirror the init-time param
        # structure; the grouped (wqkv/wig) fast path gets its shardings
        # from serving_param_shardings (the live server's load path)
        pshapes = jax.eval_shape(
            lambda p: convert_params_for_serving(p, cfg, group=False),
            pshapes)
    psh = _param_shardings(mesh, rules, pshapes, paxes)

    cache_shapes, cache_axes = _abstract_cache(cfg, b, shape.seq_len)
    csh = fitted_shardings(mesh, rules, cache_axes, cache_shapes)

    if shape.kind == "prefill":
        batch = batch_specs(cfg, shape)
        fn = make_prefill_step(cfg, rules=rules, jit=False)
        in_sh = (psh, batch_sharding(mesh, batch, shard_batch=shard_batch),
                 csh)
        return Cell(fn, (pshapes, batch, cache_shapes), in_sh, cfg, shape,
                    rules, donate_argnums=(2,))

    # decode: one new token against a full cache
    tokens = sds((b, 1), jnp.int32)
    fn = make_decode_step(cfg, rules=rules, jit=False)
    tok_sh = batch_sharding(mesh, tokens, shard_batch=shard_batch)
    in_sh = (psh, tok_sh, csh)
    return Cell(fn, (pshapes, tokens, cache_shapes), in_sh, cfg, shape, rules,
                donate_argnums=(2,))


def _abstract_cache(cfg: ModelConfig, b: int, max_seq: int):
    box = {}

    def f():
        c, ax = lm.init_cache(cfg, b, max_seq)
        box["ax"] = ax
        return c

    shapes = jax.eval_shape(f)
    return shapes, box["ax"]


def _param_shardings(mesh, rules, pshapes, paxes):
    """Shardings for (possibly quantized-container) param trees.

    PPAC containers keep the original weight's logical axes: int8/bf16 wq
    is [in, out] (same axis order, divisibility re-checked by fit_spec);
    packed1 wq is [out, in/32] (axes reversed, lanes replicated); packed4
    wq is [K, out, in/32] bitplanes (plane dim replicated); scales follow
    the out dim.
    """
    from ..core.engine import QuantContainer

    def spec_or_rep(leaf_axes, leaf):
        try:
            spec = fit_spec(mesh, rules.spec(leaf_axes), tuple(leaf.shape))
            return NamedSharding(mesh, spec)
        except Exception:
            return NamedSharding(mesh, P())

    def one(ax, leaf):
        if isinstance(leaf, QuantContainer):
            ax = tuple(ax) if ax else (None, None)
            # stacked (layers) containers carry a leading 'layers' axis
            lead = ax[:-2] if len(ax) > 2 else ()
            a_in, a_out = ax[-2], ax[-1]
            if leaf.kind == "packed1":
                wq_ax = lead + (a_out, None)
            elif leaf.kind == "packed4":
                wq_ax = lead + (None, a_out, None)
            else:
                wq_ax = lead + (a_in, a_out)
            shadow_sh = (spec_or_rep(lead + (a_in, a_out), leaf.shadow)
                         if leaf.shadow is not None else None)
            # the resident draft rung is packed1-shaped regardless of kind
            draft_sh = dict(
                dwq=(spec_or_rep(lead + (a_out, None), leaf.dwq)
                     if leaf.dwq is not None else None),
                dscale=(spec_or_rep(lead + (a_out,), leaf.dscale)
                        if leaf.dscale is not None else None),
                dshadow=(spec_or_rep(lead + (a_in, a_out), leaf.dshadow)
                         if leaf.dshadow is not None else None))
            return leaf.with_children(
                spec_or_rep(wq_ax, leaf.wq),
                spec_or_rep(lead + (a_out,), leaf.scale),
                shadow_sh, **draft_sh)
        return spec_or_rep(ax, leaf)

    is_ax = lambda x: x is None or (isinstance(x, tuple) and all(
        a is None or isinstance(a, str) for a in x))
    return jax.tree.map(one, paxes, pshapes, is_leaf=is_ax)


# grouped serving containers inherit the logical axes of their first
# member: wqkv concatenates q/k/v along the out dim (heads and kv_heads
# both map to 'model'), wig concatenates the SwiGLU up/gate pair (both
# 'mlp') — so member 0's annotation IS the group's annotation, with
# fit_spec re-checking divisibility at the concatenated width.
_GROUP_AXES_SOURCE = {"wqkv": "wq", "wig": "wi"}


def _group_axes_like(params, axes):
    """Mirror the runtime param tree's (wqkv/wig) grouping onto the
    init-time logical-axes tree, so the two stay congruent for
    ``jax.tree.map``. Keys the axes tree lacks entirely fall back to
    replicated (None) annotations rather than raising."""
    if not isinstance(params, dict):
        return axes
    out = {}
    for k, v in params.items():
        src = axes.get(k) if isinstance(axes, dict) else None
        if src is None and k in _GROUP_AXES_SOURCE \
                and isinstance(axes, dict):
            src = axes.get(_GROUP_AXES_SOURCE[k])
        if src is None:
            out[k] = jax.tree.map(
                lambda _: None, v,
                is_leaf=lambda x: not isinstance(x, dict))
        else:
            out[k] = _group_axes_like(v, src)
    return out


def serving_param_shardings(mesh: Mesh, rules: ShardingRules, params,
                            cfg: ModelConfig):
    """NamedShardings for a *converted* serving param tree — the live
    server's resident layout: grouped ``wqkv``/``wig`` containers,
    per-projection containers, optional packed1 draft rungs, and the
    untouched float leaves (embeddings, norms).

    The init-time logical-axes annotations drive everything
    (:data:`repro.sharding.rules.DEFAULT_RULES` maps them onto the
    mesh); the grouped containers reuse member 0's annotation and
    non-divisible dims fall back to replicated via ``fit_spec`` — so a
    mesh the weights don't fit degrades to replication, never to a
    shape error."""
    _, paxes = lm.abstract_init(cfg)
    paxes = _group_axes_like(params, paxes)
    return _param_shardings(mesh, rules, params, paxes)
