"""Deterministic fault injection for the LM serving stack.

A :class:`FaultPlan` is a *pure schedule*: every fault is keyed by a
dispatch counter at a named executor seam (plus an optional worker tag)
or by a request id — never by wall-clock time — so a chaos run with the
same plan and seed replays bit-identically. The executors in
``launch/workers.py`` and the scheduler in ``launch/serve_lm.py`` call
:meth:`FaultPlan.fire` at their seams; with no plan installed
(``faults is None``, the default) the seams cost one ``is not None``
check and the production path pays zero overhead.

Seams (where ``fire`` is called):

  ``prefill``  — one count per prefill dispatch (per worker for the
                 disaggregated pool; the unified executor counts as its
                 own worker). ``crash`` kills the worker mid-dispatch
                 (before any device work), ``error`` raises a transient
                 dispatch exception, ``stall`` sleeps.
  ``handoff``  — one count per prefill->decode handoff. ``crash`` = the
                 producing worker dies mid-handoff (after prefill, before
                 the resident write — the scheduler must re-prefill with
                 correct page refcounts); ``stall`` = latency spike.
  ``decode``   — one count per fused decode/spec dispatch. ``error``
                 raises before the launch (cache untouched -> the
                 scheduler retries the step).
  ``step``     — one count per scheduler tick. ``flip`` corrupts one bit
                 of a KV page (``page=-1`` picks the lowest sealed page so
                 the CRC scrub is armed) or, with ``param=1``, of a
                 resident packed weight container. ``squeeze`` grabs
                 ``pages`` pool pages for ``hold`` ticks (pool-exhaustion
                 backpressure without real traffic).
  ``request``  — keyed by request id, not a counter. ``deadline`` stamps
                 ``deadline_s`` onto the request at submit.

Fault kinds: ``crash`` | ``error`` | ``stall`` | ``flip`` | ``squeeze``
| ``deadline``. All faults fire once (they are consumed), so a retried
dispatch always makes progress.

CLI spec (``--fault-plan``): either a path to a JSON file holding a list
of fault dicts, or an inline ``;``-separated spec where each item is
``kind:seam:at[:k=v,...]``, e.g.::

    crash:prefill:0:worker=p0;flip:step:3;deadline:request:5
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Dict, List, Optional, Tuple

SEAMS = ("prefill", "handoff", "decode", "step", "request")
KINDS = ("crash", "error", "stall", "flip", "squeeze", "deadline")


class InjectedFault(RuntimeError):
    """A transient dispatch exception injected by the plan."""


class WorkerCrash(RuntimeError):
    """An injected worker death; ``wid`` names the deceased."""

    def __init__(self, wid: str, seam: str = ""):
        super().__init__(f"injected crash of worker {wid!r}"
                         + (f" at seam {seam!r}" if seam else ""))
        self.wid = wid
        self.seam = seam


@dataclasses.dataclass(frozen=True)
class Fault:
    """One scheduled fault. ``at`` counts dispatches at ``seam`` (from 0);
    with ``worker`` set the count is per-(seam, worker), otherwise it is
    the seam's global count. For seam ``request``, ``at`` is the rid."""

    kind: str
    seam: str
    at: int
    worker: str = ""
    stall_s: float = 0.0     # stall: injected latency
    page: int = -1           # flip: physical page (-1 = lowest sealed)
    bit: int = 0             # flip: bit index within the page/container
    param: int = 0           # flip: 1 = corrupt a resident weight container
    pages: int = 0           # squeeze: pool pages to hold
    hold: int = 1            # squeeze: scheduler ticks to hold them
    deadline_s: float = 0.0  # deadline: stamped onto the request

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.seam not in SEAMS:
            raise ValueError(f"unknown fault seam {self.seam!r}")

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return {k: v for k, v in d.items()
                if v != Fault.__dataclass_fields__[k].default}


_NUMERIC = {"at": int, "stall_s": float, "page": int, "bit": int,
            "param": int, "pages": int, "hold": int, "deadline_s": float}


class FaultPlan:
    """A consumable schedule of :class:`Fault` s with per-seam counters."""

    def __init__(self, faults: List[Fault]):
        self._pending: List[Fault] = list(faults)
        self._counts: Dict[Tuple[str, str], int] = {}
        self.fired: List[Fault] = []

    def __len__(self) -> int:
        return len(self._pending)

    # -- seam API ------------------------------------------------------------

    def fire(self, seam: str, *, worker: str = "") -> List[Fault]:
        """Advance the (seam[, worker]) dispatch counters and consume the
        faults scheduled for this dispatch. Returns them ordered; raising
        kinds (crash/error) are the caller's job to act on."""
        assert seam in SEAMS, seam
        n_global = self._counts.get((seam, ""), 0)
        self._counts[(seam, "")] = n_global + 1
        n_worker = None
        if worker:
            n_worker = self._counts.get((seam, worker), 0)
            self._counts[(seam, worker)] = n_worker + 1
        hits, rest = [], []
        for f in self._pending:
            if f.seam != seam:
                rest.append(f)
            elif f.worker:
                (hits if worker == f.worker and n_worker == f.at
                 else rest).append(f)
            elif f.at == n_global:
                hits.append(f)
            else:
                rest.append(f)
        self._pending = rest
        self.fired.extend(hits)
        return hits

    def raise_any(self, hits: List[Fault], *, wid: str = "w0") -> None:
        """Standard seam epilogue: sleep the stalls, then raise the first
        crash/error (flip/squeeze/deadline are scheduler-handled and are
        not expected at executor seams). ``wid`` attributes a globally
        scheduled crash to the worker actually dispatching."""
        import time
        for f in hits:
            if f.kind == "stall":
                time.sleep(f.stall_s)
        for f in hits:
            if f.kind == "crash":
                raise WorkerCrash(f.worker or wid, f.seam)
            if f.kind == "error":
                raise InjectedFault(
                    f"injected dispatch error at seam {f.seam!r}")

    def for_request(self, rid: int) -> List[Fault]:
        """Consume the faults keyed to request ``rid`` (seam 'request')."""
        hits = [f for f in self._pending
                if f.seam == "request" and f.at == rid]
        if hits:
            self._pending = [f for f in self._pending if f not in hits]
            self.fired.extend(hits)
        return hits

    # -- construction --------------------------------------------------------

    @classmethod
    def seeded(cls, seed: int, *, steps: int = 16, workers=("w0",),
               pool_pages: int = 0, n_requests: int = 0,
               intensity: float = 0.5) -> "FaultPlan":
        """A randomized-but-deterministic chaos schedule: ``seed`` fully
        determines the faults (numpy Generator, no wall clock). Used by
        the chaos scenario runner to sweep schedules reproducibly."""
        import numpy as np
        rng = np.random.default_rng(seed)
        faults: List[Fault] = []
        n = max(1, int(round(intensity * 4)))
        for _ in range(n):
            roll = rng.random()
            at = int(rng.integers(0, max(steps, 1)))
            if roll < 0.3:
                faults.append(Fault("error", "prefill", at))
            elif roll < 0.5:
                faults.append(Fault("error", "decode", at))
            elif roll < 0.7 and pool_pages:
                faults.append(Fault(
                    "squeeze", "step", at,
                    pages=int(rng.integers(1, max(pool_pages // 2, 2))),
                    hold=int(rng.integers(1, 4))))
            elif roll < 0.85 and n_requests:
                faults.append(Fault(
                    "deadline", "request",
                    int(rng.integers(0, n_requests)), deadline_s=0.0))
            else:
                faults.append(Fault("stall", "handoff", at,
                                    stall_s=float(rng.random() * 1e-3)))
        return cls(faults)

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse the ``--fault-plan`` argument: a JSON file path or an
        inline ``kind:seam:at[:k=v,...];...`` spec."""
        spec = spec.strip()
        if os.path.exists(spec):
            with open(spec) as f:
                return cls([Fault(**d) for d in json.load(f)])
        if spec.startswith("["):
            return cls([Fault(**d) for d in json.loads(spec)])
        faults = []
        for item in filter(None, (s.strip() for s in spec.split(";"))):
            parts = item.split(":")
            if len(parts) < 3:
                raise ValueError(
                    f"fault spec item {item!r} needs kind:seam:at")
            kind, seam, at = parts[0], parts[1], int(parts[2])
            kw = {}
            for extra in parts[3:]:
                for pair in filter(None, extra.split(",")):
                    k, _, v = pair.partition("=")
                    if k not in _NUMERIC and k != "worker":
                        raise ValueError(f"unknown fault field {k!r}")
                    kw[k] = _NUMERIC[k](v) if k in _NUMERIC else v
            faults.append(Fault(kind, seam, at, **kw))
        return cls(faults)

    def as_dicts(self) -> List[dict]:
        return [f.as_dict() for f in self._pending]
