"""Batched LDPC-decode server: request queue -> bucketed bit-flip decode.

The coding twin of launch/retrieval.py's continuous-batching loop: decode
requests (one noisy word each) arrive in a queue; the shared
``BucketedBatchServer`` scheduler drains them in fixed word-batch buckets
(bounded compiled shapes, tail padding only on the final partial bucket),
runs one fused ``BitFlipDecoder.decode`` per bucket, then retires every
request with its slice of the batch result.  With a ``mesh``, each
bucket's codeword block row-shards over the mesh axis — bit-identical to
single device.

CLI (self-contained demo: plants codewords pushed through a worst-case
t-error channel that the array code provably corrects, then reports QPS
and emulated PPAC cycles vs the §IV-B compute-cache baseline):

    PYTHONPATH=src python -m repro.launch.coding \
        --rows 32 --cols 32 --requests 256 [--errors 1] [--backend mxu]
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import numpy as np

from ..gf2.ldpc import BitFlipDecoder, LDPCCode, bsc_flip, make_array_ldpc
from .bucketed import BucketedBatchServer


@dataclasses.dataclass
class DecodeRequest:
    rid: int
    word: np.ndarray                      # [n] {0,1} noisy channel output
    msg: Optional[np.ndarray] = None      # [k] decoded message bits
    codeword: Optional[np.ndarray] = None
    ok: bool = False
    iters: int = -1
    done: bool = False


class CodingServer(BucketedBatchServer):
    """Bucketed batch scheduler over one BitFlipDecoder."""

    def __init__(self, decoder: BitFlipDecoder, *,
                 buckets=(1, 4, 16, 64), mesh=None, shard_axis: str = "data"):
        super().__init__(buckets=buckets)
        self.decoder = decoder
        self.mesh = mesh
        self.shard_axis = shard_axis

    @property
    def code(self) -> LDPCCode:
        return self.decoder.code

    def _validate(self, req: DecodeRequest):
        assert req.word.shape == (self.code.n,), req.word.shape

    def _row(self, req: DecodeRequest) -> np.ndarray:
        return req.word

    def _run(self, words: np.ndarray):
        return self.decoder.decode(words, mesh=self.mesh,
                                   shard_axis=self.shard_axis)

    def _retire(self, req: DecodeRequest, res, i: int):
        req.codeword = res.codewords[i].copy()
        req.msg = res.msgs[i].copy()
        req.ok = bool(res.ok[i])
        req.iters = int(res.iters[i])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=32)
    ap.add_argument("--cols", type=int, default=32)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--errors", type=int, default=1,
                    help="bit errors planted per word (array code "
                         "guarantees correction of 1)")
    ap.add_argument("--max-iters", type=int, default=8)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--metrics", action="store_true",
                    help="print the telemetry registry (Prometheus text) "
                         "after the run")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    code = make_array_ldpc(args.rows, args.cols)
    decoder = BitFlipDecoder(code, backend=args.backend,
                             max_iters=args.max_iters)
    print(f"array code: n={code.n} k={code.k} rate={code.rate:.3f} "
          f"checks={code.n_chk} guaranteed_t={code.guaranteed_t}")

    msgs = rng.integers(0, 2, (args.requests, code.k)).astype(np.uint8)
    codewords = code.encode(msgs, backend=decoder.backend)
    noisy = bsc_flip(codewords, args.errors, rng)

    server = CodingServer(decoder)
    for i in range(args.requests):
        server.submit(DecodeRequest(i, noisy[i]))

    cycles0 = decoder.counter.cycles
    t0 = time.perf_counter()
    done = server.run()
    dt = time.perf_counter() - t0
    cycles = decoder.counter.cycles - cycles0

    recovered = sum(int(np.array_equal(r.msg, msgs[r.rid])) for r in done)
    print(f"served {len(done)} decodes in {dt:.2f}s "
          f"({len(done) / dt:.1f} QPS, {server.batches} batches, "
          f"buckets={ {b: c for b, c in server.bucket_counts.items() if c} })")
    print(f"emulated PPAC cycles: {cycles} total, "
          f"{cycles / len(done):.1f}/word; compute-cache baseline "
          f"{decoder.compute_cache_cycles_per_word_iteration()} cycles/word/iter "
          f"vs PPAC {decoder.cycles_per_word_iteration()}")
    print(f"recovered {recovered}/{len(done)} messages "
          f"({args.errors} bit errors/word)")
    if args.errors <= code.guaranteed_t:
        assert recovered == len(done), \
            "<= t errors must always be corrected"
    if args.metrics:
        print(server.metrics.prometheus_text(), end="")
    print("OK")


if __name__ == "__main__":
    main()
