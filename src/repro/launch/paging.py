"""Host-side physical page allocator for the paged KV cache.

The device holds the paged pools and the block table
(``models.lm.init_cache(page_size=...)``); this module owns the *policy*
side: which physical pages are free, how many references point at each
page (a page shared by a prefix-cache hit carries one reference per
mapping slot plus one held by the prefix index itself), and the
conservation law tests pin down:

    sum(refcount) == live table mappings + index-held registrations

Allocation is O(n) off a free deque; freeing is refcount-driven
(``decref`` returns the pages that actually went free so the caller can
evict their prefix-index registrations and reset table rows).

Integrity bookkeeping (``--kv-crc``): a fully-written prompt page can be
*sealed* with a GF(2) CRC tag (computed by the scheduler's scrub pass via
``gf2.ops.crc_tags``); a page whose recomputed tag mismatches is
*quarantined* — it never returns to the free list, shrinking ``capacity``
but guaranteeing the corrupted frame is never re-issued.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional, Sequence

import numpy as np


class PagePool:
    """Free-list + per-page refcounts over ``pages`` physical pages."""

    def __init__(self, pages: int):
        assert pages > 0
        self.pages = pages
        self.refcount = np.zeros(pages, np.int32)
        self._free = deque(range(pages))
        self._sealed: Dict[int, int] = {}   # page -> CRC tag
        self._dead: set = set()             # quarantined: never freed again

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.pages - len(self._free)

    @property
    def dead_pages(self) -> int:
        """Quarantined page count (in or out of service)."""
        return len(self._dead)

    @property
    def capacity(self) -> int:
        """Pages that can still serve traffic (total minus quarantined)."""
        return self.pages - len(self._dead)

    @property
    def quarantined(self) -> List[int]:
        return sorted(self._dead)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` fresh pages at refcount 1, or None if the pool
        can't satisfy the request (caller decides: evict or backpressure)."""
        if n < 0 or n > len(self._free):
            return None
        out = [self._free.popleft() for _ in range(n)]
        for p in out:
            assert self.refcount[p] == 0, (p, int(self.refcount[p]))
            self.refcount[p] = 1
        return out

    def incref(self, pages: Sequence[int]):
        for p in pages:
            assert self.refcount[p] > 0, f"incref of free page {p}"
            self.refcount[p] += 1

    def decref(self, pages: Sequence[int]) -> List[int]:
        """Drop one reference per page; returns pages that went free.
        Quarantined pages reaching refcount 0 stay OUT of the free list
        (and are not reported freed) — a corrupted frame is retired, not
        recycled."""
        freed = []
        for p in pages:
            assert self.refcount[p] > 0, f"decref of free page {p}"
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._sealed.pop(p, None)  # next owner reseals fresh content
                if p not in self._dead:
                    self._free.append(p)
                    freed.append(p)
        return freed

    # -- integrity (CRC seal / quarantine) -----------------------------------

    def seal(self, page: int, tag: int) -> None:
        """Record the CRC tag of a fully-written (immutable) page."""
        assert self.refcount[page] > 0, f"seal of free page {page}"
        self._sealed[page] = int(tag)

    def sealed_tag(self, page: int) -> Optional[int]:
        return self._sealed.get(page)

    def is_sealed(self, page: int) -> bool:
        return page in self._sealed

    def sealed_items(self) -> Dict[int, int]:
        """Snapshot of page -> tag for the scrub pass."""
        return dict(self._sealed)

    def quarantine(self, page: int) -> None:
        """Retire a page from service: it keeps its current references
        (the scheduler fails/evicts the mappings) but will never re-enter
        the free list once they drop."""
        self._dead.add(page)
        self._sealed.pop(page, None)
