"""Host-side physical page allocator for the paged KV cache.

The device holds the paged pools and the block table
(``models.lm.init_cache(page_size=...)``); this module owns the *policy*
side: which physical pages are free, how many references point at each
page (a page shared by a prefix-cache hit carries one reference per
mapping slot plus one held by the prefix index itself), and the
conservation law tests pin down:

    sum(refcount) == live table mappings + index-held registrations

Allocation is O(n) off a free deque; freeing is refcount-driven
(``decref`` returns the pages that actually went free so the caller can
evict their prefix-index registrations and reset table rows).
"""
from __future__ import annotations

from collections import deque
from typing import List, Optional, Sequence

import numpy as np


class PagePool:
    """Free-list + per-page refcounts over ``pages`` physical pages."""

    def __init__(self, pages: int):
        assert pages > 0
        self.pages = pages
        self.refcount = np.zeros(pages, np.int32)
        self._free = deque(range(pages))

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def used_pages(self) -> int:
        return self.pages - len(self._free)

    def alloc(self, n: int) -> Optional[List[int]]:
        """Take ``n`` fresh pages at refcount 1, or None if the pool
        can't satisfy the request (caller decides: evict or backpressure)."""
        if n < 0 or n > len(self._free):
            return None
        out = [self._free.popleft() for _ in range(n)]
        for p in out:
            assert self.refcount[p] == 0, (p, int(self.refcount[p]))
            self.refcount[p] = 1
        return out

    def incref(self, pages: Sequence[int]):
        for p in pages:
            assert self.refcount[p] > 0, f"incref of free page {p}"
            self.refcount[p] += 1

    def decref(self, pages: Sequence[int]) -> List[int]:
        """Drop one reference per page; returns pages that went free."""
        freed = []
        for p in pages:
            assert self.refcount[p] > 0, f"decref of free page {p}"
            self.refcount[p] -= 1
            if self.refcount[p] == 0:
                self._free.append(p)
                freed.append(p)
        return freed
