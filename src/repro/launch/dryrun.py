import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch × shape) cell on the
production meshes, extract memory/cost/roofline terms.

The two lines above MUST precede any jax import: jax locks the device
count at first backend init, and the dry-run needs 512 placeholder CPU
devices to build the 2×16×16 production mesh. Tests/benchmarks import
this module never — they see 1 device.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2_72b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod both]
Results are cached as JSON under results/dryrun/ (one file per cell) so
the full sweep is resumable.
"""
import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from ..configs.base import SHAPES, cells, load_arch  # noqa: E402
from ..core.cost_model import (  # noqa: E402
    TPU_HBM_BW,
    TPU_ICI_BW,
    TPU_PEAK_BF16_FLOPS,
)
from ..optim.adamw import AdamWConfig  # noqa: E402
from ..train.step import TrainConfig  # noqa: E402
from .hlo_analysis import analysis_dict  # noqa: E402
from .mesh import make_production_mesh  # noqa: E402
from .specs import build_cell  # noqa: E402

# Per-arch baseline policies: FSDP (weights' embed dim sharded over 'data')
# for models whose fp32 replicated-state would not fit 16 GB/chip;
# int8 optimizer moments for the 1T MoE.
FSDP_ARCHS = {"h2o_danube3_4b", "stablelm_12b", "qwen2_72b",
              "deepseek_v2_lite_16b", "kimi_k2_1t_a32b", "llava_next_34b"}
QUANT_OPT_ARCHS = {"kimi_k2_1t_a32b", "qwen2_72b"}


def make_tcfg(arch: str, *, quant_opt=None, microbatches=1,
              grad_compress=False) -> TrainConfig:
    q = (arch in QUANT_OPT_ARCHS) if quant_opt is None else quant_opt
    return TrainConfig(
        opt=AdamWConfig(quantized_state=q),
        microbatches=microbatches,
        cross_pod_grad_dtype="bfloat16" if grad_compress else "float32",
    )


def make_rules(arch: str, shape_name: str, mesh, *, fsdp=None,
               pure_dp: bool = False, cache_seq_shard: bool = False,
               seq_shard: bool = False):
    from ..sharding.rules import default_rules
    from .specs import data_axes
    overrides = {}
    if pure_dp:
        # small-model mode: no TP at all — the whole mesh is data-parallel
        # (weights replicated), batch sharded over every axis.
        all_axes = tuple(mesh.axis_names)
        overrides.update({"mlp": None, "heads": None, "kv_heads": None,
                          "vocab": None, "expert": None, "ssm_inner": None,
                          "act_heads": None, "batch": all_axes,
                          "groups": all_axes})
    use_fsdp = (arch in FSDP_ARCHS) if fsdp is None else fsdp
    if use_fsdp:
        overrides["embed"] = data_axes(mesh)
    shape = SHAPES[shape_name]
    dp = 1
    for a in data_axes(mesh):
        dp *= mesh.shape[a]
    if shape.global_batch % dp or shape.global_batch < dp:
        overrides["batch"] = None
        overrides["kv_seq"] = "data"
    if seq_shard:
        # Megatron-style sequence parallelism: the residual stream (and its
        # per-layer remat saves) shard over 'model'; GSPMD inserts the
        # all-gather/reduce-scatter pairs around attention/MLP.
        overrides["seq"] = "model"
    if cache_seq_shard and shape.kind == "decode":
        # shard the KV-cache sequence dim over 'model' (sequence
        # parallelism for the cache): GSPMD turns the per-step softmax
        # into a partial-softmax + reduction
        overrides["kv_seq"] = "model"
    return default_rules(**overrides).for_mesh(mesh)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             fsdp=None, quant_opt=None, grad_compress=False,
             microbatches: int = 1, serve_quant: bool = False,
             save_hlo: str = "", rules=None, tag: str = "",
             remat: str = "", q_chunk: int = 0, pure_dp: bool = False,
             attn_blocking: str = "", scores_dtype: str = "",
             cache_seq_shard: bool = False, kv_dtype: str = "",
             seq_shard: bool = False) -> dict:
    cfg = load_arch(arch).full()
    if remat:
        cfg = dataclasses.replace(cfg, remat=remat)
    if q_chunk:
        cfg = dataclasses.replace(cfg, q_chunk=q_chunk)
    if attn_blocking:
        cfg = dataclasses.replace(cfg, attn_blocking=attn_blocking)
    if scores_dtype:
        cfg = dataclasses.replace(cfg, scores_dtype=scores_dtype)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.size
    tcfg = make_tcfg(arch, quant_opt=quant_opt, microbatches=microbatches,
                     grad_compress=grad_compress)
    if kv_dtype:
        cfg = dataclasses.replace(cfg, kv_dtype=kv_dtype)
    if rules is None:
        rules = make_rules(arch, shape_name, mesh, fsdp=fsdp,
                           pure_dp=pure_dp, cache_seq_shard=cache_seq_shard,
                           seq_shard=seq_shard)

    t0 = time.time()
    with mesh:
        cell = build_cell(cfg, shape, mesh, tcfg=tcfg, rules=rules,
                          serve_quant=serve_quant)
        lowered = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                          donate_argnums=cell.donate_argnums).lower(
            *cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    text = compiled.as_text()
    hlo = analysis_dict(text)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(text)

    # roofline terms (per-chip quantities; shapes in the partitioned module
    # are already per-device)
    compute_s = hlo["flops"] / TPU_PEAK_BF16_FLOPS
    memory_s = hlo["traffic_bytes"] / TPU_HBM_BW
    collective_s = hlo["collective_total"] / TPU_ICI_BW
    terms = dict(compute_s=compute_s, memory_s=memory_s,
                 collective_s=collective_s)
    dominant = max(terms, key=terms.get)

    # useful-FLOP ratio: MODEL_FLOPS vs compiled FLOPs (global)
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * n_active * tokens
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * n_active * tokens
    else:
        model_flops = 2.0 * n_active * shape.global_batch
    hlo_flops_global = hlo["flops"] * chips
    useful = model_flops / hlo_flops_global if hlo_flops_global else 0.0

    bound_time = max(terms.values())
    roofline_fraction = (model_flops / TPU_PEAK_BF16_FLOPS / chips) \
        / bound_time if bound_time else 0.0

    # memory-roofline efficiency: ideal HBM time = reading the live bytes
    # (weights + caches + batch) exactly once per step. This is the honest
    # roofline for decode (which can never be compute-bound).
    def _tree_bytes(t):
        return sum(l.dtype.itemsize * int(__import__("math").prod(l.shape))
                   for l in jax.tree.leaves(t)
                   if hasattr(l, "shape") and hasattr(l, "dtype"))

    live_bytes = sum(_tree_bytes(a) for a in cell.args)
    ideal_memory_s = live_bytes / chips / TPU_HBM_BW
    mem_efficiency = ideal_memory_s / memory_s if memory_s else 0.0
    if shape.kind == "decode":
        roofline_fraction = ideal_memory_s / bound_time if bound_time else 0.0

    out = dict(
        arch=arch, shape=shape_name, kind=shape.kind, tag=tag,
        multi_pod=multi_pod, chips=chips,
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=dict(
            argument_bytes=mem.argument_size_in_bytes,
            output_bytes=mem.output_size_in_bytes,
            temp_bytes=mem.temp_size_in_bytes,
            code_bytes=mem.generated_code_size_in_bytes,
            total_per_chip=mem.argument_size_in_bytes
            + mem.temp_size_in_bytes + mem.output_size_in_bytes,
        ),
        xla_cost=dict(flops=cost.get("flops"),
                      bytes_accessed=cost.get("bytes accessed")),
        hlo=hlo,
        roofline=dict(**terms, dominant=dominant,
                      model_flops=model_flops,
                      hlo_flops_global=hlo_flops_global,
                      useful_flop_ratio=useful,
                      ideal_memory_s=ideal_memory_s,
                      mem_efficiency=mem_efficiency,
                      roofline_fraction=roofline_fraction),
        params_total=cfg.param_count(),
        params_active=n_active,
    )
    return out


def cell_path(outdir, arch, shape_name, multi_pod, tag=""):
    mp = "pod2" if multi_pod else "pod1"
    suffix = f"_{tag}" if tag else ""
    return os.path.join(outdir, f"{arch}__{shape_name}__{mp}{suffix}.json")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--fsdp", default=None, type=lambda s: s == "1")
    ap.add_argument("--quant-opt", default=None, type=lambda s: s == "1")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--serve-quant", action="store_true")
    ap.add_argument("--tag", default="")
    ap.add_argument("--remat", default="")
    ap.add_argument("--pure-dp", action="store_true")
    ap.add_argument("--attn-blocking", default="")
    ap.add_argument("--scores-dtype", default="")
    ap.add_argument("--cache-seq-shard", action="store_true")
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--kv-dtype", default="")
    ap.add_argument("--q-chunk", type=int, default=0)
    ap.add_argument("--save-hlo", default="")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    todo = []
    pods = {"single": [False], "multi": [True], "both": [False, True]}[
        args.multi_pod]
    if args.all:
        for arch, shape_name, skip in cells():
            for mp in pods:
                todo.append((arch, shape_name, mp))
    else:
        for mp in pods:
            todo.append((args.arch, args.shape, mp))

    failures = []
    for arch, shape_name, mp in todo:
        path = cell_path(args.out, arch, shape_name, mp, args.tag)
        if os.path.exists(path) and not args.force:
            print(f"[skip cached] {path}")
            continue
        label = f"{arch} × {shape_name} × {'2pod' if mp else '1pod'}"
        print(f"=== {label} ===", flush=True)
        try:
            res = run_cell(arch, shape_name, multi_pod=mp, fsdp=args.fsdp,
                           quant_opt=args.quant_opt,
                           grad_compress=args.grad_compress,
                           microbatches=args.microbatches,
                           serve_quant=args.serve_quant,
                           save_hlo=args.save_hlo, tag=args.tag,
                           remat=args.remat, q_chunk=args.q_chunk,
                           pure_dp=args.pure_dp,
                           attn_blocking=args.attn_blocking,
                           scores_dtype=args.scores_dtype,
                           cache_seq_shard=args.cache_seq_shard,
                           kv_dtype=args.kv_dtype, seq_shard=args.seq_shard)
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            r = res["roofline"]
            print(f"  lower {res['lower_s']}s compile {res['compile_s']}s | "
                  f"mem/chip {res['memory']['total_per_chip']/2**30:.2f} GiB | "
                  f"compute {r['compute_s']*1e3:.2f}ms mem {r['memory_s']*1e3:.2f}ms "
                  f"coll {r['collective_s']*1e3:.2f}ms -> {r['dominant']} | "
                  f"roofline {r['roofline_fraction']:.3f}", flush=True)
        except Exception as e:
            failures.append((label, repr(e)))
            print(f"  FAILED: {e}\n{traceback.format_exc()}", flush=True)
    if failures:
        print("\nFAILURES:")
        for l, e in failures:
            print(f"  {l}: {e}")
        raise SystemExit(1)
    print("\nALL CELLS OK")


if __name__ == "__main__":
    main()
