"""Continuous-batching LM decode server: device-resident ring/linear KV
caches, slot-based admission/eviction, bucketed prefill.

The serving loop the kernel work of PRs 3-4 was building toward — the LM
itself served to many concurrent users:

  * a resident cache pytree sized [slots, max_seq, ...] lives on device
    for the whole server lifetime; every jitted entry point *donates* it
    (``donate_argnums``), so per-token cache updates are in-place
    scatters, never whole-cache copies,
  * decode runs as ONE fused step over all slots with per-sequence
    positions (``cache['pos']: [S]``) — sequences at different depths
    (admitted mid-flight) share the step bit-exactly with solo decoding,
  * new requests prefill into free slots while resident sequences keep
    decoding: waiting prompts are drained in *batch buckets* (the shared
    :func:`repro.launch.bucketed.drain_take` policy) and *right-padded*
    into power-of-two length buckets — right padding + per-sequence
    ``lengths`` keeps causal prefill bit-identical to the unpadded
    prompt, and the number of compiled (batch, length) prefill shapes
    stays bounded,
  * per-slot retirement on EOS or length; the freed slot is refilled
    from the queue on the next admission pass,
  * token selection (greedy / temperature / top-k) is fused into the
    prefill and decode programs — the host only ever sees the [S] int32
    ids it needs for retirement decisions.

Paged mode (``paged=True`` / ``--paged``) virtualizes the cache: KV
leaves become fixed-size page pools ([pool_pages, page_size, ...]) and a
[slots, extent/page_size] block table maps logical to physical pages
(models/attention.py gathers rows through it, same trick as
``_ring_rows``). Admission becomes page allocation off a host free list
with per-page refcounts: memory scales with *live tokens*, a too-small
pool backpressures admission instead of crashing, and — with
``prefix_cache=True`` — each full prompt page hashes into a chained
128-bit key matched against resident pages via one batched CAM launch
(``retrieval/prefix.py``): a hit maps the new slot's table entries onto
existing pages (copy-on-write for a shared tail page) and only the
suffix is prefilled. Prefill writes go straight through the table into
the donated resident pools — no scratch cache, no copy step.

Chaos hardening (PR 10): an optional :class:`FaultPlan` (``--fault-plan``
/ ``--fault-seed``) injects deterministic worker crashes, dispatch
errors, handoff stalls, KV/weight bit-flips, pool squeezes and request
deadlines. The scheduler guarantees every submitted request reaches
exactly ONE terminal outcome — ``completed`` | ``shed`` (deadline) |
``failed`` (with a reason) — via bounded retry with page-refcount-correct
unwinding, deadline load shedding, and (``--kv-crc``) a GF(2)-CRC scrub
(``gf2/ops.crc_tags``) that tags sealed prompt pages after prefill and
quarantines any page whose recomputed tag drifts before decode can read
it. With no plan and no CRC flags the serving path is unchanged.

CLI: PYTHONPATH=src python -m repro.launch.serve_lm --arch smollm_360m \
        --requests 12 --max-new 16 [--serve-quant --weight-bits 4] \
        [--kv-int8] [--temperature 0.8 --top-k 40] [--eos 0] \
        [--paged --page-size 16 --pool-pages 64 --prefix-cache] \
        [--fault-plan 'crash:prefill:0:worker=p0;flip:step:3' \
         --kv-crc --scrub-every 1 --chaos-gate]
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import sys
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, load_arch
from ..models import lm
from ..obs.metrics import MetricsRegistry
from ..obs.trace import TraceBuilder, annotate
from ..retrieval.prefix import PagePrefixIndex
from ..serve.step import convert_params_for_serving, serving_cycle_report
from .bucketed import bucket_for, drain_take
from .faults import FaultPlan, InjectedFault, WorkerCrash
from .mesh import make_serving_mesh, parse_mesh_spec
from .paging import PagePool
from .workers import DisaggExecutor, LocalExecutor


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: Optional[str] = None
    # terminal outcome: every submitted request resolves to exactly one
    # of 'completed' | 'shed' | 'failed' (fail_reason says why)
    outcome: Optional[str] = None
    fail_reason: Optional[str] = None
    deadline_s: Optional[float] = None  # submit-relative; None = none
    retries: int = 0
    # telemetry timestamps (perf_counter readings, set by the server)
    submit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    retire_t: Optional[float] = None

    @property
    def latency_s(self) -> Optional[float]:
        """End-to-end submit -> retire latency (None until retired)."""
        if self.submit_t is None or self.retire_t is None:
            return None
        return self.retire_t - self.submit_t


class LMServer:
    """Slot-based continuous batching over a resident, donated cache.

    The server is the *scheduler* half of a scheduler/executor split
    (``launch/workers.py``): it owns admission, paging, and retirement;
    every jitted dispatch goes through ``self.ex``. Three layouts:

      * default — :class:`LocalExecutor` on one device (the PR<=8 path),
      * ``mesh=`` — the same executor with the resident weights TP-
        sharded and the slot/page cache slot-parallel over the mesh,
      * ``prefill_devices``/``decode_devices`` — :class:`DisaggExecutor`
        with disjoint prefill/decode device pools bridged by a
        ``jax.device_put`` cache handoff.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_seq: int = 128, mode: str = "float", rules=None,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 admit_buckets: Sequence[int] = (1, 2, 4),
                 metrics: Optional[MetricsRegistry] = None,
                 trace: Optional[TraceBuilder] = None,
                 paged: bool = False, page_size: int = 16,
                 pool_pages: Optional[int] = None,
                 prefix_cache: bool = False, cache_dtype=None,
                 spec_decode: bool = False, draft_k: int = 4,
                 mesh=None, prefill_devices: int = 0,
                 decode_devices: int = 0, prefill_workers: int = 0,
                 decode_mesh_shape=None,
                 faults: Optional[FaultPlan] = None, max_retries: int = 1,
                 max_worker_restarts: int = 1, kv_crc: bool = False,
                 scrub_every: int = 0):
        assert tuple(admit_buckets) == tuple(sorted(admit_buckets))
        if prefill_buckets is None:
            # powers of two up to max_seq (any prompt that leaves room to
            # decode is admissible; a bucket may not exceed the cache)
            prefill_buckets, b = [], 8
            while b < max_seq:
                prefill_buckets.append(b)
                b *= 2
            prefill_buckets.append(max_seq)
        assert tuple(prefill_buckets) == tuple(sorted(prefill_buckets))
        assert prefill_buckets[-1] <= max_seq
        self.cfg, self.mode = cfg, mode
        self.slots, self.max_seq = slots, max_seq
        self.prefill_buckets = tuple(prefill_buckets)
        self.admit_buckets = tuple(admit_buckets)
        # SSM state accumulation has no position mask: padded prefill
        # would fold pad tokens into the recurrent state (wrong tokens,
        # silently). SSM/hybrid prompts prefill at their exact length —
        # batched only with same-length peers.
        self.pad_prompts = cfg.family not in ("ssm", "hybrid")
        self.live: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.terminal: List[Request] = []  # shed + failed (never retired)
        self.decode_steps = 0
        self.admit_batches = 0
        # chaos / integrity state
        self.faults = faults
        self.max_retries = max_retries
        self.kv_crc = kv_crc
        self.scrub_every = scrub_every
        self._ticks = 0
        self._squeezes: List[list] = []    # [ticks_left, held_pages]
        self._pending_flips: List = []     # flips waiting for a sealed page
        if kv_crc and not paged:
            raise ValueError("--kv-crc seals KV pages; it needs --paged")
        if kv_crc and cfg.sliding_window:
            raise ValueError("--kv-crc needs a linear cache: ring pages "
                             "are rewritten in place after sealing")
        # telemetry: always-on registry (negligible cost — a few Python
        # dict/float ops per step), optional Chrome-trace span capture
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.trace = trace
        self._key = jax.random.PRNGKey(seed)
        self.paged, self.page_size = paged, page_size
        self._cache_dtype = cache_dtype
        ckw = {} if cache_dtype is None else {"dtype": cache_dtype}

        # family/layout validation happens here, before any executor (and
        # hence any compile or placement) is built
        self.spec_decode, self.draft_k = spec_decode, draft_k
        if paged and cfg.family in ("ssm", "hybrid"):
            raise ValueError("paged serving needs a token-indexed KV "
                             "cache; SSM/hybrid state stays contiguous")
        if spec_decode:
            if cfg.family in ("ssm", "hybrid"):
                raise ValueError("speculative decoding needs a "
                                 "token-indexed KV cache; SSM/hybrid "
                                 "state cannot rewind")
            if paged and cfg.sliding_window:
                raise ValueError("speculative decoding over a paged ring "
                                 "cache is unsupported: rejected wrapped "
                                 "writes cannot be rolled back through "
                                 "the block table")

        disagg = prefill_devices > 0 or decode_devices > 0
        if disagg and prefix_cache:
            raise ValueError("prefix-cache reuse prefills against resident "
                             "pool history, which disaggregated prefill "
                             "workers cannot read; drop --prefix-cache or "
                             "the worker split")
        if mesh is not None and not hasattr(mesh, "devices"):
            mesh = make_serving_mesh(tuple(mesh))  # shape tuple -> mesh
        if disagg:
            self.ex = DisaggExecutor(
                cfg, params, prefill_devices=max(prefill_devices, 1),
                decode_devices=max(decode_devices, 1),
                prefill_workers=prefill_workers,
                decode_mesh_shape=decode_mesh_shape, mode=mode,
                rules=rules, temperature=temperature, top_k=top_k,
                paged=paged, page_size=page_size, spec_decode=spec_decode,
                draft_k=draft_k, max_seq=max_seq, cache_dtype=cache_dtype,
                metrics=self.metrics, faults=faults,
                max_worker_restarts=max_worker_restarts)
        else:
            self.ex = LocalExecutor(
                cfg, params, mode=mode, rules=rules, mesh=mesh,
                temperature=temperature, top_k=top_k, paged=paged,
                spec_decode=spec_decode, draft_k=draft_k, max_seq=max_seq,
                cache_dtype=cache_dtype, metrics=self.metrics,
                faults=faults)

        if paged:
            self.extent = lm.paged_extent(cfg, max_seq)
            self.n_pages = self.extent // page_size
            self.pool_pages = (pool_pages if pool_pages is not None
                               else slots * self.n_pages)
            self.cache, caxes = lm.init_cache(cfg, slots, max_seq,
                                              page_size=page_size,
                                              pool_pages=self.pool_pages,
                                              **ckw)
            self.pool = PagePool(self.pool_pages)
            # host mirror of the device block table (sentinel = unmapped)
            self.table_np = np.full((slots, self.n_pages), self.pool_pages,
                                    np.int32)
            self.prefix = None
            if prefix_cache:
                if cfg.sliding_window:
                    raise ValueError("prefix reuse needs a linear cache: "
                                     "ring page contents depend on the "
                                     "sequence's own positions")
                self.prefix = PagePrefixIndex(page_size)
        else:
            # the resident cache: allocated once, donated through every step
            self.cache, caxes = lm.init_cache(cfg, slots, max_seq, **ckw)
        # on a mesh the resident cache shards slot-parallel ('data');
        # single-device executors return it unchanged
        self.cache = self.ex.place_cache(self.cache, caxes)

        # integrity baseline: CRC tags of every resident packed container
        # (host-side dict keyed by tree path — NOT in the pytree aux, so
        # jit caches stay unfragmented). Empty for float-mode params.
        self._param_tags: Dict[str, int] = {}
        if scrub_every > 0:
            from ..core.engine import container_tags
            self._param_tags = container_tags(self.ex.params)

    @property
    def params(self):
        """The resident (possibly sharded) weights live on the executor."""
        return self.ex.params

    # -- telemetry -----------------------------------------------------------

    @contextlib.contextmanager
    def _span(self, name: str, **args):
        """One server-track span: Chrome-trace event (when tracing) plus a
        jax.profiler annotation, so the same region shows up in both."""
        with annotate(name):
            if self.trace is not None:
                with self.trace.span(name, track="server",
                                     args=args or None):
                    yield
            else:
                yield

    # -- scheduling ----------------------------------------------------------

    def submit(self, req: Request):
        plen = len(req.prompt)
        assert 0 < plen <= self.prefill_buckets[-1], plen
        # prefill emits the first of the max_new tokens, so the last
        # decode step writes cache row plen + max_new - 2: a request
        # needs exactly plen + max_new - 1 rows, not plen + max_new.
        assert plen + req.max_new - 1 <= self.max_seq, \
            f"prompt {plen} + max_new {req.max_new} needs " \
            f"{plen + req.max_new - 1} cache rows, max_seq {self.max_seq}"
        req.submit_t = time.perf_counter()
        if self.faults is not None:  # request-keyed faults apply at submit
            for f in self.faults.for_request(req.rid):
                if f.kind == "deadline":
                    req.deadline_s = f.deadline_s
        self.metrics.counter("lm_requests_submitted").inc()
        self.queue.append(req)

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _plen_bucket(self, plen: int) -> int:
        """Padded prompt length for one request: a power-of-two bucket for
        attention families (right-pad is bit-exact under causal masking),
        the exact length for SSM/hybrid (padding would corrupt the state)."""
        if self.pad_prompts:
            return bucket_for(plen, self.prefill_buckets)
        return plen

    # -- terminal outcomes / recovery ----------------------------------------

    def _shed(self, r: Request, where: str):
        """Deadline load shedding: the request leaves the system with the
        terminal outcome 'shed' (never admitted, or aborted in flight)."""
        r.done = True
        r.outcome = "shed"
        r.finish_reason = "deadline"
        r.retire_t = time.perf_counter()
        self.metrics.counter("lm_requests_shed", where=where).inc()
        self.terminal.append(r)

    def _fail(self, r: Request, reason: str):
        """Terminal failure (retry budget exhausted, capacity,
        corruption): the request resolves — never silently dropped."""
        r.done = True
        r.outcome = "failed"
        r.fail_reason = reason
        r.finish_reason = reason
        r.retire_t = time.perf_counter()
        self.metrics.counter("lm_requests_failed", reason=reason).inc()
        self.terminal.append(r)

    def _abort_slot(self, s: int):
        """Free a live slot WITHOUT retiring its request (deadline abort,
        corruption re-prefill): pages decref'd through the normal reclaim
        path (quarantined pages stay dead), table row sentineled."""
        self.live[s] = None
        if self.paged:
            self._reclaim_pages()

    def _requeue(self, reqs: List[Request], exc: Exception):
        """Bounded-retry requeue after an injected/real dispatch failure:
        each request goes back to the queue FRONT in order (FIFO held);
        past ``max_retries`` it fails terminally. A WorkerCrash first
        routes through the executor's recovery (restart/drop/degrade)."""
        m = self.metrics
        if isinstance(exc, WorkerCrash):
            verdict = self.ex.on_worker_crash(exc.wid)
            m.counter("lm_worker_crashes", worker=exc.wid,
                      verdict=verdict).inc()
        keep = []
        for r in reqs:
            r.retries += 1
            m.counter("lm_retries").inc()
            if r.retries > self.max_retries:
                self._fail(r, "prefill")
            else:
                keep.append(r)
        self.queue[:0] = keep

    def _expire_deadlines(self):
        """Shed expired requests: at admission (still queued) and in
        flight (slot aborted, pages reclaimed). FIFO order of the
        surviving queue is untouched."""
        now = time.perf_counter()

        def expired(r):
            return (r.deadline_s is not None and r.submit_t is not None
                    and now - r.submit_t > r.deadline_s)
        if any(expired(r) for r in self.queue):
            keep = []
            for r in self.queue:
                (self._shed(r, "queue") if expired(r) else keep.append(r))
            self.queue = keep
        for s, r in enumerate(self.live):
            if r is not None and expired(r):
                self._abort_slot(s)
                self._shed(r, "inflight")

    def _admit(self):
        """Prefill waiting prompts into free slots, in bucketed batches.

        FIFO groups share one padded-length bucket per batch; the batch
        itself is padded to an admission bucket (``drain_take`` policy),
        so compiled prefill shapes stay bounded at
        len(prefill_buckets) x len(admit_buckets) (for SSM archs: one
        shape per distinct prompt length instead)."""
        free = [s for s in range(self.slots) if self.live[s] is None]
        while free and self.queue:
            plb = self._plen_bucket(len(self.queue[0].prompt))
            cap, _ = drain_take(min(len(free), len(self.queue)),
                                self.admit_buckets)
            grp: List[Request] = []
            while (self.queue and len(grp) < cap
                   and self._plen_bucket(len(self.queue[0].prompt)) == plb):
                grp.append(self.queue.pop(0))
            if self.paged:
                if not self._admit_paged(grp, free, plb):
                    break  # pool backpressure: retry after retirements
                continue
            blen = bucket_for(len(grp), self.admit_buckets)
            toks = np.zeros((blen, plb), np.int32)
            lens = np.ones((blen,), np.int32)
            for i, r in enumerate(grp):
                toks[i, :len(r.prompt)] = r.prompt  # RIGHT-pad: bit-exact
                lens[i] = len(r.prompt)
            t0 = time.perf_counter()
            try:
                with self._span("prefill_batch", batch=blen, plen=plb,
                                fill=len(grp) / blen):
                    tok0, handle = self.ex.prefill(jnp.asarray(toks),
                                                   jnp.asarray(lens),
                                                   self._next_key())
            except (InjectedFault, WorkerCrash) as e:
                # nothing resident yet: the whole group requeues (or
                # fails past its retry budget); stop admitting this tick
                self._requeue(grp, e)
                break
            t1 = time.perf_counter()
            self.admit_batches += 1
            m = self.metrics
            m.counter("lm_prefill_batches").inc()
            m.histogram("lm_prefill_s").record(t1 - t0)
            m.histogram("lm_admit_fill_ratio").record(len(grp) / blen)
            ok = 0
            for i, r in enumerate(grp):
                s = free[0]
                try:
                    self.cache = self.ex.write_slot(self.cache, handle,
                                                    i, s)
                except (InjectedFault, WorkerCrash) as e:
                    # crash mid-handoff: the resident cache is untouched
                    # (seams fire before the donating write) — this and
                    # every later row of the batch re-prefill
                    self._requeue(grp[i:], e)
                    break
                free.pop(0)
                ok += 1
                r.out.append(int(tok0[i]))
                r.first_token_t = t1  # prefill emits the first token
                if r.submit_t is not None:
                    m.histogram("lm_queue_wait_s").record(t0 - r.submit_t)
                    m.histogram("lm_ttft_s").record(t1 - r.submit_t)
                self.live[s] = r
            m.counter("lm_requests_admitted").inc(ok)
            # prefill emits each request's first token: count it here so
            # lm_tokens_generated matches sum(len(r.out)) — the decode
            # loop only adds the per-step occupancy (decode tokens)
            m.counter("lm_tokens_generated").inc(ok)
            if ok < len(grp):
                break

    def _admit_paged(self, grp: List[Request], free: List[int],
                     plb: int) -> bool:
        """Page-granular admission: map each request's table row onto
        physical pages off the pool (prefix hits first), then prefill
        cold prompts and hit suffixes straight through the table into
        the donated resident pools.

        Returns False when the pool backpressured: un-admitted requests
        went back to the queue FRONT (FIFO order preserved) and the
        caller stops admitting this tick — pages free up as live
        requests retire."""
        m = self.metrics
        psz = self.page_size
        plans = []  # (req, slot, mapping, keys, s0)
        bounced: List[Request] = []
        for r in grp:
            if bounced:  # keep FIFO order behind the first bounce
                bounced.append(r)
                continue
            plen = len(r.prompt)
            if self.cfg.sliding_window:
                # ring prefill writes all `extent` wrapped rows up front,
                # and ring page contents depend on the sequence's own
                # positions — every slot needs the full page complement
                need, keys, matched = self.n_pages, [], []
            else:
                rows = min(plen + r.max_new - 1, self.extent)
                need = -(-rows // psz)
                keys = (self.prefix.keys_for(r.prompt)
                        if self.prefix is not None else [])
                matched = (self.prefix.lookup(keys)
                           if self.prefix is not None and keys else [])
            if need > self.pool.pages:
                raise RuntimeError(
                    f"request {r.rid} needs {need} pages but the pool "
                    f"holds only {self.pool.pages}; raise --pool-pages "
                    f"or lower max_new")
            if need > self.pool.capacity:
                # quarantined pages shrank the pool below this request's
                # need: it can never fit — terminal, not a bounce
                self._fail(r, "capacity")
                continue
            nm = len(matched)
            # the suffix must re-emit from row plen-1 (whose logits pick
            # the first output token), so even a full match of every
            # prompt page still prefills one row — and that row lands in
            # a SHARED page: copy-on-write it into a private page first
            s0 = min(nm * psz, plen - 1)
            cow = nm > 0 and nm * psz > plen - 1
            fresh_needed = need - nm + (1 if cow else 0)
            pages = self.pool.alloc(fresh_needed)
            if pages is None and self.prefix is not None:
                # recycle idle registrations (refcount == 1, LRU) — but
                # never the pages this very request just matched
                protect = set(matched)
                for p in self.prefix.idle_pages(self.pool.refcount):
                    if p in protect:
                        continue
                    self.prefix.evict_page(p)
                    self.pool.decref([p])
                    m.counter("lm_prefix_pages_evicted").inc()
                    if self.pool.free_pages >= fresh_needed:
                        break
                pages = self.pool.alloc(fresh_needed)
            if pages is None:
                # a fault-injected squeeze returns its pages in a known
                # number of ticks: bounce, don't raise
                if (not plans and not self._squeezes
                        and not any(x is not None for x in self.live)):
                    raise RuntimeError(
                        f"pool exhausted with no live requests to "
                        f"retire: request {r.rid} needs {fresh_needed} "
                        f"fresh pages, {self.pool.free_pages} free of "
                        f"{self.pool.pages}")
                bounced.append(r)
                continue
            mapping = list(matched)
            if cow:
                src, dst = mapping[-1], pages.pop(0)
                mapping[-1] = dst
                self.cache = self.ex.copy_page(self.cache, jnp.int32(src),
                                               jnp.int32(dst))
                m.counter("lm_pages_cow").inc()
                self.pool.incref(matched[:-1])  # still-shared pages only
            else:
                self.pool.incref(matched)
            mapping += pages
            s = free.pop(0)
            self.table_np[s] = self.pool_pages  # sentinel-fill the tail
            self.table_np[s, :len(mapping)] = mapping
            m.counter("lm_prefix_pages_hit").inc(nm)
            m.counter("lm_prefix_pages_total").inc(plen // psz)
            m.counter("lm_prefill_rows_skipped").inc(s0)
            plans.append((r, s, mapping, keys, s0))
        if bounced:
            self.queue[:0] = bounced
        done_plans, launch_failed = [], False
        if plans:
            slot_ids = np.array([p[1] for p in plans], np.int32)
            self.cache = self.ex.table_write(
                self.cache, jnp.asarray(slot_ids),
                jnp.asarray(self.table_np[slot_ids]))
            cold = [p for p in plans if p[4] == 0]
            hits = [p for p in plans if p[4] > 0]
            by_slb = {}
            for p in hits:  # suffixes re-bucket by their OWN length
                slb = bucket_for(len(p[0].prompt) - p[4],
                                 self.prefill_buckets)
                by_slb.setdefault(slb, []).append(p)
            groups = ([(cold, plb, False)] if cold else []) + \
                [(by_slb[slb], slb, True) for slb in sorted(by_slb)]
            for gi, (g, lenb, hist) in enumerate(groups):
                try:
                    self._launch_prefill(g, lenb, history=hist)
                    done_plans.extend(g)
                except (InjectedFault, WorkerCrash) as e:
                    # failed group + every unlaunched group unwind
                    # (exactly one decref per mapped page) and requeue in
                    # plan order; already-launched groups stay admitted
                    lost = [p for gg, _, _ in groups[gi:] for p in gg]
                    self._unwind_plans(lost)
                    self._requeue([p[0] for p in lost], e)
                    launch_failed = True
                    break
            if self.prefix is not None:
                # register fresh full-prompt pages; the index holds one
                # reference so hot prefixes outlive their creator.
                # register() refuses duplicates (already-matched pages,
                # COW copies whose key is resident) so no double-count.
                for r, _, mapping, keys, _ in done_plans:
                    for j in range(len(r.prompt) // psz):
                        if self.prefix.register(keys[j], mapping[j]):
                            self.pool.incref([mapping[j]])
            if self.kv_crc:
                self._seal_plans(done_plans)
            # prefill-emitted first tokens (mirrors the contiguous path)
            m.counter("lm_tokens_generated").inc(len(done_plans))
        m.gauge("lm_pool_pages_used").set(self.pool.used_pages)
        m.gauge("lm_pool_pages_free").set(self.pool.free_pages)
        return not bounced and not launch_failed

    def _unwind_plans(self, plans):
        """Roll back planned-but-unlaunched admissions after a prefill
        failure: every page in a plan's mapping carries exactly ONE
        reference from this admission (fresh alloc, prefix incref, or
        COW dst), so one decref per page restores the pool, and the
        table rows go back to the sentinel on host and device."""
        sids = []
        for r, s, mapping, _keys, _s0 in plans:
            self.pool.decref(mapping)
            self.table_np[s] = self.pool_pages
            sids.append(s)
        if sids:
            ss = np.asarray(sorted(sids), np.int32)
            self.cache = self.ex.table_write(
                self.cache, jnp.asarray(ss),
                jnp.asarray(self.table_np[ss]))

    def _seal_plans(self, plans):
        """Tag-and-seal every fully-prefilled prompt page of the freshly
        admitted plans: pages wholly below plen ((j+1)*page_size <= plen)
        are never written again (decode writes rows >= plen), so their
        GF(2) CRC is stable until the slot's pages are reclaimed. One
        batched ``crc_tags`` launch covers all new pages."""
        psz = self.page_size
        to_seal = sorted({p for r, _s, mapping, _k, _s0 in plans
                          for j, p in enumerate(mapping)
                          if (j + 1) * psz <= len(r.prompt)
                          and not self.pool.is_sealed(p)})
        if not to_seal:
            return
        from ..gf2.ops import crc_tags
        bufs = self.ex.read_pages(self.cache, to_seal)
        tags = crc_tags(bufs)
        for p, t in zip(to_seal, tags):
            self.pool.seal(p, int(t))
        self.metrics.counter("lm_pages_sealed").inc(len(to_seal))

    def _launch_prefill(self, plans, lenb: int, *, history: bool):
        """One paged prefill launch: cold prompts (history=False) or the
        unshared suffixes of prefix hits (history=True). Dead batch rows
        carry slot_id == slots and all-sentinel table rows, so their
        pos/table scatters drop on the floor instead of clobbering a
        live slot."""
        blen = bucket_for(len(plans), self.admit_buckets)
        toks = np.zeros((blen, lenb), np.int32)
        lens = np.ones((blen,), np.int32)
        starts = np.zeros((blen,), np.int32)
        slot_ids = np.full((blen,), self.slots, np.int32)
        rows = np.full((blen, self.n_pages), self.pool_pages, np.int32)
        for i, (r, s, mapping, _, s0) in enumerate(plans):
            span = r.prompt[s0:] if history else r.prompt
            toks[i, :len(span)] = span  # RIGHT-pad: bit-exact
            lens[i] = len(span)
            starts[i] = s0
            slot_ids[i] = s
            rows[i] = self.table_np[s]
        t0 = time.perf_counter()
        with self._span("prefill_batch", batch=blen, plen=lenb,
                        fill=len(plans) / blen, history=history):
            tok0, self.cache = self.ex.prefill_paged(
                jnp.asarray(toks), jnp.asarray(lens), jnp.asarray(starts),
                jnp.asarray(slot_ids), jnp.asarray(rows), self.cache,
                self._next_key(), history=history)
        t1 = time.perf_counter()
        self.admit_batches += 1
        m = self.metrics
        m.counter("lm_prefill_batches").inc()
        m.counter("lm_requests_admitted").inc(len(plans))
        m.histogram("lm_prefill_s").record(t1 - t0)
        m.histogram("lm_admit_fill_ratio").record(len(plans) / blen)
        for i, (r, s, *_rest) in enumerate(plans):
            r.out.append(int(tok0[i]))
            r.first_token_t = t1
            if r.submit_t is not None:
                m.histogram("lm_queue_wait_s").record(t0 - r.submit_t)
                m.histogram("lm_ttft_s").record(t1 - r.submit_t)
            self.live[s] = r

    def _retire_slot(self, s: int, r: Request, now: float):
        """Evict a finished request from its slot and record telemetry."""
        m = self.metrics
        r.retire_t = now
        r.outcome = "completed"
        m.counter("lm_requests_retired").inc()
        m.counter("lm_slots_evicted").inc()
        m.counter(f"lm_finish_{r.finish_reason}").inc()
        if r.latency_s is not None:
            m.histogram("lm_request_latency_s").record(r.latency_s)
        if r.first_token_t is not None and len(r.out) > 1:
            m.histogram("lm_tpot_s").record(
                (now - r.first_token_t) / (len(r.out) - 1))
        self.live[s] = None  # evict: slot is free for re-admission

    def _reclaim_pages(self):
        """Return the pages of freshly-freed slots to the pool."""
        m = self.metrics
        reclaim = [s for s, r in enumerate(self.live)
                   if r is None and (self.table_np[s]
                                     < self.pool_pages).any()]
        for s in reclaim:
            held = [int(p) for p in self.table_np[s]
                    if p < self.pool_pages]
            self.pool.decref(held)  # shared pages survive via refcount
            self.table_np[s] = self.pool_pages
        if reclaim:
            sids = np.asarray(reclaim, np.int32)
            self.cache = self.ex.table_write(
                self.cache, jnp.asarray(sids),
                jnp.asarray(self.table_np[sids]))
        m.gauge("lm_pool_pages_used").set(self.pool.used_pages)
        m.gauge("lm_pool_pages_free").set(self.pool.free_pages)

    def step(self) -> List[Request]:
        """One fused decode step over all slots; returns retired requests."""
        if self.spec_decode:
            return self._step_spec()
        occupied = sum(r is not None for r in self.live)
        if occupied == 0:
            # admission backpressured with nothing resident: a decode
            # launch would only burn a step on dead slots
            return []
        toks = np.zeros((self.slots, 1), np.int32)
        for s, r in enumerate(self.live):
            if r is not None:
                toks[s, 0] = r.out[-1]
        t0 = time.perf_counter()
        try:
            with self._span("decode_step", occupied=occupied):
                nxt, self.cache = self.ex.decode(jnp.asarray(toks),
                                                 self.cache,
                                                 self._next_key())
                nxt = np.asarray(nxt)  # the only host transfer: [S] ids
        except (InjectedFault, WorkerCrash) as e:
            # the seam fires before the donating dispatch, so the cache
            # is intact: skip this tick and redo the step (the fault is
            # consumed — the retry always makes progress)
            if isinstance(e, WorkerCrash):
                self.ex.on_worker_crash(e.wid)
            self.metrics.counter("lm_retries").inc()
            return []
        t1 = time.perf_counter()
        self.decode_steps += 1
        m = self.metrics
        m.histogram("lm_decode_step_s").record(t1 - t0)
        m.gauge("lm_slot_occupancy").set(occupied)
        m.histogram("lm_slot_occupancy_per_step").record(occupied)
        m.counter("lm_tokens_generated").inc(occupied)
        m.gauge("lm_queue_depth").set(len(self.queue))
        retired = []
        for s, r in enumerate(self.live):
            if r is None:
                continue
            t = int(nxt[s])
            r.out.append(t)
            hit_eos = r.eos is not None and t == r.eos
            if hit_eos or len(r.out) >= r.max_new:
                r.done = True
                r.finish_reason = "eos" if hit_eos else "length"
                self._retire_slot(s, r, t1)
                retired.append(r)
        if self.paged and retired:
            self._reclaim_pages()
        return retired

    def _step_spec(self) -> List[Request]:
        """One speculative draft->verify->accept round over all slots.

        A single cache-donating dispatch (k packed1-rung drafts + ONE
        batched target-rung verify) retires a *variable* number of
        tokens per slot — ``n_emit[s]`` in [1, draft_k + 1] — so the
        host-side loop appends each slot's accepted prefix and truncates
        at EOS / max_new (tokens past a mid-window stop are discarded;
        the slot is evicted and its cache rows recycled on re-admission).
        """
        occupied = sum(r is not None for r in self.live)
        if occupied == 0:
            return []
        toks = np.zeros((self.slots,), np.int32)
        for s, r in enumerate(self.live):
            if r is not None:
                toks[s] = r.out[-1]
        t0 = time.perf_counter()
        try:
            with self._span("spec_round", occupied=occupied,
                            draft_k=self.draft_k):
                emitted, n_emit, self.cache = self.ex.spec_round(
                    jnp.asarray(toks), self.cache, self._next_key())
                emitted = np.asarray(emitted)  # [S, draft_k+1] token ids
                n_emit = np.asarray(n_emit)    # [S] accepted prefix + 1
        except (InjectedFault, WorkerCrash) as e:
            if isinstance(e, WorkerCrash):
                self.ex.on_worker_crash(e.wid)
            self.metrics.counter("lm_retries").inc()
            return []
        t1 = time.perf_counter()
        self.decode_steps += 1
        m = self.metrics
        m.histogram("lm_decode_step_s").record(t1 - t0)
        m.gauge("lm_slot_occupancy").set(occupied)
        m.histogram("lm_slot_occupancy_per_step").record(occupied)
        m.gauge("lm_queue_depth").set(len(self.queue))
        retired = []
        for s, r in enumerate(self.live):
            if r is None:
                continue
            ne = int(n_emit[s])
            if self.draft_k:  # per-slot acceptance telemetry
                m.counter("lm_spec_rounds").inc()
                m.counter("lm_spec_tokens_drafted").inc(self.draft_k)
                m.counter("lm_spec_tokens_accepted").inc(ne - 1)
                m.histogram("lm_spec_accept_rate").record(
                    (ne - 1) / self.draft_k)
            for j in range(ne):
                t = int(emitted[s, j])
                r.out.append(t)
                m.counter("lm_tokens_generated").inc()
                hit_eos = r.eos is not None and t == r.eos
                if hit_eos or len(r.out) >= r.max_new:
                    r.done = True
                    r.finish_reason = "eos" if hit_eos else "length"
                    break  # discard accepted tokens past the stop
            if r.done:
                self._retire_slot(s, r, t1)
                retired.append(r)
        if self.paged and retired:
            self._reclaim_pages()
        return retired

    # -- chaos tick: fault application + integrity scrub ---------------------

    def _tick_faults(self):
        """Apply this tick's step-seam faults: bit-flips (KV page or
        resident weight container) and pool squeezes. Runs BEFORE the
        scrub, so with ``scrub_every=1`` every flip is detected before
        any decode step can read the corrupted page."""
        m = self.metrics
        # release expired squeezes first: a hold of 1 spans exactly one
        # admission+step and frees on the next tick
        keep = []
        for sq in self._squeezes:
            sq[0] -= 1
            if sq[0] <= 0:
                self.pool.decref(sq[1])
            else:
                keep.append(sq)
        self._squeezes = keep
        hits = self.faults.fire("step")
        for f in hits:
            if f.kind == "stall":
                time.sleep(f.stall_s)
        flips = self._pending_flips + [f for f in hits if f.kind == "flip"]
        self._pending_flips = []
        for f in flips:
            if f.param:
                from ..core.engine import flip_container_bit
                self.ex.reload_params(flip_container_bit(
                    self.ex.params, index=max(f.page, 0), bit=f.bit))
                m.counter("lm_faults_injected", kind="param_flip").inc()
            elif self.paged:
                page = f.page
                if page < 0:
                    sealed = self.pool.sealed_items()
                    if not sealed:  # nothing sealed yet: fire next tick
                        self._pending_flips.append(f)
                        continue
                    page = min(sealed)
                self.cache = self.ex.corrupt_page(self.cache, page, f.bit)
                m.counter("lm_faults_injected", kind="kv_flip").inc()
        for f in hits:
            if f.kind == "squeeze" and self.paged:
                k = min(f.pages, self.pool.free_pages)
                if k > 0:
                    self._squeezes.append([f.hold, self.pool.alloc(k)])
                    m.counter("lm_faults_injected", kind="squeeze").inc()

    def _scrub(self):
        """Integrity scrub: recompute the GF(2) CRC of every sealed KV
        page (one batched CRC-as-MVP launch) and of every tagged weight
        container; quarantine drifted pages (their requests re-prefill or
        fail with 'corruption'), repair drifted containers from their
        quantization shadow."""
        m = self.metrics
        t0 = time.perf_counter()
        if self.kv_crc:
            sealed = self.pool.sealed_items()
            if sealed:
                from ..gf2.ops import crc_tags
                pages = sorted(sealed)
                bufs = self.ex.read_pages(self.cache, pages)
                tags = crc_tags(bufs)
                m.counter("lm_scrub_pages").inc(len(pages))
                for p, t in zip(pages, tags):
                    if int(t) != sealed[p]:
                        self._quarantine_page(p)
        if self._param_tags:
            from ..core.engine import scrub_params
            params, report = scrub_params(self.ex.params, self._param_tags)
            for path, verdict in report.items():
                if verdict != "clean":
                    m.counter(f"lm_param_scrub_{verdict}").inc()
            if any(v == "repaired" for v in report.values()):
                self.ex.reload_params(params)
        m.histogram("lm_scrub_s").record(time.perf_counter() - t0)

    def _quarantine_page(self, p: int):
        """A sealed page failed its CRC re-check: pull it out of
        circulation permanently (it never re-enters the free list) and
        recompute every request that mapped it — abort the slot, clear
        the partial output, and re-prefill from the prompt (greedy
        re-generation is bit-identical); past the retry budget the
        request fails terminally with reason 'corruption'. The page is
        also evicted from the prefix index so no future prompt can match
        into poisoned history."""
        m = self.metrics
        m.counter("lm_pages_quarantined").inc()
        if self.prefix is not None and self.prefix.evict_page(p):
            self.pool.decref([p])  # the index's registration reference
        self.pool.quarantine(p)
        requeue = []
        for s, r in enumerate(self.live):
            if r is None or p not in self.table_np[s]:
                continue
            self._abort_slot(s)
            r.out.clear()  # restart generation from the prompt
            r.first_token_t = None
            r.retries += 1
            m.counter("lm_retries").inc()
            if r.retries > self.max_retries:
                self._fail(r, "corruption")
            else:
                requeue.append(r)
        self.queue[:0] = requeue

    def tick(self) -> List[Request]:
        """One scheduler tick: faults -> scrub -> deadlines -> admission
        -> decode step. The ordering is the scrub-before-read guarantee:
        a bit flipped at this tick's fault stage is caught by this
        tick's scrub (``scrub_every=1``) before the decode step can read
        it — corrupted tokens are never emitted silently."""
        self._ticks += 1
        if self.faults is not None:
            self._tick_faults()
        if self.scrub_every and self._ticks % self.scrub_every == 0:
            self._scrub()
        self._expire_deadlines()
        self._admit()
        return self.step()

    def run(self) -> List[Request]:
        done = []
        while self.queue or any(r is not None for r in self.live):
            done.extend(self.tick())
        return done


def fmt_latency(latency_s: Optional[float]) -> str:
    """Render a latency for the per-request summary line. Only ``None``
    (not yet retired) is unknown — 0.0 is a legitimate measurement and
    must NOT fall through a truthiness check to '?'."""
    return "?" if latency_s is None else f"{latency_s * 1e3:.1f}ms"


def run_and_report(server: LMServer, requests: List[Request], *,
                   report=None, show_metrics: bool = False) -> List[Request]:
    """Submit, run to completion, and print the shared serving summary
    (one copy for both the serve and serve_lm CLIs: identically-timed
    tok/s, slot/bucket stats, per-request latency percentiles from the
    telemetry registry, optional PPAC cycle accounting)."""
    for r in requests:
        server.submit(r)
    t0 = time.time()
    completed = server.run()
    # an empty request list (or a sub-resolution run) must not divide
    # the tok/s line by zero
    dt = max(time.time() - t0, 1e-9)
    toks = sum(len(r.out) for r in completed)
    print(f"served {len(completed)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s, slots={server.slots}, "
          f"{server.decode_steps} decode steps, "
          f"{server.admit_batches} prefill batches)")
    if server.spec_decode:
        acc = server.metrics.histogram("lm_spec_accept_rate")
        drafted = server.metrics.counter("lm_spec_tokens_drafted").value
        accepted = server.metrics.counter("lm_spec_tokens_accepted").value
        print(f"speculative: draft_k={server.draft_k}, "
              f"accepted {accepted}/{drafted} drafts "
              f"({accepted / max(drafted, 1):.0%}), "
              f"accept-rate p50={acc.percentile(50):.2f} "
              f"({toks / max(server.decode_steps, 1):.2f} tok/round)")
    if server.paged:
        line = (f"paged pool: {server.pool.used_pages}/{server.pool.pages} "
                f"pages held (page_size={server.page_size})")
        if server.prefix is not None:
            hit, tot = server.prefix.pages_hit, server.prefix.pages_probed
            line += (f", prefix hits {hit}/{tot} pages "
                     f"({hit / max(tot, 1):.0%})")
        print(line)
    shed = server.metrics.total("lm_requests_shed")
    failed = server.metrics.total("lm_requests_failed")
    retries = server.metrics.counter("lm_retries").value
    if shed or failed or retries or server.terminal:
        reasons = sorted({r.fail_reason for r in server.terminal
                          if r.fail_reason})
        print(f"outcomes: {len(completed)} completed, {shed} shed, "
              f"{failed} failed"
              + (f" ({', '.join(reasons)})" if reasons else "")
              + f"; {retries} retries, "
              f"{server.metrics.total('lm_worker_restarts')} worker "
              f"restarts"
              + (", DEGRADED (prefill on decode mesh)"
                 if server.metrics.gauge('lm_degraded').value else ""))
    quar = server.metrics.total("lm_pages_quarantined")
    if quar:
        print(f"integrity: {quar} KV pages quarantined by CRC scrub "
              f"({server.metrics.total('lm_scrub_pages')} page checks)")
    lat = server.metrics.histogram("lm_request_latency_s")
    ttft = server.metrics.histogram("lm_ttft_s")
    if lat.count:
        print(f"latency submit->retire: p50={lat.percentile(50) * 1e3:.1f}ms "
              f"p95={lat.percentile(95) * 1e3:.1f}ms "
              f"max={lat.max * 1e3:.1f}ms; "
              f"ttft p50={ttft.percentile(50) * 1e3:.1f}ms "
              f"p95={ttft.percentile(95) * 1e3:.1f}ms")
    if report is not None:
        print(f"PPAC compute: {toks * report.cycles_per_token} emulated "
              f"cycles for {toks} decoded tokens "
              f"({report.cycles_per_token}/token, "
              f"{toks * report.energy_nj_per_token / 1e3:.2f} uJ modeled)")
    for r in completed[:3]:
        print(f"  req {r.rid} [{r.finish_reason}, {fmt_latency(r.latency_s)}]: "
              f"{r.out[:8]}...")
    if show_metrics:
        print(server.metrics.prometheus_text(), end="")
    return completed


def chaos_check(server: LMServer) -> List[str]:
    """The chaos invariants (shared by ``--chaos-gate`` and the test
    suite). Returns human-readable violations; empty = all held.

      1. no request lost: submitted == completed + shed + failed, and
         nothing is still queued or resident;
      2. page-pool refcount conservation: every remaining reference is a
         live slot mapping, a prefix registration, or an injected
         squeeze hold — nothing leaked, nothing double-freed;
      3. every injected KV bit-flip was caught by the CRC scrub (each
         flip quarantines the page it corrupted — schedule flips on
         distinct scrub intervals).
    """
    m = server.metrics
    problems: List[str] = []
    submitted = m.counter("lm_requests_submitted").value
    retired = m.counter("lm_requests_retired").value
    shed = m.total("lm_requests_shed")
    failed = m.total("lm_requests_failed")
    if submitted != retired + shed + failed:
        problems.append(
            f"request conservation: {submitted} submitted != "
            f"{retired} completed + {shed} shed + {failed} failed")
    if server.queue or any(r is not None for r in server.live):
        problems.append("requests still queued/resident after run")
    for r in server.terminal:
        if r.outcome not in ("shed", "failed"):
            problems.append(
                f"terminal request {r.rid} has outcome {r.outcome!r}")
    if server.paged:
        refs = int(server.pool.refcount.sum())
        mapped = int(sum((server.table_np[s] < server.pool_pages).sum()
                         for s, r in enumerate(server.live)
                         if r is not None))
        registered = (server.prefix.registered_pages
                      if server.prefix is not None else 0)
        held = sum(len(sq[1]) for sq in server._squeezes)
        if refs != mapped + registered + held:
            problems.append(
                f"pool conservation: {refs} refs != {mapped} slot "
                f"mappings + {registered} prefix registrations + "
                f"{held} squeeze holds")
    flips = m.counter("lm_faults_injected", kind="kv_flip").value
    quar = m.counter("lm_pages_quarantined").value
    if server.kv_crc and quar < flips:
        problems.append(f"{flips} KV bit-flips injected but only {quar} "
                        f"pages quarantined by the scrub")
    return problems


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for sampled decoding (temperature > 0); "
                         "runs with the same seed reproduce exactly")
    ap.add_argument("--eos", type=int, default=None)
    ap.add_argument("--spec-decode", action="store_true",
                    help="self-speculative decoding: draft with the "
                         "resident packed1 rung, verify all drafts in one "
                         "batched target-rung launch (outputs identical "
                         "to plain decoding; greedy is bit-exact)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="speculative draft depth per round")
    ap.add_argument("--serve-quant", action="store_true")
    ap.add_argument("--weight-bits", type=int, default=4,
                    choices=(1, 2, 3, 4, 8))
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="virtualize the KV cache into fixed-size pages "
                         "over a bounded pool with a block table")
    ap.add_argument("--page-size", type=int, default=16,
                    help="rows per physical page (must divide the cache "
                         "extent)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="physical pool size; default slots*extent/page_size "
                         "(smaller pools backpressure admission)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="CAM-matched prefix reuse: map shared prompt "
                         "pages instead of re-prefilling them")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="shard the resident server over a device mesh, "
                         "e.g. '2x2' (data x model); falls back to the "
                         "largest valid submesh when fewer devices are "
                         "attached")
    ap.add_argument("--prefill-devices", type=int, default=0,
                    help="disaggregated serving: devices for the prefill "
                         "worker pool (disjoint from decode)")
    ap.add_argument("--decode-devices", type=int, default=0,
                    help="disaggregated serving: devices for the resident "
                         "decode mesh")
    ap.add_argument("--prefill-workers", type=int, default=0,
                    help="split the prefill devices into this many TP "
                         "workers (default: one worker over all of them)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the telemetry registry (Prometheus text) "
                         "after the run")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics snapshot as JSON")
    ap.add_argument("--fault-plan", default=None, metavar="SPEC",
                    help="inject deterministic faults: a JSON file, an "
                         "inline JSON list, or 'kind:seam:at[:k=v,...];...'"
                         " (see launch/faults.py)")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help="seeded random chaos schedule instead of an "
                         "explicit --fault-plan")
    ap.add_argument("--max-retries", type=int, default=1,
                    help="per-request retry budget before terminal "
                         "failure")
    ap.add_argument("--max-worker-restarts", type=int, default=1,
                    help="rebuilds per dead prefill worker before it is "
                         "dropped (empty pool => degraded mode)")
    ap.add_argument("--kv-crc", action="store_true",
                    help="GF(2)-CRC-tag sealed prompt pages (paged only); "
                         "the scrub quarantines drifted pages")
    ap.add_argument("--scrub-every", type=int, default=0,
                    help="scrub sealed pages + weight containers every N "
                         "scheduler ticks (0 = off; 1 guarantees flips "
                         "are caught before any decode reads them)")
    ap.add_argument("--chaos-gate", action="store_true",
                    help="exit nonzero unless every request reached one "
                         "terminal outcome, the page pool conserved "
                         "refcounts, and every injected KV flip was "
                         "caught by the scrub")
    args = ap.parse_args()

    cfg = load_arch(args.arch).smoke()
    if args.kv_int8:
        cfg = dataclasses.replace(cfg, kv_dtype="int8")
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    mode, report = "float", None
    if args.serve_quant:
        cfg = dataclasses.replace(
            cfg, ppac=dataclasses.replace(cfg.ppac, enabled=True,
                                          weight_bits=args.weight_bits,
                                          act_bits=8, min_features=32,
                                          backend="auto"))
        params = convert_params_for_serving(params, cfg,
                                            draft=args.spec_decode)
        mode = "serve"
        report = serving_cycle_report(params, cfg)

    faults = None
    if args.fault_plan:
        faults = FaultPlan.parse(args.fault_plan)
    elif args.fault_seed is not None:
        faults = FaultPlan.seeded(args.fault_seed,
                                  n_requests=args.requests)

    mesh = (make_serving_mesh(parse_mesh_spec(args.mesh))
            if args.mesh else None)
    server = LMServer(cfg, params, slots=args.slots, max_seq=args.max_seq,
                      mode=mode, temperature=args.temperature,
                      top_k=args.top_k, seed=args.seed, paged=args.paged,
                      page_size=args.page_size, pool_pages=args.pool_pages,
                      prefix_cache=args.prefix_cache,
                      spec_decode=args.spec_decode, draft_k=args.draft_k,
                      mesh=mesh, prefill_devices=args.prefill_devices,
                      decode_devices=args.decode_devices,
                      prefill_workers=args.prefill_workers,
                      faults=faults, max_retries=args.max_retries,
                      max_worker_restarts=args.max_worker_restarts,
                      kv_crc=args.kv_crc, scrub_every=args.scrub_every)
    rng = np.random.default_rng(0)
    run_and_report(
        server,
        [Request(i, rng.integers(0, cfg.vocab, int(rng.integers(4, 24))),
                 args.max_new, eos=args.eos)
         for i in range(args.requests)],
        report=report, show_metrics=args.metrics)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(server.metrics.snapshot(), f, indent=1)
        print(f"wrote metrics snapshot to {args.metrics_out}")
    if args.chaos_gate:
        problems = chaos_check(server)
        if problems:
            for p in problems:
                print(f"CHAOS GATE FAILED: {p}")
            sys.exit(1)
        print("chaos gate passed: no request lost, pool conserved, "
              "all injected flips detected")


if __name__ == "__main__":
    main()
