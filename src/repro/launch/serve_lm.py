"""Continuous-batching LM decode server: device-resident ring/linear KV
caches, slot-based admission/eviction, bucketed prefill.

The serving loop the kernel work of PRs 3-4 was building toward — the LM
itself served to many concurrent users:

  * a resident cache pytree sized [slots, max_seq, ...] lives on device
    for the whole server lifetime; every jitted entry point *donates* it
    (``donate_argnums``), so per-token cache updates are in-place
    scatters, never whole-cache copies,
  * decode runs as ONE fused step over all slots with per-sequence
    positions (``cache['pos']: [S]``) — sequences at different depths
    (admitted mid-flight) share the step bit-exactly with solo decoding,
  * new requests prefill into free slots while resident sequences keep
    decoding: waiting prompts are drained in *batch buckets* (the shared
    :func:`repro.launch.bucketed.drain_take` policy) and *right-padded*
    into power-of-two length buckets — right padding + per-sequence
    ``lengths`` keeps causal prefill bit-identical to the unpadded
    prompt, and the number of compiled (batch, length) prefill shapes
    stays bounded,
  * per-slot retirement on EOS or length; the freed slot is refilled
    from the queue on the next admission pass,
  * token selection (greedy / temperature / top-k) is fused into the
    prefill and decode programs — the host only ever sees the [S] int32
    ids it needs for retirement decisions.

Paged mode (``paged=True`` / ``--paged``) virtualizes the cache: KV
leaves become fixed-size page pools ([pool_pages, page_size, ...]) and a
[slots, extent/page_size] block table maps logical to physical pages
(models/attention.py gathers rows through it, same trick as
``_ring_rows``). Admission becomes page allocation off a host free list
with per-page refcounts: memory scales with *live tokens*, a too-small
pool backpressures admission instead of crashing, and — with
``prefix_cache=True`` — each full prompt page hashes into a chained
128-bit key matched against resident pages via one batched CAM launch
(``retrieval/prefix.py``): a hit maps the new slot's table entries onto
existing pages (copy-on-write for a shared tail page) and only the
suffix is prefilled. Prefill writes go straight through the table into
the donated resident pools — no scratch cache, no copy step.

CLI: PYTHONPATH=src python -m repro.launch.serve_lm --arch smollm_360m \
        --requests 12 --max-new 16 [--serve-quant --weight-bits 4] \
        [--kv-int8] [--temperature 0.8 --top-k 40] [--eos 0] \
        [--paged --page-size 16 --pool-pages 64 --prefix-cache]
"""
from __future__ import annotations

import argparse
import contextlib
import dataclasses
import json
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, load_arch
from ..models import lm
from ..obs.metrics import MetricsRegistry
from ..obs.trace import TraceBuilder, annotate
from ..retrieval.prefix import PagePrefixIndex
from ..serve.step import convert_params_for_serving, serving_cycle_report
from .bucketed import bucket_for, drain_take
from .mesh import make_serving_mesh, parse_mesh_spec
from .paging import PagePool
from .workers import DisaggExecutor, LocalExecutor


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    eos: Optional[int] = None
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    finish_reason: Optional[str] = None
    # telemetry timestamps (perf_counter readings, set by the server)
    submit_t: Optional[float] = None
    first_token_t: Optional[float] = None
    retire_t: Optional[float] = None

    @property
    def latency_s(self) -> Optional[float]:
        """End-to-end submit -> retire latency (None until retired)."""
        if self.submit_t is None or self.retire_t is None:
            return None
        return self.retire_t - self.submit_t


class LMServer:
    """Slot-based continuous batching over a resident, donated cache.

    The server is the *scheduler* half of a scheduler/executor split
    (``launch/workers.py``): it owns admission, paging, and retirement;
    every jitted dispatch goes through ``self.ex``. Three layouts:

      * default — :class:`LocalExecutor` on one device (the PR<=8 path),
      * ``mesh=`` — the same executor with the resident weights TP-
        sharded and the slot/page cache slot-parallel over the mesh,
      * ``prefill_devices``/``decode_devices`` — :class:`DisaggExecutor`
        with disjoint prefill/decode device pools bridged by a
        ``jax.device_put`` cache handoff.
    """

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_seq: int = 128, mode: str = "float", rules=None,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 prefill_buckets: Optional[Sequence[int]] = None,
                 admit_buckets: Sequence[int] = (1, 2, 4),
                 metrics: Optional[MetricsRegistry] = None,
                 trace: Optional[TraceBuilder] = None,
                 paged: bool = False, page_size: int = 16,
                 pool_pages: Optional[int] = None,
                 prefix_cache: bool = False, cache_dtype=None,
                 spec_decode: bool = False, draft_k: int = 4,
                 mesh=None, prefill_devices: int = 0,
                 decode_devices: int = 0, prefill_workers: int = 0,
                 decode_mesh_shape=None):
        assert tuple(admit_buckets) == tuple(sorted(admit_buckets))
        if prefill_buckets is None:
            # powers of two up to max_seq (any prompt that leaves room to
            # decode is admissible; a bucket may not exceed the cache)
            prefill_buckets, b = [], 8
            while b < max_seq:
                prefill_buckets.append(b)
                b *= 2
            prefill_buckets.append(max_seq)
        assert tuple(prefill_buckets) == tuple(sorted(prefill_buckets))
        assert prefill_buckets[-1] <= max_seq
        self.cfg, self.mode = cfg, mode
        self.slots, self.max_seq = slots, max_seq
        self.prefill_buckets = tuple(prefill_buckets)
        self.admit_buckets = tuple(admit_buckets)
        # SSM state accumulation has no position mask: padded prefill
        # would fold pad tokens into the recurrent state (wrong tokens,
        # silently). SSM/hybrid prompts prefill at their exact length —
        # batched only with same-length peers.
        self.pad_prompts = cfg.family not in ("ssm", "hybrid")
        self.live: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        self.decode_steps = 0
        self.admit_batches = 0
        # telemetry: always-on registry (negligible cost — a few Python
        # dict/float ops per step), optional Chrome-trace span capture
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.trace = trace
        self._key = jax.random.PRNGKey(seed)
        self.paged, self.page_size = paged, page_size
        self._cache_dtype = cache_dtype
        ckw = {} if cache_dtype is None else {"dtype": cache_dtype}

        # family/layout validation happens here, before any executor (and
        # hence any compile or placement) is built
        self.spec_decode, self.draft_k = spec_decode, draft_k
        if paged and cfg.family in ("ssm", "hybrid"):
            raise ValueError("paged serving needs a token-indexed KV "
                             "cache; SSM/hybrid state stays contiguous")
        if spec_decode:
            if cfg.family in ("ssm", "hybrid"):
                raise ValueError("speculative decoding needs a "
                                 "token-indexed KV cache; SSM/hybrid "
                                 "state cannot rewind")
            if paged and cfg.sliding_window:
                raise ValueError("speculative decoding over a paged ring "
                                 "cache is unsupported: rejected wrapped "
                                 "writes cannot be rolled back through "
                                 "the block table")

        disagg = prefill_devices > 0 or decode_devices > 0
        if disagg and prefix_cache:
            raise ValueError("prefix-cache reuse prefills against resident "
                             "pool history, which disaggregated prefill "
                             "workers cannot read; drop --prefix-cache or "
                             "the worker split")
        if mesh is not None and not hasattr(mesh, "devices"):
            mesh = make_serving_mesh(tuple(mesh))  # shape tuple -> mesh
        if disagg:
            self.ex = DisaggExecutor(
                cfg, params, prefill_devices=max(prefill_devices, 1),
                decode_devices=max(decode_devices, 1),
                prefill_workers=prefill_workers,
                decode_mesh_shape=decode_mesh_shape, mode=mode,
                rules=rules, temperature=temperature, top_k=top_k,
                paged=paged, page_size=page_size, spec_decode=spec_decode,
                draft_k=draft_k, max_seq=max_seq, cache_dtype=cache_dtype,
                metrics=self.metrics)
        else:
            self.ex = LocalExecutor(
                cfg, params, mode=mode, rules=rules, mesh=mesh,
                temperature=temperature, top_k=top_k, paged=paged,
                spec_decode=spec_decode, draft_k=draft_k, max_seq=max_seq,
                cache_dtype=cache_dtype, metrics=self.metrics)

        if paged:
            self.extent = lm.paged_extent(cfg, max_seq)
            self.n_pages = self.extent // page_size
            self.pool_pages = (pool_pages if pool_pages is not None
                               else slots * self.n_pages)
            self.cache, caxes = lm.init_cache(cfg, slots, max_seq,
                                              page_size=page_size,
                                              pool_pages=self.pool_pages,
                                              **ckw)
            self.pool = PagePool(self.pool_pages)
            # host mirror of the device block table (sentinel = unmapped)
            self.table_np = np.full((slots, self.n_pages), self.pool_pages,
                                    np.int32)
            self.prefix = None
            if prefix_cache:
                if cfg.sliding_window:
                    raise ValueError("prefix reuse needs a linear cache: "
                                     "ring page contents depend on the "
                                     "sequence's own positions")
                self.prefix = PagePrefixIndex(page_size)
        else:
            # the resident cache: allocated once, donated through every step
            self.cache, caxes = lm.init_cache(cfg, slots, max_seq, **ckw)
        # on a mesh the resident cache shards slot-parallel ('data');
        # single-device executors return it unchanged
        self.cache = self.ex.place_cache(self.cache, caxes)

    @property
    def params(self):
        """The resident (possibly sharded) weights live on the executor."""
        return self.ex.params

    # -- telemetry -----------------------------------------------------------

    @contextlib.contextmanager
    def _span(self, name: str, **args):
        """One server-track span: Chrome-trace event (when tracing) plus a
        jax.profiler annotation, so the same region shows up in both."""
        with annotate(name):
            if self.trace is not None:
                with self.trace.span(name, track="server",
                                     args=args or None):
                    yield
            else:
                yield

    # -- scheduling ----------------------------------------------------------

    def submit(self, req: Request):
        plen = len(req.prompt)
        assert 0 < plen <= self.prefill_buckets[-1], plen
        # prefill emits the first of the max_new tokens, so the last
        # decode step writes cache row plen + max_new - 2: a request
        # needs exactly plen + max_new - 1 rows, not plen + max_new.
        assert plen + req.max_new - 1 <= self.max_seq, \
            f"prompt {plen} + max_new {req.max_new} needs " \
            f"{plen + req.max_new - 1} cache rows, max_seq {self.max_seq}"
        req.submit_t = time.perf_counter()
        self.metrics.counter("lm_requests_submitted").inc()
        self.queue.append(req)

    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _plen_bucket(self, plen: int) -> int:
        """Padded prompt length for one request: a power-of-two bucket for
        attention families (right-pad is bit-exact under causal masking),
        the exact length for SSM/hybrid (padding would corrupt the state)."""
        if self.pad_prompts:
            return bucket_for(plen, self.prefill_buckets)
        return plen

    def _admit(self):
        """Prefill waiting prompts into free slots, in bucketed batches.

        FIFO groups share one padded-length bucket per batch; the batch
        itself is padded to an admission bucket (``drain_take`` policy),
        so compiled prefill shapes stay bounded at
        len(prefill_buckets) x len(admit_buckets) (for SSM archs: one
        shape per distinct prompt length instead)."""
        free = [s for s in range(self.slots) if self.live[s] is None]
        while free and self.queue:
            plb = self._plen_bucket(len(self.queue[0].prompt))
            cap, _ = drain_take(min(len(free), len(self.queue)),
                                self.admit_buckets)
            grp: List[Request] = []
            while (self.queue and len(grp) < cap
                   and self._plen_bucket(len(self.queue[0].prompt)) == plb):
                grp.append(self.queue.pop(0))
            if self.paged:
                if not self._admit_paged(grp, free, plb):
                    break  # pool backpressure: retry after retirements
                continue
            blen = bucket_for(len(grp), self.admit_buckets)
            toks = np.zeros((blen, plb), np.int32)
            lens = np.ones((blen,), np.int32)
            for i, r in enumerate(grp):
                toks[i, :len(r.prompt)] = r.prompt  # RIGHT-pad: bit-exact
                lens[i] = len(r.prompt)
            t0 = time.perf_counter()
            with self._span("prefill_batch", batch=blen, plen=plb,
                            fill=len(grp) / blen):
                tok0, handle = self.ex.prefill(jnp.asarray(toks),
                                               jnp.asarray(lens),
                                               self._next_key())
            t1 = time.perf_counter()
            self.admit_batches += 1
            m = self.metrics
            m.counter("lm_prefill_batches").inc()
            m.counter("lm_requests_admitted").inc(len(grp))
            # prefill emits each request's first token: count it here so
            # lm_tokens_generated matches sum(len(r.out)) — the decode
            # loop only adds the per-step occupancy (decode tokens)
            m.counter("lm_tokens_generated").inc(len(grp))
            m.histogram("lm_prefill_s").record(t1 - t0)
            m.histogram("lm_admit_fill_ratio").record(len(grp) / blen)
            for i, r in enumerate(grp):
                s = free.pop(0)
                self.cache = self.ex.write_slot(self.cache, handle, i, s)
                r.out.append(int(tok0[i]))
                r.first_token_t = t1  # prefill emits the first token
                if r.submit_t is not None:
                    m.histogram("lm_queue_wait_s").record(t0 - r.submit_t)
                    m.histogram("lm_ttft_s").record(t1 - r.submit_t)
                self.live[s] = r

    def _admit_paged(self, grp: List[Request], free: List[int],
                     plb: int) -> bool:
        """Page-granular admission: map each request's table row onto
        physical pages off the pool (prefix hits first), then prefill
        cold prompts and hit suffixes straight through the table into
        the donated resident pools.

        Returns False when the pool backpressured: un-admitted requests
        went back to the queue FRONT (FIFO order preserved) and the
        caller stops admitting this tick — pages free up as live
        requests retire."""
        m = self.metrics
        psz = self.page_size
        plans = []  # (req, slot, mapping, keys, s0)
        bounced: List[Request] = []
        for r in grp:
            if bounced:  # keep FIFO order behind the first bounce
                bounced.append(r)
                continue
            plen = len(r.prompt)
            if self.cfg.sliding_window:
                # ring prefill writes all `extent` wrapped rows up front,
                # and ring page contents depend on the sequence's own
                # positions — every slot needs the full page complement
                need, keys, matched = self.n_pages, [], []
            else:
                rows = min(plen + r.max_new - 1, self.extent)
                need = -(-rows // psz)
                keys = (self.prefix.keys_for(r.prompt)
                        if self.prefix is not None else [])
                matched = (self.prefix.lookup(keys)
                           if self.prefix is not None and keys else [])
            if need > self.pool.pages:
                raise RuntimeError(
                    f"request {r.rid} needs {need} pages but the pool "
                    f"holds only {self.pool.pages}; raise --pool-pages "
                    f"or lower max_new")
            nm = len(matched)
            # the suffix must re-emit from row plen-1 (whose logits pick
            # the first output token), so even a full match of every
            # prompt page still prefills one row — and that row lands in
            # a SHARED page: copy-on-write it into a private page first
            s0 = min(nm * psz, plen - 1)
            cow = nm > 0 and nm * psz > plen - 1
            fresh_needed = need - nm + (1 if cow else 0)
            pages = self.pool.alloc(fresh_needed)
            if pages is None and self.prefix is not None:
                # recycle idle registrations (refcount == 1, LRU) — but
                # never the pages this very request just matched
                protect = set(matched)
                for p in self.prefix.idle_pages(self.pool.refcount):
                    if p in protect:
                        continue
                    self.prefix.evict_page(p)
                    self.pool.decref([p])
                    m.counter("lm_prefix_pages_evicted").inc()
                    if self.pool.free_pages >= fresh_needed:
                        break
                pages = self.pool.alloc(fresh_needed)
            if pages is None:
                if not plans and not any(x is not None for x in self.live):
                    raise RuntimeError(
                        f"pool exhausted with no live requests to "
                        f"retire: request {r.rid} needs {fresh_needed} "
                        f"fresh pages, {self.pool.free_pages} free of "
                        f"{self.pool.pages}")
                bounced.append(r)
                continue
            mapping = list(matched)
            if cow:
                src, dst = mapping[-1], pages.pop(0)
                mapping[-1] = dst
                self.cache = self.ex.copy_page(self.cache, jnp.int32(src),
                                               jnp.int32(dst))
                m.counter("lm_pages_cow").inc()
                self.pool.incref(matched[:-1])  # still-shared pages only
            else:
                self.pool.incref(matched)
            mapping += pages
            s = free.pop(0)
            self.table_np[s] = self.pool_pages  # sentinel-fill the tail
            self.table_np[s, :len(mapping)] = mapping
            m.counter("lm_prefix_pages_hit").inc(nm)
            m.counter("lm_prefix_pages_total").inc(plen // psz)
            m.counter("lm_prefill_rows_skipped").inc(s0)
            plans.append((r, s, mapping, keys, s0))
        if bounced:
            self.queue[:0] = bounced
        if plans:
            slot_ids = np.array([p[1] for p in plans], np.int32)
            self.cache = self.ex.table_write(
                self.cache, jnp.asarray(slot_ids),
                jnp.asarray(self.table_np[slot_ids]))
            cold = [p for p in plans if p[4] == 0]
            hits = [p for p in plans if p[4] > 0]
            if cold:
                self._launch_prefill(cold, plb, history=False)
            by_slb = {}
            for p in hits:  # suffixes re-bucket by their OWN length
                slb = bucket_for(len(p[0].prompt) - p[4],
                                 self.prefill_buckets)
                by_slb.setdefault(slb, []).append(p)
            for slb in sorted(by_slb):
                self._launch_prefill(by_slb[slb], slb, history=True)
            if self.prefix is not None:
                # register fresh full-prompt pages; the index holds one
                # reference so hot prefixes outlive their creator.
                # register() refuses duplicates (already-matched pages,
                # COW copies whose key is resident) so no double-count.
                for r, _, mapping, keys, _ in plans:
                    for j in range(len(r.prompt) // psz):
                        if self.prefix.register(keys[j], mapping[j]):
                            self.pool.incref([mapping[j]])
            # prefill-emitted first tokens (mirrors the contiguous path)
            m.counter("lm_tokens_generated").inc(len(plans))
        m.gauge("lm_pool_pages_used").set(self.pool.used_pages)
        m.gauge("lm_pool_pages_free").set(self.pool.free_pages)
        return not bounced

    def _launch_prefill(self, plans, lenb: int, *, history: bool):
        """One paged prefill launch: cold prompts (history=False) or the
        unshared suffixes of prefix hits (history=True). Dead batch rows
        carry slot_id == slots and all-sentinel table rows, so their
        pos/table scatters drop on the floor instead of clobbering a
        live slot."""
        blen = bucket_for(len(plans), self.admit_buckets)
        toks = np.zeros((blen, lenb), np.int32)
        lens = np.ones((blen,), np.int32)
        starts = np.zeros((blen,), np.int32)
        slot_ids = np.full((blen,), self.slots, np.int32)
        rows = np.full((blen, self.n_pages), self.pool_pages, np.int32)
        for i, (r, s, mapping, _, s0) in enumerate(plans):
            span = r.prompt[s0:] if history else r.prompt
            toks[i, :len(span)] = span  # RIGHT-pad: bit-exact
            lens[i] = len(span)
            starts[i] = s0
            slot_ids[i] = s
            rows[i] = self.table_np[s]
        t0 = time.perf_counter()
        with self._span("prefill_batch", batch=blen, plen=lenb,
                        fill=len(plans) / blen, history=history):
            tok0, self.cache = self.ex.prefill_paged(
                jnp.asarray(toks), jnp.asarray(lens), jnp.asarray(starts),
                jnp.asarray(slot_ids), jnp.asarray(rows), self.cache,
                self._next_key(), history=history)
        t1 = time.perf_counter()
        self.admit_batches += 1
        m = self.metrics
        m.counter("lm_prefill_batches").inc()
        m.counter("lm_requests_admitted").inc(len(plans))
        m.histogram("lm_prefill_s").record(t1 - t0)
        m.histogram("lm_admit_fill_ratio").record(len(plans) / blen)
        for i, (r, s, *_rest) in enumerate(plans):
            r.out.append(int(tok0[i]))
            r.first_token_t = t1
            if r.submit_t is not None:
                m.histogram("lm_queue_wait_s").record(t0 - r.submit_t)
                m.histogram("lm_ttft_s").record(t1 - r.submit_t)
            self.live[s] = r

    def _retire_slot(self, s: int, r: Request, now: float):
        """Evict a finished request from its slot and record telemetry."""
        m = self.metrics
        r.retire_t = now
        m.counter("lm_requests_retired").inc()
        m.counter("lm_slots_evicted").inc()
        m.counter(f"lm_finish_{r.finish_reason}").inc()
        if r.latency_s is not None:
            m.histogram("lm_request_latency_s").record(r.latency_s)
        if r.first_token_t is not None and len(r.out) > 1:
            m.histogram("lm_tpot_s").record(
                (now - r.first_token_t) / (len(r.out) - 1))
        self.live[s] = None  # evict: slot is free for re-admission

    def _reclaim_pages(self):
        """Return the pages of freshly-freed slots to the pool."""
        m = self.metrics
        reclaim = [s for s, r in enumerate(self.live)
                   if r is None and (self.table_np[s]
                                     < self.pool_pages).any()]
        for s in reclaim:
            held = [int(p) for p in self.table_np[s]
                    if p < self.pool_pages]
            self.pool.decref(held)  # shared pages survive via refcount
            self.table_np[s] = self.pool_pages
        if reclaim:
            sids = np.asarray(reclaim, np.int32)
            self.cache = self.ex.table_write(
                self.cache, jnp.asarray(sids),
                jnp.asarray(self.table_np[sids]))
        m.gauge("lm_pool_pages_used").set(self.pool.used_pages)
        m.gauge("lm_pool_pages_free").set(self.pool.free_pages)

    def step(self) -> List[Request]:
        """One fused decode step over all slots; returns retired requests."""
        if self.spec_decode:
            return self._step_spec()
        occupied = sum(r is not None for r in self.live)
        if occupied == 0:
            # admission backpressured with nothing resident: a decode
            # launch would only burn a step on dead slots
            return []
        toks = np.zeros((self.slots, 1), np.int32)
        for s, r in enumerate(self.live):
            if r is not None:
                toks[s, 0] = r.out[-1]
        t0 = time.perf_counter()
        with self._span("decode_step", occupied=occupied):
            nxt, self.cache = self.ex.decode(jnp.asarray(toks), self.cache,
                                             self._next_key())
            nxt = np.asarray(nxt)  # the only host transfer: [S] token ids
        t1 = time.perf_counter()
        self.decode_steps += 1
        m = self.metrics
        m.histogram("lm_decode_step_s").record(t1 - t0)
        m.gauge("lm_slot_occupancy").set(occupied)
        m.histogram("lm_slot_occupancy_per_step").record(occupied)
        m.counter("lm_tokens_generated").inc(occupied)
        m.gauge("lm_queue_depth").set(len(self.queue))
        retired = []
        for s, r in enumerate(self.live):
            if r is None:
                continue
            t = int(nxt[s])
            r.out.append(t)
            hit_eos = r.eos is not None and t == r.eos
            if hit_eos or len(r.out) >= r.max_new:
                r.done = True
                r.finish_reason = "eos" if hit_eos else "length"
                self._retire_slot(s, r, t1)
                retired.append(r)
        if self.paged and retired:
            self._reclaim_pages()
        return retired

    def _step_spec(self) -> List[Request]:
        """One speculative draft->verify->accept round over all slots.

        A single cache-donating dispatch (k packed1-rung drafts + ONE
        batched target-rung verify) retires a *variable* number of
        tokens per slot — ``n_emit[s]`` in [1, draft_k + 1] — so the
        host-side loop appends each slot's accepted prefix and truncates
        at EOS / max_new (tokens past a mid-window stop are discarded;
        the slot is evicted and its cache rows recycled on re-admission).
        """
        occupied = sum(r is not None for r in self.live)
        if occupied == 0:
            return []
        toks = np.zeros((self.slots,), np.int32)
        for s, r in enumerate(self.live):
            if r is not None:
                toks[s] = r.out[-1]
        t0 = time.perf_counter()
        with self._span("spec_round", occupied=occupied,
                        draft_k=self.draft_k):
            emitted, n_emit, self.cache = self.ex.spec_round(
                jnp.asarray(toks), self.cache, self._next_key())
            emitted = np.asarray(emitted)  # [S, draft_k+1] token ids
            n_emit = np.asarray(n_emit)    # [S] accepted prefix + 1
        t1 = time.perf_counter()
        self.decode_steps += 1
        m = self.metrics
        m.histogram("lm_decode_step_s").record(t1 - t0)
        m.gauge("lm_slot_occupancy").set(occupied)
        m.histogram("lm_slot_occupancy_per_step").record(occupied)
        m.gauge("lm_queue_depth").set(len(self.queue))
        retired = []
        for s, r in enumerate(self.live):
            if r is None:
                continue
            ne = int(n_emit[s])
            if self.draft_k:  # per-slot acceptance telemetry
                m.counter("lm_spec_rounds").inc()
                m.counter("lm_spec_tokens_drafted").inc(self.draft_k)
                m.counter("lm_spec_tokens_accepted").inc(ne - 1)
                m.histogram("lm_spec_accept_rate").record(
                    (ne - 1) / self.draft_k)
            for j in range(ne):
                t = int(emitted[s, j])
                r.out.append(t)
                m.counter("lm_tokens_generated").inc()
                hit_eos = r.eos is not None and t == r.eos
                if hit_eos or len(r.out) >= r.max_new:
                    r.done = True
                    r.finish_reason = "eos" if hit_eos else "length"
                    break  # discard accepted tokens past the stop
            if r.done:
                self._retire_slot(s, r, t1)
                retired.append(r)
        if self.paged and retired:
            self._reclaim_pages()
        return retired

    def run(self) -> List[Request]:
        done = []
        while self.queue or any(r is not None for r in self.live):
            self._admit()
            done.extend(self.step())
        return done


def fmt_latency(latency_s: Optional[float]) -> str:
    """Render a latency for the per-request summary line. Only ``None``
    (not yet retired) is unknown — 0.0 is a legitimate measurement and
    must NOT fall through a truthiness check to '?'."""
    return "?" if latency_s is None else f"{latency_s * 1e3:.1f}ms"


def run_and_report(server: LMServer, requests: List[Request], *,
                   report=None, show_metrics: bool = False) -> List[Request]:
    """Submit, run to completion, and print the shared serving summary
    (one copy for both the serve and serve_lm CLIs: identically-timed
    tok/s, slot/bucket stats, per-request latency percentiles from the
    telemetry registry, optional PPAC cycle accounting)."""
    for r in requests:
        server.submit(r)
    t0 = time.time()
    completed = server.run()
    # an empty request list (or a sub-resolution run) must not divide
    # the tok/s line by zero
    dt = max(time.time() - t0, 1e-9)
    toks = sum(len(r.out) for r in completed)
    print(f"served {len(completed)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s, slots={server.slots}, "
          f"{server.decode_steps} decode steps, "
          f"{server.admit_batches} prefill batches)")
    if server.spec_decode:
        acc = server.metrics.histogram("lm_spec_accept_rate")
        drafted = server.metrics.counter("lm_spec_tokens_drafted").value
        accepted = server.metrics.counter("lm_spec_tokens_accepted").value
        print(f"speculative: draft_k={server.draft_k}, "
              f"accepted {accepted}/{drafted} drafts "
              f"({accepted / max(drafted, 1):.0%}), "
              f"accept-rate p50={acc.percentile(50):.2f} "
              f"({toks / max(server.decode_steps, 1):.2f} tok/round)")
    if server.paged:
        line = (f"paged pool: {server.pool.used_pages}/{server.pool.pages} "
                f"pages held (page_size={server.page_size})")
        if server.prefix is not None:
            hit, tot = server.prefix.pages_hit, server.prefix.pages_probed
            line += (f", prefix hits {hit}/{tot} pages "
                     f"({hit / max(tot, 1):.0%})")
        print(line)
    lat = server.metrics.histogram("lm_request_latency_s")
    ttft = server.metrics.histogram("lm_ttft_s")
    if lat.count:
        print(f"latency submit->retire: p50={lat.percentile(50) * 1e3:.1f}ms "
              f"p95={lat.percentile(95) * 1e3:.1f}ms "
              f"max={lat.max * 1e3:.1f}ms; "
              f"ttft p50={ttft.percentile(50) * 1e3:.1f}ms "
              f"p95={ttft.percentile(95) * 1e3:.1f}ms")
    if report is not None:
        print(f"PPAC compute: {toks * report.cycles_per_token} emulated "
              f"cycles for {toks} decoded tokens "
              f"({report.cycles_per_token}/token, "
              f"{toks * report.energy_nj_per_token / 1e3:.2f} uJ modeled)")
    for r in completed[:3]:
        print(f"  req {r.rid} [{r.finish_reason}, {fmt_latency(r.latency_s)}]: "
              f"{r.out[:8]}...")
    if show_metrics:
        print(server.metrics.prometheus_text(), end="")
    return completed


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0,
                    help="PRNG seed for sampled decoding (temperature > 0); "
                         "runs with the same seed reproduce exactly")
    ap.add_argument("--eos", type=int, default=None)
    ap.add_argument("--spec-decode", action="store_true",
                    help="self-speculative decoding: draft with the "
                         "resident packed1 rung, verify all drafts in one "
                         "batched target-rung launch (outputs identical "
                         "to plain decoding; greedy is bit-exact)")
    ap.add_argument("--draft-k", type=int, default=4,
                    help="speculative draft depth per round")
    ap.add_argument("--serve-quant", action="store_true")
    ap.add_argument("--weight-bits", type=int, default=4,
                    choices=(1, 2, 3, 4, 8))
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--paged", action="store_true",
                    help="virtualize the KV cache into fixed-size pages "
                         "over a bounded pool with a block table")
    ap.add_argument("--page-size", type=int, default=16,
                    help="rows per physical page (must divide the cache "
                         "extent)")
    ap.add_argument("--pool-pages", type=int, default=None,
                    help="physical pool size; default slots*extent/page_size "
                         "(smaller pools backpressure admission)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="CAM-matched prefix reuse: map shared prompt "
                         "pages instead of re-prefilling them")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="shard the resident server over a device mesh, "
                         "e.g. '2x2' (data x model); falls back to the "
                         "largest valid submesh when fewer devices are "
                         "attached")
    ap.add_argument("--prefill-devices", type=int, default=0,
                    help="disaggregated serving: devices for the prefill "
                         "worker pool (disjoint from decode)")
    ap.add_argument("--decode-devices", type=int, default=0,
                    help="disaggregated serving: devices for the resident "
                         "decode mesh")
    ap.add_argument("--prefill-workers", type=int, default=0,
                    help="split the prefill devices into this many TP "
                         "workers (default: one worker over all of them)")
    ap.add_argument("--metrics", action="store_true",
                    help="print the telemetry registry (Prometheus text) "
                         "after the run")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the metrics snapshot as JSON")
    args = ap.parse_args()

    cfg = load_arch(args.arch).smoke()
    if args.kv_int8:
        cfg = dataclasses.replace(cfg, kv_dtype="int8")
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    mode, report = "float", None
    if args.serve_quant:
        cfg = dataclasses.replace(
            cfg, ppac=dataclasses.replace(cfg.ppac, enabled=True,
                                          weight_bits=args.weight_bits,
                                          act_bits=8, min_features=32,
                                          backend="auto"))
        params = convert_params_for_serving(params, cfg,
                                            draft=args.spec_decode)
        mode = "serve"
        report = serving_cycle_report(params, cfg)

    mesh = (make_serving_mesh(parse_mesh_spec(args.mesh))
            if args.mesh else None)
    server = LMServer(cfg, params, slots=args.slots, max_seq=args.max_seq,
                      mode=mode, temperature=args.temperature,
                      top_k=args.top_k, seed=args.seed, paged=args.paged,
                      page_size=args.page_size, pool_pages=args.pool_pages,
                      prefix_cache=args.prefix_cache,
                      spec_decode=args.spec_decode, draft_k=args.draft_k,
                      mesh=mesh, prefill_devices=args.prefill_devices,
                      decode_devices=args.decode_devices,
                      prefill_workers=args.prefill_workers)
    rng = np.random.default_rng(0)
    run_and_report(
        server,
        [Request(i, rng.integers(0, cfg.vocab, int(rng.integers(4, 24))),
                 args.max_new, eos=args.eos)
         for i in range(args.requests)],
        report=report, show_metrics=args.metrics)
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(server.metrics.snapshot(), f, indent=1)
        print(f"wrote metrics snapshot to {args.metrics_out}")


if __name__ == "__main__":
    main()
