"""Serving executors: the device half of the scheduler/executor split.

``LMServer`` (launch/serve_lm.py) is the *scheduler* — it owns admission,
paging, and retirement, and never touches a jitted entry point directly.
Everything device-side lives behind an executor object from this module:

  * :class:`LocalExecutor` — prefill + decode colocated, the PR<=8
    layout. Optionally *mesh-sharded*: given a mesh, the resident packed
    weights shard via the logical-axis rules (TP over 'model', grouped
    wqkv/wig containers and draft rungs included —
    :func:`repro.launch.specs.serving_param_shardings`) and the resident
    slot cache shards slot-parallel over 'data' (DP). Every jitted entry
    point still donates the cache pytree, so the PR 4–7 invariants
    (donation aliasing, zero weight-repack, in-place scatter) hold
    unchanged on the sharded path.

  * :class:`DisaggExecutor` — disaggregated serving: a pool of
    :class:`PrefillWorker` s on their own device slices and a resident
    decode side on a disjoint mesh. Prefill runs against a *scratch*
    cache on the prefill worker's devices; the finished K/V state then
    moves to the decode mesh via ``jax.device_put`` (per-slot rows for
    contiguous caches, whole page pools adopted through the block table
    for paged caches) — so a long prompt costs the resident decoders one
    cheap scatter, never a multi-thousand-token prefill stall.

Worker attribution rides along: every executor dispatch is wrapped in a
``obs.ledger.phase`` carrying a worker tag (``p0``/``d0``/…), and the
executors record per-worker labeled series (``lm_worker_dispatches``,
``lm_prefill_s{worker=...}``, ``lm_handoff_latency``) next to the
scheduler's unlabeled aggregates.

Chaos hardening (PR 10): every executor accepts an optional
:class:`repro.launch.faults.FaultPlan` and calls ``fire`` at its seams
(prefill dispatch, handoff, decode dispatch) — with no plan the seams
cost one ``is not None`` check. A :class:`WorkerCrash` escaping a seam
is the scheduler's signal to retry/requeue; ``DisaggExecutor.
on_worker_crash`` owns the pool-side recovery (bounded restart, drop,
and graceful degradation to decode-mesh prefill when the pool is gone),
with in-process heartbeat supervision via ``launch/ft.py``'s
:class:`HeartbeatBook`.
"""
from __future__ import annotations

import contextlib
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from ..configs.base import ModelConfig
from ..models import lm
from ..obs import ledger as _flight
from ..obs.metrics import MetricsRegistry
from ..serve.step import (
    make_decode_select_step,
    make_prefill_select_step,
    make_speculative_decode_step,
)
from ..sharding.rules import default_rules, fitted_shardings
from .faults import FaultPlan, WorkerCrash  # noqa: F401  (re-exported)
from .ft import HeartbeatBook
from .mesh import carve_devices, make_serving_mesh
from .specs import serving_param_shardings


def _place_params(mesh, rules, params, cfg):
    return jax.device_put(params,
                          serving_param_shardings(mesh, rules, params, cfg))


def _replicate_on(mesh, tree):
    """device_put a pytree fully replicated onto ``mesh`` — the handoff
    transfer: prefill-side results resharded onto the decode mesh."""
    rep = NamedSharding(mesh, P())
    return jax.tree.map(lambda x: jax.device_put(x, rep), tree)


class _PrefillHandle:
    """Opaque prefill result the scheduler passes back to ``write_slot``:
    the scratch cache plus the worker that produced it (the handoff needs
    the producer's extraction jit and mesh)."""

    def __init__(self, worker, cache):
        self.worker = worker
        self.cache = cache


class _DecodeSide:
    """Shared decode-side machinery: the resident params + the donated
    jitted entry points, optionally on a mesh."""

    def __init__(self, cfg: ModelConfig, params, *, mode: str, rules,
                 mesh, temperature: float, top_k: int, paged: bool,
                 spec_decode: bool, draft_k: int,
                 metrics: Optional[MetricsRegistry], worker: str,
                 faults: Optional[FaultPlan] = None):
        self.cfg, self.mode, self.mesh = cfg, mode, mesh
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.worker = worker
        self.faults = faults
        self.temperature, self.top_k = temperature, top_k
        if mesh is not None:
            rules = (rules if rules is not None
                     else default_rules()).for_mesh(mesh)
            params = _place_params(mesh, rules, params, cfg)
        self.rules = rules
        self.params = params
        self.paged = paged

        self._decode = make_decode_select_step(
            cfg, rules, mode, temperature=temperature, top_k=top_k)
        self._spec = (make_speculative_decode_step(
            cfg, rules, mode, draft_k=draft_k, temperature=temperature,
            top_k=top_k) if spec_decode else None)

        if paged:
            def table_write(cache, slot_ids, rows):
                out = dict(cache)
                out["table"] = cache["table"].at[slot_ids].set(rows)
                return out
            self._table_write = jax.jit(table_write, donate_argnums=(0,))

            def copy_page(cache, src, dst):
                """Copy-on-write: duplicate physical page ``src`` into the
                private page ``dst`` across every pool leaf, in place."""
                def leaf(x):
                    row = lax.dynamic_index_in_dim(x, src, 1, keepdims=False)
                    return x.at[:, dst].set(row)
                out = dict(cache)
                for grp in ("layers", "dense_layers"):
                    if grp in cache:
                        out[grp] = jax.tree.map(leaf, cache[grp])
                return out
            self._copy_page = jax.jit(copy_page, donate_argnums=(0,))
        else:
            def write_slot(cache, src, row, slot):
                """Copy sequence ``row`` of a prefill cache into ``slot``
                of the resident cache — on device, resident cache
                donated."""
                def leaf(full, one):
                    if full.ndim == 1:  # per-sequence pos vector
                        return full.at[slot].set(
                            lax.dynamic_index_in_dim(one, row, 0,
                                                     keepdims=False))
                    r = lax.dynamic_slice_in_dim(one, row, 1, axis=1)
                    return lax.dynamic_update_slice_in_dim(
                        full, r.astype(full.dtype), slot, axis=1)
                return jax.tree.map(leaf, cache, src)
            self._write = jax.jit(write_slot, donate_argnums=(0,))

    def _ctx(self):
        """Mesh context for dispatches (nullcontext on a single device):
        sharding constraints inside the model only bind to mesh axes
        while a mesh is active."""
        if self.mesh is None:
            return contextlib.nullcontext()
        return self.mesh

    def _tag(self):
        """Ledger worker attribution for the dispatches inside; the empty
        tag/zero window keep untagged launches' phase accounting
        unchanged."""
        return _flight.phase("", window=0, worker=self.worker)

    def place_cache(self, cache, axes):
        """Shard the resident cache over the mesh: the slot ('batch')
        dim of every slot-indexed leaf — contiguous K/V, pos, the block
        table — goes slot-parallel over 'data'; paged pool leaves follow
        their own annotations (kv_heads over 'model'). Non-divisible
        dims fall back to replicated (``fit_spec``)."""
        if self.mesh is None:
            return cache
        with self.mesh:
            sh = fitted_shardings(self.mesh, self.rules, axes, cache)
            return jax.device_put(cache, sh)

    def _fire(self, seam: str, wid: Optional[str] = None) -> None:
        """Fault seam: consume + act on this dispatch's scheduled faults.
        Always fires BEFORE the jitted (donating) call so an injected
        raise leaves the caller's cache pytree untouched and a retry is
        clean."""
        if self.faults is not None:
            w = wid if wid is not None else self.worker
            self.faults.raise_any(self.faults.fire(seam, worker=w), wid=w)

    # -- decode-side entry points (scheduler-facing) -------------------------

    def decode(self, toks, cache, key):
        self._fire("decode")
        t0 = time.perf_counter()
        with self._ctx(), self._tag():
            out = self._decode(self.params, toks, cache, key)
        self._account("decode", t0)
        return out

    def spec_round(self, toks, cache, key):
        self._fire("decode")
        t0 = time.perf_counter()
        with self._ctx(), self._tag():
            out = self._spec(self.params, toks, cache, key)
        self._account("decode", t0)
        return out

    def table_write(self, cache, slot_ids, rows):
        with self._ctx():
            return self._table_write(cache, slot_ids, rows)

    def copy_page(self, cache, src, dst):
        with self._ctx():
            return self._copy_page(cache, src, dst)

    def _account(self, kind: str, t0: float):
        m = self.metrics
        m.counter("lm_worker_dispatches", worker=self.worker,
                  role=self.role, kind=kind).inc()
        m.histogram(f"lm_{kind}_worker_s", worker=self.worker,
                    role=self.role).record(time.perf_counter() - t0)

    # -- integrity / recovery hooks (scheduler-facing) -----------------------

    def read_pages(self, cache, page_ids) -> np.ndarray:
        """Host byte image ``[P, nbytes]`` of the given physical pages,
        concatenated across every pool leaf (layers then dense_layers, in
        tree-leaf order) — the unit the KV CRC scrub tags and re-checks.
        Deterministic: leaf order and dtype byte layout are fixed by the
        cache pytree."""
        ids = [int(p) for p in page_ids]
        idx = jnp.asarray(ids, jnp.int32)
        per_page: List[List[bytes]] = [[] for _ in ids]
        for grp in ("layers", "dense_layers"):
            if grp not in cache:
                continue
            for leaf in jax.tree.leaves(cache[grp]):
                rows = np.asarray(jnp.take(leaf, idx, axis=1))
                rows = np.moveaxis(rows, 1, 0)  # [P, n_layers, ...]
                for i in range(len(ids)):
                    per_page[i].append(rows[i].tobytes())
        blobs = [b"".join(parts) for parts in per_page]
        if not blobs:
            return np.zeros((0, 0), np.uint8)
        return np.frombuffer(b"".join(blobs),
                             np.uint8).reshape(len(ids), -1)

    def corrupt_page(self, cache, page: int, bit: int):
        """Flip one bit of physical page ``page`` in the first pool leaf
        (host round-trip) — the chaos injector's KV bit-flip. Returns the
        updated cache; the page's stored CRC tag no longer matches."""
        grp = "layers" if "layers" in cache else "dense_layers"
        leaves, treedef = jax.tree.flatten(cache[grp])
        leaf = leaves[0]
        block = np.asarray(leaf[:, page])
        raw = np.frombuffer(block.tobytes(), np.uint8).copy()
        raw[(bit // 8) % len(raw)] ^= np.uint8(1 << (bit % 8))
        fixed = np.frombuffer(raw.tobytes(),
                              block.dtype).reshape(block.shape)
        leaves[0] = leaf.at[:, page].set(jnp.asarray(fixed))
        out = dict(cache)
        out[grp] = jax.tree.unflatten(treedef, leaves)
        return out

    def reload_params(self, params) -> None:
        """Swap in (repaired) resident weights — the scrub path after a
        shadow repack. Re-places onto the mesh when sharded."""
        if self.mesh is not None:
            params = _place_params(self.mesh, self.rules, params, self.cfg)
        self.params = params

    def on_worker_crash(self, wid: str) -> str:
        """Recovery verdict for a crashed worker. The unified executor
        has no pool to lose — a crash is always retryable in place."""
        return "retry"


class LocalExecutor(_DecodeSide):
    """Unified executor: prefill + decode share one device (or one
    sharded mesh) and the resident cache — prefill writes land in place,
    no handoff."""

    role = "unified"

    def __init__(self, cfg: ModelConfig, params, *, mode: str = "float",
                 rules=None, mesh=None, temperature: float = 0.0,
                 top_k: int = 0, paged: bool = False,
                 spec_decode: bool = False, draft_k: int = 4,
                 max_seq: int = 128, cache_dtype=None,
                 metrics: Optional[MetricsRegistry] = None,
                 worker: str = "w0", faults: Optional[FaultPlan] = None):
        super().__init__(cfg, params, mode=mode, rules=rules, mesh=mesh,
                         temperature=temperature, top_k=top_k, paged=paged,
                         spec_decode=spec_decode, draft_k=draft_k,
                         metrics=metrics, worker=worker, faults=faults)
        self.max_seq = max_seq
        del cache_dtype  # resident cache dtype is the scheduler's concern
        # compiles once per (batch-bucket, length-bucket) pair
        self._prefill = make_prefill_select_step(
            cfg, self.rules, mode, temperature=temperature, top_k=top_k,
            paged=paged)
        self._prefill_hit = (make_prefill_select_step(
            cfg, self.rules, mode, temperature=temperature, top_k=top_k,
            paged=True, history=True) if paged else None)

    def prefill(self, toks, lens, key):
        """Contiguous prefill into a fresh scratch cache; returns
        (first tokens [B] np, scratch handle for ``write_slot``).
        The scratch cache uses the config's native KV dtype (matching
        the single-executor server); ``write_slot`` casts at the copy."""
        self._fire("prefill")
        blen = int(toks.shape[0])
        t0 = time.perf_counter()
        with self._ctx(), self._tag():
            c1, _ = lm.init_cache(self.cfg, blen, self.max_seq)
            tok0, c1 = self._prefill(self.params, toks, lens, c1, key)
            tok0 = np.asarray(tok0)
        self._account("prefill", t0)
        return tok0, _PrefillHandle(None, c1)

    def write_slot(self, cache, handle: _PrefillHandle, row, slot):
        with self._ctx():
            return self._write(cache, handle.cache, jnp.int32(row),
                               jnp.int32(slot))

    def prefill_paged(self, toks, lens, starts, slot_ids, rows, cache, key,
                      *, history: bool):
        """Paged prefill straight through the block table into the
        resident pools (cold prompts or prefix-hit suffixes)."""
        self._fire("prefill")
        fn = self._prefill_hit if history else self._prefill
        t0 = time.perf_counter()
        with self._ctx(), self._tag():
            tok0, cache = fn(self.params, toks, lens, starts, slot_ids,
                             rows, cache, key)
            tok0 = np.asarray(tok0)
        self._account("prefill", t0)
        return tok0, cache


class PrefillWorker:
    """One prefill worker: a TP slice of the prefill pool with its own
    resident copy of the weights and a scratch cache per admission batch.
    Produces finished K/V state for the decode side to adopt."""

    def __init__(self, wid: str, cfg: ModelConfig, params, devices, *,
                 mode: str, rules, temperature: float, top_k: int,
                 paged: bool, page_size: int, max_seq: int, cache_dtype,
                 metrics: MetricsRegistry,
                 faults: Optional[FaultPlan] = None,
                 hb: Optional[HeartbeatBook] = None):
        self.wid, self.cfg, self.max_seq = wid, cfg, max_seq
        self.paged, self.page_size = paged, page_size
        self.metrics = metrics
        self.faults = faults
        self.hb = hb
        self.devices = list(devices)  # restart recipe: same carve slice
        self._ckw = {} if cache_dtype is None else {"dtype": cache_dtype}
        self.mesh = make_serving_mesh((1, len(devices)), devices=devices)
        self.rules = (rules if rules is not None
                      else default_rules()).for_mesh(self.mesh)
        self.params = _place_params(self.mesh, self.rules, params, cfg)
        self._prefill = make_prefill_select_step(
            cfg, self.rules, mode, temperature=temperature, top_k=top_k,
            paged=paged)

        def extract_row(c, row):
            """One sequence row of a scratch cache (still batched dim 1,
            for the decode side's write_slot at row 0)."""
            def leaf(x):
                if x.ndim == 1:  # per-sequence pos vector
                    return lax.dynamic_slice_in_dim(x, row, 1)
                return lax.dynamic_slice_in_dim(x, row, 1, axis=1)
            return jax.tree.map(leaf, c)
        self._extract_row = jax.jit(extract_row)

    def _fire(self, seam: str) -> None:
        if self.faults is not None:
            self.faults.raise_any(self.faults.fire(seam, worker=self.wid),
                                  wid=self.wid)

    def prefill(self, toks, lens, key):
        """Contiguous prefill on this worker's devices."""
        self._fire("prefill")
        blen = int(toks.shape[0])
        t0 = time.perf_counter()
        with self.mesh, _flight.phase("", window=0, worker=self.wid):
            c1, _ = lm.init_cache(self.cfg, blen, self.max_seq)
            tok0, c1 = self._prefill(self.params, toks, lens, c1, key)
            tok0 = np.asarray(tok0)
        self._account(t0)
        return tok0, c1

    def prefill_paged(self, toks, lens, slot_live, n_pages, key):
        """Cold paged prefill into a *scratch* pool on this worker: row i
        of the batch owns scratch pages [i*n_pages, (i+1)*n_pages) via an
        identity block table, so the decode side can adopt exactly the
        pages each admitted request touched. Dead batch rows keep the
        slot sentinel (their pos scatter drops)."""
        self._fire("prefill")
        blen = int(toks.shape[0])
        pool = blen * n_pages
        table = np.arange(pool, dtype=np.int32).reshape(blen, n_pages)
        slot_ids = np.where(slot_live, np.arange(blen, dtype=np.int32),
                            np.int32(blen))
        starts = np.zeros((blen,), np.int32)
        t0 = time.perf_counter()
        with self.mesh, _flight.phase("", window=0, worker=self.wid):
            c1, _ = lm.init_cache(self.cfg, blen, self.max_seq,
                                  page_size=self.page_size,
                                  pool_pages=pool, **self._ckw)
            c1 = self._table_write_scratch(c1, table)
            tok0, c1 = self._prefill(self.params, jnp.asarray(toks),
                                     jnp.asarray(lens), jnp.asarray(starts),
                                     jnp.asarray(slot_ids), jnp.asarray(table),
                                     c1, key)
            tok0 = np.asarray(tok0)
        self._account(t0)
        return tok0, c1

    @staticmethod
    def _table_write_scratch(cache, table):
        out = dict(cache)
        out["table"] = jnp.asarray(table)
        return out

    def extract_row(self, cache, row):
        with self.mesh:
            return self._extract_row(cache, jnp.int32(row))

    def _account(self, t0: float):
        m = self.metrics
        m.counter("lm_worker_dispatches", worker=self.wid,
                  role="prefill", kind="prefill").inc()
        m.histogram("lm_prefill_worker_s", worker=self.wid,
                    role="prefill").record(time.perf_counter() - t0)
        if self.hb is not None:  # heartbeat per successful dispatch
            self.hb.beat(self.wid)


class DisaggExecutor(_DecodeSide):
    """Disaggregated executor: prefill worker pool + resident decode mesh
    on disjoint device slices, bridged by a ``jax.device_put`` handoff.

    Device carve: the first ``prefill_devices`` attached devices become
    the prefill pool (split round-robin into ``prefill_workers`` TP
    workers), the next ``decode_devices`` the decode mesh (shape
    ``decode_mesh_shape``, default (D, 1) = slot-parallel DP). When the
    box has too few devices the pools overlap (with a warning) instead
    of raising — the handoff path still runs, it just moves bytes
    between colocated buffers.

    Unsupported combinations raise at construction: prefix-cache reuse
    needs prefill to read the *resident* pools' history, which is
    exactly the coupling disaggregation removes (degraded mode, where
    prefill runs on the decode mesh anyway, lifts the restriction).

    Recovery: a :class:`WorkerCrash` at a prefill/handoff seam routes
    through :meth:`on_worker_crash` — the dead worker is rebuilt on its
    own device slice up to ``max_worker_restarts`` times, then dropped
    from the pool; when the last worker is gone the executor *degrades*
    instead of failing: prefill falls back to the decode mesh
    (``LocalExecutor`` layout, lazily compiled), so the server keeps
    serving at reduced throughput. :meth:`check_stragglers` applies the
    same verdicts to workers whose heartbeats go silent."""

    role = "disagg"

    def __init__(self, cfg: ModelConfig, params, *,
                 prefill_devices: int = 1, decode_devices: int = 1,
                 prefill_workers: int = 0, decode_mesh_shape=None,
                 mode: str = "float", rules=None, temperature: float = 0.0,
                 top_k: int = 0, paged: bool = False, page_size: int = 16,
                 spec_decode: bool = False, draft_k: int = 4,
                 max_seq: int = 128, cache_dtype=None,
                 metrics: Optional[MetricsRegistry] = None,
                 faults: Optional[FaultPlan] = None,
                 max_worker_restarts: int = 1):
        pdevs, ddevs = carve_devices(prefill_devices, decode_devices)
        dshape = tuple(decode_mesh_shape or (len(ddevs), 1))
        mesh = make_serving_mesh(dshape, devices=ddevs)
        super().__init__(cfg, params, mode=mode, rules=rules, mesh=mesh,
                         temperature=temperature, top_k=top_k, paged=paged,
                         spec_decode=spec_decode, draft_k=draft_k,
                         metrics=metrics, worker="d0", faults=faults)
        self.max_seq = max_seq
        self.page_size = page_size
        self.max_worker_restarts = max_worker_restarts
        self.degraded = False
        self.hb = HeartbeatBook()
        self._restarts: dict = {}
        self._fb: dict = {}  # degraded-mode prefill fns, built on demand
        # worker rebuild recipe: the ORIGINAL (pre-placement) params plus
        # the construction kwargs — self.params is already mesh-placed
        self._init_params = params
        self._worker_kw = dict(mode=mode, rules=rules,
                               temperature=temperature, top_k=top_k,
                               paged=paged, page_size=page_size,
                               max_seq=max_seq, cache_dtype=cache_dtype)

        nw = prefill_workers or 1
        if len(pdevs) % nw:
            raise ValueError(f"{len(pdevs)} prefill devices do not split "
                             f"into {nw} workers")
        per = len(pdevs) // nw
        self.pool: List[PrefillWorker] = [
            self._mk_worker(f"p{i}", pdevs[i * per:(i + 1) * per])
            for i in range(nw)]
        self._rr = 0

        if paged:
            def adopt(cache, pools, src_ids, dst_ids, slot_ids,
                      pos_vals):
                """Adopt prefilled pages into the resident pools: gather
                ``src_ids`` from the handed-off scratch pools, scatter at
                ``dst_ids`` (sentinel-padded entries drop), and land each
                admitted slot's position (dead rows carry the slot
                sentinel and drop)."""
                def leaf(full, one):
                    rows = jnp.take(one, src_ids, axis=1)
                    return full.at[:, dst_ids].set(
                        rows.astype(full.dtype), mode="drop")
                out = dict(cache)
                for grp in ("layers", "dense_layers"):
                    if grp in cache:
                        out[grp] = jax.tree.map(leaf, cache[grp],
                                                pools[grp])
                out["pos"] = cache["pos"].at[slot_ids].set(pos_vals,
                                                           mode="drop")
                return out
            self._adopt = jax.jit(adopt, donate_argnums=(0,))

    def _mk_worker(self, wid: str, devices) -> PrefillWorker:
        return PrefillWorker(wid, self.cfg, self._init_params, devices,
                             metrics=self.metrics, faults=self.faults,
                             hb=self.hb, **self._worker_kw)

    def _next_worker(self) -> Optional[PrefillWorker]:
        if not self.pool:  # degraded: prefill falls back to decode mesh
            return None
        w = self.pool[self._rr % len(self.pool)]
        self._rr += 1
        return w

    # -- recovery ------------------------------------------------------------

    def on_worker_crash(self, wid: str) -> str:
        """Recovery verdict for a dead prefill worker: rebuild it on its
        own device slice (``'restarted'``, bounded by
        ``max_worker_restarts``), then drop it (``'dropped'``); losing
        the last worker flips the executor into degraded decode-mesh
        prefill (``'degraded'``). The scheduler re-prefills whatever the
        deceased had in flight either way."""
        self.hb.forget(wid)
        idx = next((i for i, w in enumerate(self.pool) if w.wid == wid),
                   None)
        if idx is None:  # already dropped (or decode-side attribution)
            return "degraded" if self.degraded else "retry"
        n = self._restarts.get(wid, 0)
        if n < self.max_worker_restarts:
            self._restarts[wid] = n + 1
            self.pool[idx] = self._mk_worker(wid, self.pool[idx].devices)
            self.metrics.counter("lm_worker_restarts", worker=wid).inc()
            return "restarted"
        self.pool.pop(idx)
        if self.pool:
            self.metrics.counter("lm_worker_drops", worker=wid).inc()
            return "dropped"
        self.degraded = True
        self.metrics.gauge("lm_degraded").set(1.0)
        return "degraded"

    def check_stragglers(self, timeout: float, now=None) -> List[str]:
        """Heartbeat supervision (``HeartbeatBook``): a worker silent for
        ``timeout`` seconds is treated exactly like a crash. Returns the
        ``wid:verdict`` actions taken (empty = everyone healthy)."""
        return [f"{wid}:{self.on_worker_crash(wid)}"
                for wid in self.hb.stale(timeout, now)]

    def _fallback_prefill(self, *, paged: bool, history: bool = False):
        """Degraded-mode prefill entry point on the decode mesh, compiled
        on first use (the happy path never pays for it)."""
        k = (paged, history)
        fn = self._fb.get(k)
        if fn is None:
            fn = self._fb[k] = make_prefill_select_step(
                self.cfg, self.rules, self.mode,
                temperature=self.temperature, top_k=self.top_k,
                paged=paged, history=history)
        return fn

    # -- contiguous path -----------------------------------------------------

    def prefill(self, toks, lens, key):
        w = self._next_worker()
        if w is None:  # degraded: prefill locally on the decode mesh
            self._fire("prefill")
            blen = int(toks.shape[0])
            t0 = time.perf_counter()
            with self._ctx(), self._tag():
                c1, _ = lm.init_cache(self.cfg, blen, self.max_seq)
                tok0, c1 = self._fallback_prefill(paged=False)(
                    self.params, toks, lens, c1, key)
                tok0 = np.asarray(tok0)
            self._account("prefill", t0)
            return tok0, _PrefillHandle(None, c1)
        tok0, c1 = w.prefill(toks, lens, key)
        return tok0, _PrefillHandle(w, c1)

    def write_slot(self, cache, handle: _PrefillHandle, row, slot):
        """The contiguous handoff: extract one finished sequence row on
        the prefill worker, ``jax.device_put`` it onto the decode mesh,
        scatter it into the donated resident cache. Degraded-mode
        handles (no worker) are already on our mesh — plain local
        write, no handoff."""
        if handle.worker is None:
            with self._ctx():
                return self._write(cache, handle.cache, jnp.int32(row),
                                   jnp.int32(slot))
        self._fire("handoff", wid=handle.worker.wid)
        t0 = time.perf_counter()
        row_cache = handle.worker.extract_row(handle.cache, row)
        moved = _replicate_on(self.mesh, row_cache)
        with self._ctx():
            out = self._write(cache, moved, jnp.int32(0), jnp.int32(slot))
        jax.block_until_ready(out["pos"])
        self._handoff(t0, handle.worker.wid)
        return out

    # -- paged path ----------------------------------------------------------

    def prefill_paged(self, toks, lens, starts, slot_ids, rows, cache, key,
                      *, history: bool):
        """The paged handoff: cold-prefill into an identity-mapped
        scratch pool on a prefill worker, move the touched pages to the
        decode mesh, and adopt them at the scheduler's physical page ids
        through the resident block table."""
        w = self._next_worker()
        if w is None:  # degraded: straight through the resident table
            self._fire("prefill")
            fn = self._fallback_prefill(paged=True, history=history)
            t0 = time.perf_counter()
            with self._ctx(), self._tag():
                tok0, cache = fn(self.params, toks, lens, starts,
                                 slot_ids, rows, cache, key)
                tok0 = np.asarray(tok0)
            self._account("prefill", t0)
            return tok0, cache
        if history:
            raise RuntimeError(
                "prefix-cache suffix prefill reads resident pool history; "
                "it cannot run on a disaggregated prefill worker")
        rows_np = np.asarray(rows)
        slots_np = np.asarray(slot_ids)
        blen, n_pages = rows_np.shape
        sentinel = int(jax.tree.leaves(cache["layers"])[0].shape[1])
        slot_live = slots_np < cache["table"].shape[0]
        tok0, scratch = w.prefill_paged(np.asarray(toks), np.asarray(lens),
                                        slot_live, n_pages, key)
        # the handoff seam fires after the scratch prefill but BEFORE the
        # donating adopt: an injected mid-handoff crash leaves the
        # resident cache valid, and the scheduler re-prefills.
        self._fire("handoff", wid=w.wid)

        t0 = time.perf_counter()
        # fixed-width id vectors (compiled once per batch bucket): row i's
        # j-th mapped page lives at scratch page i*n_pages+j and lands at
        # the physical id the scheduler allocated; unmapped entries pad
        # with the sentinel and drop in the scatter.
        src_ids = np.zeros((blen * n_pages,), np.int32)
        dst_ids = np.full((blen * n_pages,), sentinel, np.int32)
        for i in range(blen):
            if not slot_live[i]:
                continue
            mapped = rows_np[i][rows_np[i] < sentinel]
            k = len(mapped)
            src_ids[i * n_pages:i * n_pages + k] = \
                i * n_pages + np.arange(k, dtype=np.int32)
            dst_ids[i * n_pages:i * n_pages + k] = mapped
        pools = {grp: scratch[grp] for grp in ("layers", "dense_layers")
                 if grp in scratch}
        moved = _replicate_on(self.mesh, pools)
        pos_vals = _replicate_on(self.mesh, scratch["pos"])
        with self._ctx():
            cache = self._adopt(cache, moved, jnp.asarray(src_ids),
                                jnp.asarray(dst_ids), jnp.asarray(slots_np),
                                pos_vals)
        jax.block_until_ready(cache["pos"])
        self._handoff(t0, w.wid)
        return tok0, cache

    def _handoff(self, t0: float, src_worker: str):
        dt = time.perf_counter() - t0
        m = self.metrics
        m.histogram("lm_handoff_latency").record(dt)
        m.histogram("lm_handoff_latency", worker=src_worker,
                    role="prefill").record(dt)
        m.counter("lm_handoffs").inc()
