"""Fault tolerance: heartbeat supervision, straggler mitigation, elastic
restart.

Production model (scaled to subprocesses on this container):
  * every worker writes a heartbeat file each step;
  * the coordinator polls heartbeats; a worker silent past
    ``straggler_timeout`` is declared a straggler and killed (on real pods:
    the job controller evicts the VM and the slice restarts);
  * the job restarts from the latest atomic checkpoint — possibly with a
    DIFFERENT worker count (elastic): checkpoints are mesh-agnostic
    (see repro.checkpoint) and the data iterator state is a single int,
    so a resize is just "restore + new mesh".

``python -m repro.launch.ft --kill-at 7`` demos a mid-run SIGKILL and
recovery; the test suite asserts bit-identical convergence vs an
uninterrupted run.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import signal
import subprocess
import sys
import time
from typing import Dict, List, Optional

HEARTBEAT = "heartbeat_{rank}.json"


class HeartbeatBook:
    """In-memory heartbeat ledger: the file-based worker heartbeats below,
    generalized to in-process serving workers (``launch/workers.py``).
    Executors ``beat`` on every successful dispatch; a supervisor asks for
    ``stale`` workers and treats them like crashed processes. ``now`` is
    injectable everywhere so supervision itself stays deterministic in
    tests (no wall-clock coupling in the fault plans)."""

    def __init__(self):
        self._last: Dict[str, float] = {}

    def beat(self, wid: str, now: Optional[float] = None) -> None:
        self._last[wid] = time.time() if now is None else now

    def last(self, wid: str) -> Optional[float]:
        return self._last.get(wid)

    def stale(self, timeout: float,
              now: Optional[float] = None) -> List[str]:
        t = time.time() if now is None else now
        return [w for w, hb in self._last.items() if t - hb > timeout]

    def forget(self, wid: str) -> None:
        self._last.pop(wid, None)


def write_heartbeat(run_dir: str, rank: int, step: int):
    path = os.path.join(run_dir, HEARTBEAT.format(rank=rank))
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"step": step, "time": time.time()}, f)
    os.replace(tmp, path)


def read_heartbeat(run_dir: str, rank: int) -> Optional[dict]:
    path = os.path.join(run_dir, HEARTBEAT.format(rank=rank))
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            return json.load(f)
    except (json.JSONDecodeError, OSError):
        return None


class Coordinator:
    """Supervises worker processes; kills stragglers; restarts elastically.

    ``clean_cmd`` (optional) is the command used for restarts instead of
    ``worker_cmd`` — e.g. the same invocation without an injected
    ``--kill-at`` crash, so the restarted worker runs clean.
    """

    def __init__(self, run_dir: str, worker_cmd: List[str], *,
                 clean_cmd: Optional[List[str]] = None,
                 straggler_timeout: float = 30.0, max_restarts: int = 3,
                 poll_s: float = 0.5):
        self.run_dir = run_dir
        self.worker_cmd = worker_cmd
        self.clean_cmd = clean_cmd
        self.straggler_timeout = straggler_timeout
        self.max_restarts = max_restarts
        self.poll_s = poll_s
        self.restarts = 0
        self.start_time = time.time()
        os.makedirs(run_dir, exist_ok=True)
        # heartbeats left behind by a PREVIOUS run carry old `time` fields
        # and would instantly trip the straggler detector: clear them, and
        # `_fresh` below additionally ignores anything pre-dating this
        # coordinator (a worker may legitimately rewrite an old file)
        for hb in glob.glob(os.path.join(run_dir,
                                         HEARTBEAT.format(rank="*"))):
            try:
                os.remove(hb)
            except OSError:
                pass

    def _fresh(self, hb: Optional[dict]) -> Optional[dict]:
        """Only heartbeats written during THIS run count (stale-heartbeat
        regression guard)."""
        if hb and hb.get("time", 0.0) >= self.start_time:
            return hb
        return None

    def _spawn(self) -> subprocess.Popen:
        cmd = (self.clean_cmd if self.clean_cmd is not None
               and self.restarts > 0 else self.worker_cmd)
        return subprocess.Popen(cmd, cwd=os.getcwd())

    def run(self) -> int:
        """Returns the worker's final exit code (0 = converged)."""
        proc = self._spawn()
        while True:
            time.sleep(self.poll_s)
            rc = proc.poll()
            if rc == 0:
                return 0
            if rc is not None:  # crashed -> restart from checkpoint
                if self.restarts >= self.max_restarts:
                    return rc
                self.restarts += 1
                print(f"[ft] worker died rc={rc}; restart "
                      f"{self.restarts}/{self.max_restarts}", flush=True)
                proc = self._spawn()
                continue
            hb = self._fresh(read_heartbeat(self.run_dir, 0))
            if hb and time.time() - hb["time"] > self.straggler_timeout:
                if self.restarts >= self.max_restarts:
                    proc.kill()
                    return 1
                self.restarts += 1
                print(f"[ft] straggler detected (silent "
                      f"{time.time() - hb['time']:.1f}s); killing + "
                      f"restarting from checkpoint", flush=True)
                proc.kill()
                proc.wait()
                proc = self._spawn()


def _worker(args):
    """Training worker with heartbeats (and an optional injected crash)."""
    from ..configs.base import load_arch
    from ..optim.adamw import AdamWConfig
    from ..train.step import TrainConfig
    from .train import train_loop

    cfg = load_arch(args.arch).smoke()
    tcfg = TrainConfig(opt=AdamWConfig(lr=3e-3), warmup_steps=2,
                       total_steps=args.steps)

    def log(msg):
        print(msg, flush=True)

    # heartbeat once per data-batch fetch (i.e. per training step), and
    # optionally inject a hard crash for the recovery demo/test
    import repro.data.pipeline as dp
    orig_next = dp.DataIterator.__next__

    def patched_next(self):
        write_heartbeat(args.run_dir, 0, self.step)
        if args.kill_at >= 0 and self.step == args.kill_at:
            print(f"[worker] injected crash at step {self.step}", flush=True)
            os.kill(os.getpid(), signal.SIGKILL)
        return orig_next(self)

    dp.DataIterator.__next__ = patched_next
    train_loop(cfg, tcfg, steps=args.steps, ckpt_dir=args.ckpt_dir,
               seq_len=32, global_batch=4, ckpt_every=args.ckpt_every,
               log_every=5, log=log)
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--run-dir", default="/tmp/repro_ft")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--ckpt-every", type=int, default=5)
    ap.add_argument("--kill-at", type=int, default=-1,
                    help="worker: SIGKILL self at this data step")
    ap.add_argument("--worker", action="store_true",
                    help="run as worker (internal)")
    ap.add_argument("--straggler-timeout", type=float, default=60.0)
    args = ap.parse_args()
    args.ckpt_dir = args.ckpt_dir or os.path.join(args.run_dir, "ckpt")

    if args.worker:
        sys.exit(_worker(args))

    cmd = [sys.executable, "-m", "repro.launch.ft", "--worker",
           "--run-dir", args.run_dir, "--ckpt-dir", args.ckpt_dir,
           "--arch", args.arch, "--steps", str(args.steps),
           "--ckpt-every", str(args.ckpt_every)]
    # after the first (injected) crash the restarted worker must not crash
    # again: restarts run the clean command without the kill flag
    coord = Coordinator(args.run_dir,
                        cmd + ["--kill-at", str(args.kill_at)],
                        clean_cmd=cmd,
                        straggler_timeout=args.straggler_timeout)
    rc = coord.run()
    print(f"[ft] finished rc={rc} restarts={coord.restarts}")
    sys.exit(rc)


if __name__ == "__main__":
    main()
