# NOTE: do not import dryrun here — it sets XLA_FLAGS at import time.
from .mesh import make_production_mesh, make_test_mesh  # noqa: F401
