"""Shared continuous-batching scaffolding for the lookup/decode servers.

Requests queue up; ``step`` drains them in fixed batch *buckets* (an
ascending tuple, typically powers of two) so the number of compiled
shapes stays bounded.  Drain policy: while the queue fills a whole
bucket (> 1), drain the largest such bucket with no padding; only the
final partial remainder — necessarily smaller than the smallest
multi-row bucket — is padded (by repeating its tail row) into the
smallest bucket that holds it.  This bounds padding waste per drain
sequence to less than one small bucket, instead of up to 4× when a
just-over-a-boundary queue is rounded all the way up.

Subclasses provide the request validation, the row extraction, the
batched compute, and the per-request retirement.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..obs.metrics import MetricsRegistry


def bucket_for(count: int, buckets: Sequence[int]) -> int:
    """Smallest bucket holding ``count``.

    Overflow raises: silently clamping to ``buckets[-1]`` would hand the
    caller a shape SMALLER than its payload — a truncation bug (dropped
    prompt rows, out-of-bounds scatter) that surfaces far from here.
    Callers that want clamping (``drain_take``) cap explicitly first."""
    for b in buckets:
        if count <= b:
            return b
    raise ValueError(f"count {count} exceeds largest bucket {buckets[-1]}")


def drain_take(queued: int, buckets: Sequence[int]) -> Tuple[int, int]:
    """(take, bucket): whole buckets first, pad only the remainder.

    Shared scheduling policy of every bucketed server (lookup, decode,
    LM admission): while the queue fills a whole multi-row bucket, drain
    it unpadded; only the final partial remainder is padded into the
    smallest bucket that holds it."""
    cap = min(queued, buckets[-1])
    full = [b for b in buckets if 1 < b <= cap]
    if full:
        take = max(full)
        return take, take
    return cap, bucket_for(cap, buckets)


class BucketedBatchServer:
    """Queue -> bucketed batches -> per-request retirement."""

    def __init__(self, *, buckets=(1, 4, 16, 64),
                 metrics: Optional[MetricsRegistry] = None):
        assert tuple(buckets) == tuple(sorted(buckets)) and buckets
        self.buckets = tuple(buckets)
        self.queue: List = []
        self.batches = 0
        self.bucket_counts: Dict[int, int] = {b: 0 for b in self.buckets}
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._submit_t: Dict[int, float] = {}  # id(req) -> submit time

    # -- subclass hooks ------------------------------------------------------

    def _validate(self, req) -> None:
        raise NotImplementedError

    def _row(self, req) -> np.ndarray:
        """The request's input row (stacked into the batch array)."""
        raise NotImplementedError

    def _run(self, rows: np.ndarray):
        """Batched compute over [bucket, ...] rows."""
        raise NotImplementedError

    def _retire(self, req, result, i: int) -> None:
        """Fill request ``req`` from row ``i`` of the batch ``result``."""
        raise NotImplementedError

    # -- scheduling ----------------------------------------------------------

    def submit(self, req):
        self._validate(req)
        self.metrics.counter("batch_requests_submitted").inc()
        self._submit_t[id(req)] = time.perf_counter()
        self.queue.append(req)

    def _bucket(self, count: int) -> int:
        return bucket_for(count, self.buckets)

    def _drain_size(self):
        return drain_take(len(self.queue), self.buckets)

    def step(self) -> List:
        """Drain one bucket; returns retired requests."""
        if not self.queue:
            return []
        take, bucket = self._drain_size()
        batch, self.queue = self.queue[:take], self.queue[take:]
        rows = np.stack([self._row(r) for r in batch])
        if bucket > take:  # pad by repeating the tail row
            rows = np.concatenate(
                [rows, np.repeat(rows[-1:], bucket - take, axis=0)])
        t0 = time.perf_counter()
        result = self._run(rows)
        t1 = time.perf_counter()
        self.batches += 1
        self.bucket_counts[bucket] += 1
        m = self.metrics
        m.counter("batch_batches").inc()
        m.histogram("batch_step_s").record(t1 - t0)
        m.histogram("batch_fill_ratio").record(take / bucket)
        m.gauge("batch_queue_depth").set(len(self.queue))
        for i, req in enumerate(batch):
            self._retire(req, result, i)
            req.done = True
            m.counter("batch_requests_retired").inc()
            sub = self._submit_t.pop(id(req), None)
            if sub is not None:
                m.histogram("batch_queue_wait_s").record(t0 - sub)
        return batch

    def run(self) -> List:
        done = []
        while self.queue:
            done.extend(self.step())
        return done
