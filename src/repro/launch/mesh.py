"""Production meshes. Import must never touch jax device state —
everything is a function."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2, *, pod: int = 0):
    """Small mesh for CPU tests (requires enough placeholder devices)."""
    if pod:
        return jax.make_mesh((pod, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
