"""Production meshes. Import must never touch jax device state —
everything is a function.

``make_serving_mesh`` is the serving entry point: it degrades gracefully
when the requested shape exceeds the attached devices (CI forced-host
runs, single-chip dev boxes) by falling back to the largest valid
submesh with a warning — a mesh mismatch should cost a log line at
server construction, not an opaque shape error deep inside jit.
"""
from __future__ import annotations

import math
import warnings
from typing import Sequence, Tuple

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2, *, pod: int = 0):
    """Small mesh for CPU tests (requires enough placeholder devices)."""
    if pod:
        return jax.make_mesh((pod, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def fit_mesh_shape(shape: Sequence[int], n_devices: int) -> Tuple[int, ...]:
    """Largest valid submesh of ``shape`` that fits ``n_devices``.

    Pure shape arithmetic (no device state) so it unit-tests without a
    multi-device runtime. Axis sizes only ever shrink (an axis the
    caller left at 1 stays 1), by repeatedly halving the largest
    oversized axis — the power-of-two walk every TPU/CI topology uses —
    until the product fits. Degenerate inputs clamp to 1 per axis.
    """
    if n_devices < 1:
        raise ValueError(f"n_devices={n_devices} must be >= 1")
    fitted = [max(1, int(s)) for s in shape]
    while math.prod(fitted) > n_devices:
        i = max(range(len(fitted)), key=lambda j: fitted[j])
        if fitted[i] == 1:  # unreachable: prod of all-ones is 1
            break
        fitted[i] = max(1, fitted[i] // 2)
    return tuple(fitted)


def parse_mesh_spec(spec: str) -> Tuple[int, ...]:
    """'2x2' / '1x4' / '2x2x2' -> mesh shape tuple (data, model[, pod-first
    when 3 axes])."""
    try:
        shape = tuple(int(p) for p in spec.lower().split("x"))
    except ValueError:
        raise ValueError(f"bad mesh spec {spec!r}: want e.g. '2x2'") from None
    if not shape or any(s < 1 for s in shape) or len(shape) > 3:
        raise ValueError(f"bad mesh spec {spec!r}: want 1-3 positive axes")
    return shape


def carve_devices(prefill: int, decode: int,
                  devices=None) -> Tuple[list, list]:
    """Split the attached devices into disjoint prefill/decode pools.

    The first ``prefill`` devices feed the worker pool, the next
    ``decode`` the resident decode mesh. When the box is too small the
    pools overlap round-robin (with a warning) instead of raising — the
    handoff path still runs, it just moves bytes between colocated
    buffers. Shared by :class:`repro.launch.workers.DisaggExecutor` and
    its degraded-mode rebuilds, so a restarted worker always lands on the
    same carve."""
    devs = list(devices) if devices is not None else list(jax.devices())
    if prefill + decode > len(devs):
        warnings.warn(
            f"disaggregated serving wants {prefill}+{decode} devices but "
            f"only {len(devs)} are attached; pools will overlap",
            stacklevel=2)
    pdevs = [devs[i % len(devs)] for i in range(prefill)]
    ddevs = [devs[(prefill + i) % len(devs)] for i in range(decode)]
    return pdevs, ddevs


def make_serving_mesh(shape: Sequence[int] = (1, 1), *, devices=None):
    """Serving mesh over ``('data', 'model')`` (or ``('pod', 'data',
    'model')`` for 3 axes), clamped to the attached devices.

    When ``prod(shape)`` exceeds the device count, falls back to the
    largest valid submesh (:func:`fit_mesh_shape`) and warns — callers
    get a working (possibly smaller) mesh instead of a raise from inside
    a jitted computation whose error message never mentions devices.
    ``devices`` narrows the pool to an explicit device list (the
    disaggregated server carves prefill/decode pools this way).
    """
    devs = list(devices) if devices is not None else list(jax.devices())
    fitted = fit_mesh_shape(shape, len(devs))
    if fitted != tuple(shape):
        warnings.warn(
            f"requested mesh {tuple(shape)} needs {math.prod(shape)} "
            f"devices but only {len(devs)} are attached; falling back to "
            f"the largest valid submesh {fitted}", stacklevel=2)
    axes = ("pod", "data", "model")[-len(fitted):]
    import numpy as np
    from jax.sharding import Mesh
    n = math.prod(fitted)
    return Mesh(np.asarray(devs[:n]).reshape(fitted), axes)
