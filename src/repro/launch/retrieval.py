"""Batched associative-lookup server: request queue -> bucketed top-k search.

The retrieval twin of launch/serve.py's continuous-batching loop: lookup
requests (one binary code each, per-request k) arrive in a queue; the
shared ``BucketedBatchServer`` scheduler drains them in fixed query-batch
buckets (bounded compiled shapes, tail padding only on the final partial
bucket), runs one fused ``CAMIndex.search`` per bucket, then retires
every request with its slice of the batch result. Requests keep arriving
while batches run — submit/run can interleave.

CLI (self-contained demo: plants queries that must retrieve their source
row, then reports QPS and emulated PPAC cycles):

    PYTHONPATH=src python -m repro.launch.retrieval \
        --m 65536 --bits 256 --requests 256 --k 4 [--backend mxu]
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import numpy as np

from ..core.ppac import PPACConfig
from ..retrieval.index import CAMIndex
from .bucketed import BucketedBatchServer


@dataclasses.dataclass
class LookupRequest:
    rid: int
    code: np.ndarray                      # [n_bits] {0,1}
    k: int = 1
    scores: Optional[np.ndarray] = None   # [k] filled at retirement
    ids: Optional[np.ndarray] = None
    done: bool = False


class RetrievalServer(BucketedBatchServer):
    """Bucketed batch scheduler over one CAMIndex."""

    def __init__(self, index: CAMIndex, *, max_k: int = 16,
                 buckets=(1, 4, 16, 64), mesh=None, shard_axis: str = "data"):
        super().__init__(buckets=buckets)
        self.index = index
        self.max_k = max_k
        self.mesh = mesh
        self.shard_axis = shard_axis

    def _validate(self, req: LookupRequest):
        assert 1 <= req.k <= self.max_k, (req.k, self.max_k)
        assert req.code.shape == (self.index.n_bits,), req.code.shape

    def _row(self, req: LookupRequest) -> np.ndarray:
        return req.code

    def _run(self, codes: np.ndarray):
        return self.index.search(codes, k=self.max_k, mesh=self.mesh,
                                 shard_axis=self.shard_axis)

    def _retire(self, req: LookupRequest, res, i: int):
        req.scores = res.scores[i, : req.k].copy()
        req.ids = res.ids[i, : req.k].copy()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--m", type=int, default=65536)
    ap.add_argument("--bits", type=int, default=256)
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--k", type=int, default=4)
    ap.add_argument("--backend", default="auto")
    ap.add_argument("--flip", type=int, default=8,
                    help="bits flipped between a planted query and its row")
    ap.add_argument("--metrics", action="store_true",
                    help="print the telemetry registry (Prometheus text) "
                         "after the run")
    args = ap.parse_args()

    rng = np.random.default_rng(0)
    index = CAMIndex(args.bits, config=PPACConfig(),
                     backend=args.backend, min_capacity=args.m)
    # bulk load random codes straight in packed form (bits = 32*W exactly)
    w = index.w
    if args.bits == 32 * w:
        index.add_packed(rng.integers(0, 2**32, (args.m, w), dtype=np.uint64)
                         .astype(np.uint32))
    else:
        index.add(rng.integers(0, 2, (args.m, args.bits)))

    server = RetrievalServer(index, max_k=args.k)
    targets = rng.integers(0, args.m, args.requests)
    from ..core.formats import unpack_bits

    db_bits = np.asarray(unpack_bits(index._codes[targets], args.bits))
    for i in range(args.requests):
        code = db_bits[i].copy()
        flip = rng.choice(args.bits, size=args.flip, replace=False)
        code[flip] ^= 1
        server.submit(LookupRequest(i, code, k=args.k))

    cycles0 = index.counter.cycles
    t0 = time.perf_counter()
    done = server.run()
    dt = time.perf_counter() - t0
    cycles = index.counter.cycles - cycles0

    hits = sum(int(r.ids[0] == targets[r.rid]) for r in done)
    print(f"served {len(done)} lookups in {dt:.2f}s "
          f"({len(done) / dt:.1f} QPS, {server.batches} batches, "
          f"buckets={ {b: c for b, c in server.bucket_counts.items() if c} })")
    print(f"emulated PPAC cycles: {cycles} total, "
          f"{cycles / len(done):.1f}/query")
    print(f"recall@1 vs planted rows ({args.flip}/{args.bits} bits flipped): "
          f"{hits / len(done):.3f}")
    assert hits / len(done) >= 0.99, "planted neighbors must be retrieved"
    if args.metrics:
        print(server.metrics.prometheus_text(), end="")
    print("OK")


if __name__ == "__main__":
    main()
