"""Production training launcher: mesh + sharded step + checkpoint/restart.

Single entry point used by the examples, the FT harness and (with
``--arch``/``--steps`` flags) as a CLI. On the CPU container it runs real
training on reduced configs; on a TPU pod the same code path shards over
the production mesh (the dry-run proves those graphs compile).
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp

from ..checkpoint.ckpt import latest_step, restore, save
from ..configs.base import InputShape, load_arch
from ..data.pipeline import DataConfig, DataIterator
from ..optim.adamw import AdamWConfig
from ..sharding.rules import ShardingRules, fitted_shardings
from ..train.step import TrainConfig, abstract_state, init_state, make_train_step


def train_loop(cfg, tcfg: TrainConfig, *, steps: int, ckpt_dir: Optional[str],
               seq_len: int, global_batch: int, mesh=None,
               rules: Optional[ShardingRules] = None, ckpt_every: int = 50,
               log_every: int = 10, seed: int = 0, log=print):
    """Returns (final_state, losses). Resumes from ckpt_dir if present."""
    shape = InputShape("train", seq_len, global_batch, "train")
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=seq_len,
                      global_batch=global_batch, seed=seed)
    it = DataIterator(dcfg, cfg, shape)

    step_fn = make_train_step(cfg, tcfg, rules=rules, mesh=mesh)
    if mesh is not None and rules is not None:
        _, state_axes = abstract_state(cfg, tcfg)
        state0, _ = init_state(cfg, tcfg, jax.random.PRNGKey(seed))
        shardings = fitted_shardings(mesh, rules.for_mesh(mesh), state_axes,
                                     jax.eval_shape(lambda: state0))
        state = jax.device_put(state0, shardings)
        step_fn = jax.jit(step_fn, in_shardings=(shardings, None),
                          out_shardings=(shardings, None), donate_argnums=0)
    else:
        state, _ = init_state(cfg, tcfg, jax.random.PRNGKey(seed))
        step_fn = jax.jit(step_fn, donate_argnums=0)

    start = 0
    if ckpt_dir:
        last = latest_step(ckpt_dir)
        if last is not None:
            template = jax.eval_shape(lambda: state)
            state, extra = restore(ckpt_dir, last, template)
            it.restore(extra["data_step"])
            start = last
            log(f"[train] resumed from step {last}")

    losses = []
    t0 = time.time()
    for i in range(start, steps):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        state, metrics = step_fn(state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if log_every and (i + 1) % log_every == 0:
            rate = (i + 1 - start) / (time.time() - t0)
            log(f"[train] step {i + 1}/{steps} loss {loss:.4f} "
                f"({rate:.2f} steps/s)")
        if ckpt_dir and (i + 1) % ckpt_every == 0:
            save(ckpt_dir, i + 1, state, extra={"data_step": it.state()})
    if ckpt_dir and steps > start:
        save(ckpt_dir, steps, state, extra={"data_step": it.state()})
    return state, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--qat", action="store_true")
    ap.add_argument("--quant-opt", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    args = ap.parse_args()

    mod = load_arch(args.arch)
    cfg = mod.smoke() if args.smoke else mod.full()
    tcfg = TrainConfig(
        opt=AdamWConfig(lr=args.lr, quantized_state=args.quant_opt),
        qat=args.qat, warmup_steps=max(1, args.steps // 20),
        total_steps=args.steps)
    _, losses = train_loop(cfg, tcfg, steps=args.steps,
                           ckpt_dir=args.ckpt_dir or None,
                           seq_len=args.seq_len,
                           global_batch=args.global_batch,
                           ckpt_every=args.ckpt_every)
    print(f"final loss: {losses[-1]:.4f} (start {losses[0]:.4f})")


if __name__ == "__main__":
    main()
