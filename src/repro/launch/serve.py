"""Batched serving launcher: request queue -> prefill -> batched decode.

A production-shaped (single-host scaled) server loop:
  * requests arrive with different prompt lengths; they are left-padded
    into fixed prefill buckets (compile-count bounded),
  * decode runs as one fused batch step over all live requests,
  * finished requests (EOS/length) retire and their slots are refilled
    from the queue — a simple continuous-batching scheduler,
  * optional PPAC quantized weights / int8 KV via flags; with
    ``--serve-quant`` the decode matmuls run on the fused PPAC kernels
    (packed bitplane weights) and the server reports the emulated PPAC
    cycle cost per decoded token / per decode step (§III-C accounting).

CLI: PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m \
        --requests 12 --max-new 16 [--serve-quant] [--weight-bits 4] \
        [--kv-int8]
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, load_arch
from ..models import lm
from ..serve.step import (
    autotune_serving_plans,
    convert_params_for_serving,
    serving_cycle_report,
)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray
    max_new: int
    out: List[int] = dataclasses.field(default_factory=list)
    done: bool = False


class BatchServer:
    """Slot-based continuous batching over a fixed decode batch."""

    def __init__(self, cfg: ModelConfig, params, *, slots: int = 4,
                 max_seq: int = 128, mode: str = "float"):
        self.cfg, self.params, self.mode = cfg, params, mode
        self.slots = slots
        self.max_seq = max_seq
        self.cache, _ = lm.init_cache(cfg, slots, max_seq)
        self.live: List[Optional[Request]] = [None] * slots
        self.queue: List[Request] = []
        # one jitted decode step reused across the whole run
        self._decode = jax.jit(
            lambda p, t, c: lm.decode_step(p, cfg, t, c, mode=mode))
        self._prefill_len = None

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        """Fill free slots. Single-slot prefill (padded to a bucket) keeps
        the number of compiled prefill shapes bounded."""
        for s in range(self.slots):
            if self.live[s] is None and self.queue:
                req = self.queue.pop(0)
                plen = int(2 ** np.ceil(np.log2(max(8, len(req.prompt)))))
                pad = plen - len(req.prompt)
                toks = np.concatenate(
                    [np.zeros(pad, np.int32), req.prompt]).astype(np.int32)
                c1, _ = lm.init_cache(self.cfg, 1, self.max_seq)
                logits, c1 = lm.prefill(
                    self.params, self.cfg,
                    {"tokens": jnp.asarray(toks[None, :])}, c1,
                    mode=self.mode)
                self.cache = self._merge_cache(c1, s)  # slot write
                tok = int(jnp.argmax(logits[0, -1]))
                req.out.append(tok)
                self.live[s] = req

    def _merge_cache(self, one_cache, s: int):
        def merge(full, one):
            if full.ndim >= 2 and one.ndim == full.ndim \
                    and one.shape[0] == full.shape[0]:
                # layer-stacked leaves: batch is axis 1
                idx = (slice(None), slice(s, s + 1))
                return full.at[idx].set(one)
            return full
        merged = jax.tree.map(merge, self.cache, one_cache)
        # pos: single shared scalar — keep the max (prompts are bucketed)
        merged["pos"] = jnp.maximum(self.cache["pos"], one_cache["pos"])
        return merged

    def step(self):
        """One fused decode step over all slots."""
        toks = np.zeros((self.slots, 1), np.int32)
        for s, req in enumerate(self.live):
            if req is not None and req.out:
                toks[s, 0] = req.out[-1]
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(toks), self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, 0], -1))
        retired = []
        for s, req in enumerate(self.live):
            if req is None:
                continue
            req.out.append(int(nxt[s]))
            if len(req.out) >= req.max_new:
                req.done = True
                retired.append(req)
                self.live[s] = None
        return retired

    def run(self):
        done = []
        while self.queue or any(r is not None for r in self.live):
            self._admit()
            done.extend(self.step())
        return done


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--serve-quant", action="store_true")
    ap.add_argument("--weight-bits", type=int, default=4,
                    choices=(1, 2, 3, 4, 8),
                    help="resident weight precision K for --serve-quant: "
                         "1/2..4 run the fused PPAC kernels, 8 the int8 "
                         "MXU fallback")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--autotune", action="store_true",
                    help="measure + persist tile plans for every packed "
                         "projection shape before serving (refreshes the "
                         "PPAC_TILE_CACHE json; meaningful on TPU)")
    args = ap.parse_args()

    cfg = load_arch(args.arch).smoke()
    if args.kv_int8:
        cfg = dataclasses.replace(cfg, kv_dtype="int8")
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    mode = "float"
    report = None
    if args.serve_quant:
        cfg = dataclasses.replace(
            cfg, ppac=dataclasses.replace(cfg.ppac, enabled=True,
                                          weight_bits=args.weight_bits,
                                          act_bits=8, min_features=32,
                                          backend="auto"))
        params = convert_params_for_serving(params, cfg)
        mode = "serve"
        if args.autotune:
            from ..kernels.tiling import plan_cache
            tuned = autotune_serving_plans(params, cfg, batch=args.slots,
                                           verbose=True)
            print(f"autotuned {len(tuned)} tile plans -> "
                  f"{plan_cache().path}")
        report = serving_cycle_report(params, cfg)
        est = report.est_us_per_token()
        # K/L from the accounting itself: packed1 binarizes activations, so
        # its bit-serial schedule is 1x1 regardless of act_bits.
        kl = sorted({(p.k_bits, p.l_bits) for p in report.projections})
        kl_str = ", ".join(f"K={k}, L={l}" for k, l in kl)
        print(f"PPAC serving: {report.num_projections} quantized projections "
              f"({kl_str}), "
              f"{report.cycles_per_token} emulated cycles/token "
              f"({report.fused_cycles_per_token} on fused kernels); "
              f"per decode step of {args.slots} slots: "
              f"{report.cycles_per_token * args.slots} cycles"
              + (f", est {est:.1f} us/token at the paper's "
                 f"{report.config.m}x{report.config.n} clock"
                 if est is not None else ""))

    rng = np.random.default_rng(0)
    server = BatchServer(cfg, params, slots=args.slots, mode=mode)
    t0 = time.time()
    for i in range(args.requests):
        plen = int(rng.integers(4, 24))
        server.submit(Request(i, rng.integers(0, cfg.vocab, plen),
                              args.max_new))
    completed = server.run()
    dt = time.time() - t0
    toks = sum(len(r.out) for r in completed)
    print(f"served {len(completed)} requests, {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s, slots={args.slots})")
    if report is not None:
        print(f"PPAC compute: {toks * report.cycles_per_token} emulated "
              f"cycles for {toks} decoded tokens "
              f"({report.cycles_per_token}/token)")
    for r in completed[:3]:
        print(f"  req {r.rid}: {r.out[:8]}...")


if __name__ == "__main__":
    main()
