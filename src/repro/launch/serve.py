"""Batched serving launcher (back-compat CLI over launch/serve_lm.py).

The server itself — slot-based continuous batching over a device-resident
donated cache, bucketed right-padded prefill admission, per-sequence
decode positions, fused on-device token selection — lives in
:mod:`repro.launch.serve_lm`; this module keeps the original CLI (with
the PPAC quantization / cycle-accounting / autotune flags) and the
``BatchServer`` name for existing callers.

CLI: PYTHONPATH=src python -m repro.launch.serve --arch smollm_360m \
        --requests 12 --max-new 16 [--serve-quant] [--weight-bits 4] \
        [--kv-int8] [--autotune]
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from ..configs.base import load_arch
from ..models import lm
from ..serve.step import (
    autotune_serving_plans,
    convert_params_for_serving,
    serving_cycle_report,
)
from .serve_lm import LMServer, Request, run_and_report

# Back-compat: the slot-based server moved to serve_lm and grew bucketed
# admission + donated-cache residency; the old name stays importable.
BatchServer = LMServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--serve-quant", action="store_true")
    ap.add_argument("--weight-bits", type=int, default=4,
                    choices=(1, 2, 3, 4, 8),
                    help="resident weight precision K for --serve-quant: "
                         "1/2..4 run the fused PPAC kernels, 8 the int8 "
                         "MXU fallback")
    ap.add_argument("--kv-int8", action="store_true")
    ap.add_argument("--autotune", action="store_true",
                    help="measure + persist tile plans for every packed "
                         "projection shape before serving (refreshes the "
                         "PPAC_TILE_CACHE json; meaningful on TPU)")
    args = ap.parse_args()

    cfg = load_arch(args.arch).smoke()
    if args.kv_int8:
        cfg = dataclasses.replace(cfg, kv_dtype="int8")
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    mode = "float"
    report = None
    if args.serve_quant:
        cfg = dataclasses.replace(
            cfg, ppac=dataclasses.replace(cfg.ppac, enabled=True,
                                          weight_bits=args.weight_bits,
                                          act_bits=8, min_features=32,
                                          backend="auto"))
        params = convert_params_for_serving(params, cfg)
        mode = "serve"
        if args.autotune:
            from ..kernels.tiling import plan_cache
            tuned = autotune_serving_plans(params, cfg, batch=args.slots,
                                           verbose=True)
            print(f"autotuned {len(tuned)} tile plans -> "
                  f"{plan_cache().path}")
        report = serving_cycle_report(params, cfg)
        est = report.est_us_per_token()
        # K/L from the accounting itself: packed1 binarizes activations, so
        # its bit-serial schedule is 1x1 regardless of act_bits.
        kl = sorted({(p.k_bits, p.l_bits) for p in report.projections})
        kl_str = ", ".join(f"K={k}, L={l}" for k, l in kl)
        print(f"PPAC serving: {report.num_projections} quantized projections "
              f"({kl_str}), "
              f"{report.cycles_per_token} emulated cycles/token "
              f"({report.fused_cycles_per_token} on fused kernels); "
              f"per decode step of {args.slots} slots: "
              f"{report.cycles_per_token * args.slots} cycles"
              + (f", est {est:.1f} us/token at the paper's "
                 f"{report.config.m}x{report.config.n} clock"
                 if est is not None else ""))

    rng = np.random.default_rng(0)
    server = LMServer(cfg, params, slots=args.slots, mode=mode)
    run_and_report(
        server,
        [Request(i, rng.integers(0, cfg.vocab, int(rng.integers(4, 24))),
                 args.max_new)
         for i in range(args.requests)],
        report=report)


if __name__ == "__main__":
    main()
