"""HLO-text analyzer: FLOPs / HBM-traffic / collective bytes with correct
while-loop (lax.scan) trip-count multiplication.

Why not ``compiled.cost_analysis()``: XLA's entry-level cost analysis counts
while bodies ONCE (verified empirically: a 10-step scanned matmul reports
the FLOPs of a single matmul), which would understate every scanned-layer
model by ~n_layers. This parser walks the post-optimization, per-partition
HLO module, accumulates per-computation stats, and multiplies through the
call graph using the ``known_trip_count`` backend configs XLA attaches to
scan-derived whiles.

Accounting model (documented in EXPERIMENTS.md §Roofline):
  * flops: dots = 2*prod(result)*prod(contracted lhs dims); convolutions =
    2*prod(result)*(kernel elems per output); elementwise/fusion interior
    ops = 1 flop per output element (minor next to dots).
  * traffic (HBM-byte proxy): for each materializing op, result bytes
    (write) + operand bytes (reads). Aliasing ops (tuple/gte/bitcast/
    parameter/constant) move nothing themselves.
  * collectives: per-device result bytes, scaled by ring factors
    (all-reduce 2(n-1)/n, gather/scatter/all-to-all (n-1)/n, permute 1)
    with n = replica-group size. Shapes in a partitioned module are
    already per-device.
"""
from __future__ import annotations

import dataclasses
import json
import math
import re
from typing import Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s2": 1, "s4": 1, "s8": 1, "u2": 1, "u4": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_NAME_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")


def _split_op_line(line: str):
    """'%n = TYPE opcode(args...), attrs' -> (name, type_s, opcode, args,
    attrs). Handles tuple types with embedded /*index=k*/ comments (which
    contain '=' and spaces) via paren matching."""
    m = _NAME_RE.match(line)
    if not m:
        return None
    name, rest = m.groups()
    if rest.startswith("("):
        depth, end = 0, -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        type_s, tail = rest[: end + 1], rest[end + 1:]
    else:
        sp = rest.find(" ")
        if sp < 0:
            return None
        type_s, tail = rest[:sp], rest[sp:]
    m2 = re.match(r"\s*([\w\-]+)\(", tail)
    if not m2:
        return None
    opcode = m2.group(1)
    body = tail[m2.end():]
    depth, end = 1, len(body)
    for i, ch in enumerate(body):
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    args, attrs = body[:end], body[end + 1:]
    return name, type_s, opcode, args, attrs


def _parse_type(s: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """'f32[128,64]{1,0}' or '(f32[..], s32[..])' -> [(dtype, shape), ...]"""
    out = []
    for m in _SHAPE_RE.finditer(s):
        dt, dims = m.group(1), m.group(2)
        if dt not in DTYPE_BYTES:
            continue
        shape = tuple(int(x) for x in dims.split(",")) if dims else ()
        out.append((dt, shape))
    return out


def _nbytes(types) -> int:
    return sum(DTYPE_BYTES[dt] * max(1, math.prod(sh)) for dt, sh in types)


def _nelems(types) -> int:
    return sum(max(1, math.prod(sh)) for _, sh in types)


@dataclasses.dataclass
class Op:
    name: str
    types: list           # [(dtype, shape)]
    opcode: str
    operands: List[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    params: Dict[str, list]
    ops: List[Op]
    is_entry: bool = False


ALIAS_OPS = {"tuple", "get-tuple-element", "bitcast", "parameter", "constant",
             "partition-id", "replica-id", "after-all", "custom-call"}
COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute", "all-reduce-start", "all-gather-start",
               "collective-permute-start", "ragged-all-to-all"}


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line:
            continue
        if cur is None:
            m = _COMP_RE.match(line)
            if m and line.endswith("{"):
                params = {}
                header = m.group(2)
                marks = [(pm.start(), pm.group(1))
                         for pm in re.finditer(r"([\w.\-]+):", header)]
                for idx, (pos, nm) in enumerate(marks):
                    end = marks[idx + 1][0] if idx + 1 < len(marks) \
                        else len(header)
                    params[nm] = _parse_type(header[pos:end])
                cur = Computation(m.group(1), params, [],
                                  is_entry=line.startswith("ENTRY"))
                comps[cur.name] = cur
            continue
        if line == "}" or line.startswith("}"):
            cur = None
            continue
        parsed = _split_op_line(line)
        if not parsed:
            continue
        name, type_s, opcode, args, _attrs = parsed
        operands = re.findall(r"%([\w.\-]+)", args)
        cur.ops.append(Op(name, _parse_type(type_s), opcode, operands, line))
    return comps


def _dot_flops(op: Op, symtab) -> float:
    res_elems = _nelems(op.types)
    lhs = symtab.get(op.operands[0]) if op.operands else None
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.line)
    contracted = 1
    if lhs and m and m.group(1):
        dims = [int(x) for x in m.group(1).split(",")]
        shape = lhs[0][1]
        for d in dims:
            if d < len(shape):
                contracted *= shape[d]
    return 2.0 * res_elems * contracted


def _conv_flops(op: Op, symtab) -> float:
    res_elems = _nelems(op.types)
    rhs = symtab.get(op.operands[1]) if len(op.operands) > 1 else None
    if not rhs:
        return 2.0 * res_elems
    kshape = rhs[0][1]
    kelems = max(1, math.prod(kshape))
    out_feat = op.types[0][1][-1] if op.types and op.types[0][1] else 1
    return 2.0 * res_elems * max(1, kelems // max(1, out_feat))




def _fusion_traffic(op: Op, symtab) -> float:
    """Boundary traffic of a fusion, with in-place-update awareness.

    A fused dynamic_update_slice aliases the big buffer (XLA updates in
    place); charging operand+result would bill the whole KV cache twice
    per layer per step. Detect via the op_name metadata and charge only
    the update (smallest tensor operand); fused dynamic_slice is charged
    by its result (the slice), not the sliced operand.
    """
    mname = re.search(r'op_name="([^"]+)"', op.line)
    name = mname.group(1) if mname else ""
    if name.endswith("dynamic_update_slice"):
        sizes = [b for b in (_nbytes(symtab.get(o, [])) for o in op.operands)
                 if b > 4]
        return 2.0 * min(sizes) if sizes else _nbytes(op.types)
    if name.endswith("dynamic_slice"):
        return 2.0 * _nbytes(op.types)
    t = _nbytes(op.types)
    for o in op.operands:
        t += _nbytes(symtab.get(o, []))
    return t

def _op_traffic(op: Op, symtab) -> float:
    """HBM-traffic contribution of one op (TPU-target accounting).

    * slicing ops touch only the slice; updates alias the remainder;
    * `convert` is excluded: the CPU backend legalizes every bf16 dot by
      inserting f32 converts around it (889 converts in a 32-layer
      module, ~4.4 TiB phantom traffic); on the TPU target the MXU
      consumes bf16 directly and materialized converts fuse into their
      producers. Documented in EXPERIMENTS.md §Roofline methodology.
    """
    oc = op.opcode
    if oc == "convert":
        return 0.0
    if oc in ("dynamic-slice", "slice", "gather"):
        return 2.0 * _nbytes(op.types)  # read slice + write result
    if oc in ("dynamic-update-slice", "scatter"):
        upd = op.operands[1] if len(op.operands) > 1 else None
        return 2.0 * _nbytes(symtab.get(upd, op.types))
    t = _nbytes(op.types)
    for o in op.operands:
        t += _nbytes(symtab.get(o, []))
    return t

@dataclasses.dataclass
class Stats:
    flops: float = 0.0
    traffic: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)

    def add(self, other: "Stats", mult: float = 1.0):
        self.flops += other.flops * mult
        self.traffic += other.traffic * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult

    @property
    def collective_total(self) -> float:
        return sum(self.coll_bytes.values())


def _group_size(line: str) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", line)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([\d,]+)\}", line)
    if m:
        return len(m.group(1).split(","))
    return 2


_RING = {"all-reduce": lambda n: 2.0 * (n - 1) / n,
         "all-reduce-start": lambda n: 2.0 * (n - 1) / n,
         "all-gather": lambda n: (n - 1) / n,
         "all-gather-start": lambda n: (n - 1) / n,
         "reduce-scatter": lambda n: (n - 1) / n,
         "all-to-all": lambda n: (n - 1) / n,
         "ragged-all-to-all": lambda n: (n - 1) / n,
         "collective-permute": lambda n: 1.0,
         "collective-permute-start": lambda n: 1.0}


def analyze(text: str) -> Stats:
    comps = parse_module(text)
    entry = next(c for c in comps.values() if c.is_entry)
    memo: Dict[str, Stats] = {}

    def comp_stats(comp: Computation) -> Stats:
        if comp.name in memo:
            return memo[comp.name]
        st = Stats()
        symtab = dict(comp.params)
        for op in comp.ops:
            symtab[op.name] = op.types
        for op in comp.ops:
            oc = op.opcode
            if oc in ALIAS_OPS:
                # custom-call may still move data; count result bytes
                if oc == "custom-call":
                    st.traffic += _nbytes(op.types)
                continue
            if oc in COLLECTIVES:
                n = _group_size(op.line)
                factor = _RING.get(oc, lambda n: 1.0)(n)
                # XLA-CPU promotes bf16 reductions to f32 (to_apply=
                # %add..._promoted) because the CPU lacks bf16 arithmetic;
                # TPU reduces in bf16 — count the unpromoted width.
                if re.search(r"to_apply=%[\w.\-]*promoted", op.line) \
                        and op.types and op.types[0][0] == "f32":
                    factor *= 0.5
                b = _nbytes(op.types) * factor
                key = oc.replace("-start", "")
                st.coll_bytes[key] = st.coll_bytes.get(key, 0.0) + b
                st.traffic += _nbytes(op.types)
                continue
            if oc in ("all-reduce-done", "all-gather-done",
                      "collective-permute-done"):
                continue
            if oc == "while":
                trip = 1
                m = re.search(r'known_trip_count[":{\s]+n["\s:]+(\d+)', op.line)
                if m:
                    trip = int(m.group(1))
                mc = re.search(r"condition=%([\w.\-]+), body=%([\w.\-]+)",
                               op.line)
                if mc:
                    st.add(comp_stats(comps[mc.group(1)]), trip)
                    st.add(comp_stats(comps[mc.group(2)]), trip)
                continue
            if oc in ("call", "fusion"):
                mcall = re.search(r"(?:calls|to_apply)=%([\w.\-]+)", op.line)
                inner = Stats()
                if mcall and mcall.group(1) in comps:
                    inner = comp_stats(comps[mcall.group(1)])
                # fusion interior flops count; interior traffic does NOT
                # (stays in registers/VMEM) — boundary bytes below.
                st.flops += inner.flops
                for k, v in inner.coll_bytes.items():
                    st.coll_bytes[k] = st.coll_bytes.get(k, 0.0) + v
                st.traffic += _fusion_traffic(op, symtab)
                continue
            if oc == "conditional":
                for mm in re.finditer(r"(?:true_computation|false_computation|"
                                      r"branch_computations)=\{?%([\w.\-]+)",
                                      op.line):
                    st.add(comp_stats(comps[mm.group(1)]), 1.0)
                continue
            # ordinary op
            if oc == "dot":
                st.flops += _dot_flops(op, symtab)
            elif oc == "convolution":
                st.flops += _conv_flops(op, symtab)
            elif oc in ("copy", "copy-start", "copy-done", "reshape",
                        "transpose", "broadcast", "slice", "dynamic-slice",
                        "dynamic-update-slice", "concatenate", "pad", "iota",
                        "gather", "scatter", "reverse", "reduce-window"):
                pass  # data movement only (no flops)
            else:
                st.flops += _nelems(op.types)  # 1 flop / output element
            st.traffic += _op_traffic(op, symtab)
        memo[comp.name] = st
        return st

    # Only accumulate from ENTRY through the call graph (fusion computations
    # reached via calls are not double counted because we never iterate them
    # at top level).
    return comp_stats(entry)


def breakdown(text: str, top: int = 25):
    """Per-op-name cost attribution (flops/traffic, trip-multiplied).

    Groups by the jax op_name metadata so 'while/body/.../dot_general'
    sites aggregate across layers — the profile view used for §Perf
    hypothesis forming."""
    comps = parse_module(text)
    entry = next(c for c in comps.values() if c.is_entry)
    agg: Dict[str, list] = {}

    def visit(comp: Computation, mult: float, flops_only: bool = False):
        symtab = dict(comp.params)
        for op in comp.ops:
            symtab[op.name] = op.types
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                trip = 1
                m = re.search(r'known_trip_count[":{\s]+n["\s:]+(\d+)', op.line)
                if m:
                    trip = int(m.group(1))
                mc = re.search(r"condition=%([\w.\-]+), body=%([\w.\-]+)",
                               op.line)
                if mc:
                    visit(comps[mc.group(1)], mult * trip, flops_only)
                    visit(comps[mc.group(2)], mult * trip, flops_only)
                continue
            if oc in ("call", "fusion"):
                # fusion interiors contribute FLOPs; traffic is the
                # fusion boundary (same accounting as analyze())
                mcall = re.search(r"(?:calls|to_apply)=%([\w.\-]+)", op.line)
                if mcall and mcall.group(1) in comps:
                    visit(comps[mcall.group(1)], mult, True)
                if not flops_only:
                    traffic = _fusion_traffic(op, symtab)
                    mname = re.search(r'op_name="([^"]+)"', op.line)
                    key = (mname.group(1) if mname else oc)
                    a = agg.setdefault(key, [0.0, 0.0, oc])
                    a[1] += traffic * mult
                continue
            if oc in ALIAS_OPS:
                continue
            flops = 0.0
            if oc == "dot":
                flops = _dot_flops(op, symtab)
            elif oc == "convolution":
                flops = _conv_flops(op, symtab)
            traffic = 0.0 if flops_only else _op_traffic(op, symtab)
            if flops == 0.0 and traffic == 0.0:
                continue
            mname = re.search(r'op_name="([^"]+)"', op.line)
            key = (mname.group(1) if mname else oc)
            a = agg.setdefault(key, [0.0, 0.0, oc])
            a[0] += flops * mult
            a[1] += traffic * mult

    visit(entry, 1.0)
    rows = sorted(((v[0], v[1], v[2], k) for k, v in agg.items()),
                  reverse=True)
    return rows[:top]


def analysis_dict(text: str) -> dict:
    st = analyze(text)
    return {"flops": st.flops, "traffic_bytes": st.traffic,
            "collective_bytes": st.coll_bytes,
            "collective_total": st.collective_total}


if __name__ == "__main__":
    import sys

    with open(sys.argv[1]) as f:
        print(json.dumps(analysis_dict(f.read()), indent=2))
