"""Sharding rules, spec fitting, and a real multi-device lowering (subprocess
with 8 placeholder CPU devices so the main test process keeps 1 device)."""
import subprocess
import sys
import textwrap

import jax
import pytest
from conftest import cpu_subproc_env
from jax.sharding import PartitionSpec as P

from repro.sharding.rules import ShardingRules, default_rules, fit_spec


class FakeMesh:
    def __init__(self, shape):
        self._shape = shape

    @property
    def shape(self):
        return self._shape

    @property
    def axis_names(self):
        return tuple(self._shape)


def test_rules_spec():
    r = default_rules()
    assert r.spec(("embed", "mlp")) == P(None, "model")
    assert r.spec(("batch", None, None)) == P(("pod", "data"), None, None)
    assert r.spec(None) == P()


def test_for_mesh_drops_missing_axes():
    r = default_rules().for_mesh(FakeMesh({"data": 16, "model": 16}))
    assert r.spec(("batch",)) == P("data")
    assert r.spec(("expert",)) == P("model")


def test_fit_spec_divisibility():
    mesh = FakeMesh({"data": 16, "model": 16})
    # 50280 % 16 != 0 -> dropped; 1024 % 16 == 0 -> kept
    s = fit_spec(mesh, P("model", None), (50280, 1024))
    assert s == P(None, None)
    s = fit_spec(mesh, P("model", None), (1024, 50280))
    assert s == P("model", None)
    # tuple axes: ('pod' absent is caller's business) data*model = 256
    s = fit_spec(mesh, P(("data", "model"),), (512,))
    assert s == P(("data", "model"))
    s = fit_spec(mesh, P(("data", "model"),), (100,))
    assert s == P(None)


def test_fit_spec_deduplicates_mesh_axes():
    mesh = FakeMesh({"data": 4, "model": 4})
    s = fit_spec(mesh, P("data", "data"), (8, 8))
    assert s == P("data", None)


def test_overrides():
    r = default_rules(embed="data")
    assert r.spec(("embed", "mlp")) == P("data", "model")


SUBPROC = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    from repro.configs import load_arch
    from repro.configs.base import InputShape
    from repro.launch.mesh import make_test_mesh
    from repro.launch.specs import build_cell
    from repro.train.step import TrainConfig

    cfg = load_arch("smollm_360m").smoke()
    mesh = make_test_mesh(2, 2, pod=2)
    shape = InputShape("t", 32, 8, "train")
    with mesh:
        cell = build_cell(cfg, shape, mesh, tcfg=TrainConfig())
        compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings).lower(
            *cell.args).compile()
    txt = compiled.as_text()
    assert any(k in txt for k in ("all-reduce", "all-gather")), "no collectives?"
    print("MULTIDEV_OK", compiled.memory_analysis().temp_size_in_bytes)
""")


def test_multidevice_train_lowering():
    res = subprocess.run([sys.executable, "-c", SUBPROC], capture_output=True,
                         text=True, timeout=600, env=cpu_subproc_env())
    assert "MULTIDEV_OK" in res.stdout, res.stdout + res.stderr


SUBPROC_COMPRESS = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.configs import load_arch
    from repro.configs.base import InputShape
    from repro.launch.mesh import make_test_mesh
    from repro.launch.specs import build_cell
    from repro.train.step import TrainConfig

    cfg = load_arch("smollm_360m").smoke()
    mesh = make_test_mesh(2, 2, pod=2)
    shape = InputShape("t", 32, 8, "train")
    tcfg = TrainConfig(cross_pod_grad_dtype="bfloat16")
    with mesh:
        cell = build_cell(cfg, shape, mesh, tcfg=tcfg)
        jaxpr = jax.make_jaxpr(cell.fn)(*cell.args)
    txt = str(jaxpr)
    # the cross-pod gradient psum must consume bf16 operands.
    # NOTE: we validate at jaxpr level — XLA's *CPU* backend crashes with
    # "Invalid binary instruction opcode copy" on any partial-manual
    # shard_map psum (fp32 too; minimal repro in EXPERIMENTS.md §Perf),
    # so the compiled check is TPU-only.
    import re
    assert "psum" in txt, "no psum in compressed train step"
    assert re.search(r"convert_element_type.*bf16", txt) or "bf16" in txt
    print("COMPRESS_OK")
""")


def test_cross_pod_grad_compression_traces_bf16_psum():
    res = subprocess.run([sys.executable, "-c", SUBPROC_COMPRESS],
                         capture_output=True, text=True, timeout=600,
                         env=cpu_subproc_env())
    assert "COMPRESS_OK" in res.stdout, res.stdout + res.stderr


SUBPROC_DECODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import dataclasses
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import load_arch
    from repro.launch.mesh import make_test_mesh
    from repro.models import lm
    from repro.serve.step import make_decode_step
    from repro.sharding.rules import default_rules

    # int8 KV exercises the grouped _decode_attend_q8 einsums — the path
    # that accepted `rules` but never applied a sharding constraint.
    cfg = dataclasses.replace(load_arch("stablelm_12b").smoke(),
                              dtype="float32", kv_dtype="int8")
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 8)), jnp.int32)
    tok = jnp.ones((2, 1), jnp.int32)

    cache, _ = lm.init_cache(cfg, 2, 32)
    logits, cache = lm.prefill(params, cfg, {"tokens": tokens}, cache)
    ref, _ = lm.decode_step(params, cfg, tok, cache)

    mesh = make_test_mesh(1, 2)  # pure TP: 2-way 'model'
    rules = default_rules().for_mesh(mesh)
    with mesh:
        cache2, _ = lm.init_cache(cfg, 2, 32)
        _, cache2 = lm.prefill(params, cfg, {"tokens": tokens}, cache2,
                               rules=rules)
        dec = make_decode_step(cfg, rules=rules, donate=False)
        txt = dec.lower(params, tok, cache2).as_text()
        # the q8 decode einsums must be constrained (satellite fix):
        # constraints lower to Sharding custom-calls in the StableHLO
        assert txt.count("@Sharding") >= 4, txt.count("@Sharding")
        got, _ = dec(params, tok, cache2)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(got),
                               rtol=2e-5, atol=2e-5)
    print("DECODE_SHARDED_OK")
""")


def test_sharded_decode_parity_and_constraints():
    """Decode under 2-way tensor parallelism matches the single-device
    step, and the quantized-cache attention actually emits its sharding
    constraints (it used to accept `rules` and drop them)."""
    res = subprocess.run([sys.executable, "-c", SUBPROC_DECODE],
                         capture_output=True, text=True, timeout=600,
                         env=cpu_subproc_env())
    assert "DECODE_SHARDED_OK" in res.stdout, res.stdout + res.stderr
