"""serve_dense exact-parity matrix: container kind × backend × odd shapes.

The acceptance property of the unified kernel engine: packed containers
execute on the fused tiled PPAC kernels with bit-identical results across
'pallas'/'ref'/'mxu' (integer accumulation is exact, so even the float
outputs must agree bitwise), and the raw accumulations match the
cycle-exact ``PPACArray`` oracle for small cases.

The zero-repack fast path rides the same matrix: grouped (wqkv/wig-style)
containers and the in-kernel-sliced resident mode must stay bit-identical
to the per-projection path on int32 accumulators, offset formats must
serve off their load-time resident mask plane, and the lowered HLO of a
packed serving call must contain no weight-side concatenation/broadcast.
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (
    pack_weight_for_serving,
    serve_dense,
    serve_dense_acc,
    serve_dense_grouped,
)
from repro.core.formats import from_bitplanes, unpack_bits
from repro.core.ppac import PPACArray, PPACConfig
from repro.core.quant import binarize_pm1, quantize

BACKENDS = ("pallas", "ref", "mxu")
KINDS = [(16, "bf16"), (8, "int8"), (4, "packed4"), (1, "packed1")]
# deliberately not tile multiples (sublane 8 / lane 128 / word 32)
SHAPES = [(96, 200), (100, 130)]


@pytest.mark.parametrize("d_in,d_out", SHAPES)
@pytest.mark.parametrize("bits,kind", KINDS)
def test_serve_dense_bit_identical_across_backends(rng, d_in, d_out, bits,
                                                   kind):
    w = jnp.asarray(rng.standard_normal((d_in, d_out)), jnp.float32) * 0.1
    x = jnp.asarray(rng.standard_normal((5, d_in)), jnp.float32)
    c = pack_weight_for_serving(w, weight_bits=bits)
    assert c.kind == kind
    assert c.n_in == d_in
    outs = [np.asarray(serve_dense(x, c, act_bits=6, backend=b))
            for b in BACKENDS]
    assert np.array_equal(outs[0], outs[1]), kind
    assert np.array_equal(outs[1], outs[2]), kind


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_packed_acc_matches_ppac_oracle_multibit(rng, bits):
    """packed4 accumulations == the cycle-exact array's K·L-cycle MVP."""
    d_in, d_out, b, l_bits = 40, 24, 3, 5
    w = jnp.asarray(rng.standard_normal((d_in, d_out)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((b, d_in)), jnp.float32)
    c = pack_weight_for_serving(w, weight_bits=bits)

    # reconstruct the resident integer matrix from the packed planes
    a_bits = unpack_bits(c.wq, d_in)               # [K, out, in]
    a_int = np.asarray(from_bitplanes(a_bits, c.fmt))

    xq, _ = quantize(x, l_bits, "int", axis=-1)
    x_int = np.asarray(xq, np.int64).astype(np.int32)

    arr = PPACArray(PPACConfig(m=d_out, n=d_in))
    oracle = np.stack([
        np.asarray(arr.mvp_multibit(a_int, x_int[i], bits, l_bits,
                                    "int", "int"))
        for i in range(b)])

    for backend in BACKENDS:
        acc, _ = serve_dense_acc(x, c, act_bits=l_bits, backend=backend)
        assert np.array_equal(np.asarray(acc), oracle), backend


def test_packed_acc_matches_ppac_oracle_1bit(rng):
    """packed1 accumulations == the array's ±1 XNOR MVP (eq. 1)."""
    d_in, d_out, b = 48, 32, 4
    w = jnp.asarray(rng.standard_normal((d_in, d_out)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((b, d_in)), jnp.float32)
    c = pack_weight_for_serving(w, weight_bits=1)

    a_bits = np.asarray(unpack_bits(c.wq, d_in))   # [out, in] logical levels
    xq, _ = binarize_pm1(x, axis=-1)
    x_bits = np.asarray((xq + 1) / 2, np.uint8)

    arr = PPACArray(PPACConfig(m=d_out, n=d_in))
    arr.write(a_bits)
    oracle = np.stack([
        np.asarray(arr.mvp_1bit(x_bits[i], "pm1", "pm1")) for i in range(b)])

    for backend in BACKENDS:
        acc, _ = serve_dense_acc(x, c, act_bits=1, backend=backend)
        assert np.array_equal(np.asarray(acc), oracle), backend


def test_packed4_acc_equals_exact_integer_product(rng):
    """The fused path IS the integer matmul — no approximation beyond
    quantization itself."""
    d_in, d_out = 96, 200
    w = jnp.asarray(rng.standard_normal((d_in, d_out)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((6, d_in)), jnp.float32)
    c = pack_weight_for_serving(w, weight_bits=4)
    a_int = np.asarray(from_bitplanes(unpack_bits(c.wq, d_in), c.fmt))
    xq, _ = quantize(x, 6, "int", axis=-1)
    x_int = np.asarray(xq).astype(np.int64)
    acc, _ = serve_dense_acc(x, c, act_bits=6, backend="ref")
    assert np.array_equal(np.asarray(acc), x_int @ a_int.T.astype(np.int64))


# -- the zero-repack fast path -------------------------------------------------

@pytest.mark.parametrize("bits", [3, 4])
@pytest.mark.parametrize("backend", BACKENDS)
def test_oddint_weights_serve_off_resident_mask_plane(rng, bits, backend):
    """Offset formats pack their all-ones mask plane at load time (K+1
    resident planes) and stay exact + backend-identical at serve time."""
    d_in, d_out = 51, 40  # odd n: the mask plane's padding bits matter
    w = jnp.asarray(rng.standard_normal((d_in, d_out)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((5, d_in)), jnp.float32)
    c = pack_weight_for_serving(w, weight_bits=bits, weight_format="oddint")
    assert c.kind == "packed4" and c.wq.shape == (bits + 1, d_out, 2)
    # reconstruct the resident integers from the value planes only
    a_int = np.asarray(from_bitplanes(unpack_bits(c.wq[:bits], d_in), c.fmt),
                       np.int64)
    xq, _ = quantize(x, 5, "int", axis=-1)
    acc, _ = serve_dense_acc(x, c, act_bits=5, backend=backend)
    want = np.asarray(xq, np.int64).astype(np.int64) @ a_int.T
    assert np.array_equal(np.asarray(acc), want)


@pytest.mark.parametrize("d_in,outs", [(96, (56, 24, 24)), (100, (30, 30))])
@pytest.mark.parametrize("bits,kind", KINDS)
def test_grouped_container_bit_identical_to_per_projection(rng, d_in, outs,
                                                           bits, kind):
    """A fused projection group == the member projections, bitwise, for
    every container kind × backend (per-output-channel quantization makes
    the column-stacked resident container exactly the concatenation)."""
    ws = [jnp.asarray(rng.standard_normal((d_in, o)), jnp.float32) * 0.1
          for o in outs]
    x = jnp.asarray(rng.standard_normal((5, d_in)), jnp.float32)
    cg = pack_weight_for_serving(jnp.concatenate(ws, axis=-1),
                                 weight_bits=bits, splits=outs)
    assert cg.kind == kind and cg.splits == tuple(outs)
    singles = [pack_weight_for_serving(w, weight_bits=bits) for w in ws]
    for backend in BACKENDS:
        got = serve_dense_grouped(x, cg, act_bits=6, backend=backend)
        assert len(got) == len(outs)
        for g, c in zip(got, singles):
            want = serve_dense(x, c, act_bits=6, backend=backend)
            assert np.array_equal(np.asarray(g), np.asarray(want)), backend


@pytest.mark.parametrize("bits", [1, 4])
def test_grouped_acc_int32_identical_across_backends(rng, bits):
    """Raw int32 accumulators of a grouped container agree bitwise across
    backends and equal the column-concat of the member accumulators."""
    d_in, outs = 77, (40, 24)
    ws = [jnp.asarray(rng.standard_normal((d_in, o)), jnp.float32)
          for o in outs]
    x = jnp.asarray(rng.standard_normal((3, d_in)), jnp.float32)
    cg = pack_weight_for_serving(jnp.concatenate(ws, axis=-1),
                                 weight_bits=bits, splits=outs)
    accs = []
    for backend in BACKENDS:
        acc, _ = serve_dense_acc(x, cg, act_bits=6, backend=backend)
        assert acc.dtype == jnp.int32
        accs.append(np.asarray(acc))
    assert np.array_equal(accs[0], accs[1])
    assert np.array_equal(accs[1], accs[2])
    member = [np.asarray(serve_dense_acc(
        x, pack_weight_for_serving(w, weight_bits=bits), act_bits=6,
        backend="ref")[0]) for w in ws]
    assert np.array_equal(accs[0], np.concatenate(member, axis=-1))


def _broadcast_result_elems(hlo_text):
    """Element counts of every broadcast result in a StableHLO module."""
    out = []
    for m in re.finditer(
            r"broadcast_in_dim.*?->\s*tensor<([0-9x]+)x[a-z]", hlo_text):
        dims = [int(d) for d in m.group(1).split("x") if d]
        out.append(int(np.prod(dims)) if dims else 1)
    return out


@pytest.mark.parametrize("bits", [1, 4])
@pytest.mark.parametrize("backend", ["pallas", "mxu"])
def test_packed_serving_hlo_has_no_weight_repack(rng, bits, backend):
    """The zero-repack invariant, asserted on the lowered HLO: a packed
    serving call contains NO concatenate and no broadcast materializing a
    weight-sized (or larger) tensor. The pre-PR path fails both ways
    (mask-plane concat onto [K, M, W]; per-call unpack broadcasting the
    resident planes to [K, M, n, 32] on the MXU lowering)."""
    d_in, d_out = 96, 200
    w = jnp.asarray(rng.standard_normal((d_in, d_out)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, d_in)), jnp.float32)
    c = pack_weight_for_serving(w, weight_bits=bits, store_shadow=True)

    def f(x, c):
        return serve_dense_acc(x, c, act_bits=8, backend=backend)[0]

    txt = jax.jit(f).lower(x, c).as_text()
    assert "concatenate" not in txt
    weight_elems = d_in * d_out
    too_big = [e for e in _broadcast_result_elems(txt) if e >= weight_elems]
    assert not too_big, too_big


def test_prepack_mxu_path_does_repack(rng):
    """Sanity for the assertion above: the legacy shadow-less container
    really does broadcast weight-sized tensors per call (what the fast
    path removed)."""
    d_in, d_out = 96, 200
    w = jnp.asarray(rng.standard_normal((d_in, d_out)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, d_in)), jnp.float32)
    c = pack_weight_for_serving(w, weight_bits=4, store_shadow=False)
    txt = jax.jit(
        lambda x, c: serve_dense_acc(x, c, act_bits=8, backend="mxu")[0]
    ).lower(x, c).as_text()
    assert any(e >= d_in * d_out for e in _broadcast_result_elems(txt))
