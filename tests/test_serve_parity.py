"""serve_dense exact-parity matrix: container kind × backend × odd shapes.

The acceptance property of the unified kernel engine: packed containers
execute on the fused tiled PPAC kernels with bit-identical results across
'pallas'/'ref'/'mxu' (integer accumulation is exact, so even the float
outputs must agree bitwise), and the raw accumulations match the
cycle-exact ``PPACArray`` oracle for small cases.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import (
    pack_weight_for_serving,
    serve_dense,
    serve_dense_acc,
)
from repro.core.formats import from_bitplanes, unpack_bits
from repro.core.ppac import PPACArray, PPACConfig
from repro.core.quant import binarize_pm1, quantize

BACKENDS = ("pallas", "ref", "mxu")
KINDS = [(16, "bf16"), (8, "int8"), (4, "packed4"), (1, "packed1")]
# deliberately not tile multiples (sublane 8 / lane 128 / word 32)
SHAPES = [(96, 200), (100, 130)]


@pytest.mark.parametrize("d_in,d_out", SHAPES)
@pytest.mark.parametrize("bits,kind", KINDS)
def test_serve_dense_bit_identical_across_backends(rng, d_in, d_out, bits,
                                                   kind):
    w = jnp.asarray(rng.standard_normal((d_in, d_out)), jnp.float32) * 0.1
    x = jnp.asarray(rng.standard_normal((5, d_in)), jnp.float32)
    c = pack_weight_for_serving(w, weight_bits=bits)
    assert c.kind == kind
    assert c.n_in == d_in
    outs = [np.asarray(serve_dense(x, c, act_bits=6, backend=b))
            for b in BACKENDS]
    assert np.array_equal(outs[0], outs[1]), kind
    assert np.array_equal(outs[1], outs[2]), kind


@pytest.mark.parametrize("bits", [2, 3, 4])
def test_packed_acc_matches_ppac_oracle_multibit(rng, bits):
    """packed4 accumulations == the cycle-exact array's K·L-cycle MVP."""
    d_in, d_out, b, l_bits = 40, 24, 3, 5
    w = jnp.asarray(rng.standard_normal((d_in, d_out)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((b, d_in)), jnp.float32)
    c = pack_weight_for_serving(w, weight_bits=bits)

    # reconstruct the resident integer matrix from the packed planes
    a_bits = unpack_bits(c.wq, d_in)               # [K, out, in]
    a_int = np.asarray(from_bitplanes(a_bits, c.fmt))

    xq, _ = quantize(x, l_bits, "int", axis=-1)
    x_int = np.asarray(xq, np.int64).astype(np.int32)

    arr = PPACArray(PPACConfig(m=d_out, n=d_in))
    oracle = np.stack([
        np.asarray(arr.mvp_multibit(a_int, x_int[i], bits, l_bits,
                                    "int", "int"))
        for i in range(b)])

    for backend in BACKENDS:
        acc, _ = serve_dense_acc(x, c, act_bits=l_bits, backend=backend)
        assert np.array_equal(np.asarray(acc), oracle), backend


def test_packed_acc_matches_ppac_oracle_1bit(rng):
    """packed1 accumulations == the array's ±1 XNOR MVP (eq. 1)."""
    d_in, d_out, b = 48, 32, 4
    w = jnp.asarray(rng.standard_normal((d_in, d_out)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((b, d_in)), jnp.float32)
    c = pack_weight_for_serving(w, weight_bits=1)

    a_bits = np.asarray(unpack_bits(c.wq, d_in))   # [out, in] logical levels
    xq, _ = binarize_pm1(x, axis=-1)
    x_bits = np.asarray((xq + 1) / 2, np.uint8)

    arr = PPACArray(PPACConfig(m=d_out, n=d_in))
    arr.write(a_bits)
    oracle = np.stack([
        np.asarray(arr.mvp_1bit(x_bits[i], "pm1", "pm1")) for i in range(b)])

    for backend in BACKENDS:
        acc, _ = serve_dense_acc(x, c, act_bits=1, backend=backend)
        assert np.array_equal(np.asarray(acc), oracle), backend


def test_packed4_acc_equals_exact_integer_product(rng):
    """The fused path IS the integer matmul — no approximation beyond
    quantization itself."""
    d_in, d_out = 96, 200
    w = jnp.asarray(rng.standard_normal((d_in, d_out)), jnp.float32)
    x = jnp.asarray(rng.standard_normal((6, d_in)), jnp.float32)
    c = pack_weight_for_serving(w, weight_bits=4)
    a_int = np.asarray(from_bitplanes(unpack_bits(c.wq, d_in), c.fmt))
    xq, _ = quantize(x, 6, "int", axis=-1)
    x_int = np.asarray(xq).astype(np.int64)
    acc, _ = serve_dense_acc(x, c, act_bits=6, backend="ref")
    assert np.array_equal(np.asarray(acc), x_int @ a_int.T.astype(np.int64))
