"""Number formats + bitplane codecs (paper Table I)."""
import os

import numpy as np
import pytest
from conftest import HAVE_HYPOTHESIS, given, settings, st  # hypothesis or fallback

from repro.core import formats as F


@pytest.mark.parametrize("fmt", ["uint", "int", "oddint"])
@pytest.mark.parametrize("bits", [1, 2, 3, 4, 6, 8])
def test_roundtrip(fmt, bits, rng):
    lo, hi = F.value_range(fmt, bits)
    step = 2 if fmt == "oddint" else 1
    vals = np.arange(lo, hi + 1, step)
    planes = F.to_bitplanes(vals, bits, fmt)
    back = np.asarray(F.from_bitplanes(planes, fmt))
    assert np.array_equal(back, vals)


def test_table1_ranges():
    # Table I of the paper, L=2 column
    assert F.value_range("uint", 2) == (0, 3)
    assert F.value_range("int", 2) == (-2, 1)
    assert F.value_range("oddint", 2) == (-3, 3)


def test_oddint_only_odd():
    ok = np.asarray(F.representable("oddint", 3, np.arange(-7, 8)))
    vals = np.arange(-7, 8)
    assert np.array_equal(vals[ok], np.arange(-7, 8, 2))


@pytest.mark.parametrize("n", [1, 31, 32, 33, 64, 100, 256])
def test_pack_unpack(n, rng):
    bits = rng.integers(0, 2, size=(3, n))
    packed = F.pack_bits(bits)
    assert packed.shape == (3, F.packed_width(n))
    assert np.array_equal(np.asarray(F.unpack_bits(packed, n)), bits)


@given(st.integers(1, 8), st.integers(1, 80), st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_roundtrip_hypothesis(bits, n, seed):
    rng = np.random.default_rng(seed)
    for fmt in ("uint", "int", "oddint"):
        lo, hi = F.value_range(fmt, bits)
        step = 2 if fmt == "oddint" else 1
        vals = rng.choice(np.arange(lo, hi + 1, step), size=n)
        back = np.asarray(F.from_bitplanes(F.to_bitplanes(vals, bits, fmt),
                                           fmt))
        assert np.array_equal(back, vals)


@given(st.integers(1, 200), st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_popcount_matches_numpy(n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=(n,))
    packed = F.pack_bits(bits)
    assert int(np.sum(np.asarray(F.popcount(packed)))) == int(bits.sum())


# -- property tests: round trips over every Table I format and odd shapes -----

@given(st.integers(0, 8), st.integers(0, 100), st.integers(0, 2**32 - 1))
@settings(max_examples=40, deadline=None)
def test_pack_unpack_roundtrip_property(rows, n, seed):
    """pack ∘ unpack is the identity for any (rows, n), n a multiple of 32
    or not, empty shapes included; padding lanes are always zero."""
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=(rows, n)).astype(np.uint8)
    packed = F.pack_bits(bits)
    assert packed.shape == (rows, F.packed_width(n))
    assert np.array_equal(np.asarray(F.unpack_bits(packed, n)), bits)
    # tail padding must be zero (kernels rely on it)
    if n % 32 and rows:
        tail = np.asarray(packed)[:, -1] >> (n % 32)
        assert not tail.any()


@given(st.integers(1, 8), st.integers(0, 4), st.integers(0, 40),
       st.sampled_from(["uint", "int", "oddint"]),
       st.integers(0, 2**32 - 1))
@settings(max_examples=40, deadline=None)
def test_bitplane_roundtrip_property(bits, rows, cols, fmt, seed):
    """to_bitplanes ∘ from_bitplanes is the identity on any multi-dim
    (rows, cols) array of representable values, for every Table I format."""
    rng = np.random.default_rng(seed)
    lo, hi = F.value_range(fmt, bits)
    step = 2 if fmt == "oddint" else 1
    vals = rng.choice(np.arange(lo, hi + 1, step), size=(rows, cols))
    planes = F.to_bitplanes(vals, bits, fmt)
    assert planes.shape == (bits, rows, cols)
    assert np.array_equal(np.asarray(F.from_bitplanes(planes, fmt)), vals)


@given(st.integers(1, 6), st.integers(0, 3), st.integers(0, 70),
       st.sampled_from(["uint", "int", "oddint"]),
       st.integers(0, 2**32 - 1))
@settings(max_examples=40, deadline=None)
def test_pack_planes_roundtrip_property(bits, rows, n, fmt, seed):
    """The full codec — integers -> bitplanes -> packed lanes -> unpacked
    planes -> integers — round-trips for every format, including n not a
    multiple of 32 and empty/singleton shapes."""
    rng = np.random.default_rng(seed)
    lo, hi = F.value_range(fmt, bits)
    step = 2 if fmt == "oddint" else 1
    vals = rng.choice(np.arange(lo, hi + 1, step), size=(rows, n))
    packed = F.pack_planes(vals, bits, F.fmt(fmt))
    assert packed.shape == (bits, rows, F.packed_width(n))
    planes = F.unpack_bits(packed, n)
    assert np.array_equal(np.asarray(F.from_bitplanes(planes, fmt)), vals)


@pytest.mark.parametrize("fmt", ["uint", "int", "oddint"])
@pytest.mark.parametrize("shape", [(0,), (0, 5), (3, 0), (1, 1)])
def test_bitplane_roundtrip_degenerate_shapes(fmt, shape):
    lo, hi = F.value_range(fmt, 3)
    vals = np.full(shape, hi, np.int32)
    planes = F.to_bitplanes(vals, 3, fmt)
    assert planes.shape == (3,) + shape
    back = np.asarray(F.from_bitplanes(planes, fmt))
    assert back.shape == shape and np.array_equal(back, vals)


@pytest.mark.parametrize("rows,n", [(0, 7), (0, 32), (3, 0), (1, 1)])
def test_pack_unpack_degenerate_shapes(rows, n):
    bits = np.ones((rows, n), np.uint8)
    packed = F.pack_bits(bits)
    assert packed.shape == (rows, F.packed_width(n))
    assert np.array_equal(np.asarray(F.unpack_bits(packed, n)), bits)


def test_hypothesis_installed_when_required():
    """CI sets REQUIRE_HYPOTHESIS=1 so the property tests above run under
    real hypothesis there (the local fallback only samples the strategies)."""
    if not os.environ.get("REQUIRE_HYPOTHESIS"):
        pytest.skip("hypothesis only mandatory in CI (REQUIRE_HYPOTHESIS=1)")
    assert HAVE_HYPOTHESIS, \
        "REQUIRE_HYPOTHESIS is set but the hypothesis package is missing"
