"""Number formats + bitplane codecs (paper Table I)."""
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis or skip-shim

from repro.core import formats as F


@pytest.mark.parametrize("fmt", ["uint", "int", "oddint"])
@pytest.mark.parametrize("bits", [1, 2, 3, 4, 6, 8])
def test_roundtrip(fmt, bits, rng):
    lo, hi = F.value_range(fmt, bits)
    step = 2 if fmt == "oddint" else 1
    vals = np.arange(lo, hi + 1, step)
    planes = F.to_bitplanes(vals, bits, fmt)
    back = np.asarray(F.from_bitplanes(planes, fmt))
    assert np.array_equal(back, vals)


def test_table1_ranges():
    # Table I of the paper, L=2 column
    assert F.value_range("uint", 2) == (0, 3)
    assert F.value_range("int", 2) == (-2, 1)
    assert F.value_range("oddint", 2) == (-3, 3)


def test_oddint_only_odd():
    ok = np.asarray(F.representable("oddint", 3, np.arange(-7, 8)))
    vals = np.arange(-7, 8)
    assert np.array_equal(vals[ok], np.arange(-7, 8, 2))


@pytest.mark.parametrize("n", [1, 31, 32, 33, 64, 100, 256])
def test_pack_unpack(n, rng):
    bits = rng.integers(0, 2, size=(3, n))
    packed = F.pack_bits(bits)
    assert packed.shape == (3, F.packed_width(n))
    assert np.array_equal(np.asarray(F.unpack_bits(packed, n)), bits)


@given(st.integers(1, 8), st.integers(1, 80), st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_roundtrip_hypothesis(bits, n, seed):
    rng = np.random.default_rng(seed)
    for fmt in ("uint", "int", "oddint"):
        lo, hi = F.value_range(fmt, bits)
        step = 2 if fmt == "oddint" else 1
        vals = rng.choice(np.arange(lo, hi + 1, step), size=n)
        back = np.asarray(F.from_bitplanes(F.to_bitplanes(vals, bits, fmt),
                                           fmt))
        assert np.array_equal(back, vals)


@given(st.integers(1, 200), st.integers(0, 2**32 - 1))
@settings(max_examples=30, deadline=None)
def test_popcount_matches_numpy(n, seed):
    rng = np.random.default_rng(seed)
    bits = rng.integers(0, 2, size=(n,))
    packed = F.pack_bits(bits)
    assert int(np.sum(np.asarray(F.popcount(packed)))) == int(bits.sum())
