"""Golden-value regression tests locking core/cost_model to the paper's
published Tables II/III/IV (previously asserted only by the slow benchmark
scripts).  If any of these move, the analytical reproduction has drifted
from the paper."""
import math

import pytest

from repro.core.cost_model import (
    TABLE_II,
    TABLE_III,
    compare_vs_compute_cache,
    energy_per_op_fj,
    mode_throughput_gmvps,
    ops_per_cycle,
    peak_throughput_tops,
)
from repro.core.ppac import (
    CycleCounter,
    PPACConfig,
    cycles_compute_cache_inner_product,
    cycles_multibit_mvp,
)


def test_ops_per_cycle_conventions():
    # paper accounting: N multiplies + N-1 adds per row
    assert ops_per_cycle(256, 256, "paper") == 256 * 511
    assert ops_per_cycle(16, 16, "paper") == 16 * 31
    # external convention: 2N OP per row inner product (Table IV)
    assert ops_per_cycle(256, 256, "extern") == 256 * 512


@pytest.mark.parametrize("geometry", sorted(TABLE_II))
def test_table2_throughput_and_energy_golden(geometry):
    """Derived peak TOP/s and fJ/OP must reproduce every Table II row."""
    m, n = geometry
    info = TABLE_II[geometry]
    tops = peak_throughput_tops(m, n, info["f_ghz"])
    fj = energy_per_op_fj(m, n, info["f_ghz"], info["power_mw"])
    assert abs(tops - info["peak_tops"]) / info["peak_tops"] < 0.02, tops
    assert abs(fj - info["fj_per_op"]) / info["fj_per_op"] < 0.02, fj
    # geometry bookkeeping from the same table
    cfg = PPACConfig(m=m, n=n)
    assert cfg.banks == info["banks"] and cfg.subrows == info["subrows"]


def test_table2_largest_array_exact_numbers():
    """The headline 256×256 row, spelled out: M(2N-1)·f = 91.96 TOP/s at
    0.703 GHz (the paper's table rounds this to 91.99)."""
    tops = peak_throughput_tops(256, 256, 0.703)
    assert math.isclose(tops, 256 * 511 * 0.703e9 / 1e12)
    assert round(tops, 2) == 91.96
    assert abs(tops - TABLE_II[(256, 256)]["peak_tops"]) < 0.05


@pytest.mark.parametrize("mode", sorted(TABLE_III))
def test_table3_mode_throughput_golden(mode):
    """GMVP/s per operation mode on the 256×256 array at 0.703 GHz:
    1 MVP/cycle for the 1-bit modes, K·L cycles for 4×4-bit."""
    cfg = PPACConfig(m=256, n=256)
    got = mode_throughput_gmvps(cfg, mode, 0.703)
    want = TABLE_III[mode]["gmvps"]
    assert abs(got - want) / want < 0.02, (mode, got, want)


def test_table3_multibit_is_16x_slower():
    cfg = PPACConfig()
    one_bit = mode_throughput_gmvps(cfg, "hamming", 0.703)
    four_bit = mode_throughput_gmvps(cfg, "mvp_4bit_01", 0.703)
    assert math.isclose(one_bit / four_bit, 16.0)
    assert cycles_multibit_mvp(4, 4) == 16


def test_table4_compute_cache_comparison_golden():
    """§IV-B: 256-dim 4-bit inner product — PPAC 16 cycles vs 98 for the
    bit-serial in-cache method of [3,4] (6.1× speedup)."""
    cmp = compare_vs_compute_cache(l_bits=4, n_dim=256)
    assert cmp["ppac_cycles"] == 16
    assert cmp["compute_cache_cycles"] == 98
    assert math.isclose(cmp["speedup"], 98 / 16)
    # the building blocks: L^2+5L-2 multiply + 2L*log2(N) reduce
    assert cycles_compute_cache_inner_product(4, 256) == 34 + 64
    assert cycles_compute_cache_inner_product(1, 256) == 4 + 16


def test_table4_extern_convention_peak_gops():
    """Table IV quotes PPAC at 91994 GOP/s under the 2N-OP convention."""
    gops = peak_throughput_tops(256, 256, 0.703, convention="extern") * 1000
    assert abs(gops - 91994) / 91994 < 0.02


def test_pipeline_latency_is_two_cycles():
    """§II: results appear after the 2-cycle array pipeline."""
    assert CycleCounter().pipeline_latency == 2
