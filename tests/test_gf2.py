"""GF(2) coding subsystem: tiled kernel (all backends bit-exact, n >> 256,
parity accumulation across lane tiles), affine/LFSR/CRC ops vs bit-serial
references, LDPC encode/decode (guaranteed-t exhaustive recovery, backend
and shard bit-identity, cycle accounting vs the cost model), and the
batched decode server."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from conftest import cpu_subproc_env

from repro.core import formats as F
from repro.core.ppac import (
    PPACArray,
    PPACConfig,
    cycles_compute_cache_inner_product,
)
from repro.gf2 import (
    BitFlipDecoder,
    affine_map,
    bsc_flip,
    crc,
    crc_matrix,
    crc_reference,
    descramble,
    gf2_cycles,
    gf2_matvec,
    lfsr_keystream,
    lfsr_observation_matrix,
    make_array_ldpc,
    make_random_ldpc,
    scramble,
    solve_unit_lower,
)
from repro.kernels.gf2_tiled.kernel import gf2_matmul_packed
from repro.kernels.gf2_tiled.ops import gf2_matmul_tiled
from repro.launch.coding import CodingServer, DecodeRequest


def _bits(rng, rows, n):
    return rng.integers(0, 2, (rows, n)).astype(np.uint8)


# ---------------------------------------------------------------------------
# kernels/gf2_tiled
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["pallas", "mxu"])
@pytest.mark.parametrize("b,m,n", [(1, 1, 1), (3, 17, 33), (5, 64, 300),
                                   (2, 9, 1024), (4, 40, 700)])
def test_gf2_tiled_matches_ref_exactly(rng, backend, b, m, n):
    x, a = _bits(rng, b, n), _bits(rng, m, n)
    want = (x @ a.T) % 2
    xp, ap = F.pack_bits(x), F.pack_bits(a)
    ref = np.asarray(gf2_matmul_tiled(xp, ap, n=n, backend="ref"))
    got = np.asarray(gf2_matmul_tiled(xp, ap, n=n, backend=backend))
    assert np.array_equal(ref, want)
    assert np.array_equal(got, want)


def test_gf2_tiled_parity_accumulates_across_lane_tiles(rng):
    """Tiny block_w forces many grid steps over the lane dim — the running
    XOR across tiles must equal the one-shot parity."""
    b, m, n = 3, 24, 2048  # 64 lanes
    x, a = _bits(rng, b, n), _bits(rng, m, n)
    want = (x @ a.T) % 2
    got = np.asarray(gf2_matmul_packed(
        F.pack_bits(x), F.pack_bits(a),
        block_w=1, block_m=8, block_b=8, interpret=True))
    assert np.array_equal(got, want)


def test_gf2_tiled_agrees_with_ppac_array(rng):
    """The tiled kernel must agree with the cycle-exact PPACArray emulator
    (paper §III-D) row-for-row at array geometry."""
    m, n = 32, 48
    a = _bits(rng, m, n)
    arr = PPACArray(PPACConfig(m=m, n=n))
    arr.write(a)
    x = _bits(rng, 1, n)[0]
    want = np.asarray(arr.gf2_mvp(x))
    for be in ("ref", "pallas", "mxu"):
        got = np.asarray(gf2_matmul_tiled(
            F.pack_bits(x[None, :]), F.pack_bits(a), n=n, backend=be))[0]
        assert np.array_equal(got, want), be


def test_gf2_cycles_geometry():
    cfg = PPACConfig(m=256, n=256)
    assert gf2_cycles(1, 256, 256, cfg) == 1           # one tile, no merge
    # fully parallel tiles: scan is 1 cycle, col split adds the XOR tree
    assert gf2_cycles(1, 256, 1024, cfg) == 1 + 2      # 4 col tiles
    assert gf2_cycles(1, 1024, 256, cfg) == 1          # row split: no merge
    assert gf2_cycles(2, 512, 512, cfg) == 2 * (1 + 1)
    # time-multiplexed onto fewer physical arrays: 16 tiles on 4 arrays
    assert gf2_cycles(1, 1024, 1024, cfg, parallel_arrays=4) == 4 + 2


# ---------------------------------------------------------------------------
# gf2.ops: affine / LFSR / CRC
# ---------------------------------------------------------------------------

def test_affine_map_aes_sbox(rng):
    a = np.zeros((8, 8), np.uint8)
    for i in range(8):
        for j in (0, 4, 5, 6, 7):
            a[i, (i + j) % 8] = 1
    c = np.array([1, 1, 0, 0, 0, 1, 1, 0], np.uint8)
    xs = _bits(rng, 16, 8)
    y = np.asarray(affine_map(xs, a, c, backend="ref"))
    assert np.array_equal(y, (xs @ a.T % 2) ^ c[None, :])
    # without the constant it is the plain matvec
    y0 = np.asarray(affine_map(xs, a, backend="ref"))
    assert np.array_equal(y0, xs @ a.T % 2)


def _serial_lfsr(state, taps, length):
    s = list(state)
    out = []
    for _ in range(length):
        out.append(int(s[-1]))
        fb = 0
        for t in taps:
            fb ^= int(s[t - 1])
        s = [fb] + s[:-1]
    return out


@pytest.mark.parametrize("backend", ["ref", "mxu", "pallas"])
def test_lfsr_keystream_matches_serial_reference(rng, backend):
    taps, deg = (7, 6), 7
    states = _bits(rng, 3, deg)
    ks = np.asarray(lfsr_keystream(states, taps, 200, backend=backend))
    for b in range(3):
        assert list(ks[b]) == _serial_lfsr(states[b], taps, 200)


def test_lfsr_maximal_length():
    """x^7+x^6+1 is primitive: period 2^7-1 for any nonzero seed."""
    seed = np.zeros((1, 7), np.uint8)
    seed[0, 0] = 1
    ks = np.asarray(lfsr_keystream(seed, (7, 6), 254, backend="ref"))[0]
    assert np.array_equal(ks[:127], ks[127:])
    assert not np.array_equal(ks[:63], ks[63:126])  # no shorter period
    obs = lfsr_observation_matrix((7, 6), 7, 10)
    assert obs.shape == (10, 7) and obs[0, 6] == 1


def test_scrambler_roundtrip(rng):
    taps = (5, 3)
    seeds = _bits(rng, 4, 5)
    frames = _bits(rng, 4, 100)
    tx = np.asarray(scramble(frames, seeds, taps, backend="ref"))
    assert not np.array_equal(tx, frames)
    assert np.array_equal(
        np.asarray(descramble(tx, seeds, taps, backend="ref")), frames)


def test_crc8_matches_bitwise_division(rng):
    poly, deg = 0x07, 8  # CRC-8: x^8 + x^2 + x + 1
    msgs = _bits(rng, 6, 40)
    got = np.asarray(crc(msgs, poly, deg, backend="ref"))
    for i in range(6):
        want = crc_reference(msgs[i], poly, deg)
        assert sum(int(b) << j for j, b in enumerate(got[i])) == want
    # linearity: crc(a ^ b) = crc(a) ^ crc(b)
    r = crc_matrix(poly, deg, 40)
    ab = (msgs[0] ^ msgs[1])[None, :]
    assert np.array_equal(
        np.asarray(crc(ab, poly, deg, backend="ref"))[0], got[0] ^ got[1])
    assert r.shape == (deg, 40)


# ---------------------------------------------------------------------------
# gf2.ldpc: codes + encode
# ---------------------------------------------------------------------------

def test_solve_unit_lower_random(rng):
    p = 20
    l_mat = (np.tril(rng.random((p, p)) < 0.4, -1)
             | np.eye(p, dtype=bool)).astype(np.uint8)
    rhs = _bits(rng, p, 7)
    x = solve_unit_lower(l_mat, rhs)
    assert np.array_equal((l_mat @ x) % 2, rhs)


@pytest.mark.parametrize("backend", ["ref", "pallas", "mxu"])
def test_random_ldpc_encode_zero_syndrome(rng, backend):
    code = make_random_ldpc(96, 48, rng=rng)
    msgs = _bits(rng, 8, 48)
    cw = code.encode(msgs, backend=backend)
    assert cw.shape == (8, 96)
    assert np.array_equal(cw[:, :48], msgs)          # systematic
    assert not code.syndrome(cw, backend=backend).any()
    bad = cw.copy()
    bad[:, 3] ^= 1
    assert code.syndrome(bad, backend=backend).any(axis=1).all()


def test_encode_backends_bit_identical(rng):
    code = make_random_ldpc(80, 40, rng=rng)
    msgs = _bits(rng, 5, 40)
    outs = [code.encode(msgs, backend=be) for be in ("ref", "pallas", "mxu")]
    assert np.array_equal(outs[0], outs[1])
    assert np.array_equal(outs[0], outs[2])


def test_array_ldpc_structure():
    code = make_array_ldpc(6, 5)
    assert (code.n, code.k, code.n_chk) == (30, 20, 11)
    assert code.col_weight.min() == code.col_weight.max() == 2
    assert code.max_overlap == 1
    assert code.guaranteed_t == 1
    # encode parity part is [P | L] with unit-lower-triangular L
    l_part = code.h_enc[:, code.k:]
    assert np.all(np.diag(l_part) == 1)
    assert not np.triu(l_part, 1).any()


def test_array_ldpc_encode_consistent_with_grid_parity(rng):
    r, c = 5, 7
    code = make_array_ldpc(r, c)
    cw = code.encode(_bits(rng, 6, code.k), backend="ref")
    assert not code.syndrome(cw, backend="ref").any()


# ---------------------------------------------------------------------------
# gf2.ldpc: bit-flipping decoder
# ---------------------------------------------------------------------------

def test_decoder_recovers_all_guaranteed_error_patterns(rng):
    """Exhaustive: every single-bit error pattern (t=1 for the array code)
    on several codewords must decode back exactly, in one iteration."""
    code = make_array_ldpc(4, 4)  # n=16: all 16 patterns enumerable
    t = code.guaranteed_t
    assert t == 1
    dec = BitFlipDecoder(code, backend="mxu", max_iters=4)
    msgs = _bits(rng, 3, code.k)
    cw = code.encode(msgs, backend="mxu")
    for w in range(3):
        noisy = np.repeat(cw[w:w + 1], code.n, axis=0)
        noisy[np.arange(code.n), np.arange(code.n)] ^= 1
        res = dec.decode(noisy)
        assert res.ok.all()
        assert (res.iters == 1).all()
        assert np.array_equal(res.codewords,
                              np.repeat(cw[w:w + 1], code.n, axis=0))
        assert np.array_equal(res.msgs, np.repeat(msgs[w:w + 1], code.n, 0))


def test_decoder_clean_words_take_zero_iterations(rng):
    code = make_array_ldpc(8, 8)
    dec = BitFlipDecoder(code, backend="mxu", max_iters=6)
    cw = code.encode(_bits(rng, 5, code.k), backend="mxu")
    res = dec.decode(cw)
    assert res.ok.all() and (res.iters == 0).all()
    assert np.array_equal(res.codewords, cw)
    # cycle accounting: zero iterations -> only the pipeline latency
    assert res.stats["iterations"] == 0
    assert res.stats["total_cycles"] == dec.counter.pipeline_latency


def test_decoder_backends_bit_identical(rng):
    """ref/pallas/mxu must agree on decoded bits, ok flags and per-word
    iteration counts — including on words that fail to converge."""
    code = make_random_ldpc(64, 32, rng=rng)
    words = _bits(rng, 9, 64)  # garbage: mix of decodable and not
    outs = {}
    for be in ("ref", "pallas", "mxu"):
        dec = BitFlipDecoder(code, backend=be, max_iters=6)
        r = dec.decode(words)
        outs[be] = (r.codewords, r.ok, r.iters)
    for be in ("pallas", "mxu"):
        for a, b in zip(outs["ref"], outs[be]):
            assert np.array_equal(a, b), be


def test_decoder_reports_failures(rng):
    """Words whose syndrome never clears come back ok=False with
    iters == max_iters; mixed batches keep per-word accounting."""
    code = make_array_ldpc(6, 6)
    dec = BitFlipDecoder(code, backend="mxu", max_iters=3)
    cw = code.encode(_bits(rng, 2, code.k), backend="mxu")
    two_err = bsc_flip(cw[1:], 3, rng)  # beyond t: may or may not converge
    # an adversarial stuck word: two errors in one grid row vote 1 each,
    # never passing the 2v > gamma=2 majority -> provably stuck
    stuck = cw[0].copy()
    stuck[0] ^= 1
    stuck[1] ^= 1
    batch = np.concatenate([cw[:1], stuck[None, :], two_err])
    res = dec.decode(batch)
    assert res.ok[0] and res.iters[0] == 0
    assert not res.ok[1] and res.iters[1] == dec.max_iters
    assert res.stats["iterations"] == dec.max_iters


def test_decode_cycle_accounting_against_cost_model(rng):
    """stats must be exactly the cost-model formulas: tile-virtualized
    PPAC cycles and the §IV-B compute-cache baseline."""
    code = make_array_ldpc(16, 16)  # n=256, n_chk=32
    cfg = PPACConfig(m=256, n=256)
    dec = BitFlipDecoder(code, config=cfg, backend="mxu", max_iters=5)
    cpwi = dec.cycles_per_word_iteration()
    assert cpwi == (gf2_cycles(1, code.n_chk, code.n, cfg)
                    + gf2_cycles(1, code.n, code.n_chk, cfg))
    cc = dec.compute_cache_cycles_per_word_iteration()
    assert cc == (cycles_compute_cache_inner_product(1, code.n)
                  + cycles_compute_cache_inner_product(1, code.n_chk))
    assert cc > cpwi  # the paper's §IV-B speedup claim, 1-bit case

    cw = code.encode(_bits(rng, 4, code.k), backend="mxu")
    noisy = bsc_flip(cw, 1, rng)
    c0 = dec.counter.cycles
    res = dec.decode(noisy)
    iters = int(res.iters.max())
    assert res.stats["total_cycles"] == 4 * iters * cpwi + \
        dec.counter.pipeline_latency
    assert res.stats["compute_cache_cycles"] == 4 * iters * cc
    assert dec.counter.cycles - c0 == res.stats["total_cycles"]
    assert res.stats["speedup_vs_compute_cache"] > 1


def test_gf2_matvec_counts_cycles(rng):
    from repro.core.ppac import CycleCounter

    counter = CycleCounter()
    cfg = PPACConfig(m=64, n=64)
    x, a = _bits(rng, 3, 200), _bits(rng, 100, 200)
    gf2_matvec(x, a, backend="ref", counter=counter, config=cfg)
    assert counter.cycles == gf2_cycles(3, 100, 200, cfg) + \
        counter.pipeline_latency


SUBPROC_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np, jax
    from repro.gf2 import BitFlipDecoder, bsc_flip, make_array_ldpc

    rng = np.random.default_rng(3)
    code = make_array_ldpc(8, 8)
    cw = code.encode(rng.integers(0, 2, (7, code.k)), backend="mxu")
    noisy = bsc_flip(cw, 1, rng)
    single = BitFlipDecoder(code, backend="mxu", max_iters=5).decode(noisy)
    assert single.ok.all()
    mesh = jax.make_mesh((2,), ("data",))
    for be in ("mxu", "ref", "pallas"):
        dec = BitFlipDecoder(code, backend=be, max_iters=5)
        sh = dec.decode(noisy, mesh=mesh)  # B=7 pads to 8, slices back
        assert np.array_equal(single.codewords, sh.codewords), be
        assert np.array_equal(single.ok, sh.ok), be
        assert np.array_equal(single.iters, sh.iters), be
        assert sh.stats["shards"] == 2
    print("SHARDED_OK")
""")


def test_sharded_decode_matches_single_device():
    """2 simulated devices: codeword blocks row-sharded via shard_map must
    decode bit-identically to the single-device path, for every backend."""
    res = subprocess.run([sys.executable, "-c", SUBPROC_SHARDED],
                         capture_output=True, text=True, timeout=600,
                         env=cpu_subproc_env())
    assert "SHARDED_OK" in res.stdout, res.stdout + res.stderr


# ---------------------------------------------------------------------------
# launch/coding.py server
# ---------------------------------------------------------------------------

def test_coding_server_bucketing_and_recovery(rng):
    code = make_array_ldpc(8, 8)
    dec = BitFlipDecoder(code, backend="mxu", max_iters=4)
    server = CodingServer(dec, buckets=(1, 4, 16))
    msgs = _bits(rng, 23, code.k)
    cw = code.encode(msgs, backend="mxu")
    noisy = bsc_flip(cw, 1, rng)
    for i in range(23):
        server.submit(DecodeRequest(i, noisy[i]))
    done = server.run()
    assert len(done) == 23 and all(r.done for r in done)
    for r in done:
        assert r.ok and r.iters <= 1
        assert np.array_equal(r.msg, msgs[r.rid])
        assert np.array_equal(r.codeword, cw[r.rid])
    # 23 requests: whole buckets 16 and 4 drain unpadded, the remaining
    # 3 pad into one 4-bucket
    assert server.batches == 3
    assert server.bucket_counts[16] == 1 and server.bucket_counts[4] == 2


def test_coding_server_interleaved_submit(rng):
    code = make_array_ldpc(4, 4)
    dec = BitFlipDecoder(code, backend="mxu", max_iters=4)
    server = CodingServer(dec, buckets=(1, 4))
    cw = code.encode(_bits(rng, 6, code.k), backend="mxu")
    for i in range(3):
        server.submit(DecodeRequest(i, cw[i].copy()))
    first = server.step()
    assert len(first) == 3 and server.bucket_counts[4] == 1
    for i in range(3, 6):
        server.submit(DecodeRequest(i, cw[i].copy()))
    done = server.run()
    assert {r.rid for r in done} == {3, 4, 5}
    assert all(r.ok for r in first + done)
