"""Paged KV cache: pool invariants (refcount conservation, COW,
eviction, backpressure), prefix-hit parity with cold prefill, and the
serving-loop correctness fixes riding along (submit boundary, latency
formatting, bucket overflow)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import load_arch
from repro.launch.paging import PagePool
from repro.launch.serve_lm import LMServer, Request, fmt_latency, run_and_report
from repro.models import lm
from repro.retrieval.prefix import PagePrefixIndex, page_keys


@pytest.fixture(scope="module")
def served():
    cfg = dataclasses.replace(load_arch("smollm_360m").smoke(),
                              dtype="float32")
    params, _ = lm.init(cfg, jax.random.PRNGKey(1))
    return cfg, params


def _reqs(cfg, prompts, max_new=5, **kw):
    return [Request(i, np.asarray(p, np.int32), max_new, **kw)
            for i, p in enumerate(prompts)]


def _serve(cfg, params, prompts, max_new=5, **kw):
    server = LMServer(cfg, params, slots=2, max_seq=64, paged=True,
                      page_size=8, cache_dtype=jnp.float32, **kw)
    for r in _reqs(cfg, prompts, max_new):
        server.submit(r)
    done = server.run()
    return {r.rid: r.out for r in done}, server


# -- pool unit invariants -----------------------------------------------------

def test_page_pool_refcount_conservation():
    pool = PagePool(8)
    a = pool.alloc(3)
    b = pool.alloc(2)
    assert pool.used_pages == 5 and pool.free_pages == 3
    pool.incref(a[:2])  # share two pages
    assert pool.refcount.sum() == 7
    assert pool.decref(a) == [a[2]]          # shared pages stay resident
    assert pool.refcount.sum() == 4
    assert sorted(pool.decref(a[:2] + b)) == sorted(a[:2] + b)
    assert pool.free_pages == 8 and pool.refcount.sum() == 0
    assert pool.alloc(9) is None             # over-ask: None, not a crash
    assert pool.alloc(8) is not None


def test_page_keys_chain_binds_whole_prefix():
    """key i commits to pages 0..i: equal spans at different offsets or
    behind different prefixes must NOT collide."""
    t = np.arange(32, dtype=np.int32)
    keys = page_keys(t, 8)
    assert len(keys) == 4 and len(set(keys)) == 4
    # same page-1 content behind a different page 0 -> different key
    t2 = t.copy()
    t2[0] += 1
    assert page_keys(t2, 8)[1] != keys[1]
    assert page_keys(t[:15], 8) == keys[:1]  # partial page contributes none


def test_prefix_index_register_lookup_evict():
    idx = PagePrefixIndex(4)
    toks = np.arange(12, dtype=np.int32)
    keys = idx.keys_for(toks)
    assert idx.lookup(keys) == []
    assert idx.register(keys[0], 7) and idx.register(keys[1], 3)
    assert not idx.register(keys[0], 9)      # dup key refused
    assert not idx.register(keys[2], 7)      # dup page refused
    assert idx.lookup(keys) == [7, 3]  # key 2 unregistered: run ends
    assert idx.evict_page(7)
    assert idx.lookup(keys) == []            # chain broken at page 0
    refc = np.zeros(16, np.int32)
    refc[3] = 1
    assert idx.idle_pages(refc) == [3]


# -- serving invariants -------------------------------------------------------

def test_refcount_conservation_under_serving(served):
    """sum(refcount) == live table mappings + index-held registrations,
    after every server step (the PagePool docstring's conservation law)."""
    cfg, params = served
    server = LMServer(cfg, params, slots=2, max_seq=64, paged=True,
                      page_size=8, prefix_cache=True,
                      cache_dtype=jnp.float32)
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab, 9)
    for r in _reqs(cfg, [np.concatenate([shared, rng.integers(0, cfg.vocab, 3)])
                         for _ in range(5)], max_new=4):
        server.submit(r)

    def check():
        mapped = int((server.table_np < server.pool_pages).sum())
        assert server.pool.refcount.sum() == \
            mapped + server.prefix.registered_pages
        assert server.pool.used_pages == \
            int((server.pool.refcount > 0).sum())

    while server.queue or any(x is not None for x in server.live):
        server._admit()
        check()
        server.step()
        check()
    # registrations persist after all requests retire (hot prefix stays)
    assert server.prefix.registered_pages > 0


def test_prefix_hit_bit_identical_and_skips_rows(served):
    """Warm admission (shared system prompt resident) produces the same
    tokens as cold admission, while prefilling fewer rows."""
    cfg, params = served
    rng = np.random.default_rng(7)
    sys_p = rng.integers(0, cfg.vocab, 17)
    prompts = [np.concatenate([sys_p, rng.integers(0, cfg.vocab, 5)])
               for _ in range(4)]
    cold, _ = _serve(cfg, params, prompts)
    warm, srv = _serve(cfg, params, prompts, prefix_cache=True)
    assert cold == warm
    m = srv.metrics.snapshot()
    assert m["lm_prefix_pages_hit"] > 0
    assert m["lm_prefill_rows_skipped"] > 0
    assert m["lm_prefix_pages_hit"] <= m["lm_prefix_pages_total"]


def test_cow_on_shared_tail_page(served):
    """A prompt whose length is a page multiple matches ALL its pages,
    yet must still re-emit from its last row: the shared tail page is
    copied, and the copy never corrupts the original's stream."""
    cfg, params = served
    rng = np.random.default_rng(11)
    # 2 full pages (page_size=8); three copies on 2 slots: the third
    # admits after registration and matches BOTH pages -> COW
    p = rng.integers(0, cfg.vocab, 16)
    cold, _ = _serve(cfg, params, [p, p, p])
    warm, srv = _serve(cfg, params, [p, p, p], prefix_cache=True)
    assert cold == warm
    assert srv.metrics.snapshot()["lm_pages_cow"] >= 1


def test_eviction_returns_pages_to_free_list(served):
    """When the pool runs dry, idle registrations (held only by the
    prefix index) are evicted LRU-first and their pages recycled."""
    cfg, params = served
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, 8) for _ in range(4)]
    server = LMServer(cfg, params, slots=1, max_seq=64, paged=True,
                      page_size=8, pool_pages=3, prefix_cache=True,
                      cache_dtype=jnp.float32)
    for r in _reqs(cfg, prompts, max_new=4):
        server.submit(r)
    done = server.run()
    assert len(done) == 4
    m = server.metrics.snapshot()
    assert m["lm_prefix_pages_evicted"] >= 1
    # evicted registrations released their reference: the pool drained
    # back to exactly the surviving registrations
    assert server.pool.used_pages == server.prefix.registered_pages


def test_pool_exhaustion_backpressures_not_crashes(served):
    """A pool holding one request's worth of pages serves three requests
    sequentially: admission waits for retirements instead of crashing."""
    cfg, params = served
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, 8) for _ in range(3)]
    server = LMServer(cfg, params, slots=2, max_seq=64, paged=True,
                      page_size=8, pool_pages=3, cache_dtype=jnp.float32)
    for r in _reqs(cfg, prompts, max_new=10):  # 8+10-1=17 rows -> 3 pages
        server.submit(r)
    server._admit()
    assert sum(x is not None for x in server.live) == 1  # pool-bound, not slot
    assert len(server.queue) == 2                        # FIFO order kept
    assert server.queue[0].rid == 1
    done = server.run()
    assert len(done) == 3
    assert all(len(r.out) == 10 for r in done)


def test_oversized_request_raises_not_hangs(served):
    cfg, params = served
    server = LMServer(cfg, params, slots=1, max_seq=64, paged=True,
                      page_size=8, pool_pages=2, cache_dtype=jnp.float32)
    server.submit(Request(0, np.arange(1, 9, dtype=np.int32), max_new=20))
    with pytest.raises(RuntimeError, match="pool"):
        server.run()


def test_paged_rejects_stateful_families(served):
    cfg, params = served
    ssm = load_arch("mamba2_370m").smoke()
    with pytest.raises(ValueError):
        LMServer(ssm, None, paged=True)
    ring = load_arch("h2o_danube3_4b").smoke()
    with pytest.raises(ValueError):
        LMServer(ring, None, paged=True, prefix_cache=True)


# -- serving-loop correctness fixes -------------------------------------------

def test_submit_boundary_off_by_one(served):
    """plen + max_new - 1 == max_seq must be admissible (prefill emits
    the first of max_new, so only plen + max_new - 1 rows are written);
    one token more must be rejected."""
    cfg, params = served
    server = LMServer(cfg, params, slots=1, max_seq=64)
    prompt = np.arange(1, 6, dtype=np.int32)  # plen 5
    server.submit(Request(0, prompt, max_new=60))  # 5 + 60 - 1 == 64: ok
    with pytest.raises(AssertionError):
        server.submit(Request(1, prompt, max_new=61))
    done = server.run()
    assert len(done) == 1 and len(done[0].out) == 60  # filled to the brim


def test_fmt_latency_zero_is_not_unknown():
    assert fmt_latency(None) == "?"
    assert fmt_latency(0.0) == "0.0ms"   # falsy but measured
    assert fmt_latency(0.25) == "250.0ms"


def test_run_and_report_empty_run_no_division(served, capsys):
    cfg, params = served
    server = LMServer(cfg, params, slots=1, max_seq=64)
    assert run_and_report(server, []) == []
    assert "served 0 requests" in capsys.readouterr().out
