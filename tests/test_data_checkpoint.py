"""Data pipeline determinism/sharding + checkpoint fault tolerance."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import all_steps, latest_step, restore, save
from repro.configs import load_arch
from repro.configs.base import InputShape
from repro.data.pipeline import (
    DataConfig,
    DataIterator,
    batch_for_step,
    make_model_batch,
)


def test_determinism():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4)
    a = batch_for_step(cfg, 7)
    b = batch_for_step(cfg, 7)
    assert np.array_equal(a["tokens"], b["tokens"])
    c = batch_for_step(cfg, 8)
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=2)
    b = batch_for_step(cfg, 0)
    # label[i] is the next token after tokens[i] in the underlying stream
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_sharding_disjoint():
    full = DataConfig(vocab=50, seq_len=8, global_batch=8)
    h0 = DataConfig(vocab=50, seq_len=8, global_batch=8, host_id=0,
                    num_hosts=2)
    h1 = DataConfig(vocab=50, seq_len=8, global_batch=8, host_id=1,
                    num_hosts=2)
    b0, b1 = batch_for_step(h0, 3), batch_for_step(h1, 3)
    assert b0["tokens"].shape[0] == 4 and b1["tokens"].shape[0] == 4
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_iterator_resume_exact():
    cfg = DataConfig(vocab=100, seq_len=8, global_batch=2)
    mcfg = load_arch("smollm_360m").smoke()
    shape = InputShape("t", 8, 2, "train")
    it = DataIterator(cfg, mcfg, shape)
    batches = [next(it) for _ in range(5)]
    state = it.state()
    more = [next(it) for _ in range(3)]

    it2 = DataIterator(cfg, mcfg, shape)
    it2.restore(state)
    again = [next(it2) for _ in range(3)]
    for x, y in zip(more, again):
        assert np.array_equal(x["tokens"], y["tokens"])


def test_frontend_batches():
    shape = InputShape("t", 16, 2, "train")
    for arch in ("musicgen_medium", "llava_next_34b"):
        mcfg = load_arch(arch).smoke()
        cfg = DataConfig(vocab=mcfg.vocab, seq_len=16, global_batch=2)
        b = make_model_batch(mcfg, shape, cfg, 0)
        if mcfg.frontend == "audio":
            assert b["embeds"].shape == (2, 16, mcfg.d_model)
        else:
            assert b["patches"].shape == (2, mcfg.frontend_tokens,
                                          mcfg.d_model)
            assert b["tokens"].shape[1] == 16 - mcfg.frontend_tokens


# -- checkpointing -------------------------------------------------------------

def make_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,))},
            "opt": {"step": jnp.asarray(5, jnp.int32),
                    "mu": {"w": jnp.ones((8, 8)), "b": jnp.ones((8,))}}}


def test_save_restore_roundtrip(tmp_path):
    state = make_state()
    save(str(tmp_path), 5, state, extra={"data_step": 17})
    got, extra = restore(str(tmp_path), 5, jax.eval_shape(lambda: state))
    assert extra["data_step"] == 17
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_retention_and_latest(tmp_path):
    state = make_state()
    for s in (1, 2, 3, 4, 5):
        save(str(tmp_path), s, state, keep=3)
    assert all_steps(str(tmp_path)) == [3, 4, 5]
    assert latest_step(str(tmp_path)) == 5


def test_atomicity_no_partial_checkpoint(tmp_path):
    """A leftover .tmp dir (simulated crash) is never listed as a step."""
    state = make_state()
    save(str(tmp_path), 1, state)
    os.makedirs(os.path.join(str(tmp_path), "step_00000002.tmp"))
    assert all_steps(str(tmp_path)) == [1]
    # and a subsequent save of step 2 succeeds over the junk tmp dir
    save(str(tmp_path), 2, state)
    assert latest_step(str(tmp_path)) == 2


def test_elastic_restore_with_new_sharding(tmp_path):
    """Restore onto explicit (single-device) shardings — the elastic path."""
    state = make_state()
    save(str(tmp_path), 9, state)
    dev = jax.devices()[0]
    shardings = jax.tree.map(lambda _: jax.sharding.SingleDeviceSharding(dev),
                             state)
    got, _ = restore(str(tmp_path), 9, jax.eval_shape(lambda: state),
                     shardings=shardings)
    assert jax.tree.leaves(got)[0].sharding.device_set == {dev}


def test_train_restart_bit_identical(tmp_path):
    """Kill/restart mid-run: (train 6) == (train 3, save, restore, train 3)."""
    from repro.data.pipeline import DataIterator
    from repro.optim.adamw import AdamWConfig
    from repro.train.step import TrainConfig, init_state, make_train_step

    cfg = load_arch("smollm_360m").smoke()
    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-2))
    step = jax.jit(make_train_step(cfg, tcfg))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=2)
    shape = InputShape("t", 16, 2, "train")

    def train(state, it, n):
        for _ in range(n):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            state, _ = step(state, batch)
        return state

    s0, _ = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    it = DataIterator(dcfg, cfg, shape)
    ref = train(s0, it, 6)

    s1, _ = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    it1 = DataIterator(dcfg, cfg, shape)
    s1 = train(s1, it1, 3)
    save(str(tmp_path), 3, s1, extra={"data_step": it1.state()})

    template = jax.eval_shape(lambda: s1)
    s2, extra = restore(str(tmp_path), 3, template)
    it2 = DataIterator(dcfg, cfg, shape)
    it2.restore(extra["data_step"])
    s2 = train(s2, it2, 3)

    for a, b in zip(jax.tree.leaves(ref["params"]),
                    jax.tree.leaves(s2["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
