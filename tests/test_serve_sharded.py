"""Sharded + disaggregated LMServer parity (subprocess, forced-host
devices): every multi-device layout must retire bit-identical greedy
tokens to the single-device server — across weight kinds (packed1 /
packed4 / int8), cache layouts (linear / ring / paged), mid-flight
admission, and a prefill->decode handoff mid-stream — and the sharded
entry points must keep the donation contract."""
import os
import subprocess
import sys
import textwrap

from conftest import cpu_subproc_env

_TESTS = os.path.dirname(os.path.abspath(__file__))


def _run(script: str) -> str:
    res = subprocess.run([sys.executable, "-c", script, _TESTS],
                         capture_output=True, text=True, timeout=600,
                         env=cpu_subproc_env())
    assert res.returncode == 0, res.stdout + res.stderr
    return res.stdout


_PRELUDE = textwrap.dedent("""
    import os, sys
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=%d"
    import dataclasses
    import jax
    import numpy as np
    from repro.configs import load_arch
    from repro.launch.mesh import make_serving_mesh
    from repro.launch.serve_lm import LMServer, Request
    from repro.models import lm
    from repro.serve.step import convert_params_for_serving

    def serve(cfg, params, prompts, max_new=6, slots=2, **kw):
        server = LMServer(cfg, params, slots=slots, max_seq=64, **kw)
        for i, p in enumerate(prompts):
            server.submit(Request(i, np.asarray(p, np.int32), max_new))
        done = server.run()
        assert len(done) == len(prompts)
        return {r.rid: r.out for r in done}, server
""")


SUBPROC_KINDS = _PRELUDE % 2 + textwrap.dedent("""
    # 2-dev pure TP ('model') across the PPAC weight kinds: packed1
    # (wb=1), packed4 bitplanes (wb=4), int8 (wb=8) — grouped wqkv/wig
    # containers and all. Greedy tokens must match bit-for-bit.
    rng = np.random.default_rng(3)
    for wb in (1, 4, 8):
        cfg = load_arch("smollm_360m").smoke()
        cfg = dataclasses.replace(
            cfg, dtype="float32",
            ppac=dataclasses.replace(cfg.ppac, enabled=True, weight_bits=wb,
                                     act_bits=8, min_features=32))
        params0, _ = lm.init(cfg, jax.random.PRNGKey(1))
        params = convert_params_for_serving(params0, cfg)
        prompts = [rng.integers(0, cfg.vocab, n) for n in (8, 5, 11)]
        ref, _ = serve(cfg, params, prompts, mode="serve")
        got, sv = serve(cfg, params, prompts, mode="serve",
                        mesh=make_serving_mesh((1, 2)))
        assert got == ref, (wb, got, ref)
        # the resident weights must actually be sharded, not replicated
        assert any(not l.sharding.is_fully_replicated
                   for l in jax.tree.leaves(sv.params)), wb
        print("KIND_OK", wb)
    print("KINDS_SHARDED_OK")
""")


def test_sharded_server_kinds_parity_2dev():
    out = _run(SUBPROC_KINDS)
    assert "KINDS_SHARDED_OK" in out, out


SUBPROC_LAYOUTS = _PRELUDE % 4 + textwrap.dedent("""
    # 2x2 mesh (slot-DP x TP) across cache layouts, with 5 requests into
    # 2 slots so admission necessarily happens mid-flight next to
    # decoding neighbors.
    rng = np.random.default_rng(5)
    for name, arch, kw in (("linear", "smollm_360m", {}),
                           ("ring", "h2o_danube3_4b", {}),
                           ("paged", "smollm_360m",
                            dict(paged=True, page_size=8))):
        cfg = dataclasses.replace(load_arch(arch).smoke(), dtype="float32")
        if name == "ring":
            assert cfg.sliding_window
        params, _ = lm.init(cfg, jax.random.PRNGKey(1))
        prompts = [rng.integers(0, cfg.vocab, n) for n in (8, 5, 11, 8, 3)]
        ref, rs = serve(cfg, params, prompts, **kw)
        got, sv = serve(cfg, params, prompts,
                        mesh=make_serving_mesh((2, 2)), **kw)
        assert got == ref, (name, got, ref)
        assert sv.admit_batches >= 2  # someone was admitted mid-flight
        print("LAYOUT_OK", name)
    print("LAYOUTS_SHARDED_OK")
""")


def test_sharded_server_cache_layouts_parity_4dev():
    out = _run(SUBPROC_LAYOUTS)
    assert "LAYOUTS_SHARDED_OK" in out, out


SUBPROC_DISAGG = _PRELUDE % 4 + textwrap.dedent("""
    # Disaggregated pools (2 prefill devices -> 2 decode devices): the
    # third request is submitted only after the first two are mid-decode,
    # so its prefill->decode handoff lands mid-stream into a live server.
    rng = np.random.default_rng(7)
    cfg = dataclasses.replace(load_arch("smollm_360m").smoke(),
                              dtype="float32")
    params, _ = lm.init(cfg, jax.random.PRNGKey(1))
    prompts = [rng.integers(0, cfg.vocab, n) for n in (8, 5, 11)]

    def staggered(**kw):
        server = LMServer(cfg, params, slots=2, max_seq=64, **kw)
        for i in (0, 1):
            server.submit(Request(i, np.asarray(prompts[i], np.int32), 8))
        server._admit()
        done = []
        for _ in range(3):
            done.extend(server.step())
        server.submit(Request(2, np.asarray(prompts[2], np.int32), 8))
        done.extend(server.run())
        assert len(done) == 3
        return {r.rid: r.out for r in done}, server

    for name, kw in (("contig", {}),
                     ("paged", dict(paged=True, page_size=8))):
        ref, _ = staggered(**kw)
        got, sv = staggered(prefill_devices=2, decode_devices=2, **kw)
        assert got == ref, (name, got, ref)
        snap = sv.metrics.snapshot()
        assert snap["lm_handoffs"] >= 2, snap.get("lm_handoffs")
        assert snap["lm_handoff_latency"]["count"] >= 2
        # per-worker attribution rode along with the handoff
        assert any("worker=" in k for k in snap), list(snap)
        print("DISAGG_OK", name, snap["lm_handoffs"])
    print("DISAGG_HANDOFF_OK")
""")


def test_disagg_handoff_midstream_4dev():
    out = _run(SUBPROC_DISAGG)
    assert "DISAGG_HANDOFF_OK" in out, out


SUBPROC_DONATE = _PRELUDE % 4 + textwrap.dedent("""
    # The PR 4-7 donation invariant must survive sharding. Sharded
    # lowerings drop tf.aliasing_output from the StableHLO text, so
    # assert on the compiled module header instead: every cache leaf
    # must STRICTLY alias its output (a may-alias pair). A leaf demoted
    # to buffer_donor means XLA inserted a device-local cache-sized copy
    # each step because the traced output sharding diverged from the
    # donated input's fitted placement.
    import re
    import jax.numpy as jnp

    for kw in ({}, dict(paged=True, page_size=8)):
        cfg = dataclasses.replace(load_arch("smollm_360m").smoke(),
                                  dtype="float32")
        params, _ = lm.init(cfg, jax.random.PRNGKey(0))
        server = LMServer(cfg, params, slots=2, max_seq=64,
                          mesh=make_serving_mesh((2, 2)), **kw)
        ex = server.ex
        toks = jnp.ones((2, 1), jnp.int32)
        with ex._ctx():
            low = ex._decode.lower(ex.params, toks, server.cache,
                                   jax.random.PRNGKey(0))
            txt = low.as_text()
            hdr = low.compile().as_text().splitlines()[0]
        n_leaves = len(jax.tree.leaves(server.cache))
        n_alias = len(re.findall(r"may-alias", hdr))
        assert n_alias >= n_leaves, (n_alias, n_leaves, hdr)
        assert "buffer_donor" not in hdr, hdr
        assert txt.count("@Sharding") >= 1, "no sharding constraints?"
        print("DONATE_OK", bool(kw))
    print("SHARDED_DONATION_OK")
""")


def test_sharded_decode_hlo_donates_cache_4dev():
    out = _run(SUBPROC_DONATE)
    assert "SHARDED_DONATION_OK" in out, out
