"""Unified kernel engine: the mode registry vs the cycle-exact PPACArray.

Every registry mode must (a) dispatch across all three backends with
bit-identical results and (b) agree with the paper-faithful emulator —
the oracle the issue of versatility hangs on (§III, Table I).
"""
import numpy as np
import pytest

from repro.core import formats as F
from repro.core.ppac import PPACArray, PPACConfig
from repro.kernels.engine import MODES, modes, ppac_matmul

BACKENDS = ("pallas", "ref", "mxu")


@pytest.fixture
def small_array(rng):
    m, n = 32, 48
    a_bits = rng.integers(0, 2, (m, n)).astype(np.uint8)
    arr = PPACArray(PPACConfig(m=m, n=n, rows_per_bank=16, subrow_bits=16))
    arr.write(a_bits)
    return arr, a_bits, m, n


@pytest.mark.parametrize("backend", BACKENDS)
def test_hamming_mode_vs_oracle(rng, small_array, backend):
    arr, a_bits, m, n = small_array
    x_bits = rng.integers(0, 2, (5, n)).astype(np.uint8)
    got = np.asarray(ppac_matmul(F.pack_bits(x_bits), F.pack_bits(a_bits),
                                 mode="hamming", n=n, backend=backend))
    oracle = np.stack([np.asarray(arr.hamming_similarity(x_bits[i]))
                       for i in range(5)])
    assert np.array_equal(got, oracle)


@pytest.mark.parametrize("backend", BACKENDS)
def test_cam_mode_vs_oracle(rng, small_array, backend):
    arr, a_bits, m, n = small_array
    x_bits = a_bits[3:4].copy()
    x_bits[0, :4] ^= 1  # 4 flipped bits
    xp, ap = F.pack_bits(x_bits), F.pack_bits(a_bits)
    for delta in (None, n - 4, n - 3):
        got = np.asarray(ppac_matmul(xp, ap, mode="cam", n=n, delta=delta,
                                     backend=backend))
        oracle = np.asarray(arr.cam_match(x_bits[0], delta=delta))
        assert np.array_equal(got[0].astype(bool), oracle), delta


@pytest.mark.parametrize("fmt_a", ["pm1", "01"])
@pytest.mark.parametrize("fmt_x", ["pm1", "01"])
@pytest.mark.parametrize("backend", BACKENDS)
def test_mvp_1bit_all_format_pairs_vs_oracle(rng, small_array, fmt_a, fmt_x,
                                             backend):
    arr, a_bits, m, n = small_array
    x_bits = rng.integers(0, 2, (4, n)).astype(np.uint8)
    got = np.asarray(ppac_matmul(F.pack_bits(x_bits), F.pack_bits(a_bits),
                                 mode="mvp_1bit", n=n, fmt_a=fmt_a,
                                 fmt_x=fmt_x, backend=backend))
    oracle = np.stack([np.asarray(arr.mvp_1bit(x_bits[i], fmt_a, fmt_x))
                       for i in range(4)])
    assert np.array_equal(got, oracle), (fmt_a, fmt_x)


@pytest.mark.parametrize("backend", BACKENDS)
def test_gf2_mode_vs_oracle(rng, small_array, backend):
    arr, a_bits, m, n = small_array
    x_bits = rng.integers(0, 2, (5, n)).astype(np.uint8)
    got = np.asarray(ppac_matmul(F.pack_bits(x_bits), F.pack_bits(a_bits),
                                 mode="gf2", n=n, backend=backend))
    oracle = np.stack([np.asarray(arr.gf2_mvp(x_bits[i])) for i in range(5)])
    assert np.array_equal(got, oracle)


@pytest.mark.parametrize("backend", BACKENDS)
def test_mvp_multibit_mode_vs_oracle(rng, backend):
    m, n, k, l = 16, 24, 3, 4
    a = rng.integers(-(2 ** (k - 1)), 2 ** (k - 1), (m, n))
    x = rng.integers(-(2 ** (l - 1)), 2 ** (l - 1), (3, n))
    got = np.asarray(ppac_matmul(x, a, mode="mvp_multibit", k_bits=k,
                                 l_bits=l, backend=backend))
    arr = PPACArray(PPACConfig(m=m, n=n))
    oracle = np.stack([np.asarray(arr.mvp_multibit(a, x[i], k, l))
                       for i in range(3)])
    assert np.array_equal(got, oracle)
    assert np.array_equal(got, x @ a.T)


@pytest.mark.parametrize("fmt_a,fmt_x", [("int", "int"), ("uint", "uint"),
                                         ("oddint", "int"),
                                         ("oddint", "oddint")])
@pytest.mark.parametrize("backend", BACKENDS)
def test_mvp_multibit_planes_matches_int_mode(rng, backend, fmt_a, fmt_x):
    # odd n exercises the shape-derived mask lane of the nonzero-offset
    # (oddint) formats: its padding bits must stay zero
    m, n, k, l = 20, 51, 4, 3
    la, ha = F.value_range(fmt_a, k)
    lx, hx = F.value_range(fmt_x, l)
    a = rng.choice(np.arange(la, ha + 1, 2 if fmt_a == "oddint" else 1),
                   size=(m, n))
    x = rng.choice(np.arange(lx, hx + 1, 2 if fmt_x == "oddint" else 1),
                   size=(4, n))
    a_planes = F.pack_planes(a, k, F.fmt(fmt_a))  # [K, M, W]
    got = np.asarray(ppac_matmul(x, a_planes, mode="mvp_multibit_planes",
                                 n=n, k_bits=k, l_bits=l, fmt_a=fmt_a,
                                 fmt_x=fmt_x, backend=backend))
    assert np.array_equal(got, x @ a.T), (fmt_a, fmt_x)


@pytest.mark.parametrize("fmt_a,fmt_x", [("int", "int"), ("uint", "uint"),
                                         ("oddint", "int"),
                                         ("oddint", "oddint")])
@pytest.mark.parametrize("backend", BACKENDS)
def test_mvp_multibit_resident_matches_planes_mode(rng, backend, fmt_a,
                                                   fmt_x):
    """The zero-repack decode fast path (in-kernel activation bit-slicing)
    is bit-identical to the planes mode and exact on every format pair."""
    m, n, k, l = 20, 51, 4, 3
    la, ha = F.value_range(fmt_a, k)
    lx, hx = F.value_range(fmt_x, l)
    a = rng.choice(np.arange(la, ha + 1, 2 if fmt_a == "oddint" else 1),
                   size=(m, n))
    x = rng.choice(np.arange(lx, hx + 1, 2 if fmt_x == "oddint" else 1),
                   size=(4, n))
    a_planes = F.pack_planes(a, k, F.fmt(fmt_a))
    kw = dict(n=n, k_bits=k, l_bits=l, fmt_a=fmt_a, fmt_x=fmt_x,
              backend=backend)
    got = np.asarray(ppac_matmul(x, a_planes,
                                 mode="mvp_multibit_resident", **kw))
    via_planes = np.asarray(ppac_matmul(x, a_planes,
                                        mode="mvp_multibit_planes", **kw))
    assert np.array_equal(got, via_planes), (fmt_a, fmt_x)
    assert np.array_equal(got, x @ a.T), (fmt_a, fmt_x)


@pytest.mark.parametrize("backend", BACKENDS)
def test_topk_mode_agrees_with_cam_scores(rng, backend):
    n, m = 64, 40
    a_bits = rng.integers(0, 2, (m, n)).astype(np.uint8)
    x_bits = rng.integers(0, 2, (3, n)).astype(np.uint8)
    xp, ap = F.pack_bits(x_bits), F.pack_bits(a_bits)
    scores, ids = ppac_matmul(xp, ap, mode="topk", n=n, k=5, backend=backend)
    h = (x_bits[:, None, :] == a_bits[None, :, :]).sum(-1)
    best = np.sort(h, axis=1)[:, ::-1][:, :5]
    assert np.array_equal(np.asarray(scores), best)
    assert np.array_equal(np.asarray(scores),
                          np.take_along_axis(h, np.asarray(ids), axis=1))


def test_registry_surface():
    listed = modes()
    assert set(listed) == set(MODES)
    for want in ("hamming", "cam", "topk", "mvp_1bit", "mvp_multibit",
                 "mvp_multibit_planes", "gf2"):
        assert want in listed
    with pytest.raises(ValueError, match="unknown PPAC mode"):
        ppac_matmul(np.zeros((1, 1), np.uint32), np.zeros((1, 1), np.uint32),
                    mode="nope")
