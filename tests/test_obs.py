"""Flight recorder: instruction-ledger/cost-model agreement, zero
overhead when disabled, metrics registry invariants, Chrome-trace export.

The load-bearing property is *structural*: the live ledger (records
captured at the dispatch chokepoint) and the static
``serving_cycle_report`` both price launches through
``obs.ledger.record_for``, so their totals must agree bit-exactly for
every container kind — packed1, packed4, oddint (mask plane), the int8
MXU fallback, and grouped (fused wqkv-style) projections.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig, PPACModeConfig
from repro.core.engine import (
    pack_weight_for_serving,
    serve_dense,
    serve_dense_grouped,
)
from repro.kernels.engine import ppac_matmul
from repro.obs import Ledger, MetricsRegistry, TraceBuilder
from repro.obs import ledger as obs_ledger


def _cfg(weight_bits, weight_format="int", act_bits=4):
    ppac = PPACModeConfig(enabled=True, weight_bits=weight_bits,
                          act_bits=act_bits, weight_format=weight_format)
    return ModelConfig(name="t", family="t", n_layers=1, d_model=64,
                       n_heads=2, n_kv_heads=2, d_ff=128, vocab=32,
                       ppac=ppac)


@pytest.mark.parametrize("weight_bits,weight_format,kind", [
    (1, "int", "packed1"),
    (4, "int", "packed4"),
    (4, "oddint", "packed4"),   # extra resident mask plane
    (8, "int", "int8"),         # MXU fallback, bypasses ppac_matmul
])
def test_ledger_matches_cycle_report(weight_bits, weight_format, kind):
    """One token through serve_dense records exactly the cycles/energy
    the static report replays for that projection — bit-exact."""
    from repro.serve.step import serving_cycle_report

    cfg = _cfg(weight_bits, weight_format)
    rng = np.random.default_rng(0)
    w = rng.standard_normal((64, 128)).astype(np.float32) * 0.1
    c = pack_weight_for_serving(jnp.asarray(w), weight_bits=weight_bits,
                                weight_format=weight_format)
    assert c.kind == kind
    report = serving_cycle_report({"blk": {"w": c}}, cfg)

    x = jnp.asarray(rng.standard_normal((1, 64)).astype(np.float32))
    with Ledger() as led:
        serve_dense(x, c, act_bits=cfg.ppac.act_bits, backend="mxu")

    assert led.total_cycles == report.cycles_per_token
    assert led.total_energy_nj == pytest.approx(report.energy_nj_per_token)
    (rec,) = led.records
    assert not rec.traced          # eager call: per-execution record
    assert rec.m_rows == 128 and rec.n_bits == 64
    if kind == "int8":
        assert rec.mode == "mvp_int8_mxu"
    else:
        assert rec.mode == "mvp_multibit_resident"


def test_ledger_matches_cycle_report_grouped():
    """A grouped (fused wqkv-style) container: one fat launch, priced at
    the fused [sum(out), in] shape on both sides."""
    from repro.serve.step import serving_cycle_report

    cfg = _cfg(4)
    rng = np.random.default_rng(1)
    splits = (48, 48, 32)
    w = rng.standard_normal((64, sum(splits))).astype(np.float32) * 0.1
    c = pack_weight_for_serving(jnp.asarray(w), weight_bits=4,
                                splits=splits)
    report = serving_cycle_report({"wqkv": {"w": c}}, cfg)
    assert report.projections[0].d_out == sum(splits)

    x = jnp.asarray(rng.standard_normal((1, 64)).astype(np.float32))
    with Ledger() as led:
        outs = serve_dense_grouped(x, c, act_bits=cfg.ppac.act_bits,
                                   backend="mxu")
    assert tuple(o.shape[-1] for o in outs) == splits
    assert len(led.records) == 1  # ONE fused launch for the group
    assert led.total_cycles == report.cycles_per_token
    assert led.total_energy_nj == pytest.approx(report.energy_nj_per_token)


def test_ledger_batch_scaling_and_plan_capture():
    """Cycles scale linearly in the streamed batch; pallas launches
    capture the resolved tile plan on the record."""
    rng = np.random.default_rng(2)
    c = pack_weight_for_serving(
        jnp.asarray(rng.standard_normal((64, 128)).astype(np.float32)),
        weight_bits=4)
    xs = [jnp.asarray(rng.standard_normal((b, 64)).astype(np.float32))
          for b in (1, 3)]
    with Ledger() as led:
        for x in xs:
            serve_dense(x, c, act_bits=4, backend="mxu")
    r1, r3 = led.records
    assert r3.cycles == 3 * r1.cycles
    assert r3.energy_nj == pytest.approx(3 * r1.energy_nj)
    assert led.by_mode()["mvp_multibit_resident"]["launches"] == 2


def test_ledger_nesting_is_independent():
    """Nested ledgers each see the launches issued while they are open."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(
        rng.integers(0, 2**32, (2, 2), dtype=np.uint64).astype(np.uint32))
    a = jnp.asarray(
        rng.integers(0, 2**32, (4, 2), dtype=np.uint64).astype(np.uint32))
    with Ledger() as outer:
        ppac_matmul(x, a, mode="hamming", n=64, backend="mxu")
        with Ledger() as inner:
            ppac_matmul(x, a, mode="hamming", n=64, backend="mxu")
    assert len(inner.records) == 1
    assert len(outer.records) == 2
    assert outer.total_cycles == 2 * inner.total_cycles


def test_zero_overhead_when_disabled(monkeypatch):
    """With no ledger open, the instrumented paths never touch the
    recorder beyond the single ``active()`` check — the README's
    zero-overhead-when-disabled guarantee."""
    def boom(*a, **k):  # pragma: no cover - must never run
        raise AssertionError("recorder invoked with no ledger open")

    monkeypatch.setattr(obs_ledger, "recorded_launch", boom)
    monkeypatch.setattr(obs_ledger, "record_launch", boom)
    assert not obs_ledger.active()

    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((1, 64)).astype(np.float32))
    for wb in (4, 8):  # fused path and the int8 MXU fallback
        c = pack_weight_for_serving(
            jnp.asarray(rng.standard_normal((64, 96)).astype(np.float32)),
            weight_bits=wb)
        serve_dense(x, c, act_bits=4, backend="mxu")


def test_metrics_registry_snapshot_and_percentiles():
    m = MetricsRegistry()
    m.counter("reqs").inc()
    m.counter("reqs").inc(4)
    m.gauge("occ").set(3)
    m.gauge("occ").set(1)
    h = m.histogram("lat_s")
    for v in np.linspace(0.001, 0.1, 100):
        h.record(float(v))
    snap = m.snapshot()
    assert snap["reqs"] == 5
    assert snap["occ"] == {"value": 1, "max": 3}
    assert snap["lat_s"]["count"] == 100
    assert snap["lat_s"]["min"] == pytest.approx(0.001)
    assert snap["lat_s"]["max"] == pytest.approx(0.1)
    # percentiles are bucket-interpolated estimates: ordered + in-range
    p50, p90 = h.percentile(50), h.percentile(90)
    assert 0.001 <= p50 <= p90 <= 0.1
    assert abs(p50 - 0.05) < 0.02
    json.dumps(snap)  # the CI artifact format must be JSON-serializable

    text = m.prometheus_text()
    assert "# TYPE reqs counter" in text and "reqs 5" in text
    assert "# TYPE occ gauge" in text
    assert '# TYPE lat_s summary' in text and 'quantile="0.5"' in text

    with pytest.raises(AssertionError):  # name/type collisions are bugs
        m.gauge("reqs")


def test_metrics_labels_and_escaping():
    """Per-worker labels: distinct (name, labels) pairs are distinct
    metrics, unlabeled names keep their bare snapshot keys, and
    exposition output escapes hostile label values (a worker id with a
    quote must not corrupt the whole scrape)."""
    m = MetricsRegistry()
    m.counter("lm_worker_dispatches", worker="p0", role="prefill").inc(2)
    m.counter("lm_worker_dispatches", worker="d0", role="disagg").inc()
    m.counter("lm_worker_dispatches", worker="p0", role="prefill").inc()
    m.histogram("lm_handoff_latency").record(0.002)
    m.histogram("lm_handoff_latency", worker="p0").record(0.002)

    snap = m.snapshot()
    # canonical sorted-label keys; same labels -> same instance
    assert snap['lm_worker_dispatches{role="prefill",worker="p0"}'] == 3
    assert snap['lm_worker_dispatches{role="disagg",worker="d0"}'] == 1
    # the unlabeled histogram keeps its bare-name key (back-compat)
    assert snap["lm_handoff_latency"]["count"] == 1
    assert snap['lm_handoff_latency{worker="p0"}']["count"] == 1

    text = m.prometheus_text()
    assert 'lm_worker_dispatches{role="prefill",worker="p0"} 3' in text
    # one TYPE line per metric family, not per labeled instance
    assert text.count("# TYPE lm_worker_dispatches counter") == 1

    hostile = MetricsRegistry()
    hostile.counter("c", worker='p"0\\x\n').inc()
    line = next(l for l in hostile.prometheus_text().splitlines()
                if l.startswith("c{"))
    assert line == 'c{worker="p\\"0\\\\x\\n"} 1'


def test_trace_export_valid_and_monotonic():
    """Trace output: valid JSON, named tracks, per-track monotonic ts,
    ledger launch events carrying cycles/energy args."""
    rng = np.random.default_rng(5)
    c = pack_weight_for_serving(
        jnp.asarray(rng.standard_normal((64, 128)).astype(np.float32)),
        weight_bits=4)
    x = jnp.asarray(rng.standard_normal((2, 64)).astype(np.float32))
    trace = TraceBuilder()
    with Ledger() as led:
        with trace.span("step", args=dict(i=0)):
            serve_dense(x, c, act_bits=4, backend="mxu")
        with trace.span("step", args=dict(i=1)):
            serve_dense(x, c, act_bits=4, backend="mxu")
    trace.add_ledger(led)

    payload = json.loads(json.dumps(trace.to_dict()))
    events = payload["traceEvents"]
    tracks = {e["args"]["name"]: e["tid"] for e in events
              if e["ph"] == "M" and e["name"] == "thread_name"}
    assert set(tracks) == {"server", "ppac"}
    xs = [e for e in events if e["ph"] == "X"]
    for tid in tracks.values():
        ts = [e["ts"] for e in xs if e["tid"] == tid]
        assert ts == sorted(ts) and ts[0] >= 0
    launches = [e for e in xs if e["tid"] == tracks["ppac"]]
    assert len(launches) == 2
    for e in launches:
        assert e["args"]["cycles"] > 0 and e["args"]["energy_nj"] > 0
        assert e["dur"] > 0
