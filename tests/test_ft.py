"""Fault-tolerant training harness (launch/ft.py): heartbeat files,
stale-heartbeat supervision, elastic crash recovery."""
import json
import os
import subprocess
import sys
import time

import pytest

from conftest import cpu_subproc_env
from repro.launch.ft import (
    HEARTBEAT,
    Coordinator,
    read_heartbeat,
    write_heartbeat,
)


def test_heartbeat_round_trip(tmp_path):
    run_dir = str(tmp_path)
    assert read_heartbeat(run_dir, 0) is None
    write_heartbeat(run_dir, 0, step=7)
    hb = read_heartbeat(run_dir, 0)
    assert hb["step"] == 7
    assert abs(hb["time"] - time.time()) < 5.0
    # atomic replace: no .tmp residue, rewrite wins
    assert not os.path.exists(
        os.path.join(run_dir, HEARTBEAT.format(rank=0)) + ".tmp")
    write_heartbeat(run_dir, 0, step=8)
    assert read_heartbeat(run_dir, 0)["step"] == 8
    # a torn/corrupt file reads as None, not an exception
    with open(os.path.join(run_dir, HEARTBEAT.format(rank=1)), "w") as f:
        f.write("{not json")
    assert read_heartbeat(run_dir, 1) is None


def test_coordinator_ignores_stale_heartbeats(tmp_path):
    """Regression: heartbeats left by a PREVIOUS run must not trip the
    straggler detector of a new coordinator — they are cleared at
    construction and ``_fresh`` rejects anything pre-dating start."""
    run_dir = str(tmp_path)
    # a plausible-but-old heartbeat from a prior run
    path = os.path.join(run_dir, HEARTBEAT.format(rank=0))
    with open(path, "w") as f:
        json.dump({"step": 12, "time": time.time() - 3600.0}, f)
    coord = Coordinator(run_dir, ["true"], straggler_timeout=0.1)
    assert not os.path.exists(path), "stale heartbeat file not cleared"
    # even if a file with an old timestamp reappears, _fresh rejects it
    assert coord._fresh({"step": 12, "time": coord.start_time - 1.0}) is None
    assert coord._fresh(None) is None
    fresh = {"step": 13, "time": coord.start_time + 1.0}
    assert coord._fresh(fresh) == fresh


def test_coordinator_restarts_use_clean_cmd(tmp_path):
    """First spawn runs worker_cmd (with the injected crash); every
    restart runs clean_cmd so the crash is not re-injected."""
    coord = Coordinator(str(tmp_path), ["crashy"], clean_cmd=["clean"])
    seen = []
    import repro.launch.ft as ft
    orig = ft.subprocess.Popen
    try:
        ft.subprocess.Popen = lambda cmd, **kw: seen.append(cmd)
        coord._spawn()
        coord.restarts = 1
        coord._spawn()
        coord.clean_cmd = None
        coord._spawn()
    finally:
        ft.subprocess.Popen = orig
    assert seen == [["crashy"], ["clean"], ["crashy"]]


@pytest.mark.slow
def test_crash_restart_converges(tmp_path):
    """End-to-end recovery demo: the worker SIGKILLs itself mid-run, the
    coordinator restarts it clean from the latest checkpoint, and the job
    finishes rc=0 after exactly one restart."""
    run_dir = str(tmp_path / "run")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.ft", "--run-dir", run_dir,
         "--steps", "12", "--ckpt-every", "4", "--kill-at", "7",
         "--straggler-timeout", "120"],
        env=cpu_subproc_env(), capture_output=True, text=True, timeout=900,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    assert "injected crash at step 7" in out.stdout
    assert "restart 1/" in out.stdout
    assert "finished rc=0 restarts=1" in out.stdout
