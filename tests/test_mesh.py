"""Serving-mesh construction: pure submesh fitting, mesh-spec parsing,
and the graceful fallback on a real (forced-host) 4-device runtime."""
import subprocess
import sys
import textwrap

import pytest
from conftest import cpu_subproc_env

from repro.launch.mesh import fit_mesh_shape, parse_mesh_spec


def test_fit_mesh_shape_identity_when_it_fits():
    assert fit_mesh_shape((2, 2), 4) == (2, 2)
    assert fit_mesh_shape((1, 1), 1) == (1, 1)
    assert fit_mesh_shape((4,), 8) == (4,)


def test_fit_mesh_shape_halves_largest_axis():
    # 16x16 on 4 devices: the power-of-two walk lands on 2x2
    assert fit_mesh_shape((16, 16), 4) == (2, 2)
    # asymmetric: the bigger axis gives first
    assert fit_mesh_shape((8, 2), 4) == (2, 2)
    assert fit_mesh_shape((2, 8), 4) == (2, 2)
    # 3-axis pods shrink the same way
    assert fit_mesh_shape((2, 16, 16), 8) == (2, 2, 2)


def test_fit_mesh_shape_clamps_degenerate_inputs():
    # 3 halves to 1 (the walk stays on the power-of-two lattice)
    assert fit_mesh_shape((0, 3), 2) == (1, 1)
    assert fit_mesh_shape((7, 1), 1) == (1, 1)
    with pytest.raises(ValueError):
        fit_mesh_shape((2, 2), 0)


def test_fit_mesh_shape_axes_only_shrink():
    # an axis the caller left at 1 must stay 1 (pure-TP and pure-DP
    # requests keep their meaning after the fallback)
    for shape in ((1, 8), (8, 1)):
        fitted = fit_mesh_shape(shape, 4)
        for orig, new in zip(shape, fitted):
            assert new <= orig
        assert fitted[shape.index(1)] == 1


def test_parse_mesh_spec():
    assert parse_mesh_spec("2x2") == (2, 2)
    assert parse_mesh_spec("1x4") == (1, 4)
    assert parse_mesh_spec("2X2x2") == (2, 2, 2)
    assert parse_mesh_spec("4") == (4,)
    for bad in ("", "2x", "ax2", "2x2x2x2", "0x2", "-1x2"):
        with pytest.raises(ValueError):
            parse_mesh_spec(bad)


SUBPROC_FALLBACK = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import warnings
    import jax
    from repro.launch.mesh import make_serving_mesh

    # exact fit: no warning, requested shape honored
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        mesh = make_serving_mesh((2, 2))
    assert dict(mesh.shape) == {"data": 2, "model": 2}, mesh.shape
    assert mesh.axis_names == ("data", "model")

    # oversubscribed: falls back to the largest valid submesh with a
    # warning instead of raising from inside a jitted computation
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        mesh = make_serving_mesh((8, 8))
    assert dict(mesh.shape) == {"data": 2, "model": 2}, mesh.shape
    assert any("largest valid submesh" in str(x.message) for x in w), \\
        [str(x.message) for x in w]

    # explicit device list narrows the pool (the disaggregated server
    # carves prefill/decode slices this way)
    devs = jax.devices()[2:]
    mesh = make_serving_mesh((1, 2), devices=devs)
    assert sorted(d.id for d in mesh.devices.ravel()) == \\
        sorted(d.id for d in devs)

    # 3-axis specs get the pod axis
    mesh = make_serving_mesh((1, 2, 2))
    assert mesh.axis_names == ("pod", "data", "model")
    print("MESH_FALLBACK_OK")
""")


def test_serving_mesh_fallback_4dev():
    res = subprocess.run([sys.executable, "-c", SUBPROC_FALLBACK],
                         capture_output=True, text=True, timeout=600,
                         env=cpu_subproc_env())
    assert "MESH_FALLBACK_OK" in res.stdout, res.stdout + res.stderr
