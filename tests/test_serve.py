"""Serving path: generation loop, PPAC weight conversion, quantized decode."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import load_arch
from repro.core.engine import QuantContainer
from repro.core.ppac import PPACConfig
from repro.models import lm
from repro.serve.step import (
    convert_params_for_serving,
    greedy_generate,
    serving_cycle_report,
)


def test_greedy_generate_shapes():
    cfg = load_arch("smollm_360m").smoke()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 8), jnp.int32)}
    out = greedy_generate(params, cfg, batch, steps=5, max_seq=32)
    assert out.shape == (2, 5)
    assert (np.asarray(out) >= 0).all() and (np.asarray(out) < cfg.vocab).all()


def test_convert_params_replaces_projections():
    cfg = load_arch("stablelm_12b").smoke()
    cfg = dataclasses.replace(
        cfg, ppac=dataclasses.replace(cfg.ppac, enabled=True, weight_bits=4,
                                      min_features=32))
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    served = convert_params_for_serving(params, cfg)
    containers = [l for l in jax.tree.leaves(
        served, is_leaf=lambda x: isinstance(x, QuantContainer))
        if isinstance(x := l, QuantContainer)]
    assert len(containers) > 0
    # embeddings/norms untouched
    assert served["embed"]["table"].dtype == params["embed"]["table"].dtype
    # packed4 halves the `in` dim
    c = containers[0]
    assert c.kind == "packed4"


@pytest.mark.parametrize("bits", [8, 4])
def test_quantized_decode_close_to_float(bits):
    cfg = dataclasses.replace(load_arch("stablelm_12b").smoke(),
                              dtype="float32")
    cfg = dataclasses.replace(
        cfg, ppac=dataclasses.replace(cfg.ppac, enabled=True,
                                      weight_bits=bits, act_bits=8,
                                      min_features=32))
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    served = convert_params_for_serving(params, cfg)
    tokens = jnp.asarray(np.random.default_rng(0).integers(
        0, cfg.vocab, (2, 12)), jnp.int32)

    logits_f, _ = lm.forward(params, cfg, {"tokens": tokens})
    logits_q, _ = lm.forward(served, cfg, {"tokens": tokens}, mode="serve")
    lf, lq = np.asarray(logits_f), np.asarray(logits_q)
    corr = np.corrcoef(lf.ravel(), lq.ravel())[0, 1]
    assert corr > 0.97, corr
    # top-1 agreement on most positions
    agree = (lf.argmax(-1) == lq.argmax(-1)).mean()
    assert agree > 0.7, agree


@pytest.mark.parametrize("bits,kind,kl", [(1, "packed1", 1), (4, "packed4", 32)])
def test_serving_cycle_report(bits, kind, kl):
    cfg = load_arch("stablelm_12b").smoke()
    cfg = dataclasses.replace(
        cfg, ppac=dataclasses.replace(cfg.ppac, enabled=True,
                                      weight_bits=bits, act_bits=8,
                                      min_features=32))
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    served = convert_params_for_serving(params, cfg)
    rep = serving_cycle_report(served, cfg)
    assert rep.num_projections > 0
    assert rep.cycles_per_token > 0
    # every converted projection runs on the fused kernels
    assert all(p.fused and p.kind == kind for p in rep.projections)
    assert rep.fused_cycles_per_token == rep.cycles_per_token
    # K*L plane-pair passes per tile-grid scan (packed1: one XNOR pass)
    one = rep.projections[0]
    assert one.k_bits * one.l_bits == kl
    assert rep.est_us_per_token() is not None  # 256x256 is in Table II
    d = rep.as_dict()
    assert d["cycles_per_token"] == rep.cycles_per_token
    # a 16x16 array needs strictly more tile-grid scans than the default
    # 256x256 for this model's projections — guards that the geometry
    # actually flows into the accounting
    tiny = serving_cycle_report(served, cfg,
                                config=PPACConfig(m=16, n=16))
    assert tiny.cycles_per_token > rep.cycles_per_token


def test_quantized_generation_runs():
    cfg = load_arch("smollm_360m").smoke()
    cfg = dataclasses.replace(
        cfg, ppac=dataclasses.replace(cfg.ppac, enabled=True, weight_bits=8,
                                      act_bits=8, min_features=32))
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    served = convert_params_for_serving(params, cfg)
    batch = {"tokens": jnp.ones((1, 8), jnp.int32)}
    out = greedy_generate(served, cfg, batch, steps=4, max_seq=32,
                          mode="serve")
    assert out.shape == (1, 4)
