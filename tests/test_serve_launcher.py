"""Continuous-batching server: slot management, bucketed admission,
mid-flight result parity, EOS retirement."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import load_arch
from repro.launch.bucketed import bucket_for, drain_take
from repro.launch.serve import BatchServer, Request
from repro.launch.serve_lm import LMServer
from repro.models import lm
from repro.serve.step import greedy_generate


def test_server_completes_all_requests():
    cfg = load_arch("smollm_360m").smoke()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    server = BatchServer(cfg, params, slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, int(rng.integers(4, 12))),
                    max_new=5) for i in range(5)]
    for r in reqs:
        server.submit(r)
    done = server.run()
    assert len(done) == 5
    assert all(len(r.out) >= 5 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out)


def test_server_single_request_matches_greedy():
    """One request through the batched server == greedy_generate."""
    cfg = dataclasses.replace(load_arch("smollm_360m").smoke(),
                              dtype="float32")
    params, _ = lm.init(cfg, jax.random.PRNGKey(1))
    prompt = np.arange(1, 9, dtype=np.int32)  # len 8 == bucket -> no padding

    server = BatchServer(cfg, params, slots=1, max_seq=64)
    server.submit(Request(0, prompt, max_new=6))
    done = server.run()

    ref = greedy_generate(params, cfg, {"tokens": prompt[None, :]},
                          steps=6, max_seq=64)
    assert done[0].out[:6] == list(np.asarray(ref)[0][:6])


@pytest.mark.parametrize("arch,kv,paged", [
    ("smollm_360m", "bfloat16", False),
    ("h2o_danube3_4b", "bfloat16", False),
    ("stablelm_12b", "int8", False),
    ("smollm_360m", "bfloat16", True),
    ("h2o_danube3_4b", "bfloat16", True),
    ("stablelm_12b", "int8", True),
])
def test_midflight_admission_bit_identical_to_solo(arch, kv, paged):
    """The acceptance property of per-sequence positions: requests
    admitted into free slots while other sequences keep decoding produce
    tokens bit-identical to generating each prompt alone — across linear,
    rolling (sliding-window) and int8-quantized caches, with ragged
    prompt lengths (right-padded bucketed prefill). The paged variants
    route every cache read/write through the block table and must stay
    bit-identical to the contiguous layout."""
    cfg = dataclasses.replace(load_arch(arch).smoke(), dtype="float32",
                              kv_dtype=kv)
    params, _ = lm.init(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (8, 5, 11, 8, 3)]

    solo = [list(np.asarray(greedy_generate(
        params, cfg, {"tokens": np.asarray(p)[None, :]}, steps=6,
        max_seq=64))[0]) for p in prompts]

    # 2 slots, 5 requests: requests 2..4 are necessarily admitted
    # mid-flight, into slots whose neighbors are mid-generation.
    kw = {"paged": True, "page_size": 8} if paged else {}
    server = LMServer(cfg, params, slots=2, max_seq=64, **kw)
    for i, p in enumerate(prompts):
        server.submit(Request(i, p, max_new=6))
    done = server.run()
    assert len(done) == len(prompts)
    assert server.admit_batches >= 2  # someone was admitted mid-flight
    for r in done:
        assert r.out[:6] == solo[r.rid], (r.rid, r.out[:6], solo[r.rid])


def test_eos_retirement_frees_slot_early():
    """A sequence hitting EOS retires immediately (finish_reason='eos');
    the freed slot is refilled from the queue."""
    cfg = load_arch("smollm_360m").smoke()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    server = LMServer(cfg, params, slots=1, max_seq=64)
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, cfg.vocab, 8)
    # probe the greedy continuation of THIS prompt; its second token is a
    # token the real run is guaranteed to emit -> usable as EOS.
    probe = LMServer(cfg, params, slots=1, max_seq=64)
    probe.submit(Request(0, prompt, max_new=4))
    eos = probe.run()[0].out[1]

    server.submit(Request(0, prompt, max_new=50, eos=int(eos)))
    server.submit(Request(1, rng.integers(0, cfg.vocab, 8), max_new=3))
    done = server.run()
    by_rid = {r.rid: r for r in done}
    assert by_rid[0].finish_reason == "eos"
    assert len(by_rid[0].out) < 50 and by_rid[0].out[-1] == eos
    assert by_rid[1].finish_reason == "length" and len(by_rid[1].out) == 3


def test_admission_uses_batch_buckets():
    """Admission drains waiting prompts in bucketed batches (shared
    drain policy), not one prefill per request."""
    cfg = load_arch("smollm_360m").smoke()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    server = LMServer(cfg, params, slots=4, max_seq=64)
    rng = np.random.default_rng(0)
    for i in range(4):  # same length bucket -> one batched prefill
        server.submit(Request(i, rng.integers(0, cfg.vocab, 6), max_new=4))
    done = server.run()
    assert len(done) == 4
    assert server.admit_batches == 1


def test_bucket_policy_helpers():
    assert bucket_for(3, (1, 2, 4)) == 4
    # overflow is a caller bug (a batch that can't fit its bucket): the
    # old clamp silently truncated payload rows
    with pytest.raises(ValueError):
        bucket_for(9, (1, 2, 4))
    assert drain_take(7, (1, 4, 16)) == (4, 4)  # whole bucket, unpadded
    assert drain_take(3, (1, 4, 16)) == (3, 4)  # remainder, padded
    assert drain_take(1, (1, 4, 16)) == (1, 1)
    assert drain_take(9, (1, 2, 4)) == (4, 4)   # drain_take caps, no raise


def test_ssm_server_matches_solo_generation():
    """SSM archs must serve unpadded (state accumulation has no position
    mask): ragged prompts still come out bit-identical to solo runs."""
    cfg = dataclasses.replace(load_arch("mamba2_370m").smoke(),
                              dtype="float32")
    params, _ = lm.init(cfg, jax.random.PRNGKey(1))
    rng = np.random.default_rng(2)
    prompts = [rng.integers(0, cfg.vocab, n).astype(np.int32)
               for n in (5, 7, 5)]
    solo = [list(np.asarray(greedy_generate(
        params, cfg, {"tokens": np.asarray(p)[None, :]}, steps=6,
        max_seq=64))[0]) for p in prompts]
    server = LMServer(cfg, params, slots=2, max_seq=64)
    assert not server.pad_prompts
    for i, p in enumerate(prompts):
        server.submit(Request(i, p, max_new=6))
    done = server.run()
    for r in done:
        assert r.out[:6] == solo[r.rid], (r.rid, r.out[:6], solo[r.rid])


def test_long_prompts_admissible_up_to_max_seq():
    """Prefill buckets derive from max_seq: prompts longer than the old
    fixed 64-token top bucket are servable."""
    cfg = load_arch("smollm_360m").smoke()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    server = LMServer(cfg, params, slots=1, max_seq=160)
    assert server.prefill_buckets[-1] == 160
    rng = np.random.default_rng(0)
    server.submit(Request(0, rng.integers(0, cfg.vocab, 100), max_new=4))
    done = server.run()
    assert len(done) == 1 and len(done[0].out) == 4


def test_metrics_invariants_under_midflight_admission():
    """Telemetry conservation laws hold when requests are admitted into
    slots whose neighbors are mid-generation: every submitted request is
    admitted, timed, and retired exactly once; occupancy never exceeds
    the slot count; the token counter matches the decoded output."""
    cfg = load_arch("smollm_360m").smoke()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    server = LMServer(cfg, params, slots=2, max_seq=64)
    rng = np.random.default_rng(3)
    n = 5  # 5 requests / 2 slots: 3 are necessarily admitted mid-flight
    for i in range(n):
        server.submit(Request(i, rng.integers(0, cfg.vocab,
                                              int(rng.integers(4, 12))),
                              max_new=5))
    done = server.run()
    assert len(done) == n and server.admit_batches >= 2

    snap = server.metrics.snapshot()
    assert snap["lm_requests_submitted"] == n
    assert snap["lm_requests_admitted"] == n
    assert snap["lm_requests_retired"] == n
    assert snap["lm_slots_evicted"] == n
    assert snap["lm_finish_length"] == n
    # every request timed exactly once, end to end
    for hist in ("lm_ttft_s", "lm_queue_wait_s", "lm_request_latency_s",
                 "lm_tpot_s"):
        assert snap[hist]["count"] == n, hist
        assert snap[hist]["min"] >= 0
    assert all(r.latency_s is not None and r.latency_s >= 0 for r in done)
    # TTFT (prefill included) can never beat pure queue wait
    assert snap["lm_ttft_s"]["sum"] >= snap["lm_queue_wait_s"]["sum"]
    # occupancy bounded by slots; its integral is the decoded tokens
    assert snap["lm_slot_occupancy"]["max"] <= server.slots
    decoded = sum(len(r.out) - 1 for r in done)  # first token <- prefill
    # the counter includes the prefill-emitted first tokens, so it
    # matches the tok/s numerator sum(len(r.out)); the occupancy
    # integral stays decode-only
    assert snap["lm_tokens_generated"] == decoded + n
    assert snap["lm_tokens_generated"] == sum(len(r.out) for r in done)
    assert snap["lm_slot_occupancy_per_step"]["sum"] == decoded
    assert snap["lm_decode_step_s"]["count"] == server.decode_steps
    assert snap["lm_prefill_batches"] == server.admit_batches


def test_sampling_server_stays_in_vocab():
    cfg = load_arch("smollm_360m").smoke()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    server = LMServer(cfg, params, slots=2, max_seq=64, temperature=0.9,
                      top_k=12, seed=3)
    rng = np.random.default_rng(1)
    for i in range(3):
        server.submit(Request(i, rng.integers(0, cfg.vocab, 7), max_new=6))
    done = server.run()
    assert len(done) == 3
    assert all(0 <= t < cfg.vocab for r in done for t in r.out)
