"""Continuous-batching server: correctness of slot management + outputs."""
import dataclasses

import jax
import numpy as np

from repro.configs import load_arch
from repro.launch.serve import BatchServer, Request
from repro.models import lm
from repro.serve.step import greedy_generate


def test_server_completes_all_requests():
    cfg = load_arch("smollm_360m").smoke()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    server = BatchServer(cfg, params, slots=2, max_seq=64)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, int(rng.integers(4, 12))),
                    max_new=5) for i in range(5)]
    for r in reqs:
        server.submit(r)
    done = server.run()
    assert len(done) == 5
    assert all(len(r.out) >= 5 for r in done)
    assert all(0 <= t < cfg.vocab for r in done for t in r.out)


def test_server_single_request_matches_greedy():
    """One request through the batched server == greedy_generate."""
    cfg = dataclasses.replace(load_arch("smollm_360m").smoke(),
                              dtype="float32")
    params, _ = lm.init(cfg, jax.random.PRNGKey(1))
    prompt = np.arange(1, 9, dtype=np.int32)  # len 8 == bucket -> no padding

    server = BatchServer(cfg, params, slots=1, max_seq=64)
    server.submit(Request(0, prompt, max_new=6))
    done = server.run()

    ref = greedy_generate(params, cfg, {"tokens": prompt[None, :]},
                          steps=6, max_seq=64)
    assert done[0].out[:6] == list(np.asarray(ref)[0][:6])
