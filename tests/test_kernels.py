"""Pallas kernels vs pure-jnp oracles: shape/dtype/format sweeps + hypothesis.

All kernels run in interpret mode on CPU (TPU is the lowering target);
results must be bit-exact (integer arithmetic — the property the paper
claims over mixed-signal PIM)."""
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis or skip-shim

from repro.core import formats as F
from repro.kernels.binary_mvp.kernel import binary_matmul_packed
from repro.kernels.binary_mvp.ops import (
    and_dot,
    cam_match,
    gf2_matmul,
    hamming_similarity,
    inner_product_pm1,
    pla_eval,
)
from repro.kernels.binary_mvp.ref import binary_matmul_packed_ref
from repro.kernels.bitserial_mvp.kernel import bitserial_matmul_packed
from repro.kernels.bitserial_mvp.ops import build_planes_and_weights, ppac_matmul
from repro.kernels.bitserial_mvp.ref import bitserial_matmul_packed_ref


@pytest.mark.parametrize("b,m,n", [(1, 1, 1), (3, 5, 7), (8, 16, 32),
                                   (9, 33, 100), (64, 128, 256),
                                   (17, 130, 513)])
@pytest.mark.parametrize("op", ["xor", "and"])
def test_binary_kernel_shapes(rng, b, m, n, op):
    x = F.pack_bits(rng.integers(0, 2, (b, n)))
    a = F.pack_bits(rng.integers(0, 2, (m, n)))
    got = np.asarray(binary_matmul_packed(x, a, op=op, interpret=True))
    ref = np.asarray(binary_matmul_packed_ref(x, a, op=op))
    assert np.array_equal(got, ref)


@pytest.mark.parametrize("blocks", [(8, 8, 128, 8), (16, 32, 128, 16),
                                    (64, 128, 256, 8)])
def test_binary_kernel_block_sweep(rng, blocks):
    bb, bm, bw, rc = blocks
    x = F.pack_bits(rng.integers(0, 2, (21, 300)))
    a = F.pack_bits(rng.integers(0, 2, (50, 300)))
    got = np.asarray(binary_matmul_packed(
        x, a, op="xor", block_b=bb, block_m=bm, block_w=bw, row_chunk=rc,
        interpret=True))
    ref = np.asarray(binary_matmul_packed_ref(x, a, op="xor"))
    assert np.array_equal(got, ref)


@pytest.mark.parametrize("backend", ["pallas", "ref", "mxu"])
def test_mode_ops_vs_ground_truth(rng, backend):
    b, m, n = 5, 24, 70
    xb = rng.integers(0, 2, (b, n))
    ab = rng.integers(0, 2, (m, n))
    xp, ap = F.pack_bits(xb), F.pack_bits(ab)
    hs = np.asarray(hamming_similarity(xp, ap, n=n, backend=backend))
    assert np.array_equal(hs, (xb[:, None, :] == ab[None, :, :]).sum(-1))
    ip = np.asarray(inner_product_pm1(xp, ap, n=n, backend=backend))
    assert np.array_equal(ip, (2 * xb - 1) @ (2 * ab - 1).T)
    ad = np.asarray(and_dot(xp, ap, n=n, backend=backend))
    assert np.array_equal(ad, xb @ ab.T)
    g2 = np.asarray(gf2_matmul(xp, ap, n=n, backend=backend))
    assert np.array_equal(g2, (xb @ ab.T) % 2)


def test_cam_and_pla_ops(rng):
    n = 64
    ab = rng.integers(0, 2, (32, n))
    x = ab[3:4].copy()
    xp, ap = F.pack_bits(x), F.pack_bits(ab)
    match = np.asarray(cam_match(xp, ap, n=n))
    assert match[0, 3]
    # PLA: row 0 of bank 0 = AND of first 4 variables
    a2 = np.zeros((16, n), np.uint8)
    a2[0, :4] = 1
    nvars = np.full((16,), n + 1, np.int32)
    nvars[0] = 4
    x_on = np.zeros((1, n), np.uint8)
    x_on[0, :4] = 1
    out = np.asarray(pla_eval(F.pack_bits(x_on), F.pack_bits(a2), nvars, n=n))
    assert out[0, 0] == 1
    x_off = x_on.copy()
    x_off[0, 0] = 0
    out = np.asarray(pla_eval(F.pack_bits(x_off), F.pack_bits(a2), nvars, n=n))
    assert out[0, 0] == 0


@pytest.mark.parametrize("fmt_a", ["uint", "int", "oddint"])
@pytest.mark.parametrize("fmt_x", ["uint", "int", "oddint"])
@pytest.mark.parametrize("backend", ["pallas", "ref", "mxu"])
def test_ppac_matmul_formats(rng, fmt_a, fmt_x, backend):
    k, l, b, m, n = 4, 3, 4, 20, 40
    la, ha = F.value_range(fmt_a, k)
    lx, hx = F.value_range(fmt_x, l)
    a = rng.choice(np.arange(la, ha + 1, 2 if fmt_a == "oddint" else 1),
                   size=(m, n))
    x = rng.choice(np.arange(lx, hx + 1, 2 if fmt_x == "oddint" else 1),
                   size=(b, n))
    got = np.asarray(ppac_matmul(x, a, k_bits=k, l_bits=l, fmt_a=fmt_a,
                                 fmt_x=fmt_x, backend=backend))
    assert np.array_equal(got, x @ a.T), (fmt_a, fmt_x, backend)


def test_bitserial_kernel_vs_ref(rng):
    xp = rng.integers(0, 2**32, (3, 6, 4), dtype=np.uint32)
    ap = rng.integers(0, 2**32, (2, 10, 4), dtype=np.uint32)
    w = rng.integers(-8, 8, (2, 3)).astype(np.int32)
    got = np.asarray(bitserial_matmul_packed(xp, ap, w, interpret=True))
    ref = np.asarray(bitserial_matmul_packed_ref(xp, ap, w))
    assert np.array_equal(got, ref)


@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 12),
       st.integers(1, 24), st.integers(1, 66),
       st.sampled_from(["uint", "int", "oddint"]),
       st.sampled_from(["uint", "int", "oddint"]),
       st.integers(0, 2**31 - 1))
@settings(max_examples=25, deadline=None)
def test_ppac_matmul_hypothesis(k, l, b, m, n, fmt_a, fmt_x, seed):
    rng = np.random.default_rng(seed)
    la, ha = F.value_range(fmt_a, k)
    lx, hx = F.value_range(fmt_x, l)
    a = rng.choice(np.arange(la, ha + 1, 2 if fmt_a == "oddint" else 1),
                   size=(m, n))
    x = rng.choice(np.arange(lx, hx + 1, 2 if fmt_x == "oddint" else 1),
                   size=(b, n))
    got = np.asarray(ppac_matmul(x, a, k_bits=k, l_bits=l, fmt_a=fmt_a,
                                 fmt_x=fmt_x, backend="ref"))
    assert np.array_equal(got, x @ a.T)


@given(st.integers(1, 40), st.integers(1, 40), st.integers(1, 129),
       st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_binary_kernel_hypothesis(b, m, n, seed):
    rng = np.random.default_rng(seed)
    xb = rng.integers(0, 2, (b, n))
    ab = rng.integers(0, 2, (m, n))
    xp, ap = F.pack_bits(xb), F.pack_bits(ab)
    got = np.asarray(binary_matmul_packed(xp, ap, op="xor", interpret=True))
    assert np.array_equal(got, (xb[:, None, :] ^ ab[None, :, :]).sum(-1))


def test_plane_weight_construction_offsets(rng):
    """oddint offsets fold into the extended weight matrix (eqs. 2/3
    analogue as in-kernel popcount coefficients + a constant) — the
    operands themselves never grow mask planes (zero-repack invariant)."""
    n = 10
    x = rng.choice([-3, -1, 1, 3], size=(2, n))
    a = rng.choice([-3, -1, 1, 3], size=(4, n))
    xp, ap, w, (pop_a, pop_x, const) = build_planes_and_weights(
        x, a, 2, 2, "oddint", "oddint")
    assert xp.shape[0] == 2 and ap.shape[0] == 2  # value planes only
    assert w.shape == (3, 3)                      # extended [K+1, L+1]
    assert pop_a and pop_x and const
    # oddint(2): w_l = {2, 4}, c = -3  ->  corner = c*c*n
    assert int(w[2, 2]) == 9 * n
    assert np.array_equal(np.asarray(w[:2, 2]), [-6, -12])  # wa_k * cx
    assert np.array_equal(np.asarray(w[2, :2]), [-6, -12])  # ca * wx_l
