"""Chaos-hardened serving: deterministic fault plans, recovery, and
GF(2) integrity on the LM data path.

The invariants under test (``serve_lm.chaos_check``):
  * no request lost — submitted == completed + shed + failed,
  * page-pool refcount conservation through crashes/retries/quarantine,
  * greedy outputs of COMPLETED requests bit-identical to a fault-free
    run (retries restart from the prompt; greedy decoding is pure),
  * every injected KV bit-flip is caught by the CRC scrub before a
    decode step can read it (never silently emits corrupted tokens).
"""
import dataclasses
import json

import jax
import numpy as np
import pytest

from repro.configs import load_arch
from repro.gf2.ops import crc_tag, crc_tags
from repro.launch.faults import (
    Fault,
    FaultPlan,
    InjectedFault,
    WorkerCrash,
)
from repro.launch.ft import HeartbeatBook
from repro.launch.paging import PagePool
from repro.launch.serve_lm import LMServer, Request, chaos_check
from repro.models import lm

ARCH = "smollm_360m"


# -- FaultPlan: pure-schedule semantics (no jax) -----------------------------


def test_fault_plan_fires_at_global_count():
    p = FaultPlan([Fault("error", "prefill", 2)])
    assert p.fire("prefill") == []
    assert p.fire("prefill") == []
    hits = p.fire("prefill")
    assert [f.kind for f in hits] == ["error"]
    assert p.fire("prefill") == []  # consumed: fires exactly once
    assert len(p) == 0


def test_fault_plan_per_worker_count_is_independent():
    p = FaultPlan([Fault("crash", "prefill", 1, worker="p1")])
    # global dispatches on other workers do not advance p1's counter
    assert p.fire("prefill", worker="p0") == []
    assert p.fire("prefill", worker="p1") == []
    assert p.fire("prefill", worker="p0") == []
    hits = p.fire("prefill", worker="p1")  # p1's second dispatch
    assert [f.worker for f in hits] == ["p1"]


def test_fault_plan_raise_any():
    p = FaultPlan([Fault("crash", "handoff", 0, worker="p0"),
                   Fault("error", "decode", 0)])
    with pytest.raises(WorkerCrash) as ei:
        p.raise_any(p.fire("handoff", worker="p0"))
    assert ei.value.wid == "p0" and ei.value.seam == "handoff"
    with pytest.raises(InjectedFault):
        p.raise_any(p.fire("decode"))
    # a global crash attributes to the dispatching worker
    p2 = FaultPlan([Fault("crash", "prefill", 0)])
    with pytest.raises(WorkerCrash) as ei:
        p2.raise_any(p2.fire("prefill", worker="p3"), wid="p3")
    assert ei.value.wid == "p3"


def test_fault_plan_for_request():
    p = FaultPlan([Fault("deadline", "request", 7, deadline_s=0.25)])
    assert p.for_request(3) == []
    hits = p.for_request(7)
    assert hits[0].deadline_s == 0.25
    assert p.for_request(7) == []  # consumed


def test_fault_plan_parse_dsl_and_json(tmp_path):
    spec = "crash:prefill:0:worker=p0;flip:step:3:page=2,bit=5;" \
           "deadline:request:1:deadline_s=0.5"
    p = FaultPlan.parse(spec)
    kinds = sorted(f["kind"] for f in p.as_dicts())
    assert kinds == ["crash", "deadline", "flip"]
    flip = next(f for f in p.as_dicts() if f["kind"] == "flip")
    assert flip["page"] == 2 and flip["bit"] == 5
    # JSON file round-trip through as_dicts
    path = tmp_path / "plan.json"
    path.write_text(json.dumps(p.as_dicts()))
    p2 = FaultPlan.parse(str(path))
    assert p2.as_dicts() == p.as_dicts()
    with pytest.raises(ValueError):
        FaultPlan.parse("flip:step")  # needs kind:seam:at
    with pytest.raises(ValueError):
        FaultPlan.parse("flip:step:0:bogus=1")


def test_fault_plan_seeded_is_deterministic():
    a = FaultPlan.seeded(13, steps=12, pool_pages=16, n_requests=8)
    b = FaultPlan.seeded(13, steps=12, pool_pages=16, n_requests=8)
    assert a.as_dicts() == b.as_dicts()
    assert len(a) >= 1
    assert a.as_dicts() != FaultPlan.seeded(14, steps=12, pool_pages=16,
                                            n_requests=8).as_dicts()


# -- PagePool: seal / quarantine ---------------------------------------------


def test_pool_seal_lifecycle():
    pool = PagePool(4)
    pages = pool.alloc(2)
    pool.seal(pages[0], 0xABCD)
    assert pool.is_sealed(pages[0]) and not pool.is_sealed(pages[1])
    assert pool.sealed_tag(pages[0]) == 0xABCD
    assert pool.sealed_items() == {pages[0]: 0xABCD}
    pool.decref([pages[0]])  # refcount hits 0: seal pops with the page
    assert not pool.is_sealed(pages[0])
    assert pool.free_pages == 3


def test_pool_quarantine_never_returns_to_free_list():
    pool = PagePool(4)
    pages = pool.alloc(4)
    assert pool.free_pages == 0
    pool.quarantine(pages[1])
    assert pool.capacity == 3 and pool.quarantined == [pages[1]]
    pool.decref(pages)  # dead page is NOT appended to the free list
    assert pool.free_pages == 3
    got = pool.alloc(3)
    assert got is not None and pages[1] not in got
    assert pool.alloc(1) is None  # capacity shrank for good


# -- GF(2) CRC tags ----------------------------------------------------------


def test_crc_tags_detect_single_bit_flips():
    rng = np.random.default_rng(0)
    buf = rng.integers(0, 256, 97, dtype=np.uint8)  # odd len: pad path
    base = crc_tag(buf)
    for bit in (0, 7, 400, 97 * 8 - 1):  # first, mid-chunk, last
        bad = buf.copy()
        bad[bit // 8] ^= np.uint8(1 << (bit % 8))
        assert crc_tag(bad) != base, f"bit {bit} undetected"


def test_crc_tags_batch_matches_scalar():
    rng = np.random.default_rng(1)
    bufs = rng.integers(0, 256, (5, 64), dtype=np.uint8)
    tags = crc_tags(bufs)
    assert tags.shape == (5,)
    for i in range(5):
        assert int(tags[i]) == crc_tag(bufs[i])
    # equal buffers get equal tags, and tags are content- not row-keyed
    dup = np.vstack([bufs[0], bufs[0]])
    t2 = crc_tags(dup)
    assert int(t2[0]) == int(t2[1]) == int(tags[0])


# -- HeartbeatBook -----------------------------------------------------------


def test_heartbeat_book_stale_and_forget():
    hb = HeartbeatBook()
    hb.beat("p0", now=100.0)
    hb.beat("p1", now=104.0)
    assert hb.last("p0") == 100.0
    assert hb.stale(3.0, now=105.0) == ["p0"]
    assert hb.stale(10.0, now=105.0) == []
    hb.forget("p0")
    assert hb.stale(0.5, now=110.0) == ["p1"]
    assert hb.last("p0") is None


# -- server chaos scenarios --------------------------------------------------


def _mk(seed=0, n=4, plen_lo=9, plen_hi=20, max_new=6):
    rng = np.random.default_rng(seed)
    cfg = load_arch(ARCH).smoke()
    return cfg, [Request(i, rng.integers(0, cfg.vocab,
                                         int(rng.integers(plen_lo, plen_hi))),
                         max_new) for i in range(n)]


def _serve(cfg, params, reqs, **kw):
    srv = LMServer(cfg, params, slots=2, max_seq=64, paged=True,
                   page_size=8, **kw)
    for r in reqs:
        srv.submit(r)
    done = srv.run()
    return srv, done


@pytest.fixture(scope="module")
def served():
    """Params plus the fault-free greedy reference outputs."""
    cfg, reqs = _mk()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    srv, done = _serve(cfg, params, reqs)
    assert len(done) == len(reqs)
    return cfg, params, {r.rid: list(r.out) for r in done}


def test_chaos_run_preserves_invariants_and_outputs(served):
    """A multi-fault schedule (dispatch error, pool squeeze, deadline,
    KV bit-flip) completes with every invariant intact and every
    COMPLETED request's greedy output bit-identical to fault-free."""
    cfg, params, ref = served
    _, reqs = _mk()
    faults = FaultPlan.parse(
        "error:prefill:1;squeeze:step:2:pages=6,hold=2;"
        "deadline:request:3;flip:step:4:bit=9")
    srv, done = _serve(cfg, params, reqs, faults=faults, max_retries=3,
                       kv_crc=True, scrub_every=1)
    assert chaos_check(srv) == []
    assert len(faults) == 0, "every scheduled fault fired"
    outcomes = {r.rid: r.outcome for r in reqs}
    assert outcomes[3] == "shed"  # the deadline fault
    assert all(o in ("completed", "shed", "failed")
               for o in outcomes.values())
    for r in done:  # bit-identity of completed requests
        assert list(r.out) == ref[r.rid], f"rid {r.rid} diverged"
    assert srv.metrics.counter("lm_retries").value >= 1


def test_kv_bit_flip_is_quarantined_and_recomputed(served):
    """An injected KV-page flip is detected by the GF(2) scrub BEFORE any
    decode reads it: the page is quarantined (permanently out of the
    pool), the mapped request re-prefills, and its final output is
    bit-identical — corrupted tokens are never emitted."""
    cfg, params, ref = served
    _, reqs = _mk()
    faults = FaultPlan.parse("flip:step:2:bit=3")
    srv, done = _serve(cfg, params, reqs, faults=faults, max_retries=3,
                       kv_crc=True, scrub_every=1)
    assert chaos_check(srv) == []
    assert srv.metrics.counter("lm_pages_quarantined").value == 1
    assert srv.pool.capacity == srv.pool.pages - 1
    assert len(done) == len(reqs)  # everyone completed despite the flip
    for r in done:
        assert list(r.out) == ref[r.rid]


def test_deadline_sheds_before_admission(served):
    cfg, params, _ = served
    _, reqs = _mk()
    faults = FaultPlan([Fault("deadline", "request", i) for i in (0, 2)])
    srv, done = _serve(cfg, params, reqs, faults=faults)
    assert chaos_check(srv) == []
    assert {r.rid for r in srv.terminal} == {0, 2}
    assert all(r.outcome == "shed" and not r.out for r in srv.terminal)
    assert {r.rid for r in done} == {1, 3}


def test_retry_budget_exhaustion_fails_terminally(served):
    """Three back-to-back prefill errors against max_retries=2: the
    victim fails with a reason instead of looping or vanishing."""
    cfg, params, _ = served
    _, reqs = _mk(n=1)
    faults = FaultPlan([Fault("error", "prefill", i) for i in range(3)])
    srv, done = _serve(cfg, params, reqs, faults=faults, max_retries=2)
    assert done == []
    assert reqs[0].outcome == "failed"
    assert reqs[0].fail_reason == "prefill"
    assert reqs[0].retries == 3
    assert chaos_check(srv) == []


def test_decode_error_retries_in_place(served):
    cfg, params, ref = served
    _, reqs = _mk()
    faults = FaultPlan([Fault("error", "decode", 1)])
    srv, done = _serve(cfg, params, reqs, faults=faults)
    assert chaos_check(srv) == []
    assert len(done) == len(reqs)
    for r in done:
        assert list(r.out) == ref[r.rid]
    assert srv.metrics.counter("lm_retries").value == 1


def test_prefix_cache_survives_quarantine():
    """Corrupting a REGISTERED prefix page evicts it from the index, so
    later identical prompts re-prefill instead of matching poisoned
    history; refcount conservation holds throughout."""
    cfg = load_arch(ARCH).smoke()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    prompt = np.arange(1, 18, dtype=np.int32) % cfg.vocab  # 17 toks, 2 pages
    reqs = [Request(i, prompt.copy(), 5) for i in range(3)]
    faults = FaultPlan.parse("flip:step:3:bit=1")
    srv = LMServer(cfg, params, slots=1, max_seq=64, paged=True,
                   page_size=8, prefix_cache=True, faults=faults,
                   max_retries=3, kv_crc=True, scrub_every=1)
    for r in reqs:
        srv.submit(r)
    done = srv.run()
    assert chaos_check(srv) == []
    assert srv.metrics.counter("lm_pages_quarantined").value >= 1
    assert len(done) == 3
    outs = [list(r.out) for r in done]
    assert outs[0] == outs[1] == outs[2]  # identical prompts, greedy


@pytest.mark.slow
def test_disagg_crash_restart_then_degrade(monkeypatch):
    """The acceptance scenario: a prefill worker dies mid-stream twice —
    first crash rebuilds it (lm_worker_restarts), second drops it and the
    empty pool flips the executor into degraded decode-mesh prefill
    (lm_degraded) — and every request still completes."""
    import os
    import subprocess
    import sys
    import textwrap

    from conftest import cpu_subproc_env
    prog = textwrap.dedent("""
        import warnings; warnings.filterwarnings("ignore")
        import jax, numpy as np
        from repro.configs import load_arch
        from repro.models import lm
        from repro.launch.serve_lm import LMServer, Request, chaos_check
        from repro.launch.faults import FaultPlan
        cfg = load_arch("smollm_360m").smoke()
        params, _ = lm.init(cfg, jax.random.PRNGKey(0))
        faults = FaultPlan.parse(
            "crash:prefill:0:worker=p0;crash:handoff:0:worker=p0")
        srv = LMServer(cfg, params, slots=2, max_seq=64, paged=True,
                       page_size=8, prefill_devices=2, decode_devices=2,
                       prefill_workers=1, faults=faults, max_retries=3,
                       max_worker_restarts=1)
        rng = np.random.default_rng(0)
        reqs = [Request(i, rng.integers(0, cfg.vocab,
                                        int(rng.integers(9, 20))), 4)
                for i in range(3)]
        for r in reqs: srv.submit(r)
        done = srv.run()
        assert chaos_check(srv) == [], chaos_check(srv)
        assert len(done) == 3, [r.outcome for r in reqs]
        assert srv.metrics.total("lm_worker_restarts") == 1
        assert srv.metrics.gauge("lm_degraded").value == 1.0
        assert srv.ex.degraded and srv.ex.pool == []
        print("DISAGG_CHAOS_OK")
    """)
    env = dict(cpu_subproc_env(),
               XLA_FLAGS="--xla_force_host_platform_device_count=4")
    out = subprocess.run([sys.executable, "-c", prog], env=env,
                         capture_output=True, text=True, timeout=900,
                         cwd=os.path.dirname(os.path.dirname(__file__)))
    assert out.returncode == 0, out.stderr[-2000:]
    assert "DISAGG_CHAOS_OK" in out.stdout


# -- weight-container integrity ----------------------------------------------


@pytest.mark.slow
def test_param_flip_repaired_from_shadow():
    """A bit-flip in a resident packed container is caught by the scrub
    and repaired by repacking from the quantization shadow — decoding
    continues with the original weights (bit-identical outputs)."""
    from repro.serve.step import convert_params_for_serving
    cfg = load_arch(ARCH).smoke()
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        ppac=dataclasses.replace(cfg.ppac, enabled=True, weight_bits=4,
                                 act_bits=8, min_features=32))
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    params = convert_params_for_serving(params, cfg, store_shadow=True)
    _, reqs = _mk(n=2)
    ref_srv, ref_done = _serve(cfg, params, reqs, mode="serve")
    ref = {r.rid: list(r.out) for r in ref_done}

    _, reqs = _mk(n=2)
    faults = FaultPlan([Fault("flip", "step", 2, param=1, bit=17)])
    srv, done = _serve(cfg, params, reqs, mode="serve", faults=faults,
                       max_retries=2, scrub_every=1)
    assert chaos_check(srv) == []
    assert srv.metrics.counter("lm_param_scrub_repaired").value >= 1
    assert len(done) == 2
    for r in done:
        assert list(r.out) == ref[r.rid]
