import os

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def cpu_subproc_env():
    """Env for CPU-only jax subprocesses. Forces the CPU platform: without
    it a stray libtpu install spends minutes probing for TPU metadata
    before falling back."""
    return {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
            "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}


# hypothesis is optional: property-based tests skip when it is absent.
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st  # noqa: F401
except ImportError:
    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        return lambda f: f

    class _NoStrategies:
        def __getattr__(self, _name):
            return lambda *_a, **_k: None

    st = _NoStrategies()
