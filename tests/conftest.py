import os
import pathlib

import numpy as np
import pytest

_SRC = str(pathlib.Path(__file__).resolve().parent.parent / "src")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def cpu_subproc_env():
    """Env for CPU-only jax subprocesses. Forces the CPU platform: without
    it a stray libtpu install spends minutes probing for TPU metadata
    before falling back.  PYTHONPATH is absolute so the suite can be
    invoked from any working directory."""
    return {"PYTHONPATH": _SRC, "PATH": "/usr/bin:/bin", "HOME": "/root",
            "JAX_PLATFORMS": os.environ.get("JAX_PLATFORMS", "cpu")}


# hypothesis is optional: when absent, @given tests fall back to a fixed
# number of deterministic pseudo-random draws from the declared strategies
# instead of skipping (CI installs real hypothesis and gets shrinking,
# example databases, and wider coverage; see REQUIRE_HYPOTHESIS below).
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import inspect
    import zlib

    HAVE_HYPOTHESIS = False
    FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _St:
        """Samplers for the strategy subset this repo uses."""

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(lambda rng: elems[int(rng.integers(len(elems)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    st = _St()

    def given(*strategies):
        def deco(f):
            @functools.wraps(f)
            def runner(*args, **kwargs):
                # deterministic per-test seed so failures reproduce
                rng = np.random.default_rng(zlib.crc32(f.__name__.encode()))
                for _ in range(FALLBACK_EXAMPLES):
                    f(*args, *(s.draw(rng) for s in strategies), **kwargs)
            # strategy params are filled here, not by pytest fixtures
            runner.__signature__ = inspect.Signature()
            return runner
        return deco

    def settings(*_a, **_k):
        return lambda f: f
