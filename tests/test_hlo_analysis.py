"""HLO analyzer: trip-count multiplication, collective accounting, parsing."""
import textwrap

from repro.launch.hlo_analysis import analyze, parse_module

SYNTH = textwrap.dedent("""\
    HloModule test, is_scheduled=true

    %body (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
      %p = (s32[], f32[64,64]) parameter(0)
      %i = s32[] get-tuple-element(%p), index=0
      %h = f32[64,64] get-tuple-element(%p), index=1
      %d = f32[64,64] dot(%h, %h), lhs_contracting_dims={1}, rhs_contracting_dims={0}
      %ar = f32[64,64] all-reduce(%d), replica_groups=[2,4]<=[8], to_apply=%sum
      %c1 = s32[] constant(1)
      %i2 = s32[] add(%i, %c1)
      ROOT %t = (s32[], f32[64,64]) tuple(%i2, %ar)
    }

    %cond (p2: (s32[], f32[64,64])) -> pred[] {
      %p2 = (s32[], f32[64,64]) parameter(0)
      %i3 = s32[] get-tuple-element(%p2), index=0
      %c10 = s32[] constant(10)
      ROOT %lt = pred[] compare(%i3, %c10), direction=LT
    }

    %sum (a: f32[], b: f32[]) -> f32[] {
      %a = f32[] parameter(0)
      %b = f32[] parameter(1)
      ROOT %s = f32[] add(%a, %b)
    }

    ENTRY %main (x: f32[64,64]) -> f32[64,64] {
      %x = f32[64,64] parameter(0)
      %c0 = s32[] constant(0)
      %t0 = (s32[], f32[64,64]) tuple(%c0, %x)
      %w = (s32[], f32[64,64]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
      ROOT %out = f32[64,64] get-tuple-element(%w), index=1
    }
""")


def test_while_trip_multiplication():
    st = analyze(SYNTH)
    # 10 iterations x dot(64x64x64): 2*64*64*64 = 524288 each
    dot_flops = 2 * 64 * 64 * 64
    assert abs(st.flops - 10 * (dot_flops + 2)) / st.flops < 0.01
    # all-reduce: 64*64*4 bytes * 2(n-1)/n with n=4, x10 trips
    ar = 64 * 64 * 4 * 2 * 3 / 4 * 10
    assert abs(st.coll_bytes["all-reduce"] - ar) < 1
    assert st.collective_total == st.coll_bytes["all-reduce"]


def test_parse_module_structure():
    comps = parse_module(SYNTH)
    assert set(comps) == {"body", "cond", "sum", "main"}
    assert comps["main"].is_entry
    ops = {o.opcode for o in comps["body"].ops}
    assert "dot" in ops and "all-reduce" in ops


def test_tuple_types_with_comments():
    txt = textwrap.dedent("""\
        HloModule t, is_scheduled=true
        ENTRY %main (x: f32[8,8]) -> f32[8,8] {
          %x = f32[8,8] parameter(0)
          %w = (s32[], f32[8,8], /*index=5*/f32[8,8]) tuple(%x)
          ROOT %d = f32[8,8] dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
        }
    """)
    st = analyze(txt)
    assert st.flops == 2 * 8 * 8 * 8


def test_scanned_matmul_against_known_flops():
    """End-to-end: compile a scanned matmul and check exact flop count
    (this is the case XLA's own cost_analysis undercounts)."""
    import jax
    import jax.numpy as jnp

    def f(x, ws):
        def body(c, w):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 128, 128), jnp.float32)
    comp = jax.jit(f).lower(x, ws).compile()
    st = analyze(comp.as_text())
    want = 7 * 2 * 128 * 128 * 128
    assert abs(st.flops - want) / want < 0.01
    # XLA's entry-level count misses the trip multiplier
    ca = comp.cost_analysis()
    if isinstance(ca, list):  # jax <= 0.4.x returns one dict per device
        ca = ca[0]
    assert ca["flops"] < want / 2
