"""Direct numerical oracles for the nontrivial math kernels.

These validate the *algorithms* (chunked SSD, chunked/triangular attention,
capacity-based MoE routing) against naive reference implementations,
independently of the end-to-end decode-consistency tests."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import given, settings, st  # hypothesis or skip-shim

from repro.configs import load_arch
from repro.models.attention import chunked_attention
from repro.models.mamba2 import ssd_chunked
from repro.models.moe import moe_apply, moe_init


# -- SSD vs naive linear recurrence -------------------------------------------

def naive_ssm(x, dt, a_log, b, c):
    """y_t = C_t^T h_t;  h_t = exp(dt_t A) h_{t-1} + dt_t B_t x_t."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    a = -np.exp(np.asarray(a_log, np.float64))
    xr = np.asarray(x, np.float64) * np.asarray(dt, np.float64)[..., None]
    bb = np.repeat(np.asarray(b, np.float64), h // b.shape[2], axis=2)
    cc = np.repeat(np.asarray(c, np.float64), h // c.shape[2], axis=2)
    y = np.zeros((bsz, s, h, p))
    for bi in range(bsz):
        state = np.zeros((h, n, p))
        for t in range(s):
            dec = np.exp(np.asarray(dt, np.float64)[bi, t] * a)  # [h]
            state = state * dec[:, None, None] + \
                np.einsum("hn,hp->hnp", bb[bi, t], xr[bi, t])
            y[bi, t] = np.einsum("hn,hnp->hp", cc[bi, t], state)
    return y


@pytest.mark.parametrize("s,chunk", [(16, 4), (24, 8), (32, 32), (17, 4)])
def test_ssd_chunked_matches_recurrence(rng, s, chunk):
    bsz, h, p, g, n = 2, 4, 8, 2, 4
    x = jnp.asarray(rng.standard_normal((bsz, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (bsz, s, h)), jnp.float32)
    a_log = jnp.asarray(np.log(rng.uniform(0.5, 2.0, (h,))), jnp.float32)
    b = jnp.asarray(rng.standard_normal((bsz, s, g, n)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((bsz, s, g, n)), jnp.float32)
    got = np.asarray(ssd_chunked(x, dt, a_log, b, c, chunk=chunk))
    want = naive_ssm(x, dt, a_log, b, c)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


@given(st.integers(2, 40), st.integers(1, 16), st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_ssd_chunk_size_invariance(s, chunk, seed):
    """SSD result must not depend on the chunking."""
    rng = np.random.default_rng(seed)
    bsz, h, p, g, n = 1, 2, 4, 1, 4
    x = jnp.asarray(rng.standard_normal((bsz, s, h, p)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.5, (bsz, s, h)), jnp.float32)
    a_log = jnp.asarray(np.log(rng.uniform(0.5, 2.0, (h,))), jnp.float32)
    b = jnp.asarray(rng.standard_normal((bsz, s, g, n)), jnp.float32)
    c = jnp.asarray(rng.standard_normal((bsz, s, g, n)), jnp.float32)
    y1 = np.asarray(ssd_chunked(x, dt, a_log, b, c, chunk=chunk))
    y2 = np.asarray(ssd_chunked(x, dt, a_log, b, c, chunk=s))
    np.testing.assert_allclose(y1, y2, rtol=2e-4, atol=2e-4)


# -- chunked / triangular attention vs naive softmax ---------------------------

def naive_attention(q, k, v, window=0):
    b, s, h, d = q.shape
    hkv = k.shape[2]
    kk = np.repeat(np.asarray(k, np.float64), h // hkv, axis=2)
    vv = np.repeat(np.asarray(v, np.float64), h // hkv, axis=2)
    qq = np.asarray(q, np.float64)
    scores = np.einsum("bshd,bthd->bhst", qq, kk) / np.sqrt(d)
    mask = np.tril(np.ones((s, s), bool))
    if window:
        mask &= ~np.tril(np.ones((s, s), bool), -window)
    scores = np.where(mask[None, None], scores, -1e9)
    w = np.exp(scores - scores.max(-1, keepdims=True))
    w /= w.sum(-1, keepdims=True)
    return np.einsum("bhst,bthd->bshd", w, vv)


@pytest.mark.parametrize("blocking", ["scan", "triangle"])
@pytest.mark.parametrize("s,chunk,window", [(32, 8, 0), (33, 8, 0),
                                            (32, 8, 12), (16, 16, 0)])
def test_chunked_attention_vs_naive(rng, blocking, s, chunk, window):
    if blocking == "triangle" and window:
        pytest.skip("triangle path handles full causal only")
    b, h, hkv, d = 2, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    got = np.asarray(chunked_attention(q, k, v, causal=True, window=window,
                                       q_chunk=chunk, remat=False,
                                       blocking=blocking))
    want = naive_attention(q, k, v, window=window)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


# -- MoE routing invariants -----------------------------------------------------

def test_moe_no_drop_equals_dense_mixture(rng):
    """With cf large enough for zero drops, capacity-routed MoE must equal
    the dense weighted mixture of its top-k experts."""
    cfg = load_arch("kimi_k2_1t_a32b").smoke()
    cfg = dataclasses.replace(
        cfg, dtype="float32",
        moe=dataclasses.replace(cfg.moe, capacity_factor=32.0, num_shared=0))
    p, _ = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)) * 0.3,
                    jnp.float32)
    y, aux = moe_apply(p, x, cfg)

    # dense reference
    logits = np.einsum("bsd,de->bse", np.asarray(x),
                       np.asarray(p["router"]["w"]))
    probs = jax.nn.softmax(jnp.asarray(logits), -1)
    top_v, top_i = jax.lax.top_k(probs, cfg.moe.top_k)
    top_v = top_v / top_v.sum(-1, keepdims=True)
    ref = np.zeros_like(np.asarray(x))
    for bi in range(2):
        for si in range(16):
            for kk in range(cfg.moe.top_k):
                e = int(top_i[bi, si, kk])
                h = np.asarray(x)[bi, si] @ np.asarray(p["wi"][e])
                g = np.asarray(x)[bi, si] @ np.asarray(p["wg"][e])
                h = h * (np.asarray(jax.nn.silu(jnp.asarray(g))))
                ref[bi, si] += float(top_v[bi, si, kk]) * (
                    h @ np.asarray(p["wo"][e]))
    np.testing.assert_allclose(np.asarray(y), ref, rtol=2e-3, atol=2e-3)


def test_moe_capacity_drops_bounded(rng):
    """With cf=0.5 drops must occur, outputs stay finite, aux losses sane."""
    cfg = load_arch("deepseek_v2_lite_16b").smoke()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.5))
    p, _ = moe_init(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    y, aux = moe_apply(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    assert float(aux["lb_loss"]) >= 0.9  # ~E*mean(f.p), =1 in expectation
