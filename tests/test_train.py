"""Training substrate: loss decreases, optimizer variants, microbatching."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import load_arch
from repro.data.pipeline import DataConfig, make_model_batch
from repro.configs.base import InputShape
from repro.optim.adamw import AdamWConfig, cosine_schedule, opt_init, opt_update
from repro.train.loss import next_token_xent
from repro.train.step import TrainConfig, init_state, make_train_step


def tiny_setup(arch="smollm_360m", steps=1, **tkw):
    cfg = load_arch(arch).smoke()
    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-2, **tkw.pop("opt_kw", {})),
                       warmup_steps=2, total_steps=50, **tkw)
    state, axes = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg))
    shape = InputShape("t", 32, 4, "train")
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    return cfg, tcfg, state, step, dcfg, shape


def run_steps(cfg, state, step, dcfg, shape, n):
    losses = []
    for i in range(n):
        batch = {k: jnp.asarray(v)
                 for k, v in make_model_batch(cfg, shape, dcfg, i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    return state, losses


def test_loss_decreases():
    cfg, tcfg, state, step, dcfg, shape = tiny_setup()
    state, losses = run_steps(cfg, state, step, dcfg, shape, 20)
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.3, losses


def test_int8_optimizer_converges():
    cfg, tcfg, state, step, dcfg, shape = tiny_setup(
        opt_kw=dict(quantized_state=True))
    state, losses = run_steps(cfg, state, step, dcfg, shape, 20)
    assert np.mean(losses[-3:]) < np.mean(losses[:3]) - 0.3, losses
    # moments really are int8
    q = jax.tree.leaves(state["opt"]["mu"])[0]
    assert q.dtype == jnp.int8


def test_int8_moments_track_fp32(rng):
    p = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    cfg8 = AdamWConfig(quantized_state=True, grad_clip=0)
    cfg32 = AdamWConfig(quantized_state=False, grad_clip=0)
    s8, s32 = opt_init(p, cfg8), opt_init(p, cfg32)
    p8, s8, _ = opt_update(p, g, s8, cfg8)
    p32, s32, _ = opt_update(p, g, s32, cfg32)
    np.testing.assert_allclose(np.asarray(p8["w"]), np.asarray(p32["w"]),
                               rtol=0, atol=5e-4)


def test_microbatching_matches_full_batch():
    cfg = load_arch("smollm_360m").smoke()
    t1 = TrainConfig(opt=AdamWConfig(lr=1e-2), microbatches=1)
    t4 = TrainConfig(opt=AdamWConfig(lr=1e-2), microbatches=4)
    s1, _ = init_state(cfg, t1, jax.random.PRNGKey(0))
    s4, _ = init_state(cfg, t4, jax.random.PRNGKey(0))
    step1 = jax.jit(make_train_step(cfg, t1))
    step4 = jax.jit(make_train_step(cfg, t4))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=8)
    shape = InputShape("t", 16, 8, "train")
    batch = {k: jnp.asarray(v)
             for k, v in make_model_batch(cfg, shape, dcfg, 0).items()}
    s1n, m1 = step1(s1, batch)
    s4n, m4 = step4(s4, batch)
    w1 = jax.tree.leaves(s1n["params"])[0]
    w4 = jax.tree.leaves(s4n["params"])[0]
    np.testing.assert_allclose(np.asarray(w1), np.asarray(w4),
                               rtol=0, atol=2e-5)


def test_qat_training_runs():
    cfg = load_arch("smollm_360m").smoke()
    cfg = dataclasses.replace(
        cfg, ppac=dataclasses.replace(cfg.ppac, enabled=True, min_features=1,
                                      weight_bits=4, act_bits=4))
    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-2), qat=True,
                       warmup_steps=2, total_steps=50)
    state, _ = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    shape = InputShape("t", 32, 4, "train")
    losses = []
    for i in range(10):
        batch = {k: jnp.asarray(v)
                 for k, v in make_model_batch(cfg, shape, dcfg, i).items()}
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert np.isfinite(losses).all()
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


def test_moe_train_smoke():
    cfg = load_arch("deepseek_v2_lite_16b").smoke()
    tcfg = TrainConfig(opt=AdamWConfig(lr=5e-3))
    state, _ = init_state(cfg, tcfg, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(cfg, tcfg))
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
    shape = InputShape("t", 32, 4, "train")
    batch = {k: jnp.asarray(v)
             for k, v in make_model_batch(cfg, shape, dcfg, 0).items()}
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert "lb_loss" in metrics


def test_grad_clip_and_schedule():
    s = jnp.asarray(0)
    assert float(cosine_schedule(s, warmup=10, total=100)) == 0.0
    s = jnp.asarray(10)
    assert abs(float(cosine_schedule(s, warmup=10, total=100)) - 1.0) < 1e-6
    s = jnp.asarray(100)
    assert abs(float(cosine_schedule(s, warmup=10, total=100)) - 0.1) < 1e-6


def test_masked_loss_ignores_labels():
    logits = jnp.zeros((1, 4, 8), jnp.float32)
    labels = jnp.asarray([[1, -1, 2, -1]], jnp.int32)
    loss, m = next_token_xent(logits, labels)
    assert float(m["tokens"]) == 2.0
    assert abs(float(loss) - np.log(8)) < 1e-5
