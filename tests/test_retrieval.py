"""Associative retrieval subsystem: fused Hamming top-k kernels (all
backends bit-exact vs the brute-force oracle, ties included), CAMIndex
write path + search + CAM δ-match vs PPACArray, sharded search identity,
and the batched lookup server."""
import subprocess
import sys
import textwrap

import numpy as np
import pytest
from conftest import cpu_subproc_env

from repro.core import formats as F
from repro.core.ppac import PPACArray, PPACConfig
from repro.kernels.hamming_topk.ops import (
    hamming_threshold_match,
    hamming_topk,
)
from repro.launch.retrieval import LookupRequest, RetrievalServer
from repro.retrieval import CAMIndex


def _pack(rng, rows, n):
    return F.pack_bits(rng.integers(0, 2, (rows, n)))


@pytest.mark.parametrize("backend", ["pallas", "mxu"])
@pytest.mark.parametrize("b,m,n,k", [(1, 1, 1, 1), (3, 17, 8, 5),
                                     (5, 300, 64, 16), (8, 40, 513, 7),
                                     (2, 1000, 32, 32)])
def test_topk_matches_ref_exactly(rng, backend, b, m, n, k):
    xp, ap = _pack(rng, b, n), _pack(rng, m, n)
    rs, ri = hamming_topk(xp, ap, n=n, k=k, backend="ref")
    s, i = hamming_topk(xp, ap, n=n, k=k, backend=backend)
    assert np.array_equal(np.asarray(s), np.asarray(rs))
    assert np.array_equal(np.asarray(i), np.asarray(ri))


@pytest.mark.parametrize("backend", ["pallas", "mxu"])
def test_topk_tie_handling(rng, backend):
    """n=2 forces massive score duplication; constant DB makes *every* row
    tie — index-ascending order must match lax.top_k bit-for-bit."""
    b, m, n, k = 4, 600, 2, 20
    xp, ap = _pack(rng, b, n), _pack(rng, m, n)
    rs, ri = hamming_topk(xp, ap, n=n, k=k, backend="ref")
    s, i = hamming_topk(xp, ap, n=n, k=k, backend=backend)
    assert np.array_equal(np.asarray(s), np.asarray(rs))
    assert np.array_equal(np.asarray(i), np.asarray(ri))

    const = F.pack_bits(np.ones((300, 16), np.uint8))
    q = _pack(rng, 3, 16)
    rs, ri = hamming_topk(q, const, n=16, k=10, backend="ref")
    s, i = hamming_topk(q, const, n=16, k=10, backend=backend)
    assert np.array_equal(np.asarray(i), np.asarray(ri))
    assert np.array_equal(np.asarray(i), np.tile(np.arange(10), (3, 1)))


@pytest.mark.parametrize("backend", ["pallas", "mxu"])
def test_topk_validity_mask(rng, backend):
    """Tombstoned rows score -1 and only surface when k exceeds live rows,
    in index-ascending order — identical across backends."""
    b, m, n = 3, 50, 24
    xp, ap = _pack(rng, b, n), _pack(rng, m, n)
    valid = np.ones(m, np.int32)
    valid[10:45] = 0  # 15 live rows, k=20 > live
    rs, ri = hamming_topk(xp, ap, n=n, k=20, valid=valid, backend="ref")
    s, i = hamming_topk(xp, ap, n=n, k=20, valid=valid, backend=backend)
    assert np.array_equal(np.asarray(s), np.asarray(rs))
    assert np.array_equal(np.asarray(i), np.asarray(ri))
    assert (np.asarray(s)[:, 15:] == -1).all()


@pytest.mark.parametrize("backend", ["pallas", "ref", "mxu"])
def test_threshold_match_agrees_with_ppac_array(rng, backend):
    """The fused CAM δ-match must agree with the cycle-exact PPACArray
    emulator (paper §III-A) row-for-row."""
    m, n = 32, 48
    a_bits = rng.integers(0, 2, (m, n)).astype(np.uint8)
    arr = PPACArray(PPACConfig(m=m, n=n))
    arr.write(a_bits)
    x_bits = a_bits[5].copy()
    x_bits[:4] ^= 1  # 4 mismatches
    for delta in (n, n - 4, n // 2):
        want = np.asarray(arr.cam_match(x_bits, delta=delta)).astype(np.uint8)
        got = np.asarray(hamming_threshold_match(
            F.pack_bits(x_bits[None, :]), F.pack_bits(a_bits),
            n=n, delta=delta, backend=backend))[0]
        assert np.array_equal(got, want), delta


def test_camindex_search_and_write_path(rng):
    idx = CAMIndex(64, backend="mxu", min_capacity=256)
    codes = rng.integers(0, 2, (700, 64))
    ids = idx.add(codes)
    assert np.array_equal(ids, np.arange(700))
    assert idx.size == 700 and idx.capacity % idx.config.m == 0

    # exact self-retrieval
    res = idx.search(codes[[5, 300, 699]], k=3)
    assert np.array_equal(res.ids[:, 0], [5, 300, 699])
    assert (res.scores[:, 0] == 64).all()

    # delete -> gone from results; add -> tombstones reused, ids stable
    assert idx.delete([5, 300]) == 2 and idx.size == 698
    res = idx.search(codes[[5, 300]], k=1)
    assert res.ids[0, 0] != 5 and res.ids[1, 0] != 300
    new_ids = idx.add(rng.integers(0, 2, (2, 64)))
    assert set(new_ids.tolist()) == {5, 300} and idx.size == 700

    # brute-force oracle over the whole (masked) store
    q = rng.integers(0, 2, (4, 64))
    res = idx.search(q, k=10)
    hs = (q[:, None, :] == np.asarray(
        F.unpack_bits(idx._codes, 64))[None, :, :]).sum(-1)
    hs = np.where(idx._valid[None, :] > 0, hs, -1)
    order = np.lexsort((np.arange(hs.shape[1])[None, :].repeat(4, 0), -hs), 1)
    assert np.array_equal(res.ids, order[:, :10])
    assert np.array_equal(res.scores, np.take_along_axis(hs, order, 1)[:, :10])


def test_camindex_duplicate_delete(rng):
    """Duplicate ids in one delete() must tombstone the row exactly once
    (no double free-list entry, no live-count drift)."""
    idx = CAMIndex(32, backend="mxu", min_capacity=256)
    idx.add(rng.integers(0, 2, (10, 32)))
    assert idx.delete([3, 3, 3]) == 1
    assert idx.size == 9
    new = idx.add(rng.integers(0, 2, (2, 32)))
    assert sorted(new.tolist()) == [3, 10] and idx.size == 11


def test_camindex_delete_all_then_search(rng):
    """An emptied index must still answer: every slot comes back with the
    masked score -1 and index-ascending tombstone ids."""
    idx = CAMIndex(48, backend="mxu", min_capacity=256)
    codes = rng.integers(0, 2, (30, 48))
    ids = idx.add(codes)
    assert idx.delete(ids) == 30 and idx.size == 0
    res = idx.search(codes[:4], k=5)
    assert (res.scores == -1).all()
    assert np.array_equal(res.ids, np.tile(np.arange(5), (4, 1)))
    lines = idx.match(codes[:2])
    assert not lines.any()
    assert idx.match_ids(codes[:1]) and idx.match_ids(codes[:1])[0].size == 0


def test_camindex_k_exceeds_live_rows(rng):
    """k may exceed the live count (up to capacity): real rows first, then
    -1 fillers — bit-identical between backends."""
    idx = CAMIndex(32, backend="mxu", min_capacity=256)
    codes = rng.integers(0, 2, (10, 32))
    idx.add(codes)
    idx.delete([1, 3, 5, 7, 9])
    res = idx.search(codes[[0]], k=12)
    assert res.scores[0, 0] == 32 and res.ids[0, 0] == 0
    assert (res.scores[0, :5] >= 0).all() and (res.scores[0, 5:] == -1).all()
    live = {0, 2, 4, 6, 8}
    assert set(res.ids[0, :5].tolist()) == live
    ref = idx.search(codes[[0]], k=12, backend="ref")
    assert np.array_equal(res.scores, ref.scores)
    assert np.array_equal(res.ids, ref.ids)


def test_camindex_readd_after_delete_all(rng):
    """Tombstoned slots are all reused before the high-water mark grows,
    and re-added codes are immediately searchable."""
    idx = CAMIndex(24, backend="mxu", min_capacity=256)
    first = rng.integers(0, 2, (12, 24))
    ids = idx.add(first)
    idx.delete(ids)
    second = rng.integers(0, 2, (12, 24))
    new_ids = idx.add(second)
    assert sorted(new_ids.tolist()) == sorted(ids.tolist())  # slots reused
    assert idx.high_water == 12 and idx.size == 12
    res = idx.search(second, k=1)
    assert (res.scores[:, 0] == 24).all()
    got = {int(i): int(r) for i, r in zip(res.ids[:, 0], range(12))}
    for rid, row in got.items():
        assert np.array_equal(
            np.asarray(F.unpack_bits(idx._codes[rid], 24)), second[row])


def test_camindex_delete_bogus_ids(rng):
    """Out-of-range, negative, duplicate and already-dead ids are ignored
    and never corrupt the live count or free list."""
    idx = CAMIndex(16, backend="mxu", min_capacity=256)
    idx.add(rng.integers(0, 2, (5, 16)))
    assert idx.delete([-3, 99, 1000]) == 0 and idx.size == 5
    assert idx.delete([2, 2, -1, 99]) == 1 and idx.size == 4
    assert idx.delete([2]) == 0 and idx.size == 4      # already tombstoned
    assert len(idx._free) == 1
    new = idx.add(rng.integers(0, 2, (1, 16)))
    assert new.tolist() == [2] and idx.size == 5


def test_camindex_match_and_cycles(rng):
    idx = CAMIndex(32, backend="mxu", min_capacity=256,
                   config=PPACConfig(m=64, n=16))  # 2 col tiles
    codes = rng.integers(0, 2, (200, 32))
    idx.add(codes)
    idx.delete([7])
    lines = idx.match(codes[[7, 9]])
    assert lines.shape == (2, 200)
    assert lines[0, 7] == 0      # tombstoned: never matches
    assert lines[1, 9] == 1
    cand = idx.match_ids(codes[[9]], delta=16)
    assert 9 in cand[0]

    c0 = idx.counter.cycles
    res = idx.search(codes[:8], k=4)
    assert idx.counter.cycles - c0 == res.stats["total_cycles"]
    # scan cycles grow with the store; select cost scales with k
    assert res.stats["row_tiles"] == -(-idx.high_water // 64)
    assert res.stats["col_tiles"] == 2
    assert idx.cycles_per_query(8) > idx.cycles_per_query(1) > \
        idx.cycles_per_query(0, threshold_only=True)


SUBPROC_SHARDED = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import numpy as np, jax
    from repro.retrieval import CAMIndex

    rng = np.random.default_rng(3)
    idx = CAMIndex(96, backend="mxu", min_capacity=512)
    idx.add(rng.integers(0, 2, (900, 96)))
    idx.delete(list(range(40, 80)))
    q = rng.integers(0, 2, (5, 96))
    single = idx.search(q, k=8)
    mesh = jax.make_mesh((2,), ("data",))
    for be in ("mxu", "ref", "pallas"):
        sh = idx.search(q, k=8, mesh=mesh, backend=be)
        assert np.array_equal(single.scores, sh.scores), be
        assert np.array_equal(single.ids, sh.ids), be
        assert sh.stats["shards"] == 2
    print("SHARDED_OK")
""")


def test_sharded_search_matches_single_device():
    """2 simulated devices: row-sharded search with all-gather top-k merge
    must be bit-identical to the single-device path, for every backend."""
    res = subprocess.run([sys.executable, "-c", SUBPROC_SHARDED],
                         capture_output=True, text=True, timeout=600,
                         env=cpu_subproc_env())
    assert "SHARDED_OK" in res.stdout, res.stdout + res.stderr


def test_retrieval_server_bucketing(rng):
    idx = CAMIndex(32, backend="mxu", min_capacity=256)
    codes = rng.integers(0, 2, (120, 32))
    idx.add(codes)
    server = RetrievalServer(idx, max_k=4, buckets=(1, 4, 16))
    targets = rng.integers(0, 120, 23)
    for i, t in enumerate(targets):
        server.submit(LookupRequest(i, codes[t].copy(), k=1 + i % 4))
    done = server.run()
    assert len(done) == 23 and all(r.done for r in done)
    for r in done:
        assert r.ids.shape == (r.k,) and r.ids[0] == targets[r.rid]
        assert r.scores[0] == 32
    # 23 requests: whole buckets 16 and 4 drain unpadded, the remaining
    # 3 pad into one 4-bucket
    assert server.batches == 3
    assert server.bucket_counts[16] == 1 and server.bucket_counts[4] == 2
