"""GPipe pipeline parallelism: pipelined == sequential, fwd and grad."""
import subprocess
import sys
import textwrap

from conftest import cpu_subproc_env

SUB = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, jax.numpy as jnp, numpy as np
    from repro.sharding.pipeline import pipeline_apply

    S, M, B, D = 4, 8, 16, 32
    mesh = jax.make_mesh((S,), ("pipe",))
    rng = np.random.default_rng(0)
    ws = jnp.asarray(rng.standard_normal((S, D, D)) * 0.3, jnp.float32)
    x = jnp.asarray(rng.standard_normal((B, D)), jnp.float32)

    def stage(w, h):
        return jnp.tanh(h @ w)

    def seq(ws, x):
        h = x
        for i in range(S):
            h = stage(ws[i], h)
        return h

    def stage_p(p, h):
        return stage(p["w"], h)

    with mesh:
        y_pipe = pipeline_apply(stage_p, {"w": ws}, x, mesh=mesh,
                                microbatches=M)
    y_seq = seq(ws, x)
    np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_seq),
                               rtol=1e-5, atol=1e-5)

    # gradients flow through the pipeline (ppermute transpose)
    def loss_pipe(ws):
        with mesh:
            return jnp.sum(pipeline_apply(stage_p, {"w": ws}, x, mesh=mesh,
                                          microbatches=M) ** 2)
    def loss_seq(ws):
        return jnp.sum(seq(ws, x) ** 2)
    g_pipe = jax.grad(loss_pipe)(ws)
    g_seq = jax.grad(loss_seq)(ws)
    np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_seq),
                               rtol=1e-4, atol=1e-4)
    print("PIPE_OK")
""")


def test_gpipe_matches_sequential():
    res = subprocess.run([sys.executable, "-c", SUB], capture_output=True,
                         text=True, timeout=600, env=cpu_subproc_env())
    assert "PIPE_OK" in res.stdout, res.stdout + res.stderr
