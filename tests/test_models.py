"""Per-arch smoke tests (reduced configs): forward/train/decode shapes,
no NaNs, and decode-vs-forward consistency (validates KV caches, SSD
recurrence, MLA absorption, rolling SWA caches)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, load_arch
from repro.models import lm


def smoke_batch(cfg, b=2, s=32, rng=None):
    rng = rng or np.random.default_rng(0)
    batch = {}
    if cfg.frontend == "audio":
        batch["embeds"] = jnp.asarray(
            rng.standard_normal((b, s, cfg.d_model)) * 0.02, jnp.float32)
    elif cfg.frontend == "vision":
        p = cfg.frontend_tokens
        batch["patches"] = jnp.asarray(
            rng.standard_normal((b, p, cfg.d_model)) * 0.02, jnp.float32)
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (b, s - p)), jnp.int32)
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch, rng):
    cfg = load_arch(arch).smoke()
    params, axes = lm.init(cfg, jax.random.PRNGKey(0))
    batch = smoke_batch(cfg, rng=rng)
    logits, aux = lm.forward(params, cfg, batch)
    assert logits.shape[-1] == cfg.vocab
    assert logits.dtype == jnp.float32
    assert np.isfinite(np.asarray(logits)).all()
    # axes tree matches params tree structure
    jax.tree.map(lambda p, a: None, params,
                 jax.tree.map(lambda x: 0, axes,
                              is_leaf=lambda x: x is None or isinstance(x, tuple)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch, rng):
    """Prefill T tokens then decode k: logits must match the full forward
    (fp32 smoke config -> tight tolerance). Exercises every cache type."""
    cfg = dataclasses.replace(load_arch(arch).smoke(), dtype="float32")
    if cfg.moe:
        # capacity dropping is group-shape-dependent; disable drops so
        # forward and prefill/decode see identical expert assignments
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params, _ = lm.init(cfg, jax.random.PRNGKey(1))
    b, s = 2, 24
    t0, steps = 16, 4
    batch = smoke_batch(cfg, b=b, s=s, rng=rng)

    full_logits, _ = lm.forward(params, cfg, batch)

    if cfg.frontend == "vision":
        # decode continues the text stream after patches
        pre = {"patches": batch["patches"],
               "tokens": batch["tokens"][:, : t0 - cfg.frontend_tokens]}
        toks = batch["tokens"]
        off = cfg.frontend_tokens
    elif cfg.frontend == "audio":
        pre = {"embeds": batch["embeds"][:, :t0]}
        toks = None
        off = 0
    else:
        pre = {"tokens": batch["tokens"][:, :t0]}
        toks = batch["tokens"]
        off = 0

    cache, _ = lm.init_cache(cfg, b, s, dtype=jnp.float32)
    logits, cache = lm.prefill(params, cfg, pre, cache)
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(full_logits[:, t0 - 1]),
                               rtol=2e-3, atol=2e-3)
    if cfg.frontend == "audio":
        return  # continuing decode needs token embeds; covered elsewhere

    for i in range(steps):
        nxt = toks[:, t0 - off + i][:, None]
        logits, cache = lm.decode_step(params, cfg, nxt, cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, t0 + i]),
            rtol=5e-3, atol=5e-3, err_msg=f"{arch} step {i}")


def test_swa_rolling_cache_beyond_window(rng):
    """Decode past the sliding window with the rolling cache: logits must
    match a forward whose attention is windowed the same way."""
    cfg = dataclasses.replace(load_arch("h2o_danube3_4b").smoke(),
                              dtype="float32", sliding_window=8)
    params, _ = lm.init(cfg, jax.random.PRNGKey(2))
    b, s = 1, 32
    tokens = jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)
    full_logits, _ = lm.forward(params, cfg, {"tokens": tokens})

    t0 = 4  # prefill shorter than the window, then roll far past it
    cache, _ = lm.init_cache(cfg, b, s, dtype=jnp.float32)
    logits, cache = lm.prefill(params, cfg, {"tokens": tokens[:, :t0]}, cache)
    for i in range(t0, s - 1):
        logits, cache = lm.decode_step(params, cfg, tokens[:, i][:, None],
                                       cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, i]),
            rtol=5e-3, atol=5e-3, err_msg=f"pos {i}")


def test_mamba2_long_decode_state_is_constant_memory(rng):
    cfg = dataclasses.replace(load_arch("mamba2_370m").smoke(),
                              dtype="float32")
    params, _ = lm.init(cfg, jax.random.PRNGKey(3))
    cache, _ = lm.init_cache(cfg, 1, 8, dtype=jnp.float32)
    sizes = {k: v.shape for k, v in jax.tree_util.tree_leaves_with_path(cache)}
    tok = jnp.ones((1, 1), jnp.int32)
    logits, cache = lm.prefill(params, cfg, {"tokens": jnp.ones((1, 8), jnp.int32)}, cache)
    for _ in range(5):
        logits, cache = lm.decode_step(params, cfg, tok, cache)
    # state shapes unchanged (no growth with sequence length)
    sizes2 = {k: v.shape for k, v in jax.tree_util.tree_leaves_with_path(cache)}
    assert sizes == sizes2


def test_param_counts_match_formula():
    for arch in ARCH_IDS:
        cfg = load_arch(arch).smoke()
        params, _ = lm.init(cfg, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
        est = cfg.param_count()
        # formula is approximate (biases, norms); within 20%
        assert abs(actual - est) / actual < 0.2, (arch, actual, est)
