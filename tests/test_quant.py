"""Quantizers, STE gradients, and the PPAC serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats as F
from repro.core.engine import (
    QuantContainer,
    pack_weight_for_serving,
    qat_dense,
    serve_dense,
)
from repro.core.quant import binarize_pm1, fake_quant, quantize


@pytest.mark.parametrize("fmt", ["uint", "int", "oddint"])
@pytest.mark.parametrize("bits", [2, 4, 8])
def test_quantize_in_range(rng, fmt, bits):
    x = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    q, s = quantize(x, bits, fmt)
    qn = np.asarray(q)
    lo, hi = F.value_range(fmt, bits)
    assert qn.min() >= lo and qn.max() <= hi
    assert np.array_equal(qn, np.round(qn))  # exact integers
    if fmt == "oddint":
        assert np.all(np.abs(qn.astype(int)) % 2 == 1)


def test_fake_quant_error_shrinks_with_bits(rng):
    x = jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)
    errs = [float(jnp.mean(jnp.abs(fake_quant(x, b, "int") - x)))
            for b in (2, 4, 8)]
    assert errs[0] > errs[1] > errs[2]


def test_ste_gradients_flow(rng):
    x = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)

    def f(x):
        return jnp.sum(fake_quant(x, 4, "int") ** 2)

    g = jax.grad(f)(x)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).sum()) > 0


def test_binarize_ste_clips(rng):
    x = jnp.asarray([[0.5, -2.0, 3.0, -0.1]], jnp.float32)
    g = jax.grad(lambda x: jnp.sum(binarize_pm1(x)[0]))(x)
    gn = np.asarray(g)[0]
    assert gn[0] != 0 and gn[3] != 0       # |x| <= 1 passes gradient
    assert gn[1] == 0 and gn[2] == 0       # clipped outside


@pytest.mark.parametrize("bits,kind", [(1, "packed1"), (4, "packed4"),
                                       (8, "int8")])
def test_serving_containers(bits, kind):
    rng = np.random.default_rng(42)  # deterministic: 1-bit corr is seed-sensitive
    w = jnp.asarray(rng.standard_normal((256, 128)), jnp.float32) * 0.1
    c = pack_weight_for_serving(w, weight_bits=bits)
    assert isinstance(c, QuantContainer) and c.kind == kind
    x = jnp.asarray(rng.standard_normal((6, 256)), jnp.float32)
    y = serve_dense(x, c, act_bits=8)
    yn = np.asarray(y, np.float32)
    if bits == 1:
        # 1-bit of a *random* gaussian matrix is inherently lossy vs float
        # (BNN accuracy comes from training, see examples/bnn_inference.py);
        # the engine itself must match the binarized math EXACTLY.
        wq, ws = binarize_pm1(w, axis=0)
        xq, xs = binarize_pm1(x, axis=-1)
        manual = np.asarray((xq @ (wq * ws)) * xs)
        np.testing.assert_allclose(yn, manual, rtol=1e-4, atol=1e-5)
    else:
        rn = np.asarray(x @ w)
        corr = np.corrcoef(yn.ravel(), rn.ravel())[0, 1]
        assert corr > 0.98, (kind, corr)


def test_container_memory_shrinks(rng):
    w = jnp.ones((256, 256), jnp.float32)
    raw = w.size * 2  # bf16 serving baseline
    for bits, factor in ((8, 2), (4, 4), (1, 16)):
        c = pack_weight_for_serving(w, weight_bits=bits)
        packed_bytes = c.wq.size * c.wq.dtype.itemsize
        assert packed_bytes * factor <= raw + 1


def test_qat_dense_runs_and_differentiates(rng):
    x = jnp.asarray(rng.standard_normal((4, 32)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((32, 16)), jnp.float32) * 0.1

    def loss(w):
        return jnp.sum(qat_dense(x, w, weight_bits=4, act_bits=4) ** 2
                       ).astype(jnp.float32)

    g = jax.grad(loss)(w)
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).sum()) > 0
