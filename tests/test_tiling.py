"""Tiled-kernel regression guards for the shared tiling engine.

The lane-streamed kernels must reproduce, tile-for-tile, the result of a
single whole-matrix launch (block sizes >= the padded operand — exactly
the pre-refactor whole-matrix behavior) on shapes spanning several tiles
in every grid dimension, and both must match the jnp oracles.
"""
import numpy as np
import pytest

from repro.core import formats as F
from repro.kernels.binary_mvp.kernel import binary_matmul_packed
from repro.kernels.binary_mvp.ref import binary_matmul_packed_ref
from repro.kernels.bitserial_mvp.kernel import bitserial_matmul_packed
from repro.kernels.bitserial_mvp.ref import bitserial_matmul_packed_ref
from repro.kernels.gf2_tiled.kernel import gf2_matmul_packed
from repro.kernels.gf2_tiled.ref import gf2_matmul_packed_ref
from repro.kernels.tiling import plan_tiles, round_up

# b=70 > block_b=64, m=300 > block_m=128, n=6000 -> W=188 > block_w -> the
# default plans stream several tiles along every grid dimension.
MULTI_TILE = (70, 300, 6000)


def test_plan_tiles_invariants():
    for b, m, w in [(1, 1, 1), (7, 9, 3), (70, 300, 188), (64, 128, 64)]:
        p = plan_tiles(b, m, w)
        assert p.bp % p.bb == 0 and p.mp % p.bm == 0 and p.wp % p.bw == 0
        assert p.bm % p.rc == 0
        assert p.bp >= b and p.mp >= m and p.wp >= w
        gb, gm, gw = p.grid
        assert gb * p.bb == p.bp and gm * p.bm == p.mp and gw * p.bw == p.wp


def test_plan_tiles_single_tile_when_blocks_cover():
    b, m, w = MULTI_TILE
    wl = F.packed_width(w)
    p = plan_tiles(b, m, wl, block_b=round_up(b, 8), block_m=round_up(m, 8),
                   block_w=round_up(wl, 128))
    assert p.grid == (1, 1, 1)


@pytest.mark.parametrize("op", ["xor", "and"])
def test_binary_streamed_vs_whole_matrix(rng, op):
    b, m, n = MULTI_TILE
    x = F.pack_bits(rng.integers(0, 2, (b, n)))
    a = F.pack_bits(rng.integers(0, 2, (m, n)))
    wl = x.shape[1]
    assert wl > 64  # more than one default lane tile
    streamed = np.asarray(binary_matmul_packed(x, a, op=op, interpret=True))
    whole = np.asarray(binary_matmul_packed(
        x, a, op=op, block_b=round_up(b, 8), block_m=round_up(m, 8),
        block_w=round_up(wl, 128), interpret=True))
    ref = np.asarray(binary_matmul_packed_ref(x, a, op=op))
    assert np.array_equal(streamed, whole)
    assert np.array_equal(streamed, ref)


def test_bitserial_streamed_vs_whole_matrix(rng):
    l1, k1, b, m, wl = 3, 2, 20, 140, 70  # wl > block_w=32 -> lane streaming
    xp = rng.integers(0, 2**32, (l1, b, wl), dtype=np.uint32)
    ap = rng.integers(0, 2**32, (k1, m, wl), dtype=np.uint32)
    w = rng.integers(-8, 8, (k1, l1)).astype(np.int32)
    streamed = np.asarray(bitserial_matmul_packed(xp, ap, w, interpret=True))
    whole = np.asarray(bitserial_matmul_packed(
        xp, ap, w, block_b=round_up(b, 8), block_m=round_up(m, 8),
        block_w=round_up(wl, 128), interpret=True))
    ref = np.asarray(bitserial_matmul_packed_ref(xp, ap, w))
    assert np.array_equal(streamed, whole)
    assert np.array_equal(streamed, ref)


def test_gf2_streamed_vs_whole_matrix(rng):
    b, m, n = 24, 300, 9000  # W=282 > block_w=128 -> several lane tiles
    x = F.pack_bits(rng.integers(0, 2, (b, n)))
    a = F.pack_bits(rng.integers(0, 2, (m, n)))
    wl = x.shape[1]
    streamed = np.asarray(gf2_matmul_packed(x, a, interpret=True))
    whole = np.asarray(gf2_matmul_packed(
        x, a, block_b=round_up(b, 8), block_m=round_up(m, 8),
        block_w=round_up(wl, 128), interpret=True))
    ref = np.asarray(gf2_matmul_packed_ref(x, a))
    assert np.array_equal(streamed, whole)
    assert np.array_equal(streamed, ref)


def test_binary_block_sweep_agrees(rng):
    """Any legal block geometry produces the same S (tiling is invisible)."""
    x = F.pack_bits(rng.integers(0, 2, (13, 700)))
    a = F.pack_bits(rng.integers(0, 2, (37, 700)))
    ref = np.asarray(binary_matmul_packed_ref(x, a, op="xor"))
    for bb, bm, bw, rc in [(8, 8, 128, 2), (16, 24, 128, 8), (64, 128, 16, 8)]:
        got = np.asarray(binary_matmul_packed(
            x, a, op="xor", block_b=bb, block_m=bm, block_w=bw, row_chunk=rc,
            interpret=True))
        assert np.array_equal(got, ref), (bb, bm, bw, rc)
