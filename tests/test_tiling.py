"""Tiled-kernel regression guards for the shared tiling engine.

The lane-streamed kernels must reproduce, tile-for-tile, the result of a
single whole-matrix launch (block sizes >= the padded operand — exactly
the pre-refactor whole-matrix behavior) on shapes spanning several tiles
in every grid dimension, and both must match the jnp oracles.
"""
import numpy as np
import pytest

from repro.core import formats as F
from repro.kernels.binary_mvp.kernel import binary_matmul_packed
from repro.kernels.binary_mvp.ref import binary_matmul_packed_ref
from repro.kernels.bitserial_mvp.kernel import (
    bitserial_matmul_packed,
    bitserial_matmul_sliced,
)
from repro.kernels.bitserial_mvp.ops import levels_to_stack
from repro.kernels.bitserial_mvp.ref import bitserial_matmul_packed_ref
from repro.kernels.gf2_tiled.kernel import gf2_matmul_packed
from repro.kernels.gf2_tiled.ref import gf2_matmul_packed_ref
from repro.kernels.tiling import (
    PlanCache,
    autotune_plan,
    plan_cache,
    plan_for,
    plan_tiles,
    round_up,
)

# b=70 > block_b=64, m=300 > block_m=128, n=6000 -> W=188 > block_w -> the
# default plans stream several tiles along every grid dimension.
MULTI_TILE = (70, 300, 6000)


def test_plan_tiles_invariants():
    for b, m, w in [(1, 1, 1), (7, 9, 3), (70, 300, 188), (64, 128, 64)]:
        p = plan_tiles(b, m, w)
        assert p.bp % p.bb == 0 and p.mp % p.bm == 0 and p.wp % p.bw == 0
        assert p.bm % p.rc == 0
        assert p.bp >= b and p.mp >= m and p.wp >= w
        gb, gm, gw = p.grid
        assert gb * p.bb == p.bp and gm * p.bm == p.mp and gw * p.bw == p.wp


def test_plan_tiles_single_tile_when_blocks_cover():
    b, m, w = MULTI_TILE
    wl = F.packed_width(w)
    p = plan_tiles(b, m, wl, block_b=round_up(b, 8), block_m=round_up(m, 8),
                   block_w=round_up(wl, 128))
    assert p.grid == (1, 1, 1)


@pytest.mark.parametrize("op", ["xor", "and"])
def test_binary_streamed_vs_whole_matrix(rng, op):
    b, m, n = MULTI_TILE
    x = F.pack_bits(rng.integers(0, 2, (b, n)))
    a = F.pack_bits(rng.integers(0, 2, (m, n)))
    wl = x.shape[1]
    assert wl > 64  # more than one default lane tile
    streamed = np.asarray(binary_matmul_packed(x, a, op=op, interpret=True))
    whole = np.asarray(binary_matmul_packed(
        x, a, op=op, block_b=round_up(b, 8), block_m=round_up(m, 8),
        block_w=round_up(wl, 128), interpret=True))
    ref = np.asarray(binary_matmul_packed_ref(x, a, op=op))
    assert np.array_equal(streamed, whole)
    assert np.array_equal(streamed, ref)


def test_bitserial_streamed_vs_whole_matrix(rng):
    l1, k1, b, m, wl = 3, 2, 20, 140, 70  # wl > block_w=32 -> lane streaming
    xp = rng.integers(0, 2**32, (l1, b, wl), dtype=np.uint32)
    ap = rng.integers(0, 2**32, (k1, m, wl), dtype=np.uint32)
    w = rng.integers(-8, 8, (k1, l1)).astype(np.int32)
    streamed = np.asarray(bitserial_matmul_packed(xp, ap, w, interpret=True))
    whole = np.asarray(bitserial_matmul_packed(
        xp, ap, w, block_b=round_up(b, 8), block_m=round_up(m, 8),
        block_w=round_up(wl, 128), interpret=True))
    ref = np.asarray(bitserial_matmul_packed_ref(xp, ap, w))
    assert np.array_equal(streamed, whole)
    assert np.array_equal(streamed, ref)


def test_gf2_streamed_vs_whole_matrix(rng):
    b, m, n = 24, 300, 9000  # W=282 > block_w=128 -> several lane tiles
    x = F.pack_bits(rng.integers(0, 2, (b, n)))
    a = F.pack_bits(rng.integers(0, 2, (m, n)))
    wl = x.shape[1]
    streamed = np.asarray(gf2_matmul_packed(x, a, interpret=True))
    whole = np.asarray(gf2_matmul_packed(
        x, a, block_b=round_up(b, 8), block_m=round_up(m, 8),
        block_w=round_up(wl, 128), interpret=True))
    ref = np.asarray(gf2_matmul_packed_ref(x, a))
    assert np.array_equal(streamed, whole)
    assert np.array_equal(streamed, ref)


def test_plan_tiles_rounds_row_tile_up_to_chunk():
    """A prime requested row tile used to silently degrade row_chunk to 1
    (an 8x fatter popcount loop); now the tile rounds UP to honor the
    requested chunk verbatim."""
    p = plan_tiles(8, 100, 4, block_m=13, row_chunk=8)
    assert p.rc == 8
    assert p.bm == 16 and p.bm % p.rc == 0
    # the rounded-up geometry still tiles cleanly and covers the rows
    assert p.mp % p.bm == 0 and p.mp >= 100
    # a chunk larger than the tile clamps to it, never to 1
    p2 = plan_tiles(8, 4, 4, block_m=8, row_chunk=16)
    assert p2.rc == p2.bm == 8
    # chunks that don't divide 8 keep BOTH the chunk and the TPU sublane
    # rule: the row tile lands on lcm(rc, 8)
    p3 = plan_tiles(8, 100, 4, block_m=8, row_chunk=3)
    assert p3.rc == 3 and p3.bm == 24
    assert p3.bm % p3.rc == 0 and p3.bm % 8 == 0


def test_prime_row_tile_result_unchanged(rng):
    """Tiling geometry is invisible: the rounded-up prime-tile plan still
    reproduces the oracle bitwise."""
    x = F.pack_bits(rng.integers(0, 2, (13, 700)))
    a = F.pack_bits(rng.integers(0, 2, (37, 700)))
    ref = np.asarray(binary_matmul_packed_ref(x, a, op="xor"))
    got = np.asarray(binary_matmul_packed(x, a, op="xor", block_m=13,
                                          row_chunk=8, interpret=True))
    assert np.array_equal(got, ref)


def test_bitserial_sliced_matches_packed(rng):
    """The in-kernel bit-slicing variant == the packed-plane kernel ==
    the oracle, on a multi-tile lane-streamed shape."""
    k1, b, m, n, l_bits = 2, 20, 140, 2201, 3  # wl=69 > default lane tile
    ap = rng.integers(0, 2**32, (k1, m, F.packed_width(n)), dtype=np.uint32)
    x = rng.integers(-(2 ** (l_bits - 1)), 2 ** (l_bits - 1), (b, n))
    u = levels_to_stack(F.to_levels(x, l_bits, "int"), F.packed_width(n))
    xp = F.pack_bits(F.to_bitplanes(x, l_bits, "int"))
    w = rng.integers(-8, 8, (k1, l_bits)).astype(np.int32)
    sliced = np.asarray(bitserial_matmul_sliced(u, ap, w, l_bits=l_bits,
                                                interpret=True))
    packed = np.asarray(bitserial_matmul_packed(xp, ap, w, interpret=True))
    ref = np.asarray(bitserial_matmul_packed_ref(xp, ap, w))
    assert np.array_equal(sliced, packed)
    assert np.array_equal(sliced, ref)


def test_autotune_cache_roundtrip(rng, tmp_path, monkeypatch):
    """autotune_plan persists the winning blocks; plan_for and a fresh
    PlanCache instance both read them back."""
    monkeypatch.setenv("PPAC_TILE_CACHE", str(tmp_path / "plans.json"))
    b, m, n = 4, 24, 300
    wl = F.packed_width(n)
    xp = F.pack_bits(rng.integers(0, 2, (2, b, n)))
    ap = F.pack_bits(rng.integers(0, 2, (2, m, n)))
    w = rng.integers(-4, 4, (2, 2)).astype(np.int32)
    candidates = [dict(block_b=8, block_m=8, block_w=32, row_chunk=4),
                  dict(block_b=8, block_m=24, block_w=32, row_chunk=8)]

    def run(plan):
        return bitserial_matmul_packed(xp, ap, w, interpret=True,
                                       **plan.blocks)

    tuned = autotune_plan("bitserial", b, m, wl, run, candidates=candidates,
                          reps=1)
    resolved = [plan_tiles(b, m, wl, **c).blocks for c in candidates]
    assert tuned.blocks in resolved  # winner is one of the candidates
    # plan_for (same process) returns the tuned geometry, not the default
    assert plan_for("bitserial", b, m, wl).blocks == tuned.blocks
    # a fresh cache object re-reads the persisted JSON
    fresh = PlanCache(str(tmp_path / "plans.json"))
    stored = fresh.get("bitserial", b, m, wl)
    assert stored is not None
    assert plan_tiles(b, m, wl, **stored).blocks == tuned.blocks
    # explicit overrides still beat the cache
    assert plan_for("bitserial", b, m, wl, row_chunk=2).rc == 2


def test_decode_defaults_use_thin_batch_tile():
    p = plan_for("bitserial", 2, 512, 64, use_cache=False)
    assert p.bb == 8          # decode-shaped: tiny batch tile
    assert p.bm >= 128        # ... traded for a fatter row tile
    big = plan_for("bitserial", 128, 512, 64, use_cache=False)
    assert big.bb == 64


def test_binary_block_sweep_agrees(rng):
    """Any legal block geometry produces the same S (tiling is invisible)."""
    x = F.pack_bits(rng.integers(0, 2, (13, 700)))
    a = F.pack_bits(rng.integers(0, 2, (37, 700)))
    ref = np.asarray(binary_matmul_packed_ref(x, a, op="xor"))
    for bb, bm, bw, rc in [(8, 8, 128, 2), (16, 24, 128, 8), (64, 128, 16, 8)]:
        got = np.asarray(binary_matmul_packed(
            x, a, op="xor", block_b=bb, block_m=bm, block_w=bw, row_chunk=rc,
            interpret=True))
        assert np.array_equal(got, ref), (bb, bm, bw, rc)
