"""Self-speculative decoding on the precision ladder: the multi-token
verify forward must be bit-identical to sequential decode, greedy spec
output bit-identical to target-rung-only generation (contiguous, ring,
and paged caches), accept rate exactly 1.0 when the drafter IS the
target, and ring rollback must restore rejected slots after a mid-window
rejection."""
import dataclasses
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import load_arch
from repro.models import lm
from repro.serve.step import (
    _spec_round,
    convert_params_for_serving,
    generate_scan,
    make_prefill_step,
    speculative_generate,
)


def _tokens(rng, cfg, b, s):
    return jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)


def _quant_cfg(arch, wb):
    cfg = load_arch(arch).smoke()
    return dataclasses.replace(
        cfg, ppac=dataclasses.replace(cfg.ppac, enabled=True,
                                      weight_bits=wb, act_bits=8,
                                      min_features=32))


# -- the verify forward: one batched launch == k+1 sequential steps -----------

def test_verify_logits_match_sequential_decode(rng):
    """lm.verify over a k+1 window must reproduce the per-step decode
    logits bit-exactly — same einsums, same mask ordering — and advance
    pos by the window length."""
    cfg = dataclasses.replace(load_arch("smollm_360m").smoke(),
                              dtype="float32")
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cache, _ = lm.init_cache(cfg, 2, 32)
    _, cache = lm.prefill(params, cfg, {"tokens": _tokens(rng, cfg, 2, 6)},
                          cache)
    window = _tokens(rng, cfg, 2, 4)

    seq = []
    c = cache
    for j in range(window.shape[1]):
        lg, c = lm.decode_step(params, cfg, window[:, j:j + 1], c)
        seq.append(lg[:, -1])
    ref = jnp.stack(seq, axis=1)

    got, vcache = lm.verify(params, cfg, window, cache)
    assert np.array_equal(np.asarray(got), np.asarray(ref))
    assert np.array_equal(np.asarray(vcache["pos"]), np.asarray(c["pos"]))


def test_verify_rejects_ssm():
    cfg = load_arch("mamba2_370m").smoke()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cache, _ = lm.init_cache(cfg, 1, 16)
    with pytest.raises(ValueError, match="rewind"):
        lm.verify(params, cfg, jnp.ones((1, 3), jnp.int32), cache)


# -- greedy bit-identity across target rungs and cache flavors ----------------

@pytest.mark.parametrize("wb", [0, 4, 8])
def test_spec_matches_generate_scan_contiguous(rng, wb):
    """temperature-0 spec output == plain target-rung generate_scan,
    bit for bit: float target (drafter falls back to the target itself)
    and packed4/int8 targets drafting with the resident packed1 rung."""
    if wb == 0:
        cfg = dataclasses.replace(load_arch("smollm_360m").smoke(),
                                  dtype="float32")
        params, _ = lm.init(cfg, jax.random.PRNGKey(0))
        mode = "float"
    else:
        cfg = _quant_cfg("stablelm_12b", wb)
        params0, _ = lm.init(cfg, jax.random.PRNGKey(0))
        params = convert_params_for_serving(params0, cfg, draft=True)
        mode = "serve"
    batch = {"tokens": _tokens(rng, cfg, 2, 8)}
    ref = generate_scan(params, cfg, batch, steps=7, max_seq=32, mode=mode)
    got = speculative_generate(params, cfg, batch, steps=7, max_seq=32,
                               draft_k=3, mode=mode)
    assert np.array_equal(np.asarray(got), np.asarray(ref))


def test_spec_matches_generate_scan_ring_wraparound(rng):
    """Sliding-window ring cache, generating past the ring extent: the
    rejected-slot rollback must restore superseded rows exactly (the
    packed1 drafter rejects often on random weights, so mid-window
    rejections with wrapped positions are exercised for real)."""
    cfg = _quant_cfg("h2o_danube3_4b", 4)
    assert cfg.sliding_window
    params0, _ = lm.init(cfg, jax.random.PRNGKey(0))
    params = convert_params_for_serving(params0, cfg, draft=True)
    batch = {"tokens": _tokens(rng, cfg, 2, 8)}
    ref = generate_scan(params, cfg, batch, steps=14, max_seq=16,
                        mode="serve")
    got = speculative_generate(params, cfg, batch, steps=14, max_seq=16,
                               draft_k=3, mode="serve")
    assert np.array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("spec_kw", [dict(), dict(paged=True, page_size=8)])
def test_spec_server_matches_plain_server(rng, spec_kw):
    """The continuous-batching server retires identical outputs with and
    without --spec-decode (contiguous and paged caches), and tracks
    per-slot acceptance."""
    from repro.launch.serve_lm import LMServer, Request

    cfg = _quant_cfg("smollm_360m", 4)
    params0, _ = lm.init(cfg, jax.random.PRNGKey(1))
    params = convert_params_for_serving(params0, cfg, draft=True)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(4, 16)))
               for _ in range(5)]

    def run(**kw):
        server = LMServer(cfg, params, slots=2, max_seq=64, mode="serve",
                          **kw)
        for i, p in enumerate(prompts):
            server.submit(Request(i, np.asarray(p, np.int32), 7))
        done = server.run()
        return {r.rid: r.out for r in done}, server

    ref, _ = run()
    got, sv = run(spec_decode=True, draft_k=3, **spec_kw)
    assert ref == got
    drafted = sv.metrics.counter("lm_spec_tokens_drafted").value
    accepted = sv.metrics.counter("lm_spec_tokens_accepted").value
    assert drafted > 0 and 0 <= accepted <= drafted
    assert sv.metrics.histogram("lm_spec_accept_rate").count > 0
    # spec rounds retire more tokens per dispatch than they take steps
    total = sum(len(o) for o in got.values())
    assert sv.decode_steps < total


# -- acceptance: drafter == target must accept everything ---------------------

def test_accept_rate_one_when_drafter_is_target(rng):
    """Without a resident draft rung the drafter falls back to the target
    itself: every draft must be accepted (n_emit == draft_k + 1, every
    round, deterministically)."""
    cfg = dataclasses.replace(load_arch("smollm_360m").smoke(),
                              dtype="float32")
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cache, _ = lm.init_cache(cfg, 2, 48)
    logits, cache = make_prefill_step(cfg, None, "float")(
        params, {"tokens": _tokens(rng, cfg, 2, 8)}, cache)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    k = 4
    for _ in range(5):
        emitted, n_emit, cache = _spec_round(
            params, cfg, tok, cache, jax.random.PRNGKey(0), draft_k=k,
            mode="float", rules=None, temperature=0.0, top_k=0)
        assert np.array_equal(np.asarray(n_emit), [k + 1, k + 1])
        tok = jnp.asarray(np.asarray(emitted)[:, -1])


# -- ring rollback: mid-window rejection must rewind exactly ------------------

def test_ring_rollback_restores_rejected_slots(rng):
    """Force a mid-window rejection on a wrapped ring cache and check the
    cache is value-identical to one that never saw the rejected rows:
    continuing decode from both caches must produce identical tokens."""
    cfg = dataclasses.replace(load_arch("h2o_danube3_4b").smoke(),
                              dtype="float32")
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    b, t = 2, 16
    cache, _ = lm.init_cache(cfg, b, t)
    _, cache = lm.prefill(params, cfg, {"tokens": _tokens(rng, cfg, b, 14)},
                          cache)  # pos=14: the 3-row window wraps past 16
    window = _tokens(rng, cfg, b, 3)
    _, vcache = lm.verify(params, cfg, window, cache)
    # pretend only the first row was accepted: rewind to pos + 1
    new_pos = jnp.asarray(cache["pos"], jnp.int32) + 1
    rolled = lm.rollback_ring_cache(cfg, cache, vcache,
                                    jnp.asarray(cache["pos"], jnp.int32),
                                    new_pos, 3)
    # reference: decode exactly one step (writes only the accepted row)
    _, ref = lm.decode_step(params, cfg, window[:, :1], cache)
    for a, e in zip(jax.tree.leaves(rolled), jax.tree.leaves(ref)):
        assert np.array_equal(np.asarray(a), np.asarray(e))
    nxt = _tokens(rng, cfg, b, 1)
    ga, _ = lm.decode_step(params, cfg, nxt, rolled)
    ge, _ = lm.decode_step(params, cfg, nxt, ref)
    assert np.array_equal(np.asarray(ga), np.asarray(ge))


# -- sampled decoding ---------------------------------------------------------

def test_spec_sampling_top1_matches_greedy(rng):
    """temperature > 0 with top_k=1 collapses every distribution to a
    point mass: rejection sampling must then reproduce greedy spec (and
    therefore plain greedy generation) exactly."""
    cfg = dataclasses.replace(load_arch("smollm_360m").smoke(),
                              dtype="float32")
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": _tokens(rng, cfg, 2, 8)}
    ref = generate_scan(params, cfg, batch, steps=6, max_seq=32)
    got = speculative_generate(params, cfg, batch, steps=6, max_seq=32,
                               draft_k=3, temperature=1.7, top_k=1,
                               key=jax.random.PRNGKey(3))
    assert np.array_equal(np.asarray(got), np.asarray(ref))


def test_spec_sampling_deterministic_per_key(rng):
    cfg = dataclasses.replace(load_arch("smollm_360m").smoke(),
                              dtype="float32")
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": _tokens(rng, cfg, 2, 8)}
    kw = dict(steps=6, max_seq=32, draft_k=3, temperature=0.9, top_k=8)
    a = speculative_generate(params, cfg, batch,
                             key=jax.random.PRNGKey(5), **kw)
    b = speculative_generate(params, cfg, batch,
                             key=jax.random.PRNGKey(5), **kw)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    assert (np.asarray(a) >= 0).all() and (np.asarray(a) < cfg.vocab).all()


# -- satellite: implicit PRNG key must warn, not silently repeat --------------

def test_generate_scan_warns_on_default_key_when_sampling(rng):
    cfg = dataclasses.replace(load_arch("smollm_360m").smoke(),
                              dtype="float32")
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": _tokens(rng, cfg, 2, 8)}
    with pytest.warns(UserWarning, match="IDENTICAL"):
        generate_scan(params, cfg, batch, steps=3, max_seq=32,
                      temperature=0.8)
    with warnings.catch_warnings():
        warnings.simplefilter("error")  # greedy must NOT warn
        generate_scan(params, cfg, batch, steps=3, max_seq=32)


# -- obs: draft/verify phase tags on the ledger -------------------------------

def test_ledger_phases_separate_draft_from_verify_cycles(rng):
    from repro.obs import Ledger

    cfg = _quant_cfg("stablelm_12b", 4)
    params0, _ = lm.init(cfg, jax.random.PRNGKey(0))
    params = convert_params_for_serving(params0, cfg, draft=True)
    cache, _ = lm.init_cache(cfg, 2, 32)
    logits, cache = make_prefill_step(cfg, None, "serve")(
        params, {"tokens": _tokens(rng, cfg, 2, 8)}, cache)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
    k = 4
    with Ledger() as led, jax.disable_jit():
        _spec_round(params, cfg, tok, cache, jax.random.PRNGKey(0),
                    draft_k=k, mode="serve", rules=None, temperature=0.0,
                    top_k=0)
    ph = led.by_phase()
    assert set(ph) >= {"draft", "verify"}
    # the ladder's whole point: k packed1 draft forwards cost (far) fewer
    # emulated cycles than ONE batched multi-bit verify launch set
    assert 0 < ph["draft"]["cycles"] < ph["verify"]["cycles"]
    # window fields: every verify launch covers k+1 tokens, drafts 1
    recs = [r for r in led.records if r.phase == "verify"]
    assert recs and all(r.window == k + 1 for r in recs)
    assert all(r.window == 1 for r in led.records if r.phase == "draft")
