"""Smoke-runs every examples/*.py as a subprocess (JAX_PLATFORMS=cpu) so
example rewrites cannot silently rot.  Each example is a self-asserting
demo that exits nonzero on regression.

The module is marked ``slow`` so it is exemptible locally with
``-m "not slow"``; the CI workflow runs the full suite, examples
included."""
import pathlib
import subprocess
import sys

import pytest
from conftest import cpu_subproc_env

pytestmark = pytest.mark.slow

REPO = pathlib.Path(__file__).resolve().parent.parent
EXAMPLES = sorted((REPO / "examples").glob("*.py"))

# flags keeping the heavier demos CI-sized; {tmp} is a per-test scratch dir
EXTRA_ARGS = {
    "train_lm.py": ["--steps", "30", "--ckpt-dir", "{tmp}/ckpt"],
}


def test_every_example_is_covered():
    """New examples must show up here automatically (glob, not a list)."""
    assert len(EXAMPLES) >= 6
    assert {p.name for p in EXAMPLES} >= {
        "quickstart.py", "gf2_crypto.py", "lsh_lookup.py", "train_lm.py"}


@pytest.mark.parametrize("path", EXAMPLES, ids=lambda p: p.name)
def test_example_smoke(path, tmp_path):
    args = [a.format(tmp=tmp_path) for a in EXTRA_ARGS.get(path.name, [])]
    res = subprocess.run(
        [sys.executable, str(path), *args],
        capture_output=True, text=True, timeout=600, cwd=str(REPO),
        env=cpu_subproc_env())
    assert res.returncode == 0, \
        f"{path.name} failed\n--- stdout ---\n{res.stdout[-2000:]}" \
        f"\n--- stderr ---\n{res.stderr[-2000:]}"
