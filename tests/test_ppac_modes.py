"""Cycle-exact PPAC emulator vs ground truth — paper §III semantics."""
import numpy as np
import pytest

from repro.core import formats as F
from repro.core.ppac import (
    PPACArray,
    PPACConfig,
    cycles_compute_cache_inner_product,
    cycles_multibit_mvp,
)


def make_array(rng, m=32, n=48):
    a = rng.integers(0, 2, (m, n)).astype(np.uint8)
    arr = PPACArray(PPACConfig(m=m, n=n, rows_per_bank=16, subrow_bits=16))
    arr.write(a)
    return arr, a


def test_hamming_similarity(rng):
    arr, a = make_array(rng)
    x = rng.integers(0, 2, (48,)).astype(np.uint8)
    hs = np.asarray(arr.hamming_similarity(x))
    assert np.array_equal(hs, (a == x[None, :]).sum(1))


def test_cam_complete_and_similarity_match(rng):
    arr, a = make_array(rng)
    x = a[7].copy()
    match = np.asarray(arr.cam_match(x))
    assert match[7]
    # flip 3 bits: complete match fails, delta = N-3 still matches
    x2 = x.copy()
    x2[:3] ^= 1
    assert not np.asarray(arr.cam_match(x2))[7]
    assert np.asarray(arr.cam_match(x2, delta=48 - 3))[7]


@pytest.mark.parametrize("fa,fx", [("pm1", "pm1"), ("01", "01"),
                                   ("pm1", "01"), ("01", "pm1")])
def test_1bit_mvp_formats(rng, fa, fx):
    arr, a = make_array(rng)
    x = rng.integers(0, 2, (48,)).astype(np.uint8)
    got = np.asarray(arr.mvp_1bit(x, fa, fx))
    av = 2 * a.astype(int) - 1 if fa == "pm1" else a.astype(int)
    xv = 2 * x.astype(int) - 1 if fx == "pm1" else x.astype(int)
    assert np.array_equal(got, av @ xv)


@pytest.mark.parametrize("fmt_a", ["uint", "int", "oddint"])
@pytest.mark.parametrize("fmt_x", ["uint", "int", "oddint"])
@pytest.mark.parametrize("k,l", [(2, 2), (4, 4), (3, 2)])
def test_multibit_mvp(rng, fmt_a, fmt_x, k, l):
    m, n = 16, 24
    la, ha = F.value_range(fmt_a, k)
    lx, hx = F.value_range(fmt_x, l)
    a = rng.choice(np.arange(la, ha + 1, 2 if fmt_a == "oddint" else 1),
                   size=(m, n))
    x = rng.choice(np.arange(lx, hx + 1, 2 if fmt_x == "oddint" else 1),
                   size=(n,))
    arr = PPACArray(PPACConfig(m=m, n=n))
    got = np.asarray(arr.mvp_multibit(a, x, k, l, fmt_a, fmt_x))
    assert np.array_equal(got, a @ x)


def test_multibit_cycles_match_paper():
    """§III-C: KL cycles; §IV-B: 16 vs >=98 for 4-bit, N=256."""
    assert cycles_multibit_mvp(4, 4) == 16
    cc = cycles_compute_cache_inner_product(4, 256)
    assert cc >= 98  # paper: "at least 98 clock cycles"
    assert cc == (16 + 20 - 2) + 2 * 4 * 8  # L^2+5L-2 + 2L*log2(N)


def test_gf2_mvp(rng):
    arr, a = make_array(rng)
    x = rng.integers(0, 2, (48,)).astype(np.uint8)
    got = np.asarray(arr.gf2_mvp(x))
    assert np.array_equal(got, (a.astype(int) @ x.astype(int)) % 2)


def test_pla_minterms(rng):
    """Program bank 0 with f = (X0 & X1) | (X2 & ~X3) using min-term rows.

    Columns: [X0, X1, X2, X3, ~X0, ~X1, ~X2, ~X3] (complemented variables
    occupy their own columns per §III-E)."""
    m, n = 16, 8
    arr = PPACArray(PPACConfig(m=m, n=n, rows_per_bank=16, subrow_bits=8))
    rows = np.zeros((m, n), np.uint8)
    rows[0, [0, 1]] = 1        # X0 & X1
    rows[1, [2, 7]] = 1        # X2 & ~X3
    arr.write(rows)
    nvars = np.zeros((m,), np.int32)
    nvars[0], nvars[1] = 2, 2
    # unprogrammed rows: delta=0 would make them fire; give them nvars > n
    nvars[2:] = n + 1

    def x_for(bits4):
        x = np.zeros((8,), np.uint8)
        x[:4] = bits4
        x[4:] = 1 - np.asarray(bits4)
        return x

    for x0 in (0, 1):
        for x1 in (0, 1):
            for x2 in (0, 1):
                for x3 in (0, 1):
                    want = (x0 and x1) or (x2 and not x3)
                    got = np.asarray(arr.pla(x_for([x0, x1, x2, x3]), nvars))
                    assert got[0] == int(want), (x0, x1, x2, x3)


def test_pla_maxterms(rng):
    """delta=1 rows implement OR; bank output = product of max-terms."""
    m, n = 16, 4
    arr = PPACArray(PPACConfig(m=m, n=n, rows_per_bank=16, subrow_bits=4))
    rows = np.zeros((m, n), np.uint8)
    rows[0, [0, 1]] = 1   # X0 | X1
    rows[1, [2, 3]] = 1   # X2 | X3
    arr.write(rows)
    for bits in ([1, 0, 1, 0], [0, 0, 1, 1], [1, 1, 0, 0], [0, 0, 0, 0]):
        want = int((bits[0] or bits[1]) and (bits[2] or bits[3]))
        got = np.asarray(arr.pla_max_terms(np.asarray(bits, np.uint8),
                                           programmed_rows_per_bank=2))
        # rows 2.. are all-zero -> their popcount is 0 < delta=1 -> not fired
        assert got[0] == want, bits


def test_cycle_counter_advances(rng):
    arr, a = make_array(rng)
    c0 = arr.counter.cycles
    arr.hamming_similarity(np.zeros((48,), np.uint8))
    assert arr.counter.cycles == c0 + 1
    arr.mvp_multibit(np.zeros((32, 48), int), np.zeros((48,), int), 4, 4)
    # K*L vector-mode cycles (matrix reload is config-time, §IV-A)
    assert arr.counter.cycles == c0 + 1 + 16
