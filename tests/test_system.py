"""End-to-end behaviour tests: train -> checkpoint -> serve; FT recovery."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import latest_step
from repro.configs import load_arch
from repro.launch.train import train_loop
from repro.optim.adamw import AdamWConfig
from repro.serve.step import greedy_generate
from repro.train.step import TrainConfig


def test_train_then_serve(tmp_path):
    """Full lifecycle: train a smoke model, checkpoint, reload, generate."""
    cfg = load_arch("smollm_360m").smoke()
    tcfg = TrainConfig(opt=AdamWConfig(lr=3e-3), warmup_steps=2,
                       total_steps=30)
    state, losses = train_loop(cfg, tcfg, steps=12,
                               ckpt_dir=str(tmp_path), seq_len=32,
                               global_batch=4, ckpt_every=6, log_every=0)
    assert latest_step(str(tmp_path)) == 12
    assert np.mean(losses[-3:]) < np.mean(losses[:3])

    out = greedy_generate(state["params"], cfg,
                          {"tokens": jnp.ones((2, 8), jnp.int32)},
                          steps=4, max_seq=32)
    assert out.shape == (2, 4)


def test_resume_continues_not_restarts(tmp_path):
    cfg = load_arch("smollm_360m").smoke()
    tcfg = TrainConfig(opt=AdamWConfig(lr=3e-3), total_steps=30)
    train_loop(cfg, tcfg, steps=6, ckpt_dir=str(tmp_path), seq_len=32,
               global_batch=4, ckpt_every=3, log_every=0)
    # second call with more steps resumes from 6, not 0
    logs = []
    train_loop(cfg, tcfg, steps=9, ckpt_dir=str(tmp_path), seq_len=32,
               global_batch=4, ckpt_every=3, log_every=0,
               log=logs.append)
    assert any("resumed from step 6" in l for l in logs)


@pytest.mark.slow
def test_ft_crash_recovery_end_to_end(tmp_path):
    """Coordinator + injected SIGKILL: the run must finish with restarts>0."""
    run_dir = str(tmp_path / "ft")
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.ft", "--run-dir", run_dir,
         "--steps", "12", "--ckpt-every", "4", "--kill-at", "6"],
        capture_output=True, text=True, timeout=900,
        env={**os.environ, "PYTHONPATH": "src"})
    assert "restarts=1" in res.stdout, res.stdout + res.stderr
    assert "resumed from step 4" in res.stdout
    assert latest_step(os.path.join(run_dir, "ckpt")) == 12
