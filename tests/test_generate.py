"""Device-resident generation: scan/loop parity, fused sampling, cache
donation (asserted on the lowered HLO), ring-cache wraparound, and
per-sequence position vectors with ragged batches."""
import dataclasses
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import load_arch
from repro.models import lm
from repro.serve.step import (
    generate_scan,
    greedy_generate,
    make_decode_step,
    make_generate_scan,
    make_prefill_step,
    sample_tokens,
)


def _tokens(rng, cfg, b, s):
    return jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)


# -- scan-fused generation ----------------------------------------------------

@pytest.mark.parametrize("arch", ["smollm_360m", "h2o_danube3_4b",
                                  "mamba2_370m"])
def test_generate_scan_matches_per_step_loop(rng, arch):
    """The fused N-step scan program reproduces the per-step loop exactly
    (dense, sliding-window, and SSM cache flavors)."""
    cfg = dataclasses.replace(load_arch(arch).smoke(), dtype="float32")
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": _tokens(rng, cfg, 2, 8)}
    ref = greedy_generate(params, cfg, batch, steps=6, max_seq=32)
    got = generate_scan(params, cfg, batch, steps=6, max_seq=32)
    assert np.array_equal(np.asarray(got), np.asarray(ref))


def test_generate_scan_quantized_serving(rng):
    cfg = load_arch("stablelm_12b").smoke()
    cfg = dataclasses.replace(
        cfg, ppac=dataclasses.replace(cfg.ppac, enabled=True, weight_bits=4,
                                      act_bits=8, min_features=32))
    from repro.serve.step import convert_params_for_serving
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    served = convert_params_for_serving(params, cfg)
    batch = {"tokens": _tokens(rng, cfg, 2, 8)}
    ref = greedy_generate(served, cfg, batch, steps=4, max_seq=32,
                          mode="serve")
    got = generate_scan(served, cfg, batch, steps=4, max_seq=32,
                        mode="serve")
    assert np.array_equal(np.asarray(got), np.asarray(ref))


def test_sampling_top1_is_greedy(rng):
    """temperature > 0 with top_k=1 must collapse to argmax exactly."""
    logits = jnp.asarray(rng.standard_normal((4, 33)), jnp.float32)
    key = jax.random.PRNGKey(7)
    greedy = sample_tokens(logits, key)
    top1 = sample_tokens(logits, key, temperature=1.3, top_k=1)
    assert np.array_equal(np.asarray(greedy), np.asarray(top1))


def test_sampling_top_k_restricts_support(rng):
    logits = jnp.asarray(rng.standard_normal((64, 40)), jnp.float32)
    k = 3
    topk_ids = np.asarray(jax.lax.top_k(logits, k)[1])
    toks = np.asarray(sample_tokens(logits, jax.random.PRNGKey(0),
                                    temperature=5.0, top_k=k))
    for i, t in enumerate(toks):
        assert t in topk_ids[i]


def test_generate_scan_sampling_deterministic_per_key(rng):
    cfg = dataclasses.replace(load_arch("smollm_360m").smoke(),
                              dtype="float32")
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": _tokens(rng, cfg, 2, 8)}
    kw = dict(steps=5, max_seq=32, temperature=0.9, top_k=8)
    a = generate_scan(params, cfg, batch, key=jax.random.PRNGKey(5), **kw)
    b = generate_scan(params, cfg, batch, key=jax.random.PRNGKey(5), **kw)
    assert np.array_equal(np.asarray(a), np.asarray(b))
    assert (np.asarray(a) < cfg.vocab).all() and (np.asarray(a) >= 0).all()


# -- the donation invariant, on the lowered HLO -------------------------------

def _data_movement_results(hlo_text, op):
    """(operand_elems, result_elems) of every ``op`` whose operand is an
    actual tensor (scalar-fill broadcasts are buffer *allocations* — e.g.
    a scan's ys init — not movement of cache-sized data)."""
    out = []
    pat = rf"{op} [^:\n]*:\s*\(tensor<([0-9x]*)[a-z][^)]*\)\s*->\s*" \
          rf"tensor<([0-9x]*)x?[a-z]"
    for m in re.finditer(pat, hlo_text):
        src = [int(d) for d in m.group(1).split("x") if d]
        dst = [int(d) for d in m.group(2).split("x") if d]
        if not src:
            continue  # scalar operand: allocation, not data movement
        out.append((int(np.prod(src)), int(np.prod(dst)) if dst else 1))
    return out


def _assert_cache_donated(lowered_text, cache, *, skip=()):
    """Every (live) cache leaf argument must carry an aliasing attribute
    (the donation contract XLA lowers to an in-place update), and no
    broadcast/concatenate in the program may materialize a cache-sized
    copy of real data (the repack/copy class donation exists to delete).
    ``skip`` names cache entries the program provably never reads (jax
    drops dead args from the lowering, e.g. prefill overwrites 'pos')."""
    n_alias = lowered_text.count("tf.aliasing_output")
    n_leaves = len(jax.tree.leaves(
        {k: v for k, v in cache.items() if k not in skip}))
    assert n_alias >= n_leaves, (n_alias, n_leaves)
    cache_elems = max(np.prod(l.shape)
                      for l in jax.tree.leaves(cache) if l.ndim > 1)
    for op in ("broadcast_in_dim", "concatenate"):
        big = [d for _, d in _data_movement_results(lowered_text, op)
               if d >= cache_elems]
        assert not big, (op, big, int(cache_elems))


@pytest.mark.parametrize("kv_dtype", ["bfloat16", "int8"])
def test_decode_step_hlo_donates_cache(kv_dtype):
    cfg = dataclasses.replace(load_arch("stablelm_12b").smoke(),
                              kv_dtype=kv_dtype)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cache, _ = lm.init_cache(cfg, 2, 64)
    dec = make_decode_step(cfg)
    txt = dec.lower(params, jnp.ones((2, 1), jnp.int32), cache).as_text()
    _assert_cache_donated(txt, cache)


def test_generate_scan_hlo_donates_cache():
    cfg = load_arch("stablelm_12b").smoke()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cache, _ = lm.init_cache(cfg, 2, 64)
    gen = make_generate_scan(cfg, steps=4)
    logits = jnp.zeros((2, 1, cfg.vocab), jnp.float32)
    txt = gen.lower(params, logits, cache, jax.random.PRNGKey(0)).as_text()
    _assert_cache_donated(txt, cache)


def test_prefill_step_hlo_donates_cache():
    cfg = load_arch("stablelm_12b").smoke()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cache, _ = lm.init_cache(cfg, 2, 64)
    pre = make_prefill_step(cfg)
    txt = pre.lower(params, {"tokens": jnp.ones((2, 8), jnp.int32)},
                    cache).as_text()
    _assert_cache_donated(txt, cache, skip=("pos",))


@pytest.mark.parametrize("kv_dtype", ["bfloat16", "int8"])
def test_paged_decode_step_hlo_donates_cache(kv_dtype):
    """The donation contract must survive the paged layout: pool leaves
    ([pool_pages, page_size, ...]) update in place and the block table
    rides through aliased, never copied."""
    cfg = dataclasses.replace(load_arch("stablelm_12b").smoke(),
                              kv_dtype=kv_dtype)
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cache, _ = lm.init_cache(cfg, 2, 64, page_size=8)
    dec = make_decode_step(cfg)
    txt = dec.lower(params, jnp.ones((2, 1), jnp.int32), cache).as_text()
    _assert_cache_donated(txt, cache)


def test_paged_prefill_select_hlo_donates_cache():
    """Paged prefill writes through per-request table rows straight into
    the donated resident pools — no scratch cache, no repack copy."""
    from repro.serve.step import make_prefill_select_step
    cfg = load_arch("stablelm_12b").smoke()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cache, _ = lm.init_cache(cfg, 2, 64, page_size=8)
    n_pages = cache["table"].shape[1]
    pre = make_prefill_select_step(cfg, paged=True)
    txt = pre.lower(params, jnp.ones((1, 8), jnp.int32),
                    jnp.ones((1,), jnp.int32), jnp.zeros((1,), jnp.int32),
                    jnp.zeros((1,), jnp.int32),
                    jnp.zeros((1, n_pages), jnp.int32), cache,
                    jax.random.PRNGKey(0)).as_text()
    _assert_cache_donated(txt, cache)


def test_undonated_decode_keeps_inputs_alive():
    """Sanity for the invariant: with donate=False the cache argument has
    no aliasing contract (what the donated path deletes)."""
    cfg = load_arch("stablelm_12b").smoke()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cache, _ = lm.init_cache(cfg, 2, 64)
    dec = make_decode_step(cfg, donate=False)
    txt = dec.lower(params, jnp.ones((2, 1), jnp.int32), cache).as_text()
    assert "tf.aliasing_output" not in txt


# -- rolling (ring) cache wraparound ------------------------------------------

@pytest.mark.parametrize("t0", [4, 11, 19])
def test_ring_cache_prefill_decode_consistency(rng, t0):
    """Decode must roll seamlessly out of ANY prefill length — shorter
    than the window, longer-but-not-a-multiple (the pre-PR layout bug),
    and deep into slot-reuse territory."""
    cfg = dataclasses.replace(load_arch("h2o_danube3_4b").smoke(),
                              dtype="float32", sliding_window=8)
    params, _ = lm.init(cfg, jax.random.PRNGKey(2))
    b, s = 1, 32
    tokens = _tokens(rng, cfg, b, s)
    full_logits, _ = lm.forward(params, cfg, {"tokens": tokens})
    cache, _ = lm.init_cache(cfg, b, s, dtype=jnp.float32)
    logits, cache = lm.prefill(params, cfg, {"tokens": tokens[:, :t0]},
                               cache)
    np.testing.assert_allclose(np.asarray(logits[:, 0]),
                               np.asarray(full_logits[:, t0 - 1]),
                               rtol=5e-3, atol=5e-3)
    for i in range(t0, s - 1):
        logits, cache = lm.decode_step(params, cfg, tokens[:, i][:, None],
                                       cache)
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, i]),
            rtol=5e-3, atol=5e-3, err_msg=f"pos {i}")


# -- per-sequence positions / ragged batches ----------------------------------

@pytest.mark.parametrize("arch,kv", [("smollm_360m", "bfloat16"),
                                     ("stablelm_12b", "int8"),
                                     ("h2o_danube3_4b", "bfloat16")])
def test_ragged_batch_matches_solo_generation(rng, arch, kv):
    """Right-padded ragged prefill + vector pos decode == each sequence
    generated alone, bit-identically (linear, int8, and ring caches)."""
    cfg = dataclasses.replace(load_arch(arch).smoke(), dtype="float32",
                              kv_dtype=kv)
    params, _ = lm.init(cfg, jax.random.PRNGKey(1))
    lens = [5, 8, 3]
    prompts = [np.asarray(rng.integers(0, cfg.vocab, n), np.int32)
               for n in lens]
    steps, max_seq, plen = 5, 32, 8

    solo = [np.asarray(greedy_generate(
        params, cfg, {"tokens": jnp.asarray(p)[None, :]}, steps=steps,
        max_seq=max_seq))[0] for p in prompts]

    toks = np.zeros((len(prompts), plen), np.int32)
    for i, p in enumerate(prompts):
        toks[i, :len(p)] = p                       # RIGHT-pad
    cache, _ = lm.init_cache(cfg, len(prompts), max_seq)
    logits, cache = lm.prefill(params, cfg, {"tokens": jnp.asarray(toks)},
                               cache, lengths=jnp.asarray(lens, jnp.int32))
    assert np.array_equal(np.asarray(cache["pos"]), np.asarray(lens))
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [np.asarray(tok[:, 0])]
    for _ in range(steps - 1):
        logits, cache = lm.decode_step(params, cfg, tok, cache)
        tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(np.asarray(tok[:, 0]))
    batched = np.stack(out, axis=1)                # [B, steps]
    for i in range(len(prompts)):
        assert np.array_equal(batched[i], solo[i]), \
            (i, batched[i], solo[i])


def test_mixed_progress_decode_positions_advance_independently(rng):
    """Vector pos bookkeeping: sequences at different depths advance
    their own positions in one fused step."""
    cfg = dataclasses.replace(load_arch("smollm_360m").smoke(),
                              dtype="float32")
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    cache, _ = lm.init_cache(cfg, 2, 32)
    cache["pos"] = jnp.asarray([3, 9], jnp.int32)  # mixed progress
    tok = jnp.ones((2, 1), jnp.int32)
    _, cache = lm.decode_step(params, cfg, tok, cache)
    assert np.array_equal(np.asarray(cache["pos"]), [4, 10])
