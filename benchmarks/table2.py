"""Table II reproduction: PPAC array sizes -> throughput / energy.

Throughput derives analytically from geometry × paper clock frequency
(bit-identical to the paper's accounting: M(2N-1) OP/cycle); energy uses
the paper's measured power. We additionally time our TPU-adapted kernel
(MXU backend on CPU) on the same array shapes for a us_per_call column.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cost_model import TABLE_II, energy_per_op_fj, peak_throughput_tops
from repro.core.formats import pack_bits
from repro.kernels.binary_mvp.ops import inner_product_pm1


def _time_call(fn, *args, reps=20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)
    for (m, n), info in TABLE_II.items():
        tops = peak_throughput_tops(m, n, info["f_ghz"])
        fj = energy_per_op_fj(m, n, info["f_ghz"], info["power_mw"])
        # our derivation must reproduce the paper's table
        assert abs(tops - info["peak_tops"]) / info["peak_tops"] < 0.02, \
            (m, n, tops, info["peak_tops"])
        assert abs(fj - info["fj_per_op"]) / info["fj_per_op"] < 0.02

        x = pack_bits(rng.integers(0, 2, (1, n)))
        a = pack_bits(rng.integers(0, 2, (m, n)))
        us = _time_call(
            lambda x, a: inner_product_pm1(x, a, n=n, backend="mxu"), x, a)
        rows.append((f"table2_ppac_{m}x{n}", us,
                     f"peak_tops={tops:.2f};fj_per_op={fj:.2f};"
                     f"paper_tops={info['peak_tops']};paper_fj={info['fj_per_op']}"))
    return rows
