"""Serving-path benchmark: LM decode-step latency + emulated PPAC cycles.

One decode step of a small LM is timed per resident weight container
(bf16 float baseline, int8 MXU fallback, packed4 / packed1 fused PPAC
kernels) and priced in the paper's §III-C K·L cycle accounting aggregated
over every projection — the Table II NN-inference story at model scale.
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import load_arch
from repro.models import lm
from repro.serve.step import convert_params_for_serving, serving_cycle_report

_CONTAINERS = [(0, "float_bf16"), (8, "int8"), (4, "packed4"), (1, "packed1")]


def _t(fn, reps=3):
    jax.block_until_ready(fn())  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn()
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    base = load_arch("smollm_360m").smoke()
    params0, _ = lm.init(base, jax.random.PRNGKey(0))
    slots, max_seq = 2, 32
    for wb, label in _CONTAINERS:
        if wb == 0:
            cfg, params, mode, rep = base, params0, "float", None
        else:
            cfg = dataclasses.replace(
                base, ppac=dataclasses.replace(
                    base.ppac, enabled=True, weight_bits=wb, act_bits=8,
                    min_features=32))
            params = convert_params_for_serving(params0, cfg)
            mode = "serve"
            rep = serving_cycle_report(params, cfg)

        cache, _ = lm.init_cache(cfg, slots, max_seq)
        _, cache = jax.jit(
            lambda p, b, c, cfg=cfg, mode=mode: lm.prefill(p, cfg, b, c,
                                                           mode=mode)
        )(params, {"tokens": jnp.ones((slots, 8), jnp.int32)}, cache)
        decode = jax.jit(
            lambda p, t, c, cfg=cfg, mode=mode: lm.decode_step(p, cfg, t, c,
                                                               mode=mode))
        tok = jnp.ones((slots, 1), jnp.int32)
        us = _t(lambda: decode(params, tok, cache)[0])
        derived = (f"cycles_per_tok={rep.cycles_per_token};"
                   f"fused={rep.fused_cycles_per_token}" if rep
                   else "float baseline")
        rows.append((f"serve_decode_{label}_b{slots}", us, derived))
    return rows
