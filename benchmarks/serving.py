"""Serving-path benchmark: LM decode-step latency, end-to-end generation
throughput, + emulated PPAC cycles.

One decode step of a small LM is timed per resident weight container
(bf16 float baseline, int8 MXU fallback, packed4 / packed1 fused PPAC
kernels) and priced in the paper's §III-C K·L cycle accounting aggregated
over every projection — the Table II NN-inference story at model scale.

The packed kinds run twice: the zero-repack fast path (grouped wqkv/wig
containers, in-kernel activation bit-slicing, load-time MXU shadow) and
the pre-PR ``*_prepack`` path (per-projection containers, per-call weight
unpacking on the MXU lowering) — the before/after pair the perf
trajectory tracks. ``benchmarks.check_serving`` gates CI on the fast path
beating the prepack path and staying at least level with int8.

On top of the per-step rows, ``gen_*`` rows time *generation* end to end
(prefill + N decoded tokens, reported as us/token with tokens/sec in the
derived column) across a batch sweep (b1/b2/b8/b16) per weight kind:
``gen_scan`` is the device-resident ``lax.scan`` program with donated
ring caches and fused sampling (one dispatch for the whole tail),
``gen_loop`` the per-step python loop it replaced (one dispatch per
token). ``benchmarks.check_serving`` gates scan >= 2x loop at smoke
scale — the dispatch/donation overhead the scan path deletes.

Timing is a warmed, fixed-iteration, ``lax``-free python loop; the
reported figure is the p50 over >= 5 repetitions (single-rep means on a
shared CI box are noisy enough to hide a 20% regression).

Run directly (``python -m benchmarks.serving --trace-out trace.json``)
for the *traced serving smoke*: the continuous-batching LM server runs
with the flight recorder open and exports a Perfetto-loadable Chrome
trace (server prefill/decode spans interleaved with per-launch PPAC
kernel events carrying cycles / energy / tile-plan args) plus a
telemetry-registry snapshot; an in-run gate asserts the ledger cycles of
one eager decode step equal the cost-model report exactly.
"""
import argparse
import dataclasses
import json
import statistics
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import load_arch
from repro.models import lm
from repro.serve.step import (
    convert_params_for_serving,
    generate_scan,
    greedy_generate,
    make_decode_select_step,
    make_prefill_step,
    make_speculative_decode_step,
    make_speculative_scan,
    serving_cycle_report,
)

# (weight_bits, label, fast path?) — fast = grouped + resident shadow,
# prepack = the pre-PR per-projection / per-call-unpack layout.
_CONTAINERS = [
    (0, "float_bf16", True),
    (8, "int8", True),
    (4, "packed4", True),
    (1, "packed1", True),
    (4, "packed4_prepack", False),
    (1, "packed1_prepack", False),
]

# generation sweep: every fast-path kind x decode batch; the python-loop
# baseline rides once per kind (at _GEN_LOOP_BATCH) for the CI gate.
_GEN_KINDS = [(0, "float_bf16"), (8, "int8"), (4, "packed4"), (1, "packed1")]
_GEN_BATCHES = (1, 2, 8, 16)
_GEN_LOOP_BATCH = 1
_GEN_STEPS = 16
_GEN_PROMPT = 8


def _t(fn, *, iters: int = 10, reps: int = 7):
    """p50 per-call µs: compile + warm, then ``reps`` timed runs of a
    fixed ``iters``-iteration python loop (block once per run)."""
    jax.block_until_ready(fn())  # compile
    jax.block_until_ready(fn())  # warm
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        r = None
        for _ in range(iters):
            r = fn()
        jax.block_until_ready(r)
        samples.append((time.perf_counter() - t0) / iters * 1e6)
    return statistics.median(samples)


def _serving_cfg_params(base, params0, wb, *, fast=True):
    if wb == 0:
        return base, params0, "float", None
    cfg = dataclasses.replace(
        base, ppac=dataclasses.replace(
            base.ppac, enabled=True, weight_bits=wb, act_bits=8,
            min_features=32))
    # fast: grouped containers + platform-default shadow policy;
    # prepack: per-projection, no shadow (per-call unpack — pre-PR)
    params = convert_params_for_serving(
        params0, cfg, group=fast, store_shadow=None if fast else False)
    return cfg, params, "serve", serving_cycle_report(params, cfg)


def run():
    rows = []
    base = load_arch("stablelm_12b").smoke()
    params0, _ = lm.init(base, jax.random.PRNGKey(0))
    slots, max_seq = 2, 32
    for wb, label, fast in _CONTAINERS:
        cfg, params, mode, rep = _serving_cfg_params(base, params0, wb,
                                                     fast=fast)
        cache, _ = lm.init_cache(cfg, slots, max_seq)
        _, cache = jax.jit(
            lambda p, b, c, cfg=cfg, mode=mode: lm.prefill(p, cfg, b, c,
                                                           mode=mode)
        )(params, {"tokens": jnp.ones((slots, 8), jnp.int32)}, cache)
        decode = jax.jit(
            lambda p, t, c, cfg=cfg, mode=mode: lm.decode_step(p, cfg, t, c,
                                                               mode=mode))
        tok = jnp.ones((slots, 1), jnp.int32)
        us = _t(lambda: decode(params, tok, cache)[0])
        kind = label.removesuffix("_prepack")
        extras = (dict(kind=kind, path="fast" if fast else "prepack",
                       cycles_per_tok=rep.cycles_per_token,
                       fused=rep.fused_cycles_per_token,
                       energy_nj_per_tok=round(rep.energy_nj_per_token, 3))
                  if rep else dict(kind=kind, path="fast"))
        rows.append((f"serve_decode_{label}_b{slots}", us, extras))
    rows.extend(_generation_rows(base, params0))
    rows.extend(_spec_rows(base, params0))
    rows.extend(_paged_prefix_rows())
    rows.extend(_chaos_rows())
    rows.extend(_mesh_rows())
    return rows


# chaos/integrity sweep: CRC-scrub overhead + degraded-mode throughput
_CHAOS_ARCH = "smollm_360m"
_CHAOS_REQUESTS = 6
_CHAOS_MAX_NEW = 8
_CHAOS_SLOTS = 2


def _chaos_rows():
    """Integrity/recovery costs on the serving path.

    ``serve_crc_off`` / ``serve_crc_on``: the same paged workload served
    with and without the per-page GF(2) CRC seal + every-tick scrub
    (``kv_crc=True, scrub_every=1`` — the paranoid setting; production
    would scrub every N). The delta is the full integrity bill: sealing
    freshly-prefilled prompt pages, re-reading + re-tagging every sealed
    page per tick. ``benchmarks.check_serving --crc-overhead`` gates the
    tok/s cost.

    ``serve_degraded``: throughput of a disaggregated server AFTER its
    prefill-worker pool is lost (an injected crash with a zero restart
    budget) — every admission goes through the decode-mesh fallback
    prefill. Informational: the CI chaos smoke gates the *behavior*
    (no request lost, bit-identity); this row prices the mode.
    """
    from repro.launch.faults import FaultPlan
    from repro.launch.serve_lm import LMServer, Request
    from repro.obs import MetricsRegistry

    cfg = load_arch(_CHAOS_ARCH).smoke()
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(9, 20)))
               for _ in range(_CHAOS_REQUESTS)]

    def serve_batch(server, rid0):
        for i, p in enumerate(prompts):
            server.submit(Request(rid0 + i, np.asarray(p, np.int32),
                                  _CHAOS_MAX_NEW))
        return server.run()

    def timed(server, metrics):
        serve_batch(server, 0)  # compile + warm
        pre = metrics.total("lm_scrub_pages")
        t0 = time.perf_counter()
        done = serve_batch(server, 100)
        dt = time.perf_counter() - t0
        ntok = sum(len(r.out) for r in done)
        assert len(done) == len(prompts)
        return dt / ntok * 1e6, ntok / dt, \
            metrics.total("lm_scrub_pages") - pre

    rows, baseline = [], None
    for tag, kw in (("off", {}),
                    ("on", dict(kv_crc=True, scrub_every=1))):
        metrics = MetricsRegistry()
        server = LMServer(cfg, params, slots=_CHAOS_SLOTS, max_seq=64,
                          paged=True, page_size=8, metrics=metrics, **kw)
        us, tok_s, scrubbed = timed(server, metrics)
        if tag == "off":
            baseline = tok_s
        rows.append((f"serve_crc_{tag}_b{_CHAOS_SLOTS}", us,
                     dict(crc=tag, batch=_CHAOS_SLOTS,
                          tok_s=round(tok_s, 1),
                          pages_scrubbed=int(scrubbed),
                          overhead=round(1.0 - tok_s / baseline, 3))))

    # degraded mode: crash the only prefill worker (restart budget 0)
    # during the warm pass; the timed pass runs fully degraded
    metrics = MetricsRegistry()
    server = LMServer(cfg, params, slots=_CHAOS_SLOTS, max_seq=64,
                      paged=True, page_size=8, metrics=metrics,
                      prefill_devices=1, decode_devices=1,
                      prefill_workers=1, max_worker_restarts=0,
                      max_retries=3,
                      faults=FaultPlan.parse("crash:prefill:0:worker=p0"))
    us, tok_s, _ = timed(server, metrics)
    assert server.ex.degraded, "worker pool survived the injected crash"
    rows.append((f"serve_degraded_b{_CHAOS_SLOTS}", us,
                 dict(crc="off", batch=_CHAOS_SLOTS, degraded=1,
                      tok_s=round(tok_s, 1),
                      vs_local=round(tok_s / baseline, 3))))
    return rows


# multi-device serving sweep: every mesh layout that fits the runtime
# device count, plus a disaggregated prefill/decode split at >= 4
# devices. Each layout serves the same prompt set twice through one
# server (the first pass compiles, the second is timed) and must retire
# bit-identical greedy tokens to the 1x1 baseline — parity rides in the
# row and is gated by ``check_serving --mesh-parity``.
_MESH_BATCHES = (1, 4)
_MESH_MAX_NEW = 8
_MESH_PROMPT = 8


def _mesh_layouts(ndev):
    layouts = [("1x1", {})]
    if ndev >= 2:
        layouts += [("1x2", dict(mesh=(1, 2))),   # pure TP
                    ("2x1", dict(mesh=(2, 1)))]   # pure slot-DP
    if ndev >= 4:
        layouts += [("2x2", dict(mesh=(2, 2))),
                    ("disagg_2p2d", dict(prefill_devices=2,
                                         decode_devices=2))]
    return layouts


def _hist_delta(pre, post, name):
    """(count, mean-seconds) a histogram gained between two snapshots."""
    a, b = pre.get(name, {}), post.get(name, {})
    n = b.get("count", 0) - a.get("count", 0)
    if n <= 0:
        return 0, 0.0
    return n, (b.get("sum", 0.0) - a.get("sum", 0.0)) / n


def _mesh_rows():
    ndev = jax.device_count()
    if ndev < 2:
        return []  # single-device runtime: nothing to shard against
    from repro.launch.serve_lm import LMServer, Request
    from repro.obs import MetricsRegistry

    base = dataclasses.replace(load_arch("smollm_360m").smoke(),
                               dtype="float32")
    params0, _ = lm.init(base, jax.random.PRNGKey(0))
    cfg, params, mode, _ = _serving_cfg_params(base, params0, 4)

    rows, baseline = [], {}
    for tag, kw in _mesh_layouts(ndev):
        for b in _MESH_BATCHES:
            rng = np.random.default_rng(100 + b)  # same prompts per batch
            prompts = [rng.integers(0, cfg.vocab, _MESH_PROMPT)
                       for _ in range(2 * b)]
            metrics = MetricsRegistry()
            server = LMServer(cfg, params, slots=b, max_seq=64, mode=mode,
                              metrics=metrics, **kw)

            def serve_batch(rid0):
                for i, p in enumerate(prompts):
                    server.submit(Request(rid0 + i,
                                          np.asarray(p, np.int32),
                                          _MESH_MAX_NEW))
                return server.run()

            serve_batch(0)  # compile + warm
            pre = metrics.snapshot()
            t0 = time.perf_counter()
            done = serve_batch(100)
            dt = time.perf_counter() - t0
            toks = {r.rid - 100: tuple(r.out) for r in done}
            ntok = sum(len(v) for v in toks.values())
            post = metrics.snapshot()

            if tag == "1x1":
                baseline[b] = toks
            extras = dict(mesh=tag, batch=b, devices=ndev,
                          tok_s=round(ntok / dt, 1),
                          parity=int(toks == baseline[b]))
            n, mean_s = _hist_delta(pre, post, "lm_ttft_s")
            if n:
                extras["ttft_ms"] = round(mean_s * 1e3, 3)
            n, mean_s = _hist_delta(pre, post, "lm_handoff_latency")
            if n:
                extras["handoff_ms"] = round(mean_s * 1e3, 3)
            rows.append((f"serve_mesh_{tag}_b{b}", dt / ntok * 1e6,
                         extras))
    return rows


# speculative-decoding sweep: packed4 target rung, draft_k drafts/round
_SPEC_BATCH = 2
_SPEC_STEPS = 24
_SPEC_K = 4
_SPEC_PROMPT = 8


def _spec_rows(base, params0):
    """Self-speculative decoding rows (temperature 0, packed4 target).

    Three serving paths over the same ``_SPEC_STEPS``-token tail:

      * ``serve_spec_plain``: the per-token decode-select loop — one host
        dispatch per emitted token, the continuous-batching server's
        non-speculative unit of work;
      * ``serve_spec_round``: the fused draft->verify->accept round —
        ONE dispatch retires up to draft_k + 1 tokens. Benchmarked with
        the drafter on the *target* rung (accept rate exactly 1.0, so
        the row isolates the round-dispatch amortization and is
        deterministic enough to CI-gate: ``check_serving
        --spec-speedup`` requires >= 1.3x the plain loop) and with the
        resident *packed1* rung (``draft=packed1``) — the precision-
        ladder configuration, reporting the honest measured accept rate
        (low on random smoke weights; the cycle columns carry the §III-C
        story: draft launches price 1 bit-plane pass against the
        target's K*L);
      * ``serve_spec_scan``: the whole tail as one on-device
        ``lax.while_loop`` program.

    Every spec row is output-bit-identical to the plain loop (asserted
    here, not just claimed).
    """
    rows = []
    cfg, params, mode, _ = _serving_cfg_params(base, params0, 4)
    params_lad = convert_params_for_serving(
        params0, cfg, draft=True)  # + resident packed1 rung of same weights
    b, steps, k = _SPEC_BATCH, _SPEC_STEPS, _SPEC_K
    max_seq = _SPEC_PROMPT + steps + k + 2
    batch = {"tokens": jnp.ones((b, _SPEC_PROMPT), jnp.int32)}
    prefill = make_prefill_step(cfg, None, mode)
    dec = make_decode_select_step(cfg, None, mode)
    spec = make_speculative_decode_step(cfg, None, mode, draft_k=k)
    sscan = make_speculative_scan(cfg, steps=steps, draft_k=k, mode=mode)
    key = jax.random.PRNGKey(0)

    def start(p):
        cache, _ = lm.init_cache(cfg, b, max_seq)
        logits, cache = prefill(p, batch, cache)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32), cache

    def plain_call():
        tok, cache = start(params)
        out = [np.asarray(tok)]
        for _ in range(steps - 1):
            tok, cache = dec(params, tok[:, None], cache, key)
            out.append(np.asarray(tok))
        return np.stack(out, axis=1)

    def spec_call(p, stats=None):
        tok, cache = start(p)
        out = np.full((b, steps + k + 1), -1, np.int64)
        out[:, 0] = np.asarray(tok)
        off = np.ones((b,), np.int64)
        while off.min() < steps:
            em, ne, cache = spec(p, tok, cache, key)
            em, ne = np.asarray(em), np.asarray(ne)
            if stats is not None:
                stats.append(ne)
            for s in range(b):
                out[s, off[s]:off[s] + ne[s]] = em[s, :ne[s]]
            tok = jnp.asarray(em[np.arange(b), ne - 1])
            off += ne
        return out[:, :steps]

    def scan_call():
        cache, _ = lm.init_cache(cfg, b, max_seq)
        logits, cache = prefill(params, batch, cache)
        toks, _ = sscan(params, logits, cache, key)
        return toks

    ref = plain_call()
    us = _t(plain_call, iters=2, reps=5) / (steps * b)
    rows.append((f"serve_spec_plain_packed4_b{b}", us,
                 dict(impl="plain_loop", kind="packed4", batch=b,
                      tok_s=round(1e6 / us), steps=steps)))

    for tag, p in (("target", params), ("packed1", params_lad)):
        stats = []
        got = spec_call(p, stats)
        assert np.array_equal(got, ref), \
            f"spec ({tag} drafter) diverged from the plain decode loop"
        ne = np.concatenate(stats)
        accept = float((ne - 1).sum() / (k * len(ne)))
        us = _t(lambda p=p: spec_call(p), iters=2, reps=5) / (steps * b)
        extras = dict(impl="spec_round", kind="packed4", draft=tag,
                      draft_k=k, batch=b, tok_s=round(1e6 / us),
                      accept_rate=round(accept, 3),
                      rounds=len(ne) // b, steps=steps)
        if tag == "packed1":
            # ladder cycle accounting: one eager round under the flight
            # recorder, split by phase tag (deterministic: launch
            # geometry, not wall clock)
            from repro.obs import Ledger
            from repro.serve.step import _spec_round
            tok, cache = start(p)
            with Ledger() as led, jax.disable_jit():
                _spec_round(p, cfg, tok, cache, key, draft_k=k, mode=mode,
                            rules=None, temperature=0.0, top_k=0)
            ph = led.by_phase()
            extras.update(
                draft_cycles_per_round=ph.get("draft", {}).get("cycles", 0),
                verify_cycles_per_round=ph.get("verify", {}).get("cycles",
                                                                 0))
        rows.append((f"serve_spec_round_packed4_{tag}_k{k}_b{b}", us,
                     extras))

    got = np.asarray(scan_call())
    assert np.array_equal(got, ref), \
        "spec scan diverged from the plain decode loop"
    us = _t(scan_call, iters=2, reps=5) / (steps * b)
    rows.append((f"serve_spec_scan_packed4_k{k}_b{b}", us,
                 dict(impl="spec_scan", kind="packed4", draft="target",
                      draft_k=k, batch=b, tok_s=round(1e6 / us),
                      steps=steps)))
    return rows


# paged prefix-reuse sweep: a repeated-system-prompt workload
_PAGED_ARCH = "smollm_360m"
_PAGED_REQUESTS = 4
_PAGED_SHARED = 24   # shared system prompt: 3 full pages at page_size 8
_PAGED_TAIL = 4      # per-request user suffix (partial tail page)
_PAGED_MAX_NEW = 5


def _paged_prefix_rows():
    """Prefix-reuse rows: the same repeated-system-prompt workload served
    three ways — ``cold`` (paged, no prefix cache: the baseline every
    admission pays full prefill), ``register`` (prefix cache on, first
    sight of the prompts: CAM registration + intra-run hits), ``warm``
    (the 100%-shared-prefix rerun on the now-resident pages: admission
    maps matched pages and prefills only suffixes).

    Cycles are ledger-measured per phase and the server runs EAGERLY
    (``jax.disable_jit``): the ledger prices launches at trace time, so
    a cached jit executable would replay nothing. Cycle totals are
    deterministic (launch geometry comes from the padded bucket shapes,
    not wall clock); ``benchmarks.check_serving`` gates warm <= cold/2.
    """
    from repro.launch.serve_lm import LMServer, Request
    from repro.obs import Ledger

    page_size, slots, max_seq = 8, 2, 64
    cfg = load_arch(_PAGED_ARCH).smoke()
    cfg = dataclasses.replace(cfg, ppac=dataclasses.replace(
        cfg.ppac, enabled=True, weight_bits=4, act_bits=8,
        min_features=32, backend="auto"))
    params0, _ = lm.init(cfg, jax.random.PRNGKey(0))
    params = convert_params_for_serving(params0, cfg)
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab, _PAGED_SHARED)
    prompts = [np.concatenate(
        [shared, rng.integers(0, cfg.vocab, _PAGED_TAIL)]).astype(np.int32)
        for _ in range(_PAGED_REQUESTS)]

    def serve_round(server, phase, rows):
        pre_led, dec_led = Ledger(), Ledger()
        hit0 = server.prefix.pages_hit if server.prefix else 0
        probe0 = server.prefix.pages_probed if server.prefix else 0
        skip0 = server.metrics.counter("lm_prefill_rows_skipped").value
        for i, p in enumerate(prompts):
            server.submit(Request(i, p, _PAGED_MAX_NEW))
        t0 = time.perf_counter()
        done = 0
        while server.queue or any(r is not None for r in server.live):
            with pre_led:
                server._admit()
            with dec_led:
                done += len(server.step())
        dt = time.perf_counter() - t0
        assert done == len(prompts)
        probed = (server.prefix.pages_probed - probe0) if server.prefix \
            else 0
        hits = (server.prefix.pages_hit - hit0) if server.prefix else 0
        skipped = server.metrics.counter("lm_prefill_rows_skipped").value \
            - skip0
        rows.append((
            f"serve_paged_prefill_{phase}", dt / len(prompts) * 1e6,
            dict(workload="shared_prefix", phase=phase,
                 prefill_cycles=pre_led.total_cycles,
                 prefill_launches=pre_led.num_launches,
                 decode_cycles=dec_led.total_cycles,
                 prefix_hit_rate=round(hits / probed, 3) if probed else 0.0,
                 rows_skipped=skipped,
                 requests=len(prompts), page_size=page_size)))

    rows = []
    with jax.disable_jit():
        cold = LMServer(cfg, params, slots=slots, max_seq=max_seq,
                        mode="serve", paged=True, page_size=page_size)
        serve_round(cold, "cold", rows)
        warm = LMServer(cfg, params, slots=slots, max_seq=max_seq,
                        mode="serve", paged=True, page_size=page_size,
                        prefix_cache=True)
        serve_round(warm, "register", rows)  # first sight: registration
        serve_round(warm, "warm", rows)      # 100%-shared rerun
    cyc = {e["phase"]: e["prefill_cycles"] for _, _, e in rows}
    rows[-1][2]["cycles_saved_ratio"] = round(cyc["cold"] / cyc["warm"], 2)
    return rows


def _generation_rows(base, params0):
    """End-to-end generation throughput: scan-fused vs per-step loop.

    Each call is the full serving unit — cache init + prefill(b x 8) + 16
    decoded tokens — so the row is honest end-to-end tokens/sec, and the
    donated cache is freshly allocated per call (donation consumes it)."""
    rows = []
    gen_max_seq = _GEN_PROMPT + _GEN_STEPS + 1
    for wb, label in _GEN_KINDS:
        cfg, params, mode, _ = _serving_cfg_params(base, params0, wb)
        for b in _GEN_BATCHES:
            batch = {"tokens": jnp.ones((b, _GEN_PROMPT), jnp.int32)}

            def scan_call(cfg=cfg, params=params, mode=mode, batch=batch):
                return generate_scan(params, cfg, batch, steps=_GEN_STEPS,
                                     max_seq=gen_max_seq, mode=mode)

            us = _t(scan_call, iters=2, reps=5) / (_GEN_STEPS * b)
            rows.append((f"gen_scan_{label}_b{b}", us,
                         dict(impl="scan", kind=label, batch=b,
                              tok_s=round(1e6 / us), steps=_GEN_STEPS)))
            if b == _GEN_LOOP_BATCH:
                def loop_call(cfg=cfg, params=params, mode=mode,
                              batch=batch):
                    return greedy_generate(params, cfg, batch,
                                           steps=_GEN_STEPS,
                                           max_seq=gen_max_seq, mode=mode)

                us = _t(loop_call, iters=2, reps=5) / (_GEN_STEPS * b)
                rows.append((f"gen_loop_{label}_b{b}", us,
                             dict(impl="loop", kind=label, batch=b,
                                  tok_s=round(1e6 / us), steps=_GEN_STEPS)))
    return rows


def traced_smoke(*, arch: str = "smollm_360m", requests: int = 6,
                 weight_bits: int = 4, slots: int = 3, max_new: int = 8,
                 trace_out=None, metrics_out=None):
    """Traced serving smoke: the LM server under the flight recorder.

    Serves ``requests`` random prompts through the continuous-batching
    server with a :class:`~repro.obs.Ledger` open, a telemetry registry
    attached, and Chrome-trace span capture on — then (optionally)
    writes the interleaved trace and the metrics snapshot. Before the
    serving run, one eager decode step gates the recorder against the
    static cost model. ``lax.scan`` over the stacked blocks traces its
    body exactly once (the records carry ``traced=True``), so the step
    emits each stacked projection once and the gate compares against the
    report's per-layer-unique cycles — the ``count`` column is pure
    layer multiplicity:

        ledger.total_cycles == slots * sum(p.cycles / p.count)

    Because both sides price launches through
    ``obs.ledger.record_for``, any drift between the instrumented
    dispatch path and the §III-C accounting fails CI here (full
    count-weighted equality is asserted per container kind in
    tests/test_obs.py, where no layer stacking is involved).
    """
    from repro.launch.serve_lm import LMServer, Request, run_and_report
    from repro.obs import Ledger, MetricsRegistry, TraceBuilder

    max_seq = 64
    cfg = load_arch(arch).smoke()
    cfg = dataclasses.replace(cfg, ppac=dataclasses.replace(
        cfg.ppac, enabled=True, weight_bits=weight_bits, act_bits=8,
        min_features=32, backend="auto"))
    params0, _ = lm.init(cfg, jax.random.PRNGKey(0))
    params = convert_params_for_serving(params0, cfg)
    report = serving_cycle_report(params, cfg)

    trace = TraceBuilder()
    metrics = MetricsRegistry()
    with Ledger() as flight:  # outer: every launch -> the trace
        # -- golden gate: eager decode step vs the static cycle report
        cache, _ = lm.init_cache(cfg, slots, max_seq)
        toks = jnp.ones((slots, 1), jnp.int32)
        with Ledger() as led, jax.disable_jit(), \
                trace.span("eager_decode_golden", args=dict(slots=slots)):
            lm.decode_step(params, cfg, toks, cache, mode="serve")
        per_layer = sum(p.cycles // p.count for p in report.projections)
        expect = slots * per_layer
        assert led.total_cycles == expect, (
            f"flight-recorder drift: eager decode step recorded "
            f"{led.total_cycles} cycles, cost model prices it at "
            f"{expect} ({slots} slots x {per_layer} per-layer-unique "
            f"cycles/token; full report: {report.cycles_per_token})")
        print(f"golden gate OK: {led.total_cycles} recorded cycles == "
              f"{slots} slots x {per_layer} per-layer-unique cycles/token "
              f"({len(led.records)} launches, "
              f"{led.total_energy_nj:.1f} nJ modeled, report "
              f"{report.cycles_per_token} cycles/token over "
              f"{len(report.projections)} projections)")

        # -- the served run, spans + telemetry on
        server = LMServer(cfg, params, slots=slots, max_seq=max_seq,
                          mode="serve", metrics=metrics, trace=trace)
        rng = np.random.default_rng(0)
        run_and_report(
            server,
            [Request(i, rng.integers(0, cfg.vocab, int(rng.integers(4, 16))),
                     max_new) for i in range(requests)],
            report=report)
    trace.add_ledger(flight)

    lat = metrics.histogram("lm_ttft_s")
    assert lat.count == requests, "telemetry lost requests"
    if trace_out:
        trace.write(trace_out)
        print(f"wrote {trace.num_events} trace events to {trace_out} "
              f"(load in https://ui.perfetto.dev)")
    if metrics_out:
        payload = dict(metrics=metrics.snapshot(),
                       serving_cycle_report=report.as_dict(),
                       ledger=flight.summary())
        with open(metrics_out, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"wrote metrics snapshot to {metrics_out}")
    return trace, metrics, report


def main(argv=None):
    ap = argparse.ArgumentParser(
        description="traced serving smoke (see module docstring)")
    ap.add_argument("--arch", default="smollm_360m")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--weight-bits", type=int, default=4,
                    choices=(1, 2, 3, 4, 8))
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the Chrome-trace JSON (Perfetto-loadable)")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write the telemetry + cycle-report snapshot JSON")
    ap.add_argument("--mesh-bench", default=None, metavar="PATH",
                    help="run only the multi-device serve_mesh sweep and "
                         "write its rows as benchmarks.run-schema JSON "
                         "(CI runs this under forced-host devices)")
    args = ap.parse_args(argv)
    if args.mesh_bench:
        from .run import derived_string
        rows = _mesh_rows()
        if not rows:
            print("mesh bench: single-device runtime — set XLA_FLAGS="
                  "--xla_force_host_platform_device_count=N", file=sys.stderr)
            return 1
        payload = [dict(module="serving", name=name, us_per_call=us,
                        derived=derived_string(extras), **extras)
                   for name, us, extras in rows]
        with open(args.mesh_bench, "w") as f:
            json.dump(payload, f, indent=2)
        for name, us, extras in rows:
            print(f"{name},{us:.1f},{derived_string(extras)}")
        print(f"wrote {len(payload)} mesh rows to {args.mesh_bench}",
              file=sys.stderr)
        return 0
    traced_smoke(arch=args.arch, requests=args.requests,
                 weight_bits=args.weight_bits, slots=args.slots,
                 max_new=args.max_new, trace_out=args.trace_out,
                 metrics_out=args.metrics_out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
