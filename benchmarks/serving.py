"""Serving-path benchmark: LM decode-step latency + emulated PPAC cycles.

One decode step of a small LM is timed per resident weight container
(bf16 float baseline, int8 MXU fallback, packed4 / packed1 fused PPAC
kernels) and priced in the paper's §III-C K·L cycle accounting aggregated
over every projection — the Table II NN-inference story at model scale.

The packed kinds run twice: the zero-repack fast path (grouped wqkv/wig
containers, in-kernel activation bit-slicing, load-time MXU shadow) and
the pre-PR ``*_prepack`` path (per-projection containers, per-call weight
unpacking on the MXU lowering) — the before/after pair the perf
trajectory tracks. ``benchmarks.check_serving`` gates CI on the fast path
beating the prepack path and staying at least level with int8.

Timing is a warmed, fixed-iteration, ``lax``-free python loop; the
reported figure is the p50 over >= 5 repetitions (single-rep means on a
shared CI box are noisy enough to hide a 20% regression).
"""
import dataclasses
import statistics
import time

import jax
import jax.numpy as jnp

from repro.configs import load_arch
from repro.models import lm
from repro.serve.step import convert_params_for_serving, serving_cycle_report

# (weight_bits, label, fast path?) — fast = grouped + resident shadow,
# prepack = the pre-PR per-projection / per-call-unpack layout.
_CONTAINERS = [
    (0, "float_bf16", True),
    (8, "int8", True),
    (4, "packed4", True),
    (1, "packed1", True),
    (4, "packed4_prepack", False),
    (1, "packed1_prepack", False),
]


def _t(fn, *, iters: int = 10, reps: int = 7):
    """p50 per-call µs: compile + warm, then ``reps`` timed runs of a
    fixed ``iters``-iteration python loop (block once per run)."""
    jax.block_until_ready(fn())  # compile
    jax.block_until_ready(fn())  # warm
    samples = []
    for _ in range(reps):
        t0 = time.perf_counter()
        r = None
        for _ in range(iters):
            r = fn()
        jax.block_until_ready(r)
        samples.append((time.perf_counter() - t0) / iters * 1e6)
    return statistics.median(samples)


def run():
    rows = []
    base = load_arch("stablelm_12b").smoke()
    params0, _ = lm.init(base, jax.random.PRNGKey(0))
    slots, max_seq = 2, 32
    for wb, label, fast in _CONTAINERS:
        if wb == 0:
            cfg, params, mode, rep = base, params0, "float", None
        else:
            cfg = dataclasses.replace(
                base, ppac=dataclasses.replace(
                    base.ppac, enabled=True, weight_bits=wb, act_bits=8,
                    min_features=32))
            # fast: grouped containers + platform-default shadow policy;
            # prepack: per-projection, no shadow (per-call unpack — pre-PR)
            params = convert_params_for_serving(
                params0, cfg, group=fast, store_shadow=None if fast else False)
            mode = "serve"
            rep = serving_cycle_report(params, cfg)

        cache, _ = lm.init_cache(cfg, slots, max_seq)
        _, cache = jax.jit(
            lambda p, b, c, cfg=cfg, mode=mode: lm.prefill(p, cfg, b, c,
                                                           mode=mode)
        )(params, {"tokens": jnp.ones((slots, 8), jnp.int32)}, cache)
        decode = jax.jit(
            lambda p, t, c, cfg=cfg, mode=mode: lm.decode_step(p, cfg, t, c,
                                                               mode=mode))
        tok = jnp.ones((slots, 1), jnp.int32)
        us = _t(lambda: decode(params, tok, cache)[0])
        derived = (f"cycles_per_tok={rep.cycles_per_token};"
                   f"fused={rep.fused_cycles_per_token};"
                   f"path={'fast' if fast else 'prepack'}" if rep
                   else "float baseline")
        rows.append((f"serve_decode_{label}_b{slots}", us, derived))
    return rows
